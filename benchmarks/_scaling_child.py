"""Child for bench_scaling: times distributed solves on N fake devices.

Wall-clock on fake (single-core) devices measures per-iteration WORK, not
parallel speedup — the honest quantity here is the p-BiCGSafe vs
ssBiCGSafe2 per-iteration cost ratio at zero network latency (the paper's
Table 3.1 overhead, measured end-to-end).
"""
import os
import sys

n_dev = sys.argv[1] if len(sys.argv) > 1 else "4"
os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"

import json  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402

from repro.core import (SolverConfig, pbicgsafe_solve,  # noqa: E402
                        ssbicgsafe2_solve)
from repro.core import matrices as M  # noqa: E402
from repro.core.distributed import distributed_stencil_solve  # noqa: E402


def main():
    nd = int(n_dev)
    op, b, _ = M.convection_diffusion(32, peclet=1.0)   # 32^3 = 32768 rows
    b_grid = b.reshape(32, 32, 32)
    from repro.core.compat import make_mesh
    mesh = make_mesh((nd,), ("rows",))
    out = {"devices": nd}
    for name, solver in (("ssbicgsafe2", ssbicgsafe2_solve),
                         ("p-bicgsafe", pbicgsafe_solve)):
        cfg = SolverConfig(tol=1e-30, maxiter=60)   # fixed 60 iterations
        fn = jax.jit(lambda bb: distributed_stencil_solve(
            solver, op, bb, mesh, config=cfg, jit=False))
        r = fn(b_grid)
        jax.block_until_ready(r.x)                  # compile + warm
        t0 = time.perf_counter()
        reps = 3
        for _ in range(reps):
            r = fn(b_grid)
            jax.block_until_ready(r.x)
        dt = (time.perf_counter() - t0) / reps
        out[name] = {"time_s": dt, "iters": int(r.iterations),
                     "per_iter_us": dt / max(int(r.iterations), 1) * 1e6}
    print(json.dumps(out))


if __name__ == "__main__":
    main()
