"""Observability overhead: traced + metered solves vs. bare solves.

The observe layer's contract is "zero extra synchronizations": the
iteration-trace ring is written inside the loop body from values the
iteration already computed (no reduction, no edge to the in-flight
matvec — contract-verified in tests/test_observe.py), spans and metrics
touch only the host side.  This bench pins the price of that contract:

* session — ``solver.solve(b, trace=True)`` (full-maxiter ring) vs. the
  same warm session's bare ``solve(b)``; measured warm, best-of-k, so
  the gap is the ring write + the one extra buffer in the result, not
  compilation.
* engine — a saturated engine burst with ``ServiceConfig.trace_cap``
  set (per-request trace harvest riding the retirement read, spans +
  metrics live) vs. the identical burst untraced.
* profile — one ``solve(b, profile=...)`` device-timeline capture
  (:mod:`repro.observe.profile`): records the capture's wall cost next
  to a bare solve and the parsed report's headline fields.  Captures
  are diagnostic (they hold the whole timeline), so this leg has no
  budget — the artifact pins that the capture path stays functional
  and what it costs.

Asserted: the session/engine ratios <= 1.05 (the 5% budget the issue
sets).

Artifact: experiments/bench_observe.json.

  PYTHONPATH=src python -m benchmarks.run --only observe
"""
from __future__ import annotations

import time

import jax
import numpy as np

from .common import fmt_table, write_json

jax.config.update("jax_enable_x64", True)

#: wall-time ratio budget for full observability vs. bare
BUDGET = 1.05


def _best(fn, repeats: int) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def _session_overhead(quick: bool):
    import repro
    from repro.core import SolverConfig
    from repro.core import matrices as M

    # sized so the iteration loop dominates dispatch (same rationale as
    # bench_robustness): tiny problems measure python, not the ring
    nx = 16 if quick else 20
    repeats = 3 if quick else 5
    op, b, _ = M.convection_diffusion(nx, peclet=1.0)
    maxiter = 400
    solver = repro.make_solver(
        "p-bicgsafe", op, config=SolverConfig(tol=1e-8, maxiter=maxiter))

    jax.block_until_ready(solver.solve(b).x)              # warm bare
    jax.block_until_ready(solver.solve(b, trace=True).x)  # warm traced
    t_bare = _best(lambda: solver.solve(b).x, repeats)
    t_traced = _best(lambda: solver.solve(b, trace=True).x, repeats)
    ratio = t_traced / t_bare
    return dict(n=op.shape[0], maxiter=maxiter,
                t_bare_s=t_bare, t_traced_s=t_traced,
                overhead_ratio=ratio, overhead_pct=100.0 * (ratio - 1.0))


def _engine_overhead(quick: bool):
    from repro.core import matrices as M
    from repro.service import ServiceConfig, SolveEngine

    nx = 8
    n_req = 16 if quick else 48
    repeats = 2 if quick else 3
    op, b, _ = M.convection_diffusion(nx, peclet=1.0)
    rng = np.random.default_rng(7)
    rhs = rng.standard_normal((op.shape[0], n_req))

    def burst(trace_cap: int) -> float:
        scfg = ServiceConfig(max_batch=8, chunk=12, tol=1e-8,
                             maxiter=2000, trace_cap=trace_cap)
        eng = SolveEngine(scfg, clock=time.perf_counter)
        name = eng.register(op)
        for j in range(scfg.max_batch + 1):       # warm all programs
            eng.submit(name, rhs[:, j % n_req], tol=1e-6)
        eng.run()
        best = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            for j in range(n_req):
                eng.submit(name, rhs[:, j])
            results = eng.run()
            best = min(best, time.perf_counter() - t0)
            assert len(results) == n_req
            assert all((r.trace is not None) == bool(trace_cap)
                       for r in results)
        return best

    t_bare = burst(0)
    t_traced = burst(128)
    ratio = t_traced / t_bare
    return dict(n=op.shape[0], n_requests=n_req, trace_cap=128,
                t_bare_s=t_bare, t_traced_s=t_traced,
                overhead_ratio=ratio, overhead_pct=100.0 * (ratio - 1.0))


def _profile_capture(quick: bool):
    import repro
    from .common import runtime_dir
    from repro.core import SolverConfig
    from repro.core import matrices as M

    nx = 6 if quick else 8
    op, b, _ = M.poisson3d(nx)
    solver = repro.make_solver(
        "p-bicgsafe", op, config=SolverConfig(tol=1e-8, maxiter=800))
    jax.block_until_ready(solver.solve(b).x)              # warm
    t_bare = _best(lambda: solver.solve(b).x, 2)
    out = runtime_dir("profile", "bench_observe")
    t0 = time.perf_counter()
    solver.solve(b, profile=str(out))
    t_cap = time.perf_counter() - t0
    rep = solver.last_profile
    return dict(n=op.shape[0], t_bare_s=t_bare, t_captured_s=t_cap,
                capture_cost_ratio=t_cap / t_bare,
                device_wall_us=rep.device_wall_us,
                n_device_events=rep.n_device_events,
                overlap_efficiency=rep.overlap_efficiency)


def run(quick: bool = False):
    print("\n== bench_observe (tracing + metrics overhead budget) ==")
    sess = _session_overhead(quick)
    eng = _engine_overhead(quick)
    prof = _profile_capture(quick)
    print(f"profile capture: bare {prof['t_bare_s'] * 1e3:.1f} ms vs "
          f"captured+parsed {prof['t_captured_s'] * 1e3:.1f} ms "
          f"({prof['n_device_events']} device events, "
          f"device wall {prof['device_wall_us'] / 1e3:.2f} ms)")
    rows = [
        ["session solve", sess["n"], f"{sess['t_bare_s'] * 1e3:.1f}",
         f"{sess['t_traced_s'] * 1e3:.1f}",
         f"{sess['overhead_pct']:+.2f}%"],
        ["engine burst", eng["n"], f"{eng['t_bare_s'] * 1e3:.1f}",
         f"{eng['t_traced_s'] * 1e3:.1f}",
         f"{eng['overhead_pct']:+.2f}%"],
    ]
    print(fmt_table(rows, headers=["path", "n", "bare ms", "traced ms",
                                   "overhead"]))
    # artifact first, assertion second: a failed budget check should
    # still leave the measurements on disk for CI to upload
    path = write_json("bench_observe.json",
                      dict(budget_ratio=BUDGET, session=sess, engine=eng,
                           profile=prof, quick=quick))
    print(f"\nwrote {path}")
    for name, r in (("session", sess), ("engine", eng)):
        assert r["overhead_ratio"] <= BUDGET, (
            f"{name} observability overhead {r['overhead_pct']:.2f}% "
            f"exceeds the {100 * (BUDGET - 1):.0f}% budget")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    run(quick=ap.parse_args().quick)
