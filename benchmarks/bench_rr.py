"""Paper §5.2 / Fig 5.2 analogue: residual replacement on hard matrices.

On ill-conditioned systems the recurred residual of p-BiCGSafe drifts from
the true residual and stagnates above tol while ssBiCGSafe2 converges;
p-BiCGSafe-rr (Alg. 4.1) restores convergence.  We report, per matrix:
converged?, iterations, final recurred relres, and final TRUE relres
||b - A x|| / ||b|| (the drift is the gap between the last two).
"""
from __future__ import annotations

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import (SolverConfig, as_matvec, pbicgsafe_rr_solve,  # noqa: E402
                        pbicgsafe_solve, ssbicgsafe2_solve)
from repro.core import matrices as M  # noqa: E402

from .common import fmt_table, write_json  # noqa: E402

HARD = {
    # thousands of iterations in fp64 -> the recurred/true drift shows
    # (cf. paper's sherman3 / utm5940)
    "hard_sr3.0": lambda: M.hard_nonsym(1200, seed=3, scale_range=3.0),
    "hard_sr3.5": lambda: M.hard_nonsym(1200, seed=3, scale_range=3.5),
}


def solve_and_measure(solver, mv, b, **kw):
    cfg = SolverConfig(tol=1e-8, maxiter=10_000, **kw)
    res = solver(mv, b, config=cfg)
    true_res = float(jnp.linalg.norm(b - mv(res.x)) / jnp.linalg.norm(b))
    it = int(res.iterations)
    return {"converged": bool(res.converged), "iters": it,
            "relres": float(res.relres), "true_relres": true_res}


def run(quick: bool = False):
    rows = []
    recs = {}
    problems = dict(list(HARD.items())[:1]) if quick else HARD
    for name, gen in problems.items():
        op, b, xt = gen()
        mv = as_matvec(op)
        recs[name] = {
            "ssbicgsafe2": solve_and_measure(ssbicgsafe2_solve, mv, b),
            "p-bicgsafe": solve_and_measure(pbicgsafe_solve, mv, b),
            "p-bicgsafe-rr(m=100)": solve_and_measure(
                pbicgsafe_rr_solve, mv, b, rr_epoch=100),
            "p-bicgsafe-rr(m=50)": solve_and_measure(
                pbicgsafe_rr_solve, mv, b, rr_epoch=50),
        }
        for mname, r in recs[name].items():
            gap = r["true_relres"] / max(r["relres"], 1e-300)
            r["drift_gap"] = gap
            rows.append([name, mname,
                         "yes" if r["converged"] else "NO",
                         r["iters"], f"{r['relres']:.1e}",
                         f"{r['true_relres']:.1e}", f"{gap:.1f}x"])

    print("\n== bench_rr (paper §5.2 analogue) ==")
    print(fmt_table(rows, ["matrix", "method", "conv", "iters",
                           "recurred", "true", "drift"]))
    # Paper claims validated:
    #  (1) plain p-BiCGSafe's recurred residual DRIFTS from the true
    #      residual on hard matrices (it can report convergence the true
    #      residual does not support);
    #  (2) residual replacement keeps recurred ~= true (drift ~1x), at the
    #      cost of delayed convergence (paper: "delayed convergence
    #      phenomenon... should not be used as a complete replacement").
    claims = {}
    for n in recs:
        p_gap = recs[n]["p-bicgsafe"]["drift_gap"]
        rr_gap = min(recs[n]["p-bicgsafe-rr(m=100)"]["drift_gap"],
                     recs[n]["p-bicgsafe-rr(m=50)"]["drift_gap"])
        claims[n] = {"p_drift": p_gap, "rr_drift": rr_gap,
                     "rr_truthful": rr_gap < 3.0}
    write_json("bench_rr.json", {"results": recs, "claims": claims})
    print(f"claims: {claims}")
    return recs


if __name__ == "__main__":
    run()
