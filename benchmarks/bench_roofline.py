"""§Roofline: the three roofline terms per (arch x shape) from the
compiled dry-run artifacts (experiments/dryrun/).

    compute term    = HLO_FLOPs / (chips x 197 TFLOP/s bf16)
    memory term     = HLO_bytes / (chips x 819 GB/s)
    collective term = wire_bytes / (chips x 50 GB/s/link); wire bytes are
                      parsed from the compiled HLO (hlo_analysis.py) since
                      cost_analysis() does not report collectives.

Also reported: MODEL_FLOPS = 6*N(_active)*D and the usefulness ratio
MODEL_FLOPS / HLO_FLOPs (catches remat/redundancy waste), the dominant
term, and what would move it (EXPERIMENTS.md §Roofline).

NOTE on chips: dry-run cost_analysis is for the per-device SPMD program,
so the terms below use the per-device numbers directly (no extra /chips).
"""
from __future__ import annotations

import json
from pathlib import Path

from .common import fmt_table, write_json

PEAK = 197e12          # bf16 FLOP/s per chip
HBM = 819e9            # B/s per chip
LINK = 50e9            # B/s per ICI link

# parameter counts (total, active) in billions — from the configs
PARAMS_B = {
    "phi3-mini-3.8b": (3.7, 3.7),
    "qwen2.5-32b": (32.8, 32.8),
    "qwen3-8b": (8.0, 8.0),
    "qwen1.5-110b": (111.2, 111.2),
    "deepseek-v3-671b": (672.0, 37.0),
    "llama4-scout-17b-a16e": (108.6, 16.8),
    "zamba2-1.2b": (1.2, 1.2),
    "xlstm-350m": (0.35, 0.35),
    "whisper-tiny": (0.039, 0.039),
    "qwen2-vl-72b": (72.7, 72.7),
}

TOKENS = {"train_4k": 4096 * 256, "prefill_32k": 32768 * 32,
          "decode_32k": 128, "long_500k": 1}
TRAIN_MULT = {"train_4k": 3.0}     # fwd+bwd = 3x fwd model flops


def roofline_row(rec: dict, chips: int):
    """Terms per chip.

    compute/memory: analytic jaxpr counts (global / chips) — the compiled
    cost_analysis undercounts scan bodies (counted once) and oneDNN
    matmuls (zero flops on CPU backend), so it is kept only as an
    auxiliary lower bound ("hlo_flops").  memory uses matmul-adjacent
    bytes (fusion-optimistic).  collective: wire bytes parsed from the
    compiled per-device HLO with layer-scan trip-count correction.
    """
    arch, shape = rec["arch"], rec["shape"]
    flops = (rec.get("analytic_global_flops") or 0.0) / chips
    if arch.startswith("solver-"):
        # stencil matvecs have no dot_general: elementwise streams ARE the
        # HBM traffic -> unfused byte count (upper bound; select/where
        # chains double-count), and shard_map jaxprs are already
        # per-shard so no /chips.  No bf16 discount (genuine f64/f32).
        byts = rec.get("analytic_global_bytes") or 0.0
        flops = rec.get("analytic_global_flops") or 0.0
        coll = rec.get("collectives") or {}
        wire = coll.get("total_wire_bytes", 0.0)
    else:
        byts = (rec.get("analytic_global_dot_bytes")
                or rec.get("analytic_global_bytes") or 0.0) / chips
        coll = rec.get("collectives") or {}
        wire = coll.get("tpu_wire_bytes", coll.get("total_wire_bytes", 0.0))
    t_c = flops / PEAK
    t_m = byts / HBM
    t_x = wire / LINK
    dom = max((t_c, "compute"), (t_m, "memory"), (t_x, "collective"))[1]
    if arch in PARAMS_B and shape in TOKENS:
        tot, act = PARAMS_B[arch]
        mult = TRAIN_MULT.get(shape, 1.0)
        model_flops = 2 * act * 1e9 * TOKENS[shape] * mult / chips
        useful = model_flops / flops if flops else 0.0
        bound = max(t_c, t_m, t_x)
        frac = (model_flops / PEAK) / bound if bound else 0.0
    else:  # solver cells: useful flops == analytic flops (per iteration)
        model_flops = flops
        useful = 1.0
        bound = max(t_c, t_m, t_x)
        frac = t_c / bound if bound else 0.0
    return {
        "arch": arch, "shape": shape,
        "t_compute_s": t_c, "t_memory_s": t_m, "t_collective_s": t_x,
        "dominant": dom, "model_flops_per_chip": model_flops,
        "hlo_flops_per_chip": rec.get("flops"),
        "useful_ratio": useful, "roofline_fraction": frac,
    }


QUICK_GRID = (256, 32, 32)       # nx must divide by the 256-chip mesh
QUICK_SOLVERS = ("p-bicgsafe", "ssbicgsafe2")


def _ensure_quick_artifacts(out: Path, mesh: str) -> None:
    """Compile the small-grid solver cells in a subprocess (the dry-run
    module forces 512 fake host devices via XLA_FLAGS at import — it
    must not pollute this process)."""
    import subprocess
    import sys

    nx, ny, nz = QUICK_GRID
    for solver in QUICK_SOLVERS:
        cell = out / mesh / f"solver-{solver}__poisson{nx}x{ny}x{nz}.json"
        if cell.exists():
            continue
        subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun_solver",
             "--solver", solver, "--nx", str(nx), "--ny", str(ny),
             "--nz", str(nz), "--maxiter", "50", "--out", str(out),
             "--force"],
            check=True, timeout=600)


def overlap_claims(recs: dict) -> dict:
    """Roofline-model form of the paper's claim: the pipelined solver's
    per-iteration reduction wire time fits inside the matvec stream
    (compute + HBM terms) it is scheduled to overlap with.  The (9, m)
    fused reduction moves scalars; the halo exchange moves faces — so
    the reduction term should be orders of magnitude under the window.
    """
    pip = next((r for k, r in recs.items()
                if k.startswith("solver-p-bicgsafe__")), None)
    if pip is None:
        return {}
    raw = pip.get("_collectives", {})
    red_wire = (raw.get("wire_bytes") or {}).get("all-reduce", 0.0)
    t_red = red_wire / LINK
    window = pip["t_compute_s"] + pip["t_memory_s"]
    return {
        "pipelined_hides_reduction": bool(t_red <= window),
        "reduction_wire_bytes_per_iter": red_wire,
        "t_reduction_s": t_red,
        "overlap_window_s": window,
    }


def run(quick: bool = False, mesh: str = "pod16x16"):
    if quick:
        base = Path("experiments/runtime/dryrun_quick")
        _ensure_quick_artifacts(base, mesh)
        d = base / mesh
    else:
        d = Path("experiments/dryrun") / mesh
    chips = 256 if mesh == "pod16x16" else 512
    rows, recs = [], {}
    if not d.exists():
        print(f"(no dry-run artifacts under {d}; run repro.launch.dryrun)")
        return {}
    for f in sorted(d.glob("*.json")):
        rec = json.loads(f.read_text())
        if rec.get("status") != "ok":
            continue
        r = roofline_row(rec, chips)
        r["_collectives"] = rec.get("collectives") or {}
        recs[f"{r['arch']}__{r['shape']}"] = r
        rows.append([
            r["arch"], r["shape"],
            f"{r['t_compute_s']*1e3:.2f}", f"{r['t_memory_s']*1e3:.2f}",
            f"{r['t_collective_s']*1e3:.2f}", r["dominant"],
            f"{r['useful_ratio']:.2f}", f"{r['roofline_fraction']:.3f}"])
    print(f"\n== bench_roofline ({mesh}, per-chip terms"
          f"{', quick grid' if quick else ''}) ==")
    print(fmt_table(rows, ["arch", "shape", "t_comp ms", "t_mem ms",
                           "t_coll ms", "dominant", "useful",
                           "roofline_frac"]))
    claims = overlap_claims(recs)
    if claims:
        print(f"  pipelined reduction {claims['t_reduction_s']:.2e}s vs "
              f"overlap window {claims['overlap_window_s']:.2e}s -> "
              f"hidden={claims['pipelined_hides_reduction']}")
    doc = {"mesh": mesh, "mode": "quick" if quick else "full",
           "cells": recs, "claims": claims}
    write_json("bench_roofline.json", doc)
    return doc


if __name__ == "__main__":
    run()
