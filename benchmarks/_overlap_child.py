"""Child process for bench_overlap: lowers the distributed solvers on an
8-device mesh and reports collective/matvec dependency structure as JSON.

Thin consumer of :func:`repro.analysis.hlo.overlap_report` — the HLO
backend of the contract analyzer owns the dependency analysis; this
child only builds the compiled texts on the fake 8-device mesh.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import json  # noqa: E402

import jax  # noqa: E402

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402

from repro.analysis.hlo import overlap_report  # noqa: E402
from repro.core import (SolverConfig, pbicgsafe_solve,  # noqa: E402
                        ssbicgsafe2_solve)
from repro.core import matrices as M  # noqa: E402
from repro.core.distributed import (distributed_stencil_solve,  # noqa: E402
                                    distributed_stencil_solve_batched)


def analyze(solver, op, b_grid, mesh, precond=None):
    fn = jax.jit(lambda b: distributed_stencil_solve(
        solver, op, b, mesh, config=SolverConfig(maxiter=100),
        precond=precond, jit=False))
    return overlap_report(fn.lower(b_grid).compile().as_text())


def analyze_batched(op, B_grid, mesh):
    """Batched+sharded p-BiCGSafe: the (9, m) block all-reduce must keep
    the no-dependency edge to the in-flight block matvec's halo permutes —
    batching the reduction must not serialize it behind the SpMV."""
    fn = jax.jit(lambda B: distributed_stencil_solve_batched(
        op, B, mesh, config=SolverConfig(maxiter=100), jit=False))
    return overlap_report(fn.lower(B_grid).compile().as_text())


def main():
    op, b, _ = M.convection_diffusion(16, peclet=1.0)
    b_grid = b.reshape(16, 16, 16)
    from repro.core.compat import make_mesh
    mesh = make_mesh((8,), ("rows",))
    m = 4
    keys = jax.random.split(jax.random.PRNGKey(0), m)
    B_grid = jnp.stack([b] + [jax.random.normal(k, b.shape, b.dtype)
                              for k in keys[1:]], axis=1).reshape(16, 16, 16, m)
    out = {
        "p-bicgsafe": analyze(pbicgsafe_solve, op, b_grid, mesh),
        "ssbicgsafe2": analyze(ssbicgsafe2_solve, op, b_grid, mesh),
        "p-bicgsafe-batched": analyze_batched(op, B_grid, mesh),
        # preconditioned pipelined solve: the shard-local block-Jacobi
        # M^{-1}-apply joins the in-flight matvec inside the overlap
        # window — the all-reduce must STILL not depend on any halo
        # permute (reduction_needs_permutes == 0)
        "p-bicgsafe-block-jacobi": analyze(pbicgsafe_solve, op, b_grid,
                                           mesh, precond="block_jacobi"),
    }
    print(json.dumps(out))


if __name__ == "__main__":
    main()
