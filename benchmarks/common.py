"""Shared benchmark helpers."""
from __future__ import annotations

import json
import time
from pathlib import Path

OUT = Path("experiments")


def write_json(name: str, obj):
    OUT.mkdir(exist_ok=True)
    p = OUT / name
    p.write_text(json.dumps(obj, indent=2, default=str))
    return p


def fmt_table(rows, headers):
    widths = [max(len(str(h)), *(len(str(r[i])) for r in rows))
              for i, h in enumerate(headers)]
    out = ["  ".join(str(h).ljust(w) for h, w in zip(headers, widths))]
    out.append("  ".join("-" * w for w in widths))
    for r in rows:
        out.append("  ".join(str(c).ljust(w) for c, w in zip(r, widths)))
    return "\n".join(out)
