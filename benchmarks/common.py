"""Shared benchmark helpers."""
from __future__ import annotations

import json
import time
from datetime import datetime, timezone
from pathlib import Path

OUT = Path("experiments")

#: un-committed runtime output (profiler captures, quick dry-runs,
#: trajectory reports) — ``.gitignore``'s ``experiments/*`` rule keeps
#: everything under here out of the repo; only the schema-stamped
#: ``experiments/*.json`` artifacts are tracked
RUNTIME_OUT = OUT / "runtime"


def runtime_dir(*parts: str) -> Path:
    """Create (if needed) and return a directory under the ignored
    ``experiments/runtime/`` tree for a bench's scratch output."""
    p = RUNTIME_OUT.joinpath(*parts)
    p.mkdir(parents=True, exist_ok=True)
    return p


def write_json(name: str, obj):
    """Write one benchmark artifact under experiments/.

    Every artifact is stamped with a ``schema`` id (derived from the
    file name: ``repro.benchmarks/<stem>/v1``) and a ``generated_at``
    UTC timestamp, so downstream tooling (the observe report CLI, CI
    artifact diffing) can identify and order what it is reading.
    Payload keys win on collision — a bench that declares its own
    ``schema`` keeps it.
    """
    OUT.mkdir(exist_ok=True)
    p = OUT / name
    stamped = {"schema": f"repro.benchmarks/{p.stem}/v1",
               "generated_at": datetime.now(timezone.utc).isoformat()}
    if isinstance(obj, dict):
        stamped.update(obj)
    else:
        stamped["data"] = obj
    p.write_text(json.dumps(stamped, indent=2, default=str))
    return p


def fmt_table(rows, headers):
    widths = [max(len(str(h)), *(len(str(r[i])) for r in rows))
              for i, h in enumerate(headers)]
    out = ["  ".join(str(h).ljust(w) for h, w in zip(headers, widths))]
    out.append("  ".join("-" * w for w in widths))
    for r in rows:
        out.append("  ".join(str(c).ljust(w) for c, w in zip(r, widths)))
    return "\n".join(out)
