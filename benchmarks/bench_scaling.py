"""Paper Fig 5.3 companion: measured per-iteration cost of the distributed
solvers at several device counts (fake CPU devices — measures the
per-iteration WORK overhead of pipelining at zero comm latency; the
latency-dependent speedup is modeled in bench_overlap).

Expectation (validates paper Table 3.1): p-BiCGSafe pays a bounded
per-iteration overhead (extra recurrence AXPYs) relative to ssBiCGSafe2 —
the price paid to make the reduction hideable.  On a zero-latency fabric
the ratio is <~1.6x; the latency model shows where hiding wins it back.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

from .common import fmt_table, write_json


def run(quick: bool = False):
    env = dict(os.environ)
    src = os.path.abspath(os.path.join(os.path.dirname(__file__),
                                       os.pardir, "src"))
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)

    counts = [1, 4] if quick else [1, 2, 4, 8]
    rows, recs = [], {}
    for nd in counts:
        proc = subprocess.run(
            [sys.executable, os.path.join(os.path.dirname(__file__),
                                          "_scaling_child.py"), str(nd)],
            capture_output=True, text=True, env=env, timeout=1800)
        if proc.returncode != 0:
            rows.append([nd, "ERR", "", ""])
            recs[nd] = {"error": proc.stderr[-1000:]}
            continue
        rec = json.loads(proc.stdout.strip().splitlines()[-1])
        recs[nd] = rec
        ratio = rec["p-bicgsafe"]["per_iter_us"] / \
            rec["ssbicgsafe2"]["per_iter_us"]
        rows.append([nd,
                     f"{rec['ssbicgsafe2']['per_iter_us']:.0f}",
                     f"{rec['p-bicgsafe']['per_iter_us']:.0f}",
                     f"{ratio:.2f}x"])
    print("\n== bench_scaling (zero-latency per-iteration work) ==")
    print(fmt_table(rows, ["devices", "ss us/iter", "p us/iter",
                           "p overhead"]))
    write_json("bench_scaling.json", recs)
    return recs


if __name__ == "__main__":
    run()
