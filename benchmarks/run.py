"""Benchmark harness: one module per paper table/figure.

  python -m benchmarks.run [--quick]

  bench_convergence   Table 5.2 + Fig 5.1  (iteration counts, histories)
  bench_rr            §5.2 / Fig 5.2       (residual replacement)
  bench_cost          Table 3.1            (per-iteration op counts)
  bench_overlap       §3 Fig 3.1 + Fig 5.3 (HLO overlap proof + model)
  bench_scaling       Fig 5.3 companion    (measured per-iter work)
  bench_roofline      §Roofline            (terms from dry-run artifacts)
  bench_multirhs      multi-RHS            (batched vs looped solves)
  bench_precond       preconditioning      (precond vs not, per solver)
  bench_service       solve service        (continuous batching vs
                                            sequential / static batch)
  bench_api           bind-once sessions   (repeat-solve amortization vs
                                            legacy free functions)
  bench_robustness    guarded solves       (clean-path overhead budget +
                                            fault-injection recovery)
  bench_observe       observability        (trace/metrics overhead
                                            budget, session + engine)

Artifacts land in experiments/*.json; stdout is the human summary.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller problem set (CI-sized)")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of bench names")
    args = ap.parse_args()

    from . import (bench_api, bench_convergence, bench_cost, bench_multirhs,
                   bench_observe, bench_overlap, bench_precond,
                   bench_robustness, bench_roofline, bench_rr,
                   bench_scaling, bench_service)

    benches = {
        "api": bench_api.run,
        "robustness": bench_robustness.run,
        "observe": bench_observe.run,
        "convergence": bench_convergence.run,
        "rr": bench_rr.run,
        "cost": bench_cost.run,
        "overlap": bench_overlap.run,
        "scaling": bench_scaling.run,
        "roofline": bench_roofline.run,
        "multirhs": bench_multirhs.run,
        "precond": bench_precond.run,
        "service": bench_service.run,
    }
    if args.only:
        keep = set(args.only.split(","))
        benches = {k: v for k, v in benches.items() if k in keep}

    failures = []
    for name, fn in benches.items():
        t0 = time.time()
        print(f"\n################ {name} ################")
        try:
            fn(quick=args.quick)
            print(f"[{name}] done in {time.time() - t0:.1f}s")
        except Exception:  # noqa: BLE001
            failures.append(name)
            traceback.print_exc()
            print(f"[{name}] FAILED")
    if failures:
        print(f"\nFAILED benches: {failures}")
        sys.exit(1)
    print("\nall benches ok")


if __name__ == "__main__":
    main()
