"""Benchmark harness: one module per paper table/figure.

  python -m benchmarks.run [--quick] [--only a,b]

  bench_convergence   Table 5.2 + Fig 5.1  (iteration counts, histories)
  bench_rr            §5.2 / Fig 5.2       (residual replacement)
  bench_cost          Table 3.1            (per-iteration op counts)
  bench_overlap       §3 Fig 3.1 + Fig 5.3 (HLO overlap proof + model +
                                            measured overlap)
  bench_scaling       Fig 5.3 companion    (measured per-iter work)
  bench_roofline      §Roofline            (terms from dry-run artifacts)
  bench_multirhs      multi-RHS            (batched vs looped solves)
  bench_precond       preconditioning      (precond vs not, per solver)
  bench_service       solve service        (continuous batching vs
                                            sequential / static batch)
  bench_api           bind-once sessions   (repeat-solve amortization vs
                                            legacy free functions)
  bench_robustness    guarded solves       (clean-path overhead budget +
                                            fault-injection recovery)
  bench_observe       observability        (trace/metrics overhead
                                            budget, session + engine)
  bench_scenarios     scenario registry    (declarative matrix sweep:
                                            oracle + contract claims)

Artifacts land in experiments/*.json; stdout is the human summary.

``REGISTRY`` below is the single source of truth the perf-trajectory
gate (:mod:`repro.observe.trajectory`, ``python -m repro.observe
trajectory``) reads: each benchmark declares, next to its registration,
which artifact values are tracked over git history and how much
regression its noise profile tolerates.  ``gate=True`` metrics fail CI
when the current value is worse than the median of the last committed
points by more than ``rel_tol``; ``gate=False`` ("watch") metrics are
wall-clock/throughput numbers that vary machine to machine — trended
and flagged in the report, never fatal.
"""
from __future__ import annotations

import argparse
import importlib
import sys
import time
import traceback

from repro.observe.trajectory import BenchSpec, Metric

REGISTRY = (
    BenchSpec(
        "api", "benchmarks.bench_api", "bench_api.json",
        metrics=(
            Metric("results/jnp/speedup", "higher", 0.5, gate=True,
                   note="session amortization vs legacy free functions"),
            Metric("results/jnp/session_dot_reduce_traces", "lower", 0.0,
                   gate=True,
                   note="retraces of the fused reduction per session"),
        )),
    BenchSpec(
        "robustness", "benchmarks.bench_robustness",
        "bench_robustness.json",
        metrics=(
            Metric("overhead/overhead_ratio", "lower", 0.25, gate=False,
                   note="guarded vs unguarded wall clock (machine noise)"),
        )),
    BenchSpec(
        "observe", "benchmarks.bench_observe", "bench_observe.json",
        metrics=(
            Metric("session/overhead_ratio", "lower", 0.25, gate=False,
                   note="traced vs untraced session solve (wall clock)"),
            Metric("engine/overhead_ratio", "lower", 0.25, gate=False,
                   note="traced vs untraced engine drain (wall clock)"),
        )),
    BenchSpec(
        "convergence", "benchmarks.bench_convergence",
        "bench_convergence.json",
        metrics=(
            Metric("claims/equivalence_ok", "higher", 0.0, gate=True,
                   note="p-BiCGSafe matches BiCGSafe iteration counts"),
            Metric("claims/safe_beats_stab", "higher", 0.25, gate=True,
                   note="#matrices where BiCGSafe beats BiCGSTAB"),
        )),
    BenchSpec(
        "rr", "benchmarks.bench_rr", "bench_rr.json",
        metrics=(
            Metric("claims/hard_sr3.0/rr_truthful", "higher", 0.0,
                   gate=True,
                   note="residual replacement keeps the recursion honest"),
        )),
    BenchSpec(
        "cost", "benchmarks.bench_cost", "bench_cost.json",
        metrics=(
            Metric("p-bicgsafe/measured/sync_phases", "lower", 0.0,
                   gate=True,
                   note="the paper's headline: ONE reduction per iter"),
            Metric("p-bicgsafe/measured/mul_n", "lower", 0.1, gate=True,
                   note="Table 3.1 per-iteration multiplies"),
            Metric("p-bicgsafe/measured/carry_vectors", "lower", 0.0,
                   gate=True, note="loop-carried vector count"),
        )),
    BenchSpec(
        "overlap", "benchmarks.bench_overlap", "bench_overlap.json",
        metrics=(
            Metric("claim_ok", "higher", 0.0, gate=True,
                   note="structural proof: reduction independent of A s_i"),
            Metric("batched_claim_ok", "higher", 0.0, gate=True),
            Metric("precond_claim_ok", "higher", 0.0, gate=True),
            Metric("measured/session_jnp/overlap_efficiency", "higher",
                   0.5, gate=False,
                   note="measured overlap is 0 on a serial CPU device; "
                        "trended so a real-overlap substrate shows up"),
            Metric("measured/session_jnp/exposed_per_iter_us", "lower",
                   0.5, gate=False,
                   note="exposed reduction time per iteration (wall "
                        "clock; machine-sensitive)"),
            Metric("measured/mesh/overlap_efficiency", "higher", 0.5,
                   gate=False,
                   note="the 8-device mesh leg DOES overlap (threads "
                        "run concurrently): the paper's claim, measured"),
        )),
    BenchSpec(
        "scaling", "benchmarks.bench_scaling", "bench_scaling.json",
        metrics=(
            Metric("1/p-bicgsafe/per_iter_us", "lower", 0.5, gate=False,
                   note="single-RHS per-iteration wall clock"),
        )),
    BenchSpec(
        "roofline", "benchmarks.bench_roofline", "bench_roofline.json",
        metrics=(
            Metric("claims/pipelined_hides_reduction", "higher", 0.0,
                   gate=True,
                   note="roofline model: reduction latency hidden when "
                        "overlap term is active"),
        )),
    BenchSpec(
        "multirhs", "benchmarks.bench_multirhs", "bench_multirhs.json",
        metrics=(
            Metric("pallas_kernel_path/x_err", "lower", 9.0, gate=True,
                   note="fused-kernel path accuracy — order-of-magnitude "
                        "guard against silent kernel breakage"),
        )),
    BenchSpec(
        "precond", "benchmarks.bench_precond", "bench_precond.json",
        metrics=(
            Metric("trajectory/block_jacobi/converged", "higher", 0.0,
                   gate=True),
            Metric("trajectory/block_jacobi/iterations", "lower", 0.25,
                   gate=True,
                   note="preconditioned iteration count (fp-drift slack)"),
        )),
    BenchSpec(
        "scenarios", "benchmarks.bench_scenarios", "scenario_sweep.json",
        metrics=(
            Metric("summary/n_cells", "higher", 0.0, gate=True,
                   note="registered scenario coverage never shrinks"),
            Metric("claims/all_oracle_ok", "higher", 0.0, gate=True,
                   note="every cell's solution verified by its operator "
                        "plugin's oracle"),
            Metric("claims/all_contracts_ok", "higher", 0.0, gate=True,
                   note="every cell matches the expected contract "
                        "matrix (+ plugin deltas)"),
            Metric("summary/wall_s", "lower", 0.5, gate=False,
                   note="whole-sweep wall clock (machine noise)"),
        )),
    BenchSpec(
        "service", "benchmarks.bench_service", "bench_service.json",
        metrics=(
            Metric("capacity_burst/engine/throughput_rps", "higher", 0.5,
                   gate=False,
                   note="burst throughput (quick mode under-batches; "
                        "wall clock — watch only)"),
        )),
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller problem set (CI-sized)")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of bench names")
    args = ap.parse_args()

    specs = list(REGISTRY)
    if args.only:
        keep = set(args.only.split(","))
        specs = [s for s in specs if s.name in keep]

    failures = []
    for spec in specs:
        t0 = time.time()
        print(f"\n################ {spec.name} ################")
        try:
            importlib.import_module(spec.module).run(quick=args.quick)
            print(f"[{spec.name}] done in {time.time() - t0:.1f}s")
        except Exception:  # noqa: BLE001
            failures.append(spec.name)
            traceback.print_exc()
            print(f"[{spec.name}] FAILED")
    if failures:
        print(f"\nFAILED benches: {failures}")
        sys.exit(1)
    print("\nall benches ok")


if __name__ == "__main__":
    main()
