"""Continuous-batching solve service vs. sequential / static-batch serving.

A Poisson stream of N heterogeneous solve requests (mixed tolerances)
against one operator is served three ways, all warm-compiled, all on the
same arrival trace:

* sequential — one single-RHS solve at a time, FIFO (the "library call"
              serving model every entry point had before repro.service);
* static    — FIFO batches of max_batch: wait until the batch is full
              (or the stream ends), then one ``solve_batched`` call; a
              batch holds its early arrivals hostage and its whole wall
              time is the SLOWEST column's convergence;
* engine    — :class:`repro.service.SolveEngine` continuous batching:
              one resident (n, max_batch) block, converged columns
              retire at chunk boundaries and freed slots are refilled
              mid-flight, ONE (9, m) reduction per iteration for the
              whole block regardless of request mix.

Two measurement phases, standard serving methodology:

* capacity (throughput) — saturated burst: every request is already
  queued at t=0, so the span from start to last completion is pure
  serving capacity, with no arrival-pacing or sleep-granularity noise.
  The acceptance bar (asserted): at max_batch >= 8 the engine beats
  sequential serving on burst throughput.
* latency — the Poisson stream is replayed in wall-clock time at ~2x the
  sequential capacity (an overloaded server, where queueing discipline
  matters); per-request latency is completion minus scheduled arrival,
  reported as p50/p99.

Artifact: experiments/bench_service.json.

  PYTHONPATH=src python -m benchmarks.run --only service
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from .common import fmt_table, write_json

jax.config.update("jax_enable_x64", True)


def _problem(nx: int):
    from repro.core import matrices as M
    return M.convection_diffusion(nx, peclet=1.0)


def _percentiles(lats_ms):
    a = np.asarray(lats_ms)
    return dict(p50_ms=float(np.percentile(a, 50)),
                p99_ms=float(np.percentile(a, 99)),
                mean_ms=float(a.mean()), max_ms=float(a.max()))


def _mode_summary(name, lats, t_span, n):
    out = dict(mode=name, n_requests=n,
               throughput_rps=float(n / t_span), **_percentiles(
                   [l * 1e3 for l in lats]))
    return out


def _tolv(vals):
    """Tolerance vector with a stable aval (no weak_type churn between
    the warm-up call and the serving calls — that would recompile)."""
    return jnp.asarray(np.asarray(vals, np.float64))


def _wait_until(t_abs):
    d = t_abs - time.perf_counter()
    if d > 0:
        time.sleep(d)


def serve_sequential(op, B, tols, arrivals, cfg):
    """FIFO, one single-RHS solve at a time (tol passed as a traced
    (1,) vector so every request shares ONE compiled program)."""
    from repro.core import solve_batched

    fn = jax.jit(lambda b, tol: solve_batched(
        op.matvec, b[:, None], config=cfg, tol=tol))
    # warm with the exact aval (incl. weak_type) of the serving calls
    jax.block_until_ready(fn(B[:, 0], _tolv([tols[0]])).x)

    n = B.shape[1]
    lats, conv = [], []
    t0 = time.perf_counter()
    arr = t0 + arrivals
    for i in range(n):
        _wait_until(arr[i])
        res = fn(B[:, i], _tolv([tols[i]]))
        jax.block_until_ready(res.x)
        lats.append(time.perf_counter() - arr[i])
        conv.append(bool(res.converged[0]))
    span = time.perf_counter() - t0
    assert all(conv), "sequential serving must converge every request"
    return _mode_summary("sequential", lats, span, n)


def serve_static_batch(op, B, tols, arrivals, cfg, max_batch):
    """FIFO batches of max_batch; each batch launches when its last
    member has arrived and completes when its SLOWEST column converges."""
    from repro.core import solve_batched

    fn = jax.jit(lambda BB, tt: solve_batched(op.matvec, BB, config=cfg,
                                              tol=tt))
    n = B.shape[1]
    pad_B = jnp.tile(B[:, :1], (1, max_batch))
    jax.block_until_ready(fn(pad_B, _tolv([1e-8] * max_batch)).x)

    lats, conv = [], []
    t0 = time.perf_counter()
    arr = t0 + arrivals
    for lo in range(0, n, max_batch):
        idx = list(range(lo, min(lo + max_batch, n)))
        pad = idx + [idx[-1]] * (max_batch - len(idx))   # ragged tail
        _wait_until(arr[idx[-1]])                        # batch is full
        res = fn(B[:, pad], _tolv([tols[j] for j in pad]))
        jax.block_until_ready(res.x)
        fin = time.perf_counter()
        for j in idx:
            lats.append(fin - arr[j])
        conv.extend(np.asarray(res.converged)[:len(idx)].tolist())
    span = time.perf_counter() - t0
    assert all(conv), "static-batch serving must converge every request"
    return _mode_summary("static-batch", lats, span, n)


def serve_engine(op, B, tols, arrivals, scfg):
    """Continuous batching: submit each request when it arrives, poll
    chunks, retire/refill mid-flight."""
    from repro.observe import REGISTRY
    from repro.observe.metrics import REQUEST_CHUNKS, REQUEST_QUEUE_WAIT
    from repro.service import SolveEngine

    eng = SolveEngine(scfg, clock=time.perf_counter)
    name = eng.register(op)
    n = B.shape[1]

    # warm every program (init + step + splice) on a dummy stream, then
    # let the blocks drain; the registry keeps the compilations
    for j in range(scfg.max_batch + 1):
        eng.submit(name, B[:, j % n], tol=1e-6)
    eng.run()
    # serving telemetry is read back from the observe metrics registry
    # (the engine records it at retirement) — reset after warm-up so
    # the measured window is exactly the replayed stream
    REGISTRY.reset()

    lats, results = {}, []
    t0 = time.perf_counter()
    arr = t0 + arrivals
    rid_of = {}
    i = 0
    while i < n or eng.has_work():
        now = time.perf_counter()
        while i < n and arr[i] <= now:
            rid_of[eng.submit(name, B[:, i], tol=float(tols[i]))] = i
            i += 1
        if eng.has_work():
            done = eng.poll()
            fin = time.perf_counter()
            for r in done:
                lats[rid_of[r.rid]] = fin - arr[rid_of[r.rid]]
                results.append(r)
        elif i < n:
            _wait_until(arr[i])
    span = time.perf_counter() - t0
    assert len(results) == n
    assert all(r.converged for r in results), \
        "engine serving must converge every request"
    out = _mode_summary("engine", [lats[j] for j in range(n)], span, n)
    # one source of truth: the engine already recorded these at
    # retirement, so the bench reads the histograms instead of
    # re-deriving means from per-result telemetry
    assert REQUEST_CHUNKS.count() == n
    out["mean_chunks_resident"] = REQUEST_CHUNKS.sum() / n
    out["mean_queue_wait_ms"] = 1e3 * REQUEST_QUEUE_WAIT.sum() / n
    return out


def run(quick: bool = False):
    from repro.core import SolverConfig
    from repro.service import ServiceConfig

    print("\n== bench_service (continuous batching vs sequential/static) ==")
    # A serving benchmark scales LOAD (request count), not problem size:
    # n stays in the regime where serving discipline is what's measured —
    # per-request overheads + iteration-count heterogeneity dominate, and
    # the resident block amortizes them across requests.  (On CPU the
    # (n, m) vector phases cost ~m x a single column — the paper's
    # per-iteration HBM/reduction amortization is a TPU/distributed
    # property — so very large n on CPU measures raw vector bandwidth,
    # not serving.)
    nx = 8
    max_batch = 8
    n_req = 4 * max_batch if quick else 12 * max_batch
    op, b, _ = _problem(nx)
    cfg = SolverConfig(tol=1e-8, maxiter=2000)
    # chunk ~ half the typical iteration count: refills land mid-solve,
    # keeping slot utilization high without per-chunk host-overhead churn
    scfg = ServiceConfig(max_batch=max_batch, chunk=12,
                         tol=1e-8, maxiter=2000)

    rng = np.random.default_rng(42)
    B = jnp.asarray(rng.standard_normal((op.n, n_req)))
    tols = [float(t) for t in rng.choice([1e-6, 1e-8], size=n_req)]
    modes = dict(
        sequential=lambda arr: serve_sequential(op, B, tols, arr, cfg),
        static=lambda arr: serve_static_batch(op, B, tols, arr, cfg,
                                              max_batch),
        engine=lambda arr: serve_engine(op, B, tols, arr, scfg))

    # -- phase 1: saturated-burst capacity (the asserted comparison) ----
    burst = np.zeros(n_req)
    reps = 2 if quick else 3
    cap = {name: max((f(burst) for _ in range(reps)),
                     key=lambda s: s["throughput_rps"])
           for name, f in modes.items()}
    print(f"n={op.n}, N={n_req}, max_batch={max_batch}, "
          f"chunk={scfg.chunk} (burst capacity, best of {reps})")

    # -- phase 2: Poisson stream at 1.2x sequential capacity (latency) --
    # moderate overload: the sequential server's queue grows, the engine
    # absorbs it, and static batching's head-of-line blocking (waiting
    # for a batch to fill, then for its slowest column) is visible
    # rather than hidden by saturation
    rate = 1.2 * cap["sequential"]["throughput_rps"]
    arrivals = rng.exponential(1.0 / rate, size=n_req).cumsum()
    lat = {name: f(arrivals) for name, f in modes.items()}

    headers = ["mode", "N", "capacity rps", "p50 ms @1.2x",
               "p99 ms @1.2x", "mean ms @1.2x"]
    rows = [[name, n_req, f"{cap[name]['throughput_rps']:.1f}",
             f"{lat[name]['p50_ms']:.1f}", f"{lat[name]['p99_ms']:.1f}",
             f"{lat[name]['mean_ms']:.1f}"] for name in modes]
    print(fmt_table(rows, headers))

    speedup = (cap["engine"]["throughput_rps"]
               / cap["sequential"]["throughput_rps"])
    print(f"continuous batching vs sequential: {speedup:.2f}x capacity, "
          f"p99 under 1.2x load {lat['sequential']['p99_ms']:.0f}ms -> "
          f"{lat['engine']['p99_ms']:.0f}ms")
    # artifact first, assertion second: a failed acceptance bar should
    # still leave the measurements on disk for CI to upload
    write_json("bench_service.json", {
        "config": dict(n=op.n, n_requests=n_req, max_batch=max_batch,
                       chunk=scfg.chunk, offered_rate_rps=rate,
                       capacity_reps=reps, quick=quick,
                       tol_mix=sorted(set(tols))),
        "capacity_burst": cap,
        "latency_poisson_1p2x": lat,
        "throughput_speedup_vs_sequential": speedup,
        "headers": headers, "rows": rows,
    })
    assert speedup > 1.0, (
        f"continuous batching must beat sequential serving on throughput "
        f"at max_batch={max_batch} (got {speedup:.2f}x)")
    return speedup


if __name__ == "__main__":
    run()
