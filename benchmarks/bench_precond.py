"""Preconditioned vs. unpreconditioned solves (the repro.precond subsystem).

For each (problem, solver, preconditioner) cell: converged?, iteration
count, wall time, final relres — plus the full residual-norm *trajectory*
of p-BiCGSafe on the hard problem with and without block-Jacobi (the
artifact the unpreconditioned repo could never produce: plain p-BiCGSafe
stagnates on ``hard_nonsym``, the preconditioned solve converges in a few
dozen iterations with the M^{-1}-apply hidden inside the overlap window).

Artifact: experiments/bench_precond.json (uploaded by CI next to
bench_multirhs.json).

  PYTHONPATH=src python -m benchmarks.run --only precond
"""
from __future__ import annotations

import time

import jax
import numpy as np

from .common import fmt_table, write_json

jax.config.update("jax_enable_x64", True)


def _time(fn, reps: int = 3, warm: bool = False) -> float:
    if not warm:
        fn()                                 # compile / warm up
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return min(ts)


def _problems(quick: bool):
    # built through the scenario registry's operator plugins (ONE
    # definition per problem family; cached per spec content)
    from repro.scenarios import build_problem
    n_hard = 300 if quick else 900
    nx = 8 if quick else 14
    return {
        "hard_nonsym": build_problem("hard_nonsym", n=n_hard),
        "anisotropic3d": build_problem("anisotropic3d", nx=nx, eps=1e-2),
        "convdiff": build_problem("convection_diffusion", nx=nx,
                                  peclet=1.0),
    }


def _preconds(op):
    from repro.core.linear_operator import Stencil7Operator
    names = [None, "jacobi", "block_jacobi", "neumann"]
    if isinstance(op, Stencil7Operator):
        names.append("ssor")
    return names


def run(quick: bool = False):
    from repro.core import SOLVERS, SolverConfig

    print("\n== bench_precond (preconditioned vs. unpreconditioned) ==")
    cfg = SolverConfig(tol=1e-8, maxiter=1500 if quick else 3000)
    solver_names = (["p-bicgsafe", "ssbicgsafe2"] if quick else
                    ["p-bicgsafe", "p-bicgsafe-rr", "ssbicgsafe2",
                     "bicgstab"])

    rows = []
    for pname, (op, b, xt) in _problems(quick).items():
        for sname in solver_names:
            solve = SOLVERS[sname]
            for pc in _preconds(op):
                fn = jax.jit(lambda bb, s=solve, o=op, p=pc: s(
                    o, bb, config=cfg, precond=p))
                res = jax.block_until_ready(fn(b))   # compile + warm up
                t = _time(lambda: jax.block_until_ready(fn(b).x),
                          reps=2, warm=True)
                rows.append([pname, sname, pc or "-",
                             bool(res.converged), int(res.iterations),
                             f"{t * 1e3:.1f}", f"{float(res.relres):.1e}"])

    headers = ["problem", "solver", "precond", "converged", "iters",
               "ms", "relres"]
    print(fmt_table(rows, headers))

    # the trajectory: recurred relres history, preconditioned vs not, for
    # the paper's method on the problem class preconditioning unlocks
    from repro.core import SolverConfig as SC
    from repro.core import pbicgsafe_solve
    op, b, _ = _problems(quick)["hard_nonsym"]
    hcfg = SC(tol=1e-8, maxiter=500, record_history=True)
    traj = {}
    for pc in (None, "block_jacobi"):
        r = pbicgsafe_solve(op, b, config=hcfg, precond=pc)
        h = np.asarray(r.residual_history)
        h = h[np.isfinite(h)]
        traj[pc or "none"] = {
            "converged": bool(r.converged),
            "iterations": int(r.iterations),
            "relres_history": [float(v) for v in h],
        }
    print("p-BiCGSafe on hard_nonsym: "
          f"unpreconditioned converged={traj['none']['converged']} "
          f"({traj['none']['iterations']} it), block-Jacobi "
          f"converged={traj['block_jacobi']['converged']} "
          f"({traj['block_jacobi']['iterations']} it)")

    write_json("bench_precond.json",
               {"headers": headers, "rows": rows,
                "trajectory": {"problem": "hard_nonsym",
                               "solver": "p-bicgsafe", **traj}})
    return rows


if __name__ == "__main__":
    run()
