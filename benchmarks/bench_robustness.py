"""Guarded-solve robustness: clean-path overhead + fault recovery rates.

Two claims from the resilience subsystem (repro.resilience), measured:

* overhead — the guard widens the fused per-iteration reduction from
  (9, m) to (11, m) and reads one (m,)-sized flag block per chunk; on a
  CLEAN solve that must cost <= 5% wall time vs. the unguarded batched
  program (asserted).  Measured warm, best-of-k, chunk sized to the
  iteration budget so the comparison isolates the widened reduction
  rather than host-sync cadence.
* recovery — a deterministic fault matrix (NaN-poisoned columns,
  simulated kernel failures, orthogonal-shadow rho-breakdowns) is
  injected into guarded solves; reported per scenario: recovered
  fraction, typed-failure fraction, silent-NaN count (must be ZERO),
  recovery events, added iterations vs. the clean solve.

Artifact: experiments/bench_robustness.json.

  PYTHONPATH=src python -m benchmarks.run --only robustness
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from .common import fmt_table, write_json

jax.config.update("jax_enable_x64", True)


def _best_wall(fn, repeats: int) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        res = fn()
        jax.block_until_ready(res.x)
        best = min(best, time.perf_counter() - t0)
    return best


def _overhead(quick: bool):
    """Clean-path guarded vs unguarded wall time, identical traffic."""
    import repro
    from repro.core import SolverConfig
    from repro.core import matrices as M
    from repro.resilience import RecoveryPolicy

    # sized so the solve dominates the guarded driver's fixed host-sync
    # cost (a few dispatches + one flag read) — the regime the guard is
    # built for; tiny problems measure dispatch, not the reduction
    nx = 16 if quick else 20
    m = 8
    repeats = 3 if quick else 5
    op, b, _ = M.convection_diffusion(nx, peclet=1.0)
    rng = np.random.default_rng(0)
    B = jnp.stack([b] + [jnp.asarray(rng.standard_normal(b.shape))
                         for _ in range(m - 1)], axis=1)
    maxiter = 400
    cfg = SolverConfig(tol=1e-8, maxiter=maxiter)

    plain = repro.make_solver("p-bicgsafe", op, config=cfg)
    # chunk = the full budget: ONE host flag-read per solve, so the
    # measured gap is the widened reduction itself, not sync cadence
    guarded = repro.make_solver("p-bicgsafe", op, config=cfg,
                                recovery=RecoveryPolicy(chunk=maxiter))

    plain.solve_many(B)                      # warm both programs
    guarded.solve_many(B)
    t_plain = _best_wall(lambda: plain.solve_many(B), repeats)
    t_guard = _best_wall(lambda: guarded.solve_many(B), repeats)
    assert not guarded.events, "clean bench traffic triggered recovery"
    ratio = t_guard / t_plain
    return dict(n=op.shape[0], m=m, maxiter=maxiter,
                t_unguarded_s=t_plain, t_guarded_s=t_guard,
                overhead_ratio=ratio, overhead_pct=100.0 * (ratio - 1.0))


def _fault_matrix(quick: bool):
    """Deterministic chaos scenarios through the guarded front door."""
    import repro
    from repro.core import SolverConfig
    from repro.core import matrices as M
    from repro.core.types import SolveStatus
    from repro.resilience import (ChunkFaultInjector, RecoveryPolicy,
                                  orthogonal_shadow)

    n = 48 if quick else 96
    seeds = range(3) if quick else range(6)
    rows = []
    for scenario in ("nan", "kernel", "rho_breakdown"):
        recovered = typed = silent_nan = 0
        events = 0
        added_iters = []
        for seed in seeds:
            op, b, _ = M.random_nonsym(n, 6, seed=seed, diag_dominance=1.3)
            b = b / jnp.linalg.norm(b)
            tol = 1e-2 if scenario == "rho_breakdown" else 1e-8
            cfg = SolverConfig(tol=tol, maxiter=600,
                               breakdown_eps=1e-12
                               if scenario == "rho_breakdown" else 0.0)
            clean = repro.make_solver("p-bicgsafe", op,
                                      config=cfg).solve(b)
            kw = {}
            inject = None
            r0_star = None
            if scenario == "nan":
                inject = ChunkFaultInjector(nan_at={1: (0,)})
            elif scenario == "kernel":
                inject = ChunkFaultInjector(fail_at=(1,))
                kw["substrate"] = "pallas"
            else:
                r0_star = orthogonal_shadow(b)
            gs = repro.make_solver(
                "p-bicgsafe", op, config=cfg,
                recovery=RecoveryPolicy(chunk=8), **kw)
            gs.inject = inject
            res = gs.solve(b, r0_star=r0_star)
            x = np.asarray(res.x)
            if not np.isfinite(x).all():
                silent_nan += 1
            sts = SolveStatus(int(np.asarray(res.status)))
            if bool(np.asarray(res.converged)):
                recovered += 1
                added_iters.append(int(np.asarray(res.iterations))
                                   - int(np.asarray(clean.iterations)))
            elif sts.is_failure:
                typed += 1
            events += len(gs.events)
        total = len(list(seeds))
        rows.append(dict(
            scenario=scenario, runs=total,
            recovered=recovered, typed_failures=typed,
            silent_nan=silent_nan, recovery_events=events,
            mean_added_iters=(float(np.mean(added_iters))
                              if added_iters else None)))
    return rows


def run(quick: bool = False):
    oh = _overhead(quick)
    print(fmt_table(
        [[oh["n"], oh["m"], f"{oh['t_unguarded_s'] * 1e3:.1f}",
          f"{oh['t_guarded_s'] * 1e3:.1f}", f"{oh['overhead_pct']:+.2f}%"]],
        headers=["n", "m", "unguarded ms", "guarded ms", "overhead"]))
    assert oh["overhead_ratio"] <= 1.05, (
        f"clean-path guard overhead {oh['overhead_pct']:.2f}% exceeds "
        "the 5% budget")

    rows = _fault_matrix(quick)
    print()
    print(fmt_table(
        [[r["scenario"], r["runs"], r["recovered"], r["typed_failures"],
          r["silent_nan"], r["recovery_events"],
          "-" if r["mean_added_iters"] is None
          else f"{r['mean_added_iters']:.1f}"] for r in rows],
        headers=["scenario", "runs", "recovered", "typed", "silent NaN",
                 "events", "added iters"]))
    for r in rows:
        assert r["silent_nan"] == 0, f"silent NaN in {r['scenario']}"
        assert r["recovered"] + r["typed_failures"] == r["runs"], (
            f"{r['scenario']}: unaccounted outcome")

    path = write_json("bench_robustness.json",
                      dict(overhead=oh, faults=rows, quick=quick))
    print(f"\nwrote {path}")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    run(quick=ap.parse_args().quick)
