"""Scenario matrix sweep through the benchmark harness.

Thin wrapper over ``repro.scenarios.sweep`` (the same runner behind
``python -m repro.scenarios sweep``): runs the registered scenario
subset — every cell solved through its declared binding, verified by
its operator plugin's oracle, and statically contract-checked — and
writes the ONE consolidated artifact the perf-trajectory gate
regresses.

Artifact: experiments/scenario_sweep.json (schema
``repro.scenarios/scenario_sweep/v1``); gated metrics in
benchmarks/run.py: cell count and the oracle/contract claims (fatal),
wall clock (watch-only).

  PYTHONPATH=src python -m benchmarks.run --only scenarios
"""
from __future__ import annotations

import jax

jax.config.update("jax_enable_x64", True)


def run(quick: bool = False):
    from repro.scenarios.sweep import (DEFAULT_OUT, run_sweep, sweep_table,
                                       write_artifact)

    print("\n== bench_scenarios (declarative matrix sweep) ==")
    art = run_sweep(quick=quick)
    out = write_artifact(art, DEFAULT_OUT)
    print(sweep_table(art))
    print(f"artifact: {out}")
    assert art["claims"]["all_oracle_ok"], \
        "scenario sweep: oracle verification failed (see table)"
    assert art["claims"]["all_contracts_ok"], \
        "scenario sweep: contract deviation (see table)"
    return art


if __name__ == "__main__":
    run()
