"""Repeat-solve amortization: bind-once sessions vs. legacy free functions.

The dominant real workload is many solves against one fixed operator
(Krasnopolsky 2019).  The legacy free-function path re-traces the whole
solver — init phase plus while-loop body — on EVERY call; a
``repro.make_solver`` session traces once and replays the compiled
program.  This bench times N repeat solves against one operator through
both paths, on both substrates, and counts trace-time ``dot_reduce``
invocations (2 per trace: the init ||r_0|| and the loop body's fused
phase) as the retrace metric:

* legacy:  2 * N  invocations — the trace count grows linearly in the
           number of solves;
* session: 2      invocations — O(1) in the number of solves, the
           acceptance bar of the PR-5 API redesign.

Artifact: experiments/bench_api.json (asserts session wall < legacy wall
and the O(1) trace count before writing).
"""
from __future__ import annotations

import time

from .common import fmt_table, write_json


def _bench_substrate(substrate: str, n_solves: int, grid: int):
    import jax

    jax.config.update("jax_enable_x64", True)
    import numpy as np

    import repro
    from repro.core import SOLVERS, SolverConfig
    from repro.core import matrices as M
    from repro.core._common import SyncCounter
    from repro.core.types import identity_reduce

    op, b, _ = M.poisson3d(grid)
    cfg = SolverConfig(tol=1e-8, maxiter=500)
    rhs = [b + float(i) for i in range(n_solves)]
    [r.block_until_ready() for r in rhs]

    # -- legacy free-function path: retraces every call ------------------
    # (no per-solve host reads inside the timed region — both loops only
    # dispatch, then sync once, so the ratio is pure retrace cost)
    legacy_counter = SyncCounter(identity_reduce)
    legacy_fn = SOLVERS["p-bicgsafe"]
    legacy_results = []
    t0 = time.perf_counter()
    for bb in rhs:
        res = legacy_fn(op, bb, config=cfg, substrate=substrate,
                        dot_reduce=legacy_counter)
        legacy_results.append(res)
    res.x.block_until_ready()
    legacy_wall = time.perf_counter() - t0
    iters = sum(int(r.iterations) for r in legacy_results)

    # -- session path: ONE trace, replayed -------------------------------
    session_counter = SyncCounter(identity_reduce)
    session = repro.make_solver("p-bicgsafe", op, substrate=substrate,
                                config=cfg, dot_reduce=session_counter)
    t0 = time.perf_counter()
    for bb in rhs:
        sres = session.solve(bb)
    sres.x.block_until_ready()
    session_wall = time.perf_counter() - t0

    # same algorithm, same trajectories
    assert int(sres.iterations) == int(res.iterations), (
        "session and legacy paths diverged")
    assert np.allclose(np.asarray(sres.x), np.asarray(res.x))

    # the acceptance bar: O(1) traces, and faster in wall time
    assert session_counter.calls == 2, (
        f"session path retraced: {session_counter.calls} dot_reduce "
        "trace invocations (expected 2 — init + one loop body)")
    assert legacy_counter.calls == 2 * n_solves
    assert session_wall < legacy_wall, (
        f"session path must beat legacy on {n_solves} repeat solves "
        f"({session_wall:.3f}s vs {legacy_wall:.3f}s)")

    return {
        "solves": n_solves,
        "n": int(op.shape[0]),
        "avg_iterations": iters / n_solves,
        "legacy_wall_s": legacy_wall,
        "session_wall_s": session_wall,
        "speedup": legacy_wall / session_wall,
        "legacy_dot_reduce_traces": legacy_counter.calls,
        "session_dot_reduce_traces": session_counter.calls,
        "session_stats": dict(session.stats),
    }


def run(quick: bool = False) -> None:
    n_solves = 10 if quick else 50
    results = {}
    rows = []
    for substrate in ("jnp", "pallas"):
        grid = 8 if (quick or substrate == "pallas") else 12
        r = _bench_substrate(substrate, n_solves, grid)
        results[substrate] = r
        rows.append([substrate, r["n"], r["solves"],
                     f"{r['legacy_wall_s']:.3f}",
                     f"{r['session_wall_s']:.3f}",
                     f"{r['speedup']:.1f}x",
                     r["legacy_dot_reduce_traces"],
                     r["session_dot_reduce_traces"]])

    print(fmt_table(rows, ["substrate", "n", "solves", "legacy_s",
                           "session_s", "speedup", "legacy_traces",
                           "session_traces"]))
    print("\nsession path: trace count O(1) in the number of solves "
          "(legacy: O(N)); wall-time win is the retrace cost the "
          "bind-once API removes.")
    path = write_json("bench_api.json",
                      {"quick": quick, "method": "p-bicgsafe",
                       "results": results})
    print(f"wrote {path}")
