"""The paper's central mechanism, proven structurally + modeled (Fig 5.3).

Part 1 — HLO dependency proof (in a subprocess with 8 fake devices):
in the lowered distributed p-BiCGSafe while-body, the fused 9-dot
all-reduce and the halo collective-permutes of the overlapped matvec
``A s_i`` have NO dependency path between them — so the XLA latency-hiding
scheduler may overlap them.  In ssBiCGSafe2, the reduction transitively
CONSUMES the matvec's halo exchange (``s_i = A r_i`` feeds the dots) — no
overlap is possible.  This is the TPU restatement of the paper's
MPI_Iallreduce-overlap design (DESIGN.md §3).

Part 2 — analytic strong-scaling model (paper Fig 5.3 analogue), with v5e
constants: per-iteration time of both methods vs chip count P for a fixed
global problem; the pipelined method hides min(T_reduce, T_spmv) of the
reduction, so its advantage grows with P until SpMV no longer covers the
reduction latency (the paper's observed crossover).

Part 3 — MEASURED overlap (:mod:`repro.observe.profile`): real device
timelines captured around a profiled solve for every binding — single
solve on jnp AND pallas-interpret, batched solve, an engine chunk drain,
and the 8-fake-device mesh solve (subprocess).  Each leg records the
per-phase device-time breakdown, the overlap efficiency (fraction of
reduce-phase wall time hidden under in-flight matvec), and the exposed
communication per iteration.  On a single CPU device XLA executes thunks
serially, so efficiency is honestly ~0 here — the value of committing
the numbers is the *trajectory*: a substrate or scheduler change that
starts actually overlapping shows up as a step in these fields.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

import numpy as np

from .common import fmt_table, runtime_dir, write_json

# v5e-ish constants
PEAK_FLOPS = 197e12 * 0.05      # fp64-ish effective vector rate on VPU
HBM_BW = 819e9
LINK_BW = 50e9
HOP_LAT = 1e-6                  # per-hop ICI latency
REDUCE_WORDS = 9 * 8            # 9 fp64 scalars


def hlo_proof() -> dict:
    env = dict(os.environ)
    src = os.path.abspath(os.path.join(os.path.dirname(__file__),
                                       os.pardir, "src"))
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, os.path.join(os.path.dirname(__file__),
                                      "_overlap_child.py")],
        capture_output=True, text=True, env=env, timeout=900)
    if proc.returncode != 0:
        return {"error": proc.stderr[-2000:]}
    return json.loads(proc.stdout.strip().splitlines()[-1])


def _torus_dims(P: int):
    if P <= 16:
        return (P,)
    if P == 512:
        return (2, 16, 16)   # multi-pod
    a = 1
    while (a * 2) ** 2 <= P:
        a *= 2
    return (P // a, a)


def latency_model(n: int = 512 ** 3, nnz_per_row: int = 7,
                  dci_lat: float = 20e-6):
    """Per-iteration model for a fixed global problem of n rows.

    Both methods use the fused vector-update kernels (ss: ~17 tile passes
    for its 30 vector ops; p: 22 passes for its 48 — the extra recurrences
    are the paper's Table 3.1 overhead).  The pipelined method's win is
    min(t_spmv, t_reduce) of hidden reduction minus 5 extra tile passes.
    The last column re-evaluates the speedup with MPI-cluster-like
    reduction latency (x50) — the paper's regime.
    """
    rows = []
    for P in (8, 16, 32, 64, 128, 256, 512):
        n_loc = n / P
        t_spmv = (2 * nnz_per_row * n_loc / PEAK_FLOPS
                  + nnz_per_row * 8 * n_loc / HBM_BW)
        halo_bytes = (n / P) ** (2 / 3) * 8 * 2
        t_spmv += halo_bytes / LINK_BW + 2 * HOP_LAT

        # torus all-reduce of 9 fp64 scalars: per-axis bidirectional ring
        dims = _torus_dims(P)
        hops = sum(2 * (d - 1) for d in dims)
        t_reduce = hops * HOP_LAT + REDUCE_WORDS * len(dims) / LINK_BW
        if P == 512:
            t_reduce += 2 * dci_lat          # cross-pod DCI
        pass_b = 8 * n_loc / HBM_BW          # one fused tile pass over n_loc
        t_axpy_ss, t_axpy_p = 17 * pass_b, 22 * pass_b
        t_dots = 6 * pass_b                  # fused_dots: 5 reads + partials

        def titer(reduce_lat):
            t_ss = 2 * t_spmv + reduce_lat + t_axpy_ss + t_dots
            t_p = t_spmv + max(t_spmv, reduce_lat) + t_axpy_p + t_dots
            return t_ss, t_p

        t_ss, t_p = titer(t_reduce)
        t_ss_hi, t_p_hi = titer(t_reduce * 50)   # MPI-cluster-like latency
        rows.append([P, f"{t_reduce*1e6:.1f}", f"{t_spmv*1e6:.1f}",
                     f"{t_ss*1e6:.1f}", f"{t_p*1e6:.1f}",
                     f"{t_ss/t_p:.3f}", f"{t_ss_hi/t_p_hi:.3f}"])
    return rows


def _report_summary(rep) -> dict:
    """The trajectory-tracked slice of a ProfileReport."""
    return {
        "overlap_efficiency": rep.overlap_efficiency,
        "exposed_per_iter_us": rep.exposed_per_iter_us,
        "reduce_us": rep.reduce_us,
        "matvec_us": rep.matvec_us,
        "hidden_us": rep.hidden_us,
        "device_wall_us": rep.device_wall_us,
        "phase_us": rep.phase_us,
        "iterations": rep.iterations,
        "n_device_events": rep.n_device_events,
    }


def measured_overlap(quick: bool = False) -> dict:
    """Part 3: capture + analyze real timelines for every binding."""
    from jax.experimental import enable_x64

    import repro
    from repro.core import SolverConfig
    from repro.core import matrices as M
    from repro.service import ServiceConfig, SolveEngine

    base = runtime_dir("profile", "bench_overlap")
    nx = 6 if quick else 8
    out: dict = {}

    with enable_x64(True):
        op, b, _ = M.poisson3d(nx)
        for sub in ("jnp", "pallas"):
            solver = repro.make_solver(
                "p-bicgsafe", op, substrate=sub,
                config=SolverConfig(tol=1e-8, maxiter=800))
            solver.solve(b, profile=str(base / f"session_{sub}"))
            out[f"session_{sub}"] = _report_summary(solver.last_profile)

        solver = repro.make_solver(
            "p-bicgsafe", op, config=SolverConfig(tol=1e-8, maxiter=800))
        rng = np.random.default_rng(3)
        B = np.stack([np.asarray(b)]
                     + [rng.standard_normal(op.shape[0])
                        for _ in range(3)], axis=1)
        solver.solve_many(B, profile=str(base / "batched_jnp"))
        out["batched_jnp"] = _report_summary(solver.last_profile)

        eng = SolveEngine(ServiceConfig(
            max_batch=4, chunk=16, tol=1e-8, maxiter=800,
            profile_dir=str(base / "engine")))
        eng.register(op, name="poisson")
        for _ in range(6):
            eng.submit("poisson", rng.standard_normal(op.shape[0]))
        eng.run()
        out["engine"] = _report_summary(eng.last_profile)

    # mesh leg: subprocess (needs fake-device XLA_FLAGS before jax init)
    env = dict(os.environ)
    src = os.path.abspath(os.path.join(os.path.dirname(__file__),
                                       os.pardir, "src"))
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable,
         os.path.join(os.path.dirname(__file__),
                      "_overlap_measure_child.py"),
         str(base / "mesh")],
        capture_output=True, text=True, env=env, timeout=900)
    if proc.returncode != 0:
        out["mesh"] = {"error": proc.stderr[-2000:]}
    else:
        from repro.observe.profile import ProfileReport
        rep = ProfileReport.from_json(
            {k: v for k, v in
             json.loads(proc.stdout.strip().splitlines()[-1]).items()
             if k != "converged"})
        out["mesh"] = _report_summary(rep)
    return out


def run(quick: bool = False):
    print("\n== bench_overlap (comm-hiding proof + Fig 5.3 model) ==")
    proof = hlo_proof()
    print("HLO dependency structure (8-device lowering):")
    print(json.dumps(proof, indent=2))

    ok = ("error" not in proof
          and proof["p-bicgsafe"]["independent_of_reduction"] > 0
          and proof["ssbicgsafe2"]["reduction_needs_permutes"] > 0)
    print(f"comm-hiding structurally possible for p-BiCGSafe and "
          f"impossible for ssBiCGSafe2: {ok}")
    batched = proof.get("p-bicgsafe-batched", {})
    ok_batched = ("error" not in proof
                  and batched.get("independent_of_reduction", 0) > 0
                  and batched.get("reduction_needs_permutes", 1) == 0)
    print(f"overlap survives batching+sharding (the (9, m) block "
          f"all-reduce has no edge to the block matvec): {ok_batched}")
    prec = proof.get("p-bicgsafe-block-jacobi", {})
    ok_prec = ("error" not in proof
               and prec.get("independent_of_reduction", 0) > 0
               and prec.get("reduction_needs_permutes", 1) == 0)
    print(f"overlap survives preconditioning (block-Jacobi apply inside "
          f"the window, no edge from the reduction): {ok_prec}")

    rows = latency_model()
    headers = ["chips", "t_reduce us", "t_spmv us", "t_ss us", "t_p us",
               "speedup(ICI)", "speedup(x50 lat)"]
    print(fmt_table(rows, headers))

    measured = measured_overlap(quick)
    mrows = []
    for leg, m in measured.items():
        if "error" in m:
            mrows.append([leg, "ERR", "", "", ""])
            continue
        eff = m["overlap_efficiency"]
        mrows.append([
            leg,
            "—" if eff is None else f"{eff:.3f}",
            "—" if m["exposed_per_iter_us"] is None
            else f"{m['exposed_per_iter_us']:.2f}",
            f"{m['reduce_us'] / 1e3:.3f}", f"{m['matvec_us'] / 1e3:.3f}"])
    print("\nmeasured overlap (captured device timelines; serial-CPU "
          "efficiency is honestly ~0):")
    print(fmt_table(mrows, ["binding", "overlap eff", "exposed us/iter",
                            "reduce ms", "matvec ms"]))

    write_json("bench_overlap.json",
               {"hlo_proof": proof, "model": {"headers": headers,
                                              "rows": rows},
                "measured": measured,
                "claim_ok": bool(ok), "batched_claim_ok": bool(ok_batched),
                "precond_claim_ok": bool(ok_prec)})
    return proof


if __name__ == "__main__":
    run()
