"""Batched multi-RHS vs. looped single-RHS solves (Krasnopolsky regime).

Measures wall-clock for solving A X = B with m right-hand sides two ways:

* looped  — m independent ``pbicgsafe_solve`` calls (m reductions + m HBM
            vector passes per "iteration row"),
* batched — one ``solve_batched`` call: (n, m) block vectors, ONE (9, m)
            fused reduction per iteration regardless of m.

Also asserts the communication claim structurally: a ``SyncCounter`` traces
the batched solve and must see exactly 1 ``dot_reduce`` in the iteration
body (+1 init) for any m — the batched path keeps the paper's single
synchronization phase while amortizing it over all right-hand sides.

  PYTHONPATH=src python -m benchmarks.run --only multirhs
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from .common import fmt_table, write_json

jax.config.update("jax_enable_x64", True)


def _problem(nx: int):
    # the scenario registry's operator plugin (one shared definition)
    from repro.scenarios import build_problem
    return build_problem("convection_diffusion", nx=nx, peclet=1.0)


def _rhs_block(b, m: int):
    keys = jax.random.split(jax.random.PRNGKey(7), m)
    cols = [b] + [jax.random.normal(k, b.shape, b.dtype) for k in keys[1:]]
    return jnp.stack(cols, axis=1)


def _time(fn, reps: int = 3) -> float:
    fn()                                     # compile / warm up
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return min(ts)


def assert_single_reduction(op, B, config) -> int:
    """Trace solve_batched and return dot_reduce calls in one iteration."""
    from repro.core import solve_batched
    from repro.core._common import SyncCounter
    from repro.core.types import identity_reduce

    counter = SyncCounter(identity_reduce)
    jax.make_jaxpr(lambda bb: solve_batched(
        op.matvec, bb, config=config, dot_reduce=counter))(B)
    per_iter = counter.calls - 1             # minus the ||r_0|| init reduce
    assert per_iter == 1, (
        f"batched path must fuse to 1 reduction/iter, traced {per_iter}")
    return per_iter


def check_pallas_kernel_path(op, b, cfg) -> dict:
    """Exercise the batched Pallas kernel path (fused dots + update phase
    + in-kernel convergence mask; compiled on TPU, interpret mode
    elsewhere) and assert column-by-column parity with the jnp substrate.
    Returns a summary dict for the JSON artifact."""
    from repro.core import solve_batched

    m = 2
    B = _rhs_block(b, m)
    r_jnp = solve_batched(op.matvec, B, config=cfg, substrate="jnp")
    r_pal = solve_batched(op.matvec, B, config=cfg, substrate="pallas")
    assert bool(np.asarray(r_pal.converged).all()), \
        "pallas-substrate batched solve must converge"
    iters_j = np.asarray(r_jnp.iterations).tolist()
    iters_p = np.asarray(r_pal.iterations).tolist()
    # block-wise vs pairwise accumulation may flip the stopping iteration
    # by one where relres hovers at tol — same tolerance as the tests
    assert all(abs(a - c) <= 1 for a, c in zip(iters_j, iters_p)), \
        (iters_j, iters_p)
    xerr = float(np.abs(np.asarray(r_pal.x) - np.asarray(r_jnp.x)).max())
    assert xerr < 1e-6, xerr
    backend = jax.default_backend()
    print(f"pallas batched kernel path ok on {backend} "
          f"({'compiled' if backend == 'tpu' else 'interpret mode'}): "
          f"iters={iters_p}, max |x_pallas - x_jnp| = {xerr:.2e}")
    return {"backend": backend, "iterations": iters_p, "x_err": xerr}


def run(quick: bool = False):
    from repro.core import SolverConfig, pbicgsafe_solve, solve_batched

    print("\n== bench_multirhs (batched vs looped multi-RHS solves) ==")
    nx = 10 if quick else 16
    op, b, _ = _problem(nx)
    cfg = SolverConfig(tol=1e-8, maxiter=2000)

    if quick:   # interpret-mode kernels: keep the parity problem small
        op_k, b_k, _ = _problem(8)
    else:
        op_k, b_k = op, b
    pallas_check = check_pallas_kernel_path(op_k, b_k, cfg)

    rows = []
    for m in ((2, 8) if quick else (2, 8, 32)):
        B = _rhs_block(b, m)
        per_iter = assert_single_reduction(op, B, cfg)

        looped = jax.jit(lambda BB: [
            pbicgsafe_solve(op.matvec, BB[:, j], config=cfg).x
            for j in range(m)])
        batched = jax.jit(lambda BB: solve_batched(op.matvec, BB,
                                                   config=cfg))

        t_loop = _time(lambda: jax.block_until_ready(looped(B)))
        res = batched(B)
        assert bool(np.asarray(res.converged).all()), "batched must converge"
        t_batch = _time(lambda: jax.block_until_ready(batched(B).x))
        iters = np.asarray(res.iterations)
        rows.append([op.n, m, int(iters.max()), f"{t_loop*1e3:.1f}",
                     f"{t_batch*1e3:.1f}", f"{t_loop/t_batch:.2f}",
                     per_iter])

    headers = ["n", "m", "max iters", "looped ms", "batched ms",
               "speedup", "reduce/iter"]
    print(fmt_table(rows, headers))
    print("batched path: one (9, m) fused reduction per iteration "
          "(asserted at trace time)")
    write_json("bench_multirhs.json",
               {"headers": headers, "rows": rows,
                "pallas_kernel_path": pallas_check})
    return rows


if __name__ == "__main__":
    run()
