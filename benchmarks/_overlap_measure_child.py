"""Child process for bench_overlap's measured-overlap mesh leg: run one
profiled distributed p-BiCGSafe solve on an 8-fake-device mesh and print
the :class:`repro.observe.ProfileReport` as JSON (last stdout line).

A subprocess because the fake-device XLA_FLAGS must be set before jax
initializes, and the parent bench process has already initialized jax on
the real (single) device.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import json  # noqa: E402
import sys   # noqa: E402

import jax   # noqa: E402

jax.config.update("jax_enable_x64", True)

import repro  # noqa: E402
from repro.core import SolverConfig  # noqa: E402
from repro.core import matrices as M  # noqa: E402
from repro.core.compat import make_mesh  # noqa: E402


def main():
    out_dir = sys.argv[1]
    op, b, _ = M.convection_diffusion(16, peclet=1.0)
    solver = repro.make_solver(
        "p-bicgsafe", op, config=SolverConfig(tol=1e-8, maxiter=200))
    dist = solver.on_mesh(make_mesh((8,), ("rows",)))
    res = dist.solve(b.reshape(16, 16, 16), profile=out_dir)
    rep = solver.last_profile
    doc = rep.to_json()
    doc["converged"] = bool(res.converged)
    print(json.dumps(doc))


if __name__ == "__main__":
    main()
