"""Paper Table 5.2 + Fig 5.1 analogue: iteration counts and residual
histories of the four headline methods (+ GPBi-CG) on generated matrices
of the paper's kinds.

Validates: (i) p-BiCGSafe ~ ssBiCGSafe2 iteration counts (exact-arithmetic
equivalence, finite-precision divergence only near tol); (ii) the BiCGSafe
family converges no later — and usually earlier/smoother — than the
BiCGStab family (paper's Fig 5.1 claim).
"""
from __future__ import annotations

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np  # noqa: E402

import repro  # noqa: E402
from repro.core import SolverConfig  # noqa: E402
from repro.scenarios import OperatorSpec, build_problem  # noqa: E402

from .common import fmt_table, write_json  # noqa: E402

METHODS = ["p-bicgsafe", "ssbicgsafe2", "bicgstab", "p-bicgstab", "gpbicg",
           "cgs"]

# Generated analogues of the paper's SuiteSparse kinds (Table 5.1),
# built through the scenario registry's operator plugins — ONE
# definition per problem family (repro.scenarios.builtin).
PROBLEMS = {
    # fluid dynamics, non-symmetric (atmosmodd / poisson3Db kind)
    "convdiff_24": OperatorSpec.of("convection_diffusion", nx=24,
                                   peclet=1.0),
    "convdiff_32_pe2": OperatorSpec.of("convection_diffusion", nx=32,
                                       peclet=2.0),
    "poisson_32": OperatorSpec.of("poisson3d", nx=32),
    # structural, badly scaled SPD (s3dkq4m2 kind)
    "aniso_24": OperatorSpec.of("anisotropic3d", nx=24, eps=1e-2),
    "aniso_20_hard": OperatorSpec.of("anisotropic3d", nx=20, eps=1e-3),
    # generic sparse non-symmetric (xenon2 / epb3 kind)
    "random_20k": OperatorSpec.of("random_nonsym", n=20_000,
                                  nnz_per_row=9, seed=5,
                                  diag_dominance=1.02),
    "random_50k": OperatorSpec.of("random_nonsym", n=50_000,
                                  nnz_per_row=7, seed=9,
                                  diag_dominance=1.05),
    # dense non-normal
    "nonsym_dense_400": OperatorSpec.of("nonsym_dense", n=400, skew=0.8),
}


def run(quick: bool = False):
    problems = dict(list(PROBLEMS.items())[:4]) if quick else PROBLEMS
    rows = []
    histories = {}
    for pname, spec in problems.items():
        op, b, xt = build_problem(spec)
        row = [pname, op.shape[0]]
        for mname in METHODS:
            cfg = SolverConfig(tol=1e-8, maxiter=10_000,
                               record_history=True)
            # bound session per (method, operator) — the front door; a
            # re-run against the same matrix would reuse the program
            res = repro.make_solver(mname, op, config=cfg).solve(b)
            it = int(res.iterations) if bool(res.converged) else -1
            row.append(it if it >= 0 else "-")
            h = np.asarray(res.residual_history)
            histories[f"{pname}/{mname}"] = \
                h[:int(res.iterations) + 1].tolist()
        rows.append(row)

    headers = ["matrix", "N"] + METHODS
    print("\n== bench_convergence (paper Table 5.2 analogue) ==")
    print(fmt_table(rows, headers))

    # paper claims, asserted:
    claims = {"equivalence_ok": True, "safe_beats_stab": 0, "total": 0}
    for row in rows:
        d = dict(zip(headers, row))
        if isinstance(d["p-bicgsafe"], int) and isinstance(d["ssbicgsafe2"], int):
            if abs(d["p-bicgsafe"] - d["ssbicgsafe2"]) > \
                    max(5, 0.1 * d["ssbicgsafe2"]):
                claims["equivalence_ok"] = False
        if isinstance(d["p-bicgsafe"], int) and isinstance(d["bicgstab"], int):
            claims["total"] += 1
            claims["safe_beats_stab"] += d["p-bicgsafe"] <= d["bicgstab"] * 1.1
    write_json("bench_convergence.json",
               {"table": rows, "headers": headers, "claims": claims,
                "histories": {k: v for k, v in histories.items()
                              if len(v) < 2000}})
    print(f"claims: {claims}")
    return rows


if __name__ == "__main__":
    run()
