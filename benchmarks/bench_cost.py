"""Paper Table 3.1 analogue: per-iteration computational cost, measured
from the compiled HLO of each solver's while-loop body.

Counts: matvecs (#Ax), vector-scale and vector-add flops (counted from
elementwise mul/add/sub ops on length-n operands in the loop body),
inner products (#(x,y)) and reduction phases, live state vectors
(#memories, from the while carry).  Compared against the paper's numbers.
"""
from __future__ import annotations

import re

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import SOLVERS, SolverConfig  # noqa: E402
from repro.core import matrices as M  # noqa: E402
from repro.core._common import SyncCounter  # noqa: E402
from repro.core.types import identity_reduce  # noqa: E402

from .common import fmt_table, write_json  # noqa: E402

PAPER_TABLE = {  # method: (#Ax, #alpha*x, #(x+y), #(x,y), #memories)
    "p-bicgsafe": (2, 26, 22, 9, 15),
    "ssbicgsafe2": (2, 16, 14, 9, 10),
    "p-bicgstab": (2, 11, 11, 7, 11),
    "bicgstab": (2, 6, 6, 5, 7),
}


class MatvecCounter:
    def __init__(self, mv):
        self.mv = mv
        self.calls = 0

    def __call__(self, x):
        self.calls += 1
        return self.mv(x)


def analyze(mname: str, n: int = 4096):
    op, b, _ = M.random_nonsym(n, 7, seed=0)
    solver = SOLVERS[mname]

    mv = MatvecCounter(op.matvec)
    sync = SyncCounter(identity_reduce)
    jaxpr = jax.make_jaxpr(
        lambda bb: solver(mv, bb, config=SolverConfig(maxiter=10),
                          dot_reduce=sync))(b)

    # find the while-loop body and count length-n elementwise flops
    closed = jaxpr
    body = None
    for eqn in closed.jaxpr.eqns:
        if eqn.primitive.name == "while":
            body = eqn.params["body_jaxpr"]
    assert body is not None
    counts = {"mul": 0, "add": 0, "sub": 0, "dots": 0}
    nvec = 0
    for eqn in body.jaxpr.eqns:
        out_shapes = [getattr(v.aval, "shape", ()) for v in eqn.outvars]
        prim = eqn.primitive.name
        if prim in ("mul", "add", "sub") and out_shapes and \
                out_shapes[0] == (n,):
            key = prim
            counts[key] += 1
    # dots per iteration = stacked partials length from the sync phases
    # (init call excluded)
    carry_vecs = sum(1 for v in body.jaxpr.invars
                     if getattr(v.aval, "shape", ()) == (n,))
    return {
        "matvec_per_iter": None,          # filled from paper structure
        "mul_n": counts["mul"],
        "addsub_n": counts["add"] + counts["sub"],
        "sync_phases": sync.calls - 1,    # minus init reduction
        "carry_vectors": carry_vecs,
    }


def run(quick: bool = False):
    rows = []
    out = {}
    for mname, paper in PAPER_TABLE.items():
        a = analyze(mname)
        out[mname] = {"measured": a, "paper": paper}
        rows.append([
            mname,
            paper[0],
            f"{a['mul_n']} (paper {paper[1]})",
            f"{a['addsub_n']} (paper {paper[2]})",
            f"{a['sync_phases']}",
            f"{a['carry_vectors']} (paper {paper[4]})",
        ])
    print("\n== bench_cost (paper Table 3.1 analogue, from jaxpr) ==")
    print(fmt_table(rows, ["method", "#Ax", "#alpha*x(n)", "#(x+y)(n)",
                           "sync/iter", "carry vecs"]))
    write_json("bench_cost.json", out)
    return out


if __name__ == "__main__":
    run()
