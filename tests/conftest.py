"""Shared pytest fixtures.

NOTE: XLA_FLAGS / device-count overrides are deliberately NOT set here —
smoke tests and benches must see the real single CPU device.  Multi-device
tests spawn subprocesses with their own XLA_FLAGS (tests/test_distributed.py).
"""
import os
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), os.pardir, "src")
if SRC not in sys.path:
    sys.path.insert(0, os.path.abspath(SRC))


@pytest.fixture
def x64():
    """Run a test in double precision (solver fidelity, paper protocol)."""
    import jax
    with jax.enable_x64(True):
        yield
