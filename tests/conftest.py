"""Shared pytest fixtures.

NOTE: XLA_FLAGS / device-count overrides are deliberately NOT set here —
smoke tests and benches must see the real single CPU device.  Multi-device
tests spawn subprocesses with their own XLA_FLAGS (tests/test_distributed.py).
"""
import os
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), os.pardir, "src")
if SRC not in sys.path:
    sys.path.insert(0, os.path.abspath(SRC))


def enable_x64(flag: bool = True):
    """x64 context manager compatible across jax versions.

    ``jax.enable_x64`` was removed in jax 0.4.37; the supported spelling is
    ``jax.experimental.enable_x64``.  Test modules import this helper instead
    of reaching into jax directly.
    """
    import jax
    if hasattr(jax, "enable_x64"):          # pragma: no cover - old jax
        return jax.enable_x64(flag)
    from jax.experimental import enable_x64 as _e
    return _e(flag)


@pytest.fixture
def x64():
    """Run a test in double precision (solver fidelity, paper protocol)."""
    with enable_x64(True):
        yield
