"""Tests for repro.observe: traces, spans, metrics, clock, report CLI.

The load-bearing contract is NON-PERTURBATION: turning observability on
must not change a single bit of the numerical answer and must not add a
synchronization or a dependency edge to the in-flight matvec.  The
bitwise-parity tests pin the first half; the contract-verifier tests
(one fused reduction per iteration, overlap-edge freedom — run on
TRACED bindings) pin the second.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from conftest import enable_x64  # noqa: F401  (x64 fixture dependency)
from repro.core import SolverConfig
from repro.core import matrices as M
from repro.core.types import TRACE_CHANNELS, SolveStatus
from repro.observe import (RECORDER, REGISTRY, ConvergenceTrace,
                           MetricsRegistry, SpanRecorder, TickingClock,
                           wrap_trace)
from repro.observe.clock import SYSTEM_CLOCK, Clock
from repro.service import ServiceConfig, SolveEngine


def _problem(nx=6):
    return M.poisson3d(nx)


def _same(a, b):
    return np.array_equal(np.asarray(a), np.asarray(b), equal_nan=True)


# ---------------------------------------------------------------------------
# non-perturbation: trace on == trace off, bitwise
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("substrate", ["jnp", "pallas"])
def test_trace_bitwise_parity_single(x64, substrate):
    op, b, _ = _problem()
    s = repro.make_solver("p-bicgsafe", op, substrate=substrate,
                          config=SolverConfig(tol=1e-8, maxiter=300))
    bare = s.solve(b)
    traced = s.solve(b, trace=True)
    assert bare.trace is None and traced.trace is not None
    for field in ("x", "iterations", "relres", "converged", "breakdown",
                  "status"):
        assert _same(getattr(bare, field), getattr(traced, field)), field


@pytest.mark.parametrize("substrate", ["jnp", "pallas"])
def test_trace_bitwise_parity_batched(x64, substrate):
    op, b, _ = _problem()
    rng = np.random.default_rng(3)
    B = jnp.stack([b, jnp.asarray(rng.standard_normal(b.shape))], axis=1)
    s = repro.make_solver("p-bicgsafe", op, substrate=substrate,
                          config=SolverConfig(tol=1e-8, maxiter=300))
    bare = s.solve_many(B)
    traced = s.solve_many(B, trace=True)
    assert traced.trace.batched and traced.trace.m == 2
    for field in ("x", "iterations", "relres", "converged", "breakdown",
                  "status"):
        assert _same(getattr(bare, field), getattr(traced, field)), field


def test_trace_bitwise_parity_open_loop(x64):
    """Open-loop chunk stepping: a traced config solves the same system
    to the same bits as an untraced one (tracing is config-driven on
    this path — the ring rides in the state pytree)."""
    op, b, _ = _problem()
    B = b[:, None]
    cfgs = [SolverConfig(tol=1e-8, maxiter=300),
            SolverConfig(tol=1e-8, maxiter=300, trace_cap=64)]
    states = []
    for cfg in cfgs:
        s = repro.make_solver("p-bicgsafe", op, config=cfg)
        st = s.init(B)
        for _ in range(6):
            st = s.step_chunk(st, 16)
        states.append(s.result(st))
    bare, traced = states
    assert bare.trace is None and traced.trace is not None
    for field in ("x", "iterations", "relres", "converged"):
        assert _same(getattr(bare, field), getattr(traced, field)), field


# ---------------------------------------------------------------------------
# trace content
# ---------------------------------------------------------------------------

def test_trace_records_convergence_trajectory(x64):
    op, b, _ = _problem()
    s = repro.make_solver("p-bicgsafe", op,
                          config=SolverConfig(tol=1e-8, maxiter=300))
    res = s.solve(b, trace=True)
    tr = res.trace
    assert isinstance(tr, ConvergenceTrace) and not tr.batched
    rows = tr.per_iteration()
    it = rows[:, TRACE_CHANNELS.index("iteration")]
    relres = rows[:, TRACE_CHANNELS.index("relres")]
    # completed-update convention: first row is (0, 1.0), last row is
    # (T, final_relres, CONVERGED)
    assert it[0] == 0 and relres[0] == 1.0
    assert it[-1] == int(res.iterations)
    assert np.isclose(relres[-1], float(res.relres), rtol=1e-12)
    assert int(rows[-1, TRACE_CHANNELS.index("status")]) \
        == SolveStatus.CONVERGED.value
    assert (np.diff(it) == 1).all()
    s2 = tr.summary()
    assert s2["status"] == "CONVERGED"
    assert s2["iterations"] == int(res.iterations)


def test_trace_ring_wraparound(x64):
    """An int trace cap keeps the LAST cap iterations."""
    op, b, _ = _problem()
    s = repro.make_solver("p-bicgsafe", op,
                          config=SolverConfig(tol=1e-8, maxiter=300))
    full = s.solve(b, trace=True).trace
    ringed = s.solve(b, trace=4).trace
    assert ringed.cap == 4 and ringed.steps == full.steps
    it_full = full.per_iteration()[:, TRACE_CHANNELS.index("iteration")]
    it_ring = ringed.per_iteration()[:, TRACE_CHANNELS.index("iteration")]
    assert list(it_ring) == list(it_full[-len(it_ring):])


def test_engine_splice_resets_reused_slot_trace(x64):
    """A request admitted into a reused slot must not see its
    predecessor's rows: splice NaNs the column, per_iteration drops
    them, so the harvested trace starts at the new request's iter 0."""
    op, b, _ = _problem(5)
    eng = SolveEngine(ServiceConfig(max_batch=2, chunk=8, tol=1e-8,
                                    maxiter=500, trace_cap=256))
    name = eng.register(op)
    rng = np.random.default_rng(5)
    for k in range(5):                    # 5 requests through 2 slots
        eng.submit(name, rng.standard_normal(op.shape[0]))
    results = eng.run()
    assert len(results) == 5
    for r in results:
        assert r.status == SolveStatus.CONVERGED
        rows = r.trace.per_iteration()
        it = rows[:, TRACE_CHANNELS.index("iteration")]
        assert it[0] == 0, "reused slot leaked the previous trajectory"
        assert it[-1] == r.iterations
        assert (np.diff(it) == 1).all()


def test_guarded_solve_carries_trace(x64):
    from repro.resilience import RecoveryPolicy
    op, b, _ = _problem()
    s = repro.make_solver(
        "p-bicgsafe", op,
        config=SolverConfig(tol=1e-8, maxiter=300, trace_cap=64),
        recovery=RecoveryPolicy())
    res = s.solve(b)
    assert isinstance(res.trace, ConvergenceTrace) and not res.trace.batched
    assert res.trace.summary()["status"] == "CONVERGED"


# ---------------------------------------------------------------------------
# the communication contracts hold on TRACED bindings
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("substrate", ["jnp", "pallas"])
def test_contracts_hold_with_tracing(x64, substrate):
    op, _, _ = _problem()
    s = repro.make_solver(
        "p-bicgsafe", op, substrate=substrate,
        config=SolverConfig(tol=1e-8, maxiter=300, trace_cap=50))
    reports = s.verify_contracts(raise_on_violation=True)
    contracts = {f.contract: f.status for r in reports for f in r.findings}
    assert contracts["one_reduction_per_iteration"] == "ok"
    assert contracts["overlap_edge_free"] == "ok"


def test_contracts_hold_with_tracing_mesh(x64):
    """The traced mesh binding (replicated ring in the out_specs) still
    passes the sharded contract cell — no extra collective from the
    trace payload."""
    from jax.sharding import Mesh
    op, _, _ = _problem()
    mesh = Mesh(np.asarray(jax.devices()[:1]), ("x",))
    s = repro.make_solver(
        "p-bicgsafe", op,
        config=SolverConfig(tol=1e-8, maxiter=300, trace_cap=50))
    reports = s.verify_contracts(bindings=["mesh"], mesh=mesh,
                                 raise_on_violation=True)
    contracts = {f.contract for r in reports for f in r.findings}
    assert "single_psum_sharded" in contracts


# ---------------------------------------------------------------------------
# ConvergenceTrace plumbing
# ---------------------------------------------------------------------------

def test_wrap_trace_passthrough_and_validation():
    assert wrap_trace(None) is None
    buf = np.full((4, len(TRACE_CHANNELS)), np.nan)
    tr = wrap_trace({"buffer": buf, "steps": 2})
    assert isinstance(tr, ConvergenceTrace)
    assert wrap_trace(tr) is tr
    with pytest.raises(ValueError, match="trace buffer"):
        ConvergenceTrace(np.zeros((4, 3)), 1)


def test_trace_json_roundtrip(tmp_path):
    rng = np.random.default_rng(0)
    buf = rng.standard_normal((5, len(TRACE_CHANNELS), 2))
    buf[0, :, :] = np.nan                 # never-written slot
    tr = ConvergenceTrace(buf, 12)
    payload = json.loads(json.dumps(tr.to_json()))   # JSON-able
    back = ConvergenceTrace.from_json(payload)
    assert back.steps == 12 and back.batched and back.m == 2
    assert _same(back.buffer, buf)
    p = tmp_path / "t.json"
    tr.column(1).save(p)
    single = ConvergenceTrace.from_json(json.loads(p.read_text()))
    assert not single.batched
    assert _same(single.buffer, buf[:, :, 1])


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------

def test_metrics_counter_gauge_histogram():
    reg = MetricsRegistry()
    c = reg.counter("c_total", "help", labels=("kind",))
    c.inc(kind="a")
    c.inc(2, kind="a")
    c.inc(kind="b")
    assert c.value(kind="a") == 3 and c.value(kind="b") == 1
    with pytest.raises(ValueError, match="labels"):
        c.inc(wrong="x")
    with pytest.raises(ValueError, match="only go up"):
        c.inc(-1, kind="a")

    g = reg.gauge("g", "help")
    g.set(5)
    g.dec(2)
    assert g.value() == 3

    h = reg.histogram("h_seconds", "help", buckets=(1.0, 10.0))
    for v in (0.5, 5.0, 50.0):
        h.observe(v)
    assert h.count() == 3 and h.sum() == 55.5
    with pytest.raises(ValueError, match="already registered"):
        reg.counter("h_seconds")

    text = reg.prometheus()
    assert '# TYPE c_total counter' in text
    assert 'c_total{kind="a"} 3' in text
    assert 'h_seconds_bucket{le="1"} 1' in text
    assert 'h_seconds_bucket{le="10"} 2' in text
    assert 'h_seconds_bucket{le="+Inf"} 3' in text
    assert 'h_seconds_count 3' in text

    snap = json.loads(json.dumps(reg.snapshot()))    # JSON-able
    assert snap["h_seconds"]["values"][0]["count"] == 3

    reg.reset()
    assert c.value(kind="a") == 0 and h.count() == 0
    assert reg.get("c_total") is c                   # instruments survive


def test_api_layer_records_metrics(x64):
    from repro.observe.metrics import SESSION_CACHE, SOLVES
    op, b, _ = _problem()
    before_miss = SESSION_CACHE.value(outcome="miss")
    s = repro.make_solver("p-bicgsafe", op,
                          config=SolverConfig(tol=1e-6, maxiter=200,
                                              stagnation_window=17))
    assert SESSION_CACHE.value(outcome="miss") == before_miss + 1
    before_hit = SESSION_CACHE.value(outcome="hit")
    repro.make_solver("p-bicgsafe", op,
                      config=SolverConfig(tol=1e-6, maxiter=200,
                                          stagnation_window=17))
    assert SESSION_CACHE.value(outcome="hit") == before_hit + 1
    before = SOLVES.value(method="p-bicgsafe", substrate="jnp",
                          entry="solve")
    s.solve(b)
    assert SOLVES.value(method="p-bicgsafe", substrate="jnp",
                        entry="solve") == before + 1


def test_engine_records_metrics(x64):
    from repro.observe.metrics import ENGINE_REQUESTS, REQUEST_CHUNKS
    op, b, _ = _problem(5)
    before = ENGINE_REQUESTS.value(status="CONVERGED")
    n_before = REQUEST_CHUNKS.count()
    eng = SolveEngine(ServiceConfig(max_batch=2, chunk=16, tol=1e-8,
                                    maxiter=500))
    name = eng.register(op)
    eng.submit(name, np.asarray(b))
    results = eng.run()
    assert results[0].trace is None       # trace_cap unset: no harvest
    assert ENGINE_REQUESTS.value(status="CONVERGED") == before + 1
    assert REQUEST_CHUNKS.count() == n_before + 1


# ---------------------------------------------------------------------------
# spans + clock
# ---------------------------------------------------------------------------

def test_span_recorder_with_virtual_clock():
    clk = TickingClock(dt=0.0)
    rec = SpanRecorder(clock=clk)
    with rec.span("outer", operator="p"):
        clk.advance(2.0)
        with rec.span("inner"):
            clk.advance(0.5)
    names = [s.name for s in rec.spans()]
    assert names == ["inner", "outer"]    # closed in completion order
    inner, outer = rec.spans()
    assert inner.duration == pytest.approx(0.5)
    assert outer.duration == pytest.approx(2.5)
    assert outer.args == {"operator": "p"}

    ct = rec.chrome_trace()
    ev = ct["traceEvents"]
    assert all(e["ph"] == "X" for e in ev)
    by_name = {e["name"]: e for e in ev}
    assert by_name["inner"]["dur"] == pytest.approx(0.5e6)   # µs
    json.dumps(ct)                                           # serializable

    rec.clear()
    assert rec.spans() == []


def test_span_recorder_disabled_records_nothing():
    rec = SpanRecorder(clock=TickingClock(dt=1.0))
    rec.enabled = False
    with rec.span("quiet"):
        pass
    assert rec.spans() == []


def test_clock_protocol_and_inject_shim():
    from repro.resilience.inject import TickingClock as LegacyClock
    assert LegacyClock is TickingClock
    assert isinstance(TickingClock(), Clock)
    assert isinstance(SYSTEM_CLOCK, Clock)
    c = TickingClock(dt=0.25, t0=1.0)
    assert c() == 1.25 and c() == 1.5
    c.advance(10)
    assert c() == pytest.approx(11.75)


def test_engine_emits_spans(x64):
    op, b, _ = _problem(5)
    RECORDER.clear()
    eng = SolveEngine(ServiceConfig(max_batch=2, chunk=16, tol=1e-8,
                                    maxiter=500))
    name = eng.register(op)
    eng.submit(name, np.asarray(b))
    eng.run()
    kinds = {s.name for s in RECORDER.spans()}
    assert {"engine.chunk", "engine.retire"} <= kinds
    RECORDER.clear()


# ---------------------------------------------------------------------------
# report CLI
# ---------------------------------------------------------------------------

def test_report_cli_smoke_and_render(x64, tmp_path, capsys):
    from repro.observe.report import main
    out = tmp_path / "observe"
    assert main(["smoke", "--out", str(out)]) == 0
    wrote = {p.name for p in out.iterdir()}
    assert {"convergence.json", "spans.trace.json", "metrics.prom",
            "metrics.json"} <= wrote
    conv = json.loads((out / "convergence.json").read_text())
    assert conv["schema"] == "repro.observe/convergence-trace/v1"
    assert conv["summary"]["status"] == "CONVERGED"
    spans = json.loads((out / "spans.trace.json").read_text())
    assert spans["metadata"]["schema"] == "repro.observe/chrome-trace/v1"
    assert any(e["name"] == "engine.chunk" for e in spans["traceEvents"])
    prom = (out / "metrics.prom").read_text()
    assert "repro_engine_requests_total" in prom

    capsys.readouterr()
    assert main(["report", "--dir", str(out)]) == 0
    text = capsys.readouterr().out
    assert "engine.chunk" in text          # timeline rendered
    assert "repro_engine_requests_total" in text
    assert "CONVERGED" in text
