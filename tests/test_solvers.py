"""Correctness tests for the Krylov solver core (paper Algs. 2.1-4.1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (SOLVERS, SolverConfig, as_matvec, bicgstab_solve,
                        gpbicg_solve, pbicgsafe_rr_solve, pbicgsafe_solve,
                        pbicgstab_solve, ssbicgsafe2_solve)
from repro.core import matrices as M
from repro.core._common import SyncCounter
from repro.core.types import identity_reduce

PROBLEMS = {
    "nonsym_dense": lambda: M.nonsym_dense(150),
    "spd_dense": lambda: M.spd_dense(120, cond=1e3),
    "poisson3d": lambda: M.poisson3d(10),
    "convdiff": lambda: M.convection_diffusion(10, peclet=1.0),
    "random_csr": lambda: M.random_nonsym(1200, 7, diag_dominance=1.1),
    "random_ell": lambda: M.random_nonsym(800, 7, fmt="ell"),
    "aniso": lambda: M.anisotropic3d(10, eps=1e-2),
}


@pytest.mark.parametrize("prob", list(PROBLEMS))
@pytest.mark.parametrize("sname", list(SOLVERS))
def test_converges_to_true_solution(x64, prob, sname):
    op, b, xt = PROBLEMS[prob]()
    mv = as_matvec(op)
    res = SOLVERS[sname](mv, b, config=SolverConfig(tol=1e-8, maxiter=4000))
    assert bool(res.converged), f"{sname} failed on {prob}"
    true_res = jnp.linalg.norm(b - mv(res.x)) / jnp.linalg.norm(b)
    # recurred residual matched by true residual (no silent drift at tol)
    assert float(true_res) < 1e-6
    assert float(jnp.linalg.norm(res.x - xt) / jnp.linalg.norm(xt)) < 1e-5


def test_pipelined_equiv_ssbicgsafe2(x64):
    """Paper §3: Alg 3.1 == Alg 2.3 in exact arithmetic.

    In fp64 the residual histories must agree to high precision over the
    first dozens of iterations (paper Fig. 5.1 observation).
    """
    op, b, _ = M.convection_diffusion(12, peclet=1.0)
    cfg = SolverConfig(tol=1e-10, maxiter=300, record_history=True)
    r1 = ssbicgsafe2_solve(op.matvec, b, config=cfg)
    r2 = pbicgsafe_solve(op.matvec, b, config=cfg)
    n = min(int(r1.iterations), int(r2.iterations), 40)
    h1, h2 = np.asarray(r1.residual_history)[:n], np.asarray(r2.residual_history)[:n]
    # Identical until round-off takes over (paper: histories "nearly
    # identical for the several dozen initial iterations", then diverge in
    # finite precision — that divergence is the motivation for §4).
    pre_roundoff = h1 > 1e-5
    np.testing.assert_allclose(h1[pre_roundoff], h2[pre_roundoff], rtol=1e-3)


def test_pipelined_equiv_bicgstab(x64):
    """p-BiCGStab (Cools-Vanroose) == BiCGStab in exact arithmetic."""
    op, b, _ = M.nonsym_dense(200)
    cfg = SolverConfig(tol=1e-9, maxiter=300, record_history=True)
    r1 = bicgstab_solve(op.matvec, b, config=cfg)
    r2 = pbicgstab_solve(op.matvec, b, config=cfg)
    assert abs(int(r1.iterations) - int(r2.iterations)) <= 1
    n = min(int(r1.iterations), int(r2.iterations), 30)
    np.testing.assert_allclose(np.asarray(r1.residual_history)[:n],
                               np.asarray(r2.residual_history)[:n], rtol=1e-5)


SYNC_COUNTS = {
    # init reductions + per-iteration reduction phases (while body traces once)
    "ssbicgsafe2": (1, 1),
    "p-bicgsafe": (1, 1),
    "p-bicgsafe-rr": (1, 1),
    "bicgstab": (1, 2),
    "p-bicgstab": (1, 2),
    "gpbicg": (1, 3),
}


@pytest.mark.parametrize("sname", list(SYNC_COUNTS))
def test_synchronization_phase_count(x64, sname):
    """The paper's central claim surface: reductions per iteration.

    ssBiCGSafe2 / p-BiCGSafe: ONE fused phase; BiCGStab family: two;
    GPBi-CG: three.  Counted at trace time (while_loop body traces once).
    """
    op, b, _ = M.nonsym_dense(64)
    counter = SyncCounter(identity_reduce)
    jax.make_jaxpr(
        lambda bb: SOLVERS[sname](op.matvec, bb,
                                  config=SolverConfig(maxiter=10),
                                  dot_reduce=counter))(b)
    init, per_iter = SYNC_COUNTS[sname]
    assert counter.calls == init + per_iter, (
        f"{sname}: {counter.calls} reduce calls traced, "
        f"expected {init}+{per_iter}")


def test_single_fused_message_is_nine_scalars(x64):
    """p-BiCGSafe's one reduction carries all 9 inner products at once."""
    op, b, _ = M.nonsym_dense(64)
    sizes = []

    def spy(partials):
        sizes.append(partials.shape)
        return partials

    jax.make_jaxpr(lambda bb: pbicgsafe_solve(
        op.matvec, bb, config=SolverConfig(maxiter=5), dot_reduce=spy))(b)
    assert sizes[0] == (1,)       # init ||r0||
    assert sizes[1] == (9,)       # the fused phase


def test_nonzero_initial_guess(x64):
    op, b, xt = M.poisson3d(8)
    x0 = jnp.full_like(b, 0.37)
    res = pbicgsafe_solve(op.matvec, b, x0, config=SolverConfig())
    assert bool(res.converged)
    assert float(jnp.linalg.norm(res.x - xt)) < 1e-5


def test_custom_r0_star(x64):
    op, b, xt = M.nonsym_dense(100)
    key = jax.random.PRNGKey(0)
    rstar = jax.random.normal(key, b.shape, dtype=b.dtype)
    res = pbicgsafe_solve(op.matvec, b, r0_star=rstar, config=SolverConfig())
    assert bool(res.converged)


def test_maxiter_cap(x64):
    op, b, _ = M.poisson3d(10)
    res = pbicgsafe_solve(op.matvec, b, config=SolverConfig(maxiter=3))
    assert int(res.iterations) == 3
    assert not bool(res.converged)


def test_history_recording(x64):
    op, b, _ = M.poisson3d(8)
    cfg = SolverConfig(maxiter=500, record_history=True)
    res = pbicgsafe_solve(op.matvec, b, config=cfg)
    h = np.asarray(res.residual_history)
    it = int(res.iterations)
    assert np.isfinite(h[:it + 1]).all()
    assert h[0] == pytest.approx(1.0)
    assert h[it] <= 1e-8
    assert np.isnan(h[it + 1:]).all()


def test_rr_matches_pipelined_on_easy_problem(x64):
    """With convergence before the first replacement epoch, -rr == plain.

    Same algebra on both paths; the -rr solver's ``lax.cond`` is a
    compilation boundary whose fusion/FMA choices differ at the ulp level
    on CPU, so "equal" means identical iteration counts and iterates that
    agree far below the solve tolerance (not bitwise).
    """
    op, b, _ = M.poisson3d(10)
    cfg = SolverConfig(maxiter=500, rr_epoch=1000)
    r1 = pbicgsafe_solve(op.matvec, b, config=cfg)
    r2 = pbicgsafe_rr_solve(op.matvec, b, config=cfg)
    assert int(r1.iterations) == int(r2.iterations)
    np.testing.assert_allclose(np.asarray(r1.x), np.asarray(r2.x), rtol=1e-9)


def test_rr_replacement_executes_and_converges(x64):
    op, b, xt = M.convection_diffusion(12, peclet=1.0)
    cfg = SolverConfig(maxiter=1000, rr_epoch=5, rr_maxiter=500)
    res = pbicgsafe_rr_solve(op.matvec, b, config=cfg)
    assert bool(res.converged)
    assert float(jnp.linalg.norm(res.x - xt) / jnp.linalg.norm(xt)) < 1e-5


def test_solvers_jit_compatible(x64):
    op, b, _ = M.poisson3d(8)
    fn = jax.jit(lambda bb: pbicgsafe_solve(op.matvec, bb,
                                            config=SolverConfig()))
    res = fn(b)
    assert bool(res.converged)


def test_float32_operation():
    """Solvers are dtype-generic; fp32 converges at a looser tolerance."""
    op, b, xt = M.poisson3d(8, dtype=jnp.float32)
    res = pbicgsafe_solve(op.matvec, b, config=SolverConfig(tol=1e-5))
    assert bool(res.converged)
    assert res.x.dtype == jnp.float32


def test_breakdown_on_singular_system(x64):
    a = jnp.zeros((16, 16), dtype=jnp.float64)
    b = jnp.ones((16,), dtype=jnp.float64)
    res = pbicgsafe_solve(lambda x: a @ x, b, config=SolverConfig(maxiter=50))
    assert bool(res.breakdown)
    assert not bool(res.converged)
    assert np.isfinite(np.asarray(res.x)).all()
