"""Property-based tests (hypothesis) for solver invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
import hypothesis.strategies as st  # noqa: E402
from hypothesis import given, settings  # noqa: E402

from conftest import enable_x64  # noqa: E402

from repro.core import (SolverConfig, pbicgsafe_solve, pbicgstab_solve,
                        ssbicgsafe2_solve)
from repro.core import matrices as M
from repro.core.linear_operator import (CSROperator, DenseOperator,
                                        ELLOperator)

SETTINGS = dict(max_examples=12, deadline=None,
                suppress_health_check=[hypothesis.HealthCheck.too_slow])


@settings(**SETTINGS)
@given(n=st.integers(24, 120), seed=st.integers(0, 2**16),
       dominance=st.floats(1.05, 2.0))
def test_pbicgsafe_solves_diag_dominant(n, seed, dominance):
    """Any row-diagonally-dominant system is solved to tolerance."""
    with enable_x64(True):
        op, b, xt = M.random_nonsym(n, min(6, n // 4 + 2), seed=seed,
                                    diag_dominance=dominance)
        res = pbicgsafe_solve(op.matvec, b,
                              config=SolverConfig(tol=1e-8, maxiter=2000))
        assert bool(res.converged) and not bool(res.breakdown)
        true_res = float(jnp.linalg.norm(b - op.matvec(res.x))
                         / jnp.linalg.norm(b))
        assert true_res < 1e-6


@settings(**SETTINGS)
@given(n=st.integers(16, 96), seed=st.integers(0, 2**16))
def test_pipelined_equals_baseline_iterations(n, seed):
    """Invariant: p-BiCGSafe and ssBiCGSafe2 take the same iteration count
    (±1 for round-off at the stopping boundary) on well-conditioned systems."""
    with enable_x64(True):
        op, b, _ = M.random_nonsym(n, 5, seed=seed, diag_dominance=1.5)
        cfg = SolverConfig(tol=1e-8, maxiter=1000)
        i1 = int(ssbicgsafe2_solve(op.matvec, b, config=cfg).iterations)
        i2 = int(pbicgsafe_solve(op.matvec, b, config=cfg).iterations)
        assert abs(i1 - i2) <= 1


@settings(**SETTINGS)
@given(n=st.integers(16, 80), seed=st.integers(0, 2**16))
def test_ell_csr_matvec_agree(n, seed):
    """Format invariance: ELL and CSR encode the same matrix."""
    with enable_x64(True):
        op_csr, b, _ = M.random_nonsym(n, 5, seed=seed)
        op_ell = ELLOperator.from_csr(op_csr)
        x = jnp.asarray(np.random.default_rng(seed).standard_normal(n))
        np.testing.assert_allclose(np.asarray(op_csr.matvec(x)),
                                   np.asarray(op_ell.matvec(x)),
                                   rtol=1e-12, atol=1e-12)


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**16), shift=st.floats(-0.3, 0.3))
def test_solution_invariant_under_x0(seed, shift):
    """The converged solution does not depend on the initial guess."""
    with enable_x64(True):
        op, b, xt = M.random_nonsym(64, 5, seed=seed, diag_dominance=1.4)
        x0 = jnp.full_like(b, shift)
        r1 = pbicgsafe_solve(op.matvec, b, config=SolverConfig())
        r2 = pbicgsafe_solve(op.matvec, b, x0, config=SolverConfig())
        assert bool(r1.converged) and bool(r2.converged)
        np.testing.assert_allclose(np.asarray(r1.x), np.asarray(r2.x),
                                   rtol=1e-5, atol=1e-7)


@settings(**SETTINGS)
@given(n=st.integers(24, 96), seed=st.integers(0, 2**16))
def test_residual_history_monotone_envelope(n, seed):
    """The min-so-far envelope of the residual history is non-increasing
    and ends below tol (smooth convergence claim for the Safe family)."""
    with enable_x64(True):
        op, b, _ = M.random_nonsym(n, 5, seed=seed, diag_dominance=1.5)
        cfg = SolverConfig(tol=1e-8, maxiter=1000, record_history=True)
        res = pbicgsafe_solve(op.matvec, b, config=cfg)
        assert bool(res.converged)
        h = np.asarray(res.residual_history)[:int(res.iterations) + 1]
        env = np.minimum.accumulate(h)
        assert env[-1] <= 1e-8
        assert (np.diff(env) <= 0).all()
