"""Shared jaxpr-walking helpers for the structural communication tests
(tests/test_substrate_parity.py and tests/_distributed_check.py)."""
import jax


def subjaxprs(eqn):
    """Yield every sub-jaxpr referenced by an equation's params."""
    for v in eqn.params.values():
        for sub in (v if isinstance(v, (list, tuple)) else [v]):
            j = getattr(sub, "jaxpr", sub)
            if isinstance(j, jax.core.Jaxpr):
                yield j


def find_while_body(jaxpr):
    """First while-loop body jaxpr, searching nested jaxprs depth-first."""
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "while":
            return eqn.params["body_jaxpr"].jaxpr
        for sub in subjaxprs(eqn):
            found = find_while_body(sub)
            if found is not None:
                return found
    return None


def count_prim(jaxpr, name):
    """Occurrences of a primitive in a jaxpr, including nested jaxprs."""
    cnt = sum(1 for eqn in jaxpr.eqns if eqn.primitive.name == name)
    for eqn in jaxpr.eqns:
        for sub in subjaxprs(eqn):
            cnt += count_prim(sub, name)
    return cnt


def eqn_needs_ppermute(body, target_eqn):
    """Overlap probe: does ``target_eqn`` (e.g. the psum of the fused dot
    partials) transitively consume any ppermute output of ``body``?

    Walks the loop body's equations in reverse, growing the set of
    variables the target needs (Literals excluded), and intersects it
    with every ppermute's outputs.  Returns ``(permute_outs, needs)`` —
    the set of halo-exchange outputs found, and whether the target
    depends on any of them (False == no dependency edge == the reduction
    may overlap the in-flight matvec).
    """
    needed = {v for v in target_eqn.invars
              if not isinstance(v, jax.core.Literal)}
    permute_outs = set()
    for eqn in reversed(body.eqns):
        if eqn is target_eqn:
            continue
        if eqn.primitive.name == "ppermute":
            permute_outs.update(eqn.outvars)
        if any(ov in needed for ov in eqn.outvars):
            needed |= {v for v in eqn.invars
                       if not isinstance(v, jax.core.Literal)}
    return permute_outs, bool(permute_outs & needed)


def find_prim_eqn(jaxpr, name):
    """First equation of the given primitive, searching nested jaxprs."""
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == name:
            return eqn
        for sub in subjaxprs(eqn):
            found = find_prim_eqn(sub, name)
            if found is not None:
                return found
    return None
