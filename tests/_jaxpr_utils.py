"""Shared jaxpr-walking helpers for the structural communication tests
(tests/test_substrate_parity.py and tests/_distributed_check.py)."""
import jax


def subjaxprs(eqn):
    """Yield every sub-jaxpr referenced by an equation's params."""
    for v in eqn.params.values():
        for sub in (v if isinstance(v, (list, tuple)) else [v]):
            j = getattr(sub, "jaxpr", sub)
            if isinstance(j, jax.core.Jaxpr):
                yield j


def find_while_body(jaxpr):
    """First while-loop body jaxpr, searching nested jaxprs depth-first."""
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "while":
            return eqn.params["body_jaxpr"].jaxpr
        for sub in subjaxprs(eqn):
            found = find_while_body(sub)
            if found is not None:
                return found
    return None


def count_prim(jaxpr, name):
    """Occurrences of a primitive in a jaxpr, including nested jaxprs."""
    cnt = sum(1 for eqn in jaxpr.eqns if eqn.primitive.name == name)
    for eqn in jaxpr.eqns:
        for sub in subjaxprs(eqn):
            cnt += count_prim(sub, name)
    return cnt


def find_prim_eqn(jaxpr, name):
    """First equation of the given primitive, searching nested jaxprs."""
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == name:
            return eqn
        for sub in subjaxprs(eqn):
            found = find_prim_eqn(sub, name)
            if found is not None:
                return found
    return None
