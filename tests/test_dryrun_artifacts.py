"""Validates the multi-pod dry-run artifacts (deliverable e).

These tests read experiments/dryrun/*.json produced by
``python -m repro.launch.dryrun --all --both-meshes`` and assert the
grading contract: every (arch x shape x mesh) cell compiled (or is an
explicitly documented skip), and the per-chip peak memory fits a 16 GB
v5e chip.  Skipped when the artifacts have not been generated.
"""
import json
from pathlib import Path

import pytest

from repro.configs import SHAPES, skip_reason
from repro.configs.base import ARCH_IDS

DRYRUN = Path(__file__).resolve().parent.parent / "experiments" / "dryrun"
MESHES = {"pod16x16": 256, "pod2x16x16": 512}
V5E_HBM = 16 * 2 ** 30


def _load(mesh, arch, shape):
    p = DRYRUN / mesh / f"{arch}__{shape}.json"
    if not p.exists():
        pytest.skip(f"dry-run artifact missing: {p} (run repro.launch.dryrun)")
    return json.loads(p.read_text())


@pytest.mark.parametrize("mesh", list(MESHES))
@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("shape", list(SHAPES))
def test_cell_compiled_or_documented_skip(mesh, arch, shape):
    rec = _load(mesh, arch, shape)
    expected_skip = skip_reason(arch, shape)
    if expected_skip:
        assert rec["status"] == "skip"
        assert rec["reason"] == expected_skip
    else:
        assert rec["status"] == "ok", rec.get("error", "")[:500]
        assert rec["compile_s"] > 0


@pytest.mark.parametrize("mesh", list(MESHES))
@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_cell_fits_v5e(mesh, arch):
    rec = _load(mesh, arch, "train_4k")
    peak = rec["memory"].get("peak_memory_in_bytes", 0)
    assert 0 < peak < V5E_HBM, f"{arch} {mesh}: peak {peak/2**30:.1f} GiB"


def test_roofline_inputs_present():
    rec = _load("pod16x16", "qwen3-8b", "train_4k")
    assert rec["analytic_global_flops"] > 1e15
    assert rec["collectives"]["total_wire_bytes"] > 0
    assert rec["collectives"]["counts"]
