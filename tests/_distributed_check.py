"""Multi-device solver checks — run in a subprocess with 8 fake devices.

Invoked by tests/test_distributed.py.  Exits nonzero on any failure.
"""
import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax  # noqa: E402

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import (SolverConfig, bicgstab_solve, gpbicg_solve,  # noqa: E402
                        pbicgsafe_rr_solve, pbicgsafe_solve, pbicgstab_solve,
                        solve_batched, ssbicgsafe2_solve)
from repro.core import matrices as M  # noqa: E402
from repro.core.distributed import (distributed_stencil_solve,  # noqa: E402
                                    distributed_stencil_solve_batched)


def check(mesh_shape, axis_names, solver, op, b_grid, ref_iters, xt):
    mesh = jax.make_mesh(mesh_shape, axis_names)
    res = distributed_stencil_solve(solver, op, b_grid, mesh,
                                    config=SolverConfig(tol=1e-8))
    it = int(res.iterations)
    assert bool(res.converged), f"{axis_names}: not converged"
    err = float(jnp.linalg.norm(res.x.reshape(-1) - xt) / jnp.linalg.norm(xt))
    assert err < 1e-6, f"{axis_names}: err {err}"
    # Same math => same iteration count modulo rounding: sharded partial
    # sums reduce in a different order than a single global sum, which can
    # shift the stopping iteration by a few when relres hovers at tol.
    assert abs(it - ref_iters) <= max(3, int(0.2 * ref_iters)), \
        f"{axis_names}: iters {it} vs {ref_iters}"
    print(f"  ok mesh={mesh_shape} axes={axis_names} "
          f"solver={solver.__module__.split('.')[-1]} iters={it} err={err:.1e}")


def check_batched(mesh_shape, axis_names, op, b, substrate):
    """Sharded multi-RHS solve: every column reproduces the local batched
    solve; one (9, m) psum per iteration (asserted in-process by
    tests/test_substrate_parity.py; here we check the numbers)."""
    m = 3
    keys = jax.random.split(jax.random.PRNGKey(11), m)
    B = jnp.stack([b] + [jax.random.normal(k, b.shape, b.dtype)
                         for k in keys[1:]], axis=1)
    cfg = SolverConfig(tol=1e-8, maxiter=2000)
    ref = solve_batched(op.matvec, B, config=cfg)
    mesh = jax.make_mesh(mesh_shape, axis_names)
    res = distributed_stencil_solve_batched(
        op, B.reshape(op.nx, op.ny, op.nz, m), mesh, config=cfg,
        substrate=substrate)
    assert bool(np.asarray(res.converged).all()), \
        f"batched {axis_names}/{substrate}: not converged"
    for j in range(m):
        xj = res.x.reshape(-1, m)[:, j]
        true = float(jnp.linalg.norm(B[:, j] - op.matvec(xj))
                     / jnp.linalg.norm(B[:, j]))
        assert true < 1e-6, (j, true)
        assert abs(int(res.iterations[j]) - int(ref.iterations[j])) \
            <= max(3, int(0.2 * int(ref.iterations[j])))
    print(f"  ok batched mesh={mesh_shape} axes={axis_names} "
          f"substrate={substrate} iters={np.asarray(res.iterations)}")


from repro.analysis import eqn_needs_ppermute as _eqn_needs_ppermute  # noqa: E402
from repro.analysis import find_while_body as _find_while_body  # noqa: E402


def check_batched_structure(op, b):
    """8-way sharded batched solve, jaxpr level: the while body holds
    EXACTLY ONE psum (the (9, m) block), halo ppermutes are present, and
    the psum's transitive inputs contain NO ppermute — the reduction has
    no dependency edge to the in-flight block matvec, so the overlap
    survives batching+sharding."""
    m = 3
    B_grid = jnp.stack([b * (j + 1) for j in range(m)],
                       axis=1).reshape(op.nx, op.ny, op.nz, m)
    mesh = jax.make_mesh((8,), ("rows",))
    jaxpr = jax.make_jaxpr(lambda BB: distributed_stencil_solve_batched(
        op, BB, mesh, config=SolverConfig(maxiter=10), jit=False))(B_grid)
    body = _find_while_body(jaxpr.jaxpr)
    assert body is not None, "no while loop found"

    psums = [e for e in body.eqns if e.primitive.name == "psum"]
    assert len(psums) == 1, f"want ONE psum/iter, got {len(psums)}"
    psum_eqn = psums[0]
    assert psum_eqn.invars[0].aval.shape == (9, m), \
        psum_eqn.invars[0].aval.shape

    permute_outs, needs = _eqn_needs_ppermute(body, psum_eqn)
    assert permute_outs, "no halo ppermutes in the loop body"
    assert not needs, \
        "the (9, m) reduction transitively consumes the halo exchange"
    print(f"  ok batched structure: 1 psum/iter of (9, {m}), "
          f"{len(permute_outs)} halo ppermute outputs, no edge to psum")


def check_precond_structure(op, b):
    """Preconditioning must not change the communication structure: the
    8-way sharded p-BiCGSafe while body with shard-local block-Jacobi
    still holds EXACTLY ONE psum (the (9,) stacked partials) and the
    psum's transitive inputs contain NO ppermute — the M^{-1}-apply rides
    inside the overlap window without adding or serializing collectives."""
    mesh = jax.make_mesh((8,), ("rows",))
    bodies = {}
    for pc in (None, "block_jacobi"):
        jaxpr = jax.make_jaxpr(lambda bb: distributed_stencil_solve(
            pbicgsafe_solve, op, bb, mesh, config=SolverConfig(maxiter=10),
            precond=pc, jit=False))(b.reshape(op.nx, op.ny, op.nz))
        body = _find_while_body(jaxpr.jaxpr)
        assert body is not None, f"no while loop (precond={pc})"
        bodies[pc] = body

    counts = {}
    for pc, body in bodies.items():
        psums = [e for e in body.eqns if e.primitive.name == "psum"]
        counts[pc] = len(psums)
        assert len(psums) == 1, \
            f"precond={pc}: want ONE psum/iter, got {len(psums)}"
        psum_eqn = psums[0]
        assert psum_eqn.invars[0].aval.shape == (9,), \
            psum_eqn.invars[0].aval.shape
        permute_outs, needs = _eqn_needs_ppermute(body, psum_eqn)
        assert permute_outs, f"precond={pc}: no halo ppermutes in body"
        assert not needs, \
            f"precond={pc}: the reduction consumes the halo exchange"
    assert counts[None] == counts["block_jacobi"], counts
    print("  ok precond structure: single-psum-per-iteration count "
          f"unchanged by block-Jacobi ({counts[None]} == "
          f"{counts['block_jacobi']}), no edge to the halo exchange")


def check_precond_numeric(mesh_shape, axis_names, op, b_grid, xt):
    """Shard-local block-Jacobi converges in <= the unpreconditioned
    iterations and still solves the ORIGINAL system."""
    mesh = jax.make_mesh(mesh_shape, axis_names)
    cfg = SolverConfig(tol=1e-8)
    plain = distributed_stencil_solve(pbicgsafe_solve, op, b_grid, mesh,
                                      config=cfg)
    prec = distributed_stencil_solve(pbicgsafe_solve, op, b_grid, mesh,
                                     config=cfg, precond="block_jacobi")
    assert bool(prec.converged), f"{axis_names}: preconditioned not converged"
    err = float(jnp.linalg.norm(prec.x.reshape(-1) - xt)
                / jnp.linalg.norm(xt))
    assert err < 1e-6, f"{axis_names}: err {err}"
    assert int(prec.iterations) <= int(plain.iterations), \
        (int(prec.iterations), int(plain.iterations))
    print(f"  ok precond mesh={mesh_shape} axes={axis_names} "
          f"block-Jacobi iters={int(prec.iterations)} <= "
          f"plain {int(plain.iterations)}, err={err:.1e}")


def check_guarded_structure(op, b):
    """Guarded + sharded: the health rows widen the fused block from
    (9, m) to (11, m) but the communication structure is untouched —
    EXACTLY ONE psum per iteration, halo ppermutes present, and the
    reduction's transitive inputs contain NO ppermute (the in-reduction
    breakdown detection costs zero extra synchronizations even across
    8 devices)."""
    m = 3
    B_grid = jnp.stack([b * (j + 1) for j in range(m)],
                       axis=1).reshape(op.nx, op.ny, op.nz, m)
    mesh = jax.make_mesh((8,), ("rows",))
    cfg = SolverConfig(maxiter=10, guard=True)
    jaxpr = jax.make_jaxpr(lambda BB: distributed_stencil_solve_batched(
        op, BB, mesh, config=cfg, jit=False))(B_grid)
    body = _find_while_body(jaxpr.jaxpr)
    assert body is not None, "no while loop found"

    psums = [e for e in body.eqns if e.primitive.name == "psum"]
    assert len(psums) == 1, f"want ONE psum/iter, got {len(psums)}"
    psum_eqn = psums[0]
    assert psum_eqn.invars[0].aval.shape == (11, m), \
        psum_eqn.invars[0].aval.shape

    permute_outs, needs = _eqn_needs_ppermute(body, psum_eqn)
    assert permute_outs, "no halo ppermutes in the loop body"
    assert not needs, \
        "the guarded (11, m) reduction transitively consumes the halo " \
        "exchange"
    print(f"  ok guarded structure: 1 psum/iter of (11, {m}), "
          f"{len(permute_outs)} halo ppermute outputs, no edge to psum")


def check_guarded_numeric(op, b):
    """Guarded sharded solve == unguarded sharded solve (same iteration
    counts, iterates equal to fusion round-off): the health rows
    observe, never steer."""
    m = 2
    B = jnp.stack([b, 0.5 * b], axis=1)
    B_grid = B.reshape(op.nx, op.ny, op.nz, m)
    mesh = jax.make_mesh((8,), ("rows",))
    plain = distributed_stencil_solve_batched(
        op, B_grid, mesh, config=SolverConfig(tol=1e-8, maxiter=2000))
    guard = distributed_stencil_solve_batched(
        op, B_grid, mesh,
        config=SolverConfig(tol=1e-8, maxiter=2000, guard=True))
    assert bool(np.asarray(guard.converged).all())
    np.testing.assert_allclose(np.asarray(guard.x), np.asarray(plain.x),
                               rtol=1e-12, atol=1e-13)
    assert np.array_equal(np.asarray(guard.iterations),
                          np.asarray(plain.iterations))
    print("  ok guarded numeric: sharded guarded == unguarded, "
          f"iters={np.asarray(guard.iterations)}")


def guarded_smoke():
    """CI/pytest smoke entry (``python tests/_distributed_check.py
    guarded``): sharded guarded structure + parity assertions."""
    assert jax.device_count() == 8, jax.device_count()
    op, b, _ = M.convection_diffusion(16, peclet=1.0)
    check_guarded_structure(op, b)
    check_guarded_numeric(op, b)
    print("GUARDED DISTRIBUTED SMOKE PASSED")


def precond_smoke():
    """CI smoke entry (``python tests/_distributed_check.py precond``):
    block-Jacobi-enabled distributed solve with the psum-count assertion."""
    assert jax.device_count() == 8, jax.device_count()
    op, b, xt = M.convection_diffusion(16, peclet=1.0)
    check_precond_structure(op, b)
    check_precond_numeric((8,), ("rows",), op, b.reshape(16, 16, 16), xt)
    print("PRECOND DISTRIBUTED SMOKE PASSED")


def main():
    assert jax.device_count() == 8, jax.device_count()
    op, b, xt = M.convection_diffusion(16, peclet=1.0)
    b_grid = b.reshape(16, 16, 16)

    solvers = [pbicgsafe_solve, ssbicgsafe2_solve, bicgstab_solve,
               pbicgstab_solve, gpbicg_solve, pbicgsafe_rr_solve]
    refs = {s: int(s(op.matvec, b, config=SolverConfig(tol=1e-8)).iterations)
            for s in solvers}

    # 1-axis ring, 2-axis (data, model), 3-axis (pod, data, model)
    for mesh_shape, axes in [((8,), ("rows",)),
                             ((4, 2), ("data", "model")),
                             ((2, 2, 2), ("pod", "data", "model"))]:
        for s in solvers:
            check(mesh_shape, axes, s, op, b_grid, refs[s], xt)

    # batched multi-RHS: row-sharded (n, m) block, one (9, m) psum/iter
    check_batched_structure(op, b)
    check_batched((8,), ("rows",), op, b, "jnp")
    check_batched((4, 2), ("data", "model"), op, b, "jnp")
    check_batched((8,), ("rows",), op, b, "pallas")

    # shard-local preconditioning: psum count unchanged, numerics hold
    check_precond_structure(op, b)
    check_precond_numeric((8,), ("rows",), op, b_grid, xt)
    check_precond_numeric((4, 2), ("data", "model"), op, b_grid, xt)
    print("ALL DISTRIBUTED CHECKS PASSED")


if __name__ == "__main__":
    if "precond" in sys.argv[1:]:
        precond_smoke()
    elif "guarded" in sys.argv[1:]:
        guarded_smoke()
    else:
        main()
