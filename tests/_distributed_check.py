"""Multi-device solver checks — run in a subprocess with 8 fake devices.

Invoked by tests/test_distributed.py.  Exits nonzero on any failure.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax  # noqa: E402

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import (SolverConfig, bicgstab_solve, gpbicg_solve,  # noqa: E402
                        pbicgsafe_rr_solve, pbicgsafe_solve, pbicgstab_solve,
                        ssbicgsafe2_solve)
from repro.core import matrices as M  # noqa: E402
from repro.core.distributed import distributed_stencil_solve  # noqa: E402


def check(mesh_shape, axis_names, solver, op, b_grid, ref_iters, xt):
    mesh = jax.make_mesh(mesh_shape, axis_names)
    res = distributed_stencil_solve(solver, op, b_grid, mesh,
                                    config=SolverConfig(tol=1e-8))
    it = int(res.iterations)
    assert bool(res.converged), f"{axis_names}: not converged"
    err = float(jnp.linalg.norm(res.x.reshape(-1) - xt) / jnp.linalg.norm(xt))
    assert err < 1e-6, f"{axis_names}: err {err}"
    # Same math => same iteration count modulo rounding: sharded partial
    # sums reduce in a different order than a single global sum, which can
    # shift the stopping iteration by a few when relres hovers at tol.
    assert abs(it - ref_iters) <= max(3, int(0.2 * ref_iters)), \
        f"{axis_names}: iters {it} vs {ref_iters}"
    print(f"  ok mesh={mesh_shape} axes={axis_names} "
          f"solver={solver.__module__.split('.')[-1]} iters={it} err={err:.1e}")


def main():
    assert jax.device_count() == 8, jax.device_count()
    op, b, xt = M.convection_diffusion(16, peclet=1.0)
    b_grid = b.reshape(16, 16, 16)

    solvers = [pbicgsafe_solve, ssbicgsafe2_solve, bicgstab_solve,
               pbicgstab_solve, gpbicg_solve, pbicgsafe_rr_solve]
    refs = {s: int(s(op.matvec, b, config=SolverConfig(tol=1e-8)).iterations)
            for s in solvers}

    # 1-axis ring, 2-axis (data, model), 3-axis (pod, data, model)
    for mesh_shape, axes in [((8,), ("rows",)),
                             ((4, 2), ("data", "model")),
                             ((2, 2, 2), ("pod", "data", "model"))]:
        for s in solvers:
            check(mesh_shape, axes, s, op, b_grid, refs[s], xt)
    print("ALL DISTRIBUTED CHECKS PASSED")


if __name__ == "__main__":
    main()
