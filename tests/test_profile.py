"""repro.observe.profile: trace parsing + overlap math on golden
timelines (device-free, deterministic).

The committed fixtures under tests/data/ are synthetic Chrome
trace-event timelines in exactly the shape ``jax.profiler.trace``'s
perfetto export produces (``ph: "X"`` device ops carrying
``args.hlo_op`` / ``args.hlo_module``):

* ``timeline_exposed.json`` — every all-reduce runs strictly AFTER the
  matvec's collective-permute finished: fully exposed communication,
  overlap efficiency 0.
* ``timeline_hidden.json`` — every all-reduce runs on a second device
  lane entirely inside the matvec's window: fully hidden, efficiency 1.

These pin the headline math the runtime captures feed
(``bench_overlap``'s measured section, ``session.solve(profile=)``).
"""
import json
import os

import pytest

from repro.observe import profile as P

DATA = os.path.join(os.path.dirname(__file__), "data")


def _load(name):
    with open(os.path.join(DATA, name)) as fh:
        return json.load(fh)


# ---------------------------------------------------------------------------
# interval math
# ---------------------------------------------------------------------------

def test_merge_intervals_coalesces_and_sorts():
    assert P.merge_intervals([(5, 7), (0, 2), (1, 3), (7, 7)]) == \
        [(0, 3), (5, 7)]


def test_merge_intervals_drops_empty():
    assert P.merge_intervals([(3, 3), (4, 2)]) == []


def test_intersect_intervals_two_pointer():
    a = [(0, 10), (20, 30)]
    b = [(5, 25), (28, 40)]
    assert P.intersect_intervals(a, b) == [(5, 10), (20, 25), (28, 30)]


def test_total():
    assert P.total([(0, 3), (5, 7)]) == 5


# ---------------------------------------------------------------------------
# golden timelines: the two extremes of the headline number
# ---------------------------------------------------------------------------

def test_fully_exposed_timeline():
    rep = P.analyze_timeline(_load("timeline_exposed.json"))
    assert rep.overlap_efficiency == 0.0
    assert rep.hidden_us == 0.0
    assert rep.reduce_us == pytest.approx(100.0)
    assert rep.exposed_us == pytest.approx(100.0)
    assert rep.matvec_us == pytest.approx(200.0)
    # iterations estimated from the most-run reduce op (2 all-reduces)
    assert rep.iterations == 2
    assert rep.exposed_per_iter_us == pytest.approx(50.0)
    # the unmapped fusion.9 falls into "other" via name heuristics
    assert rep.phase_us["other"] == pytest.approx(60.0)
    assert rep.n_device_events == 6
    # device wall is the union of all op intervals: [0,180] + [200,380]
    assert rep.device_wall_us == pytest.approx(360.0)
    # the host-side TraceAnnotation span is aggregated, not a device op
    assert rep.host_spans["api.solve"]["count"] == 1
    assert rep.host_spans["api.solve"]["total_us"] == pytest.approx(400.0)


def test_fully_hidden_timeline():
    rep = P.analyze_timeline(_load("timeline_hidden.json"))
    assert rep.overlap_efficiency == pytest.approx(1.0)
    assert rep.exposed_us == pytest.approx(0.0)
    assert rep.hidden_us == pytest.approx(80.0)
    assert rep.exposed_per_iter_us == pytest.approx(0.0)


def test_partial_overlap_half_hidden():
    doc = {"traceEvents": [
        {"ph": "X", "pid": 1, "tid": 1, "ts": 0.0, "dur": 100.0,
         "args": {"hlo_op": "collective-permute.1", "hlo_module": "m"}},
        {"ph": "X", "pid": 1, "tid": 2, "ts": 50.0, "dur": 100.0,
         "args": {"hlo_op": "all-reduce.1", "hlo_module": "m"}},
    ]}
    rep = P.analyze_timeline(doc)
    assert rep.overlap_efficiency == pytest.approx(0.5)
    assert rep.hidden_us == pytest.approx(50.0)
    assert rep.exposed_us == pytest.approx(50.0)


def test_no_reduce_time_means_no_efficiency():
    doc = {"traceEvents": [
        {"ph": "X", "pid": 1, "tid": 1, "ts": 0.0, "dur": 10.0,
         "args": {"hlo_op": "fusion.1", "hlo_module": "m"}},
    ]}
    rep = P.analyze_timeline(doc)
    assert rep.overlap_efficiency is None
    assert rep.exposed_per_iter_us is None


def test_concurrent_reduce_ops_not_double_counted():
    # two overlapping all-reduces on different lanes: union, not sum
    doc = {"traceEvents": [
        {"ph": "X", "pid": 1, "tid": 1, "ts": 0.0, "dur": 100.0,
         "args": {"hlo_op": "all-reduce.1", "hlo_module": "m"}},
        {"ph": "X", "pid": 1, "tid": 2, "ts": 50.0, "dur": 100.0,
         "args": {"hlo_op": "all-reduce.2", "hlo_module": "m"}},
    ]}
    rep = P.analyze_timeline(doc)
    assert rep.reduce_us == pytest.approx(150.0)


def test_explicit_iterations_override():
    rep = P.analyze_timeline(_load("timeline_exposed.json"), iterations=4)
    assert rep.iterations == 4
    assert rep.exposed_per_iter_us == pytest.approx(25.0)


# ---------------------------------------------------------------------------
# HLO metadata map
# ---------------------------------------------------------------------------

_HLO_TEXT = """\
HloModule jit_solve_program, entry_computation_layout={(f64[64]{0})->f64[64]{0}}

%fused_computation.1 (param_0.1: f64[64]) -> f64[9] {
  %param_0.1 = f64[64]{0} parameter(0)
  ROOT %dot.1 = f64[9]{0} dot(%param_0.1, %param_0.1), metadata={op_name="jit(solve_program)/jit(main)/while/body/repro.reduce/dot_general"}
}

%fused_computation.2 (param_0.2: f64[64]) -> f64[64] {
  %param_0.2 = f64[64]{0} parameter(0)
  ROOT %mul.3 = f64[64]{0} multiply(%param_0.2, %param_0.2), metadata={op_name="jit(solve_program)/jit(main)/while/body/repro.axpy/mul"}
}

ENTRY %main.1 (Arg_0.1: f64[64]) -> f64[64] {
  %Arg_0.1 = f64[64]{0} parameter(0)
  %fusion.1 = f64[9]{0} fusion(%Arg_0.1), kind=kLoop, calls=%fused_computation.1, metadata={op_name="jit(solve_program)/jit(main)/while/body/reduce_sum"}
  %fusion.2 = f64[64]{0} fusion(%Arg_0.1), kind=kLoop, calls=%fused_computation.2, metadata={op_name="jit(solve_program)/jit(main)/while/body/add"}
  ROOT %add.5 = f64[64]{0} add(%Arg_0.1, %Arg_0.1), metadata={op_name="jit(solve_program)/jit(main)/while/body/repro.matvec/add"}
}
"""


def test_hlo_op_map_module_and_direct_scopes():
    module, ops = P.hlo_op_map(_HLO_TEXT)
    assert module == "jit_solve_program"
    assert "repro.matvec" in ops["add.5"]


def test_hlo_op_map_attributes_fusions_by_body():
    # the fusion instruction's own metadata has no repro.* tag; the tag
    # comes from the instructions inside its called computation
    _, ops = P.hlo_op_map(_HLO_TEXT)
    assert "repro.reduce" in ops["fusion.1"]
    assert "repro.axpy" in ops["fusion.2"]
    assert P.classify_op("fusion.1", ops["fusion.1"]) == "reduce"
    assert P.classify_op("fusion.2", ops["fusion.2"]) == "axpy"


def test_classify_op_name_fallbacks():
    assert P.classify_op("all-reduce.17") == "reduce"
    assert P.classify_op("collective-permute.3") == "matvec"
    assert P.classify_op("copy.2") == "other"


def test_analyze_with_hlo_map_and_spmd_prefix_fallback():
    _, ops = P.hlo_op_map(_HLO_TEXT)
    maps = {"jit_solve_program": ops}
    doc = {"traceEvents": [
        # exact module match
        {"ph": "X", "pid": 1, "tid": 1, "ts": 0.0, "dur": 10.0,
         "args": {"hlo_op": "fusion.1",
                  "hlo_module": "jit_solve_program"}},
        # SPMD-renamed module: matched by prefix
        {"ph": "X", "pid": 1, "tid": 1, "ts": 20.0, "dur": 10.0,
         "args": {"hlo_op": "fusion.2",
                  "hlo_module": "jit_solve_program.spmd"}},
    ]}
    rep = P.analyze_timeline(doc, hlo_maps=maps)
    assert rep.phase_us["reduce"] == pytest.approx(10.0)
    assert rep.phase_us["axpy"] == pytest.approx(10.0)
    assert rep.unmapped_ops == 0


# ---------------------------------------------------------------------------
# report round-trip
# ---------------------------------------------------------------------------

def test_report_save_load_roundtrip(tmp_path):
    rep = P.analyze_timeline(_load("timeline_exposed.json"),
                             label="golden/exposed")
    p = rep.save(str(tmp_path / "profile.json"))
    back = P.ProfileReport.load(p)
    assert back == rep
    with open(p) as fh:
        assert json.load(fh)["schema"] == P.SCHEMA_PROFILE


def test_render_mentions_headline(capsys=None):
    rep = P.analyze_timeline(_load("timeline_hidden.json"))
    text = rep.render()
    assert "overlap efficiency 1.000" in text
