"""Distributed-runtime tests.

The main process must keep seeing exactly one CPU device (smoke tests +
benches), so multi-device checks run in a subprocess that sets
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` before importing jax.
"""
import os
import subprocess
import sys

import pytest

HERE = os.path.dirname(__file__)
SRC = os.path.abspath(os.path.join(HERE, os.pardir, "src"))


@pytest.mark.slow
def test_distributed_solvers_all_meshes():
    """All 6 solvers × {1,2,3}-axis meshes reproduce the reference solve."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)  # the script sets its own
    proc = subprocess.run(
        [sys.executable, os.path.join(HERE, "_distributed_check.py")],
        capture_output=True, text=True, env=env, timeout=1200)
    assert proc.returncode == 0, \
        f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
    assert "ALL DISTRIBUTED CHECKS PASSED" in proc.stdout
