"""Property-based resilience tests: one injected fault never goes silent.

The property (ISSUE 6): for ANY single injected fault — a NaN-poisoned
column at an arbitrary chunk boundary, or a simulated kernel failure —
a guarded solve either (a) RECOVERS, producing the same answer as the
fault-free unguarded solve to tolerance, or (b) reports a TYPED failure
status; in both cases every returned array is finite.  Silent NaN is a
bug, full stop.

Runs under hypothesis when it is installed; otherwise falls back to a
deterministic seeded grid of drawn examples (same property, same check
body, fixed coverage) so the suite exercises the property either way —
CI images without hypothesis still run it.
"""
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.core import SolverConfig
from repro.core import matrices as M
from repro.core.types import SolveStatus
from repro.resilience import ChunkFaultInjector, RecoveryPolicy

try:
    import hypothesis
    import hypothesis.strategies as st
    from hypothesis import given, settings
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:       # no new deps: seeded-grid fallback
    HAVE_HYPOTHESIS = False

FAULT_KINDS = ("nan", "kernel")


def _draw_examples(num=10, seed=20260808):
    """Deterministic fallback example stream mirroring the hypothesis
    strategy space (seed, size, fault chunk, faulted column, kind)."""
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(num):
        out.append(dict(seed=int(rng.integers(0, 2**16)),
                        n=int(rng.integers(24, 97)),
                        chunk_at=int(rng.integers(0, 4)),
                        col=int(rng.integers(0, 3)),
                        kind=FAULT_KINDS[int(rng.integers(0, 2))]))
    return out


def _check_single_fault(seed, n, chunk_at, col, kind, x64=None):
    """The property body: inject ONE fault, demand recovery-or-typed."""
    from conftest import enable_x64
    with enable_x64(True):
        op, b, _ = M.random_nonsym(n, min(6, n // 4 + 2), seed=seed,
                                   diag_dominance=1.3)
        b = b / jnp.linalg.norm(b)
        m = 3
        B = jnp.stack([b, 0.5 * b, b + 0.1], axis=1)
        cfg = SolverConfig(tol=1e-8, maxiter=600)
        clean = repro.make_solver("p-bicgsafe", op,
                                  config=cfg).solve_many(B)
        assert bool(np.asarray(clean.converged).all()), "bad clean baseline"

        inj = ChunkFaultInjector(
            nan_at={chunk_at: (col,)} if kind == "nan" else None,
            fail_at=(chunk_at,) if kind == "kernel" else ())
        gs = repro.make_solver(
            "p-bicgsafe", op, config=cfg,
            substrate="pallas" if kind == "kernel" else "jnp",
            recovery=RecoveryPolicy(chunk=8))
        gs.inject = inj
        res = gs.solve_many(B)

        x = np.asarray(res.x)
        relres = np.asarray(res.relres)
        assert np.isfinite(x).all(), "guarded surface leaked NaN/Inf in x"
        conv = np.asarray(res.converged)
        status = np.asarray(res.status)
        for j in range(m):
            sts = SolveStatus(int(status[j]))
            if conv[j]:
                assert sts == SolveStatus.CONVERGED
                np.testing.assert_allclose(
                    x[:, j], np.asarray(clean.x)[:, j],
                    rtol=1e-5, atol=1e-7,
                    err_msg=f"column {j} recovered to a different answer")
                assert np.isfinite(relres[j])
            else:
                assert sts.is_failure, (
                    f"column {j} unconverged without a typed failure "
                    f"status (got {sts.name})")


def _check_clean_identity(seed, n, x64=None):
    """No fault injected: the guarded program takes the unguarded
    numerical path (health rows observe, never write) — same iteration
    count, same iterate to fusion-reordering round-off, zero events."""
    from conftest import enable_x64
    with enable_x64(True):
        op, b, _ = M.random_nonsym(n, min(6, n // 4 + 2), seed=seed,
                                   diag_dominance=1.3)
        cfg = SolverConfig(tol=1e-8, maxiter=600)
        # baseline through the BATCHED m=1 program — the exact program
        # the guard widens (the single-RHS driver is a different code
        # path, not bitwise comparable)
        clean = repro.make_solver("p-bicgsafe", op,
                                  config=cfg).solve_many(b[:, None])
        gs = repro.make_solver("p-bicgsafe", op, config=cfg,
                               recovery=RecoveryPolicy(chunk=16))
        res = gs.solve(b)
        assert gs.events == []
        assert int(res.iterations) == int(clean.iterations[0])
        # the guard widens the fused dot, so XLA may fuse/reorder float
        # ops differently — identical math, round-off-level slack only
        np.testing.assert_allclose(np.asarray(res.x),
                                   np.asarray(clean.x[:, 0]),
                                   rtol=1e-12, atol=1e-13)


if HAVE_HYPOTHESIS:
    SETTINGS = dict(max_examples=10, deadline=None,
                    suppress_health_check=[hypothesis.HealthCheck.too_slow])

    @settings(**SETTINGS)
    @given(seed=st.integers(0, 2**16), n=st.integers(24, 96),
           chunk_at=st.integers(0, 3), col=st.integers(0, 2),
           kind=st.sampled_from(FAULT_KINDS))
    def test_single_fault_recovers_or_typed(seed, n, chunk_at, col, kind):
        _check_single_fault(seed, n, chunk_at, col, kind)

    @settings(**SETTINGS)
    @given(seed=st.integers(0, 2**16), n=st.integers(24, 96))
    def test_clean_guarded_is_unguarded(seed, n):
        _check_clean_identity(seed, n)

else:
    @pytest.mark.parametrize(
        "ex", _draw_examples(),
        ids=lambda ex: f"{ex['kind']}-n{ex['n']}-c{ex['chunk_at']}")
    def test_single_fault_recovers_or_typed(x64, ex):
        _check_single_fault(**ex)

    @pytest.mark.parametrize("seed,n", [(7, 32), (91, 48), (1234, 72),
                                        (5555, 96)])
    def test_clean_guarded_is_unguarded(x64, seed, n):
        _check_clean_identity(seed, n)
