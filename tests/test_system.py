"""End-to-end behaviour tests for the paper's system."""
import jax
import jax.numpy as jnp
import numpy as np

from conftest import enable_x64


def test_end_to_end_solver_pipeline():
    """The paper's full story in one test: a convection-diffusion system is
    solved by p-BiCGSafe in the same iterations as ssBiCGSafe2, faster in
    sync phases than BiCGStab, to the true solution."""
    from repro.core import (SOLVERS, SolverConfig)
    from repro.core import matrices as M
    from repro.core._common import SyncCounter
    from repro.core.types import identity_reduce

    with enable_x64(True):
        op, b, x_true = M.convection_diffusion(12, peclet=1.0)
        results = {}
        syncs = {}
        for name in ("p-bicgsafe", "ssbicgsafe2", "bicgstab"):
            counter = SyncCounter(identity_reduce)
            jax.make_jaxpr(lambda bb: SOLVERS[name](
                op.matvec, bb, config=SolverConfig(maxiter=5),
                dot_reduce=counter))(b)
            syncs[name] = counter.calls - 1     # minus init reduction
            res = SOLVERS[name](op.matvec, b, config=SolverConfig())
            assert bool(res.converged), name
            err = float(jnp.linalg.norm(res.x - x_true)
                        / jnp.linalg.norm(x_true))
            assert err < 1e-6, (name, err)
            results[name] = int(res.iterations)

    # single sync phase/iter for the paper's methods, two for BiCGStab
    assert syncs["p-bicgsafe"] == 1
    assert syncs["ssbicgsafe2"] == 1
    assert syncs["bicgstab"] == 2
    # pipelined == baseline iterations (exact-arithmetic equivalence)
    assert abs(results["p-bicgsafe"] - results["ssbicgsafe2"]) <= 1


def test_end_to_end_train_and_serve():
    """Train a tiny LM a few steps, checkpoint, serve from it."""
    import tempfile

    from repro.configs import smoke_config
    from repro.data import DataConfig
    from repro.optim import AdamWConfig
    from repro.serve import Request, ServeConfig, ServingEngine
    from repro.train import TrainConfig, train

    cfg = smoke_config("qwen3-8b")
    with tempfile.TemporaryDirectory() as d:
        out = train(cfg,
                    DataConfig(batch_size=2, seq_len=32,
                               vocab_size=cfg.vocab_size),
                    TrainConfig(steps=8, ckpt_every=4, ckpt_dir=d,
                                opt=AdamWConfig(lr=1e-3)))
        assert np.isfinite(out["final_loss"])
        eng = ServingEngine(cfg, ServeConfig(max_batch=2, max_len=48),
                            params=out["params"])
        eng.submit(Request(prompt=[1, 2, 3, 4], max_new_tokens=4))
        done = eng.run()
        assert len(done[0].output) == 4
