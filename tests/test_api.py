"""Tests for the repro.api front door: bind-once LinearSolver sessions.

Pins the three contracts of the API redesign (PR 5):

* parity — a session solve runs the SAME traced program as the legacy
  free function: bitwise-identical SolveResult for all 7 methods x 2
  substrates x {precond on/off} (and within fp-fusion noise of the
  un-jitted legacy call);
* caching — repeat solves against one session never retrace; equal-
  content operators share one session (built preconditioner included),
  across make_solver, repro.solve, and the service registry;
* deprecation hygiene — legacy shims warn once per process, the
  linear_operator re-exports warn on attribute access, and the session
  path never warns.
"""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from conftest import enable_x64  # noqa: F401  (x64 fixture dependency)
from repro.core import SOLVERS, SolverConfig, solve_batched
from repro.core import matrices as M
from repro.core._common import SyncCounter
from repro.core.types import identity_reduce


def _fields_equal(a, b):
    for x, y in zip(a, b):
        if x is None or y is None:       # optional fields (status, trace)
            if x is not y:
                return False
        elif isinstance(x, dict) or isinstance(y, dict):
            if not (isinstance(x, dict) and isinstance(y, dict)
                    and x.keys() == y.keys()
                    and _fields_equal([x[k] for k in x], [y[k] for k in x])):
                return False
        elif not np.array_equal(np.asarray(x), np.asarray(y),
                                equal_nan=True):
            return False
    return True


# ---------------------------------------------------------------------------
# session vs legacy parity: 7 methods x 2 substrates x {precond on/off}
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("substrate", ["jnp", "pallas"])
@pytest.mark.parametrize("method", list(SOLVERS))
@pytest.mark.parametrize("precond", [None, "jacobi"])
def test_session_matches_legacy_bitwise(x64, method, substrate, precond):
    """session.solve == the legacy free function, bitwise.

    The session traces the SAME program the legacy entry point runs, so
    under a common execution regime (one jit wrapper — what the session
    does) every SolveResult field is bitwise-identical.  The un-jitted
    legacy call is additionally asserted to fp-fusion noise (XLA fuses
    the init phase differently eagerly; the while-loop program is the
    same).
    """
    op, b, _ = M.convection_diffusion(8, peclet=1.0)
    cfg = SolverConfig(tol=1e-8, maxiter=500)
    session = repro.make_solver(method, op, precond=precond,
                                substrate=substrate, config=cfg)
    res = session.solve(b)
    assert bool(res.converged)

    legacy_fn = SOLVERS[method]
    legacy_jit = jax.jit(lambda bb: legacy_fn(
        op, bb, config=cfg, substrate=substrate,
        precond=session.precond))(b)
    assert _fields_equal(res, legacy_jit), (
        f"{method}/{substrate}/precond={precond}: session result is not "
        "bitwise-identical to the (jitted) legacy entry point")

    legacy_eager = legacy_fn(op, b, config=cfg, substrate=substrate,
                             precond=precond)
    assert int(legacy_eager.iterations) == int(res.iterations)
    np.testing.assert_allclose(np.asarray(res.x), np.asarray(legacy_eager.x),
                               rtol=1e-9, atol=1e-10)


@pytest.mark.parametrize("substrate", ["jnp", "pallas"])
@pytest.mark.parametrize("precond", [None, "jacobi"])
def test_solve_many_matches_legacy_bitwise(x64, substrate, precond):
    """session.solve_many == legacy solve_batched, bitwise per field."""
    op, b, _ = M.poisson3d(8)
    B = jnp.stack([b, 0.5 * b, b + 1.0], axis=1)
    cfg = SolverConfig(tol=1e-8, maxiter=500)
    session = repro.make_solver("p-bicgsafe", op, precond=precond,
                                substrate=substrate, config=cfg)
    res = session.solve_many(B)
    assert bool(np.asarray(res.converged).all())
    # the session runs the SAME program as the legacy entry point: under
    # a common execution regime (one jit wrapper, the session's built
    # preconditioner instance — binding it once is the point of the
    # redesign) every field is bitwise-identical
    legacy_jit = jax.jit(lambda BB: solve_batched(
        op, BB, config=cfg, substrate=substrate,
        precond=session.precond))(B)
    assert _fields_equal(res, legacy_jit), (
        f"solve_many/{substrate}/precond={precond}: not bitwise-identical "
        "to the (jitted) legacy solve_batched")
    # and the plain eager name-spec legacy call agrees to fp-fusion noise
    named = solve_batched(op, B, config=cfg, substrate=substrate,
                          precond=precond)
    np.testing.assert_allclose(np.asarray(res.x), np.asarray(named.x),
                               rtol=1e-9, atol=1e-10)
    np.testing.assert_array_equal(np.asarray(res.iterations),
                                  np.asarray(named.iterations))


def test_solve_many_accepts_column_vectors_and_per_column_settings(x64):
    op, b, _ = M.poisson3d(8)
    session = repro.make_solver("p-bicgsafe", op,
                                config=SolverConfig(maxiter=2000))
    tols = jnp.asarray([1e-4, 1e-8, 1e-10])
    res = session.solve_many([b, 0.5 * b, b + 1.0], tol=tols)
    assert bool(np.asarray(res.converged).all())
    relres = np.asarray(res.relres)
    for j, tol in enumerate(np.asarray(tols)):
        assert relres[j] <= tol
    iters = np.asarray(res.iterations)
    assert iters[0] < iters[1] < iters[2]
    # heterogeneous tol batches share ONE compiled program (tol is a
    # runtime argument, not baked into the trace)
    before = session.stats["traces"]
    session.solve_many([b, b, b], tol=jnp.asarray([1e-3, 1e-6, 1e-9]))
    assert session.stats["traces"] == before


def test_open_loop_handles_match_solve_many(x64):
    """init + step_chunk through the session == solve_many (same k)."""
    op, b, _ = M.poisson3d(8)
    cfg = SolverConfig(tol=1e-8, maxiter=300)
    session = repro.make_solver("p-bicgsafe", op, precond="jacobi",
                                config=cfg)
    B = jnp.stack([b, 2.0 * b], axis=1)
    st = session.init(B)
    st = session.step_chunk(st, cfg.maxiter)
    res = session.result(st)
    ref = session.solve_many(B)
    assert _fields_equal(res, ref)


def test_session_splice_resets_columns(x64):
    """Splicing a fresh rhs into a converged block restarts that column
    (the service's refill path, via the session handle)."""
    op, b, _ = M.poisson3d(8)
    session = repro.make_solver("p-bicgsafe", op,
                                config=SolverConfig(tol=1e-8, maxiter=300))
    B = jnp.stack([b, 0.5 * b], axis=1)
    st = session.step_chunk(session.init(B), 300)
    assert bool(np.asarray(st["converged"]).all())
    fresh = jax.random.normal(jax.random.PRNGKey(0), b.shape, b.dtype)
    st = session.splice(st, jnp.asarray([False, True]),
                        jnp.stack([b, fresh], axis=1))
    assert not bool(st["converged"][1])
    assert bool(st["converged"][0])
    st = session.step_chunk(st, 300)
    res = session.result(st)
    assert bool(np.asarray(res.converged).all())
    solo = session.solve_many(fresh[:, None])
    assert int(res.iterations[1]) == int(solo.iterations[0])


# ---------------------------------------------------------------------------
# caching: no retrace on repeat solves; content-keyed session reuse
# ---------------------------------------------------------------------------

def test_second_solve_does_not_retrace(x64):
    """The headline amortization: solve #2 with a NEW b reuses the
    compiled program (trace count stays 1) and the built preconditioner."""
    op, b, _ = M.poisson3d(8)
    session = repro.make_solver("p-bicgsafe", op, precond="block_jacobi")
    pc = session.precond
    assert pc is not None                     # built at bind time, once
    session.solve(b)
    assert session.stats["traces"] == 1
    for i in range(3):
        session.solve(b + float(i + 1))
    assert session.stats["traces"] == 1, "repeat solves must not retrace"
    assert session.precond is pc
    # a different static override compiles its own program, once
    session.solve(b, tol=1e-4)
    session.solve(2.0 * b, tol=1e-4)
    assert session.stats["traces"] == 2


def test_make_solver_content_cache_hit(x64):
    """Equal-content operators (fresh objects) return the SAME session:
    the fingerprint promoted out of service/registry.py is the key."""
    s1 = repro.make_solver("p-bicgsafe", M.poisson3d(8)[0],
                           precond="block_jacobi")
    s1.solve(M.poisson3d(8)[1])
    traces = s1.stats["traces"]
    s2 = repro.make_solver("p-bicgsafe", M.poisson3d(8)[0],
                           precond="block_jacobi")
    assert s2 is s1                            # fingerprint hit
    assert s2.precond is s1.precond
    s2.solve(2.0 * M.poisson3d(8)[1])
    assert s1.stats["traces"] == traces        # compiled program reused

    # distinct content / spec / method / substrate: distinct sessions
    assert repro.make_solver("p-bicgsafe", M.poisson3d(10)[0],
                             precond="block_jacobi") is not s1
    assert repro.make_solver("p-bicgsafe", M.poisson3d(8)[0],
                             precond="jacobi") is not s1
    assert repro.make_solver("bicgstab", M.poisson3d(8)[0],
                             precond="block_jacobi") is not s1
    assert repro.make_solver("p-bicgsafe", M.poisson3d(8)[0],
                             precond="block_jacobi",
                             substrate="pallas") is not s1


def test_repro_solve_one_shot_hits_session_cache(x64):
    op, b, xt = M.poisson3d(8)
    r1 = repro.solve(op, b, tol=1e-8)
    assert bool(r1.converged)
    s = repro.make_solver("p-bicgsafe", M.poisson3d(8)[0],
                          config=SolverConfig())
    traces = s.stats["traces"]
    r2 = repro.solve(M.poisson3d(8)[0], 2.0 * b, tol=1e-8)
    assert bool(r2.converged)
    assert s.stats["traces"] == traces, (
        "repeat repro.solve against equal content must reuse the session")


def test_service_registry_consumes_api_cache(x64):
    """The service registry is a thin consumer: registering an operator
    shares the session with a direct make_solver of the same content."""
    from repro.service import ServiceConfig, SolveEngine
    scfg = ServiceConfig(max_batch=2, chunk=8, tol=1e-8, maxiter=250)
    eng = SolveEngine(scfg)
    name = eng.register(M.poisson3d(8)[0], precond="jacobi")
    entry = eng.registry[name]
    direct = repro.make_solver(
        "p-bicgsafe", M.poisson3d(8)[0], precond="jacobi",
        config=SolverConfig(tol=scfg.tol, maxiter=scfg.maxiter))
    assert entry.session is direct
    assert entry.precond is direct.precond


def test_uncacheable_sessions_are_fresh(x64):
    """Bare matvec callables are not content-addressable: sessions are
    built fresh (no id-aliasing risk), and still solve correctly."""
    op, b, xt = M.poisson3d(8)
    s1 = repro.make_solver("p-bicgsafe", op.matvec)
    s2 = repro.make_solver("p-bicgsafe", op.matvec)
    assert s1 is not s2
    assert s1.fingerprint is None
    res = s1.solve(b)
    assert bool(res.converged)
    err = float(jnp.linalg.norm(res.x - xt) / jnp.linalg.norm(xt))
    assert err < 1e-5
    # name-spec preconds need an operator object — loud, as before
    with pytest.raises(TypeError, match="operator"):
        repro.make_solver("p-bicgsafe", op.matvec, precond="jacobi")


def test_custom_dot_reduce_skips_cache_and_counts_syncs(x64):
    """dot_reduce callables are honored (sessions just aren't cached):
    the session path keeps ONE reduction per iteration."""
    op, b, _ = M.nonsym_dense(64)
    counter = SyncCounter(identity_reduce)
    s = repro.make_solver("p-bicgsafe", op, dot_reduce=counter,
                          config=SolverConfig(maxiter=10))
    assert repro.make_solver("p-bicgsafe", op,
                             config=SolverConfig(maxiter=10)) is not s
    s.solve(b)
    assert counter.calls == 2                  # init ||r0|| + 1/iter
    s.solve(2.0 * b)
    assert counter.calls == 2                  # no retrace, no new syncs


# ---------------------------------------------------------------------------
# distributed binding
# ---------------------------------------------------------------------------

def test_on_mesh_matches_legacy_distributed(x64):
    """session.on_mesh(mesh) == the legacy distributed drivers, bitwise,
    and repeat solves reuse the built shard_map program."""
    from repro.core.compat import make_mesh
    from repro.core.distributed import (distributed_stencil_solve,
                                        distributed_stencil_solve_batched)
    op, b, _ = M.convection_diffusion(8, peclet=1.0)
    mesh = make_mesh((1,), ("rows",))
    cfg = SolverConfig(tol=1e-8, maxiter=500)
    session = repro.make_solver("p-bicgsafe", op, precond="jacobi",
                                config=cfg)
    dist = session.on_mesh(mesh)

    b_grid = b.reshape(8, 8, 8)
    res = dist.solve(b_grid)
    ref = distributed_stencil_solve(SOLVERS["p-bicgsafe"], op, b_grid, mesh,
                                    config=cfg, precond="jacobi")
    assert _fields_equal(res, ref)

    B_grid = jnp.stack([b, 2.0 * b], axis=1).reshape(8, 8, 8, 2)
    resb = dist.solve_many(B_grid)
    refb = distributed_stencil_solve_batched(op, B_grid, mesh, config=cfg,
                                             precond="jacobi")
    assert _fields_equal(resb, refb)

    programs = session.stats["programs"]
    dist.solve(2.0 * b_grid)
    dist.solve_many(3.0 * B_grid)
    assert session.stats["programs"] == programs, (
        "repeat distributed solves must reuse the built programs")
    # the binding itself is memoized, so the literal loop idiom the
    # deprecation message recommends (.on_mesh(mesh).solve(b) per call)
    # also reuses the built shard_map programs
    assert session.on_mesh(mesh) is dist
    session.on_mesh(mesh).solve(b_grid)
    assert session.stats["programs"] == programs


def test_on_mesh_requires_stencil_operator(x64):
    from repro.core.compat import make_mesh
    op, _, _ = M.nonsym_dense(16)
    with pytest.raises(TypeError, match="Stencil7"):
        repro.make_solver("p-bicgsafe", op).on_mesh(
            make_mesh((1,), ("rows",)))


def test_on_mesh_rejects_custom_dot_reduce(x64):
    """The sharded driver supplies its own single-psum reduction; a
    session-bound dot_reduce must fail loudly, not be silently dropped."""
    from repro.core.compat import make_mesh
    op, _, _ = M.convection_diffusion(8, peclet=1.0)
    session = repro.make_solver("p-bicgsafe", op,
                                dot_reduce=lambda p: p)
    with pytest.raises(ValueError, match="dot_reduce"):
        session.on_mesh(make_mesh((1,), ("rows",)))


def test_on_mesh_only_session_skips_global_precond_build(x64):
    """A session used only via .on_mesh never pays the global
    preconditioner build — the distributed binding rebuilds the name
    spec shard-locally (the legacy drivers' cost model, kept)."""
    from repro.core.compat import make_mesh
    op, b, _ = M.convection_diffusion(8, peclet=1.0)
    session = repro.make_solver(
        "p-bicgsafe", M.convection_diffusion(8, peclet=1.0)[0],
        precond="block_jacobi", config=SolverConfig(maxiter=300))
    dist = session.on_mesh(make_mesh((1,), ("rows",)))
    res = dist.solve(b.reshape(8, 8, 8))
    assert bool(res.converged)
    assert not session._precond_built, (
        "mesh-only usage must not build the global preconditioner")
    # first LOCAL use builds it, once
    assert session.precond is not None
    assert session._precond_built


# ---------------------------------------------------------------------------
# deprecation hygiene
# ---------------------------------------------------------------------------

def test_legacy_shims_warn_once_per_process(x64):
    """Each legacy entry point emits a single DeprecationWarning per
    process — not per call — and the session path emits none."""
    from repro.core import _deprecation, pbicgsafe_solve
    op, b, _ = M.poisson3d(8)
    _deprecation.reset_for_testing()
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        pbicgsafe_solve(op, b, config=SolverConfig(maxiter=5))
        pbicgsafe_solve(op, b, config=SolverConfig(maxiter=5))
        dep = [x for x in w if issubclass(x.category, DeprecationWarning)
               and "pbicgsafe_solve" in str(x.message)]
    assert len(dep) == 1, "legacy shim must warn exactly once per process"

    _deprecation.reset_for_testing()
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        s = repro.make_solver("p-bicgsafe", op,
                              config=SolverConfig(maxiter=50))
        s.solve(b)
        s.solve_many(jnp.stack([b, b], axis=1))
        dep = [x for x in w if issubclass(x.category, DeprecationWarning)]
    assert not dep, f"session path must never warn: {[str(d.message) for d in dep]}"


def test_linear_operator_reexports_warn_but_preserve_identity(x64):
    """The historical repro.core.linear_operator aliases warn on access
    (no more silent aliasing) and still return the repro.precond
    objects themselves."""
    import repro.precond as P
    from repro.core import _deprecation
    from repro.core import linear_operator as LO
    _deprecation.reset_for_testing()
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        assert LO.JacobiPreconditioner is P.JacobiPreconditioner
        assert LO.preconditioned_matvec is P.preconditioned_matvec
        dep = [x for x in w if issubclass(x.category, DeprecationWarning)]
    assert len(dep) == 2
    with pytest.raises(AttributeError):
        LO.not_a_thing
    # the repro.core package-level alias gets the same treatment
    import repro.core as C
    _deprecation.reset_for_testing()
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        assert C.preconditioned_matvec is P.preconditioned_matvec
        dep = [x for x in w if issubclass(x.category, DeprecationWarning)]
    assert len(dep) == 1


def test_service_accepts_substrate_instance(x64):
    """ServiceConfig.substrate documents Substrate instances; a fresh
    instance must register fine (the session just is not globally
    cached) — regression for the fingerprint-skip on uncached
    substrates."""
    from repro.core import JnpSubstrate
    from repro.service import ServiceConfig, SolveEngine
    eng = SolveEngine(ServiceConfig(max_batch=2, chunk=8, maxiter=200,
                                    substrate=JnpSubstrate()))
    name = eng.register(M.poisson3d(8)[0], precond="jacobi", name="p")
    entry = eng.registry[name]
    assert entry.fingerprint is not None
    # equal-content re-registration still dedups within the engine
    n2 = eng.register(M.poisson3d(8)[0], precond="jacobi")
    assert eng.registry[n2] is entry
    op, b, _ = M.poisson3d(8)
    rid = eng.submit("p", b)
    res = {r.rid: r for r in eng.run()}
    assert res[rid].converged


# ---------------------------------------------------------------------------
# construction errors are loud
# ---------------------------------------------------------------------------

def test_make_solver_errors(x64):
    op, b, _ = M.poisson3d(8)
    with pytest.raises(ValueError, match="unknown method"):
        repro.make_solver("bicgfoo", op)
    with pytest.raises(TypeError, match="requires an operator"):
        repro.make_solver("p-bicgsafe")
    blocked = repro.make_solver(
        "p-bicgsafe", jax.vmap(op.matvec, in_axes=1, out_axes=1),
        blocked=True)
    with pytest.raises(ValueError, match="blocked"):
        blocked.solve(b)
    res = blocked.solve_many(jnp.stack([b, 2.0 * b], axis=1))
    assert bool(np.asarray(res.converged).all())
    with pytest.raises(ValueError, match=r"\(n, m\)"):
        repro.make_solver("p-bicgsafe", op).solve_many(b)


def test_session_cache_is_bounded(x64):
    """The content-keyed cache is LRU-bounded: churning operator content
    (time-stepping one-shots) must not pin every historical session."""
    from repro import api
    api.clear_session_cache()
    for i in range(api._SESSION_CACHE_MAX + 8):
        a = jnp.eye(4) * (2.0 + i)
        repro.make_solver("p-bicgsafe", repro.DenseOperator(a))
    assert api.session_cache_info()["sessions"] == api._SESSION_CACHE_MAX
    api.clear_session_cache()


def test_service_rejects_bare_callable_operator(x64):
    """The engine needs op.shape/dtype and content addressing; a bare
    matvec is rejected loudly at registration, not deep in submit."""
    from repro.service import ServiceConfig, SolveEngine
    op, _, _ = M.poisson3d(8)
    eng = SolveEngine(ServiceConfig())
    with pytest.raises(TypeError, match="content-addressable"):
        eng.register(op.matvec)


def test_batched_paths_require_pbicgsafe(x64):
    """The batched/open-loop iteration IS p-BiCGSafe; a session bound to
    another method must fail loudly on those entry points instead of
    silently running the wrong algorithm."""
    from repro.core.compat import make_mesh
    op, b, _ = M.convection_diffusion(8, peclet=1.0)
    session = repro.make_solver("bicgstab", op,
                                config=SolverConfig(maxiter=200))
    assert bool(session.solve(b).converged)        # single-RHS: fine
    B = jnp.stack([b, 2.0 * b], axis=1)
    with pytest.raises(ValueError, match="p-bicgsafe"):
        session.solve_many(B)
    with pytest.raises(ValueError, match="p-bicgsafe"):
        session.init(B)
    with pytest.raises(ValueError, match="p-bicgsafe"):
        session.on_mesh(make_mesh((1,), ("rows",))).solve_many(
            B.reshape(8, 8, 8, 2))


def test_mutable_operator_sessions_not_served_stale(x64):
    """A session over a writeable-numpy-backed operator must not stay
    findable after the backing array is mutated in place: such sessions
    are simply never cached (same immutability bar as the digest memo)."""
    a = np.diag(np.full(8, 2.0))
    s1 = repro.make_solver("p-bicgsafe", repro.DenseOperator(a))
    a *= 50.0                                  # mutate under the cache
    fresh = repro.DenseOperator(np.diag(np.full(8, 2.0)))
    s2 = repro.make_solver("p-bicgsafe", fresh)
    assert s2 is not s1, "stale session served for mutated content"
    x = np.asarray(s2.solve(jnp.ones(8)).x)
    np.testing.assert_allclose(x, 0.5)         # solves 2*x = 1, not 100*x


def test_fingerprint_not_memoized_for_mutable_operators(x64):
    """An operator backed by a writeable numpy array can be mutated in
    place under the caller's feet: its fingerprint must be re-hashed per
    call (no stale memo serving results for the OLD content)."""
    a = np.eye(6) * 3.0
    op = repro.DenseOperator(a)
    fp1 = repro.operator_fingerprint(op)
    a *= 2.0                                   # in-place mutation
    fp2 = repro.operator_fingerprint(op)
    assert fp1 != fp2, "mutated content must change the fingerprint"
    # immutable (jax-array-backed) operators ARE memoized: same digest,
    # and the repeat call is a dict hit (covered by the O(1) claim)
    op_j = repro.DenseOperator(jnp.asarray(a))
    assert repro.operator_fingerprint(op_j) == repro.operator_fingerprint(op_j)


def test_fingerprint_rejects_non_array_content(x64):
    with pytest.raises(TypeError, match="fingerprint"):
        repro.operator_fingerprint(lambda x: x)
    # the precond/base delegate keeps the historical import path alive
    from repro.precond import operator_fingerprint as legacy_fp
    op = M.poisson3d(8)[0]
    assert legacy_fp(op, "jacobi") == repro.operator_fingerprint(op, "jacobi")
