"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes + no NaNs; plus prefill->decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, smoke_config
from repro.models import (decode_step, forward, init_cache, init_params,
                          loss_fn, prefill_step)

B, S = 2, 32


def make_batch(cfg, key, seq=S):
    ks = jax.random.split(key, 3)
    batch = {"tokens": jax.random.randint(ks[0], (B, seq), 0,
                                          cfg.vocab_size, jnp.int32)}
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(ks[1], (B, seq, cfg.d_model),
                                            jnp.float32).astype(cfg.dtype)
    if cfg.family == "vlm":
        n_patch = 8
        batch["patch_embeds"] = jax.random.normal(
            ks[1], (B, n_patch, cfg.d_model), jnp.float32).astype(cfg.dtype)
        t = jnp.arange(seq)[None, :, None]
        batch["positions"] = jnp.broadcast_to(t, (B, seq, 3)).astype(jnp.int32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_no_nan(arch):
    cfg = smoke_config(arch)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    logits, aux = forward(params, cfg, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_loss_finite(arch):
    cfg = smoke_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    (loss, metrics), grads = jax.value_and_grad(
        lambda p: loss_fn(p, cfg, batch), has_aux=True)(params)
    assert np.isfinite(float(loss))
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree_util.tree_leaves(grads)))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step_runs(arch):
    cfg = smoke_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    cache = init_cache(cfg, B, 64, enc_len=S)
    tok = jnp.ones((B, 1), jnp.int32)
    logits, cache2 = decode_step(params, cfg, cache, tok,
                                 jnp.asarray(3, jnp.int32))
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert jax.tree_util.tree_structure(cache) == \
        jax.tree_util.tree_structure(cache2)


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_matches_forward(arch):
    """prefill_step's logits == forward's logits (same math + caches)."""
    cfg = smoke_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    l1, _ = forward(params, cfg, batch)
    l2, cache = prefill_step(params, cfg, batch)
    np.testing.assert_allclose(np.asarray(l1, np.float32),
                               np.asarray(l2, np.float32),
                               rtol=2e-2, atol=2e-2)
    assert all(np.isfinite(np.asarray(x, np.float32)).all()
               for x in jax.tree_util.tree_leaves(cache))


@pytest.mark.parametrize("arch", [
    "phi3-mini-3.8b", "qwen3-8b", "deepseek-v3-671b",
    "whisper-tiny", "qwen2-vl-72b"])
def test_prefill_then_decode_consistent(arch):
    """Greedy decode after prefill ~ teacher-forced forward logits."""
    cfg = smoke_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    full_logits, _ = forward(params, cfg, batch)

    # prefill on the first S-1 tokens, then decode token S-1
    pre = {k: (v[:, :S - 1] if k in ("tokens", "frames") else v)
           for k, v in batch.items()}
    if "positions" in pre:
        pre["positions"] = batch["positions"][:, :S - 1]
    _, cache = prefill_step(params, cfg, pre)
    cache = pad_cache(cfg, cache, S + 8)
    tok = batch["tokens"][:, S - 1:S]
    logits, _ = decode_step(params, cfg, cache, tok,
                            jnp.asarray(S - 1, jnp.int32))
    a = np.asarray(logits[:, 0], np.float32)
    b = np.asarray(full_logits[:, S - 1], np.float32)
    # same argmax and mostly-close values (bf16; decode uses different
    # arithmetic, e.g. absorbed-MLA vs reconstruction for deepseek)
    assert (a.argmax(-1) == b.argmax(-1)).mean() >= 0.5
    close = np.isclose(a, b, rtol=0.1, atol=0.15).mean()
    assert close >= 0.85, f"only {close:.1%} of logits close"


def test_moe_dispatch_batch_invariance():
    """Regression for the deepseek prefill/decode drift: the drift was NOT
    decode dtype/accumulation — it was capacity dropping in the gather
    dispatch.  Expert assignment there is batch-competitive (tokens race
    for (expert, slot) capacity), so the same token gets a different FFN
    output depending on which other tokens share the batch; single-token
    decode never hits capacity while a full prefill does.  The dropless
    sort dispatch (what deepseek-v3 now uses; the real model is dropless)
    must be batch-invariant: per-token outputs equal the batched output."""
    from repro.models.moe import _route, init_moe_params, moe_ffn
    cfg = smoke_config("deepseek-v3-671b")
    assert cfg.moe_impl == "sort"
    p = init_moe_params(jax.random.PRNGKey(3), cfg)
    # an input stream routed very unevenly: bias one router direction so
    # one expert is oversubscribed past gather's capacity
    key = jax.random.PRNGKey(4)
    x = jax.random.normal(key, (2, 32, cfg.d_model), jnp.float32)
    x = (x + 2.0 * jnp.asarray(np.linalg.svd(
        np.asarray(p["router"], np.float64), full_matrices=False
    )[0][:, 0])[None, None, :]).astype(cfg.dtype)

    loads = np.bincount(
        np.asarray(_route(p, x.reshape(-1, cfg.d_model).astype(cfg.dtype),
                          cfg)[1]).reshape(-1),
        minlength=cfg.moe_experts)
    n, k, E = 64, cfg.moe_top_k, cfg.moe_experts
    capacity = int(max(4, cfg.moe_capacity_factor * n * k / E))
    assert loads.max() > capacity, (
        f"test vector too tame: loads {loads} all within capacity "
        f"{capacity}; the drop regime is what this test must cover")

    y_batch, _ = moe_ffn(p, x, cfg, impl="sort")
    y_tok = jnp.concatenate(
        [moe_ffn(p, x[:, i:i + 1], cfg, impl="sort")[0] for i in range(32)],
        axis=1)
    np.testing.assert_allclose(np.asarray(y_batch, np.float32),
                               np.asarray(y_tok, np.float32),
                               rtol=2e-2, atol=2e-2)
    # and the gather dispatch provably is NOT batch-invariant here (the
    # pinned root cause): same inputs, capacity drops change outputs
    yg_batch, _ = moe_ffn(p, x, cfg, impl="gather")
    yg_tok = jnp.concatenate(
        [moe_ffn(p, x[:, i:i + 1], cfg, impl="gather")[0] for i in range(32)],
        axis=1)
    assert float(jnp.abs(yg_batch - yg_tok).max()) > 1e-3


def pad_cache(cfg, cache, max_len):
    """Right-pad length-S prefill caches to max_len along the seq axis."""
    grow = {"k", "v", "ckv", "krope"}

    def pad(path, x):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if name in grow:
            pads = [(0, 0)] * x.ndim
            pads[2] = (0, max_len - x.shape[2])
            return jnp.pad(x, pads)
        return x

    return jax.tree_util.tree_map_with_path(pad, cache)
