"""Property-based tests (hypothesis) for the preconditioning subsystem:
on the hard problem classes, preconditioned p-BiCGSafe converges and
never needs more iterations than the unpreconditioned solve."""
import pytest

hypothesis = pytest.importorskip("hypothesis")
import hypothesis.strategies as st  # noqa: E402
from hypothesis import given, settings  # noqa: E402

from conftest import enable_x64  # noqa: E402

from repro.core import SolverConfig, pbicgsafe_solve
from repro.core import matrices as M

SETTINGS = dict(max_examples=8, deadline=None,
                suppress_health_check=[hypothesis.HealthCheck.too_slow])


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**10), scale_range=st.floats(4.0, 8.0))
def test_precond_helps_hard_nonsym(seed, scale_range):
    """On every hard_nonsym instance, block-Jacobi p-BiCGSafe converges
    and needs no more iterations than the unpreconditioned solve."""
    with enable_x64(True):
        op, b, _ = M.hard_nonsym(n=240, seed=seed, scale_range=scale_range)
        cfg = SolverConfig(tol=1e-8, maxiter=1200)
        plain = pbicgsafe_solve(op, b, config=cfg)
        prec = pbicgsafe_solve(op, b, config=cfg, precond="block_jacobi")
        assert bool(prec.converged) and not bool(prec.breakdown)
        assert int(prec.iterations) <= int(plain.iterations)


@settings(**SETTINGS)
@given(nx=st.sampled_from([6, 8, 10]), eps=st.floats(1e-3, 1e-1))
def test_precond_helps_anisotropic3d(nx, eps):
    """On every anisotropic3d instance, SSOR p-BiCGSafe converges and
    needs no more iterations than the unpreconditioned solve."""
    with enable_x64(True):
        op, b, _ = M.anisotropic3d(nx, eps=eps)
        cfg = SolverConfig(tol=1e-8, maxiter=2000)
        plain = pbicgsafe_solve(op, b, config=cfg)
        prec = pbicgsafe_solve(op, b, config=cfg, precond="ssor")
        assert bool(prec.converged) and not bool(prec.breakdown)
        assert int(prec.iterations) <= int(plain.iterations)
