"""Tests for the continuous-batching solve service (repro.service).

The engine multiplexes heterogeneous requests onto one resident
(n, max_batch) block; these tests pin its three contracts:

* correctness — every multiplexed request returns the same
  x / iterations / converged (to tolerance) as a standalone
  ``solve_batched`` call, including requests that enter via mid-flight
  refill, on both substrates (deterministic + hypothesis property tests);
* communication — the engine's step program issues exactly ONE
  ``dot_reduce`` per iteration with NO dependency edge from the fused
  (9, m) reduction to the in-flight block matvec, on both substrates
  (contract probes via repro.analysis);
* caching — re-registering an operator with equal content reuses the
  built preconditioner AND the compiled step programs (fingerprint
  cache).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax import lax

from repro.analysis import (BindingSpec, find_while_body as _find_while_body,
                            reduction_consumes_matvec, tag_matvec,
                            tag_reduce, trace_fn)
from repro.core import SolverConfig, solve_batched
from repro.core import matrices as M
from repro.core._common import SyncCounter
from repro.core.multirhs import init_state, step_chunk
from repro.core.substrate import get_substrate
from repro.core.types import identity_reduce
from repro.service import ServiceConfig, SolveEngine


def _standalone(op, b, tol, maxiter, substrate="jnp", precond=None):
    return solve_batched(
        op, jnp.asarray(b)[:, None],
        config=SolverConfig(tol=tol, maxiter=maxiter),
        substrate=substrate, precond=precond)


def _check_request(r, ref, *, rtol=1e-6, atol=1e-8, iter_slack=1):
    """Engine column == standalone solve_batched column, to tolerance."""
    assert r.converged == bool(ref.converged[0]), (
        f"rid {r.rid}: engine converged={r.converged}, "
        f"standalone={bool(ref.converged[0])}")
    assert abs(r.iterations - int(ref.iterations[0])) <= iter_slack, (
        f"rid {r.rid}: iterations {r.iterations} vs "
        f"{int(ref.iterations[0])}")
    np.testing.assert_allclose(r.x, np.asarray(ref.x[:, 0]),
                               rtol=rtol, atol=atol,
                               err_msg=f"rid {r.rid}")


# ---------------------------------------------------------------------------
# engine == standalone, with mid-flight refill
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("substrate", ["jnp", "pallas"])
def test_engine_matches_standalone_with_refill(x64, substrate):
    """More requests than slots, heterogeneous tolerances and budgets,
    two operators (one preconditioned): every request must reproduce its
    standalone solve — including the ones that entered via mid-flight
    splice (N > max_batch and staggered finish times force refills)."""
    op1, b1, _ = M.poisson3d(8)
    op2, b2, _ = M.convection_diffusion(8, peclet=1.0)
    eng = SolveEngine(ServiceConfig(max_batch=3, chunk=4, tol=1e-8,
                                    maxiter=400, substrate=substrate))
    eng.register(op1, name="poisson")
    eng.register(op2, precond="jacobi", name="convdiff")

    rng = np.random.default_rng(7)
    tols = [1e-4, 1e-8, 1e-10]
    reqs = []
    for i in range(8):
        opn = "poisson" if i % 2 == 0 else "convdiff"
        b = jnp.asarray(rng.standard_normal(512))
        tol = tols[i % 3]
        rid = eng.submit(opn, b, tol=tol, maxiter=300)
        reqs.append((rid, opn, b, tol))

    results = {r.rid: r for r in eng.run()}
    assert len(results) == len(reqs)
    assert not eng.has_work()
    # 8 requests through 3+3 slots: refills necessarily happened
    for rid, opn, b, tol in reqs:
        op = op1 if opn == "poisson" else op2
        pc = None if opn == "poisson" else "jacobi"
        ref = _standalone(op, b, tol, 300, substrate=substrate, precond=pc)
        _check_request(results[rid], ref)


def test_engine_per_request_maxiter_and_deadline(x64):
    """Per-request budgets: a maxiter-capped request retires unconverged
    at exactly its budget (device-enforced); a deadline-blown request
    retires at the next chunk boundary with the partial iterate and the
    telemetry flag set; a queued request whose deadline lapses before a
    slot frees never occupies one."""
    t = [0.0]
    op, b, _ = M.hard_nonsym(200)       # slow enough to outlive deadlines
    eng = SolveEngine(ServiceConfig(max_batch=2, chunk=4, maxiter=10_000),
                      clock=lambda: t[0])
    eng.register(op, name="hard")
    rid_budget = eng.submit("hard", b, maxiter=50)
    rid_deadline = eng.submit("hard", b, deadline=0.5)
    rid_expired = eng.submit("hard", 2.0 * b, deadline=0.1)  # queued-only

    out = []
    while eng.has_work():
        out.extend(eng.poll())
        t[0] += 0.2
    res = {r.rid: r for r in out}
    assert len(res) == 3

    assert not res[rid_budget].converged
    assert res[rid_budget].iterations == 50
    assert not res[rid_budget].telemetry.deadline_exceeded

    assert not res[rid_deadline].converged
    assert res[rid_deadline].telemetry.deadline_exceeded
    assert res[rid_deadline].iterations > 0          # partial progress

    assert res[rid_expired].telemetry.deadline_exceeded
    assert res[rid_expired].iterations == 0
    assert res[rid_expired].telemetry.chunks_resident == 0


def test_engine_telemetry(x64):
    """Telemetry fields are populated and consistent."""
    op, b, _ = M.poisson3d(8)
    eng = SolveEngine(ServiceConfig(max_batch=2, chunk=8, maxiter=200))
    eng.register(op, name="p")
    rids = [eng.submit("p", jnp.asarray(v))
            for v in np.random.default_rng(1).standard_normal((5, 512))]
    res = {r.rid: r for r in eng.run()}
    assert len(res) == 5
    for rid in rids:
        tel = res[rid].telemetry
        assert tel.chunks_resident >= 1
        assert tel.queue_wait_s >= 0.0
        assert tel.wall_s >= tel.service_s >= 0.0
        assert not tel.deadline_exceeded
    # 5 requests / 2 slots: the late ones waited in the queue
    waits = sorted(res[r].telemetry.queue_wait_s for r in rids)
    assert waits[-1] > waits[0]


# (the hypothesis property test over random request streams lives in
# tests/test_service_properties.py so this module still runs when
# hypothesis is absent — same split as test_precond_properties.py)


# ---------------------------------------------------------------------------
# communication structure of the engine's step program
# ---------------------------------------------------------------------------

def _engine_entry(op, substrate, max_batch=3, chunk=8, precond=None):
    eng = SolveEngine(ServiceConfig(max_batch=max_batch, chunk=chunk,
                                    substrate=substrate))
    name = eng.register(op, precond=precond)
    return eng.registry[name]


@pytest.mark.parametrize("substrate", ["jnp", "pallas"])
def test_engine_step_single_reduction_per_iter(x64, substrate):
    """The engine's step program performs exactly ONE dot_reduce in its
    iteration body — the (9, m) fused block — for any resident mix."""
    op, b, _ = M.nonsym_dense(64)
    entry = _engine_entry(op, substrate)
    m = 3
    B = jnp.stack([b, 0.5 * b, b + 1.0], axis=1)
    counter = SyncCounter(identity_reduce)
    sub = get_substrate(substrate)
    bmv = entry.bmv
    state = init_state(bmv, B, substrate=sub)
    jaxpr = jax.make_jaxpr(lambda st: step_chunk(
        bmv, st, 8, dot_reduce=counter, substrate=sub))(state)
    assert counter.calls == 1, "step body must trace ONE dot_reduce"
    body = _find_while_body(jaxpr.jaxpr)
    assert body is not None, "step program must be one while_loop"


@pytest.mark.parametrize("substrate", ["jnp", "pallas"])
@pytest.mark.parametrize("precond", [None, "block_jacobi"])
def test_engine_step_overlap_edge(x64, substrate, precond):
    """The engine step program keeps the paper's overlap invariant: the
    (9, m) fused reduction has NO dependency path from the in-flight
    block matvec (preconditioned or not) — multiplexing requests must not
    serialize the reduction behind the SpMV."""
    from repro.precond import resolve_precond
    op, b, _ = M.nonsym_dense(64)
    sub = get_substrate(substrate)
    m = 3
    B = jnp.stack([b, 0.5 * b, b + 1.0], axis=1)

    # the engine's composed block matvec (M^{-1} ∘ A), tagged with the
    # repro.analysis probe tags
    base = jax.vmap(op.matvec, in_axes=1, out_axes=1)
    tagged = tag_matvec(base)
    pc = resolve_precond(precond, op)
    if pc is not None:
        papply = sub.as_precond_apply(pc)
        bmv = lambda X: papply(tagged(X))  # noqa: E731
        Bp = papply(B)
    else:
        bmv, Bp = tagged, B

    state = init_state(bmv, Bp, substrate=sub)
    spec = BindingSpec(method="p-bicgsafe", substrate=str(substrate),
                      binding="open_loop", precond=precond, m=m)
    tb = trace_fn(lambda st: step_chunk(
        bmv, st, 8, dot_reduce=tag_reduce, substrate=sub), state, spec=spec)
    assert tb.body is not None
    reds = tb.reduce_eqns()
    assert len(reds) == 1, "fused (9, m) phase not found in step body"
    assert reds[0].invars[0].aval.shape == (9, m)
    edge, detail, _ = reduction_consumes_matvec(tb)
    assert not edge, (
        "the engine step's fused reduction must keep NO dependency edge "
        f"to the in-flight block matvec (comm-hiding under load): {detail}")


def test_engine_kernel_backed_assertion(x64):
    """The pallas-substrate service path is kernel-backed."""
    op, _, _ = M.poisson3d(8)
    entry = _engine_entry(op, "pallas")
    assert entry.kernel_backed
    assert not _engine_entry(op, "jnp").kernel_backed


# ---------------------------------------------------------------------------
# registry: fingerprint-keyed reuse
# ---------------------------------------------------------------------------

def test_registry_fingerprint_reuses_precond_and_programs(x64):
    """Re-registering equal content returns the SAME entry: the built
    preconditioner and the compiled step programs are reused (repeat
    traffic against the same A must not rebuild/retrace)."""
    eng = SolveEngine(ServiceConfig(max_batch=2))
    op_a = M.poisson3d(8)[0]
    op_b = M.poisson3d(8)[0]            # equal content, fresh object
    assert op_a is not op_b
    n1 = eng.register(op_a, precond="block_jacobi", name="A")
    n2 = eng.register(op_b, precond="block_jacobi")       # cache hit
    e1, e2 = eng.registry[n1], eng.registry[n2]
    assert e1 is e2
    assert e1.precond is e2.precond
    assert e1.step_fn is e2.step_fn
    assert len(eng.registry.entries()) == 1

    # different precond spec or different content: distinct entries
    n3 = eng.register(op_a, precond="jacobi")
    assert eng.registry[n3] is not e1
    n4 = eng.register(M.poisson3d(10)[0], precond="block_jacobi")
    assert eng.registry[n4] is not e1
    assert len(eng.registry.entries()) == 3

    # name collision with different content is loud
    with pytest.raises(ValueError, match="different content"):
        eng.register(M.convection_diffusion(8)[0], name="A")


def test_registry_unknown_operator_is_loud(x64):
    eng = SolveEngine(ServiceConfig())
    with pytest.raises(KeyError, match="unknown operator"):
        eng.submit("nope", jnp.ones((8,)))


def test_submit_validates_rhs_shape(x64):
    eng = SolveEngine(ServiceConfig())
    name = eng.register(M.poisson3d(8)[0], name="p")
    with pytest.raises(ValueError, match="shape"):
        eng.submit(name, jnp.ones((7,)))
