"""Compute-substrate tests: jnp/pallas parity, sync counts, the structural
overlap invariant, and the batched multi-RHS path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax import lax

from conftest import enable_x64
from repro.analysis import (BindingSpec, count_prim as _count_prim,
                            find_prim_eqn as _find_prim_eqn,
                            find_while_body as _find_while_body,
                            reduction_consumes_matvec, tag_matvec,
                            tag_reduce, trace_fn)
from repro.core import (SOLVERS, SolverConfig, get_substrate, pbicgsafe_solve,
                        solve_batched, ssbicgsafe2_solve)
from repro.core import matrices as M
from repro.core._common import SyncCounter
from repro.core.types import identity_reduce
from repro.scenarios import build_problem

# built through the scenario registry's operator plugins (one shared
# definition per family; cached per spec content)
SEED_PROBLEMS = {
    "poisson3d": lambda: build_problem("poisson3d", nx=8),
    "convdiff": lambda: build_problem("convection_diffusion", nx=10,
                                      peclet=1.0),
}


# ---------------------------------------------------------------------------
# substrate resolution
# ---------------------------------------------------------------------------

def test_get_substrate_resolution():
    assert get_substrate(None).name == "jnp"
    assert get_substrate("jnp").name == "jnp"
    assert get_substrate("pallas").name == "pallas"
    sub = get_substrate("pallas")
    assert get_substrate(sub) is sub
    with pytest.raises(ValueError, match="unknown substrate"):
        get_substrate("cuda")


# ---------------------------------------------------------------------------
# jnp <-> pallas parity (interpret mode on CPU: same kernel bodies as TPU)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("prob", list(SEED_PROBLEMS))
@pytest.mark.parametrize("sname", ["p-bicgsafe", "ssbicgsafe2"])
def test_pallas_substrate_iterate_parity(x64, prob, sname):
    """Both substrates run the same algorithm: same iterate trajectory up
    to fp64 summation-order noise.  On the SPD seed problem the iteration
    counts are identical and the iterates bitwise-close; on the
    convection-diffusion problem the tol check may flip by a couple of
    iterations (the kernel accumulates block-wise, jnp.vdot pairwise,
    and the crossing point lands differently per XLA build), so there
    we assert the drift bound and solution-level parity instead."""
    op, b, xt = SEED_PROBLEMS[prob]()
    cfg = SolverConfig(tol=1e-8, maxiter=2000)
    r_jnp = SOLVERS[sname](op.matvec, b, config=cfg, substrate="jnp")
    r_pal = SOLVERS[sname](op.matvec, b, config=cfg, substrate="pallas")
    assert bool(r_jnp.converged) and bool(r_pal.converged)
    if prob == "poisson3d":
        assert int(r_jnp.iterations) == int(r_pal.iterations), (
            f"{sname}/{prob}: substrate changed the iteration count")
        np.testing.assert_allclose(np.asarray(r_pal.x), np.asarray(r_jnp.x),
                                   rtol=1e-9, atol=1e-10)
        np.testing.assert_allclose(float(r_pal.relres), float(r_jnp.relres),
                                   rtol=1e-6)
    else:
        assert abs(int(r_jnp.iterations) - int(r_pal.iterations)) <= 2
        for res in (r_jnp, r_pal):
            true = float(jnp.linalg.norm(b - op.matvec(res.x))
                         / jnp.linalg.norm(b))
            assert true < 1e-6
        np.testing.assert_allclose(np.asarray(r_pal.x), np.asarray(r_jnp.x),
                                   rtol=1e-5, atol=1e-7)


@pytest.mark.parametrize("sname", ["bicgstab", "p-bicgstab", "gpbicg",
                                   "p-bicgsafe-rr", "cgs"])
def test_all_entry_points_accept_substrate(x64, sname):
    """Every solver entry point takes substrate= and still converges."""
    op, b, xt = M.poisson3d(8)
    res = SOLVERS[sname](op.matvec, b, config=SolverConfig(tol=1e-8),
                         substrate="pallas")
    assert bool(res.converged)
    assert float(jnp.linalg.norm(res.x - xt) / jnp.linalg.norm(xt)) < 1e-5


def test_pallas_substrate_dispatches_banded_ell_spmv(x64):
    """An ELLOperator with banded structure routes through the Pallas SpMV
    when passed (as an operator) to a solver on the pallas substrate."""
    n = 1024
    rng = np.random.default_rng(0)
    offs = np.array([-2, -1, 0, 1, 2])
    cols = np.clip(np.arange(n)[:, None] + offs[None, :], 0, n - 1)
    vals = rng.standard_normal((n, 5))
    vals[:, 2] = 1.0 + 1.2 * np.abs(vals).sum(axis=1)
    from repro.core import ELLOperator
    ell = ELLOperator(jnp.asarray(vals), jnp.asarray(cols, np.int32), n)
    xt = jnp.ones((n,), jnp.float64)
    b = ell.matvec(xt)
    res = pbicgsafe_solve(ell, b, config=SolverConfig(tol=1e-10),
                          substrate="pallas")
    assert bool(res.converged)
    assert float(jnp.linalg.norm(res.x - xt) / jnp.linalg.norm(xt)) < 1e-7


# ---------------------------------------------------------------------------
# communication structure survives the refactor
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("substrate", ["jnp", "pallas"])
@pytest.mark.parametrize("sname,per_iter", [("ssbicgsafe2", 1),
                                            ("p-bicgsafe", 1)])
def test_sync_count_per_substrate(x64, substrate, sname, per_iter):
    """The substrate refactor keeps ONE reduction/iter for the safes."""
    op, b, _ = M.nonsym_dense(64)
    counter = SyncCounter(identity_reduce)
    jax.make_jaxpr(
        lambda bb: SOLVERS[sname](op.matvec, bb,
                                  config=SolverConfig(maxiter=10),
                                  dot_reduce=counter,
                                  substrate=substrate))(b)
    assert counter.calls == 1 + per_iter


def _reduction_sees_matvec(solve, op, b, substrate, precond=None) -> bool:
    """Structural overlap probe via the repro.analysis contract core.

    The matvec output and the fused-dot partials are tagged
    (``tag_matvec`` / ``tag_reduce``); ``reduction_consumes_matvec``
    then walks the while-body jaxpr for a path from the reduction back
    to the matvec tag.  False == no dependency edge == the reduction
    may overlap the matvec.

    Works for the single-RHS solvers ((9,) partials) and for
    ``solve_batched`` ((9, m) partial blocks; ``b`` is then (n, m), and
    the tag wraps the block matvec — optimization_barrier has no vmap
    batching rule, so the barrier must sit outside the column lift).
    """
    if b.ndim == 2:
        mv = tag_matvec(jax.vmap(op.matvec, in_axes=1, out_axes=1))
        solve_kw = {"blocked": True}
        binding = "batched"
    else:
        mv = tag_matvec(op.matvec)
        solve_kw = {}
        binding = "single"

    if precond is not None:
        # instances only: the probe hands the solver a tagged CALLABLE,
        # which a name spec could not build from.  The matvec tag sits
        # inside the M^{-1} ∘ A composition, so "reduction needs the tag"
        # still captures any edge to the in-flight precond+matvec (the
        # apply is strictly downstream of the tag).
        solve_kw["precond"] = precond
    spec = BindingSpec(method="probe", substrate=str(substrate),
                      binding=binding)
    tb = trace_fn(lambda bb: solve(
        mv, bb, config=SolverConfig(maxiter=10), dot_reduce=tag_reduce,
        substrate=substrate, **solve_kw), b, spec=spec)
    edge, _, _ = reduction_consumes_matvec(tb)
    return edge


@pytest.mark.parametrize("substrate", ["jnp", "pallas"])
def test_overlap_edge_survives_substrate_refactor(x64, substrate):
    """p-BiCGSafe's fused dots read only {s, y, r, t_prev, rs}: no path
    from the in-flight matvec to the reduction (the paper's overlap
    property), on EITHER substrate; ssBiCGSafe2's reduction consumes the
    fresh matvec, so there the edge must exist."""
    op, b, _ = M.nonsym_dense(64)
    assert not _reduction_sees_matvec(pbicgsafe_solve, op, b, substrate)
    assert _reduction_sees_matvec(ssbicgsafe2_solve, op, b, substrate)


@pytest.mark.parametrize("substrate", ["jnp", "pallas"])
@pytest.mark.parametrize("pname", ["jacobi", "block_jacobi", "neumann"])
def test_overlap_edge_survives_preconditioning(x64, substrate, pname):
    """The tentpole invariant of the preconditioned pipelined method: the
    M^{-1}-apply joins the in-flight matvec INSIDE the overlap window, so
    the fused reduction still has no dependency path to it — while
    preconditioned ssBiCGSafe2 (whose dots consume the fresh
    preconditioned matvec) must keep the edge."""
    from repro.precond import resolve_precond
    op, b, _ = M.nonsym_dense(64)
    pc = resolve_precond(pname, op)
    assert not _reduction_sees_matvec(pbicgsafe_solve, op, b, substrate,
                                      precond=pc)
    assert _reduction_sees_matvec(ssbicgsafe2_solve, op, b, substrate,
                                  precond=pc)


@pytest.mark.parametrize("substrate", ["jnp", "pallas"])
def test_overlap_edge_survives_precond_batching(x64, substrate):
    """Preconditioned + batched: the (9, m) block reduction keeps no path
    from the in-flight preconditioned BLOCK matvec."""
    from repro.precond import block_jacobi
    op, b, _ = M.nonsym_dense(64)
    B = jnp.stack([b, 0.5 * b, b + 1.0], axis=1)
    assert not _reduction_sees_matvec(solve_batched, op, B, substrate,
                                      precond=block_jacobi(op, 16))


# the per-solver reduction-phase table of test_solvers (single source of
# truth), which preconditioning must NOT change (no preconditioner
# computes an inner product); cgs only appears here because its
# unpreconditioned count is asserted by test_converges_* instead
from test_solvers import SYNC_COUNTS as _SYNC_COUNTS  # noqa: E402

PRECOND_SYNC_COUNTS = dict(_SYNC_COUNTS, cgs=(1, 2))


@pytest.mark.parametrize("substrate", ["jnp", "pallas"])
@pytest.mark.parametrize("sname", list(PRECOND_SYNC_COUNTS))
def test_sync_count_preconditioned(x64, substrate, sname):
    """Preconditioning leaves every solver's synchronization count
    untouched, on either substrate (all preconditioned paths)."""
    op, b, _ = M.nonsym_dense(64)
    counter = SyncCounter(identity_reduce)
    jax.make_jaxpr(
        lambda bb: SOLVERS[sname](op, bb,
                                  config=SolverConfig(maxiter=10),
                                  dot_reduce=counter,
                                  substrate=substrate,
                                  precond="block_jacobi"))(b)
    init, per_iter = PRECOND_SYNC_COUNTS[sname]
    assert counter.calls == init + per_iter, (
        f"{sname}: preconditioning changed the reduce count "
        f"({counter.calls} != {init}+{per_iter})")


def test_sync_count_preconditioned_batched(x64):
    """solve_batched with precond: still exactly one (9, m) reduction per
    iteration for any m."""
    op, b, _ = M.poisson3d(8)
    for m in (1, 3):
        counter = SyncCounter(identity_reduce)
        jax.make_jaxpr(lambda bb: solve_batched(
            op, bb, config=SolverConfig(maxiter=10),
            dot_reduce=counter, precond="ssor"))(_rhs_block(b, m))
        assert counter.calls == 2, (m, counter.calls)   # init + 1/iter


@pytest.mark.parametrize("substrate", ["jnp", "pallas"])
def test_overlap_edge_survives_batching(x64, substrate):
    """The (9, m) fused block reduction of solve_batched still has no
    dependency path from the in-flight BLOCK matvec — batching the
    reduction must not serialize it behind the SpMV, on either substrate."""
    op, b, _ = M.nonsym_dense(64)
    B = jnp.stack([b, 0.5 * b, b + 1.0], axis=1)
    assert not _reduction_sees_matvec(solve_batched, op, B, substrate)


# ---------------------------------------------------------------------------
# sharded batched solve: one psum/iter, no edge to the halo exchange
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("substrate", ["jnp", "pallas"])
@pytest.mark.parametrize("m", [1, 4])
@pytest.mark.parametrize("precond", [None, "block_jacobi"])
def test_sharded_batched_single_psum_per_iter(x64, substrate, m, precond):
    """The sharded batched solve lowers to EXACTLY ONE psum per iteration
    — the (9, m) block — for any m and either substrate (the paper's
    one-synchronization property).  A 1-device mesh suffices for the
    count (the psum is mesh-size independent); the multi-device halo /
    dependency-edge structure is asserted in tests/_distributed_check.py
    and benchmarks/bench_overlap.py on 8 fake devices."""
    from repro.core.compat import make_mesh
    from repro.core.distributed import distributed_stencil_solve_batched

    op, b, _ = M.convection_diffusion(8, peclet=1.0)
    B_grid = jnp.stack([b * (j + 1) for j in range(m)],
                       axis=1).reshape(8, 8, 8, m)
    mesh = make_mesh((1,), ("rows",))
    jaxpr = jax.make_jaxpr(lambda BB: distributed_stencil_solve_batched(
        op, BB, mesh, config=SolverConfig(maxiter=10),
        substrate=substrate, precond=precond, jit=False))(B_grid)
    body = _find_while_body(jaxpr.jaxpr)
    assert body is not None, "no while loop in the sharded batched solve"
    assert _count_prim(body, "psum") == 1, "must be ONE reduction/iter"
    psum_eqn = _find_prim_eqn(body, "psum")
    assert psum_eqn.invars[0].aval.shape == (9, m), \
        "the one reduction must carry the whole (9, m) partial block"


# ---------------------------------------------------------------------------
# batched multi-RHS path
# ---------------------------------------------------------------------------

def _rhs_block(b, m, seed=3):
    keys = jax.random.split(jax.random.PRNGKey(seed), m)
    cols = [b] + [jax.random.normal(k, b.shape, b.dtype) for k in keys[1:]]
    return jnp.stack(cols, axis=1)


@pytest.mark.parametrize("substrate", ["jnp", "pallas"])
def test_batched_matches_looped(x64, substrate):
    """Each batched column solves its system (true residual at tol) and
    needs essentially the per-column iteration counts of looped solves."""
    op, b, _ = M.convection_diffusion(10, peclet=1.0)
    B = _rhs_block(b, 4)
    cfg = SolverConfig(tol=1e-8, maxiter=2000)
    res = solve_batched(op.matvec, B, config=cfg, substrate=substrate)
    assert bool(np.asarray(res.converged).all())
    for j in range(B.shape[1]):
        true = float(jnp.linalg.norm(B[:, j] - op.matvec(res.x[:, j]))
                     / jnp.linalg.norm(B[:, j]))
        assert true < 1e-6, (j, true)
        rj = pbicgsafe_solve(op.matvec, B[:, j], config=cfg)
        # same algorithm per column; allow a couple iters of fp drift
        assert abs(int(res.iterations[j]) - int(rj.iterations)) <= 3


def test_batched_single_reduction_any_m(x64):
    """Exactly one dot_reduce per iteration regardless of m."""
    op, b, _ = M.poisson3d(8)
    for m in (1, 3, 17):
        counter = SyncCounter(identity_reduce)
        jax.make_jaxpr(lambda bb: solve_batched(
            op.matvec, bb, config=SolverConfig(maxiter=10),
            dot_reduce=counter))(_rhs_block(b, m))
        assert counter.calls == 2, (m, counter.calls)   # init + 1/iter


def test_batched_reduction_is_one_9xm_block(x64):
    """The per-iteration message is a single (9, m) partial block."""
    op, b, _ = M.poisson3d(8)
    m = 5
    sizes = []

    def spy(partials):
        sizes.append(partials.shape)
        return partials

    jax.make_jaxpr(lambda bb: solve_batched(
        op.matvec, bb, config=SolverConfig(maxiter=5),
        dot_reduce=spy))(_rhs_block(b, m))
    assert sizes[0] == (1, m)     # init ||r0|| per column
    assert sizes[1] == (9, m)     # the fused phase, all m systems at once


@pytest.mark.parametrize("substrate", ["jnp", "pallas"])
def test_batched_per_rhs_masking(x64, substrate):
    """Columns converge at their own iteration; early columns freeze (on
    the pallas substrate the freeze happens in-kernel via the convergence
    mask the update-phase kernel consumes)."""
    op, b, _ = M.poisson3d(8)
    # power-of-two scaling keeps the fp trajectory bitwise identical
    B = jnp.stack([b, (2.0 ** -20) * b, jax.random.normal(
        jax.random.PRNGKey(0), b.shape, b.dtype)], axis=1)
    cfg = SolverConfig(tol=1e-8, maxiter=2000)
    res = solve_batched(op.matvec, B, config=cfg, substrate=substrate)
    iters = np.asarray(res.iterations)
    assert bool(np.asarray(res.converged).all())
    # scaled column converges in the same iterations as its parent
    assert iters[1] == iters[0]
    assert np.asarray(res.relres).max() <= 1e-8


def test_batched_pallas_jnp_parity_per_column(x64):
    """solve_batched(substrate="pallas") == substrate="jnp" column by
    column: same per-column iteration counts and fp64-tolerance iterates
    (interpret mode on CPU runs the same kernel bodies as TPU)."""
    op, b, _ = M.convection_diffusion(10, peclet=1.0)
    B = _rhs_block(b, 4)
    cfg = SolverConfig(tol=1e-8, maxiter=2000)
    r_jnp = solve_batched(op.matvec, B, config=cfg, substrate="jnp")
    r_pal = solve_batched(op.matvec, B, config=cfg, substrate="pallas")
    assert bool(np.asarray(r_jnp.converged).all())
    assert bool(np.asarray(r_pal.converged).all())
    for j in range(B.shape[1]):
        assert int(r_jnp.iterations[j]) == int(r_pal.iterations[j]), (
            f"column {j}: substrate changed the iteration count")
        np.testing.assert_allclose(
            np.asarray(r_pal.x[:, j]), np.asarray(r_jnp.x[:, j]),
            rtol=1e-6, atol=1e-8, err_msg=f"column {j}")
    # relres sits at ~tol where block-wise vs pairwise summation order is
    # visible; the iterates themselves are asserted tight above
    np.testing.assert_allclose(np.asarray(r_pal.relres),
                               np.asarray(r_jnp.relres),
                               rtol=5e-2, atol=1e-10)


def test_batched_pallas_block_ell_spmv(x64):
    """A banded ELLOperator handed to solve_batched on the pallas
    substrate routes through the BLOCK ELL kernel (matrix tiles read once
    for all m columns) and reproduces the jnp path."""
    n, m = 1024, 3
    rng = np.random.default_rng(0)
    offs = np.array([-2, -1, 0, 1, 2])
    cols = np.clip(np.arange(n)[:, None] + offs[None, :], 0, n - 1)
    vals = rng.standard_normal((n, 5))
    vals[:, 2] = 1.0 + 1.2 * np.abs(vals).sum(axis=1)
    from repro.core import ELLOperator, get_substrate
    ell = ELLOperator(jnp.asarray(vals), jnp.asarray(cols, np.int32), n)

    # dispatch check: the block matvec is the kernel, not a vmap
    bmv = get_substrate("pallas").as_block_matvec(ell)
    X = jnp.asarray(rng.standard_normal((n, m)))
    np.testing.assert_allclose(np.asarray(bmv(X)),
                               np.stack([np.asarray(ell.matvec(X[:, j]))
                                         for j in range(m)], axis=1),
                               rtol=1e-10)

    Xt = jnp.ones((n, m), jnp.float64) * jnp.arange(1., m + 1.)
    B = bmv(Xt)
    res = solve_batched(ell, B, config=SolverConfig(tol=1e-10),
                        substrate="pallas")
    assert bool(np.asarray(res.converged).all())
    err = float(jnp.linalg.norm(res.x - Xt) / jnp.linalg.norm(Xt))
    assert err < 1e-7


@pytest.mark.parametrize("substrate", ["jnp", "pallas"])
def test_batched_per_column_tol(x64, substrate):
    """solve_batched accepts an (m,) tol vector: each column converges
    against its OWN tolerance (what heterogeneous service requests need),
    matching a standalone solve at that tolerance, on both substrates."""
    op, b, _ = M.poisson3d(8)
    B = _rhs_block(b, 3)
    tols = jnp.asarray([1e-4, 1e-8, 1e-10])
    cfg = SolverConfig(tol=1e-8, maxiter=2000)
    res = solve_batched(op.matvec, B, config=cfg, substrate=substrate,
                        tol=tols)
    assert bool(np.asarray(res.converged).all())
    relres = np.asarray(res.relres)
    iters = np.asarray(res.iterations)
    for j, tol in enumerate(np.asarray(tols)):
        assert relres[j] <= tol, (j, relres[j], tol)
        solo = solve_batched(op.matvec, B[:, j:j + 1],
                             config=SolverConfig(tol=float(tol),
                                                 maxiter=2000),
                             substrate=substrate)
        assert int(iters[j]) == int(solo.iterations[0]), (
            f"column {j}: per-column tol changed the trajectory")
    # looser columns stop earlier than tighter ones
    assert iters[0] < iters[1] < iters[2]


def test_batched_per_column_tol_shape_is_loud(x64):
    """A wrong-length tol vector must not silently broadcast."""
    op, b, _ = M.poisson3d(8)
    with pytest.raises(ValueError, match="per-column tol"):
        solve_batched(op.matvec, _rhs_block(b, 3),
                      tol=jnp.asarray([1e-8, 1e-8]))


def test_batched_history_and_x0(x64):
    op, b, _ = M.poisson3d(8)
    B = _rhs_block(b, 3)
    X0 = jnp.full_like(B, 0.37)
    cfg = SolverConfig(tol=1e-8, maxiter=500, record_history=True)
    res = solve_batched(op.matvec, B, X0, config=cfg)
    assert bool(np.asarray(res.converged).all())
    h = np.asarray(res.residual_history)
    assert h.shape == (501, 3)
    for j in range(3):
        it = int(res.iterations[j])
        assert np.isfinite(h[:it + 1, j]).all()
        assert np.isnan(h[it + 1:, j]).all()


def test_batched_rejects_1d_rhs(x64):
    op, b, _ = M.poisson3d(8)
    with pytest.raises(ValueError, match=r"\(n, m\)"):
        solve_batched(op.matvec, b)


def test_masked_normalizes_m1_degenerate_shapes(x64):
    """multirhs._masked accepts coefficients whose trailing m=1 axis was
    squeezed away (e.g. by a dot_reduce that collapses the (9, 1) partial
    block to (9,)) instead of raising / producing mis-shaped state."""
    from repro.core.multirhs import _masked
    mask = jnp.asarray([True])
    # scalar new vs (1,) old — the squeezed-coefficient case
    out = _masked(mask, jnp.asarray(2.0), jnp.asarray([1.0]))
    assert out.shape == (1,) and float(out[0]) == 2.0
    # (n,) new vs (n, 1) old
    out = _masked(jnp.asarray([False]), jnp.arange(4.0),
                  jnp.zeros((4, 1)))
    assert out.shape == (4, 1)
    np.testing.assert_array_equal(np.asarray(out), np.zeros((4, 1)))
    # matching ranks stay the fast path
    out = _masked(jnp.asarray([True, False]), jnp.ones((3, 2)),
                  jnp.zeros((3, 2)))
    np.testing.assert_array_equal(np.asarray(out),
                                  np.stack([np.ones(3), np.zeros(3)], 1))
    # m>1 rank collapse stays a LOUD failure (a dot_reduce that sums away
    # a real RHS axis must not silently broadcast one column to all m)
    with pytest.raises(ValueError, match="rank mismatch"):
        _masked(jnp.asarray([True, True]), jnp.ones(()), jnp.zeros((2,)))


# ---------------------------------------------------------------------------
# deprecation hygiene: the init_state/step_chunk refactor of solve_batched
# (PR 4) must be BYTE-identical to the historical monolithic while_loop
# ---------------------------------------------------------------------------

def _solve_batched_pre_refactor(matvec, B, *, config):
    """Verbatim copy of the pre-refactor ``solve_batched`` hot loop
    (git 9a6cb8c): one closed ``lax.while_loop`` with a scalar global
    iteration counter, closure-carried RS/norm_r0, and a scalar tol.
    The refactored open-loop wrapper must reproduce it bit for bit."""
    from repro.core._common import (bicgsafe_coefficients,
                                    pipelined_recurrence_tail)
    from repro.core.multirhs import _masked
    from repro.core.substrate import get_substrate
    from repro.core.types import SolveResult

    sub = get_substrate("jnp")
    bmv = sub.as_block_matvec(matvec)
    n, m = B.shape
    eps = config.breakdown_threshold(B.dtype)
    X = jnp.zeros_like(B)
    R0 = B
    RS = R0
    S0 = bmv(R0)
    norm_r0 = jnp.sqrt(sub.dots([(R0, R0)]))[0]
    Z0 = jnp.zeros_like(B)
    ones_m = jnp.ones((m,), B.dtype)
    if config.record_history:
        hist = jnp.full((config.maxiter + 1, m), jnp.nan, norm_r0.dtype)
    else:
        hist = jnp.zeros((0, m), norm_r0.dtype)
    state = dict(
        x=X, r=R0, s=S0, p=Z0, u=Z0, t=Z0, y=Z0, z=Z0, w=Z0, l=Z0, g=Z0,
        alpha=jnp.zeros((m,), B.dtype), zeta=ones_m, f=ones_m,
        i=jnp.zeros((), jnp.int32),
        iterations=jnp.zeros((m,), jnp.int32),
        relres=jnp.ones((m,), norm_r0.dtype),
        converged=jnp.zeros((m,), bool), breakdown=jnp.zeros((m,), bool),
        hist=hist)

    def cond(st):
        active = (~st["converged"]) & (~st["breakdown"])
        return jnp.any(active) & (st["i"] < config.maxiter)

    def body(st):
        r, s, y, t_prev = st["r"], st["s"], st["y"], st["t"]
        active = (~st["converged"]) & (~st["breakdown"])
        As = bmv(s)
        dots = sub.bicgsafe_dots(s, y, r, t_prev, RS)
        beta, alpha, zeta, eta, f, rr, bad = bicgsafe_coefficients(
            dots, st["i"], st["alpha"], st["zeta"], st["f"], eps)
        relres = jnp.sqrt(jnp.abs(rr)) / norm_r0
        done = relres <= config.tol
        advance = active & ~done & ~bad
        upd = sub.axpy_phase(
            dict(r=r, p=st["p"], u=st["u"], t=t_prev, y=y, z=st["z"],
                 s=s, l=st["l"], g=st["g"], w=st["w"], x=st["x"], As=As),
            (alpha, beta, zeta, eta), mask=advance)
        p, u, q, w, t = (upd[k] for k in ("p", "u", "q", "w", "t"))
        z, y_next, x_next, r_next = (
            upd[k] for k in ("z", "y", "x", "r"))
        Aw = bmv(w)
        l, g_next, s_next = pipelined_recurrence_tail(
            q, s, As, st["g"], Aw, alpha, zeta, eta)
        upd = lambda new, old: _masked(advance, new, old)  # noqa: E731
        relres_out = _masked(active, relres, st["relres"])
        if config.record_history:
            hist_i = st["hist"].at[st["i"]].set(
                jnp.where(active, relres_out.astype(st["hist"].dtype),
                          st["hist"][st["i"]]))
        else:
            hist_i = st["hist"]
        return dict(
            x=x_next, r=r_next, s=upd(s_next, s),
            p=p, u=u, t=t, y=y_next, z=z, w=w,
            l=upd(l, st["l"]), g=upd(g_next, st["g"]),
            alpha=upd(alpha, st["alpha"]), zeta=upd(zeta, st["zeta"]),
            f=upd(f, st["f"]),
            i=st["i"] + 1,
            iterations=jnp.where(advance, st["i"] + 1, st["iterations"]),
            relres=relres_out,
            converged=st["converged"] | (active & done),
            breakdown=st["breakdown"] | (active & bad & ~done),
            hist=hist_i)

    st = jax.lax.while_loop(cond, body, state)
    return SolveResult(st["x"], st["iterations"], st["relres"],
                       st["converged"], st["breakdown"], st["hist"])


REGRESSION_PROBLEMS = {
    "stencil7": lambda: M.poisson3d(8),                     # Stencil7
    "dense": lambda: M.nonsym_dense(64),                    # Dense
    "csr": lambda: M.random_nonsym(300, seed=2),            # CSR
    "ell": lambda: M.random_nonsym(300, seed=2, fmt="ell"),  # ELL
}


@pytest.mark.parametrize("prob", list(REGRESSION_PROBLEMS))
def test_solve_batched_bitwise_pre_refactor_regression(x64, prob):
    """Fixed-seed before/after regression on all four operator classes:
    the open-loop refactor (state-carried rs/norm_r0, per-column tol and
    first-iteration logic) keeps ``solve_batched`` BYTE-identical to the
    pre-refactor monolithic loop — every result field, including the
    recorded residual history."""
    op, b, _ = REGRESSION_PROBLEMS[prob]()
    B = _rhs_block(b, 3, seed=11)
    cfg = SolverConfig(tol=1e-8, maxiter=300, record_history=True)
    old = _solve_batched_pre_refactor(op.matvec, B, config=cfg)
    new = solve_batched(op.matvec, B, config=cfg)
    assert bool(np.asarray(new.converged).all()), (
        f"{prob}: regression baseline did not converge")
    for field in ("x", "iterations", "relres", "converged", "breakdown",
                  "residual_history"):
        a = np.asarray(getattr(old, field))
        c = np.asarray(getattr(new, field))
        assert np.array_equal(a, c, equal_nan=True), (
            f"{prob}: solve_batched.{field} changed bitwise after the "
            "init_state/step_chunk refactor")


# ---------------------------------------------------------------------------
# the session path (repro.api) preserves the structural invariants on
# every binding: single, batched, distributed (PR 5 acceptance)
# ---------------------------------------------------------------------------

def _session_reduction_sees_matvec(method, op, b, substrate) -> bool:
    """The overlap probe of _reduction_sees_matvec, through a bound
    session: tag the matvec and the fused-dot partials
    (repro.analysis tags), then walk the while-body (inside the
    session's jitted program — find_while_body recurses through pjit)
    for a path from the reduction back to the matvec tag."""
    import repro
    if b.ndim == 2:
        mv = tag_matvec(jax.vmap(op.matvec, in_axes=1, out_axes=1))
        session = repro.make_solver(method, mv, substrate=substrate,
                                    config=SolverConfig(maxiter=10),
                                    dot_reduce=tag_reduce, blocked=True)
        run, binding = (lambda bb: session.solve_many(bb)), "batched"
    else:
        mv = tag_matvec(op.matvec)
        session = repro.make_solver(method, mv, substrate=substrate,
                                    config=SolverConfig(maxiter=10),
                                    dot_reduce=tag_reduce)
        run, binding = (lambda bb: session.solve(bb)), "single"
    spec = BindingSpec(method=method, substrate=str(substrate),
                      binding=binding)
    tb = trace_fn(run, b, spec=spec)
    edge, _, _ = reduction_consumes_matvec(tb)
    return edge


@pytest.mark.parametrize("substrate", ["jnp", "pallas"])
def test_session_overlap_edge_single(x64, substrate):
    """p-BiCGSafe through a session keeps the no-dependency-edge overlap
    (and ssBiCGSafe2 keeps the edge) — the jitted session program does
    not serialize the reduction behind the matvec."""
    op, b, _ = M.nonsym_dense(64)
    assert not _session_reduction_sees_matvec("p-bicgsafe", op, b, substrate)
    assert _session_reduction_sees_matvec("ssbicgsafe2", op, b, substrate)


@pytest.mark.parametrize("substrate", ["jnp", "pallas"])
def test_session_overlap_edge_batched(x64, substrate):
    """solve_many through a session: the (9, m) block reduction keeps no
    path from the in-flight block matvec."""
    op, b, _ = M.nonsym_dense(64)
    B = jnp.stack([b, 0.5 * b, b + 1.0], axis=1)
    assert not _session_reduction_sees_matvec("p-bicgsafe", op, B, substrate)


@pytest.mark.parametrize("substrate", ["jnp", "pallas"])
@pytest.mark.parametrize("sname,per_iter", [("ssbicgsafe2", 1),
                                            ("p-bicgsafe", 1)])
def test_session_sync_count(x64, substrate, sname, per_iter):
    """ONE reduction per iteration through the session path — and zero
    NEW reductions on the repeat solve (the program is reused, which is
    the amortization the API redesign exists for)."""
    import repro
    op, b, _ = M.nonsym_dense(64)
    counter = SyncCounter(identity_reduce)
    session = repro.make_solver(sname, op, substrate=substrate,
                                config=SolverConfig(maxiter=10),
                                dot_reduce=counter)
    session.solve(b)
    assert counter.calls == 1 + per_iter
    session.solve(2.0 * b)
    assert counter.calls == 1 + per_iter, "repeat solve must not retrace"


@pytest.mark.parametrize("substrate", ["jnp", "pallas"])
@pytest.mark.parametrize("m", [1, 4])
@pytest.mark.parametrize("precond", [None, "block_jacobi"])
def test_session_sharded_batched_single_psum_per_iter(x64, substrate, m,
                                                      precond):
    """The mesh-bound session lowers to EXACTLY ONE psum per iteration —
    the (9, m) block — matching the legacy distributed driver probe
    above (the session path must not add or split reductions)."""
    import repro
    from repro.core.compat import make_mesh

    op, b, _ = M.convection_diffusion(8, peclet=1.0)
    B_grid = jnp.stack([b * (j + 1) for j in range(m)],
                       axis=1).reshape(8, 8, 8, m)
    mesh = make_mesh((1,), ("rows",))
    dist = repro.make_solver(
        "p-bicgsafe", op, precond=precond,
        substrate=substrate, config=SolverConfig(maxiter=10)).on_mesh(mesh)
    jaxpr = jax.make_jaxpr(lambda BB: dist.solve_many(BB))(B_grid)
    body = _find_while_body(jaxpr.jaxpr)
    assert body is not None, "no while loop in the sharded batched solve"
    assert _count_prim(body, "psum") == 1, "must be ONE reduction/iter"
    psum_eqn = _find_prim_eqn(body, "psum")
    assert psum_eqn.invars[0].aval.shape == (9, m), \
        "the one reduction must carry the whole (9, m) partial block"


def test_batched_m1_with_squeezing_dot_reduce(x64):
    """End-to-end m=1 regression: a dot_reduce that squeezes the
    degenerate RHS axis (returning (9,) for the (9, 1) block) must still
    solve — this was reachable and raised the _masked rank check."""
    op, b, xt = M.poisson3d(8)
    B = b[:, None]

    def squeezing_reduce(partials):
        return partials.reshape(partials.shape[0])   # (k, 1) -> (k,)

    cfg = SolverConfig(tol=1e-8, maxiter=2000)
    res = solve_batched(op.matvec, B, config=cfg,
                        dot_reduce=squeezing_reduce)
    assert bool(np.asarray(res.converged).all())
    ref = solve_batched(op.matvec, B, config=cfg)
    assert int(res.iterations[0]) == int(ref.iterations[0])
    np.testing.assert_allclose(np.asarray(res.x), np.asarray(ref.x),
                               rtol=1e-10, atol=1e-12)
