"""Substrate tests: data pipeline, optimizer, checkpointing, train loop,
fault tolerance, serving engine, Newton-Krylov."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import enable_x64
from repro.configs import smoke_config
from repro.data import DataConfig, make_dataset, synthetic_token_stream
from repro.models import init_params, loss_fn
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.optim.eightbit import dequantize, quantize
from repro.train import CheckpointManager, TrainConfig, train
from repro.train.fault_tolerance import (BadStepFilter, FailureInjector,
                                         run_with_restarts)


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------

def test_data_deterministic_and_shard_disjoint():
    cfg = DataConfig(batch_size=4, seq_len=64, vocab_size=128, seed=7)
    a = synthetic_token_stream(cfg, 3)
    b = synthetic_token_stream(cfg, 3)
    np.testing.assert_array_equal(a, b)
    c = synthetic_token_stream(
        DataConfig(batch_size=4, seq_len=64, vocab_size=128, seed=7,
                   shard_index=1, shard_count=2), 3)
    assert not np.array_equal(a, c)
    assert a.min() >= 0 and a.max() < 128


def test_file_source(tmp_path):
    p = tmp_path / "corpus.txt"
    p.write_text("hello world, this is a test corpus for the pipeline. " * 50)
    cfg = DataConfig(batch_size=2, seq_len=32, vocab_size=256,
                     source="file", path=str(p))
    fn = make_dataset(cfg)
    b0, b1 = fn(0), fn(1)
    assert b0["tokens"].shape == (2, 32)
    assert not np.array_equal(b0["tokens"], b1["tokens"])


# ---------------------------------------------------------------------------
# 8-bit state + AdamW
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(7,), (128,), (3, 256), (5, 130)])
def test_q8_roundtrip(shape):
    x = jax.random.normal(jax.random.PRNGKey(0), shape) * 3.0
    q = quantize(x)
    err = jnp.abs(dequantize(q) - x).max() / (jnp.abs(x).max() + 1e-9)
    assert float(err) < 1.5 / 127


@pytest.mark.parametrize("state_dtype", ["f32", "i8"])
def test_adamw_reduces_quadratic(state_dtype):
    cfg = AdamWConfig(lr=0.05, weight_decay=0.0, state_dtype=state_dtype,
                      warmup_steps=1, decay_steps=1000)
    params = {"w": jnp.array([2.0, -3.0, 1.0])}
    state = adamw_init(params, cfg)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state = adamw_update(params, grads, state, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.1


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(5.0), "b": {"c": jnp.ones((3, 2), jnp.bfloat16)}}
    mgr = CheckpointManager(tmp_path, keep=2)
    for step in (10, 20, 30):
        mgr.save(tree, step, blocking=True)
    assert mgr.latest_step() == 30
    # retention: only last 2 kept
    assert sorted(int(p.stem.split("_")[1])
                  for p in tmp_path.glob("step_*.npz")) == [20, 30]
    restored, step = mgr.restore(tree)
    assert step == 30
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]))
    assert restored["b"]["c"].dtype == jnp.bfloat16


# ---------------------------------------------------------------------------
# train loop + fault tolerance
# ---------------------------------------------------------------------------

def _tiny_train_cfg(tmp_path, steps=30, **kw):
    return TrainConfig(
        steps=steps, ckpt_every=10, ckpt_dir=str(tmp_path / "ckpt"),
        log_every=100,
        opt=AdamWConfig(lr=3e-3, warmup_steps=2, decay_steps=steps), **kw)


def test_train_loss_decreases(tmp_path):
    cfg = smoke_config("phi3-mini-3.8b")
    dcfg = DataConfig(batch_size=4, seq_len=64, vocab_size=cfg.vocab_size)
    out = train(cfg, dcfg, _tiny_train_cfg(tmp_path, steps=30))
    first = np.mean([h["loss"] for h in out["history"][:5]])
    last = np.mean([h["loss"] for h in out["history"][-5:]])
    assert last < first - 0.2, (first, last)


def test_train_restart_resumes_and_matches(tmp_path):
    """Kill at step 17 -> restart -> final state equals uninterrupted run."""
    cfg = smoke_config("xlstm-350m")
    dcfg = DataConfig(batch_size=2, seq_len=32, vocab_size=cfg.vocab_size)

    ref = train(cfg, dcfg, _tiny_train_cfg(tmp_path / "ref", steps=25))

    inj = FailureInjector(fail_at=[17])

    def attempt():
        return train(cfg, dcfg, _tiny_train_cfg(tmp_path / "ft", steps=25),
                     injector=inj)

    out = run_with_restarts(attempt, max_restarts=2)
    assert out["restarts"] == 1
    assert out["start_step"] == 10            # resumed from the step-10 ckpt
    ra, rb = ref["params"], out["params"]
    for a, b in zip(jax.tree_util.tree_leaves(ra),
                    jax.tree_util.tree_leaves(rb)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-2, atol=2e-2)


def test_bad_step_filter():
    f = BadStepFilter(nan_zap=10.0, max_bad=2)
    for _ in range(10):
        assert f.accept(1.0, 1.0)
    assert not f.accept(float("nan"), 1.0)
    assert not f.accept(1.0, 1e9)
    with pytest.raises(RuntimeError):
        f.accept(float("inf"), 1.0)


def test_in_graph_bad_step_gate(tmp_path):
    """A poisoned batch (loss=NaN via synthetic inf logits is hard to force;
    instead force a spike threshold of 0 so every step is rejected) leaves
    params bit-identical."""
    from repro.train.train_loop import make_train_step
    cfg = smoke_config("xlstm-350m")
    tcfg = _tiny_train_cfg(tmp_path, steps=1)
    step_fn = make_train_step(cfg, tcfg)
    params = init_params(cfg, jax.random.PRNGKey(0))
    from repro.optim import adamw_init, pipelined_clip_init
    opt = adamw_init(params, tcfg.opt)
    clip = pipelined_clip_init()
    batch = {"tokens": jnp.ones((2, 16), jnp.int32)}
    p0 = jax.tree_util.tree_map(lambda x: np.asarray(x).copy(), params)
    params2, *_ , metrics = step_fn(params, opt, clip, batch,
                                    jnp.asarray(0.0, jnp.float32))
    assert float(metrics["accepted"]) == 0.0
    for a, b in zip(jax.tree_util.tree_leaves(p0),
                    jax.tree_util.tree_leaves(params2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def test_serving_engine_batches_and_decodes():
    from repro.serve import Request, ServeConfig, ServingEngine
    cfg = smoke_config("qwen3-8b")
    eng = ServingEngine(cfg, ServeConfig(max_batch=3, max_len=64))
    rng = np.random.default_rng(0)
    for i in range(5):
        plen = 8 if i < 3 else 12
        eng.submit(Request(prompt=list(rng.integers(1, 200, plen)),
                           max_new_tokens=6))
    done = eng.run()
    assert len(done) == 5
    for r in done:
        assert len(r.output) == 6
        assert all(0 <= t < cfg.vocab_size for t in r.output)


def test_serving_matches_teacher_forcing():
    """Engine greedy decode == argmax of teacher-forced forward."""
    from repro.models import forward
    from repro.serve import Request, ServeConfig, ServingEngine
    cfg = smoke_config("phi3-mini-3.8b")
    eng = ServingEngine(cfg, ServeConfig(max_batch=1, max_len=64))
    prompt = list(range(1, 11))
    eng.submit(Request(prompt=prompt, max_new_tokens=4))
    done = eng.run()
    out = done[0].output

    toks = list(prompt)
    for i in range(4):
        logits, _ = forward(eng.params, cfg,
                            {"tokens": jnp.asarray([toks], jnp.int32)})
        nxt = int(jnp.argmax(logits[0, -1]))
        assert nxt == out[i], (i, nxt, out)
        toks.append(nxt)


# ---------------------------------------------------------------------------
# Newton-Krylov (paper's solver inside the optimizer)
# ---------------------------------------------------------------------------

def test_newton_krylov_step_reduces_loss():
    """Regression: the GGN matvec must stay exactly linear in the param
    dtype.  An f32 downcast inside it made the operator nonlinear at f32
    rounding, breaking p-BiCGSafe's recurrences — the inner solve reported
    relres ~1e-8 while the true residual stalled O(1), so the line search
    (correctly) rejected every direction after two steps."""
    from repro.optim.newton_krylov import (NewtonKrylovConfig,
                                           newton_krylov_step)
    with enable_x64(True):
        # tiny softmax-regression "LM": logits = x @ W
        key = jax.random.PRNGKey(0)
        X = jax.random.normal(key, (64, 8), jnp.float64)
        ytrue = jax.random.randint(jax.random.PRNGKey(1), (64,), 0, 5)
        params = {"w": jnp.zeros((8, 5), jnp.float64)}

        def logits_fn(p, batch):
            return batch["x"] @ p["w"]

        def lossf(p, batch):
            lg = logits_fn(p, batch)
            return -jnp.mean(jax.nn.log_softmax(lg)[
                jnp.arange(lg.shape[0]), batch["y"]])

        batch = {"x": X, "y": ytrue}
        cfg = NewtonKrylovConfig(damping=1e-2, inner_maxiter=50,
                                 inner_tol=1e-8, trust_radius=10.0)
        losses = [float(lossf(params, batch))]
        m1 = None
        for _ in range(5):
            params, m1 = newton_krylov_step(lossf, logits_fn, params,
                                            batch, cfg)
            losses.append(float(lossf(params, batch)))
        # monotone (line-searched) + substantial progress toward the
        # problem's CE floor (~1.28 for this random dataset)
        assert all(b <= a + 1e-12 for a, b in zip(losses, losses[1:]))
        assert losses[-1] < losses[0] - 0.25
        assert int(m1["inner_iters"]) > 0


def test_newton_krylov_on_model():
    """GGN + p-BiCGSafe step on a real (tiny) transformer reduces loss."""
    from repro.models import forward
    from repro.optim.newton_krylov import (NewtonKrylovConfig,
                                           newton_krylov_step)
    cfg = smoke_config("phi3-mini-3.8b").replace(
        n_layers=1, dtype=jnp.float32, param_dtype=jnp.float32)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 16),
                                          0, cfg.vocab_size)}

    def logits_fn(p, b):
        return forward(p, cfg, b)[0]

    def lossf(p, b):
        return loss_fn(p, cfg, b)[0]

    nk = NewtonKrylovConfig(damping=1e-2, inner_maxiter=10, inner_tol=1e-2,
                            lr=0.5)
    l0 = float(lossf(params, batch))
    p1, m = newton_krylov_step(lossf, logits_fn, params, batch, nk)
    l1 = float(lossf(p1, batch))
    assert np.isfinite(l1)
    assert l1 < l0
