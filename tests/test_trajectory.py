"""repro.observe.trajectory: path resolution, baseline math, the
regression gate failing on an injected synthetic regression, and the
benchmarks/run.py registry's consistency with the committed artifacts.

All evaluation tests are pure (no git, no device): histories are passed
in as already-loaded artifact points.
"""
import json
import os
import sys

import pytest

from repro.observe import trajectory as T

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), os.pardir))


# ---------------------------------------------------------------------------
# path resolution
# ---------------------------------------------------------------------------

def test_resolve_path_nested_and_list():
    doc = {"a": {"b": [10, {"c": 2.5}]}}
    assert T.resolve_path(doc, "a/b/0") == 10.0
    assert T.resolve_path(doc, "a/b/1/c") == 2.5


def test_resolve_path_dotted_keys_work_with_slash_separator():
    # bench_rr keys contain dots ("hard_sr3.0") — the reason paths are
    # slash-separated
    doc = {"claims": {"hard_sr3.0": {"rr_truthful": True}}}
    assert T.resolve_path(doc, "claims/hard_sr3.0/rr_truthful") == 1.0


def test_resolve_path_bool_and_missing():
    assert T.resolve_path({"ok": False}, "ok") == 0.0
    assert T.resolve_path({"ok": True}, "nope") is None
    assert T.resolve_path({"s": "text"}, "s") is None
    assert T.resolve_path({"a": [1]}, "a/7") is None


# ---------------------------------------------------------------------------
# evaluate_metric
# ---------------------------------------------------------------------------

def _m(**kw):
    kw.setdefault("path", "x")
    return T.Metric(**kw)


def test_stable_history_is_ok():
    v = T.evaluate_metric(_m(direction="higher", rel_tol=0.1),
                          [10.0, 10.0, 10.0], 10.0)
    assert v.status == "ok" and not v.failed
    assert v.baseline == 10.0


def test_injected_regression_fails_gate():
    # the satellite requirement: a synthetic regression must trip the gate
    v = T.evaluate_metric(_m(direction="higher", rel_tol=0.1, gate=True),
                          [10.0, 10.0, 10.0], 5.0)
    assert v.status == "regression" and v.failed


def test_lower_is_better_direction():
    v = T.evaluate_metric(_m(direction="lower", rel_tol=0.1),
                          [100.0], 130.0)
    assert v.failed
    v = T.evaluate_metric(_m(direction="lower", rel_tol=0.1),
                          [100.0], 105.0)
    assert v.status == "ok"


def test_improvement_never_fails():
    v = T.evaluate_metric(_m(direction="lower", rel_tol=0.0),
                          [100.0], 50.0)
    assert v.status == "ok"


def test_watch_metric_never_fails_the_gate():
    v = T.evaluate_metric(_m(direction="higher", rel_tol=0.1, gate=False),
                          [10.0, 10.0], 1.0)
    assert v.status == "watch-regression" and not v.failed


def test_boolean_claim_flip_trips_zero_tolerance():
    v = T.evaluate_metric(_m(direction="higher", rel_tol=0.0),
                          [1.0, 1.0], 0.0)
    assert v.failed


def test_baseline_is_median_of_last_window():
    # one poisoned historical point must not move the median baseline
    hist = [10.0, 10.0, 1000.0, 10.0, 10.0, 10.0]
    v = T.evaluate_metric(_m(direction="higher", rel_tol=0.1), hist, 10.0)
    assert v.baseline == 10.0 and v.status == "ok"


def test_missing_history_and_current():
    v = T.evaluate_metric(_m(), [], 5.0)
    assert v.status == "new" and not v.failed
    v = T.evaluate_metric(_m(), [5.0], None)
    assert v.status == "no-data" and not v.failed


def test_bad_direction_rejected():
    with pytest.raises(ValueError):
        T.Metric(path="x", direction="sideways")


# ---------------------------------------------------------------------------
# evaluate + report over a synthetic registry
# ---------------------------------------------------------------------------

def _fixture_registry():
    return [T.BenchSpec(
        "fake", "benchmarks.fake", "fake.json",
        metrics=(T.Metric("speed", "higher", 0.1, gate=True),
                 T.Metric("wall_s", "lower", 0.25, gate=False)))]


def _points(values):
    return [{"commit": f"c{i}", "committed_unix": i,
             "data": {"speed": v, "wall_s": 1.0}}
            for i, v in enumerate(values)]


def test_evaluate_gate_fails_on_injected_regression():
    reg = _fixture_registry()
    histories = {"fake": _points([10.0, 10.0, 10.0])}
    ok = T.evaluate(reg, histories,
                    {"fake": {"commit": None,
                              "data": {"speed": 10.0, "wall_s": 1.0}}})
    assert ok.ok and not ok.regressions
    bad = T.evaluate(reg, histories,
                     {"fake": {"commit": None,
                               "data": {"speed": 4.0, "wall_s": 1.0}}})
    assert not bad.ok
    assert [v.metric.path for v in bad.regressions] == ["speed"]


def test_render_flags_regression():
    reg = _fixture_registry()
    rep = T.evaluate(reg, {"fake": _points([10.0, 10.0])},
                     {"fake": {"commit": None,
                               "data": {"speed": 1.0, "wall_s": 9.0}}})
    md = T.render_markdown(rep)
    txt = T.render_ascii(rep)
    assert "REGRESSION" in md and "## regressions" in md
    assert "REGRESSION" in txt and "watch(worse)" in txt


def test_consolidate_structure():
    reg = _fixture_registry()
    doc = T.consolidate(reg, {"fake": _points([1.0, 2.0])},
                        {"fake": {"commit": None,
                                  "data": {"speed": 3.0, "wall_s": 1.0}}})
    assert doc["schema"] == T.SCHEMA_TRAJECTORY
    fake = doc["benches"]["fake"]
    assert fake["metrics"]["speed"]["series"] == [1.0, 2.0]
    assert fake["metrics"]["speed"]["current"] == 3.0
    assert len(fake["commits"]) == 2


def test_sparkline_shapes():
    assert T.sparkline([]) == ""
    assert T.sparkline([1.0, None, 2.0])[1] == "·"
    s = T.sparkline([0.0, 1.0])
    assert s[0] == "▁" and s[-1] == "█"


# ---------------------------------------------------------------------------
# the real registry vs the committed artifacts
# ---------------------------------------------------------------------------

def _real_registry():
    if REPO not in sys.path:
        sys.path.insert(0, REPO)
    from benchmarks.run import REGISTRY
    return REGISTRY


def test_registry_artifacts_exist_and_gated_paths_resolve():
    """Every registered artifact is committed and every *gated* metric
    path resolves in it — a typo in benchmarks/run.py would silently
    disarm the gate otherwise."""
    for spec in _real_registry():
        path = os.path.join(REPO, "experiments", spec.artifact)
        assert os.path.exists(path), f"missing artifact {spec.artifact}"
        with open(path) as fh:
            data = json.load(fh)
        # bench modules stamp repro.benchmarks/<name>/v1; subsystem
        # consolidators registered in the same gate (the scenario sweep)
        # stamp repro.<subsystem>/<name>/v1 — either way the artifact
        # must be schema-stamped for history consolidation
        assert str(data.get("schema", "")).startswith("repro.")
        for metric in spec.metrics:
            if metric.gate:
                assert T.resolve_path(data, metric.path) is not None, \
                    f"{spec.name}: gated path {metric.path} unresolvable"


def test_git_history_consolidation_runs_here():
    """artifact_history over this repo's own git log returns committed
    points for a long-standing artifact (device-free, but needs git)."""
    pts = T.artifact_history("bench_cost.json", root=REPO, limit=10)
    if not pts:
        pytest.skip("no git history available (shallow checkout?)")
    assert all("data" in p and p["commit"] for p in pts)
    assert T.resolve_path(pts[-1]["data"],
                          "p-bicgsafe/measured/sync_phases") == 1.0
