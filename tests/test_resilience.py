"""Guarded solves: detection, recovery, and service resilience.

Pins the three layers of :mod:`repro.resilience`:

* detection — the guarded fused phase is ONE (11, m) reduction per
  iteration with NO dependency edge to the in-flight block matvec, on
  both substrates and (via subprocess) sharded across 8 devices with a
  single psum; the clean guarded path is numerically identical to the
  unguarded program;
* recovery — typed per-column :class:`~repro.core.SolveStatus` codes,
  restart-from-current-x, on-trigger residual replacement, substrate
  degradation, method fallback, and a finite-output guarantee, all
  driven by deterministic fault injection (:mod:`repro.resilience
  .inject`);
* serving — guarded engines retire typed statuses, scrub poisoned
  columns before the slot is reused, and re-enqueue failed requests
  with capped backoff.

Also the satellite regressions: zero right-hand sides across every
registered method, and typed statuses on the legacy shim results.
"""
import os
import subprocess
import sys
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax import lax

from repro.analysis import (BindingSpec, find_while_body as _find_while_body,
                            reduction_consumes_matvec, tag_matvec,
                            tag_reduce, trace_fn)
import repro
from repro.core import SOLVERS, SolverConfig
from repro.core import matrices as M
from repro.core._common import SyncCounter
from repro.core.multirhs import GUARD_FIELDS, init_state, step_chunk
from repro.core.substrate import get_substrate
from repro.core.types import SolveStatus, identity_reduce
from repro.resilience import (ChunkFaultInjector, GuardedSolver,
                              RecoveryPolicy, SimulatedKernelFailure,
                              TickingClock, corrupt_engine_block,
                              near_singular_dense, orthogonal_shadow)
from repro.service import ServiceConfig, SolveEngine

HERE = os.path.dirname(__file__)


def _normalized_problem(n=64):
    """Well-conditioned dense problem with a unit-norm rhs (recovery
    scenarios anchor tolerances to ||b||)."""
    op, b, xt = M.nonsym_dense(n)
    b = b / jnp.linalg.norm(b)
    return op, b, xt


def _guarded(op, policy, *, substrate="jnp", config=SolverConfig(),
             inject=None):
    gs = repro.make_solver("p-bicgsafe", op, substrate=substrate,
                           config=config, recovery=policy)
    gs.inject = inject
    return gs


# ---------------------------------------------------------------------------
# detection: the guarded fused phase
# ---------------------------------------------------------------------------

def test_make_solver_recovery_returns_guarded(x64):
    op, _, _ = M.nonsym_dense(32)
    gs = repro.make_solver("p-bicgsafe", op, recovery=True)
    assert isinstance(gs, GuardedSolver)
    assert gs.session.config.guard
    assert isinstance(gs.policy, RecoveryPolicy)
    with pytest.raises(TypeError):
        repro.make_solver("p-bicgsafe", op, recovery="yes")


@pytest.mark.parametrize("substrate", ["jnp", "pallas"])
def test_guarded_single_reduction_per_iter(x64, substrate):
    """The guarded step body still traces exactly ONE dot_reduce — the
    fused phase widened from (9, m) to (11, m), not a second sync."""
    op, b, _ = M.nonsym_dense(64)
    m = 3
    B = jnp.stack([b, 0.5 * b, b + 1.0], axis=1)
    counter = SyncCounter(identity_reduce)
    sub = get_substrate(substrate)
    cfg = SolverConfig(guard=True)
    bmv = jax.vmap(op.matvec, in_axes=1, out_axes=1)
    state = init_state(bmv, B, config=cfg, substrate=sub)
    jaxpr = jax.make_jaxpr(lambda st: step_chunk(
        bmv, st, 8, config=cfg, dot_reduce=counter, substrate=sub))(state)
    assert counter.calls == 1, "guarded step must trace ONE dot_reduce"
    assert _find_while_body(jaxpr.jaxpr) is not None


@pytest.mark.parametrize("substrate", ["jnp", "pallas"])
def test_guarded_overlap_edge(x64, substrate):
    """Overlap invariant survives the guard: the (11, m) fused reduction
    has NO dependency path from the in-flight block matvec."""
    op, b, _ = M.nonsym_dense(64)
    sub = get_substrate(substrate)
    m = 3
    B = jnp.stack([b, 0.5 * b, b + 1.0], axis=1)
    bmv = tag_matvec(jax.vmap(op.matvec, in_axes=1, out_axes=1))
    cfg = SolverConfig(guard=True)

    state = init_state(bmv, B, config=cfg, substrate=sub)
    spec = BindingSpec(method="p-bicgsafe", substrate=str(substrate),
                      binding="open_loop", guard=True, m=m,
                      guard_effective=True)
    tb = trace_fn(lambda st: step_chunk(
        bmv, st, 8, config=cfg, dot_reduce=tag_reduce, substrate=sub),
        state, spec=spec)
    assert tb.body is not None
    reds = tb.reduce_eqns()
    assert len(reds) == 1, "fused (11, m) phase not found in step body"
    assert reds[0].invars[0].aval.shape == (11, m)
    edge, detail, _ = reduction_consumes_matvec(tb)
    assert not edge, (
        "the guarded fused reduction must keep NO dependency edge to "
        f"the in-flight block matvec (health rows ride the overlap): "
        f"{detail}")


@pytest.mark.slow
def test_guarded_sharded_single_psum():
    """8-way sharded guarded solve: still ONE psum/iter — the (11, m)
    block — with no edge to the halo exchange (subprocess probe)."""
    env = dict(os.environ)
    src = os.path.abspath(os.path.join(HERE, os.pardir, "src"))
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, os.path.join(HERE, "_distributed_check.py"),
         "guarded"],
        capture_output=True, text=True, env=env, timeout=600)
    assert proc.returncode == 0, \
        f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
    assert "GUARDED DISTRIBUTED SMOKE PASSED" in proc.stdout


@pytest.mark.parametrize("substrate", ["jnp", "pallas"])
def test_guarded_kernel_parity(x64, substrate):
    """The guarded state advanced on either substrate agrees: same
    iterates AND same health scalars (the pallas (11, m) kernel computes
    the same probe rows as the jnp reference)."""
    op, b, _ = M.nonsym_dense(64)
    B = jnp.stack([b, 2.0 * b], axis=1)
    cfg = SolverConfig(guard=True)
    bmv = jax.vmap(op.matvec, in_axes=1, out_axes=1)
    sub = get_substrate(substrate)
    ref = get_substrate("jnp")
    st = step_chunk(bmv, init_state(bmv, B, config=cfg, substrate=sub),
                    12, config=cfg, substrate=sub)
    rf = step_chunk(bmv, init_state(bmv, B, config=cfg, substrate=ref),
                    12, config=cfg, substrate=ref)
    for k in ("x", "r") + GUARD_FIELDS:
        np.testing.assert_allclose(
            np.asarray(st[k], dtype=np.float64),
            np.asarray(rf[k], dtype=np.float64),
            rtol=1e-10, atol=1e-12, err_msg=f"field {k}")


def test_guarded_clean_path_identical(x64):
    """A clean guarded solve takes the unguarded numerical path (the
    health rows observe, never write): same iteration count per column,
    same iterate up to XLA fusion-reordering round-off, zero recovery
    events, CONVERGED stamped everywhere."""
    op, b, _ = M.nonsym_dense(64)
    B = jnp.stack([b, 0.5 * b, b + 1.0], axis=1)
    cfg = SolverConfig(tol=1e-10, maxiter=400)
    plain = repro.make_solver("p-bicgsafe", op, config=cfg)
    gs = _guarded(op, RecoveryPolicy(), config=cfg)
    ref = plain.solve_many(B)
    res = gs.solve_many(B)
    assert gs.events == []
    np.testing.assert_allclose(np.asarray(res.x), np.asarray(ref.x),
                               rtol=1e-12, atol=1e-13)
    assert np.array_equal(np.asarray(res.iterations),
                          np.asarray(ref.iterations))
    assert all(SolveStatus(int(s)) == SolveStatus.CONVERGED
               for s in np.asarray(res.status))


# ---------------------------------------------------------------------------
# recovery policies
# ---------------------------------------------------------------------------

def test_policy_validation():
    with pytest.raises(ValueError):
        RecoveryPolicy(chunk=0)
    with pytest.raises(ValueError):
        RecoveryPolicy(method_fallback="not-a-method")
    with pytest.raises(ValueError):
        RecoveryPolicy(max_restarts=-1)


def test_guarded_solver_rejects_wrong_sessions(x64):
    op, _, _ = M.nonsym_dense(32)
    with pytest.raises(ValueError, match="guarded session"):
        GuardedSolver(repro.make_solver("p-bicgsafe", op))
    with pytest.raises(ValueError, match="bicgstab"):
        GuardedSolver(repro.make_solver(
            "bicgstab", op, config=SolverConfig(guard=True)))


def test_nan_injection_restart_recovers(x64):
    """Poisoned residual mid-solve: the finiteness probe flags NONFINITE,
    the policy restarts from current x, and the recovered solution
    matches the clean solve."""
    op, b, _ = _normalized_problem()
    B = jnp.stack([b, 0.7 * b], axis=1)
    cfg = SolverConfig(tol=1e-8, maxiter=400)
    clean = repro.make_solver("p-bicgsafe", op, config=cfg).solve_many(B)
    inj = ChunkFaultInjector(nan_at={1: (0,)})
    gs = _guarded(op, RecoveryPolicy(chunk=8), config=cfg, inject=inj)
    res = gs.solve_many(B)
    assert inj.fired, "injector never fired"
    assert any(e["event"] == "restart" for e in gs.events)
    assert bool(np.asarray(res.converged).all())
    assert np.isfinite(np.asarray(res.x)).all()
    np.testing.assert_allclose(np.asarray(res.x), np.asarray(clean.x),
                               rtol=1e-6, atol=1e-8)
    assert int(np.asarray(res.status)[0]) == SolveStatus.CONVERGED


def test_nan_without_recovery_is_typed_failure(x64):
    """With the restart budget at zero the poison surfaces as a typed
    NONFINITE failure — and x is STILL finite (sanitized, never NaN)."""
    op, b, _ = _normalized_problem()
    cfg = SolverConfig(tol=1e-8, maxiter=200)
    inj = ChunkFaultInjector(nan_at={1: (0,)})
    gs = _guarded(op, RecoveryPolicy(chunk=8, max_restarts=0,
                                     method_fallback=None),
                  config=cfg, inject=inj)
    res = gs.solve(b)
    assert SolveStatus(int(np.asarray(res.status))) == SolveStatus.NONFINITE
    assert not bool(np.asarray(res.converged))
    assert np.isfinite(np.asarray(res.x)).all()


def test_breakdown_restart_recovers(x64):
    """Orthogonal shadow residual: rho = (r0*, r0) = 0 trips the typed
    in-reduction BREAKDOWN_RHO at the first iteration; a restart (which
    re-seeds r0* = r0) then converges to the clean answer."""
    op, b, _ = _normalized_problem()
    cfg = SolverConfig(tol=1e-2, maxiter=300, breakdown_eps=1e-12)
    shadow = orthogonal_shadow(b)
    gs = _guarded(op, RecoveryPolicy(chunk=16, method_fallback=None),
                  config=cfg)
    res = gs.solve(b, r0_star=shadow)
    assert any(e["event"] == "restart" for e in gs.events)
    assert bool(np.asarray(res.converged))
    assert SolveStatus(int(np.asarray(res.status))) == SolveStatus.CONVERGED
    x = np.asarray(res.x)
    relres = float(np.linalg.norm(np.asarray(b) - np.asarray(
        op.matvec(jnp.asarray(x)))) / np.linalg.norm(np.asarray(b)))
    assert relres <= 1e-2 * 1.01


def test_breakdown_without_recovery_is_typed(x64):
    """Same scenario, no recovery: the result reports WHICH denominator
    broke (typed BREAKDOWN_RHO), finite x, no silent NaN."""
    op, b, _ = _normalized_problem()
    cfg = SolverConfig(tol=1e-2, maxiter=300, breakdown_eps=1e-12)
    gs = _guarded(op, RecoveryPolicy(chunk=16, max_restarts=0,
                                     method_fallback=None),
                  config=cfg)
    res = gs.solve(b, r0_star=orthogonal_shadow(b))
    assert SolveStatus(int(np.asarray(res.status))) == \
        SolveStatus.BREAKDOWN_RHO
    assert bool(np.asarray(res.breakdown))
    assert np.isfinite(np.asarray(res.x)).all()


def test_method_fallback_rescues_exhausted_column(x64):
    """Restart budget zero + shadow-induced breakdown: the per-column
    method fallback (BiCGSTAB) rescues the solve and logs the handoff."""
    op, b, _ = _normalized_problem()
    cfg = SolverConfig(tol=1e-2, maxiter=300, breakdown_eps=1e-12)
    gs = _guarded(op, RecoveryPolicy(chunk=16, max_restarts=0,
                                     method_fallback="bicgstab"),
                  config=cfg)
    res = gs.solve(b, r0_star=orthogonal_shadow(b))
    fb = [e for e in gs.events if e["event"] == "method_fallback"]
    assert fb and fb[0]["method"] == "bicgstab"
    assert fb[0]["from_status"] == "BREAKDOWN_RHO"
    assert bool(np.asarray(res.converged))
    assert SolveStatus(int(np.asarray(res.status))) == SolveStatus.CONVERGED


def test_kernel_failure_degrades_substrate(x64):
    """A kernel-level failure on the pallas path degrades the session to
    the jnp substrate and finishes from the SAME state pytree."""
    op, b, _ = _normalized_problem()
    cfg = SolverConfig(tol=1e-8, maxiter=400)
    inj = ChunkFaultInjector(fail_at=(1,))
    gs = _guarded(op, RecoveryPolicy(chunk=8), substrate="pallas",
                  config=cfg, inject=inj)
    res = gs.solve(b)
    deg = [e for e in gs.events if e["event"] == "substrate_degraded"]
    assert deg and deg[0]["detail"]["to"] == "jnp"
    assert gs._active.sub.name == "jnp"
    assert bool(np.asarray(res.converged))
    assert np.isfinite(np.asarray(res.x)).all()


def test_kernel_failure_without_fallback_raises(x64):
    op, b, _ = _normalized_problem()
    inj = ChunkFaultInjector(fail_at=(0,))
    gs = _guarded(op, RecoveryPolicy(substrate_fallback=False),
                  substrate="pallas",
                  config=SolverConfig(tol=1e-8, maxiter=100), inject=inj)
    with pytest.raises(SimulatedKernelFailure):
        gs.solve(b)


def test_drift_trigger_replaces_residual(x64):
    """An artificially tight drift threshold fires the on-trigger
    replacement (r <- B - A x, recomputed derived vectors); the solve
    still converges and the events are audited per column."""
    op, b, _ = _normalized_problem()
    cfg = SolverConfig(tol=1e-8, maxiter=400)
    gs = _guarded(op, RecoveryPolicy(chunk=8, drift_scale=1e-12),
                  config=cfg)
    res = gs.solve(b)
    rep = [e for e in gs.events if e["event"] == "replace"]
    assert rep, "tightened drift bound must trigger replacement"
    assert all(e["columns"] == [0] for e in rep)
    assert bool(np.asarray(res.converged))


def test_stagnation_gives_up_typed(x64):
    """A column that cannot reach tol: stagnation restarts burn out, then
    the driver stamps typed STAGNATION instead of spinning forever."""
    op = near_singular_dense(48, sigma_min=1e-14)
    b = jnp.ones((48,), jnp.float64)
    b = b / jnp.linalg.norm(b)
    cfg = SolverConfig(tol=1e-13, maxiter=4000)
    gs = _guarded(op, RecoveryPolicy(chunk=32, stagnation_window=64,
                                     max_restarts=1, method_fallback=None),
                  config=cfg)
    res = gs.solve(b)
    sts = SolveStatus(int(np.asarray(res.status)))
    assert sts.is_failure or bool(np.asarray(res.converged))
    assert np.isfinite(np.asarray(res.x)).all()
    assert np.isfinite(float(np.asarray(res.relres))) or \
        float(np.asarray(res.relres)) == np.inf
    if sts == SolveStatus.STAGNATION:
        assert any(e["event"] == "stagnation_giveup" for e in gs.events)


def test_near_singular_never_silent_nan(x64):
    """Near-singular operator, no recovery: whatever the typed outcome,
    the guarded surface never leaks NaN."""
    op = near_singular_dense(48, sigma_min=1e-15)
    b = jnp.ones((48,), jnp.float64)
    gs = _guarded(op, RecoveryPolicy(max_restarts=0, method_fallback=None,
                                     chunk=16),
                  config=SolverConfig(tol=1e-12, maxiter=500,
                                      breakdown_eps=1e-12))
    res = gs.solve(b)
    assert np.isfinite(np.asarray(res.x)).all()
    assert SolveStatus(int(np.asarray(res.status))).is_terminal


# ---------------------------------------------------------------------------
# satellites: zero rhs across every method, legacy shim statuses
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", sorted(SOLVERS))
def test_zero_rhs_regression(x64, method):
    """b = 0 must return x = 0, converged in 0 iterations, relres 0 —
    not a 0/0 NaN out of the ||r0|| normalization (regression: every
    registered method)."""
    op, b, _ = M.nonsym_dense(48)
    res = repro.make_solver(method, op).solve(jnp.zeros_like(b))
    assert bool(np.asarray(res.converged))
    assert int(np.asarray(res.iterations)) == 0
    assert float(np.abs(np.asarray(res.x)).max()) == 0.0
    assert float(np.asarray(res.relres)) == 0.0
    assert SolveStatus(int(np.asarray(res.status))) == SolveStatus.CONVERGED


def test_zero_rhs_batched_mixed_columns(x64):
    """A zero column riding next to live columns converges instantly
    without perturbing its neighbours."""
    op, b, _ = M.nonsym_dense(48)
    B = jnp.stack([b, jnp.zeros_like(b), 2.0 * b], axis=1)
    sess = repro.make_solver("p-bicgsafe", op,
                             config=SolverConfig(tol=1e-8, maxiter=300))
    res = sess.solve_many(B)
    assert bool(np.asarray(res.converged).all())
    assert int(np.asarray(res.iterations)[1]) == 0
    assert float(np.abs(np.asarray(res.x)[:, 1]).max()) == 0.0
    ref = sess.solve_many(b[:, None])
    np.testing.assert_allclose(np.asarray(res.x[:, 0]),
                               np.asarray(ref.x[:, 0]), rtol=1e-8)


def test_legacy_shims_carry_typed_status(x64):
    """The deprecated free-function entry points fill SolveResult.status
    (satellite: typed statuses are universal, not guarded-only)."""
    from repro import core as C
    op, b, _ = M.nonsym_dense(48)
    for name in ("pbicgsafe_solve", "bicgstab_solve", "cgs_solve",
                 "gpbicg_solve", "pbicgstab_solve", "ssbicgsafe2_solve",
                 "pbicgsafe_rr_solve"):
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            res = getattr(C, name)(op.matvec, b)
        sts = SolveStatus(int(np.asarray(res.status)))
        assert sts == SolveStatus.CONVERGED, (name, sts)


# ---------------------------------------------------------------------------
# service-level resilience
# ---------------------------------------------------------------------------

def _guarded_engine(op, *, recovery=RecoveryPolicy(), clock=None,
                    max_batch=3, chunk=8, tol=1e-8, maxiter=600):
    kw = {} if clock is None else dict(clock=clock)
    eng = SolveEngine(ServiceConfig(max_batch=max_batch, chunk=chunk,
                                    tol=tol, maxiter=maxiter,
                                    recovery=recovery), **kw)
    name = eng.register(op)
    return eng, name


def test_engine_clean_guarded_traffic(x64):
    """Guarded serving on clean traffic: every result typed CONVERGED,
    zero retries, same answers as standalone."""
    op, b, _ = _normalized_problem()
    eng, name = _guarded_engine(op)
    rids = [eng.submit(name, np.asarray(v))
            for v in (b, 0.5 * b, b + 0.1, 2.0 * b)]
    out = {r.rid: r for r in eng.run()}
    assert sorted(out) == sorted(rids)
    for r in out.values():
        assert r.status == SolveStatus.CONVERGED
        assert r.retries == 0
        assert r.converged
        assert np.isfinite(r.x).all()


def test_engine_corruption_scrub_and_retry(x64):
    """Mid-flight NaN corruption: the guarded chunk surfaces NONFINITE,
    the poisoned column is scrubbed before reuse, the victim request is
    re-enqueued and completes on retry — and the resident block stays
    finite throughout."""
    op, b, _ = _normalized_problem()
    eng, name = _guarded_engine(op, recovery=RecoveryPolicy(max_retries=1))
    rids = [eng.submit(name, np.asarray(v)) for v in (b, 0.6 * b)]
    first = eng.poll()                       # block resident, one chunk in
    assert not first
    corrupt_engine_block(eng, name, cols=[0])
    out = {r.rid: r for r in eng.run()}
    assert sorted(out) == sorted(rids)
    retried = [r for r in out.values() if r.retries > 0]
    assert retried, "corrupted request must be retried"
    for r in out.values():
        assert r.converged, (r.rid, r.status)
        assert r.status == SolveStatus.CONVERGED
        assert np.isfinite(r.x).all()
    blk = eng._blocks[name]
    if blk is not None and blk.state is not None:
        assert np.isfinite(np.asarray(
            jax.device_get(blk.state["x"]))).all(), \
            "resident block must stay finite after the scrub"


def test_engine_corruption_retries_exhausted_is_typed(x64):
    """max_retries=0: the corrupted request retires once with its typed
    NONFINITE status and a finite (sanitized) iterate."""
    op, b, _ = _normalized_problem()
    eng, name = _guarded_engine(op, recovery=RecoveryPolicy(max_retries=0))
    rid = eng.submit(name, np.asarray(b))
    assert not eng.poll()
    corrupt_engine_block(eng, name, cols=[0])
    out = {r.rid: r for r in eng.run()}
    r = out[rid]
    assert r.status == SolveStatus.NONFINITE
    assert not r.converged
    assert r.retries == 0
    assert np.isfinite(r.x).all()


def test_engine_deadline_is_typed(x64):
    """Deadline expiry under a virtual clock retires with the typed
    DEADLINE status (queued-only AND mid-flight)."""
    op, b, _ = _normalized_problem()
    clock = TickingClock(dt=0.05)
    eng, name = _guarded_engine(op, clock=clock, maxiter=2000, tol=1e-14)
    rid_ok = eng.submit(name, np.asarray(b), tol=1e-6)
    rid_dead = eng.submit(name, np.asarray(0.5 * b), deadline=0.01)
    clock.advance(1.0)
    out = {r.rid: r for r in eng.run()}
    assert out[rid_dead].status == SolveStatus.DEADLINE
    assert out[rid_dead].telemetry.deadline_exceeded
    assert out[rid_ok].status == SolveStatus.CONVERGED


def test_engine_retry_backoff_window(x64):
    """A re-enqueued request inside its backoff window rotates at the
    back of the queue instead of being dropped, and still completes."""
    op, b, _ = _normalized_problem()
    clock = TickingClock(dt=0.001)
    eng, name = _guarded_engine(
        op, clock=clock,
        recovery=RecoveryPolicy(max_retries=2, retry_backoff_s=0.5,
                                retry_backoff_cap_s=2.0))
    rid = eng.submit(name, np.asarray(b))
    assert not eng.poll()
    corrupt_engine_block(eng, name, cols=[0])
    out = {r.rid: r for r in eng.run()}
    r = out[rid]
    assert r.retries >= 1
    assert r.converged and r.status == SolveStatus.CONVERGED
