"""Property-based tests (hypothesis) for the continuous-batching solve
service: for ANY request stream — mixed tolerances/budgets/operators,
any slot/chunk geometry, either substrate — every multiplexed request
returns the same x / iterations / converged (to tolerance) as a
standalone ``solve_batched`` call.  Streams are drawn longer than the
slot count, so some requests always enter via mid-flight refill."""
import pytest

hypothesis = pytest.importorskip("hypothesis")
import hypothesis.strategies as st  # noqa: E402
from hypothesis import given, settings  # noqa: E402

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from conftest import enable_x64  # noqa: E402
from repro.core import SolverConfig, solve_batched  # noqa: E402
from repro.core import matrices as M  # noqa: E402
from repro.service import ServiceConfig, SolveEngine  # noqa: E402

SETTINGS = dict(max_examples=6, deadline=None,
                suppress_health_check=[hypothesis.HealthCheck.too_slow])


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**10),
       n_req=st.integers(4, 9),
       max_batch=st.sampled_from([2, 3, 4]),
       chunk=st.sampled_from([3, 8, 64]),
       substrate=st.sampled_from(["jnp", "jnp", "pallas"]))
def test_engine_stream_matches_standalone(seed, n_req, max_batch, chunk,
                                          substrate):
    with enable_x64(True):
        nx = 6 if substrate == "pallas" else 8   # interpret mode is slow
        op1, _, _ = M.poisson3d(nx)
        op2, _, _ = M.convection_diffusion(nx, peclet=1.0)
        n = op1.n
        eng = SolveEngine(ServiceConfig(max_batch=max_batch, chunk=chunk,
                                        tol=1e-8, maxiter=300,
                                        substrate=substrate))
        eng.register(op1, name="a")
        eng.register(op2, name="b")
        rng = np.random.default_rng(seed)
        reqs = []
        for _ in range(n_req):
            opn = str(rng.choice(["a", "b"]))
            bb = jnp.asarray(rng.standard_normal(n))
            tol = float(rng.choice([1e-4, 1e-8]))
            maxiter = int(rng.choice([9, 300]))
            rid = eng.submit(opn, bb, tol=tol, maxiter=maxiter)
            reqs.append((rid, opn, bb, tol, maxiter))
        results = {r.rid: r for r in eng.run()}
        assert len(results) == n_req
        for rid, opn, bb, tol, maxiter in reqs:
            op = op1 if opn == "a" else op2
            ref = solve_batched(op, bb[:, None],
                                config=SolverConfig(tol=tol,
                                                    maxiter=maxiter),
                                substrate=substrate)
            r = results[rid]
            assert r.converged == bool(ref.converged[0]), rid
            assert abs(r.iterations - int(ref.iterations[0])) <= 1, rid
            np.testing.assert_allclose(r.x, np.asarray(ref.x[:, 0]),
                                       rtol=1e-6, atol=1e-8,
                                       err_msg=f"rid {rid}")
