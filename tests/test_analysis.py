"""Tests for the HLO analysis + analytic flop counting machinery."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.flops import count_fn, count_jaxpr
from repro.launch.hlo_analysis import (HloGraph, collective_stats,
                                       split_computations)

HLO_SNIPPET = """
HloModule test

%region_0.1 (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %add = f32[] add(%a, %b)
}

%body.2 (p: (f32[128], f32[16])) -> (f32[128], f32[16]) {
  %p = (f32[128], f32[16]) parameter(0)
  %x = f32[128] get-tuple-element(%p), index=0
  %ar = f32[16]{0} all-reduce(%x2), replica_groups={{0,1,2,3}}, to_apply=%region_0.1
  %cp = f32[128]{0} collective-permute(%x), source_target_pairs={{0,1},{1,2}}
  ROOT %t = (f32[128], f32[16]) tuple(%cp, %ar)
}

ENTRY %main (arg: f32[128]) -> f32[128] {
  %arg = f32[128] parameter(0)
  %ag = f32[512]{0} all-gather(%arg), replica_groups={{0,1,2,3}}, dimensions={0}
  %w = (f32[128], f32[16]) while(%init), condition=%cond.3, body=%body.2
  ROOT %out = f32[128] get-tuple-element(%w), index=0
}
"""


def test_collective_stats_basic():
    cs = collective_stats(HLO_SNIPPET, n_devices=4)
    assert cs.counts["all-reduce"] == 1
    assert cs.counts["collective-permute"] == 1
    assert cs.counts["all-gather"] == 1
    # all-reduce of 16 f32 over group of 4: 2 * 64B * 3/4
    assert cs.wire_bytes["all-reduce"] == pytest.approx(2 * 64 * 3 / 4)
    # all-gather result 512 f32 = 2048B * 3/4
    assert cs.wire_bytes["all-gather"] == pytest.approx(2048 * 3 / 4)
    assert cs.wire_bytes["collective-permute"] == pytest.approx(512)


def test_collective_stats_while_multiplier():
    cs1 = collective_stats(HLO_SNIPPET, n_devices=4)
    cs8 = collective_stats(HLO_SNIPPET, n_devices=4,
                           while_body_multiplier=8)
    # body collectives x8; entry all-gather unchanged
    assert cs8.counts["all-reduce"] == 8
    assert cs8.counts["all-gather"] == 1
    assert cs8.wire_bytes["all-reduce"] == \
        pytest.approx(8 * cs1.wire_bytes["all-reduce"])


def test_split_computations():
    comps = split_computations(HLO_SNIPPET)
    assert set(comps) == {"region_0.1", "body.2", "main"}
    assert "all-reduce" in comps["body.2"]
    assert "all-gather" in comps["main"]


def test_hlo_graph_dependencies():
    g = HloGraph(split_computations(HLO_SNIPPET)["body.2"])
    assert "ar" in g.ops and "cp" in g.ops
    # cp consumes %x, ar consumes %x2 (undefined here -> no edge): independent
    assert g.independent("ar", "cp")


def test_count_single_matmul():
    x = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    c = count_fn(lambda a, b: a @ b, x, w)
    assert c["flops"] == pytest.approx(2 * 32 * 64 * 128)
    assert c["dot_bytes"] == pytest.approx(4 * (32 * 64 + 64 * 128
                                                + 32 * 128))


def test_count_scan_multiplies():
    x = jax.ShapeDtypeStruct((16, 16), jnp.float32)
    w = jax.ShapeDtypeStruct((10, 16, 16), jnp.float32)

    def f(x, w):
        return jax.lax.scan(lambda c, ww: (c @ ww, None), x, w)[0]

    c = count_fn(f, x, w)
    assert c["flops"] == pytest.approx(10 * 2 * 16 * 16 * 16)


def test_count_through_jit_and_remat():
    x = jax.ShapeDtypeStruct((8, 8), jnp.float32)

    @jax.jit
    def f(a):
        g = jax.checkpoint(lambda y: y @ y)
        return g(a).sum()

    c = count_fn(lambda a: jax.grad(lambda b: f(b))(a), x)
    # fwd matmul + remat recompute + 2 bwd matmuls >= 3 matmuls
    assert c["flops"] >= 3 * 2 * 8 ** 3


def test_count_model_flops_close_to_6nd():
    """Analytic count vs 6*N*D napkin math on a small dense config."""
    from repro.configs import smoke_config
    from repro.models import init_params, loss_fn
    cfg = smoke_config("phi3-mini-3.8b").replace(
        n_layers=4, d_model=128, n_heads=4, n_kv_heads=4, d_ff=512,
        vocab_size=512, remat="full")
    params = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    batch = {"tokens": jax.ShapeDtypeStruct((4, 128), jnp.int32)}
    c = count_fn(lambda p, b: jax.value_and_grad(
        lambda pp: loss_fn(pp, cfg, b)[0])(p), params, batch)
    n_params = sum(np.prod(l.shape) for l in jax.tree_util.tree_leaves(
        params))
    tokens = 4 * 128
    # full remat: ~8*N*D (2 fwd + 4 bwd + 2 recompute); embeddings skew small
    ratio = c["flops"] / (8 * n_params * tokens)
    assert 0.5 < ratio < 3.0, ratio
