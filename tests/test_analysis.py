"""Tests for the analysis layer: HLO analysis, analytic flop counting,
and the static contract passes of :mod:`repro.analysis`."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax import lax

from conftest import enable_x64
from repro.analysis import (BindingSpec, ContractReport, Finding,
                            REDUCE_MARK_DIM, TracedBinding, format_table,
                            run_passes, tag_matvec, tag_reduce, trace_fn)
from repro.analysis.audit import (ARTIFACT_SCHEMA, METHOD_ORDER,
                                  audit_table, expected_outcomes, run_audit)
from repro.analysis.hlo import (HloGraph, collective_stats,
                                split_computations)
from repro.analysis.trace import trace_binding
from repro.launch.flops import count_fn, count_jaxpr

HLO_SNIPPET = """
HloModule test

%region_0.1 (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %add = f32[] add(%a, %b)
}

%body.2 (p: (f32[128], f32[16])) -> (f32[128], f32[16]) {
  %p = (f32[128], f32[16]) parameter(0)
  %x = f32[128] get-tuple-element(%p), index=0
  %ar = f32[16]{0} all-reduce(%x2), replica_groups={{0,1,2,3}}, to_apply=%region_0.1
  %cp = f32[128]{0} collective-permute(%x), source_target_pairs={{0,1},{1,2}}
  ROOT %t = (f32[128], f32[16]) tuple(%cp, %ar)
}

ENTRY %main (arg: f32[128]) -> f32[128] {
  %arg = f32[128] parameter(0)
  %ag = f32[512]{0} all-gather(%arg), replica_groups={{0,1,2,3}}, dimensions={0}
  %w = (f32[128], f32[16]) while(%init), condition=%cond.3, body=%body.2
  ROOT %out = f32[128] get-tuple-element(%w), index=0
}
"""


def test_collective_stats_basic():
    cs = collective_stats(HLO_SNIPPET, n_devices=4)
    assert cs.counts["all-reduce"] == 1
    assert cs.counts["collective-permute"] == 1
    assert cs.counts["all-gather"] == 1
    # all-reduce of 16 f32 over group of 4: 2 * 64B * 3/4
    assert cs.wire_bytes["all-reduce"] == pytest.approx(2 * 64 * 3 / 4)
    # all-gather result 512 f32 = 2048B * 3/4
    assert cs.wire_bytes["all-gather"] == pytest.approx(2048 * 3 / 4)
    assert cs.wire_bytes["collective-permute"] == pytest.approx(512)


def test_collective_stats_while_multiplier():
    cs1 = collective_stats(HLO_SNIPPET, n_devices=4)
    cs8 = collective_stats(HLO_SNIPPET, n_devices=4,
                           while_body_multiplier=8)
    # body collectives x8; entry all-gather unchanged
    assert cs8.counts["all-reduce"] == 8
    assert cs8.counts["all-gather"] == 1
    assert cs8.wire_bytes["all-reduce"] == \
        pytest.approx(8 * cs1.wire_bytes["all-reduce"])


def test_split_computations():
    comps = split_computations(HLO_SNIPPET)
    assert set(comps) == {"region_0.1", "body.2", "main"}
    assert "all-reduce" in comps["body.2"]
    assert "all-gather" in comps["main"]


def test_hlo_graph_dependencies():
    g = HloGraph(split_computations(HLO_SNIPPET)["body.2"])
    assert "ar" in g.ops and "cp" in g.ops
    # cp consumes %x, ar consumes %x2 (undefined here -> no edge): independent
    assert g.independent("ar", "cp")


def test_count_single_matmul():
    x = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    c = count_fn(lambda a, b: a @ b, x, w)
    assert c["flops"] == pytest.approx(2 * 32 * 64 * 128)
    assert c["dot_bytes"] == pytest.approx(4 * (32 * 64 + 64 * 128
                                                + 32 * 128))


def test_count_scan_multiplies():
    x = jax.ShapeDtypeStruct((16, 16), jnp.float32)
    w = jax.ShapeDtypeStruct((10, 16, 16), jnp.float32)

    def f(x, w):
        return jax.lax.scan(lambda c, ww: (c @ ww, None), x, w)[0]

    c = count_fn(f, x, w)
    assert c["flops"] == pytest.approx(10 * 2 * 16 * 16 * 16)


def test_count_through_jit_and_remat():
    x = jax.ShapeDtypeStruct((8, 8), jnp.float32)

    @jax.jit
    def f(a):
        g = jax.checkpoint(lambda y: y @ y)
        return g(a).sum()

    c = count_fn(lambda a: jax.grad(lambda b: f(b))(a), x)
    # fwd matmul + remat recompute + 2 bwd matmuls >= 3 matmuls
    assert c["flops"] >= 3 * 2 * 8 ** 3


def test_count_model_flops_close_to_6nd():
    """Analytic count vs 6*N*D napkin math on a small dense config."""
    from repro.configs import smoke_config
    from repro.models import init_params, loss_fn
    cfg = smoke_config("phi3-mini-3.8b").replace(
        n_layers=4, d_model=128, n_heads=4, n_kv_heads=4, d_ff=512,
        vocab_size=512, remat="full")
    params = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    batch = {"tokens": jax.ShapeDtypeStruct((4, 128), jnp.int32)}
    c = count_fn(lambda p, b: jax.value_and_grad(
        lambda pp: loss_fn(pp, cfg, b)[0])(p), params, batch)
    n_params = sum(np.prod(l.shape) for l in jax.tree_util.tree_leaves(
        params))
    tokens = 4 * 128
    # full remat: ~8*N*D (2 fwd + 4 bwd + 2 recompute); embeddings skew small
    ratio = c["flops"] / (8 * n_params * tokens)
    assert 0.5 < ratio < 3.0, ratio


# ---------------------------------------------------------------------------
# contract passes (repro.analysis): clean bindings pass, hand-built
# violating programs make each pass fail when it should
# ---------------------------------------------------------------------------

def _stencil_op(nx=6, ny=4, nz=4):
    from repro.core.linear_operator import Stencil7Operator
    dtype = jax.dtypes.canonicalize_dtype(np.float64)
    c = jnp.array([6.5, -1.5, -1.0, -1.25, -1.0, -1.0, -1.0], dtype)
    return Stencil7Operator(c, nx, ny, nz)


def _probe_spec(**kw):
    base = dict(method="probe", substrate="jnp", binding="single", m=1)
    base.update(kw)
    return BindingSpec(**base)


def _probe_loop(b, body_fn, iters=5):
    return lax.while_loop(lambda c: c[1] < iters,
                          lambda c: body_fn(*c), (b, 0))[0]


def test_clean_pipelined_binding_passes_all():
    tb = trace_binding("p-bicgsafe", _stencil_op(), binding="batched",
                       substrate="jnp", m=3)
    rep = run_passes(tb)
    assert rep.ok, [f.to_dict() for f in rep.findings if not f.ok]
    assert rep.finding("one_reduction_per_iteration").status == "ok"
    assert rep.finding("overlap_edge_free").status == "ok"
    assert rep.finding("single_psum_sharded").status == "skipped"
    assert rep.finding("kernel_backed").status == "skipped"
    assert rep.finding("dtype_flow").status == "ok"


def test_second_reduction_violates_one_reduction_pass():
    """A hand-built while body that syncs TWICE per iteration."""
    mv = tag_matvec(lambda x: 2.0 * x)

    def body(x, i):
        y = mv(x)
        p1 = tag_reduce(x[0] * jnp.ones((9,), x.dtype))
        p2 = tag_reduce(y[0] * jnp.ones((9,), x.dtype))   # second sync
        return (y + p1[0] + p2[0], i + 1)

    tb = trace_fn(lambda b: _probe_loop(b, body), jnp.ones((8,)),
                  spec=_probe_spec())
    f = run_passes(tb).finding("one_reduction_per_iteration")
    assert f.status == "violation"
    assert "2 reduction phases" in f.detail
    assert len(f.provenance) == 2


def test_wrong_partial_block_shape_violates():
    """One sync, but not carrying the fused (9[, m]) partial block."""
    def body(x, i):
        p = tag_reduce(x[:4])
        return (x + p[0], i + 1)

    tb = trace_fn(lambda b: _probe_loop(b, body), jnp.ones((8,)),
                  spec=_probe_spec())
    f = run_passes(tb).finding("one_reduction_per_iteration")
    assert f.status == "violation"
    assert "fused" in f.detail


def test_reduction_consuming_matvec_violates_overlap():
    """The reduction transitively consumes the in-flight matvec output:
    the dependency edge the paper's pipelining removes."""
    mv = tag_matvec(lambda x: 2.0 * x)

    def dirty(x, i):
        y = mv(x)
        p = tag_reduce(y[0] * jnp.ones((9,), x.dtype))    # needs the matvec
        return (y + p[0], i + 1)

    def clean(x, i):
        y = mv(x)                                         # in flight
        p = tag_reduce(x[0] * jnp.ones((9,), x.dtype))    # previous vectors
        return (y + p[0], i + 1)

    tb = trace_fn(lambda b: _probe_loop(b, dirty), jnp.ones((8,)),
                  spec=_probe_spec())
    f = run_passes(tb).finding("overlap_edge_free")
    assert f.status == "violation"
    assert "transitively consumes" in f.detail

    tb = trace_fn(lambda b: _probe_loop(b, clean), jnp.ones((8,)),
                  spec=_probe_spec())
    assert run_passes(tb).finding("overlap_edge_free").status == "ok"


def test_sequential_and_baseline_methods_are_negative_controls():
    """ssBiCGSafe2 fuses the dots but its reduction consumes the matvec;
    the BiCGStab family keeps several scattered reductions."""
    op = _stencil_op()
    rep = run_passes(trace_binding("ssbicgsafe2", op, binding="single"))
    assert rep.finding("one_reduction_per_iteration").status == "ok"
    assert rep.finding("overlap_edge_free").status == "violation"
    for method in ("bicgstab", "cgs"):
        rep = run_passes(trace_binding(method, op, binding="single"))
        assert rep.finding("one_reduction_per_iteration").status \
            == "violation"
        assert rep.finding("overlap_edge_free").status == "violation"


def test_dtype_flow_catches_reintroduced_f32_downcast():
    """Regression for the PR-2 (GGN-path) class of bug: an operator
    closure that silently round-trips the iterate through f32 breaks
    recurrence linearity — dtype_flow must flag the downcast."""
    with enable_x64(True):
        op = _stencil_op()
        clean = trace_binding("p-bicgsafe", op, binding="batched", m=3)
        assert run_passes(clean).finding("dtype_flow").status == "ok"

        def dirty(x):                      # f64 -> f32 -> f64 round trip
            return op.matvec(x.astype(jnp.float32)).astype(x.dtype)

        bmv = jax.vmap(dirty, in_axes=1, out_axes=1)
        tb = trace_binding("p-bicgsafe", bmv, binding="batched", m=3,
                           n=op.shape[0], blocked=True)
        f = run_passes(tb).finding("dtype_flow")
        assert f.status == "violation"
        assert "float64->float32" in f.detail
        assert f.provenance


def test_kernel_backed_flags_silent_jnp_fallback():
    op = _stencil_op()
    tb = trace_binding("p-bicgsafe", op, binding="batched",
                       substrate="pallas", m=3)
    assert run_passes(tb).finding("kernel_backed").status == "ok"
    # the identical program traced on jnp, under a spec CLAIMING pallas:
    # exactly what a silent fallback looks like to the analyzer
    jnp_tb = trace_binding("p-bicgsafe", op, binding="batched",
                           substrate="jnp", m=3)
    faked = TracedBinding(
        spec=dataclasses.replace(jnp_tb.spec, substrate="pallas"),
        jaxpr=jnp_tb.jaxpr, body=jnp_tb.body)
    f = run_passes(faked).finding("kernel_backed")
    assert f.status == "violation"
    assert "silent jnp fallback" in f.detail


def test_expected_outcomes_matrix():
    def s(**kw):
        return _probe_spec(**{**dict(method="p-bicgsafe",
                                     binding="batched", m=3), **kw})
    exp = expected_outcomes(s())
    assert exp["one_reduction_per_iteration"] == "ok"
    assert exp["overlap_edge_free"] == "ok"
    assert exp["single_psum_sharded"] == "skipped"
    exp = expected_outcomes(s(method="ssbicgsafe2"))
    assert exp["one_reduction_per_iteration"] == "ok"
    assert exp["overlap_edge_free"] == "violation"
    exp = expected_outcomes(s(method="bicgstab"))
    assert exp["one_reduction_per_iteration"] == "violation"
    # a 1-device mesh has no halo ppermutes: overlap trivially edge-free
    # even for the sequential baselines, but the psum count still tells
    exp = expected_outcomes(s(method="bicgstab", binding="mesh",
                              mesh_shape=(1,)))
    assert exp["overlap_edge_free"] == "ok"
    assert exp["single_psum_sharded"] == "violation"


def test_format_table_and_report_dict():
    rep = run_passes(trace_binding("p-bicgsafe", _stencil_op(),
                                   binding="batched", m=3))
    table = format_table([rep])
    assert "one_reduction_per_iteration" in table
    assert "pass" in table
    d = rep.to_dict()
    assert d["ok"] is True
    assert d["binding"]["method"] == "p-bicgsafe"
    assert {f["contract"] for f in d["findings"]} >= {
        "one_reduction_per_iteration", "overlap_edge_free", "dtype_flow"}


def test_session_verify_contracts():
    from repro.api import LinearSolver
    op = _stencil_op()
    reports = LinearSolver("p-bicgsafe", op).verify_contracts()
    assert reports and all(r.ok for r in reports)
    with pytest.raises(ValueError, match="overlap_edge_free"):
        LinearSolver("ssbicgsafe2", op).verify_contracts(
            raise_on_violation=True)


def test_audit_golden_snapshot():
    """Pin the audit artifact schema and the expected pass/fail matrix
    for all 7 methods x 2 substrates (quick mode, in-process: the mesh
    smoke runs trivially on the single pytest device).  The cell list
    is registry-driven: 60 dense acceptance cells + one contract row
    per registered quick scenario + the 5 mesh smoke cells."""
    from repro.analysis.audit import audit_specs
    from repro.scenarios.cells import matrix_cells, scenario_cells
    art = run_audit(quick=True)
    assert art["schema"] == ARTIFACT_SCHEMA \
        == "repro.analysis/contract_audit/v1"
    assert art["ok"] is True
    assert art["deviations"] == []
    assert len(matrix_cells(quick=True)) == 60
    n_scen = len(scenario_cells(quick=True))
    assert n_scen >= 3       # the seed registrations incl. helmholtz
    assert len(audit_specs(quick=True)) == 60 + n_scen
    assert art["n_cells"] == 60 + n_scen + 5
    assert art["n_mesh_cells"] == 5
    assert art["n_scenario_cells"] == n_scen
    # registry-driven rows carry their scenario name + operator class
    helm = [r for r in art["reports"]
            if r.get("operator_class") == "helmholtz_shifted"]
    assert helm and all(not r["deviations"] for r in helm)
    assert tuple(art["methods"]) == METHOD_ORDER
    pipelined = {"p-bicgsafe", "p-bicgsafe-rr"}
    fused = pipelined | {"ssbicgsafe2"}
    for method in METHOD_ORDER:
        for substrate in ("jnp", "pallas"):
            cell = art["matrix"][f"{method}/{substrate}"]
            assert cell["one_reduction_per_iteration"] == \
                ("ok" if method in fused else "violation"), (method,
                                                             substrate)
            assert cell["overlap_edge_free"] == \
                ("ok" if method in pipelined else "violation")
            assert cell["single_psum_sharded"] == "skipped"
            assert cell["kernel_backed"] == \
                ("ok" if substrate == "pallas" and method in fused
                 else "skipped")
            assert cell["dtype_flow"] == "ok"
    assert "contract matrix" in audit_table(art)
