"""repro.scenarios: registry semantics, serialization, cache-hitting
binds, the operator-plugin protocol, and the sweep runner."""
import json
from collections import OrderedDict

import numpy as np
import pytest

from repro.scenarios import (OperatorSpec, Scenario, ScenarioError,
                             build_problem, get_operator_class,
                             get_scenario, register_operator_class,
                             register_scenario, resolve_scenario,
                             scenario_names)


@pytest.fixture(autouse=True)
def _hermetic_registries():
    """Roll back registrations, the built-problem cache (a float32
    problem cached here must not leak into an x64 test elsewhere), and
    the global x64 flag (run_sweep flips it) after every test."""
    import jax

    from repro.scenarios import registry as R
    ops = dict(R.OPERATOR_CLASSES)
    scs = OrderedDict(R.SCENARIOS)
    probs = OrderedDict(R._PROBLEMS)
    x64_was = jax.config.jax_enable_x64
    yield
    R.OPERATOR_CLASSES.clear()
    R.OPERATOR_CLASSES.update(ops)
    R.SCENARIOS.clear()
    R.SCENARIOS.update(scs)
    R._PROBLEMS.clear()
    R._PROBLEMS.update(probs)
    jax.config.update("jax_enable_x64", x64_was)


# ---------------------------------------------------------------------------
# serialization: JSON <-> dataclass is lossless
# ---------------------------------------------------------------------------

def test_json_round_trip_lossless_for_every_registered_scenario():
    for name in scenario_names():
        sc = get_scenario(name)
        assert Scenario.from_json(sc.to_json()) == sc
        assert Scenario.from_dict(json.loads(sc.to_json())) == sc


def test_json_round_trip_lossless_nondefault_fields():
    sc = Scenario(
        "rt", OperatorSpec.of("convection_diffusion", nx=9, peclet=2.0),
        method="ssbicgsafe2", substrate="pallas", precond="jacobi",
        tol=1e-10, maxiter=777, batch=1, binding="single",
        trace=True, tags=("a", "b"), quick=False)
    back = Scenario.from_json(sc.to_json())
    assert back == sc and back.operator.kwargs == {"nx": 9, "peclet": 2.0}


def test_from_dict_rejects_unknown_and_missing_keys():
    with pytest.raises(ScenarioError, match="unknown scenario keys"):
        Scenario.from_dict({"name": "x", "operator": {"cls": "poisson3d"},
                            "solvr": "p-bicgsafe"})
    with pytest.raises(ScenarioError, match="missing required keys"):
        Scenario.from_dict({"name": "x"})
    with pytest.raises(ScenarioError, match="JSON scalar"):
        OperatorSpec.of("poisson3d", nx=[8, 8])


# ---------------------------------------------------------------------------
# registry: conflict detection, validation messages
# ---------------------------------------------------------------------------

def test_duplicate_scenario_registration_raises():
    sc = Scenario("dup-cell", OperatorSpec.of("poisson3d", nx=6))
    assert register_scenario(sc) is sc
    # equal content: idempotent (returns the existing registration)
    assert register_scenario(
        Scenario("dup-cell", OperatorSpec.of("poisson3d", nx=6))) is sc
    with pytest.raises(ScenarioError, match="already registered"):
        register_scenario(
            Scenario("dup-cell", OperatorSpec.of("poisson3d", nx=7)))


def test_duplicate_operator_class_registration_raises():
    def build(**kw):
        return build_problem("poisson3d", **kw)
    register_operator_class("dup-op-class", build)
    register_operator_class("dup-op-class", build)   # same builder: ok
    with pytest.raises(ScenarioError, match="already registered"):
        register_operator_class("dup-op-class", lambda **kw: None)


def test_validation_names_the_valid_choices():
    with pytest.raises(ScenarioError, match="unregistered operator class"):
        register_scenario(Scenario("bad-op", OperatorSpec.of("nope")))
    with pytest.raises(ScenarioError, match="unknown precond"):
        register_scenario(Scenario(
            "bad-pc", OperatorSpec.of("poisson3d", nx=6), precond="ilu"))
    with pytest.raises(ScenarioError, match="unknown method"):
        Scenario("bad-m", OperatorSpec.of("poisson3d", nx=6),
                 method="gmres").validate()
    with pytest.raises(ScenarioError, match="p-BiCGSafe iteration only"):
        Scenario("bad-b", OperatorSpec.of("poisson3d", nx=6),
                 method="bicgstab", batch=4).validate()
    with pytest.raises(ScenarioError, match="unknown scenario"):
        get_scenario("never-registered")
    with pytest.raises(ScenarioError, match="unregistered operator class"):
        build_problem("never-registered-class")
    with pytest.raises(ScenarioError, match="not mesh-capable"):
        register_scenario(Scenario(
            "bad-mesh", OperatorSpec.of("hard_nonsym", n=50),
            binding="mesh"))


# ---------------------------------------------------------------------------
# bind(): the PR-5 session cache, through the scenario layer
# ---------------------------------------------------------------------------

def test_bind_hits_session_cache_no_retrace(x64):
    sc = get_scenario("poisson-jacobi")
    s1 = sc.bind()
    _, b, _ = sc.problem()
    s1.solve(b)
    traces = s1.stats["traces"]
    assert traces >= 1
    s2 = sc.bind()                      # same content -> SAME session
    assert s2 is s1
    s2.solve(b)                         # compiled program reused
    assert s1.stats["traces"] == traces


def test_make_solver_scenario_kwarg(x64):
    import repro
    sc = get_scenario("poisson-jacobi")
    assert repro.make_solver(scenario="poisson-jacobi") is sc.bind()
    # the scenario declares everything: other arguments are a loud error
    with pytest.raises(TypeError, match="exclusive"):
        repro.make_solver(scenario="poisson-jacobi", precond="jacobi")
    with pytest.raises(ScenarioError, match="unknown scenario"):
        repro.make_solver(scenario="never-registered")


def test_resolve_scenario_passthrough_validates():
    ad_hoc = Scenario("ad-hoc", OperatorSpec.of("poisson3d", nx=6))
    assert resolve_scenario(ad_hoc) is ad_hoc
    with pytest.raises(ScenarioError, match="unregistered operator"):
        resolve_scenario(Scenario("ad-hoc2", OperatorSpec.of("zzz")))


def test_built_problems_are_cached_per_spec_content():
    p1 = build_problem("convection_diffusion", nx=8, peclet=1.0)
    p2 = build_problem(OperatorSpec.of("convection_diffusion",
                                      peclet=1.0, nx=8))
    assert p1[0] is p2[0]               # param order is normalized


# ---------------------------------------------------------------------------
# the Helmholtz plugin: oracle + contracts, zero core edits
# ---------------------------------------------------------------------------

def test_helmholtz_session_verify_contracts(x64):
    session = get_scenario("helmholtz-shifted").bind()
    reports = session.verify_contracts()
    assert reports and all(r.ok for r in reports)


def test_helmholtz_solve_and_complex_oracle(x64):
    sc = get_scenario("helmholtz-shifted")
    plugin = get_operator_class("helmholtz_shifted")
    problem = sc.problem()
    op, b, x_true = problem
    res = sc.bind().solve(b)
    assert bool(res.converged)
    X = np.asarray(res.x)[:, None]
    B = np.asarray(b)[:, None]
    verdict = plugin.oracle(problem, B, X, sc.tol)
    assert verdict["ok"] and verdict["relres_complex"] < 1e-6
    assert verdict["x_err_complex"] < 1e-6
    # the oracle judges the COMPLEX system: flipping the imaginary half
    # (a real-equivalent sign bug) must fail verification
    X_bad = X.copy()
    X_bad[op.stencil.n:] *= -1.0
    assert not plugin.oracle(problem, B, X_bad, sc.tol)["ok"]


def test_helmholtz_real_equivalent_algebra(x64):
    op, b, x_true = build_problem("helmholtz_shifted", nx=6)
    half = op.stencil.n
    rng = np.random.default_rng(0)
    z = rng.standard_normal(2 * half)
    y = np.asarray(op.matvec(z))
    # against straight complex arithmetic
    zc = z[:half] + 1j * z[half:]
    Lr = np.asarray(op.stencil.matvec(z[:half]))
    Li = np.asarray(op.stencil.matvec(z[half:]))
    yc = (Lr + 1j * Li) - 1j * float(op.eps) * zc
    np.testing.assert_allclose(y[:half], yc.real, rtol=1e-12)
    np.testing.assert_allclose(y[half:], yc.imag, rtol=1e-12)


# ---------------------------------------------------------------------------
# service + audit integration
# ---------------------------------------------------------------------------

def test_engine_register_scenario(x64):
    from repro.service import SolveEngine
    eng = SolveEngine()
    name = eng.register_scenario("poisson-jacobi")
    assert name == "poisson-jacobi"
    entry = eng.registry[name]
    _, b, x_true = get_scenario("poisson-jacobi").problem()
    rid = eng.submit(name, np.asarray(b))
    results = {r.rid: r for r in eng.run()}
    assert results[rid].converged
    np.testing.assert_allclose(np.asarray(results[rid].x),
                               np.asarray(x_true), atol=1e-6)
    assert entry.n == len(np.asarray(b))


def test_audit_negative_control_unregistered_class(tmp_path, capsys):
    """Satellite: the audit CLI fails with a clear one-line message —
    not a traceback — when a scenario file names an unregistered
    operator class or an unknown precond."""
    from repro.analysis.__main__ import main
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps([{
        "name": "negctl", "operator": {"cls": "no_such_class"}}]))
    rc = main(["audit", "--quick", "--no-mesh", "--devices", "1",
               "--scenarios", str(bad),
               "--out", str(tmp_path / "a.json")])
    assert rc == 2
    err = capsys.readouterr().err
    assert "error:" in err and "no_such_class" in err \
        and "registered classes" in err

    bad.write_text(json.dumps([{
        "name": "negctl2", "operator": {"cls": "poisson3d",
                                        "params": {"nx": 6}},
        "precond": "ilu"}]))
    rc = main(["audit", "--quick", "--no-mesh", "--devices", "1",
               "--scenarios", str(bad),
               "--out", str(tmp_path / "a.json")])
    assert rc == 2
    assert "unknown precond 'ilu'" in capsys.readouterr().err


# ---------------------------------------------------------------------------
# the sweep runner
# ---------------------------------------------------------------------------

def test_sweep_single_cell_artifact(x64):
    from repro.scenarios.sweep import ARTIFACT_SCHEMA, run_sweep
    art = run_sweep(only=["convdiff-baseline"])
    assert art["schema"] == ARTIFACT_SCHEMA \
        == "repro.scenarios/scenario_sweep/v1"
    assert art["summary"]["n_cells"] == 1
    assert art["claims"] == {"all_converged": True,
                             "all_oracle_ok": True,
                             "all_contracts_ok": True}
    (cell,) = art["cells"]
    assert cell["scenario"] == "convdiff-baseline"
    assert cell["operator"]["cls"] == "convection_diffusion"
    assert cell["oracle"]["ok"] and cell["contracts"]["ok"]


def test_sweep_unknown_selection_raises():
    from repro.scenarios.sweep import run_sweep
    with pytest.raises(ScenarioError, match="unknown scenario"):
        run_sweep(only=["no-such-cell"])
    with pytest.raises(ScenarioError, match="matched nothing"):
        run_sweep(tags=["no-such-tag"])


def test_plugin_expected_outcome_deltas_are_honored():
    """A plugin's contract_overrides REPLACE the expected status for its
    cells.  bicgstab is a negative control: the default matrix expects
    'violation' for the fused-reduction contract, so its cell is clean.
    A plugin declaring 'ok' for that contract flips the expectation and
    the same trace now counts as a deviation."""
    from repro.scenarios.sweep import _check_contracts
    plain = Scenario("delta-plain-cell",
                     OperatorSpec.of("convection_diffusion", nx=6),
                     method="bicgstab")
    rec = _check_contracts(plain, plain.problem())
    assert rec["ok"]                    # violation expected -> no deviation

    register_operator_class(
        "delta-probe", lambda **kw: build_problem("convection_diffusion",
                                                  nx=6),
        contract_overrides={"one_reduction_per_iteration": "ok"})
    sc = Scenario("delta-probe-cell", OperatorSpec.of("delta-probe"),
                  method="bicgstab")
    rec = _check_contracts(sc, sc.problem())
    assert not rec["ok"]                # plugin's delta is now violated
    assert rec["deviations"][0]["contract"] == \
        "one_reduction_per_iteration"
    assert rec["deviations"][0]["expected"] == "ok"
