"""Snapshot of the public ``repro`` namespace.

The front-door namespace is a contract: accidental export drift (a new
helper leaking to ``repro.*``, a re-export vanishing during a refactor)
must fail loudly here, with the fix being an intentional edit of BOTH
the package ``__all__`` and this snapshot.
"""
import repro

# the intended public surface of `import repro` — keep sorted
PUBLIC_API = [
    "CSROperator",
    "ConvergenceTrace",
    "DenseOperator",
    "DistributedSolver",
    "ELLOperator",
    "GuardedSolver",
    "LinearSolver",
    "OperatorSpec",
    "Preconditioner",
    "RecoveryPolicy",
    "SOLVERS",
    "SUBSTRATES",
    "Scenario",
    "SolveResult",
    "SolveStatus",
    "SolverConfig",
    "Stencil7Operator",
    "get_substrate",
    "make_solver",
    "operator_fingerprint",
    "register_operator_class",
    "register_scenario",
    "solve",
]

# submodules that legitimately appear as attributes after import
# (importing repro.api pulls these in); NOT part of the call surface
_SUBMODULES = {"api", "core", "precond", "kernels", "resilience",
               "observe", "scenarios"}


def test_all_matches_snapshot():
    assert sorted(repro.__all__) == PUBLIC_API, (
        "public repro namespace drifted; if intentional, update BOTH "
        "repro/__init__.__all__ and tests/test_api_surface.PUBLIC_API")


def test_exports_exist_and_nothing_leaks():
    for name in PUBLIC_API:
        assert hasattr(repro, name), f"declared export {name!r} missing"
    leaked = {n for n in dir(repro)
              if not n.startswith("_")
              and n not in set(PUBLIC_API) | _SUBMODULES
              and type(getattr(repro, n)).__name__ != "module"}
    assert not leaked, (
        f"unexported public names leaked into repro.*: {sorted(leaked)}")


def test_solver_registry_matches_methods():
    """SOLVERS is the method registry make_solver resolves from — its
    key set is part of the public contract."""
    assert sorted(repro.SOLVERS) == [
        "bicgstab", "cgs", "gpbicg", "p-bicgsafe", "p-bicgsafe-rr",
        "p-bicgstab", "ssbicgsafe2"]


def test_front_door_docstrings_point_home():
    """The layer docs route newcomers to the front door."""
    import repro.core
    assert "repro.api" in (repro.core.__doc__ or "")
    assert "make_solver" in (repro.__doc__ or "")
