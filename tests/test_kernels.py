"""Pallas kernels vs. pure-jnp oracles (interpret mode), sweeping
shapes and dtypes as required for each kernel."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import enable_x64
from repro.core.linear_operator import ELLOperator
from repro.core import matrices as M
from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.fused_axpy import (IN_ORDER, fused_axpy_batched_pallas,
                                      fused_axpy_pallas)
from repro.kernels.fused_dots import (fused_dots_batched_pallas,
                                      fused_dots_pallas)
from repro.kernels.spmv_ell import (spmv_ell_batched_pallas,
                                    spmv_ell_pallas)


def rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


@pytest.mark.parametrize("n", [100, 4096, 40_000])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_fused_dots(n, dtype):
    with enable_x64(dtype == jnp.float64):
        ks = jax.random.split(jax.random.PRNGKey(0), 5)
        vecs = [rand(k, (n,), dtype) for k in ks]
        got = fused_dots_pallas(*vecs, interpret=True)
        want = ref.fused_dots(*vecs)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5)


@pytest.mark.parametrize("n,m", [(100, 1), (1000, 7), (4096, 32),
                                 (513, 130)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_fused_dots_batched(n, m, dtype):
    """Multi-RHS kernel: (n, m) blocks -> (9, m) partials, incl. lane
    padding (m=7, 130) and row-block padding (n=513)."""
    with enable_x64(dtype == jnp.float64):
        ks = jax.random.split(jax.random.PRNGKey(5), 5)
        vecs = [rand(k, (n, m), dtype) for k in ks]
        got = fused_dots_batched_pallas(*vecs, interpret=True)
        want = ref.fused_dots_batched(*vecs)
        assert got.shape == (9, m)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=1e-5)
        # column j of the batched kernel == the 1-D kernel on column j
        col = [v[:, 0] for v in vecs]
        np.testing.assert_allclose(
            np.asarray(got[:, 0]),
            np.asarray(fused_dots_pallas(*col, interpret=True)),
            rtol=2e-4, atol=1e-5)


@pytest.mark.parametrize("n,stencil", [(512, True), (4096, True),
                                       (1000, False)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_spmv_ell(n, stencil, dtype):
    with enable_x64(dtype == jnp.float64):
        if stencil:
            # banded matrix: tridiagonal-ish with k=5
            rng = np.random.default_rng(0)
            k = 5
            offs = np.array([-2, -1, 0, 1, 2])
            cols = np.clip(np.arange(n)[:, None] + offs[None, :], 0, n - 1)
            vals = rng.standard_normal((n, k))
            vals[cols == np.arange(n)[:, None]] += 3.0
            op = ELLOperator(jnp.asarray(vals, dtype),
                             jnp.asarray(cols, np.int32), n)
        else:
            csr, _, _ = M.random_nonsym(n, 6, seed=1, dtype=np.float64)
            op = ELLOperator.from_csr(csr)
            op = ELLOperator(op.values.astype(dtype), op.cols, n)
            pytest.skip("non-banded: ops.spmv_ell falls back to jnp ref")
        x = rand(jax.random.PRNGKey(2), (n,), dtype)
        got = spmv_ell_pallas(op.values, op.cols, x, interpret=True)
        want = ref.spmv_ell(op.values, op.cols, x)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("n", [100, 8192])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_fused_axpy(n, dtype):
    with enable_x64(dtype == jnp.float64):
        keys = jax.random.split(jax.random.PRNGKey(1), len(IN_ORDER))
        vecs = {k: rand(kk, (n,), dtype) for k, kk in zip(IN_ORDER, keys)}
        scalars = (0.3, -0.7, 1.1, 0.2)
        got = fused_axpy_pallas(vecs, scalars, interpret=True)
        want = ref.fused_axpy(vecs, scalars)
        for k in got:
            np.testing.assert_allclose(
                np.asarray(got[k]), np.asarray(want[k]),
                rtol=5e-5, atol=1e-5, err_msg=k)


@pytest.mark.parametrize("n,m", [(100, 1), (1000, 7), (513, 130)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_fused_axpy_batched(n, m, dtype):
    """Multi-RHS update-phase kernel: (n, m) blocks with per-column
    coefficients, incl. lane padding (m=7, 130) and row padding (n=513)."""
    with enable_x64(dtype == jnp.float64):
        keys = jax.random.split(jax.random.PRNGKey(1), len(IN_ORDER) + 4)
        vecs = {k: rand(kk, (n, m), dtype)
                for k, kk in zip(IN_ORDER, keys)}
        scalars = tuple(rand(kk, (m,), dtype)
                        for kk in keys[len(IN_ORDER):])
        got = fused_axpy_batched_pallas(vecs, scalars, interpret=True)
        want = ref.fused_axpy(vecs, scalars)
        for k in got:
            np.testing.assert_allclose(
                np.asarray(got[k]), np.asarray(want[k]),
                rtol=5e-5, atol=1e-5, err_msg=k)
        # column 0 of the batched kernel == the 1-D kernel on column 0
        col = {k: v[:, 0] for k, v in vecs.items()}
        got0 = fused_axpy_pallas(col, tuple(s[0] for s in scalars),
                                 interpret=True)
        for k in got0:
            np.testing.assert_allclose(
                np.asarray(got[k][:, 0]), np.asarray(got0[k]),
                rtol=5e-5, atol=1e-5, err_msg=k)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_fused_axpy_batched_mask_freezes_columns(dtype):
    """The in-kernel convergence mask: frozen columns return their INPUT
    tiles bitwise for every state output; o/q stay fresh."""
    from repro.kernels.fused_axpy import MASKED_OUT
    with enable_x64(dtype == jnp.float64):
        n, m = 300, 5
        keys = jax.random.split(jax.random.PRNGKey(2), len(IN_ORDER) + 4)
        vecs = {k: rand(kk, (n, m), dtype)
                for k, kk in zip(IN_ORDER, keys)}
        scalars = tuple(rand(kk, (m,), dtype)
                        for kk in keys[len(IN_ORDER):])
        mask = jnp.asarray([True, False, True, False, False])
        got = fused_axpy_batched_pallas(vecs, scalars, mask, interpret=True)
        want = ref.fused_axpy(vecs, scalars, mask=mask)
        frozen = ~np.asarray(mask)
        for k in got:
            np.testing.assert_allclose(
                np.asarray(got[k]), np.asarray(want[k]),
                rtol=5e-5, atol=1e-5, err_msg=k)
            if k in MASKED_OUT:
                np.testing.assert_array_equal(
                    np.asarray(got[k])[:, frozen],
                    np.asarray(vecs[k])[:, frozen], err_msg=k)


@pytest.mark.parametrize("n,m", [(512, 1), (1030, 4), (4096, 33)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_spmv_ell_batched(n, m, dtype):
    """Block banded ELL SpMV: matrix tiles amortized over m columns."""
    with enable_x64(dtype == jnp.float64):
        rng = np.random.default_rng(0)
        k = 5
        offs = np.array([-2, -1, 0, 1, 2])
        cols = np.clip(np.arange(n)[:, None] + offs[None, :], 0, n - 1)
        vals = rng.standard_normal((n, k))
        vals[cols == np.arange(n)[:, None]] += 3.0
        values = jnp.asarray(vals, dtype)
        cols = jnp.asarray(cols, np.int32)
        x = rand(jax.random.PRNGKey(2), (n, m), dtype)
        got = spmv_ell_batched_pallas(values, cols, x, interpret=True)
        want = ref.spmv_ell(values, cols, x)
        assert got.shape == (n, m)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-5)
        # column j == the 1-D kernel on column j
        col0 = spmv_ell_pallas(values, cols, x[:, 0], interpret=True)
        np.testing.assert_allclose(np.asarray(got[:, 0]), np.asarray(col0),
                                   rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("shape", [
    (1, 4, 4, 256, 64),     # MHA
    (2, 8, 2, 512, 64),     # GQA G=4
    (1, 2, 1, 1024, 128),   # MQA-ish, longer S
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(shape, dtype):
    B, H, K, S, hd = shape
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = rand(ks[0], (B, H, S, hd), dtype)
    k = rand(ks[1], (B, K, S, hd), dtype)
    v = rand(ks[2], (B, K, S, hd), dtype)
    scale = 1.0 / np.sqrt(hd)
    got = flash_attention_pallas(q, k, v, scale=scale, causal=True,
                                 block_q=128, block_k=128, interpret=True)
    want = ref.flash_attention(q, k, v, scale=scale, causal=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_non_causal():
    B, H, K, S, hd = 1, 4, 4, 256, 64
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    q, k, v = (rand(kk, (B, H if i == 0 else K, S, hd), jnp.float32)
               for i, kk in enumerate(ks))
    scale = 1.0 / np.sqrt(hd)
    got = flash_attention_pallas(q, k, v, scale=scale, causal=False,
                                 block_q=128, block_k=128, interpret=True)
    want = ref.flash_attention(q, k, v, scale=scale, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5,
                               atol=2e-5)


def test_solver_with_pallas_kernels():
    """End-to-end: p-BiCGSafe using the Pallas SpMV + fused dots
    (interpret) reproduces the jnp solver on a banded system."""
    import functools
    from repro.core import SolverConfig, pbicgsafe_solve
    from repro.kernels import ops

    with enable_x64(True):
        op, b, xt = M.poisson3d(8)   # stencil -> banded under natural order?
        # use a 1-D banded operator instead (guaranteed band)
        n = 2048
        rng = np.random.default_rng(0)
        offs = np.array([-2, -1, 0, 1, 2])
        cols = np.clip(np.arange(n)[:, None] + offs[None, :], 0, n - 1)
        vals = rng.standard_normal((n, 5))
        # strict row diagonal dominance -> guaranteed convergence
        vals[:, 2] = 1.0 + 1.2 * np.abs(vals).sum(axis=1)
        ell = ELLOperator(jnp.asarray(vals), jnp.asarray(cols, np.int32), n)
        xt = jnp.ones((n,), jnp.float64)
        b = ell.matvec(xt)

        mv = functools.partial(ops.spmv_ell, ell)
        res = pbicgsafe_solve(mv, b, config=SolverConfig(tol=1e-10))
        assert bool(res.converged)
        err = float(jnp.linalg.norm(res.x - xt) / jnp.linalg.norm(xt))
        assert err < 1e-7
