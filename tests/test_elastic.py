"""Elastic scaling: a checkpoint taken under one device topology restores
bit-exactly under another (checkpoints store unsharded leaves; the
restoring job re-shards under its own in_shardings) — the contract that
lets a 512-chip job resume on 256 chips after losing a pod."""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), os.pardir,
                                   "src"))

_CHILD = textwrap.dedent("""
    import os, sys
    n_dev, ckpt_dir, mode = sys.argv[1], sys.argv[2], sys.argv[3]
    os.environ["XLA_FLAGS"] = \\
        f"--xla_force_host_platform_device_count={n_dev}"
    import jax, numpy as np
    from repro.configs import smoke_config
    from repro.data import DataConfig
    from repro.optim import AdamWConfig
    from repro.parallel import LogicalMesh
    from repro.train import TrainConfig, train

    cfg = smoke_config("phi3-mini-3.8b")
    dcfg = DataConfig(batch_size=4, seq_len=32, vocab_size=cfg.vocab_size)
    steps = 6 if mode == "first" else 12
    lm = None
    if int(n_dev) > 1:
        from repro.core.compat import make_mesh
        mesh = make_mesh((2, int(n_dev) // 2), ("data", "model"))
        lm = LogicalMesh(mesh)
    tcfg = TrainConfig(steps=steps, ckpt_every=6, ckpt_dir=ckpt_dir,
                       opt=AdamWConfig(lr=1e-3, warmup_steps=2,
                                       decay_steps=12))
    out = train(cfg, dcfg, tcfg, lm=lm)
    print("START_STEP", out["start_step"])
    print("FINAL_LOSS", out["final_loss"])
""")


def _run(n_dev, ckpt_dir, mode):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    p = subprocess.run([sys.executable, "-c", _CHILD, str(n_dev),
                        str(ckpt_dir), mode],
                       capture_output=True, text=True, env=env, timeout=900)
    assert p.returncode == 0, p.stderr[-3000:]
    return {l.split()[0]: float(l.split()[1])
            for l in p.stdout.splitlines()
            if l.startswith(("START_STEP", "FINAL_LOSS"))}


@pytest.mark.slow
def test_checkpoint_restores_across_topologies(tmp_path):
    # leg 1: 6 steps on an 8-device (2,4) mesh; checkpoint at step 6
    a = _run(8, tmp_path, "first")
    assert a["START_STEP"] == 0
    # leg 2: resume the same checkpoint on a SINGLE device to step 12
    b = _run(1, tmp_path, "second")
    assert b["START_STEP"] == 6
    # reference: same 12 steps uninterrupted on 1 device
    ref = _run(1, tmp_path / "ref", "second")
    assert abs(b["FINAL_LOSS"] - ref["FINAL_LOSS"]) < 0.15, \
        (b["FINAL_LOSS"], ref["FINAL_LOSS"])
