"""Tests for the repro.precond subsystem: kernel parity (single and
(n, m) batched), API/resolution, the dtype-preserving Jacobi guard,
preconditioned-solve behaviour on the hard problem classes, and the
operator ``diagonal()`` consistency sweep every preconditioner bootstraps
from."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import enable_x64
from repro.core import (SOLVERS, SolverConfig, get_substrate, pbicgsafe_solve,
                        solve_batched)
from repro.core import matrices as M
from repro.core.linear_operator import (CSROperator, DenseOperator,
                                        ELLOperator, Stencil7Operator)
from repro.kernels import ref
from repro.kernels.precond_apply import (block_jacobi_apply_batched_pallas,
                                         block_jacobi_apply_pallas)
from repro.precond import (BlockJacobiPreconditioner, JacobiPreconditioner,
                           NeumannPreconditioner, SSORPreconditioner,
                           block_jacobi, jacobi, neumann, resolve_precond,
                           ssor)


# ---------------------------------------------------------------------------
# Pallas block-apply kernel vs. the jnp oracle (interpret mode on CPU runs
# the same kernel bodies as TPU)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("nb,bs", [(12, 16), (7, 8), (300, 4), (3, 128),
                                   (1000, 2)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_block_jacobi_kernel_parity(nb, bs, dtype):
    """Single-RHS kernel == oracle, incl. group padding (nb=7, 300)."""
    with enable_x64(dtype == jnp.float64):
        rng = np.random.default_rng(0)
        inv = jnp.asarray(rng.standard_normal((nb, bs, bs)), dtype)
        x = jnp.asarray(rng.standard_normal((nb * bs,)), dtype)
        got = block_jacobi_apply_pallas(inv, x, interpret=True)
        want = ref.block_jacobi_apply(inv, x)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=5e-5, atol=1e-5)


@pytest.mark.parametrize("nb,bs,m", [(12, 16, 3), (7, 8, 1), (64, 4, 17),
                                     (3, 128, 2)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_block_jacobi_kernel_parity_batched(nb, bs, m, dtype):
    """(n, m) block kernel == oracle; column j == the 1-D kernel on j."""
    with enable_x64(dtype == jnp.float64):
        rng = np.random.default_rng(1)
        inv = jnp.asarray(rng.standard_normal((nb, bs, bs)), dtype)
        X = jnp.asarray(rng.standard_normal((nb * bs, m)), dtype)
        got = block_jacobi_apply_batched_pallas(inv, X, interpret=True)
        want = ref.block_jacobi_apply(inv, X)
        assert got.shape == (nb * bs, m)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=5e-5, atol=1e-5)
        col0 = block_jacobi_apply_pallas(inv, X[:, 0], interpret=True)
        np.testing.assert_allclose(np.asarray(got[:, 0]), np.asarray(col0),
                                   rtol=5e-5, atol=1e-5)


def test_ops_dispatch_and_shared_block(x64):
    """ops.block_jacobi_apply: ndim dispatch + the shared-block (nb == 1)
    fast path match the oracle."""
    from repro.kernels import ops
    rng = np.random.default_rng(2)
    for nb in (1, 9):
        inv = jnp.asarray(rng.standard_normal((nb, 8, 8)))
        x = jnp.asarray(rng.standard_normal((72,)))
        X = jnp.asarray(rng.standard_normal((72, 4)))
        np.testing.assert_allclose(
            np.asarray(ops.block_jacobi_apply(inv, x)),
            np.asarray(ref.block_jacobi_apply(inv, x)), rtol=1e-10)
        np.testing.assert_allclose(
            np.asarray(ops.block_jacobi_apply(inv, X)),
            np.asarray(ref.block_jacobi_apply(inv, X)), rtol=1e-10)


# ---------------------------------------------------------------------------
# substrate-bound applies: jnp == pallas for every preconditioner, (n,)
# and (n, m)
# ---------------------------------------------------------------------------

def _ell_banded(n, seed=0):
    rng = np.random.default_rng(seed)
    offs = np.array([-2, -1, 0, 1, 2])
    cols = np.clip(np.arange(n)[:, None] + offs[None, :], 0, n - 1)
    vals = rng.standard_normal((n, 5))
    vals[:, 2] = 1.0 + 1.2 * np.abs(vals).sum(axis=1)
    return ELLOperator(jnp.asarray(vals), jnp.asarray(cols, np.int32), n)


@pytest.mark.parametrize("factory", ["jacobi", "block_jacobi", "neumann",
                                     "ssor"])
def test_bound_apply_substrate_parity(x64, factory):
    """pc.bind(jnp) == pc.bind(pallas) on (n,) vectors and (n, m) blocks
    — every preconditioner apply runs through the substrate layer on both
    paths (block-Jacobi through the Pallas kernel, Neumann through the
    Pallas SpMV for banded ELL operators)."""
    if factory == "ssor":
        op, b, _ = M.anisotropic3d(8, eps=1e-2)
    else:
        op = _ell_banded(512)
        b = op.matvec(jnp.ones((512,), jnp.float64))
    pc = resolve_precond(factory, op)
    a_jnp = get_substrate("jnp").as_precond_apply(pc)
    a_pal = get_substrate("pallas").as_precond_apply(pc)
    X = jnp.stack([b, 0.5 * b, b - 1.0], axis=1)
    np.testing.assert_allclose(np.asarray(a_pal(b)), np.asarray(a_jnp(b)),
                               rtol=1e-9, atol=1e-11)
    np.testing.assert_allclose(np.asarray(a_pal(X)), np.asarray(a_jnp(X)),
                               rtol=1e-9, atol=1e-11)
    # (n, m) apply == column-by-column (n,) apply
    np.testing.assert_allclose(np.asarray(a_jnp(X)[:, 0]),
                               np.asarray(a_jnp(b)), rtol=1e-12)


# ---------------------------------------------------------------------------
# resolution / API
# ---------------------------------------------------------------------------

def test_resolve_precond(x64):
    op, _, _ = M.poisson3d(6)
    assert resolve_precond(None, op) is None
    pc = jacobi(op)
    assert resolve_precond(pc, op) is pc
    assert isinstance(resolve_precond("jacobi", op), JacobiPreconditioner)
    assert isinstance(resolve_precond("block_jacobi", op),
                      BlockJacobiPreconditioner)
    assert isinstance(resolve_precond("neumann", op), NeumannPreconditioner)
    assert isinstance(resolve_precond("ssor", op), SSORPreconditioner)
    with pytest.raises(ValueError, match="unknown preconditioner"):
        resolve_precond("ilu", op)
    with pytest.raises(TypeError, match="operator object"):
        resolve_precond("jacobi", op.matvec)
    with pytest.raises(TypeError, match="Stencil7Operator"):
        ssor(M.nonsym_dense(16)[0])


def test_preconds_are_pytrees(x64):
    """Preconditioners are pytrees: they survive jit closures/arguments."""
    op, b, _ = M.poisson3d(6)
    for pc in (jacobi(op), block_jacobi(op), neumann(op), ssor(op)):
        leaves, treedef = jax.tree_util.tree_flatten(pc)
        pc2 = jax.tree_util.tree_unflatten(treedef, leaves)
        np.testing.assert_allclose(np.asarray(pc2.apply(b)),
                                   np.asarray(pc.apply(b)), rtol=1e-12)
        out = jax.jit(lambda p, v: p.apply(v))(pc, b)
        np.testing.assert_allclose(np.asarray(out), np.asarray(pc.apply(b)),
                                   rtol=1e-12)


def test_deprecation_reexports():
    """The historical repro.core.linear_operator import path still works
    and resolves to the repro.precond implementations."""
    from repro.core.linear_operator import (JacobiPreconditioner as J,
                                            preconditioned_matvec)
    import repro.precond as P
    assert J is P.JacobiPreconditioner
    assert preconditioned_matvec is P.preconditioned_matvec
    from repro.core import JacobiPreconditioner as J2
    assert J2 is P.JacobiPreconditioner


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_jacobi_from_operator_dtype_preserving(x64, dtype):
    """Regression (PR 3): the zero-diagonal guard must preserve the
    operator dtype under the x64 conftest — no weak-typed ``1.0 / d``
    promotion — and substitute exactly 1 on zero-diagonal rows."""
    a = jnp.asarray(np.diag([2.0, 0.0, -4.0, 8.0]), dtype)
    pc = JacobiPreconditioner.from_operator(DenseOperator(a))
    assert pc.inv_diag.dtype == dtype
    assert not pc.inv_diag.weak_type
    np.testing.assert_allclose(np.asarray(pc.inv_diag),
                               [0.5, 1.0, -0.25, 0.125])


def test_block_jacobi_singular_block_guard(x64):
    """A singular diagonal block (e.g. an empty row) degrades to the
    identity — the block analogue of the Jacobi zero-diagonal guard —
    instead of raising LinAlgError at setup."""
    a = np.diag(np.arange(1.0, 13.0))
    a[2, :] = 0.0                       # empty row -> block 0 singular
    pc = block_jacobi(DenseOperator(jnp.asarray(a)), block_size=4)
    inv = np.asarray(pc.inv_blocks)
    assert np.isfinite(inv).all()
    np.testing.assert_allclose(inv[0], np.eye(4))       # guarded block
    np.testing.assert_allclose(inv[1], np.linalg.inv(a[4:8, 4:8]))
    np.testing.assert_allclose(inv[2], np.linalg.inv(a[8:12, 8:12]))


def test_preconditioned_matvec_composes(x64):
    op, b, _ = M.poisson3d(6)
    from repro.precond import preconditioned_matvec
    mv = preconditioned_matvec(op, jacobi(op))
    np.testing.assert_allclose(np.asarray(mv(b)),
                               np.asarray(op.matvec(b) / 6.0), rtol=1e-12)
    assert preconditioned_matvec(op, None)(b).shape == b.shape


# ---------------------------------------------------------------------------
# solver-level behaviour: the acceptance scenario + parity
# ---------------------------------------------------------------------------

def test_pbicgsafe_block_jacobi_pallas_hard_nonsym(x64):
    """The acceptance scenario: plain p-BiCGSafe stagnates on the badly
    row-scaled hard_nonsym family; with precond=block_jacobi(op) on
    substrate="pallas" it converges in (far) fewer iterations AND still
    solves the ORIGINAL system."""
    op, b, xt = M.hard_nonsym(n=600)
    cfg = SolverConfig(tol=1e-8, maxiter=2000)
    plain = pbicgsafe_solve(op, b, config=cfg)
    prec = pbicgsafe_solve(op, b, config=cfg, precond=block_jacobi(op),
                           substrate="pallas")
    assert bool(prec.converged)
    assert int(prec.iterations) < int(plain.iterations)
    err = float(jnp.linalg.norm(prec.x - xt) / jnp.linalg.norm(xt))
    assert err < 1e-5
    # true residual of the ORIGINAL (unpreconditioned) system
    true = float(jnp.linalg.norm(b - op.matvec(prec.x))
                 / jnp.linalg.norm(b))
    assert true < 1e-4


@pytest.mark.parametrize("precond", ["jacobi", "block_jacobi", "neumann",
                                     "ssor"])
def test_preconditioned_solve_substrate_parity(x64, precond):
    """Preconditioned p-BiCGSafe: jnp and pallas substrates run the same
    algorithm (iteration counts within the usual ±1 stopping jitter,
    solution-level agreement)."""
    op, b, xt = M.convection_diffusion(10, peclet=1.0)
    cfg = SolverConfig(tol=1e-8, maxiter=2000)
    r_jnp = pbicgsafe_solve(op, b, config=cfg, precond=precond,
                            substrate="jnp")
    r_pal = pbicgsafe_solve(op, b, config=cfg, precond=precond,
                            substrate="pallas")
    assert bool(r_jnp.converged) and bool(r_pal.converged)
    assert abs(int(r_jnp.iterations) - int(r_pal.iterations)) <= 1
    np.testing.assert_allclose(np.asarray(r_pal.x), np.asarray(r_jnp.x),
                               rtol=1e-5, atol=1e-7)


@pytest.mark.parametrize("substrate", ["jnp", "pallas"])
def test_batched_preconditioned_matches_single(x64, substrate):
    """solve_batched with precond: each column reproduces the single-RHS
    preconditioned solve (same M^{-1} for every column)."""
    op, b, _ = M.convection_diffusion(8, peclet=1.0)
    B = jnp.stack([b, 0.5 * b, b + 1.0], axis=1)
    cfg = SolverConfig(tol=1e-8, maxiter=2000)
    res = solve_batched(op, B, config=cfg, precond="block_jacobi",
                        substrate=substrate)
    assert bool(np.asarray(res.converged).all())
    for j in range(B.shape[1]):
        rj = pbicgsafe_solve(op, B[:, j], config=cfg,
                             precond="block_jacobi", substrate=substrate)
        assert abs(int(res.iterations[j]) - int(rj.iterations)) <= 3
        np.testing.assert_allclose(np.asarray(res.x[:, j]),
                                   np.asarray(rj.x), rtol=1e-5, atol=1e-7)


def test_all_entry_points_accept_precond(x64):
    """Every solver entry point takes precond= and still converges to the
    true solution of the original system."""
    op, b, xt = M.convection_diffusion(8, peclet=1.0)
    cfg = SolverConfig(tol=1e-8, maxiter=2000)
    for sname, solve in SOLVERS.items():
        res = solve(op, b, config=cfg, precond="ssor")
        assert bool(res.converged), sname
        err = float(jnp.linalg.norm(res.x - xt) / jnp.linalg.norm(xt))
        assert err < 1e-5, (sname, err)


# ---------------------------------------------------------------------------
# deterministic instances of the property "preconditioning never needs
# MORE iterations on the hard problem classes" (the hypothesis sweep over
# random instances lives in tests/test_precond_properties.py, which skips
# without hypothesis installed)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [1, 3, 7])
def test_precond_helps_hard_nonsym_instances(x64, seed):
    op, b, _ = M.hard_nonsym(n=240, seed=seed)
    cfg = SolverConfig(tol=1e-8, maxiter=1200)
    plain = pbicgsafe_solve(op, b, config=cfg)
    prec = pbicgsafe_solve(op, b, config=cfg, precond="block_jacobi")
    assert bool(prec.converged) and not bool(prec.breakdown)
    assert int(prec.iterations) <= int(plain.iterations)


@pytest.mark.parametrize("eps", [1e-3, 1e-2, 1e-1])
def test_precond_helps_anisotropic3d_instances(x64, eps):
    op, b, _ = M.anisotropic3d(8, eps=eps)
    cfg = SolverConfig(tol=1e-8, maxiter=2000)
    plain = pbicgsafe_solve(op, b, config=cfg)
    prec = pbicgsafe_solve(op, b, config=cfg, precond="ssor")
    assert bool(prec.converged) and not bool(prec.breakdown)
    assert int(prec.iterations) <= int(plain.iterations)


# ---------------------------------------------------------------------------
# diagonal() consistency sweep (every preconditioner bootstraps from it)
# ---------------------------------------------------------------------------

def _operator_cases():
    def dense():
        return M.nonsym_dense(40)[0]

    def csr():
        return M.random_nonsym(60, 5, seed=2)[0]

    def ell():
        return ELLOperator.from_csr(M.random_nonsym(60, 5, seed=3)[0])

    def stencil():
        return M.convection_diffusion(4, peclet=0.7)[0]

    return {"dense": dense, "csr": csr, "ell": ell, "stencil7": stencil}


@pytest.mark.parametrize("kind", list(_operator_cases()))
def test_diagonal_matches_dense_materialization(x64, kind):
    """diagonal() of all four operator classes agrees with the diagonal
    of the densely materialized matrix (matvec against the identity)."""
    op = _operator_cases()[kind]()
    n = op.shape[0]
    eye = jnp.eye(n, dtype=op.dtype)
    dense = jax.vmap(op.matvec, in_axes=1, out_axes=1)(eye)
    np.testing.assert_allclose(np.asarray(op.diagonal()),
                               np.asarray(jnp.diagonal(dense)),
                               rtol=1e-12, atol=1e-12)
    assert isinstance(op, (DenseOperator, CSROperator, ELLOperator,
                           Stencil7Operator))
