"""Unit tests for smaller pieces: preconditioning, LR schedule, pipelined
clipping, data prefetcher, solver mesh helper."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (JacobiPreconditioner, SolverConfig, as_matvec,
                        pbicgsafe_solve, preconditioned_matvec)
from repro.core import matrices as M
from repro.optim import AdamWConfig
from repro.optim.adamw import schedule
from repro.optim.clipping import (global_norm, pipelined_clip,
                                  pipelined_clip_init)


def test_jacobi_preconditioner_reduces_iterations(x64):
    op, b, xt = M.anisotropic3d(12, eps=1e-3)
    plain = pbicgsafe_solve(op.matvec, b, config=SolverConfig(maxiter=4000))
    pre = JacobiPreconditioner.from_operator(op)
    mv = preconditioned_matvec(op, pre)
    cond = pbicgsafe_solve(mv, pre.apply(b),
                           config=SolverConfig(maxiter=4000))
    assert bool(cond.converged)
    # preconditioned system solves the same problem
    err = float(jnp.linalg.norm(cond.x - xt) / jnp.linalg.norm(xt))
    assert err < 1e-5
    if bool(plain.converged):
        assert int(cond.iterations) <= int(plain.iterations) + 5


def test_lr_schedule_shape():
    cfg = AdamWConfig(lr=1e-3, warmup_steps=10, decay_steps=100,
                      min_lr_ratio=0.1)
    lrs = [float(schedule(cfg, jnp.asarray(s))) for s in
           [0, 5, 10, 50, 100, 1000]]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(5e-4)
    assert lrs[2] == pytest.approx(1e-3, rel=0.2)
    assert lrs[-1] == pytest.approx(1e-4, rel=0.01)   # min lr floor
    assert lrs[3] > lrs[4]


def test_pipelined_clip_uses_stale_norm():
    g1 = {"w": jnp.full((4,), 100.0)}     # norm 200
    g2 = {"w": jnp.full((4,), 0.001)}
    st = pipelined_clip_init()
    s1, st = pipelined_clip(g1, st, max_norm=1.0)
    # first step: no previous norm -> uses fresh (200) -> scale 1/200
    assert float(s1) == pytest.approx(1.0 / float(global_norm(g1)))
    s2, st = pipelined_clip(g2, st, max_norm=1.0)
    # second step clips with step-1's norm (stale): tiny scale despite
    # tiny fresh gradient — the one-step-stale contract
    assert float(s2) == pytest.approx(1.0 / float(global_norm(g1)))
    s3, _ = pipelined_clip(g2, st, max_norm=1.0)
    assert float(s3) == 1.0               # now sees g2's small norm


def test_prefetcher_yields_in_order():
    from repro.data import DataConfig, make_dataset
    from repro.data.pipeline import prefetch
    cfg = DataConfig(batch_size=2, seq_len=16, vocab_size=64)
    fn = make_dataset(cfg)
    it = prefetch(fn, start_step=0)
    got = [next(it) for _ in range(3)]
    for step, b in enumerate(got):
        np.testing.assert_array_equal(b["tokens"], fn(step)["tokens"])


def test_ring_shift_is_exact_shift():
    """ring_shift on a 1-axis mesh == roll with zero boundary."""
    import subprocess, sys, os, textwrap
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), os.pardir, "src"))
    env.pop("XLA_FLAGS", None)
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.core import compat
        from repro.core.distributed import ring_shift
        mesh = compat.make_mesh((4, 2), ("a", "b"))
        x = jnp.arange(8.0).reshape(8, 1)
        def f(x):
            fwd = ring_shift(x, ("a", "b"), (4, 2), True)
            bwd = ring_shift(x, ("a", "b"), (4, 2), False)
            return fwd, bwd
        fwd, bwd = jax.jit(compat.shard_map(f, mesh=mesh,
            in_specs=P(("a", "b")), out_specs=(P(("a", "b")),) * 2))(x)
        np.testing.assert_allclose(np.asarray(fwd).ravel(),
                                   [0,0,1,2,3,4,5,6])
        np.testing.assert_allclose(np.asarray(bwd).ravel(),
                                   [1,2,3,4,5,6,7,0])
        print("RING OK")
    """)
    p = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=300)
    assert p.returncode == 0, p.stderr[-2000:]
    assert "RING OK" in p.stdout
