"""Complex-shifted Helmholtz operators — the proof-of-plugin class.

The complex-shifted Helmholtz system (van Gijzen et al.'s shifted-
Laplacian family)::

    (L - (k^2 + i eps) I) x_c = b_c

with ``L`` the 7-point Laplacian, is the canonical wave-equation
problem class pipelined nonsymmetric solvers get pointed at.  The
solvers and kernels in :mod:`repro.core` are real-dtype; rather than
teach them complex arithmetic, this plugin registers the system in its
REAL-EQUIVALENT block form, acting on stacked ``[Re x; Im x]`` of
length 2n::

    [[A_r,  eps I],        A_r = L - k^2 I   (a Stencil7Operator)
     [-eps I,  A_r]]

whose eigenvalues are ``lambda(A_r) -+ i eps`` — modulus bounded below
by ``eps`` even where the shifted Laplacian is indefinite, and
decisively non-symmetric: exactly the BiCGSafe regime.

Everything here — the pytree operator, the builder, the complex-residual
oracle, the expected contract outcomes — registers from the plugin side;
no file under ``src/repro/core/`` changes.  That is the extension
contract the scenario registry exists to prove.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.linear_operator import Stencil7Operator

from .registry import register_operator_class

__all__ = ["HelmholtzShiftedOperator"]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class HelmholtzShiftedOperator:
    """Real-equivalent form of ``L - (k^2 + i eps) I`` on a 3-D grid.

    ``stencil`` is the REAL part ``A_r = L - k^2 I`` (center coefficient
    ``6 - k^2``); ``eps`` the imaginary shift.  Vectors are the stacked
    real/imaginary halves, length ``2 * stencil.n``.  Composes two
    stencil applications plus the scalar coupling — matrix-free, and a
    registered pytree with array leaves, so sessions bound to it are
    content-fingerprinted and cached like any core operator.
    """

    stencil: Stencil7Operator
    eps: jax.Array                      # scalar imaginary shift

    @property
    def n(self):
        return 2 * self.stencil.n

    @property
    def shape(self):
        return (self.n, self.n)

    @property
    def dtype(self):
        return self.stencil.dtype

    def matvec(self, x: jax.Array) -> jax.Array:
        half = self.stencil.n
        xr, xi = x[:half], x[half:]
        yr = self.stencil.matvec(xr) + self.eps * xi
        yi = self.stencil.matvec(xi) - self.eps * xr
        return jnp.concatenate([yr, yi])

    def diagonal(self) -> jax.Array:
        d = self.stencil.diagonal()
        return jnp.concatenate([d, d])

    def tree_flatten(self):
        return (self.stencil, self.eps), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(*children)


def _helmholtz_oracle(problem, B, X, tol: float) -> dict:
    """Verify solutions of the REAL-EQUIVALENT solve against the
    COMPLEX system they encode.

    Reassembles ``x_c = Re + i Im`` per column in numpy complex
    arithmetic, applies ``L - (k^2 + i eps) I`` through the real stencil,
    and checks the complex true residual — so a sign error in the block
    coupling (the classic real-equivalent bug) fails verification even
    when the real residual looks converged.
    """
    import numpy as np
    op, _, x_true = problem
    half = op.stencil.n
    eps = complex(0.0, float(op.eps))

    def apply_c(z):
        re = np.asarray(op.stencil.matvec(jnp.asarray(z.real)))
        im = np.asarray(op.stencil.matvec(jnp.asarray(z.imag)))
        return re + 1j * im - eps * z

    Bc = np.asarray(B[:half]) + 1j * np.asarray(B[half:])
    Xc = np.asarray(X[:half]) + 1j * np.asarray(X[half:])
    res = np.stack([Bc[:, j] - apply_c(Xc[:, j])
                    for j in range(Xc.shape[1])], axis=1)
    bnorm = np.linalg.norm(Bc, axis=0)
    relres = np.linalg.norm(res, axis=0) / np.where(bnorm == 0, 1, bnorm)
    detail = {"relres_complex": float(relres.max())}
    if x_true is not None:
        xt = np.asarray(x_true)
        xtc = xt[:half] + 1j * xt[half:]          # (1 + i) * ones
        detail["x_err_complex"] = float(np.abs(Xc[:, 0] - xtc).max())
    return {"ok": bool(relres.max() <= 50 * tol), **detail}


# Expected contract outcomes: the block operator composes jnp stencil
# applications with NO reduction of its own, so every cell keeps the
# paper's per-method expected matrix — one tagged fused reduction per
# iteration, overlap-edge free, and (on the pallas substrate) the
# operator-independent fused-phase kernels.  Declared explicitly empty:
# a plugin whose operators legitimately deviate would list the deltas
# here and the audit would hold it to them.
@register_operator_class(
    "helmholtz_shifted", oracle=_helmholtz_oracle, contract_overrides={},
    mesh_capable=False,
    description="complex-shifted Helmholtz, real-equivalent 2x2 block "
                "form (wave-equation kind)")
def _build(nx: int = 8, ny: int = 0, nz: int = 0,
           shift: float = 0.3, eps: float = 0.6):
    """Builder: ``shift`` is k^2 (0 -> pure Laplacian + rotation);
    ``eps`` the imaginary shift that bounds the spectrum away from 0.
    ``ny``/``nz`` default (0) to ``nx``."""
    ny, nz = ny or nx, nz or nx
    dtype = jax.dtypes.canonicalize_dtype(jnp.float64)
    c = jnp.array([6.0 - shift, -1.0, -1.0, -1.0, -1.0, -1.0, -1.0],
                  dtype=dtype)
    stencil = Stencil7Operator(c, nx, ny, nz)
    op = HelmholtzShiftedOperator(stencil, jnp.asarray(eps, dtype=dtype))
    x_true = jnp.ones((op.n,), dtype=dtype)     # complex (1 + i) * ones
    b = op.matvec(x_true)
    return op, b, x_true
