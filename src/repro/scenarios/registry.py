"""The global scenario + operator-class registries.

Two tables, both content-aware with ``service/registry.py``-style name
conflict detection:

* :data:`OPERATOR_CLASSES` — name -> :class:`OperatorPlugin`.  A plugin
  is the ONE definition of a problem family: the builder that
  materializes ``(op, b, x_true)``, the verification oracle the sweep
  runs on solutions, and the expected-outcome deltas the contract audit
  merges over :func:`repro.analysis.audit.expected_outcomes`.  The
  benchmarks and tests that used to copy-paste operator construction now
  call :func:`build_problem` against this table.
* :data:`SCENARIOS` — name -> :class:`~.types.Scenario`.  Registration
  validates every name the scenario references; re-registering EQUAL
  content is idempotent (returns the existing entry), a name collision
  with different content raises.

Built problems are memoized per spec content (bounded LRU), so repeat
``Scenario.bind()`` calls hand :func:`repro.api.make_solver` the same
operator object and hit the PR-5 session cache.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Callable, Dict, List, Mapping, Optional, Tuple, Union

from .types import OperatorSpec, Scenario, ScenarioError

__all__ = [
    "OperatorPlugin", "register_operator_class", "register_scenario",
    "get_operator_class", "get_scenario", "resolve_scenario",
    "operator_class_names", "scenario_names", "scenarios",
    "build_problem", "default_oracle",
]


def default_oracle(problem, B, X, tol: float) -> dict:
    """The stock verification oracle: per-column true residual.

    ``B``/``X`` are (n, m) numpy arrays (the sweep normalizes single-RHS
    results to one column).  A solution verifies when every column's
    TRUE relative residual — recomputed from the operator, not the
    solver's recurrence — lands within a modest factor of the requested
    tolerance (pipelined recurrences drift near tol; 50x is the same
    order-of-magnitude guard the benchmarks use).
    """
    import numpy as np
    op, _, x_true = problem
    AX = np.stack([np.asarray(op.matvec(X[:, j]))
                   for j in range(X.shape[1])], axis=1)
    bnorm = np.linalg.norm(B, axis=0)
    relres = np.linalg.norm(B - AX, axis=0) / np.where(bnorm == 0, 1, bnorm)
    detail = {"relres_true": float(relres.max())}
    if x_true is not None and B.shape[1] >= 1:
        # column 0 of every sweep block is the unit-solution rhs
        xerr = float(np.abs(X[:, 0] - np.asarray(x_true)).max())
        detail["x_err"] = xerr
    return {"ok": bool(relres.max() <= 50 * tol), **detail}


@dataclasses.dataclass(frozen=True)
class OperatorPlugin:
    """One operator class, registered from the outside.

    ``build(**params)`` returns ``(op, b, x_true)`` with the
    unit-solution protocol (``x_true`` may be None for oracle-only
    verification).  ``oracle(problem, B, X, tol)`` judges a sweep
    solution (default: :func:`default_oracle`'s true-residual check).
    ``contract_overrides`` maps contract name -> expected status
    ("ok"/"violation"/"skipped"), merged over the paper's per-method
    expected matrix for every audit cell that uses this class —
    how a plugin declares that its operators legitimately deviate.
    ``mesh_capable`` gates ``binding="mesh"`` scenarios (the sharded
    driver needs the row-sharded stencil halo format).
    """

    name: str
    build: Callable
    oracle: Callable = default_oracle
    contract_overrides: Tuple[Tuple[str, str], ...] = ()
    mesh_capable: bool = False
    description: str = ""


OPERATOR_CLASSES: Dict[str, OperatorPlugin] = {}
SCENARIOS: "OrderedDict[str, Scenario]" = OrderedDict()

#: built-problem memo: (OperatorSpec, x64 regime) -> (op, b, x_true).
#: Builders canonicalize dtypes against the live x64 flag, so the same
#: spec built under float32 and float64 is two different problems — the
#: flag is part of the key.  Bounded: a sweep over many one-off specs
#: must not pin every operator's arrays.
_PROBLEMS: "OrderedDict[tuple, tuple]" = OrderedDict()
_PROBLEMS_MAX = 32


def register_operator_class(
        name: str, build: Optional[Callable] = None, *,
        oracle: Optional[Callable] = None,
        contract_overrides: Optional[Mapping[str, str]] = None,
        mesh_capable: bool = False,
        description: str = "") -> Union[OperatorPlugin, Callable]:
    """Register an operator-class plugin; usable as a decorator::

        @register_operator_class("helmholtz_shifted", oracle=my_oracle)
        def build(nx=8, ...):
            return op, b, x_true

    Re-registering the same name with the same builder is idempotent;
    a different builder under a taken name raises (the
    ``service/registry.py`` conflict rule).
    """
    def _register(build_fn: Callable) -> OperatorPlugin:
        plugin = OperatorPlugin(
            name=name, build=build_fn,
            oracle=oracle if oracle is not None else default_oracle,
            contract_overrides=tuple(sorted(
                (contract_overrides or {}).items())),
            mesh_capable=mesh_capable,
            description=description or (build_fn.__doc__ or "")
            .strip().split("\n")[0])
        existing = OPERATOR_CLASSES.get(name)
        if existing is not None:
            if existing.build is build_fn \
                    and existing.contract_overrides \
                    == plugin.contract_overrides:
                return existing
            raise ScenarioError(
                f"operator class {name!r} already registered with "
                "different content")
        OPERATOR_CLASSES[name] = plugin
        return plugin

    if build is not None:
        return _register(build)
    return _register                         # decorator form


def get_operator_class(name: str) -> OperatorPlugin:
    try:
        return OPERATOR_CLASSES[name]
    except KeyError:
        raise ScenarioError(
            f"unregistered operator class {name!r}; registered classes: "
            f"{', '.join(operator_class_names()) or '(none)'}") from None


def operator_class_names() -> List[str]:
    return sorted(OPERATOR_CLASSES)


def build_problem(spec: Union[OperatorSpec, str], **params):
    """Materialize ``(op, b, x_true)`` for one operator spec, memoized
    per spec content.  Accepts an :class:`OperatorSpec` or
    ``build_problem("poisson3d", nx=8)``."""
    import jax
    if isinstance(spec, str):
        spec = OperatorSpec.of(spec, **params)
    elif params:
        raise TypeError("pass params inside the OperatorSpec OR as "
                        "kwargs with a class name, not both")
    key = (spec, bool(jax.config.jax_enable_x64))
    hit = _PROBLEMS.get(key)
    if hit is not None:
        _PROBLEMS.move_to_end(key)
        return hit
    plugin = get_operator_class(spec.cls)
    try:
        prob = plugin.build(**spec.kwargs)
    except TypeError as e:
        raise ScenarioError(
            f"operator class {spec.cls!r} rejected params "
            f"{spec.kwargs!r}: {e}") from None
    if not (isinstance(prob, tuple) and len(prob) == 3):
        raise ScenarioError(
            f"operator class {spec.cls!r} builder must return "
            f"(op, b, x_true); got {type(prob).__name__}")
    _PROBLEMS[key] = prob
    while len(_PROBLEMS) > _PROBLEMS_MAX:
        _PROBLEMS.popitem(last=False)
    return prob


def register_scenario(sc: Union[Scenario, Callable]) -> Scenario:
    """Register one scenario (validating every referenced name).

    Usable directly (``register_scenario(Scenario(...))``) or as a
    decorator on a zero-arg factory::

        @register_scenario
        def _poisson():
            return Scenario("poisson-jacobi", OperatorSpec.of(...), ...)

    Equal-content re-registration is idempotent; a taken name with
    different content raises :class:`ScenarioError`.
    """
    if callable(sc) and not isinstance(sc, Scenario):
        sc = sc()
    if not isinstance(sc, Scenario):
        raise ScenarioError(
            f"register_scenario expects a Scenario (or a factory "
            f"returning one); got {type(sc).__name__}")
    sc.validate()
    if not get_operator_class(sc.operator.cls).mesh_capable \
            and sc.resolved_binding() == "mesh":
        raise ScenarioError(
            f"scenario {sc.name!r}: operator class {sc.operator.cls!r} "
            "is not mesh-capable (the sharded driver needs the "
            "row-sharded stencil halo format)")
    existing = SCENARIOS.get(sc.name)
    if existing is not None:
        if existing == sc:
            return existing
        raise ScenarioError(
            f"scenario name {sc.name!r} already registered with "
            "different content")
    SCENARIOS[sc.name] = sc
    return sc


def get_scenario(name: str) -> Scenario:
    try:
        return SCENARIOS[name]
    except KeyError:
        raise ScenarioError(
            f"unknown scenario {name!r}; registered: "
            f"{', '.join(SCENARIOS) or '(none)'}") from None


def resolve_scenario(sc: Union[str, Scenario]) -> Scenario:
    """Name -> registered scenario; a Scenario instance passes through
    (validated), so ad-hoc unregistered scenarios work everywhere a
    name does."""
    if isinstance(sc, str):
        return get_scenario(sc)
    if isinstance(sc, Scenario):
        return sc.validate()
    raise ScenarioError(
        f"expected a scenario name or Scenario; got {type(sc).__name__}")


def scenarios(quick: Optional[bool] = None,
              tags: Optional[Tuple[str, ...]] = None) -> List[Scenario]:
    """Registered scenarios in registration order, optionally filtered
    to quick cells and/or to those carrying any of ``tags``."""
    out = list(SCENARIOS.values())
    if quick:
        out = [s for s in out if s.quick]
    if tags:
        want = set(tags)
        out = [s for s in out if want & set(s.tags)]
    return out


def scenario_names() -> List[str]:
    return list(SCENARIOS)
