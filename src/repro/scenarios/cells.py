"""The contract-audit cell list, derived from the registries.

``repro.analysis``'s :func:`~repro.analysis.audit.audit_specs` delegates
here: the audit's cell list is the dense acceptance matrix (every
method x substrate x guard x precond + the open-loop chunk — unchanged,
so the expected-outcome matrix and its negative controls stay anchored)
PLUS one contract row per registered scenario.  Registering a scenario
is therefore sufficient to put its exact binding coordinates — operator
class included — under the paper's communication contracts in CI, with
the plugin's ``contract_overrides`` merged over the expected matrix.

Imports of :mod:`repro.analysis` stay lazy (the audit imports this
module lazily too; neither package costs the other at import time).
"""
from __future__ import annotations

from typing import List

from .registry import scenarios

__all__ = ["matrix_cells", "scenario_cells", "contract_cells"]


def matrix_cells(quick: bool = False) -> List[dict]:
    """The dense acceptance matrix (identical in quick and full mode:
    7 methods x 2 substrates x guard x precond + open-loop); full mode
    widens the preconditioner axis to the kernel-dispatching ones."""
    from repro.analysis.audit import METHOD_ORDER, SUBSTRATE_ORDER
    preconds = (None, "jacobi") if quick \
        else (None, "jacobi", "ssor", "block_jacobi")
    cells: List[dict] = []
    for method in METHOD_ORDER:
        binding = "batched" if method == "p-bicgsafe" else "single"
        for substrate in SUBSTRATE_ORDER:
            for guard in (False, True):
                for precond in preconds:
                    cells.append(dict(method=method, binding=binding,
                                      substrate=substrate, guard=guard,
                                      precond=precond))
    # the service's open-loop chunk program (p-BiCGSafe only)
    for substrate in SUBSTRATE_ORDER:
        for guard in (False, True):
            cells.append(dict(method="p-bicgsafe", binding="open_loop",
                              substrate=substrate, guard=guard,
                              precond=None))
    return cells


def scenario_cells(quick: bool = False) -> List[dict]:
    """One audit cell per registered scenario (quick mode keeps the
    quick-flagged ones).  Mesh-binding scenarios are excluded — the
    audit's mesh smoke owns the sharded cells, whose operator extents
    must match the live device count."""
    return [sc.contract_cell() for sc in scenarios(quick=quick)
            if sc.resolved_binding() != "mesh"]


def contract_cells(quick: bool = False) -> List[dict]:
    """Everything the audit traces (minus the mesh smoke): the dense
    acceptance matrix, then the per-scenario rows."""
    return matrix_cells(quick=quick) + scenario_cells(quick=quick)
