"""``python -m repro.scenarios`` — the scenario CLI.

``sweep`` materializes a registered subset of the scenario matrix into
solver sessions, runs every cell, verifies solutions against the
operator plugins' oracles, statically checks the communication
contracts, and writes ONE consolidated artifact
(``experiments/scenario_sweep.json`` — the CI ``scenario-sweep`` job).
``list`` prints the registry.

Scenario/registry errors exit with a one-line message (exit code 2),
never a traceback.
"""
import argparse
import sys


def main(argv=None):
    ap = argparse.ArgumentParser(prog="python -m repro.scenarios")
    sub = ap.add_subparsers(dest="cmd", required=True)

    sweep_p = sub.add_parser(
        "sweep", help="run a subset of the scenario matrix and emit "
        "one consolidated artifact")
    sweep_p.add_argument("--quick", action="store_true",
                         help="CI-sized subset (quick-flagged scenarios)")
    sweep_p.add_argument("--only", default=None,
                         help="comma-separated scenario names")
    sweep_p.add_argument("--tags", default=None,
                         help="comma-separated tag filter")
    sweep_p.add_argument("--out", default=None,
                         help="artifact path (default: "
                         "experiments/scenario_sweep.json)")
    sweep_p.add_argument("--no-contracts", action="store_true",
                         help="skip the static contract checks")
    sweep_p.add_argument("--scenarios", default=None, metavar="FILE",
                         help="JSON file with extra scenario dicts to "
                         "register before sweeping")

    sub.add_parser("list", help="print registered scenarios and "
                   "operator classes")
    args = ap.parse_args(argv)

    from repro.scenarios import (ScenarioError, get_operator_class,
                                 operator_class_names, scenarios)

    try:
        if args.cmd == "list":
            print("registered scenarios:")
            for sc in scenarios():
                print(f"  {sc.name:<28} {sc.operator}  "
                      f"method={sc.method} substrate={sc.substrate} "
                      f"precond={sc.precond} batch={sc.batch}"
                      f"{'' if sc.quick else '  [full]'}")
            print("\noperator classes:")
            for name in operator_class_names():
                print(f"  {name:<22} {get_operator_class(name).description}")
            return 0

        if args.scenarios:
            _register_file(args.scenarios)
        from repro.scenarios.sweep import (DEFAULT_OUT, run_sweep,
                                           sweep_table, write_artifact)
        art = run_sweep(
            quick=args.quick,
            only=args.only.split(",") if args.only else None,
            tags=args.tags.split(",") if args.tags else None,
            contracts=not args.no_contracts)
        out = write_artifact(art, args.out or DEFAULT_OUT)
        print(sweep_table(art))
        print(f"\nartifact: {out}")
        ok = art["claims"]["all_oracle_ok"] and \
            art["claims"]["all_contracts_ok"]
        return 0 if ok else 1
    except ScenarioError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2


def _register_file(path: str) -> None:
    import json

    from repro.scenarios import Scenario, ScenarioError, register_scenario
    try:
        with open(path) as f:
            entries = json.load(f)
    except OSError as e:
        raise ScenarioError(f"cannot read scenario file {path!r}: {e}") \
            from None
    except json.JSONDecodeError as e:
        raise ScenarioError(
            f"scenario file {path!r} is not valid JSON: {e}") from None
    if isinstance(entries, dict):
        entries = [entries]
    for d in entries:
        register_scenario(Scenario.from_dict(d))


if __name__ == "__main__":
    sys.exit(main())
