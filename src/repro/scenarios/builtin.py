"""The seed operator classes, registered from :mod:`repro.core.matrices`.

ONE definition per problem family: the benchmarks
(``bench_convergence``, ``bench_precond``, ``bench_multirhs``) and the
substrate-parity tests build their operators through these plugins
(:func:`repro.scenarios.build_problem`) instead of each importing and
parameterizing the generators themselves.  The generators stay where
they are — these plugins are the registry's (cached, spec-addressed)
view onto them.

All seed classes satisfy the paper's expected contract matrix as-is
(``contract_overrides`` empty); the stencil families are mesh-capable
(the row-sharded halo format).
"""
from __future__ import annotations

from .registry import register_operator_class


def _m():
    from repro.core import matrices
    return matrices


@register_operator_class("poisson3d", mesh_capable=True,
                         description="SPD 7-point Laplacian (poisson3Db "
                         "kind)")
def _poisson3d(**kw):
    return _m().poisson3d(**kw)


@register_operator_class("convection_diffusion", mesh_capable=True,
                         description="non-symmetric convection-diffusion "
                         "stencil (atmosmodd kind)")
def _convection_diffusion(**kw):
    return _m().convection_diffusion(**kw)


@register_operator_class("anisotropic3d", mesh_capable=True,
                         description="badly scaled SPD stencil "
                         "(s3dkq4m2 kind)")
def _anisotropic3d(**kw):
    return _m().anisotropic3d(**kw)


@register_operator_class("random_nonsym",
                         description="random sparse non-symmetric "
                         "CSR/ELL (xenon2 kind)")
def _random_nonsym(**kw):
    return _m().random_nonsym(**kw)


@register_operator_class("hard_nonsym",
                         description="ill-conditioned non-symmetric "
                         "dense (sherman3 kind, paper §5.2)")
def _hard_nonsym(**kw):
    return _m().hard_nonsym(**kw)


@register_operator_class("spd_dense",
                         description="small dense SPD with prescribed "
                         "condition number")
def _spd_dense(**kw):
    return _m().spd_dense(**kw)


@register_operator_class("nonsym_dense",
                         description="small dense non-symmetric, "
                         "well-conditioned")
def _nonsym_dense(**kw):
    return _m().nonsym_dense(**kw)
