"""One-command matrix sweep: materialize, run, verify, consolidate.

``run_sweep`` takes a subset of the registered scenarios and, per cell:

1. builds the operator through its plugin (cached per spec content),
2. binds the session via :func:`repro.api.make_solver` (the PR-5
   content-keyed cache — scenarios sharing an operator share programs),
3. runs the solve through the binding the scenario declares (single /
   batched / open-loop chunks / sharded mesh),
4. judges the solution with the plugin's verification oracle
   (true-residual recomputation by default; e.g. the complex-residual
   check for the Helmholtz class),
5. statically traces the cell through the :mod:`repro.analysis`
   contract passes and compares against the expected-outcome matrix
   (with the plugin's declared deltas merged in).

The result is ONE consolidated, schema-stamped artifact
(``experiments/scenario_sweep.json``) whose claims the perf-trajectory
gate regresses (benchmarks/run.py registers cell counts and pass/fail
claims as gated metrics; wall clock is watch-only).
"""
from __future__ import annotations

import json
import time
from datetime import datetime, timezone
from typing import List, Optional, Sequence

from .registry import build_problem, get_operator_class, resolve_scenario
from .registry import scenarios as registered_scenarios
from .types import Scenario, ScenarioError

__all__ = ["run_sweep", "write_artifact", "sweep_table",
           "ARTIFACT_SCHEMA", "DEFAULT_OUT"]

ARTIFACT_SCHEMA = "repro.scenarios/scenario_sweep/v1"
DEFAULT_OUT = "experiments/scenario_sweep.json"


def _rhs_block(b, m: int):
    """Column 0 is the unit-solution rhs (the oracle's x_true anchor);
    the rest are seeded random vectors (the bench_multirhs protocol)."""
    import jax
    import jax.numpy as jnp
    if m == 1:
        return jnp.asarray(b)[:, None]
    keys = jax.random.split(jax.random.PRNGKey(7), m)
    cols = [b] + [jax.random.normal(k, b.shape, b.dtype)
                  for k in keys[1:]]
    return jnp.stack(cols, axis=1)


def _build_mesh():
    import jax
    import numpy as np
    from jax.sharding import Mesh
    devs = jax.devices()
    return Mesh(np.array(devs).reshape(len(devs)), ("x",))


def _solve_cell(sc: Scenario, problem):
    """Bind and run one scenario; returns (X, B, result) with X/B
    normalized to (n, m) numpy arrays."""
    import jax
    import numpy as np
    op, b, _ = problem
    binding = sc.resolved_binding()
    solver = sc.bind()
    if binding == "single":
        res = solver.solve(b)
        X = np.asarray(res.x)[:, None]
        B = np.asarray(b)[:, None]
    elif binding == "batched":
        B_dev = _rhs_block(b, sc.batch)
        res = solver.solve_many(B_dev)
        X, B = np.asarray(res.x), np.asarray(B_dev)
    elif binding == "open_loop":
        B_dev = _rhs_block(b, sc.batch)
        st = solver.init(B_dev)
        st = solver.step_chunk(st, sc.maxiter)
        res = solver.result(st)
        X, B = np.asarray(res.x), np.asarray(B_dev)
    elif binding == "mesh":
        grid = (op.nx, op.ny, op.nz)
        dist = solver.on_mesh(_build_mesh())
        res = dist.solve(b.reshape(grid))
        X = np.asarray(res.x).reshape(-1)[:, None]
        B = np.asarray(b)[:, None]
    else:                               # pragma: no cover - validated
        raise ScenarioError(f"unhandled binding {binding!r}")
    jax.block_until_ready(res.x)
    return X, B, res


def _check_contracts(sc: Scenario, problem, mesh=None) -> dict:
    """Trace this cell through the contract passes and diff against the
    expected-outcome matrix + the plugin's declared deltas."""
    from repro.analysis import run_passes, trace_binding
    from repro.analysis.audit import expected_outcomes
    cell = sc.contract_cell()
    if cell["binding"] == "mesh" and mesh is None:
        mesh = _build_mesh()
    tb = trace_binding(cell["method"], problem[0],
                       binding=cell["binding"],
                       substrate=cell["substrate"], guard=cell["guard"],
                       precond=cell["precond"], m=3, mesh=mesh)
    rep = run_passes(tb)
    exp = expected_outcomes(tb.spec)
    exp.update(cell["expected"])
    deviations = [
        {"contract": f.contract, "expected": exp[f.contract],
         "actual": f.status, "detail": f.detail}
        for f in rep.findings
        if f.contract in exp and f.status != exp[f.contract]]
    return {"ok": not deviations, "deviations": deviations}


def run_cell(sc: Scenario, contracts: bool = True) -> dict:
    """Run ONE scenario end to end; returns its artifact record."""
    import numpy as np
    sc = resolve_scenario(sc)
    plugin = get_operator_class(sc.operator.cls)
    problem = build_problem(sc.operator)
    t0 = time.perf_counter()
    X, B, res = _solve_cell(sc, problem)
    wall_ms = (time.perf_counter() - t0) * 1e3
    oracle = plugin.oracle(problem, B, X, sc.tol)
    rec = {
        "scenario": sc.name,
        "operator": sc.operator.to_dict(),
        "method": sc.method, "substrate": sc.substrate,
        "precond": sc.precond, "binding": sc.resolved_binding(),
        "guard": bool(sc.guard), "recovery": bool(sc.recovery),
        "tags": list(sc.tags),
        "n": int(problem[0].shape[0]), "m": int(X.shape[1]),
        "converged": bool(np.asarray(res.converged).all()),
        "iterations": int(np.asarray(res.iterations).max()),
        "oracle": oracle,
        "wall_ms": round(wall_ms, 2),
    }
    if contracts:
        rec["contracts"] = _check_contracts(sc, problem)
    return rec


def run_sweep(quick: bool = False,
              only: Optional[Sequence[str]] = None,
              tags: Optional[Sequence[str]] = None,
              contracts: bool = True,
              select: Optional[List[Scenario]] = None) -> dict:
    """Sweep a registered subset of the matrix into one artifact dict.

    ``only`` selects scenarios by name (unknown names raise
    :class:`ScenarioError` with the registered list), ``tags`` filters
    by tag, ``quick`` keeps the CI-sized cells; ``select`` bypasses the
    registry with an explicit scenario list.
    """
    import jax
    jax.config.update("jax_enable_x64", True)

    if select is not None:
        chosen = [resolve_scenario(s) for s in select]
    elif only:
        chosen = [resolve_scenario(name) for name in only]
    else:
        chosen = registered_scenarios(
            quick=quick, tags=tuple(tags) if tags else None)
    if not chosen:
        raise ScenarioError("no scenarios selected (registry empty or "
                            "filters matched nothing)")

    t0 = time.perf_counter()
    cells = [run_cell(sc, contracts=contracts) for sc in chosen]
    wall_s = time.perf_counter() - t0

    n_oracle_ok = sum(c["oracle"]["ok"] for c in cells)
    n_contracts_ok = sum(c.get("contracts", {}).get("ok", True)
                         for c in cells)
    art = {
        "schema": ARTIFACT_SCHEMA,
        "generated_at": datetime.now(timezone.utc).isoformat(),
        "jax_version": jax.__version__,
        "quick": bool(quick),
        "n_devices": len(jax.devices()),
        "contracts_checked": bool(contracts),
        "summary": {
            "n_cells": len(cells),
            "n_converged": sum(c["converged"] for c in cells),
            "n_oracle_ok": n_oracle_ok,
            "n_contracts_ok": n_contracts_ok,
            "wall_s": round(wall_s, 2),
        },
        "claims": {
            "all_converged": all(c["converged"] for c in cells),
            "all_oracle_ok": n_oracle_ok == len(cells),
            "all_contracts_ok": n_contracts_ok == len(cells),
        },
        "cells": cells,
    }
    return art


def write_artifact(art: dict, out: str = DEFAULT_OUT) -> str:
    import os
    os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
    with open(out, "w") as f:
        json.dump(art, f, indent=1, sort_keys=True)
        f.write("\n")
    return out


def sweep_table(art: dict) -> str:
    """Human summary of one sweep artifact."""
    headers = ["scenario", "operator", "method", "sub", "pc", "m",
               "iters", "conv", "oracle", "contracts", "ms"]
    rows = []
    for c in art["cells"]:
        rows.append([
            c["scenario"], c["operator"]["cls"], c["method"],
            c["substrate"], c["precond"] or "-", c["m"],
            c["iterations"], "y" if c["converged"] else "N",
            "ok" if c["oracle"]["ok"] else "FAIL",
            ("ok" if c["contracts"]["ok"] else "DEVIATION")
            if "contracts" in c else "-",
            c["wall_ms"],
        ])
    widths = [max(len(str(h)), *(len(str(r[i])) for r in rows))
              for i, h in enumerate(headers)]
    lines = ["  ".join(str(h).ljust(w) for h, w in zip(headers, widths)),
             "  ".join("-" * w for w in widths)]
    lines += ["  ".join(str(v).ljust(w) for v, w in zip(r, widths))
              for r in rows]
    s = art["summary"]
    lines.append("")
    lines.append(f"{s['n_cells']} cells: {s['n_converged']} converged, "
                 f"{s['n_oracle_ok']} oracle-verified, "
                 f"{s['n_contracts_ok']} contract-clean "
                 f"({s['wall_s']}s)")
    return "\n".join(lines)
