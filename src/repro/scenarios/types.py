"""Scenario vocabulary: frozen, composable, losslessly serializable.

A :class:`Scenario` is ONE cell of the regression matrix the repo must
hold — operator class x method x substrate x precond x guard/recovery x
batch shape x binding — written down as data instead of hand-rolled in
each benchmark.  Cells are hashable value objects: two scenarios with
equal content compare equal, and ``Scenario.bind()`` routes through
:func:`repro.api.make_solver`'s content-keyed session cache, so binding
the same scenario twice returns the SAME session (no retrace, no
preconditioner rebuild).

Serialization is a contract: ``from_dict(to_dict(sc)) == sc`` exactly
(tests/test_scenarios.py pins it), so scenario files shipped to the
audit CLI (``python -m repro.analysis audit --scenarios FILE``) and
artifacts that embed scenario specs round-trip without drift.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, Optional, Tuple

__all__ = ["ScenarioError", "OperatorSpec", "Scenario", "BINDINGS"]

#: binding kinds a scenario may request; "auto" resolves to "batched"
#: when batch > 1 else "single" (mirrors repro.analysis.trace)
BINDINGS = ("auto", "single", "batched", "open_loop", "mesh")

#: JSON-representable scalar types allowed as operator params — the
#: spec must survive a JSON round-trip byte-for-byte
_SCALARS = (bool, int, float, str)


class ScenarioError(ValueError):
    """A scenario or operator-class registration/lookup problem, with a
    message meant for humans at the CLI (never a traceback)."""


@dataclasses.dataclass(frozen=True)
class OperatorSpec:
    """One operator-class invocation: plugin name + builder kwargs.

    ``params`` is a sorted tuple of (key, value) pairs so the spec is
    hashable and order-insensitive; :meth:`of` is the ergonomic
    constructor (``OperatorSpec.of("poisson3d", nx=8)``).
    """

    cls: str
    params: Tuple[Tuple[str, Any], ...] = ()

    @classmethod
    def of(cls_, cls: str, **params) -> "OperatorSpec":
        for k, v in params.items():
            if not isinstance(v, _SCALARS):
                raise ScenarioError(
                    f"operator param {k}={v!r} of class {cls!r} is not a "
                    "JSON scalar (bool/int/float/str); scenario specs "
                    "must round-trip through JSON")
        return cls_(cls, tuple(sorted(params.items())))

    @property
    def kwargs(self) -> Dict[str, Any]:
        return dict(self.params)

    def to_dict(self) -> dict:
        return {"cls": self.cls, "params": self.kwargs}

    @classmethod
    def from_dict(cls_, d: dict) -> "OperatorSpec":
        if not isinstance(d, dict) or "cls" not in d:
            raise ScenarioError(
                f"operator spec must be a dict with a 'cls' key; got {d!r}")
        unknown = set(d) - {"cls", "params"}
        if unknown:
            raise ScenarioError(
                f"unknown operator-spec keys {sorted(unknown)} "
                f"(expected 'cls' and optional 'params')")
        return cls_.of(d["cls"], **(d.get("params") or {}))

    def __str__(self):
        kw = ", ".join(f"{k}={v!r}" for k, v in self.params)
        return f"{self.cls}({kw})"


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One regression cell, declaratively.

    Fields mirror the knobs of :func:`repro.api.make_solver` plus the
    run shape (``batch``, ``binding``) and sweep metadata (``tags``,
    ``quick``).  Construction is cheap and validation-free;
    :meth:`validate` (run at registration and before ``bind``) checks
    every name against the live registries and raises
    :class:`ScenarioError` with the valid choices spelled out.
    """

    name: str
    operator: OperatorSpec
    method: str = "p-bicgsafe"
    substrate: str = "jnp"
    precond: Optional[str] = None
    guard: bool = False
    recovery: bool = False
    tol: float = 1e-8
    maxiter: int = 2000
    batch: int = 1
    binding: str = "auto"
    trace: bool = False
    tags: Tuple[str, ...] = ()
    #: include in ``--quick`` sweeps / the quick contract audit
    quick: bool = True

    # -- resolution -------------------------------------------------------

    def resolved_binding(self) -> str:
        if self.binding != "auto":
            return self.binding
        return "batched" if self.batch > 1 else "single"

    def validate(self) -> "Scenario":
        """Check every name against the live registries (operator
        classes, solvers, substrates, preconditioners); raises
        :class:`ScenarioError` naming the valid choices."""
        from repro.core import SOLVERS
        from repro.core.substrate import SUBSTRATES
        from repro.precond.base import PRECONDITIONERS

        from .registry import operator_class_names
        if not self.name or not isinstance(self.name, str):
            raise ScenarioError(f"scenario needs a non-empty name; "
                                f"got {self.name!r}")
        if self.operator.cls not in operator_class_names():
            raise ScenarioError(
                f"scenario {self.name!r} names unregistered operator "
                f"class {self.operator.cls!r}; registered classes: "
                f"{', '.join(operator_class_names())}")
        if self.method not in SOLVERS:
            raise ScenarioError(
                f"scenario {self.name!r} names unknown method "
                f"{self.method!r}; expected one of {sorted(SOLVERS)}")
        if self.substrate not in SUBSTRATES:
            raise ScenarioError(
                f"scenario {self.name!r} names unknown substrate "
                f"{self.substrate!r}; expected one of {sorted(SUBSTRATES)}")
        if self.precond is not None and self.precond not in PRECONDITIONERS:
            raise ScenarioError(
                f"scenario {self.name!r} names unknown precond "
                f"{self.precond!r}; expected one of "
                f"{sorted(PRECONDITIONERS)} or null")
        if self.binding not in BINDINGS:
            raise ScenarioError(
                f"scenario {self.name!r}: unknown binding "
                f"{self.binding!r}; expected one of {BINDINGS}")
        if self.batch < 1:
            raise ScenarioError(
                f"scenario {self.name!r}: batch must be >= 1")
        if self.resolved_binding() in ("batched", "open_loop") \
                and self.method != "p-bicgsafe":
            raise ScenarioError(
                f"scenario {self.name!r}: binding "
                f"{self.resolved_binding()!r} runs the batched "
                "p-BiCGSafe iteration only; bind method 'p-bicgsafe' "
                "or use binding 'single'")
        if (self.guard or self.recovery) and self.method != "p-bicgsafe":
            raise ScenarioError(
                f"scenario {self.name!r}: guard/recovery ride the "
                "batched p-BiCGSafe iteration only")
        return self

    # -- materialization --------------------------------------------------

    def config(self):
        """The bound :class:`repro.core.SolverConfig` for this cell."""
        from repro.core import SolverConfig
        return SolverConfig(tol=self.tol, maxiter=self.maxiter,
                            guard=self.guard)

    def problem(self):
        """Build (cached) ``(op, b, x_true)`` via the operator plugin."""
        from .registry import build_problem
        return build_problem(self.operator)

    def bind(self):
        """Materialize the session via :func:`repro.api.make_solver`.

        The built operator is cached per spec content, so repeat binds
        of the same scenario hand make_solver the SAME operator object
        and hit the PR-5 session cache — no retrace, no preconditioner
        rebuild.  ``recovery=True`` scenarios return the
        :class:`repro.resilience.GuardedSolver` wrapper (the session
        underneath is still cached by content).
        """
        from repro.api import make_solver
        self.validate()
        op, _, _ = self.problem()
        return make_solver(self.method, op, precond=self.precond,
                           substrate=self.substrate, config=self.config(),
                           recovery=True if self.recovery else None)

    def contract_cell(self) -> dict:
        """This scenario as one `repro.analysis` audit cell: the
        trace_binding coordinates plus the operator spec and the
        plugin's expected-outcome overrides."""
        from .registry import get_operator_class
        plugin = get_operator_class(self.operator.cls)
        return dict(method=self.method, binding=self.resolved_binding(),
                    substrate=self.substrate, guard=self.guard,
                    precond=self.precond, scenario=self.name,
                    operator_class=self.operator.cls,
                    operator_params=self.operator.kwargs,
                    expected=dict(plugin.contract_overrides))

    # -- serialization ----------------------------------------------------

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["operator"] = self.operator.to_dict()
        d["tags"] = list(self.tags)
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "Scenario":
        if not isinstance(d, dict):
            raise ScenarioError(f"scenario must be a dict; got {d!r}")
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ScenarioError(
                f"unknown scenario keys {sorted(unknown)}; expected a "
                f"subset of {sorted(known)}")
        missing = {"name", "operator"} - set(d)
        if missing:
            raise ScenarioError(
                f"scenario is missing required keys {sorted(missing)}")
        kw = dict(d)
        kw["operator"] = OperatorSpec.from_dict(d["operator"])
        kw["tags"] = tuple(d.get("tags") or ())
        return cls(**kw)

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), sort_keys=True)

    @classmethod
    def from_json(cls, s: str) -> "Scenario":
        try:
            d = json.loads(s)
        except json.JSONDecodeError as e:
            raise ScenarioError(f"scenario JSON does not parse: {e}") \
                from None
        return cls.from_dict(d)
