"""repro.scenarios — the declarative scenario registry + matrix sweep.

The regression surface of this repo is a matrix: operator class x
method x substrate x precond x guard/recovery x batch x binding.  This
package writes the cells down as data:

    from repro.scenarios import Scenario, OperatorSpec, register_scenario

    register_scenario(Scenario(
        "poisson-jacobi", OperatorSpec.of("poisson3d", nx=8),
        precond="jacobi"))

    solver = repro.make_solver(scenario="poisson-jacobi")   # cached session
    x = solver.solve(b)

One registration buys three things:

* a session: ``Scenario.bind()`` / ``make_solver(scenario=...)``
  materializes the cell through the PR-5 content-keyed cache;
* a contract row: ``repro.analysis audit`` derives its cell list from
  this registry, so every scenario is statically held to the paper's
  communication invariants in CI (plugins may declare expected-outcome
  deltas);
* a sweep cell: ``python -m repro.scenarios sweep`` runs the subset and
  emits ONE consolidated ``experiments/scenario_sweep.json`` the
  trajectory gate regresses.

Operator classes are **plugins** (builder + verification oracle +
expected contract outcomes): :mod:`~repro.scenarios.builtin` registers
the seed generators, and :mod:`~repro.scenarios.helmholtz` registers a
complex-shifted Helmholtz class entirely from the outside — no edits
under ``src/repro/core/``.
"""
from . import builtin as _builtin          # registers the seed classes
from . import helmholtz as _helmholtz      # the plugin-proof class
from . import seeds as _seeds              # registers the seed scenarios
from .helmholtz import HelmholtzShiftedOperator
from .registry import (OPERATOR_CLASSES, SCENARIOS, OperatorPlugin,
                       build_problem, default_oracle, get_operator_class,
                       get_scenario, operator_class_names,
                       register_operator_class, register_scenario,
                       resolve_scenario, scenario_names, scenarios)
from .types import BINDINGS, OperatorSpec, Scenario, ScenarioError

__all__ = [
    "Scenario", "OperatorSpec", "ScenarioError", "BINDINGS",
    "OperatorPlugin", "HelmholtzShiftedOperator",
    "register_scenario", "register_operator_class",
    "get_scenario", "get_operator_class", "resolve_scenario",
    "scenarios", "scenario_names", "operator_class_names",
    "build_problem", "default_oracle",
    "SCENARIOS", "OPERATOR_CLASSES",
    "contract_cells", "run_sweep",
]

del _builtin, _helmholtz, _seeds


def contract_cells(quick: bool = False):
    """Audit cells (dense matrix + per-scenario rows); see
    :mod:`repro.scenarios.cells`."""
    from .cells import contract_cells as _cc
    return _cc(quick=quick)


def run_sweep(quick: bool = False, **kw):
    """Run the matrix sweep; see :mod:`repro.scenarios.sweep` (lazy —
    importing the registry must not pull the runner/analysis stack)."""
    from .sweep import run_sweep as _rs
    return _rs(quick=quick, **kw)
