"""The registered seed scenarios — the matrix subset CI regresses.

Each registration is one cell of the operator-class x method x
substrate x precond x guard x batch matrix; the quick flag marks the
CI-sized subset (``sweep --quick`` / the quick contract audit).  The
full set adds the larger problems the committed
``experiments/scenario_sweep.json`` artifact pins for the trajectory
gate.

Naming: ``<operator>-<distinguishing axis>``.
"""
from __future__ import annotations

from .registry import register_scenario
from .types import OperatorSpec, Scenario

_CONVDIFF8 = OperatorSpec.of("convection_diffusion", nx=8, peclet=1.0)

# -- the paper's method over the seed operator classes ---------------------

register_scenario(Scenario(
    "convdiff-baseline", _CONVDIFF8, tags=("core", "convergence")))

register_scenario(Scenario(
    "convdiff-multirhs-pallas", _CONVDIFF8, substrate="pallas", batch=4,
    tags=("core", "kernels", "multirhs")))

register_scenario(Scenario(
    "convdiff-guarded", _CONVDIFF8, guard=True, batch=3,
    tags=("resilience",)))

register_scenario(Scenario(
    "convdiff-recovery", _CONVDIFF8, recovery=True,
    tags=("resilience",)))

register_scenario(Scenario(
    "convdiff-openloop", _CONVDIFF8, binding="open_loop", batch=3,
    tags=("service",)))

register_scenario(Scenario(
    "poisson-jacobi", OperatorSpec.of("poisson3d", nx=8),
    precond="jacobi", tags=("core", "precond")))

register_scenario(Scenario(
    "aniso-block-jacobi", OperatorSpec.of("anisotropic3d", nx=8, eps=1e-2),
    precond="block_jacobi", tags=("precond",)))

register_scenario(Scenario(
    "hard-block-jacobi", OperatorSpec.of("hard_nonsym", n=300),
    precond="block_jacobi", maxiter=3000, tags=("precond", "hard")))

register_scenario(Scenario(
    "random-csr-rr", OperatorSpec.of("random_nonsym", n=2000,
                                     nnz_per_row=8, seed=5),
    method="p-bicgsafe-rr", tags=("core",)))

# -- negative controls: the baselines the contract audit must FAIL --------

register_scenario(Scenario(
    "ssbicgsafe2-baseline", _CONVDIFF8, method="ssbicgsafe2",
    tags=("baseline",)))

register_scenario(Scenario(
    "bicgstab-baseline", _CONVDIFF8, method="bicgstab",
    tags=("baseline",)))

# -- the plugin-registered operator class (no core edits) ------------------

register_scenario(Scenario(
    "helmholtz-shifted", OperatorSpec.of("helmholtz_shifted", nx=8),
    maxiter=4000, tags=("helmholtz", "plugin")))

register_scenario(Scenario(
    "helmholtz-jacobi", OperatorSpec.of("helmholtz_shifted", nx=8),
    precond="jacobi", maxiter=4000, tags=("helmholtz", "plugin",
                                          "precond")))

register_scenario(Scenario(
    "helmholtz-multirhs-pallas",
    OperatorSpec.of("helmholtz_shifted", nx=6), substrate="pallas",
    batch=2, maxiter=4000, tags=("helmholtz", "plugin", "kernels")))

# -- full-sweep-only cells (committed artifact; not CI --quick) ------------

register_scenario(Scenario(
    "poisson-mesh", OperatorSpec.of("poisson3d", nx=8, ny=6, nz=6),
    binding="mesh", quick=False, tags=("distributed",)))

register_scenario(Scenario(
    "convdiff-16-multirhs", OperatorSpec.of("convection_diffusion",
                                            nx=16, peclet=1.0),
    batch=8, quick=False, tags=("multirhs",)))

register_scenario(Scenario(
    "random-20k", OperatorSpec.of("random_nonsym", n=20_000,
                                  nnz_per_row=9, seed=5,
                                  diag_dominance=1.02),
    maxiter=5000, quick=False, tags=("convergence",)))
