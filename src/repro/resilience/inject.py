"""Fault-injection harness for chaos-testing guarded solves.

Deterministic, host-controlled faults for tests and
``benchmarks/bench_robustness.py``:

* :class:`ChunkFaultInjector` — the GuardedSolver's test hook: NaN
  insertion into chosen columns of the live state, and simulated
  kernel-level failures, fired at chosen chunk boundaries (exact,
  repeatable — no randomness on the device path).
* :func:`nan_columns` — poison chosen columns of a state field.
* :func:`near_singular_dense` — a Dense operator with a controlled
  smallest singular value (drives genuine numerical breakdowns).
* :func:`orthogonal_shadow` — a shadow residual r0* orthogonal to r0
  (zero initial rho: the classic BREAKDOWN_RHO scenario).
* :class:`TickingClock` — virtual monotonic clock for deadline-pressure
  tests against :mod:`repro.service` without wall-clock sleeps.
* :func:`corrupt_engine_block` — poke NaN into columns of a service
  engine's resident block, mid-flight.

Injection here simulates the *effects* of real faults (memory
corruption surfacing as NaN, a kernel launch failure surfacing as an
exception) at the state level, so the recovery machinery — not the
fault transport — is what gets exercised.
"""
from __future__ import annotations

from typing import Dict, Iterable, Optional, Sequence

import jax.numpy as jnp
import numpy as np


class SimulatedKernelFailure(RuntimeError):
    """Stand-in for a kernel-level launch/execution failure.

    Raised by :class:`ChunkFaultInjector` before a chosen chunk; the
    GuardedSolver's substrate-degradation path treats it exactly like a
    real Pallas failure (rebuild on ``"jnp"``, continue from the same
    state).
    """


def nan_columns(state: dict, cols: Sequence[int],
                field: str = "r") -> dict:
    """Return ``state`` with NaN written into ``cols`` of ``field``.

    The canonical corruption model: a poisoned residual column.  The
    guarded (11, m) reduction's finiteness probe detects it on the next
    iteration without any extra synchronization.
    """
    arr = state[field]
    mask = np.zeros((arr.shape[-1],), bool)
    mask[list(cols)] = True
    out = dict(state)
    out[field] = jnp.where(jnp.asarray(mask)[None, :], jnp.nan, arr)
    return out


class ChunkFaultInjector:
    """Deterministic fault schedule over a guarded solve's chunk loop.

    Args:
      nan_at: ``{chunk_index: columns}`` — before that chunk runs, NaN is
        written into those columns of ``field``.
      fail_at: chunk indices at which a :class:`SimulatedKernelFailure`
        is raised (once each — the retried chunk proceeds).
      field: state field to poison (default the residual ``"r"``).

    Instances are callables ``(chunk_index, state) -> state`` — the
    signature of ``GuardedSolver``'s ``inject`` hook.
    """

    def __init__(self, nan_at: Optional[Dict[int, Sequence[int]]] = None,
                 fail_at: Iterable[int] = (), field: str = "r"):
        self.nan_at = {int(k): tuple(v) for k, v in (nan_at or {}).items()}
        self.fail_at = set(int(k) for k in fail_at)
        self.field = field
        self.fired: list = []

    def __call__(self, chunk_index: int, state: dict) -> dict:
        if chunk_index in self.fail_at:
            self.fail_at.discard(chunk_index)
            self.fired.append(("kernel_failure", chunk_index))
            raise SimulatedKernelFailure(
                f"injected kernel failure at chunk {chunk_index}")
        cols = self.nan_at.pop(chunk_index, None)
        if cols:
            self.fired.append(("nan", chunk_index, cols))
            state = nan_columns(state, cols, self.field)
        return state


def near_singular_dense(n: int, *, sigma_min: float = 1e-14,
                        seed: int = 0, dtype=jnp.float64):
    """A DenseOperator whose smallest singular value is ``sigma_min``.

    Built from a seeded random orthogonal pair U diag(s) V^T with a
    well-spread spectrum [1, 2] except for one tiny singular value —
    conditioning bad enough to drive coefficient denominators under any
    realistic ``breakdown_eps`` while keeping the operator finite.
    """
    from repro.core import DenseOperator
    rng = np.random.default_rng(seed)
    q1, _ = np.linalg.qr(rng.standard_normal((n, n)))
    q2, _ = np.linalg.qr(rng.standard_normal((n, n)))
    s = np.linspace(1.0, 2.0, n)
    s[0] = sigma_min
    a = (q1 * s) @ q2.T
    return DenseOperator(jnp.asarray(a, dtype=dtype))


def orthogonal_shadow(r0) -> jnp.ndarray:
    """A shadow residual r0* exactly* orthogonal to ``r0`` (*up to
    round-off — pair with an explicit ``breakdown_eps`` like 1e-12).

    Zero initial ``rho = (r0*, r0)`` makes the very first beta/alpha
    denominators degenerate: the canonical typed-BREAKDOWN_RHO scenario.
    """
    r0 = jnp.asarray(r0)
    v = jnp.ones_like(r0)
    proj = jnp.vdot(r0, v) / jnp.vdot(r0, r0)
    shadow = v - proj * r0
    # degenerate case (r0 parallel to ones): pick a coordinate swap
    alt = jnp.zeros_like(r0).at[0].set(1.0).at[1].add(-1.0)
    use_alt = jnp.sqrt(jnp.vdot(shadow, shadow)) == 0
    return jnp.where(use_alt, alt, shadow)


# The virtual clock moved to repro.observe.clock when the observe layer
# unified the engine's deadline clock and the span recorder's timestamps
# behind one Clock protocol; re-exported here so existing fault-injection
# imports keep working.
from repro.observe.clock import TickingClock  # noqa: E402,F401


def corrupt_engine_block(engine, operator: str,
                         cols: Sequence[int], field: str = "r") -> None:
    """Poison columns of a service engine's resident block, in place.

    Simulates mid-flight memory corruption inside the serving layer; the
    engine's next chunk must surface NONFINITE for the affected requests
    and scrub the column before reusing the slot (chaos tests in
    tests/test_resilience.py).
    """
    blk = engine._blocks.get(engine.registry[operator].name)
    if blk is None or blk.state is None:
        raise ValueError(f"operator {operator!r} has no resident block")
    blk.state = nan_columns(blk.state, cols, field)
