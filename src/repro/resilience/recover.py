"""Recovery programs over the batched p-BiCGSafe state pytree.

Both transformations are pure jax functions over the guarded state dict
of :mod:`repro.core.multirhs` — :class:`repro.resilience.GuardedSolver`
jits them once per session and applies them at chunk boundaries to the
columns its policy selects.  Both are masked: untouched columns pass
through bit-identical, so recovery on one column never perturbs its
neighbours (the same exactness argument as ``splice_columns``).

``replace_columns`` is the *on-trigger* generalization of
p-BiCGSafe-rr's Alg. 4.1 reset: identical algebra (recompute ``r`` and
every recurred A-image from true matvecs), but fired by the in-flight
Cools / van-der-Vorst–Ye drift bound (state ``drift_flag``) instead of a
fixed ``rr_epoch`` counter.

``restart_columns`` re-seeds the Krylov space from the current iterate
after a typed breakdown: mathematically a fresh solve of
``A x = b`` with ``x0 = x_current`` (non-finite iterates are sanitized
to 0 first — restarting *from* NaN would be re-poisoning).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.multirhs import _guard_init
from repro.core.types import SolveStatus


def _vec(mask, new, old):
    return jnp.where(mask[None, :], new, old)


def _sca(mask, new, old):
    return jnp.where(mask, new, old)


def replace_columns(bmv, state: dict, mask: jax.Array,
                    B: jax.Array) -> dict:
    """On-trigger residual replacement of the masked columns.

    The recurred quantities and their definitional invariants
    (pipelined_bicgsafe Eqns. 3.2/3.7/3.9/3.10):

        r = b - A x,  s = A r,  l = A t,  g = A y,  w = A u

    are all recomputed from true matvecs; the primary recurrence vectors
    ``p, u, t, y, z`` (and ``x``) are exact either way and pass through.
    Costs 5 block matvecs on the full block (frozen columns ride along;
    ONE compiled program for any mask).  Resets the masked columns' drift
    bookkeeping and counts the event in ``replacements``.

    ``B`` is the (preconditioned) right-hand-side block the state was
    initialized from — the state itself does not carry it.
    """
    mask = mask.astype(bool)
    r_true = B.astype(state["r"].dtype) - bmv(state["x"])
    out = dict(state)
    out["r"] = _vec(mask, r_true, state["r"])
    out["s"] = _vec(mask, bmv(r_true), state["s"])
    out["l"] = _vec(mask, bmv(state["t"]), state["l"])
    out["g"] = _vec(mask, bmv(state["y"]), state["g"])
    out["w"] = _vec(mask, bmv(state["u"]), state["w"])
    rdt = state["drift"].dtype
    m = mask.shape[0]
    out["drift"] = _sca(mask, jnp.zeros((m,), rdt), state["drift"])
    out["drift_flag"] = state["drift_flag"] & ~mask
    out["replacements"] = _sca(mask, state["replacements"] + 1,
                               state["replacements"])
    return out


def restart_columns(bmv, state: dict, mask: jax.Array,
                    B: jax.Array) -> dict:
    """Restart the masked columns from their current iterate.

    Equivalent to a fresh guarded solve of those columns with
    ``x0 = x_current`` (non-finite entries sanitized to 0): true residual
    ``r0 = b - A x0`` becomes both the residual and the fresh shadow
    residual ``r0*``, the auxiliary vectors zero out, the coefficient
    carries reset, and the per-column iteration count restarts (the
    driver bounds *total* work host-side).  ``norm_r0`` is kept from the
    original solve so ``relres`` stays comparable across the restart.
    Columns whose restarted residual is already below tolerance are
    marked converged on the spot.  Counts the event in ``restarts``.
    """
    mask = mask.astype(bool)
    m = mask.shape[0]
    dt = state["r"].dtype
    x_safe = jnp.where(jnp.isfinite(state["x"]), state["x"], 0.0)
    r0 = B.astype(dt) - bmv(x_safe)
    # only the masked columns' r0 matters; keep the rest numerically inert
    r0 = jnp.where(mask[None, :], r0, 0.0)
    s0 = bmv(r0)
    norm_new = jnp.sqrt(jnp.sum(r0 * r0, axis=0))
    relres_new = (norm_new / state["norm_r0"]).astype(state["relres"].dtype)
    conv_new = relres_new <= state["tol"]

    zero_b = jnp.zeros_like(state["r"])
    zero_m = jnp.zeros((m,), dt)
    out = dict(state)
    out["x"] = _vec(mask, x_safe, state["x"])
    out["r"] = _vec(mask, r0, state["r"])
    out["s"] = _vec(mask, s0, state["s"])
    out["rs"] = _vec(mask, r0, state["rs"])
    for k in ("p", "u", "t", "y", "z", "w", "l", "g"):
        out[k] = _vec(mask, zero_b, state[k])
    out["alpha"] = _sca(mask, zero_m, state["alpha"])
    out["zeta"] = _sca(mask, jnp.ones((m,), dt), state["zeta"])
    out["f"] = _sca(mask, jnp.ones((m,), dt), state["f"])
    out["iterations"] = _sca(mask, jnp.zeros((m,), jnp.int32),
                             state["iterations"])
    out["relres"] = _sca(mask, relres_new, state["relres"])
    out["converged"] = _sca(mask, conv_new, state["converged"])
    out["breakdown"] = _sca(mask, jnp.zeros((m,), bool),
                            state["breakdown"])

    # _guard_init stamps CONVERGED where conv_new, RUNNING elsewhere —
    # exactly the restart semantics for status too.
    fresh = _guard_init(m, state["drift"].dtype, conv_new)
    restarts = state["restarts"]
    for k in ("status", "drift", "drift_flag", "stall", "best_relres",
              "stagnant"):
        out[k] = _sca(mask, fresh[k], state[k])
    out["restarts"] = _sca(mask, restarts + 1, restarts)
    return out
