"""GuardedSolver: chunked guarded solves with automatic recovery.

The driver that closes the loop between the *device-side* health
monitoring of :mod:`repro.core.multirhs` (``SolverConfig.guard``: the
(9, m) fused reduction widened to (11, m) — same single synchronization
phase, still no dependency edge to the in-flight matvec) and the
*host-side* :class:`~repro.resilience.RecoveryPolicy`:

1. step the guarded state in chunks of ``policy.chunk`` iterations
   through a bound :class:`repro.api.LinearSolver` session,
2. read the (m,) health flags at each chunk boundary (ONE device->host
   transfer, amortized over the chunk),
3. apply the policy: on-trigger residual replacement for drifted
   columns, restart-from-current-x for broken-down / non-finite /
   stagnant columns, substrate degradation (pallas -> jnp, same state
   pytree) after kernel-level failures, and per-column method fallback
   once restarts are exhausted.

Everything the driver does is logged in ``events`` (host-side list of
dicts) and counted in the result state (``replacements`` / ``restarts``
per column), so a recovered solve is auditable.  Clean solves take the
exact unguarded numerical path — the guard rows only *observe* — and pay
only the widened reduction plus one flag read per chunk
(``benchmarks/bench_robustness.py`` pins the overhead).

Construct via ``repro.make_solver(..., recovery=RecoveryPolicy(...))``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import (SolveResult, SolveStatus, SolverConfig,
                              identity_reduce, per_column)
from repro.observe import metrics as _metrics

from .inject import SimulatedKernelFailure
from .policy import RecoveryPolicy
from .recover import replace_columns, restart_columns

#: statuses that restart-from-current-x is allowed to answer
_RESTARTABLE = np.array([SolveStatus.BREAKDOWN.value,
                         SolveStatus.BREAKDOWN_RHO.value,
                         SolveStatus.BREAKDOWN_ALPHA.value,
                         SolveStatus.BREAKDOWN_OMEGA.value,
                         SolveStatus.NONFINITE.value], np.int32)


def _stamp_stagnation(state: dict, mask: jax.Array) -> dict:
    """Freeze columns whose stagnation outlived the restart budget:
    typed STAGNATION, breakdown-frozen so the chunk loop stops burning
    iterations on them."""
    out = dict(state)
    out["breakdown"] = state["breakdown"] | mask
    out["status"] = jnp.where(mask, SolveStatus.STAGNATION.value,
                              state["status"]).astype(jnp.int32)
    return out


class GuardedSolver:
    """A p-BiCGSafe session wrapped with breakdown detection + recovery.

    Duck-types the solve surface of :class:`repro.api.LinearSolver`
    (``solve`` / ``solve_many``); every result carries typed per-column
    :class:`~repro.core.SolveStatus` codes, and ``x`` is guaranteed
    finite (failed columns are sanitized, never NaN).

    Attributes:
      session: the inner guarded session (``config.guard`` is set).
      policy: the bound :class:`RecoveryPolicy`.
      events: host-side audit log — one dict per recovery action
        (replace / restart / substrate_degraded / method_fallback /
        stagnation_giveup), accumulated across solves.
      inject: optional test hook ``(chunk_index, state) -> state`` run
        before each chunk (see :class:`repro.resilience.inject
        .ChunkFaultInjector`); may raise to simulate kernel failures.
    """

    def __init__(self, session, policy: RecoveryPolicy = RecoveryPolicy(),
                 *, inject=None):
        if session.method != "p-bicgsafe":
            raise ValueError(
                "GuardedSolver drives the batched guarded p-BiCGSafe "
                f"iteration (got a {session.method!r} session); "
                "method fallbacks are where other methods come in")
        if not session.config.guard:
            raise ValueError(
                "GuardedSolver needs a guarded session "
                "(SolverConfig.guard=True; make_solver(recovery=...) "
                "sets this up)")
        self.session = session
        self.policy = policy
        self.events: List[Dict[str, Any]] = []
        self.inject = inject
        self._active = session          # degrades to a jnp session on fault
        self._recover_fns: Dict[Any, Any] = {}

    # -- public solve surface ---------------------------------------------

    @property
    def config(self) -> SolverConfig:
        return self.session.config

    def solve(self, b, x0=None, *, tol=None, maxiter=None,
              r0_star=None) -> SolveResult:
        """Guarded single-RHS solve (routed through the m=1 batched
        guarded iteration; scalar-squeezed result)."""
        b = jnp.asarray(b)
        X0 = None if x0 is None else jnp.asarray(x0)[:, None]
        rs = None if r0_star is None else jnp.asarray(r0_star)[:, None]
        res = self.solve_many(b[:, None], X0, tol=tol, maxiter=maxiter,
                              r0_star=rs)
        hist = res.residual_history
        if hist.ndim == 2:
            hist = hist[:, 0]
        trace = res.trace.column(0) if res.trace is not None else None
        return SolveResult(res.x[:, 0], res.iterations[0], res.relres[0],
                           res.converged[0], res.breakdown[0], hist,
                           res.status[0], trace)

    def solve_many(self, B, X0=None, *, tol=None, maxiter=None,
                   r0_star=None) -> SolveResult:
        """Guarded multi-RHS solve with policy-driven recovery.

        The happy path is numerically identical to the unguarded
        ``session.solve_many`` (the health rows read, never write); the
        return differs only in carrying real per-column statuses and in
        surviving faults.
        """
        sess = self.session
        B = sess._as_block(B)
        n, m = B.shape
        cfg = sess.config
        tol_col = np.asarray(per_column(
            cfg.tol if tol is None else tol, m, B.dtype, name="tol"))
        mit_col = np.asarray(per_column(
            cfg.maxiter if maxiter is None else maxiter, m, jnp.int32,
            name="maxiter"))
        state = self._active.init(B, X0, tol=jnp.asarray(tol_col),
                                  maxiter=jnp.asarray(mit_col),
                                  r0_star=r0_star)
        # the (preconditioned) rhs block the recovery programs recompute
        # true residuals against — the state pytree does not carry it
        Bp = self._active._prep(B)

        pol = self.policy
        chunk = pol.chunk
        budget = int(mit_col.max()) if mit_col.size else 0
        # total-work bound: every restart refunds a column's iteration
        # budget, so the chunk loop is capped at (1 + max_restarts)
        # budgets (+1 chunk of slack for boundary effects)
        max_chunks = (1 + pol.max_restarts) * math.ceil(
            max(budget, 1) / chunk) + 1

        ci = 0
        degraded_once = False
        while ci < max_chunks:
            try:
                st = state
                if self.inject is not None:
                    st = self.inject(ci, st)
                state = self._active.step_chunk(st, chunk)
            except (SimulatedKernelFailure, RuntimeError) as exc:
                if degraded_once or not self._degrade(exc, ci):
                    raise
                degraded_once = True
                continue            # retry the same chunk, degraded
            ci += 1

            f = jax.device_get({k: state[k] for k in (
                "status", "converged", "breakdown", "iterations",
                "col_maxiter", "drift_flag", "stagnant",
                "replacements", "restarts")})
            active = (~f["converged"] & ~f["breakdown"]
                      & (f["iterations"] < f["col_maxiter"]))

            need_restart = (np.isin(f["status"], _RESTARTABLE)
                            | (f["stagnant"] & active)) \
                & ~f["converged"] \
                & (f["restarts"] < pol.max_restarts)
            need_replace = f["drift_flag"] & active & ~need_restart \
                & (f["replacements"] < pol.max_replacements)
            give_up = f["stagnant"] & active & ~need_restart

            acted = False
            if need_replace.any():
                state = self._recover("replace", replace_columns)(
                    state, jnp.asarray(need_replace), Bp)
                self._log("replace", ci, need_replace)
                acted = True
            if need_restart.any():
                state = self._recover("restart", restart_columns)(
                    state, jnp.asarray(need_restart), Bp)
                self._log("restart", ci, need_restart)
                acted = True
            if give_up.any():
                state = self._stamp(state, jnp.asarray(give_up))
                self._log("stagnation_giveup", ci, give_up)
                active = active & ~give_up
            if not acted and not active.any():
                break

        res = self._active.result(state)
        return self._finalize(res, state, B, tol_col, mit_col)

    # -- internals --------------------------------------------------------

    def _log(self, event: str, chunk: int, mask_or_info) -> None:
        _metrics.RECOVERY_ACTIONS.inc(action=event)
        info = mask_or_info
        if isinstance(info, np.ndarray):
            info = [int(j) for j in np.nonzero(info)[0]]
            self.events.append(dict(event=event, chunk=chunk, columns=info))
        else:
            self.events.append(dict(event=event, chunk=chunk, detail=info))

    def _recover(self, kind: str, fn):
        key = (kind, self._active.sub.name)
        prog = self._recover_fns.get(key)
        if prog is None:
            bmv = self._active.block_matvec
            prog = self._recover_fns[key] = jax.jit(
                lambda state, mask, Bp: fn(bmv, state, mask, Bp))
        return prog

    def _stamp(self, state, mask):
        prog = self._recover_fns.get("stamp")
        if prog is None:
            prog = self._recover_fns["stamp"] = jax.jit(_stamp_stagnation)
        return prog(state, mask)

    def _degrade(self, exc, chunk: int) -> bool:
        """Kernel-level failure: rebuild the step program on the jnp
        substrate and continue from the SAME state pytree (it is a plain
        dict of arrays — substrate-independent by construction)."""
        if not self.policy.substrate_fallback:
            return False
        if getattr(self._active.sub, "name", None) == "jnp" \
                and not isinstance(exc, SimulatedKernelFailure):
            return False                # nothing lower to degrade to
        from repro.api import make_solver
        sess = self.session
        dr = None if sess._dot_reduce is identity_reduce \
            else sess._dot_reduce
        self._active = make_solver(
            sess.method, sess.operator, precond=sess.precond_spec,
            substrate="jnp", config=sess.config, dot_reduce=dr,
            blocked=sess.blocked)
        self._log("substrate_degraded", chunk,
                  dict(error=repr(exc), to="jnp"))
        return True

    def _finalize(self, res: SolveResult, state: dict, B, tol_col,
                  mit_col) -> SolveResult:
        """Method fallback for columns that exhausted recovery, then the
        finite-output guarantee (failed columns never return NaN)."""
        pol = self.policy
        h = jax.device_get(dict(status=res.status, x=res.x,
                                iterations=res.iterations,
                                relres=res.relres, converged=res.converged,
                                breakdown=res.breakdown))
        status = np.asarray(h["status"]).copy()
        failed = np.array([SolveStatus(int(s)).is_failure for s in status])
        x = np.asarray(h["x"]).copy()
        iters = np.asarray(h["iterations"]).copy()
        relres = np.asarray(h["relres"]).copy()
        conv = np.asarray(h["converged"]).copy()
        brk = np.asarray(h["breakdown"]).copy()

        if failed.any() and pol.method_fallback is not None:
            from repro.api import make_solver
            sess = self.session
            fb = make_solver(
                pol.method_fallback, sess.operator,
                precond=sess.precond_spec, substrate="jnp",
                config=dataclasses.replace(
                    sess.config, guard=False, stagnation_window=0,
                    drift_scale=0.0))
            B_host = np.asarray(jax.device_get(B))
            for j in np.nonzero(failed)[0]:
                x0j = x[:, j]
                x0j = x0j if np.isfinite(x0j).all() else None
                r = fb.solve(B_host[:, j], x0j, tol=float(tol_col[j]),
                             maxiter=int(mit_col[j]))
                ok = bool(r.converged)
                self.events.append(dict(
                    event="method_fallback", column=int(j),
                    method=pol.method_fallback,
                    from_status=SolveStatus(int(status[j])).name,
                    converged=ok))
                iters[j] = iters[j] + int(r.iterations)
                if ok:
                    x[:, j] = np.asarray(jax.device_get(r.x))
                    relres[j] = float(r.relres)
                    conv[j] = True
                    brk[j] = False
                    status[j] = SolveStatus.CONVERGED.value

        # finite-output guarantee: whatever went wrong, x never carries
        # NaN/Inf out of the guarded surface
        bad = ~np.isfinite(x)
        if bad.any():
            x = np.where(bad, 0.0, x)
            relres = np.where(np.isfinite(relres), relres, np.inf)
        return SolveResult(jnp.asarray(x), jnp.asarray(iters),
                           jnp.asarray(relres), jnp.asarray(conv),
                           jnp.asarray(brk), res.residual_history,
                           jnp.asarray(status.astype(np.int32)), res.trace)


def guarded_config(config: SolverConfig,
                   policy: RecoveryPolicy) -> SolverConfig:
    """The inner session's config for a given policy: guard on, monitor
    windows forwarded (used by :func:`repro.api.make_solver` and the
    service registry)."""
    return dataclasses.replace(
        config, guard=True, stagnation_window=policy.stagnation_window,
        drift_scale=policy.drift_scale)
