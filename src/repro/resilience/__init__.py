"""repro.resilience — guarded solves: detection, recovery, injection.

Three layers, one per module:

* **Detection** rides *inside* the solver's single synchronization
  phase: with ``SolverConfig.guard`` the fused (9, m) dot phase of the
  batched p-BiCGSafe iteration becomes an (11, m) phase whose two extra
  rows carry ``||x||^2`` and a NaN/Inf probe over the reduction operands
  — zero additional reductions, and still no dependency edge to the
  in-flight matvec (the paper's comm-hiding overlap is intact;
  jaxpr-asserted in tests/test_resilience.py).  The state gains typed
  per-column :class:`~repro.core.SolveStatus` codes, the Cools /
  van-der-Vorst–Ye drift bound, and a stagnation monitor
  (:mod:`repro.core.multirhs`).
* **Recovery** is host-side and declarative: a frozen
  :class:`RecoveryPolicy` tells the :class:`GuardedSolver` driver what
  it may do at chunk boundaries — on-trigger residual replacement
  (generalizing p-BiCGSafe-rr's fixed cadence), restart-from-current-x,
  p-bicgsafe -> bicgstab method fallback, pallas -> jnp substrate
  degradation (:mod:`repro.resilience.policy`, ``.guard``,
  ``.recover``).
* **Injection** (:mod:`repro.resilience.inject`) drives deterministic
  chaos: NaN insertion, near-singular operators, simulated kernel
  failures, virtual-clock deadline pressure — the harness behind
  tests/test_resilience.py and benchmarks/bench_robustness.py.

Front door: ``repro.make_solver(..., recovery=RecoveryPolicy(...))``.
The service layer (:mod:`repro.service`) consumes the same machinery
for per-request typed statuses, NaN scrubbing of the resident block,
and capped-backoff retries.
"""
from repro.core.types import SolveStatus

from .guard import GuardedSolver, guarded_config
from .inject import (ChunkFaultInjector, SimulatedKernelFailure,
                     TickingClock, corrupt_engine_block, nan_columns,
                     near_singular_dense, orthogonal_shadow)
from .policy import RecoveryPolicy
from .recover import replace_columns, restart_columns

__all__ = [
    "SolveStatus", "RecoveryPolicy", "GuardedSolver", "guarded_config",
    "replace_columns", "restart_columns",
    "ChunkFaultInjector", "SimulatedKernelFailure", "TickingClock",
    "corrupt_engine_block", "nan_columns", "near_singular_dense",
    "orthogonal_shadow",
]
