"""Declarative recovery policies for guarded solves.

A :class:`RecoveryPolicy` is a frozen, hashable description of *what the
host is allowed to do* when the in-reduction health rows of a guarded
solve (``SolverConfig.guard``; see :mod:`repro.core.multirhs`) flag a
column at a chunk boundary:

* **replace** — on-trigger residual replacement: recompute ``r = b - A x``
  and the recurred A-images from true matvecs when the Cools /
  van-der-Vorst–Ye drift bound trips (the generalization of
  p-BiCGSafe-rr's fixed ``rr_epoch`` cadence — the trigger is the
  in-flight drift estimate, not a counter).
* **restart** — re-seed the Krylov space from the current iterate after a
  typed breakdown (``BREAKDOWN_RHO`` / ``_ALPHA`` / ``_OMEGA``), a
  non-finite state, or stagnation: keep x, take a fresh ``r0 = b - A x``
  and shadow residual, zero the auxiliary vectors.
* **method fallback** — columns that exhaust restarts fall back to a
  non-pipelined method (default BiCGStab) whose shorter recurrences
  tolerate the breakdown mode.
* **substrate degradation** — a kernel-level failure on the pallas
  substrate rebuilds the step program on the jnp substrate and continues
  from the same state pytree (it is substrate-independent by design).
* **service retries** — the engine re-enqueues failed requests with a
  capped exponential backoff (:mod:`repro.service`).

The policy itself holds no state; :class:`repro.resilience.GuardedSolver`
interprets it, and every action it takes is appended to the solver's
``events`` log.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class RecoveryPolicy:
    """What a guarded solve may do about an unhealthy column.

    Attributes:
      max_restarts: per-column budget of restart-from-current-x events
        (breakdown / non-finite / stagnation responses).  0 disables
        restarts — a broken column goes straight to method fallback (if
        enabled) or is surfaced with its typed status.
      max_replacements: per-column budget of on-trigger residual
        replacements (drift-flag responses).  0 disables replacement.
      stagnation_window: consecutive non-improving iterations before a
        column is flagged stagnant (forwarded into
        ``SolverConfig.stagnation_window``; 0 disables the monitor).
      drift_scale: drift threshold multiplier (forwarded into
        ``SolverConfig.drift_scale``; 0 means ``sqrt(eps)`` of the
        dtype).
      method_fallback: method name from :data:`repro.core.SOLVERS` run on
        columns that are still broken after all restarts (``None``
        disables the fallback).
      substrate_fallback: rebuild the step program on the ``"jnp"``
        substrate and continue from the same state after a kernel-level
        failure on ``"pallas"``.
      chunk: iterations between host health checks.  Larger chunks
        amortize the device->host flag read; smaller chunks bound how
        long a broken column burns before the policy reacts.
      max_retries: service layer only — times the engine re-enqueues a
        failed (broken-down / non-finite, not converged, not past
        deadline) request before surfacing the typed failure.
      retry_backoff_s: base delay before a retry becomes eligible
        (doubled per attempt).  The default 0.0 retries at the next
        admission opportunity — appropriate for the virtual-clock tests
        and for faults that are not load-correlated.
      retry_backoff_cap_s: upper bound on the per-retry delay.
    """

    max_restarts: int = 2
    max_replacements: int = 4
    stagnation_window: int = 0
    drift_scale: float = 0.0
    method_fallback: Optional[str] = "bicgstab"
    substrate_fallback: bool = True
    chunk: int = 64
    max_retries: int = 1
    retry_backoff_s: float = 0.0
    retry_backoff_cap_s: float = 1.0

    def __post_init__(self):
        if self.method_fallback is not None:
            from repro.core import SOLVERS
            if self.method_fallback not in SOLVERS:
                raise ValueError(
                    f"unknown method_fallback {self.method_fallback!r}; "
                    f"expected one of {sorted(SOLVERS)} or None")
        if self.chunk < 1:
            raise ValueError("chunk must be >= 1")
        for name in ("max_restarts", "max_replacements", "max_retries"):
            if getattr(self, name) < 0:
                raise ValueError(f"{name} must be >= 0")
