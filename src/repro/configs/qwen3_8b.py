"""qwen3-8b [dense] — GQA kv=8, qk_norm.  [hf:Qwen/Qwen3-8B]"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-8b", family="dense",
    n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=12288, vocab_size=151936,
    qk_norm=True, head_dim=128, rope_theta=1_000_000.0,
)


def smoke() -> ModelConfig:
    return CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                          d_ff=128, vocab_size=256, head_dim=16,
                          remat="none")
