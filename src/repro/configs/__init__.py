from .base import (ARCHS, SHAPES, applicable, get_config, input_specs,
                   skip_reason, smoke_config)

__all__ = ["ARCHS", "SHAPES", "applicable", "get_config", "input_specs",
           "skip_reason", "smoke_config"]
