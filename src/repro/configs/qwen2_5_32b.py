"""qwen2.5-32b [dense] — GQA kv=8, QKV bias.  [hf:Qwen/Qwen2.5-*]"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-32b", family="dense",
    n_layers=64, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=27648, vocab_size=152064,
    qkv_bias=True, rope_theta=1_000_000.0,
)


def smoke() -> ModelConfig:
    return CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                          d_ff=128, vocab_size=256, remat="none")
