"""zamba2-1.2b [hybrid] — Mamba2 backbone + shared attention block every 6
layers; sliding-window attention for long contexts.  [arXiv:2411.15242]"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab_size=32000,
    ssm_state=64, ssm_conv=4, ssm_expand=2,
    hybrid_shared_period=6, sliding_window=4096,
    rope_theta=10_000.0,
)


def smoke() -> ModelConfig:
    return CONFIG.replace(n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
                          d_ff=128, vocab_size=256, ssm_state=16,
                          hybrid_shared_period=2, sliding_window=64,
                          remat="none")
