"""whisper-tiny [audio] — enc-dec, conv frontend stubbed (input_specs
provides post-conv frame embeddings).  [arXiv:2212.04356]

Note: decode_32k exercises the decoder mechanically far beyond whisper's
448-token convention (dec_pos_embed sized 32768 for lowering); long_500k is
skipped (full attention).  DESIGN.md §4.
"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny", family="audio",
    n_layers=4, n_encoder_layers=4, d_model=384, n_heads=6, n_kv_heads=6,
    d_ff=1536, vocab_size=51865,
    is_encoder_decoder=True, frontend_stub=True, tie_embeddings=True,
)


def smoke() -> ModelConfig:
    return CONFIG.replace(n_layers=2, n_encoder_layers=2, d_model=64,
                          n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=256,
                          remat="none")
