"""llama4-scout-17b-a16e [moe] — 16 experts top-1 + shared, early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E]"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=8192, vocab_size=202048,
    moe_experts=16, moe_top_k=1, moe_shared_experts=1,
    moe_groups=256, moe_capacity_factor=1.25,
    rope_theta=500_000.0,
)


def smoke() -> ModelConfig:
    return CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                          d_ff=64, vocab_size=256, moe_experts=4,
                          moe_top_k=1, moe_groups=1, remat="none")
