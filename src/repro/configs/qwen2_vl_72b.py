"""qwen2-vl-72b [vlm] — M-RoPE, dynamic resolution (patch frontend stubbed:
input_specs provides patch embeddings + 3-D positions).  [arXiv:2409.12191]"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=29568, vocab_size=152064,
    qkv_bias=True, mrope_sections=(16, 24, 24),
    rope_theta=1_000_000.0, frontend_stub=True,
)


def smoke() -> ModelConfig:
    # sections sum to hd/2 (= 8 for hd 16)
    return CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                          d_ff=128, vocab_size=256,
                          mrope_sections=(2, 3, 3), remat="none")
