"""xlstm-350m [ssm] — alternating sLSTM + mLSTM blocks.  [arXiv:2405.04517]"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m", family="ssm",
    n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab_size=50304,
)


def smoke() -> ModelConfig:
    return CONFIG.replace(n_layers=4, d_model=64, n_heads=2, n_kv_heads=2,
                          vocab_size=256, remat="none")
