"""Architecture registry + input shapes (the assigned 10 × 4 grid).

``get_config(arch)`` returns the exact assigned full-size config;
``smoke_config(arch)`` a reduced same-family config for CPU tests;
``input_specs(cfg, shape)`` ShapeDtypeStruct stand-ins for every input of
the step function the shape exercises (train_step / prefill_step /
serve_step) — weak-type-correct, shardable, zero allocation.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import ModelConfig, init_cache

ARCH_IDS = [
    "phi3-mini-3.8b", "qwen2.5-32b", "qwen3-8b", "qwen1.5-110b",
    "deepseek-v3-671b", "llama4-scout-17b-a16e", "zamba2-1.2b",
    "xlstm-350m", "whisper-tiny", "qwen2-vl-72b",
]


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str       # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def _module(arch: str):
    mod = arch.replace("-", "_").replace(".", "_")
    return importlib.import_module(f"repro.configs.{mod}")


def get_config(arch: str) -> ModelConfig:
    return _module(arch).CONFIG


def smoke_config(arch: str) -> ModelConfig:
    return _module(arch).smoke()


ARCHS = ARCH_IDS  # alias


# ---------------------------------------------------------------------------
# applicability (DESIGN.md §4)
# ---------------------------------------------------------------------------

_FULL_ATTN = {"phi3-mini-3.8b", "qwen2.5-32b", "qwen3-8b", "qwen1.5-110b",
              "deepseek-v3-671b", "llama4-scout-17b-a16e", "qwen2-vl-72b",
              "whisper-tiny"}


def skip_reason(arch: str, shape: str) -> Optional[str]:
    if shape == "long_500k" and arch in _FULL_ATTN:
        return ("full-attention backbone: 500k-token KV decode is "
                "quadratic-prefill/huge-KV; run only for SSM/hybrid archs "
                "(DESIGN.md §4)")
    return None


def applicable(arch: str, shape: str) -> bool:
    return skip_reason(arch, shape) is None


# ---------------------------------------------------------------------------
# input specs
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: str,
                arch: str = "") -> Dict[str, Any]:
    """ShapeDtypeStruct inputs for the step function of ``shape``.

    train:   {"batch": {tokens[, frames, patch_embeds, positions]}}
    prefill: {"batch": {...}}                                (no labels)
    decode:  {"tokens": (B,1), "cache": <tree>, "cache_len": scalar}
    """
    sp = SHAPES[shape]
    B, S = sp.global_batch, sp.seq_len
    toks = _sds((B, S), jnp.int32)

    if sp.kind in ("train", "prefill"):
        batch: Dict[str, Any] = {"tokens": toks}
        if cfg.family == "audio":
            # frontend stub: precomputed post-conv frame embeddings
            batch["frames"] = _sds((B, S, cfg.d_model), cfg.dtype)
        if cfg.family == "vlm":
            n_patch = min(1024, S - 2)
            batch["patch_embeds"] = _sds((B, n_patch, cfg.d_model), cfg.dtype)
            batch["positions"] = _sds((B, S, 3), jnp.int32)
        return {"batch": batch}

    # decode: one new token against a seq_len cache
    cache = jax.eval_shape(
        lambda: init_cache(cfg, B, S,
                           enc_len=1500 if cfg.family == "audio" else 0))
    return {
        "tokens": _sds((B, 1), jnp.int32),
        "cache": cache,
        "cache_len": _sds((), jnp.int32),
    }
