"""deepseek-v3-671b [moe] — MLA, 1 shared + 256 routed top-8, MTP.
[arXiv:2412.19437]"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b", family="moe",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128,
    d_ff=2048, vocab_size=129280,
    use_mla=True, q_lora_rank=1536, kv_lora_rank=512,
    qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128,
    moe_experts=256, moe_top_k=8, moe_shared_experts=1,
    moe_groups=256, moe_capacity_factor=1.25,
    # DeepSeek-V3 "does not drop any tokens during training or inference"
    # (arXiv:2412.19437 §3): route through the dropless sort dispatch.  The
    # capacity-gather path makes expert assignment batch-competitive, so a
    # token's FFN output depends on which other tokens share the batch —
    # which breaks prefill/decode logit consistency (single-token decode
    # never hits capacity; a 32-token prefill does).
    moe_impl="sort",
    use_mtp=True, mtp_loss_weight=0.3,
    rope_theta=10_000.0,
)


def smoke() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=64,
        vocab_size=256, q_lora_rank=32, kv_lora_rank=16,
        qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16,
        moe_experts=4, moe_top_k=2, moe_groups=1, remat="none")
