from .common import ModelConfig
from .transformer import (cache_logical_axes, decode_step, forward,
                          init_cache, init_params, loss_fn, prefill_step)

__all__ = ["ModelConfig", "cache_logical_axes", "decode_step", "forward",
           "init_cache", "init_params", "loss_fn", "prefill_step"]
