"""Mixture-of-Experts MLP: top-k routing, shared experts, EP sharding.

Two dispatch implementations:

* ``gather``  — capacity-slot dispatch via *index* tensors (no one-hot
  einsum): tokens are assigned (expert, slot) positions with an intra-group
  cumsum, an inverse map (E, C) -> token id is built by scatter, and the
  expert inputs are a gather.  Tokens are first reshaped into ``moe_groups``
  groups aligned with the data axis so the (E, C) buffers stay per-device
  sized at any scale; GSPMD emits the EP all-to-all at the
  (group->expert) resharding boundary.  Dropless up to the capacity factor.
* ``sort``    — MegaBlocks-style: tokens argsorted by expert id, dense
  per-expert GEMMs via ``jax.lax.ragged_dot`` when available.  Used by the
  perf pass (no capacity dropping, no inverse-map scatter).

Routing: softmax over router logits in fp32; optional aux-loss-free bias
(DeepSeek-V3) applied to *selection only*; load-balancing aux loss
returned for logging/training.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.parallel import logical_constraint as shard

from .common import ModelConfig, dense_init

Params = Dict[str, Any]


def init_moe_params(key, cfg: ModelConfig) -> Params:
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.moe_experts
    ks = jax.random.split(key, 7)
    p = {
        "router": dense_init(ks[0], (d, E), jnp.float32),
        "router_bias": jnp.zeros((E,), jnp.float32),   # aux-loss-free bias
        "wi": dense_init(ks[1], (E, d, ff), cfg.param_dtype, fan_in=d),
        "wg": dense_init(ks[2], (E, d, ff), cfg.param_dtype, fan_in=d),
        "wo": dense_init(ks[3], (E, ff, d), cfg.param_dtype, fan_in=ff),
    }
    if cfg.moe_shared_experts:
        sf = ff * cfg.moe_shared_experts
        p["shared_wi"] = dense_init(ks[4], (d, sf), cfg.param_dtype)
        p["shared_wg"] = dense_init(ks[5], (d, sf), cfg.param_dtype)
        p["shared_wo"] = dense_init(ks[6], (sf, d), cfg.param_dtype)
    return p


def _route(p: Params, xf: jax.Array, cfg: ModelConfig):
    """xf: (N, d) -> (probs (N,k), experts (N,k), aux_loss)."""
    # bf16 matmul, fp32 accumulation: avoids an (N, d) fp32 activation copy
    logits = jnp.einsum("nd,de->ne", xf, p["router"].astype(xf.dtype),
                        preferred_element_type=jnp.float32)    # (N, E)
    scores = jax.nn.softmax(logits, axis=-1)
    select = scores + p["router_bias"][None, :]                # bias: selection only
    _, experts = jax.lax.top_k(select, cfg.moe_top_k)          # (N, k)
    probs = jnp.take_along_axis(scores, experts, axis=-1)
    probs = probs / jnp.clip(probs.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance loss: E * sum_e f_e * p_e
    E = cfg.moe_experts
    density = jnp.mean(
        jax.nn.one_hot(experts[..., 0], E, dtype=jnp.float32), axis=0)
    mean_probs = jnp.mean(scores, axis=0)
    aux = E * jnp.sum(density * mean_probs) * cfg.moe_aux_loss_coef
    return probs, experts, aux


def _expert_ffn(wi, wg, wo, xin, dtype):
    """xin: (E, C, d) -> (E, C, d); SwiGLU per expert."""
    h = jnp.einsum("ecd,edf->ecf", xin, wi.astype(dtype))
    g = jnp.einsum("ecd,edf->ecf", xin, wg.astype(dtype))
    return jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * h, wo.astype(dtype))


def moe_ffn(p: Params, x: jax.Array, cfg: ModelConfig,
            impl: str = "gather") -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (y, aux_loss)."""
    B, S, d = x.shape
    N = B * S
    E, k = cfg.moe_experts, cfg.moe_top_k
    xf = x.reshape(N, d)
    probs, experts, aux = _route(p, xf, cfg)

    if impl == "sort":
        y = _moe_sort(p, xf, probs, experts, cfg)
    else:
        y = _moe_gather(p, xf, probs, experts, cfg)

    if cfg.moe_shared_experts:
        h = xf @ p["shared_wi"].astype(x.dtype)
        g = xf @ p["shared_wg"].astype(x.dtype)
        y = y + (jax.nn.silu(g) * h) @ p["shared_wo"].astype(x.dtype)
    return y.reshape(B, S, d), aux


def _moe_gather(p, xf, probs, experts, cfg: ModelConfig):
    """Index-dispatch MoE (see module docstring)."""
    N, d = xf.shape
    E, k = cfg.moe_experts, cfg.moe_top_k
    G = max(1, cfg.moe_groups)
    while N % G:
        G //= 2
    n = N // G
    C = int(max(4, cfg.moe_capacity_factor * n * k / E))
    C = min(C, n * k)

    xg = xf.reshape(G, n, d)
    eg = experts.reshape(G, n, k)
    pg = probs.reshape(G, n, k)

    # slot position of each (token, k) within its (group, expert) capacity.
    # Sort-based ranking: O(N*k) memory — an (N*k, E) one-hot cumsum would
    # be terabytes at DeepSeek scale (1M tokens x 8 x 256 experts).
    N_k = N * k
    key = (jnp.arange(N_k, dtype=jnp.int32) // (n * k)) * E \
        + experts.reshape(-1)                                   # (N*k,)
    order = jnp.argsort(key)                                    # stable
    sk = key[order]
    counts = jnp.bincount(key, length=G * E)
    starts = (jnp.cumsum(counts) - counts).astype(jnp.int32)
    rank = jnp.arange(N_k, dtype=jnp.int32) - starts[sk]
    pos_flat = jnp.zeros((N_k,), jnp.int32).at[order].set(rank)
    pos = pos_flat.reshape(G, n, k)
    keep = pos < C
    # flattened (expert, slot) id; dropped tokens -> sentinel slot E*C
    eidx = jnp.where(keep, eg * C + pos, E * C).astype(jnp.int32)

    # inverse map: (G, E*C+1) slot -> source token id (sentinel n = zero row)
    ginv = jnp.full((G, E * C + 1), n, jnp.int32)
    gi = jnp.broadcast_to(jnp.arange(G)[:, None, None], eidx.shape)
    ti = jnp.broadcast_to(jnp.arange(n)[None, :, None], eidx.shape)
    ginv = ginv.at[gi, eidx].set(ti)
    inv = ginv[:, :E * C]                                       # (G, E*C)

    xgp = jnp.concatenate([xg, jnp.zeros((G, 1, d), xg.dtype)], axis=1)
    xin = jnp.take_along_axis(xgp, inv[..., None], axis=1)      # (G,E*C,d)
    xin = shard(xin.reshape(G, E, C, d), "batch", "experts", None, None)

    # expert FFN (EP: E sharded on 'model', G rides the data axis; the
    # (batch->experts) resharding boundary is the EP all-to-all)
    h = jnp.einsum("gecd,edf->gecf", xin, p["wi"].astype(xf.dtype))
    g_ = jnp.einsum("gecd,edf->gecf", xin, p["wg"].astype(xf.dtype))
    yout = jnp.einsum("gecf,efd->gecd", jax.nn.silu(g_) * h,
                      p["wo"].astype(xf.dtype))
    yout = shard(yout, "batch", "experts", None, None)
    yflat = yout.reshape(G, E * C, d)

    # combine: per-k gather + weighted accumulate (no (G,n,k,d) tensor)
    y = jnp.zeros((G, n, d), xf.dtype)
    for kk in range(k):
        idx = jnp.minimum(eidx[:, :, kk], E * C - 1)
        gk = (pg[:, :, kk] * keep[:, :, kk]).astype(xf.dtype)
        yk = jnp.take_along_axis(yflat, idx[..., None], axis=1)
        y = y + yk * gk[..., None]
    return y.reshape(N, d)


def _moe_sort(p, xf, probs, experts, cfg: ModelConfig):
    """Sort-based dropless dispatch (perf path)."""
    N, d = xf.shape
    E, k = cfg.moe_experts, cfg.moe_top_k
    expert_flat = experts.reshape(-1)                          # (N*k,)
    order = jnp.argsort(expert_flat)
    token_of = order // k
    xin = xf[token_of]                                         # (N*k, d) sorted
    group_sizes = jnp.bincount(expert_flat, length=E).astype(jnp.int32)

    if hasattr(jax.lax, "ragged_dot"):
        h = jax.lax.ragged_dot(xin, p["wi"].astype(xf.dtype), group_sizes)
        g = jax.lax.ragged_dot(xin, p["wg"].astype(xf.dtype), group_sizes)
        yo = jax.lax.ragged_dot(jax.nn.silu(g) * h,
                                p["wo"].astype(xf.dtype), group_sizes)
    else:  # pragma: no cover - fallback for jax without ragged_dot
        # per-row expert id of the SORTED stream (group sizes are ragged;
        # an even split would pair tokens with the wrong expert weights)
        seg = expert_flat[order]
        h = jnp.einsum("nd,ndf->nf", xin,
                       p["wi"].astype(xf.dtype)[seg])
        g = jnp.einsum("nd,ndf->nf", xin, p["wg"].astype(xf.dtype)[seg])
        yo = jnp.einsum("nf,nfd->nd", jax.nn.silu(g) * h,
                        p["wo"].astype(xf.dtype)[seg])

    gate_sorted = probs.reshape(-1)[order].astype(xf.dtype)
    y = jnp.zeros_like(xf).at[token_of].add(yo * gate_sorted[:, None])
    return y


def dense_ffn_init(key, cfg: ModelConfig, d_ff: int = 0) -> Params:
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "wi": dense_init(ks[0], (d, ff), cfg.param_dtype),
        "wg": dense_init(ks[1], (d, ff), cfg.param_dtype),
        "wo": dense_init(ks[2], (ff, d), cfg.param_dtype),
    }


def dense_ffn(p: Params, x: jax.Array) -> jax.Array:
    """SwiGLU MLP.  The intermediate is pinned ff-sharded so GSPMD keeps
    the wi/wg -> wo chain local per model shard and resolves the output
    partial sums with one reduce-scatter at the (seq-sharded) residual."""
    h = x @ p["wi"].astype(x.dtype)
    g = x @ p["wg"].astype(x.dtype)
    hg = shard(jax.nn.silu(g) * h, "batch", None, "ff")
    return (hg @ p["wo"].astype(x.dtype))


def gelu_ffn_init(key, cfg: ModelConfig) -> Params:
    d, ff = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 2)
    return {
        "wi": dense_init(ks[0], (d, ff), cfg.param_dtype),
        "bi": jnp.zeros((ff,), cfg.param_dtype),
        "wo": dense_init(ks[1], (ff, d), cfg.param_dtype),
        "bo": jnp.zeros((d,), cfg.param_dtype),
    }


def gelu_ffn(p: Params, x: jax.Array) -> jax.Array:
    """GELU MLP (whisper)."""
    h = jax.nn.gelu(x @ p["wi"].astype(x.dtype) + p["bi"].astype(x.dtype))
    return h @ p["wo"].astype(x.dtype) + p["bo"].astype(x.dtype)
