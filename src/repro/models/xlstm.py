"""xLSTM blocks (Beck et al. 2024): mLSTM (matrix memory, parallelizable)
and sLSTM (scalar memory, sequential) — the [ssm] assigned architecture.

mLSTM parallel form is attention-like with an exponential-gate decay
matrix D (stabilized with a running max); decode is the O(1) recurrence on
the (hd × hd) matrix memory C, normalizer n, and stabilizer m.

sLSTM runs as a ``lax.scan`` over time with per-head block-diagonal
recurrent weights and exponential input / sigmoid-forget gating.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from .common import ModelConfig, dense_init, layer_norm, rms_norm

Params = Dict[str, Any]
NEG_INF = -1e30


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def init_mlstm_params(key, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    H = cfg.n_heads
    dp = 2 * d                      # up-projection factor 2 (xLSTM paper)
    hd = dp // H
    ks = jax.random.split(key, 8)
    return {
        "w_up": dense_init(ks[0], (d, 2 * dp), cfg.param_dtype),     # x, gate
        "wq": dense_init(ks[1], (dp, dp), cfg.param_dtype),
        "wk": dense_init(ks[2], (dp, dp), cfg.param_dtype),
        "wv": dense_init(ks[3], (dp, dp), cfg.param_dtype),
        "w_if": dense_init(ks[4], (dp, 2 * H), cfg.param_dtype),     # i,f gates
        "b_if": jnp.concatenate([jnp.zeros((H,)), 3.0 * jnp.ones((H,))]
                                ).astype(cfg.param_dtype),
        "norm": jnp.ones((dp,), cfg.param_dtype),
        "norm_in": jnp.ones((d,), cfg.param_dtype),
        "w_down": dense_init(ks[5], (dp, d), cfg.param_dtype),
    }


def mlstm_forward(p: Params, x: jax.Array, cfg: ModelConfig,
                  chunk: int = 256, return_state: bool = False):
    """Chunkwise-parallel (training) mLSTM.  x: (B, S, d).

    Within a chunk: stabilized decay matrix D (Q, Q, H); across chunks:
    ``lax.scan`` carrying the stabilized matrix memory (C, n, m) — so the
    (S, S) matrix never materializes (cf. the SSD chunk algorithm).
    """
    B, S, d = x.shape
    H = cfg.n_heads
    x = rms_norm(p["norm_in"], x, cfg.norm_eps)
    up = x @ p["w_up"].astype(x.dtype)
    xin, gate = jnp.split(up, 2, axis=-1)
    dp = xin.shape[-1]
    hd = dp // H

    q = (xin @ p["wq"].astype(x.dtype)).reshape(B, S, H, hd)
    k = (xin @ p["wk"].astype(x.dtype)).reshape(B, S, H, hd) / (hd ** 0.5)
    v = (xin @ p["wv"].astype(x.dtype)).reshape(B, S, H, hd)
    gates = (xin @ p["w_if"].astype(x.dtype)
             + p["b_if"].astype(x.dtype)).astype(jnp.float32)
    ig, fg = gates[..., :H], gates[..., H:]                     # (B,S,H)
    log_f = jax.nn.log_sigmoid(fg)

    if S % chunk:
        chunk = S
    Q, nc = chunk, S // chunk
    tri = jnp.tril(jnp.ones((Q, Q), bool))

    def to_chunks(a):
        return jnp.moveaxis(a.reshape(B, nc, Q, *a.shape[2:]), 1, 0)

    qc, kc, vc = (to_chunks(a.astype(jnp.float32)) for a in (q, k, v))
    ic, fc = to_chunks(ig), to_chunks(log_f)

    def one_chunk(carry, inputs):
        C, n, mc = carry            # (B,H,hd,hd), (B,H,hd), (B,H)
        qi, ki, vi, ii, fi = inputs
        lf = jnp.cumsum(fi, axis=1)                      # (B,Q,H)
        total = lf[:, -1]                                # (B,H)
        # intra-chunk exponents b[t,j] = lf_t - lf_j + i_j  (j <= t)
        bmat = lf[:, :, None, :] - lf[:, None, :, :] + ii[:, None, :, :]
        bmat = jnp.where(tri[None, :, :, None], bmat, NEG_INF)
        a_t = lf + mc[:, None, :]                        # carry exponent
        m_t = jnp.maximum(jnp.max(bmat, axis=2), a_t)    # (B,Q,H)
        dstab = jnp.exp(bmat - m_t[:, :, None, :])
        scores = jnp.einsum("bthd,bjhd->btjh", qi, ki) * dstab
        num = jnp.einsum("btjh,bjhd->bthd", scores, vi)
        den = scores.sum(axis=2)                         # (B,Q,H)
        cw = jnp.exp(a_t - m_t)                          # carry weight
        num = num + cw[..., None] * jnp.einsum("bthd,bhdv->bthv", qi, C)
        den = den + cw * jnp.einsum("bthd,bhd->bth", qi, n)
        y = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[..., None]
        # carry update (stabilized at m_new)
        wj = total[:, None] - lf + ii                    # (B,Q,H)
        m_new = jnp.maximum(mc + total, jnp.max(wj, axis=1))
        kv = jnp.einsum("bjh,bjhd,bjhv->bhdv",
                        jnp.exp(wj - m_new[:, None]), ki, vi)
        ksum = jnp.einsum("bjh,bjhd->bhd",
                          jnp.exp(wj - m_new[:, None]), ki)
        decay = jnp.exp(mc + total - m_new)
        C2 = C * decay[..., None, None] + kv
        n2 = n * decay[..., None] + ksum
        return (C2, n2, m_new), y

    carry0 = (jnp.zeros((B, H, hd, hd), jnp.float32),
              jnp.zeros((B, H, hd), jnp.float32),
              jnp.full((B, H), -1e30, jnp.float32))
    fin, y_c = jax.lax.scan(one_chunk, carry0, (qc, kc, vc, ic, fc))
    y = jnp.moveaxis(y_c, 0, 1).reshape(B, S, dp).astype(x.dtype)

    y = rms_norm(p["norm"], y, cfg.norm_eps)
    y = y * jax.nn.silu(gate)
    out = y @ p["w_down"].astype(x.dtype)
    if return_state:
        return out, {"c": fin[0], "n": fin[1], "m": fin[2]}
    return out


def mlstm_init_state(cfg: ModelConfig, batch: int):
    H = cfg.n_heads
    hd = 2 * cfg.d_model // H
    return {
        "c": jnp.zeros((batch, H, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, H, hd), jnp.float32),
        "m": jnp.full((batch, H), -1e30, jnp.float32),
    }


def mlstm_decode(p: Params, x: jax.Array, state: Dict, cfg: ModelConfig):
    """Recurrent mLSTM step.  x: (B, 1, d)."""
    B = x.shape[0]
    H = cfg.n_heads
    x = rms_norm(p["norm_in"], x, cfg.norm_eps)
    up = x @ p["w_up"].astype(x.dtype)
    xin, gate = jnp.split(up, 2, axis=-1)
    dp = xin.shape[-1]
    hd = dp // H
    q = (xin @ p["wq"].astype(x.dtype)).reshape(B, H, hd).astype(jnp.float32)
    k = ((xin @ p["wk"].astype(x.dtype)).reshape(B, H, hd)
         / (hd ** 0.5)).astype(jnp.float32)
    v = (xin @ p["wv"].astype(x.dtype)).reshape(B, H, hd).astype(jnp.float32)
    gates = (xin @ p["w_if"].astype(x.dtype)
             + p["b_if"].astype(x.dtype)).astype(jnp.float32)[:, 0]
    ig, fg = gates[..., :H], gates[..., H:]
    log_f = jax.nn.log_sigmoid(fg)

    m_new = jnp.maximum(log_f + state["m"], ig)                 # (B,H)
    fs = jnp.exp(log_f + state["m"] - m_new)
    is_ = jnp.exp(ig - m_new)
    c = state["c"] * fs[..., None, None] + is_[..., None, None] \
        * jnp.einsum("bhk,bhv->bhkv", k, v)
    n = state["n"] * fs[..., None] + is_[..., None] * k
    num = jnp.einsum("bhk,bhkv->bhv", q, c)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", q, n)),
                      jnp.exp(-m_new))
    y = (num / den[..., None]).reshape(B, 1, dp).astype(x.dtype)
    y = rms_norm(p["norm"], y, cfg.norm_eps) * jax.nn.silu(gate)
    return y @ p["w_down"].astype(x.dtype), \
        {"c": c, "n": n, "m": m_new}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def init_slstm_params(key, cfg: ModelConfig) -> Params:
    d, H = cfg.d_model, cfg.n_heads
    hd = d // H
    ks = jax.random.split(key, 4)
    ff = int(d * 4 / 3 / 64) * 64 * 2 or 2 * d
    return {
        # input projections for gates (z, i, f, o)
        "w_x": dense_init(ks[0], (d, 4 * d), cfg.param_dtype),
        # block-diagonal recurrent weights per head: (H, hd, 4*hd)
        "w_r": dense_init(ks[1], (H, hd, 4 * hd), cfg.param_dtype, fan_in=hd),
        "bias": jnp.zeros((4 * d,), cfg.param_dtype),
        "norm": jnp.ones((d,), cfg.param_dtype),
        "norm_in": jnp.ones((d,), cfg.param_dtype),
        "w_up": dense_init(ks[2], (d, ff), cfg.param_dtype),
        "w_down": dense_init(ks[3], (ff // 2, d), cfg.param_dtype,
                             fan_in=ff // 2),
    }


def slstm_init_state(cfg: ModelConfig, batch: int):
    d, H = cfg.d_model, cfg.n_heads
    hd = d // H
    z = jnp.zeros((batch, H, hd), jnp.float32)
    return {"c": z, "n": z, "h": z,
            "m": jnp.full((batch, H, hd), -1e30, jnp.float32)}


def _slstm_cell(p, xt, st, cfg: ModelConfig):
    """One sLSTM time step.  xt: (B, 4*d) pre-projected input contribution."""
    B = xt.shape[0]
    d, H = cfg.d_model, cfg.n_heads
    hd = d // H
    rec = jnp.einsum("bhk,hkg->bhg", st["h"].astype(xt.dtype),
                     p["w_r"].astype(xt.dtype))          # (B,H,4*hd)
    tot = (xt.reshape(B, H, 4 * hd) + rec
           + p["bias"].astype(xt.dtype).reshape(H, 4 * hd)).astype(jnp.float32)
    z, i, f, o = jnp.split(tot, 4, axis=-1)              # each (B,H,hd)
    log_f = jax.nn.log_sigmoid(f)
    m_new = jnp.maximum(log_f + st["m"], i)
    fs = jnp.exp(log_f + st["m"] - m_new)
    is_ = jnp.exp(i - m_new)
    c = fs * st["c"] + is_ * jnp.tanh(z)
    n = fs * st["n"] + is_
    h = jax.nn.sigmoid(o) * c / jnp.maximum(n, 1.0)
    return {"c": c, "n": n, "h": h, "m": m_new}


def slstm_forward(p: Params, x: jax.Array, cfg: ModelConfig,
                  return_state: bool = False):
    """Sequential sLSTM over time + gated FFN.  x: (B, S, d)."""
    B, S, d = x.shape
    H = cfg.n_heads
    hd = d // H
    x = rms_norm(p["norm_in"], x, cfg.norm_eps)
    xg = x @ p["w_x"].astype(x.dtype)                    # (B,S,4d)

    def step(st, xt):
        st2 = _slstm_cell(p, xt, st, cfg)
        return st2, st2["h"]

    st0 = slstm_init_state(cfg, B)
    fin, hs = jax.lax.scan(step, st0, jnp.moveaxis(xg, 1, 0))
    y = jnp.moveaxis(hs, 0, 1).reshape(B, S, d).astype(x.dtype)
    y = rms_norm(p["norm"], y, cfg.norm_eps)
    up = y @ p["w_up"].astype(x.dtype)
    a, b = jnp.split(up, 2, axis=-1)
    out = (jax.nn.gelu(a) * b) @ p["w_down"].astype(x.dtype)
    if return_state:
        return out, fin
    return out


def slstm_decode(p: Params, x: jax.Array, state: Dict, cfg: ModelConfig):
    B = x.shape[0]
    x = rms_norm(p["norm_in"], x, cfg.norm_eps)
    xg = (x @ p["w_x"].astype(x.dtype))[:, 0]
    st2 = _slstm_cell(p, xg, state, cfg)
    y = st2["h"].reshape(B, 1, cfg.d_model).astype(x.dtype)
    y = rms_norm(p["norm"], y, cfg.norm_eps)
    up = y @ p["w_up"].astype(x.dtype)
    a, b = jnp.split(up, 2, axis=-1)
    return (jax.nn.gelu(a) * b) @ p["w_down"].astype(x.dtype), st2
