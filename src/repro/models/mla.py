"""Multi-head Latent Attention (DeepSeek-V2/V3).

Queries and KV are low-rank compressed; K/V are reconstructed from a
shared latent ``c_kv`` (kv_lora_rank wide) plus a single shared RoPE key
stream.  Decode runs in *absorbed* form: scores and values are computed
directly against the cached latent — the KV cache is only
``kv_lora_rank + qk_rope_head_dim`` wide per token (the production trick
that makes MLA decode cheap).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import ModelConfig, apply_rope, dense_init, rms_norm

Params = Dict[str, Any]
NEG_INF = -1e30


def init_mla_params(key, cfg: ModelConfig) -> Params:
    d, H = cfg.d_model, cfg.n_heads
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    ks = jax.random.split(key, 6)
    return {
        "wdq": dense_init(ks[0], (d, qr), cfg.param_dtype),
        "q_norm": jnp.ones((qr,), cfg.param_dtype),
        "wuq": dense_init(ks[1], (qr, H * (dn + dr)), cfg.param_dtype),
        "wdkv": dense_init(ks[2], (d, kvr + dr), cfg.param_dtype),
        "kv_norm": jnp.ones((kvr,), cfg.param_dtype),
        "wuk": dense_init(ks[3], (kvr, H * dn), cfg.param_dtype),
        "wuv": dense_init(ks[4], (kvr, H * dv), cfg.param_dtype),
        "wo": dense_init(ks[5], (H * dv, d), cfg.param_dtype),
    }


def _compress(p: Params, x: jax.Array, cfg: ModelConfig, positions):
    """Returns (q_nope, q_rope, c_kv, k_rope)."""
    B, S, _ = x.shape
    H = cfg.n_heads
    dn, dr = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    cq = rms_norm(p["q_norm"], x @ p["wdq"].astype(x.dtype), cfg.norm_eps)
    q = (cq @ p["wuq"].astype(x.dtype)).reshape(B, S, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    ckv_full = x @ p["wdkv"].astype(x.dtype)
    c_kv = rms_norm(p["kv_norm"], ckv_full[..., :cfg.kv_lora_rank],
                    cfg.norm_eps)
    k_rope = ckv_full[..., cfg.kv_lora_rank:][:, :, None, :]  # 1 shared head
    if positions is not None:
        q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
        k_rope = apply_rope(k_rope, positions, cfg.rope_theta)
    return q_nope, q_rope, c_kv, k_rope[:, :, 0, :]


def mla_attention(p: Params, x: jax.Array, positions: jax.Array,
                  cfg: ModelConfig, *, q_block: int = 1024,
                  return_cache: bool = False):
    """Prefill/train MLA: reconstruct K/V from the latent, causal attention."""
    B, S, _ = x.shape
    H = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    scale = 1.0 / math.sqrt(dn + dr)

    q_nope, q_rope, c_kv, k_rope = _compress(p, x, cfg, positions)
    k_nope = (c_kv @ p["wuk"].astype(x.dtype)).reshape(B, S, H, dn)
    v = (c_kv @ p["wuv"].astype(x.dtype)).reshape(B, S, H, dv)

    def block_attn(qn, qr, row_idx):
        # scores: content (per-head k_nope) + shared rope stream
        lg = jnp.einsum("bskh,btkh->bkst", qn, k_nope,
                        preferred_element_type=jnp.float32)
        lg += jnp.einsum("bskh,bth->bkst", qr, k_rope,
                         preferred_element_type=jnp.float32)
        lg *= scale
        col = jnp.arange(S)
        mask = row_idx[:, None] >= col[None, :]
        lg = jnp.where(mask[None, None], lg, NEG_INF)
        pr = jax.nn.softmax(lg, axis=-1).astype(x.dtype)
        return jnp.einsum("bkst,btkh->bskh", pr, v)

    if S <= q_block:
        o = block_attn(q_nope, q_rope, jnp.arange(S))
    else:
        nblk = S // q_block
        qn = jnp.moveaxis(q_nope.reshape(B, nblk, q_block, H, dn), 1, 0)
        qr = jnp.moveaxis(q_rope.reshape(B, nblk, q_block, H, dr), 1, 0)

        @jax.checkpoint  # recompute block logits in bwd: O(blk) live memory
        def step(_, args):
            qni, qri, i = args
            rows = i * q_block + jnp.arange(q_block)
            return None, block_attn(qni, qri, rows)

        _, ob = jax.lax.scan(step, None, (qn, qr, jnp.arange(nblk)))
        o = jnp.moveaxis(ob, 0, 1).reshape(B, S, H, dv)

    out = o.reshape(B, S, H * dv) @ p["wo"].astype(x.dtype)
    if return_cache:
        return out, (c_kv, k_rope)
    return out


def mla_decode(p: Params, x: jax.Array, position: jax.Array,
               ckv_cache: jax.Array, krope_cache: jax.Array,
               cache_len: jax.Array, cfg: ModelConfig):
    """Absorbed-form MLA decode against the latent cache.

    ckv_cache: (B, T, kv_lora_rank); krope_cache: (B, T, qk_rope_head_dim).
    Scores: (W_uk^T q_nope) · c  +  q_rope · k_rope;  values in latent space
    then projected once through W_uv.
    """
    B, S1, _ = x.shape
    H = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    kvr = cfg.kv_lora_rank
    T = ckv_cache.shape[1]
    scale = 1.0 / math.sqrt(dn + dr)
    positions = position[:, None] if position.ndim == 1 else position

    q_nope, q_rope, c_new, krope_new = _compress(p, x, cfg, positions)
    ckv_cache = jax.lax.dynamic_update_slice(
        ckv_cache, c_new.astype(ckv_cache.dtype), (0, cache_len, 0))
    krope_cache = jax.lax.dynamic_update_slice(
        krope_cache, krope_new.astype(krope_cache.dtype), (0, cache_len, 0))

    # absorb: q_eff[b,h,:] = q_nope[b,h] @ W_uk[h]  (latent-space query)
    wuk = p["wuk"].astype(x.dtype).reshape(kvr, H, dn)
    q_eff = jnp.einsum("bskh,ckh->bskc", q_nope, wuk)        # (B,1,H,kvr)

    lg = jnp.einsum("bskc,btc->bkst", q_eff, ckv_cache.astype(x.dtype),
                    preferred_element_type=jnp.float32)
    lg += jnp.einsum("bskh,bth->bkst", q_rope, krope_cache.astype(x.dtype),
                     preferred_element_type=jnp.float32)
    lg *= scale
    valid = jnp.arange(T) <= cache_len
    lg = jnp.where(valid[None, None, None, :], lg, NEG_INF)
    pr = jax.nn.softmax(lg, axis=-1).astype(x.dtype)
    o_lat = jnp.einsum("bkst,btc->bskc", pr, ckv_cache.astype(x.dtype))
    wuv = p["wuv"].astype(x.dtype).reshape(kvr, H, dv)
    o = jnp.einsum("bskc,ckh->bskh", o_lat, wuv).reshape(B, 1, H * dv)
    y = o @ p["wo"].astype(x.dtype)
    return y, ckv_cache, krope_cache
