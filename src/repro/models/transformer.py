"""Model assembly for all assigned architectures.

One functional entry set, dispatched on ``cfg.family``:

* ``init_params(cfg, key)``                      parameter pytree
* ``forward(params, cfg, batch)``                logits (train/prefill)
* ``loss_fn(params, cfg, batch)``                scalar loss + metrics
* ``init_cache(cfg, batch, max_len)``            decode cache pytree
* ``decode_step(params, cfg, cache, tokens, cache_len)``  one-token decode
* ``cache_logical_axes(cfg)``                    sharding annotations

Layers are stacked (leading L dim) and executed with ``lax.scan`` so the
HLO stays one-layer-sized; ``cfg.remat`` wraps the block in
``jax.checkpoint``.  Activation shardings are logical
(:func:`repro.parallel.logical_constraint`) and resolve against whatever
mesh is active — including none (single-device smoke tests).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.parallel import logical_constraint as shard

from .attention import (decode_attention, init_attention_params,
                        multihead_attention)
from .common import (ModelConfig, dense_init, embed_init, layer_norm,
                     rms_norm, sinusoidal_positions)
from .mla import init_mla_params, mla_attention, mla_decode
from .moe import (dense_ffn, dense_ffn_init, gelu_ffn, gelu_ffn_init,
                  init_moe_params, moe_ffn)
from .ssm import (init_mamba2_params, mamba2_decode, mamba2_forward,
                  mamba2_init_state)
from .xlstm import (init_mlstm_params, init_slstm_params, mlstm_decode,
                    mlstm_forward, mlstm_init_state, slstm_decode,
                    slstm_forward, slstm_init_state)

Params = Dict[str, Any]


# ===========================================================================
# init
# ===========================================================================

def _init_decoder_block(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 3)
    p: Params = {"ln1": jnp.ones((cfg.d_model,), cfg.param_dtype),
                 "ln2": jnp.ones((cfg.d_model,), cfg.param_dtype)}
    if cfg.use_mla:
        p["attn"] = init_mla_params(ks[0], cfg)
    else:
        p["attn"] = init_attention_params(ks[0], cfg)
    if cfg.moe_experts:
        p["moe"] = init_moe_params(ks[1], cfg)
    else:
        p["mlp"] = dense_ffn_init(ks[1], cfg)
    return p


def _stack(blocks):
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *blocks)


def init_params(cfg: ModelConfig, key) -> Params:
    keys = jax.random.split(key, cfg.n_layers + 8)
    p: Params = {}
    p["embed"] = embed_init(keys[-1], (cfg.vocab_size, cfg.d_model),
                            cfg.param_dtype)
    p["final_norm"] = jnp.ones((cfg.d_model,), cfg.param_dtype)
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(keys[-2], (cfg.d_model, cfg.vocab_size),
                                  cfg.param_dtype)

    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        p["layers"] = _stack([_init_decoder_block(keys[i], cfg)
                              for i in range(cfg.n_layers)])
        if cfg.use_mtp:
            p["mtp"] = {
                "proj": dense_init(keys[-3], (2 * cfg.d_model, cfg.d_model),
                                   cfg.param_dtype),
                "block": _init_decoder_block(keys[-4], cfg),
                "ln_h": jnp.ones((cfg.d_model,), cfg.param_dtype),
                "ln_e": jnp.ones((cfg.d_model,), cfg.param_dtype),
            }
    elif fam == "hybrid":
        p["layers"] = _stack([init_mamba2_params(keys[i], cfg)
                              for i in range(cfg.n_layers)])
        kk = jax.random.split(keys[-3], 3)
        p["shared_attn"] = {
            "ln": jnp.ones((cfg.d_model,), cfg.param_dtype),
            "attn": init_attention_params(kk[0], cfg),
            "ln2": jnp.ones((cfg.d_model,), cfg.param_dtype),
            "mlp": dense_ffn_init(kk[1], cfg),
        }
    elif fam == "ssm":
        assert cfg.n_layers % 2 == 0
        npairs = cfg.n_layers // 2
        p["layers"] = {
            "slstm": _stack([init_slstm_params(keys[2 * i], cfg)
                             for i in range(npairs)]),
            "mlstm": _stack([init_mlstm_params(keys[2 * i + 1], cfg)
                             for i in range(npairs)]),
        }
    elif fam == "audio":
        enc, dec = [], []
        for i in range(cfg.n_encoder_layers):
            ks = jax.random.split(keys[i], 2)
            enc.append({
                "ln1_s": jnp.ones((cfg.d_model,), cfg.param_dtype),
                "ln1_b": jnp.zeros((cfg.d_model,), cfg.param_dtype),
                "attn": init_attention_params(ks[0], cfg),
                "ln2_s": jnp.ones((cfg.d_model,), cfg.param_dtype),
                "ln2_b": jnp.zeros((cfg.d_model,), cfg.param_dtype),
                "mlp": gelu_ffn_init(ks[1], cfg),
            })
        for i in range(cfg.n_layers):
            ks = jax.random.split(keys[cfg.n_encoder_layers + i], 3)
            dec.append({
                "ln1_s": jnp.ones((cfg.d_model,), cfg.param_dtype),
                "ln1_b": jnp.zeros((cfg.d_model,), cfg.param_dtype),
                "attn": init_attention_params(ks[0], cfg),
                "lnx_s": jnp.ones((cfg.d_model,), cfg.param_dtype),
                "lnx_b": jnp.zeros((cfg.d_model,), cfg.param_dtype),
                "xattn": init_attention_params(ks[1], cfg, cross=True),
                "ln2_s": jnp.ones((cfg.d_model,), cfg.param_dtype),
                "ln2_b": jnp.zeros((cfg.d_model,), cfg.param_dtype),
                "mlp": gelu_ffn_init(ks[2], cfg),
            })
        p["enc_layers"] = _stack(enc)
        p["dec_layers"] = _stack(dec)
        p["enc_final_s"] = jnp.ones((cfg.d_model,), cfg.param_dtype)
        p["enc_final_b"] = jnp.zeros((cfg.d_model,), cfg.param_dtype)
        # sized for the decode_32k dry-run cell (whisper convention is 448;
        # mechanical lowering far beyond it — see configs/whisper_tiny.py)
        p["dec_pos_embed"] = embed_init(keys[-5], (32768, cfg.d_model),
                                        cfg.param_dtype)
    else:
        raise ValueError(f"unknown family {fam}")
    return p


# ===========================================================================
# forward (train / prefill)
# ===========================================================================

def _maybe_remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


def _decoder_block_apply(cfg: ModelConfig, lp: Params, x, positions):
    # Megatron-style sequence parallelism: the residual stream lives
    # seq-sharded (cheap remat residuals); activations are all-gathered to
    # full sequence right before each matmul region and reduce-scattered
    # back at the residual add.  Without the explicit gather, GSPMD
    # resolves the seq/ff axis conflict by replicating whole weight
    # matrices instead (~25x the wire bytes — EXPERIMENTS.md §Perf).
    h = rms_norm(lp["ln1"], x, cfg.norm_eps)
    h = shard(h, "batch", None, "embed")          # all-gather seq
    if cfg.use_mla:
        a = mla_attention(lp["attn"], h, positions, cfg)
    else:
        a = multihead_attention(lp["attn"], h, positions, cfg, causal=True)
    x = shard(x + a, "batch", "seq", "embed")     # reduce-scatter seq
    h = rms_norm(lp["ln2"], x, cfg.norm_eps)
    h = shard(h, "batch", None, "embed")          # all-gather seq
    if cfg.moe_experts:
        f, aux = moe_ffn(lp["moe"], h, cfg, impl=cfg.moe_impl)
    else:
        f, aux = dense_ffn(lp["mlp"], h), jnp.zeros((), jnp.float32)
    return shard(x + f, "batch", "seq", "embed"), aux


def _run_decoder_stack(params, cfg: ModelConfig, x, positions):
    block = _maybe_remat(
        functools.partial(_decoder_block_apply, cfg), cfg)

    def body(carry, lp):
        h, aux = carry
        h2, a = block(lp, h, positions)
        return (h2, aux + a), None

    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                               params["layers"])
    return x, aux


def _run_hybrid_stack(params, cfg: ModelConfig, x, positions):
    shared = params["shared_attn"]
    period = cfg.hybrid_shared_period

    def apply_shared(h):
        a = multihead_attention(shared["attn"],
                                rms_norm(shared["ln"], h, cfg.norm_eps),
                                positions, cfg, causal=True)
        h = h + a
        f = dense_ffn(shared["mlp"], rms_norm(shared["ln2"], h, cfg.norm_eps))
        return h + f

    def block(lp, i, h):
        h = jax.lax.cond(i % period == 0, apply_shared, lambda y: y, h)
        m = mamba2_forward(lp, h, cfg)
        return shard(h + m, "batch", "seq", "embed")

    block = _maybe_remat(block, cfg)

    def body(h, inputs):
        lp, i = inputs
        return block(lp, i, h), None

    x, _ = jax.lax.scan(body, x,
                        (params["layers"], jnp.arange(cfg.n_layers)))
    return x, jnp.zeros((), jnp.float32)


def _run_ssm_stack(params, cfg: ModelConfig, x):
    def pair(lp, h):
        h = h + slstm_forward(lp["slstm"], h, cfg)
        h = h + mlstm_forward(lp["mlstm"], h, cfg)
        return shard(h, "batch", "seq", "embed")

    pair = _maybe_remat(pair, cfg)

    def body(h, lp):
        return pair(lp, h), None

    x, _ = jax.lax.scan(body, x, params["layers"])
    return x, jnp.zeros((), jnp.float32)


def _lm_head(params, cfg: ModelConfig, x):
    x = rms_norm(params["final_norm"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        w = params["embed"].astype(x.dtype).T
    else:
        w = params["lm_head"].astype(x.dtype)
    logits = x @ w
    return shard(logits, "batch", None, "vocab")


def _embed_tokens(params, cfg: ModelConfig, tokens):
    x = params["embed"][tokens].astype(cfg.dtype)
    return shard(x, "batch", "seq", "embed")


def _whisper_encode(params, cfg: ModelConfig, frames):
    B, S, _ = frames.shape
    pos = jnp.asarray(sinusoidal_positions(S, cfg.d_model),
                      dtype=cfg.dtype)
    x = frames.astype(cfg.dtype) + pos[None]

    def body(h, lp):
        a = multihead_attention(
            lp["attn"], layer_norm(lp["ln1_s"], lp["ln1_b"], h), None, cfg,
            causal=False)
        h = h + a
        f = gelu_ffn(lp["mlp"], layer_norm(lp["ln2_s"], lp["ln2_b"], h))
        return h + f, None

    x, _ = jax.lax.scan(_maybe_remat(body, cfg), x, params["enc_layers"])
    return layer_norm(params["enc_final_s"], params["enc_final_b"], x)


def _whisper_decode_stack(params, cfg: ModelConfig, x, enc_out):
    def body(h, lp):
        a = multihead_attention(
            lp["attn"], layer_norm(lp["ln1_s"], lp["ln1_b"], h), None, cfg,
            causal=True)
        h = h + a
        c = multihead_attention(
            lp["xattn"], layer_norm(lp["lnx_s"], lp["lnx_b"], h), None, cfg,
            causal=False, x_kv=enc_out)
        h = h + c
        f = gelu_ffn(lp["mlp"], layer_norm(lp["ln2_s"], lp["ln2_b"], h))
        return h + f, None

    x, _ = jax.lax.scan(_maybe_remat(body, cfg), x, params["dec_layers"])
    return x


def forward(params: Params, cfg: ModelConfig, batch: Dict[str, jax.Array]
            ) -> Tuple[jax.Array, jax.Array]:
    """Returns (logits (B,S,V), aux_loss)."""
    fam = cfg.family
    if fam == "audio":
        enc = _whisper_encode(params, cfg, batch["frames"])
        tokens = batch["tokens"]
        x = _embed_tokens(params, cfg, tokens)
        S = tokens.shape[1]
        x = x + params["dec_pos_embed"][:S][None].astype(x.dtype)
        x = _whisper_decode_stack(params, cfg, x, enc)
        # whisper final norm uses LayerNorm; reuse final_norm as scale
        x = rms_norm(params["final_norm"], x, cfg.norm_eps)
        if cfg.tie_embeddings:
            logits = x @ params["embed"].astype(x.dtype).T
        else:
            logits = x @ params["lm_head"].astype(x.dtype)
        return logits, jnp.zeros((), jnp.float32)

    tokens = batch["tokens"]
    B, S = tokens.shape
    x = _embed_tokens(params, cfg, tokens)

    if fam == "vlm" and "patch_embeds" in batch:
        pe = batch["patch_embeds"].astype(x.dtype)
        x = jax.lax.dynamic_update_slice(x, pe, (0, 1, 0))

    if cfg.mrope_sections is not None:
        positions = batch.get("positions")
        if positions is None:
            t = jnp.arange(S)[None, :, None]
            positions = jnp.broadcast_to(t, (B, S, 3)).astype(jnp.int32)
    else:
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    if fam in ("dense", "moe", "vlm"):
        x, aux = _run_decoder_stack(params, cfg, x, positions)
    elif fam == "hybrid":
        x, aux = _run_hybrid_stack(params, cfg, x, positions)
    elif fam == "ssm":
        x, aux = _run_ssm_stack(params, cfg, x)
    else:
        raise ValueError(fam)
    return _lm_head(params, cfg, x), aux


# ===========================================================================
# prefill (forward + cache extraction for serving)
# ===========================================================================

def prefill_step(params: Params, cfg: ModelConfig,
                 batch: Dict[str, jax.Array]):
    """Forward pass that also returns the decode cache built from the
    prompt.  Cache layouts match ``decode_step``'s expectations (length-S
    KV; the serving engine right-pads to its max length)."""
    fam = cfg.family
    if fam == "audio":
        enc = _whisper_encode(params, cfg, batch["frames"])
        tokens = batch["tokens"]
        x = _embed_tokens(params, cfg, tokens)
        S = tokens.shape[1]
        x = x + params["dec_pos_embed"][:S][None].astype(x.dtype)

        def body(h, lp):
            a, kv = multihead_attention(
                lp["attn"], layer_norm(lp["ln1_s"], lp["ln1_b"], h), None,
                cfg, causal=True, return_kv=True)
            h = h + a
            c, xkv = multihead_attention(
                lp["xattn"], layer_norm(lp["lnx_s"], lp["lnx_b"], h), None,
                cfg, causal=False, x_kv=enc, return_kv=True)
            h = h + c
            f = gelu_ffn(lp["mlp"], layer_norm(lp["ln2_s"], lp["ln2_b"], h))
            return h + f, (kv[0], kv[1], xkv[0], xkv[1])

        x, (k, v, ck, cv) = jax.lax.scan(body, x, params["dec_layers"])
        x = rms_norm(params["final_norm"], x, cfg.norm_eps)
        logits = x @ (params["embed"].astype(x.dtype).T if cfg.tie_embeddings
                      else params["lm_head"].astype(x.dtype))
        return logits, {"k": k, "v": v, "cross_k": ck, "cross_v": cv}

    tokens = batch["tokens"]
    B, S = tokens.shape
    x = _embed_tokens(params, cfg, tokens)
    if fam == "vlm" and "patch_embeds" in batch:
        x = jax.lax.dynamic_update_slice(
            x, batch["patch_embeds"].astype(x.dtype), (0, 1, 0))
    if cfg.mrope_sections is not None:
        positions = batch.get("positions")
        if positions is None:
            positions = jnp.broadcast_to(
                jnp.arange(S)[None, :, None], (B, S, 3)).astype(jnp.int32)
    else:
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    if fam in ("dense", "moe", "vlm"):
        def body(h, lp):
            hn = rms_norm(lp["ln1"], h, cfg.norm_eps)
            if cfg.use_mla:
                a, kv = mla_attention(lp["attn"], hn, positions, cfg,
                                      return_cache=True)
            else:
                a, kv = multihead_attention(lp["attn"], hn, positions, cfg,
                                            causal=True, return_kv=True)
            h = shard(h + a, "batch", None, "embed")
            hn = rms_norm(lp["ln2"], h, cfg.norm_eps)
            if cfg.moe_experts:
                f, _ = moe_ffn(lp["moe"], hn, cfg, impl=cfg.moe_impl)
            else:
                f = dense_ffn(lp["mlp"], hn)
            return shard(h + f, "batch", None, "embed"), kv

        x, kv = jax.lax.scan(_maybe_remat(body, cfg), x, params["layers"])
        cache = ({"ckv": kv[0], "krope": kv[1]} if cfg.use_mla
                 else {"k": kv[0], "v": kv[1]})
        return _lm_head(params, cfg, x), cache

    if fam == "hybrid":
        shared = params["shared_attn"]
        period = cfg.hybrid_shared_period
        W = min(S, cfg.sliding_window or S)

        def block(lp, i, h):
            def apply_shared(h):
                a, (kw, vw) = multihead_attention(
                    shared["attn"], rms_norm(shared["ln"], h, cfg.norm_eps),
                    positions, cfg, causal=True, return_kv=True)
                h = h + a
                f = dense_ffn(shared["mlp"],
                              rms_norm(shared["ln2"], h, cfg.norm_eps))
                return h + f, kw[:, -W:], vw[:, -W:]

            def no_shared(h):
                z = jnp.zeros((h.shape[0], W, cfg.n_kv_heads, cfg.hd),
                              h.dtype)
                return h, z, z

            h, kw, vw = jax.lax.cond(i % period == 0, apply_shared,
                                     no_shared, h)
            m, st = mamba2_forward(lp, h, cfg, return_state=True)
            return shard(h + m, "batch", "seq", "embed"), \
                (st["h"], st["conv"], kw, vw)

        def body(h, inputs):
            lp, i = inputs
            return block(lp, i, h)

        x, (hs, convs, kws, vws) = jax.lax.scan(
            body, x, (params["layers"], jnp.arange(cfg.n_layers)))
        cache = {"ssm_h": hs, "ssm_conv": convs,
                 "attn_k": kws[::cfg.hybrid_shared_period],
                 "attn_v": vws[::cfg.hybrid_shared_period]}
        return _lm_head(params, cfg, x), cache

    if fam == "ssm":
        def body(h, lp):
            s, sfin = slstm_forward(lp["slstm"], h, cfg, return_state=True)
            h = h + s
            m, mfin = mlstm_forward(lp["mlstm"], h, cfg, return_state=True)
            return shard(h + m, "batch", "seq", "embed"), \
                (sfin["c"], sfin["n"], sfin["h"], sfin["m"],
                 mfin["c"], mfin["n"], mfin["m"])

        x, outs = jax.lax.scan(body, x, params["layers"])
        cache = dict(zip(["s_c", "s_n", "s_h", "s_m", "m_c", "m_n", "m_m"],
                         outs))
        return _lm_head(params, cfg, x), cache

    raise ValueError(fam)


# ===========================================================================
# loss
# ===========================================================================

def _xent(logits, labels, mask=None):
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is not None:
        return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()


def loss_fn(params: Params, cfg: ModelConfig, batch: Dict[str, jax.Array]):
    logits, aux = forward(params, cfg, batch)
    tokens = batch["tokens"]
    labels = batch.get("labels")
    if labels is None:
        labels = jnp.concatenate(
            [tokens[:, 1:], jnp.zeros_like(tokens[:, :1])], axis=1)
        mask = jnp.concatenate(
            [jnp.ones_like(tokens[:, 1:], jnp.float32),
             jnp.zeros_like(tokens[:, :1], jnp.float32)], axis=1)
    else:
        mask = batch.get("loss_mask")
    loss = _xent(logits, labels, mask)

    metrics = {"ce_loss": loss, "aux_loss": aux}
    if cfg.use_mtp and "mtp" in params:
        mtp_loss = _mtp_loss(params, cfg, batch, tokens)
        metrics["mtp_loss"] = mtp_loss
        loss = loss + cfg.mtp_loss_weight * mtp_loss
    total = loss + aux
    metrics["loss"] = total
    return total, metrics


def _mtp_loss(params, cfg: ModelConfig, batch, tokens):
    """DeepSeek-V3 MTP (depth 1): predict token t+2 from h_t ++ emb_{t+1}."""
    mtp = params["mtp"]
    # recompute trunk hidden states cheaply? reuse forward's trunk would
    # need plumbing; MTP here re-embeds and runs ONE block over the shifted
    # stream — the paper's MTP module operates on final hidden states, so
    # we take the main-path embedding as a proxy trunk for the dry-run and
    # training alike (documented simplification, DESIGN.md §4).
    B, S = tokens.shape
    h = _embed_tokens(params, cfg, tokens)
    e_next = _embed_tokens(params, cfg,
                           jnp.roll(tokens, -1, axis=1))
    hcat = jnp.concatenate([rms_norm(mtp["ln_h"], h, cfg.norm_eps),
                            rms_norm(mtp["ln_e"], e_next, cfg.norm_eps)],
                           axis=-1)
    x = hcat @ mtp["proj"].astype(h.dtype)
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    if cfg.mrope_sections is not None:
        positions = jnp.broadcast_to(positions[..., None], (B, S, 3))
    x, _ = _decoder_block_apply(cfg, mtp["block"], x, positions)
    logits = _lm_head(params, cfg, x)
    labels = jnp.roll(tokens, -2, axis=1)
    mask = jnp.ones((B, S), jnp.float32).at[:, -2:].set(0.0)
    return _xent(logits, labels, mask)


# ===========================================================================
# decode (serving)
# ===========================================================================

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               enc_len: int = 0) -> Dict[str, jax.Array]:
    fam = cfg.family
    hd, K, L = cfg.hd, cfg.n_kv_heads, cfg.n_layers
    cdt = cfg.dtype
    if fam in ("dense", "vlm"):
        return {"k": jnp.zeros((L, batch, max_len, K, hd), cdt),
                "v": jnp.zeros((L, batch, max_len, K, hd), cdt)}
    if fam == "moe":
        if cfg.use_mla:
            return {"ckv": jnp.zeros((L, batch, max_len, cfg.kv_lora_rank), cdt),
                    "krope": jnp.zeros((L, batch, max_len,
                                        cfg.qk_rope_head_dim), cdt)}
        return {"k": jnp.zeros((L, batch, max_len, K, hd), cdt),
                "v": jnp.zeros((L, batch, max_len, K, hd), cdt)}
    if fam == "hybrid":
        npts = (cfg.n_layers + cfg.hybrid_shared_period - 1) \
            // cfg.hybrid_shared_period
        W = min(max_len, cfg.sliding_window or max_len)
        st = mamba2_init_state(cfg, batch, cdt)
        return {
            "ssm_h": jnp.zeros((L, *st["h"].shape), jnp.float32),
            "ssm_conv": jnp.zeros((L, *st["conv"].shape), cdt),
            "attn_k": jnp.zeros((npts, batch, W, K, hd), cdt),
            "attn_v": jnp.zeros((npts, batch, W, K, hd), cdt),
        }
    if fam == "ssm":
        np_ = cfg.n_layers // 2
        s0 = slstm_init_state(cfg, batch)
        m0 = mlstm_init_state(cfg, batch)
        return {
            "s_c": jnp.zeros((np_, *s0["c"].shape), jnp.float32),
            "s_n": jnp.zeros((np_, *s0["n"].shape), jnp.float32),
            "s_h": jnp.zeros((np_, *s0["h"].shape), jnp.float32),
            "s_m": jnp.full((np_, *s0["m"].shape), -1e30, jnp.float32),
            "m_c": jnp.zeros((np_, *m0["c"].shape), jnp.float32),
            "m_n": jnp.zeros((np_, *m0["n"].shape), jnp.float32),
            "m_m": jnp.full((np_, *m0["m"].shape), -1e30, jnp.float32),
        }
    if fam == "audio":
        return {
            "k": jnp.zeros((L, batch, max_len, K, hd), cdt),
            "v": jnp.zeros((L, batch, max_len, K, hd), cdt),
            "cross_k": jnp.zeros((L, batch, enc_len or 1500, K, hd), cdt),
            "cross_v": jnp.zeros((L, batch, enc_len or 1500, K, hd), cdt),
        }
    raise ValueError(fam)


def cache_logical_axes(cfg: ModelConfig) -> Dict[str, Tuple]:
    """Logical sharding for each cache entry (None -> replicated dim)."""
    seq = "seq" if cfg.seq_shard_attn else None
    kvh = None if cfg.seq_shard_attn else "kv_heads"
    fam = cfg.family
    if fam in ("dense", "vlm") or (fam == "moe" and not cfg.use_mla):
        return {"k": (None, "batch", seq, kvh, None),
                "v": (None, "batch", seq, kvh, None)}
    if fam == "moe":
        return {"ckv": (None, "batch", seq, None),
                "krope": (None, "batch", seq, None)}
    if fam == "hybrid":
        return {"ssm_h": (None, "batch", "heads", None, None),
                "ssm_conv": (None, "batch", None, None),
                "attn_k": (None, "batch", seq, kvh, None),
                "attn_v": (None, "batch", seq, kvh, None)}
    if fam == "ssm":
        return {"s_c": (None, "batch", None, None),
                "s_n": (None, "batch", None, None),
                "s_h": (None, "batch", None, None),
                "s_m": (None, "batch", None),
                "m_c": (None, "batch", "heads", None, None),
                "m_n": (None, "batch", "heads", None),
                "m_m": (None, "batch", None)}
    if fam == "audio":
        return {"k": (None, "batch", seq, kvh, None),
                "v": (None, "batch", seq, kvh, None),
                "cross_k": (None, "batch", None, kvh, None),
                "cross_v": (None, "batch", None, kvh, None)}
    raise ValueError(fam)


def decode_step(params: Params, cfg: ModelConfig, cache: Dict,
                tokens: jax.Array, cache_len: jax.Array):
    """One-token decode.  tokens: (B, 1) int32 -> (logits (B,1,V), cache)."""
    fam = cfg.family
    B = tokens.shape[0]
    x = _embed_tokens(params, cfg, tokens)
    pos = jnp.full((B,), cache_len, jnp.int32)

    if fam in ("dense", "moe", "vlm"):
        def body(h, inputs):
            lp, kc, vc_or = inputs
            hn = rms_norm(lp["ln1"], h, cfg.norm_eps)
            if cfg.use_mla:
                a, c1, c2 = mla_decode(lp["attn"], hn, pos, kc, vc_or,
                                       cache_len, cfg)
            else:
                a, c1, c2 = decode_attention(lp["attn"], hn, pos, kc, vc_or,
                                             cache_len, cfg)
            h = h + a
            hn = rms_norm(lp["ln2"], h, cfg.norm_eps)
            if cfg.moe_experts:
                f, _ = moe_ffn(lp["moe"], hn, cfg, impl=cfg.moe_impl)
            else:
                f = dense_ffn(lp["mlp"], hn)
            return h + f, (c1, c2)

        if cfg.use_mla:
            xs = (params["layers"], cache["ckv"], cache["krope"])
        else:
            xs = (params["layers"], cache["k"], cache["v"])
        x, (c1, c2) = jax.lax.scan(body, x, xs)
        if cfg.use_mla:
            cache = {"ckv": c1, "krope": c2}
        else:
            cache = {"k": c1, "v": c2}

    elif fam == "hybrid":
        shared = params["shared_attn"]
        period = cfg.hybrid_shared_period
        W = cache["attn_k"].shape[2]
        # effective in-window write position for the ring cache
        wpos = jnp.minimum(cache_len, W - 1)
        kc_all, vc_all = cache["attn_k"], cache["attn_v"]

        def body(carry, inputs):
            h, kc_all, vc_all = carry
            lp, i = inputs

            def with_attn(h, kc_all=kc_all, vc_all=vc_all):
                j = i // period
                kc = kc_all[j]
                vc = vc_all[j]
                # sliding-window ring: once full, shift left by one so the
                # write position stays at W-1 (O(W) copy; perf pass note)
                full = cache_len >= W
                kc = jnp.where(full, jnp.roll(kc, -1, axis=1), kc)
                vc = jnp.where(full, jnp.roll(vc, -1, axis=1), vc)
                a, kc2, vc2 = decode_attention(
                    shared["attn"], rms_norm(shared["ln"], h, cfg.norm_eps),
                    pos, kc, vc, wpos, cfg)
                h2 = h + a
                f = dense_ffn(shared["mlp"],
                              rms_norm(shared["ln2"], h2, cfg.norm_eps))
                kc_all = jax.lax.dynamic_update_index_in_dim(kc_all, kc2, j, 0)
                vc_all = jax.lax.dynamic_update_index_in_dim(vc_all, vc2, j, 0)
                return h2 + f, kc_all, vc_all

            h, kc_all, vc_all = jax.lax.cond(
                i % period == 0, with_attn,
                lambda h, kc_all=kc_all, vc_all=vc_all: (h, kc_all, vc_all), h)
            m, st2 = mamba2_decode(lp["blk"], h, {"h": lp["h"],
                                                  "conv": lp["conv"]}, cfg)
            return (h + m, kc_all, vc_all), (st2["h"], st2["conv"])

        xs = ({"blk": params["layers"], "h": cache["ssm_h"],
               "conv": cache["ssm_conv"]}, jnp.arange(cfg.n_layers))
        (x, kc_all, vc_all), (hs, convs) = jax.lax.scan(body, (x, kc_all, vc_all), xs)
        cache = {"ssm_h": hs, "ssm_conv": convs,
                 "attn_k": kc_all, "attn_v": vc_all}

    elif fam == "ssm":
        def body(h, lp):
            s_state = {"c": lp["s_c"], "n": lp["s_n"], "h": lp["s_h"],
                       "m": lp["s_m"]}
            s, s2 = slstm_decode(lp["blk"]["slstm"], h, s_state, cfg)
            h = h + s
            m_state = {"c": lp["m_c"], "n": lp["m_n"], "m": lp["m_m"]}
            m, m2 = mlstm_decode(lp["blk"]["mlstm"], h, m_state, cfg)
            return h + m, (s2["c"], s2["n"], s2["h"], s2["m"],
                           m2["c"], m2["n"], m2["m"])

        xs = {"blk": params["layers"], "s_c": cache["s_c"],
              "s_n": cache["s_n"], "s_h": cache["s_h"], "s_m": cache["s_m"],
              "m_c": cache["m_c"], "m_n": cache["m_n"], "m_m": cache["m_m"]}
        x, outs = jax.lax.scan(body, x, xs)
        cache = dict(zip(["s_c", "s_n", "s_h", "s_m", "m_c", "m_n", "m_m"],
                         outs))

    elif fam == "audio":
        x = x + params["dec_pos_embed"][cache_len][None, None].astype(x.dtype)

        def body(h, inputs):
            lp, kc, vc, ck, cv = inputs
            a, kc, vc = decode_attention(
                lp["attn"], layer_norm(lp["ln1_s"], lp["ln1_b"], h), pos,
                kc, vc, cache_len, cfg)
            h = h + a
            # cross attention against the precomputed encoder KV
            c, _, _ = decode_attention(
                lp["xattn"], layer_norm(lp["lnx_s"], lp["lnx_b"], h),
                pos, ck, cv, jnp.asarray(ck.shape[1] - 1, jnp.int32), cfg,
                update_cache=False)
            h = h + c
            f = gelu_ffn(lp["mlp"], layer_norm(lp["ln2_s"], lp["ln2_b"], h))
            return h + f, (kc, vc)

        xs = (params["dec_layers"], cache["k"], cache["v"],
              cache["cross_k"], cache["cross_v"])
        x, (kc, vc) = jax.lax.scan(body, x, xs)
        cache = {"k": kc, "v": vc, "cross_k": cache["cross_k"],
                 "cross_v": cache["cross_v"]}
    else:
        raise ValueError(fam)

    logits = _lm_head(params, cfg, x)
    return logits, cache
