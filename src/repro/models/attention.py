"""GQA/MHA attention: train (chunked-causal), prefill, and cached decode.

Covers the assigned archs' attention variants: GQA with arbitrary kv-head
count, optional QKV bias (qwen2.5/qwen1.5), qk_norm (qwen3), sliding window
(zamba2 long-context), M-RoPE (qwen2-vl), cross-attention (whisper).

Training/prefill uses a q-block-chunked attention (``lax.scan`` over query
blocks) so the (S × S) score matrix never materializes — O(S·blk) live
memory, the TPU-idiomatic analogue of FlashAttention at the XLA level.  The
Pallas flash kernel in ``repro.kernels.flash_attention`` is the
hand-tiled TPU version of the same computation (``cfg.use_flash_kernel``).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import (ModelConfig, apply_mrope, apply_rope, dense_init,
                     rms_norm)

Params = Dict[str, Any]

NEG_INF = -1e30


def init_attention_params(key, cfg: ModelConfig, *, cross: bool = False) -> Params:
    hd = cfg.hd
    H, K, d = cfg.n_heads, cfg.n_kv_heads, cfg.d_model
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, H * hd), cfg.param_dtype),
        "wk": dense_init(ks[1], (d, K * hd), cfg.param_dtype),
        "wv": dense_init(ks[2], (d, K * hd), cfg.param_dtype),
        "wo": dense_init(ks[3], (H * hd, d), cfg.param_dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), cfg.param_dtype)
        p["bk"] = jnp.zeros((K * hd,), cfg.param_dtype)
        p["bv"] = jnp.zeros((K * hd,), cfg.param_dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), cfg.param_dtype)
        p["k_norm"] = jnp.ones((hd,), cfg.param_dtype)
    return p


def _project_qkv(p: Params, x: jax.Array, x_kv: jax.Array, cfg: ModelConfig,
                 positions, kv_positions) -> Tuple[jax.Array, jax.Array, jax.Array]:
    B, S, d = x.shape
    T = x_kv.shape[1]
    hd, H, K = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    q = x @ p["wq"].astype(x.dtype)
    k = x_kv @ p["wk"].astype(x.dtype)
    v = x_kv @ p["wv"].astype(x.dtype)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, T, K, hd)
    v = v.reshape(B, T, K, hd)
    if cfg.qk_norm:
        q = rms_norm(p["q_norm"], q, cfg.norm_eps)
        k = rms_norm(p["k_norm"], k, cfg.norm_eps)
    if positions is not None:  # rope (None for cross-attention / whisper)
        if cfg.mrope_sections is not None:
            q = apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
            k = apply_mrope(k, kv_positions, cfg.rope_theta, cfg.mrope_sections)
        else:
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, kv_positions, cfg.rope_theta)
    return q, k, v


def _sdpa_block(q, k, v, mask, scale):
    """q: (B,Sq,H,hd)  k/v: (B,T,H,hd) (KV pre-repeated to H heads).

    Flat-head einsums keep the head dim cleanly sharded on 'model'; a
    (K, G) factorization fragments the axis and makes GSPMD all-gather the
    logits (EXPERIMENTS.md §Perf, hillclimb B iteration 2).
    """
    from repro.parallel import logical_constraint as _shard
    logits = jnp.einsum("bshd,bthd->bhst", q, k,
                        preferred_element_type=jnp.float32) * scale
    logits = _shard(logits, "batch", "heads", None, None)
    if mask is not None:
        logits = logits + jnp.where(mask, 0.0, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhst,bthd->bshd", probs, v)
    return _shard(out, "batch", None, "heads", None)


def multihead_attention(p: Params, x: jax.Array, positions: jax.Array,
                        cfg: ModelConfig, *,
                        causal: bool = True,
                        x_kv: Optional[jax.Array] = None,
                        kv_positions: Optional[jax.Array] = None,
                        q_block: int = 1024,
                        return_kv: bool = False):
    """Full attention over a sequence (train / prefill / encoder / cross).

    Chunked over query blocks when S > q_block to bound live memory.
    """
    cross = x_kv is not None
    x_kv = x if x_kv is None else x_kv
    kv_positions = positions if kv_positions is None else kv_positions
    B, S, d = x.shape
    T = x_kv.shape[1]
    hd, H, K = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    G = H // K
    scale = 1.0 / math.sqrt(hd)

    q, k, v = _project_qkv(p, x, x_kv, cfg,
                           None if cross else positions,
                           None if cross else kv_positions)
    k_kv, v_kv = k, v          # pre-repeat KV (what the decode cache stores)
    # repeat KV to full heads: keeps the head axis contiguously sharded
    if G > 1:
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)

    if cfg.use_flash_kernel and causal and not cross \
            and cfg.sliding_window == 0 and S == T and S >= 256:
        from repro.kernels import ops as kops
        o = kops.flash_attention(q.reshape(B, S, H, 1, hd), k, v,
                                 scale=scale, causal=True)
        o = o.reshape(B, S, H * hd)
    elif S <= q_block:
        mask = None
        if causal and S == T:
            idx = jnp.arange(S)
            mask = idx[:, None] >= idx[None, :]
            if cfg.sliding_window:
                mask &= idx[:, None] - idx[None, :] < cfg.sliding_window
        o = _sdpa_block(q, k, v, mask, scale).reshape(B, S, H * hd)
    else:
        # q-block chunking for BOTH causal and bidirectional attention —
        # the (S x S) score matrix must never materialize at 32k+ tokens
        nblk = S // q_block
        assert S % q_block == 0, f"S={S} not divisible by q_block={q_block}"
        qb = q.reshape(B, nblk, q_block, H, hd)

        @jax.checkpoint  # recompute block logits in bwd: O(blk) live memory
        def one_block(_, qi_i):
            qi, i = qi_i
            if causal:
                row = i * q_block + jnp.arange(q_block)
                col = jnp.arange(T)
                mask = row[:, None] >= col[None, :]
                if cfg.sliding_window:
                    mask &= row[:, None] - col[None, :] < cfg.sliding_window
            else:
                mask = None
            return None, _sdpa_block(qi, k, v, mask, scale)

        _, ob = jax.lax.scan(one_block, None,
                             (jnp.moveaxis(qb, 1, 0), jnp.arange(nblk)))
        o = jnp.moveaxis(ob, 0, 1).reshape(B, S, H * hd)

    out = o @ p["wo"].astype(x.dtype)
    if return_kv:
        return out, (k_kv, v_kv)
    return out


def decode_attention(p: Params, x: jax.Array, position: jax.Array,
                     k_cache: jax.Array, v_cache: jax.Array,
                     cache_len: jax.Array, cfg: ModelConfig, *,
                     kv_positions: Optional[jax.Array] = None,
                     update_cache: bool = True):
    """Single-token decode against a (B, T, K, hd) KV cache.

    Returns (y, k_cache, v_cache).  The new token's K/V are written at
    ``cache_len`` (dynamic index).  With ``cfg.seq_shard_attn`` the cache's
    T axis is sharded over 'model' and GSPMD turns the softmax/PV reduction
    into a flash-decoding-style partial reduction + psum.
    """
    B, S1, d = x.shape  # S1 == 1
    hd, H, K = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    G = H // K
    T = k_cache.shape[1]
    scale = 1.0 / math.sqrt(hd)
    positions = position[:, None] if position.ndim == 1 else position

    if cfg.mrope_sections is not None and positions.ndim == 2:
        positions = jnp.broadcast_to(positions[..., None], (*positions.shape, 3))

    q, k_new, v_new = _project_qkv(
        p, x, x, cfg, positions,
        positions if kv_positions is None else kv_positions)

    if update_cache:
        # dynamic-slice write of the fresh K/V at cache_len
        k_cache = jax.lax.dynamic_update_slice(
            k_cache, k_new.astype(k_cache.dtype), (0, cache_len, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(
            v_cache, v_new.astype(v_cache.dtype), (0, cache_len, 0, 0))

    qg = q.reshape(B, 1, K, G, hd)
    logits = jnp.einsum("bskgh,btkh->bkgst", qg,
                        k_cache.astype(x.dtype),
                        preferred_element_type=jnp.float32) * scale
    t_idx = jnp.arange(T)
    valid = t_idx <= cache_len
    if cfg.sliding_window:
        valid &= t_idx > cache_len - cfg.sliding_window
    logits = jnp.where(valid[None, None, None, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
    o = jnp.einsum("bkgst,btkh->bskgh", probs, v_cache.astype(x.dtype))
    y = o.reshape(B, 1, H * hd) @ p["wo"].astype(x.dtype)
    return y, k_cache, v_cache
