"""Model-stack common pieces: config, norms, RoPE (incl. M-RoPE), init.

Functional style: parameters are plain pytrees (nested dicts of arrays);
every layer is a pure function ``f(params, x, ...)``.  No flax/haiku —
keeps tracing cheap, sharding explicit, and checkpointing trivial.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """One config describes every assigned architecture (unused fields 0/None)."""

    name: str = "model"
    family: str = "dense"          # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    d_ff: int = 1024
    vocab_size: int = 1024
    head_dim: int = 0              # 0 -> d_model // n_heads
    # attention options
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    mrope_sections: Optional[Tuple[int, int, int]] = None   # qwen2-vl
    sliding_window: int = 0        # 0 -> full attention
    # MLA (deepseek)
    use_mla: bool = False
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128
    # MoE
    moe_experts: int = 0           # 0 -> dense mlp
    moe_top_k: int = 1
    moe_shared_experts: int = 0
    moe_capacity_factor: float = 1.25
    moe_aux_loss_coef: float = 0.01
    moe_groups: int = 1            # token groups (align with data shards)
    moe_impl: str = "gather"       # gather | sort
    # MTP (deepseek multi-token prediction)
    use_mtp: bool = False
    mtp_loss_weight: float = 0.3
    # SSM (mamba2 / zamba2)
    ssm_state: int = 64
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_heads: int = 0             # 0 -> d_inner // 64
    hybrid_shared_period: int = 6  # zamba2: shared attn every k mamba blocks
    # xLSTM
    xlstm_slstm_every: int = 2     # sLSTM block at layer i % k == 0, else mLSTM
    # encoder-decoder (whisper)
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    # VLM / audio stubs: frontend provides embeddings directly
    frontend_stub: bool = False
    # numerics / partitioning
    dtype: Any = jnp.bfloat16      # activation/compute dtype
    param_dtype: Any = jnp.bfloat16
    remat: str = "full"            # none | full | dots
    use_flash_kernel: bool = False # Pallas flash-attention (TPU target)
    seq_shard_attn: bool = True    # shard long KV over 'model' (flash-decode)
    norm_eps: float = 1e-6
    tie_embeddings: bool = False

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.ssm_heads or max(1, self.d_inner // 64)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def dense_init(key, shape, dtype, fan_in: Optional[int] = None):
    fan = fan_in if fan_in is not None else shape[0]
    scale = 1.0 / math.sqrt(max(1, fan))
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rms_norm(scale: jax.Array, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    return out.astype(dt)


def layer_norm(scale: jax.Array, bias: jax.Array, x: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float64)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(hd, theta), dtype=jnp.float32)
    ang = positions.astype(jnp.float32)[..., None] * freqs      # (..., S, hd/2)
    cos = jnp.cos(ang)[..., None, :]                            # (..., S, 1, hd/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jax.Array, positions: jax.Array, theta: float,
                sections: Sequence[int]) -> jax.Array:
    """Multimodal RoPE (Qwen2-VL): positions (..., S, 3) = (t, h, w) ids;
    the hd/2 frequency slots are split into ``sections`` (sum = hd/2), each
    rotated by its own position stream."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(hd, theta), dtype=jnp.float32)  # (hd/2,)
    # section id per frequency slot
    sec_id = np.repeat(np.arange(len(sections)), sections)
    sec_id = jnp.asarray(sec_id)                                   # (hd/2,)
    pos = positions.astype(jnp.float32)                            # (..., S, 3)
    pos_per_slot = pos[..., sec_id]                                # (..., S, hd/2)
    ang = pos_per_slot * freqs
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(n: int, d: int) -> np.ndarray:
    """Whisper-style sinusoidal embeddings (n, d)."""
    pos = np.arange(n)[:, None]
    dim = np.arange(0, d, 2)[None, :]
    ang = pos / (10000.0 ** (dim / d))
    out = np.zeros((n, d), dtype=np.float32)
    out[:, 0::2] = np.sin(ang)
    out[:, 1::2] = np.cos(ang)
    return out
