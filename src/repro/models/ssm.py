"""Mamba2 (SSD) block — chunked state-space duality algorithm.

Training/prefill uses the SSD chunked algorithm (Mamba-2 paper §6):
within-chunk attention-like form with cumulative-decay masks, inter-chunk
``lax.scan`` carrying the (H, P, N) state.  Decode is the O(1) recurrence.
State h_t = a_t h_{t-1} + dt_t B_t x_t,  y_t = C_t h_t + D x_t, with
a_t = exp(dt_t * A_h) (scalar per head).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from .common import ModelConfig, dense_init, rms_norm

Params = Dict[str, Any]


def init_mamba2_params(key, cfg: ModelConfig) -> Params:
    d, din, ns = cfg.d_model, cfg.d_inner, cfg.ssm_state
    H = cfg.n_ssm_heads
    conv_dim = din + 2 * ns
    ks = jax.random.split(key, 5)
    return {
        # in_proj -> [z (din), x (din), B (ns), C (ns), dt (H)]
        "w_in": dense_init(ks[0], (d, 2 * din + 2 * ns + H), cfg.param_dtype),
        "conv_w": dense_init(ks[1], (cfg.ssm_conv, conv_dim), cfg.param_dtype,
                             fan_in=cfg.ssm_conv),
        "conv_b": jnp.zeros((conv_dim,), cfg.param_dtype),
        "a_log": jnp.zeros((H,), jnp.float32),          # A = -exp(a_log)
        "dt_bias": jnp.full((H,), -2.0, jnp.float32),   # softplus ~ 0.12
        "d_skip": jnp.ones((H,), jnp.float32),
        "norm": jnp.ones((din,), cfg.param_dtype),
        "norm_in": jnp.ones((d,), cfg.param_dtype),
        "w_out": dense_init(ks[2], (din, d), cfg.param_dtype),
    }


def _split_proj(p, x, cfg: ModelConfig):
    din, ns, H = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    zxbcdt = x @ p["w_in"].astype(x.dtype)
    z = zxbcdt[..., :din]
    xbc = zxbcdt[..., din:din + din + 2 * ns]
    dt = zxbcdt[..., -H:]
    return z, xbc, dt


def _causal_conv(p, xbc: jax.Array, cfg: ModelConfig,
                 conv_state=None):
    """Depthwise causal conv, k=cfg.ssm_conv.  xbc: (B, S, conv_dim)."""
    k = cfg.ssm_conv
    w = p["conv_w"].astype(xbc.dtype)                    # (k, conv_dim)
    if conv_state is None:
        pad = jnp.zeros((xbc.shape[0], k - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = conv_state.astype(xbc.dtype)               # (B, k-1, conv_dim)
    xp = jnp.concatenate([pad, xbc], axis=1)
    out = sum(xp[:, i:i + xbc.shape[1]] * w[i] for i in range(k))
    out = jax.nn.silu(out + p["conv_b"].astype(xbc.dtype))
    new_state = xp[:, -(k - 1):] if k > 1 else pad
    return out, new_state


def mamba2_forward(p: Params, x: jax.Array, cfg: ModelConfig,
                   chunk: int = 256, return_state: bool = False):
    """Train/prefill SSD.  x: (B, S, d) -> (B, S, d) [, final state]."""
    B, S, d = x.shape
    din, ns, H = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    P = din // H
    x = rms_norm(p["norm_in"], x, cfg.norm_eps)
    z, xbc, dt = _split_proj(p, x, cfg)
    xbc, conv_state = _causal_conv(p, xbc, cfg)
    xs = xbc[..., :din].reshape(B, S, H, P)
    Bm = xbc[..., din:din + ns]                          # (B, S, N)
    Cm = xbc[..., din + ns:]                             # (B, S, N)

    dtp = jax.nn.softplus(dt.astype(jnp.float32)
                          + p["dt_bias"][None, None])    # (B, S, H)
    A = -jnp.exp(p["a_log"])                             # (H,)
    log_a = dtp * A[None, None]                          # (B, S, H) <= 0

    if S % chunk:
        chunk = S  # tiny sequences: single chunk
    nc = S // chunk
    Q = chunk
    # chunk-major leading axis for lax.scan; one chunk's (Q,Q,H) score
    # tensor lives at a time (SSD's SRAM tile, expressed at the XLA level)
    xs_c = jnp.moveaxis(xs.reshape(B, nc, Q, H, P), 1, 0)
    B_c = jnp.moveaxis(Bm.reshape(B, nc, Q, ns), 1, 0).astype(jnp.float32)
    C_c = jnp.moveaxis(Cm.reshape(B, nc, Q, ns), 1, 0).astype(jnp.float32)
    la_c = jnp.moveaxis(log_a.reshape(B, nc, Q, H), 1, 0)
    dt_c = jnp.moveaxis(dtp.reshape(B, nc, Q, H), 1, 0)
    tri = jnp.tril(jnp.ones((Q, Q), bool))

    def one_chunk(h, inputs):
        xc, bc, cc, lac, dtc = inputs                    # per-chunk slices
        cum = jnp.cumsum(lac, axis=1)                    # (B,Q,H)
        total = cum[:, -1]                               # (B,H)
        # intra: scores[t,j] = (C_t.B_j) exp(cum_t - cum_j) dt_j, j <= t
        cb = jnp.einsum("bqn,bkn->bqk", cc, bc)          # (B,Q,Q)
        decay = cum[:, :, None, :] - cum[:, None, :, :]  # (B,Q,Q,H)
        scores = jnp.where(tri[None, :, :, None], jnp.exp(decay), 0.0) \
            * cb[..., None] * dtc[:, None, :, :]
        y_intra = jnp.einsum("bqkh,bkhp->bqhp", scores,
                             xc.astype(jnp.float32))
        # inter: y_t += C_t (exp(cum_t) h_carry)
        y_inter = jnp.einsum("bqn,bhpn->bqhp", cc, h) \
            * jnp.exp(cum)[..., None]
        # carry update: h' = exp(total) h + sum_j exp(total-cum_j) dt_j B_j x_j
        wj = jnp.exp(total[:, None] - cum) * dtc         # (B,Q,H)
        h_new = h * jnp.exp(total)[:, :, None, None] + jnp.einsum(
            "bqh,bqn,bqhp->bhpn", wj, bc, xc.astype(jnp.float32))
        return h_new, y_intra + y_inter

    h0 = jnp.zeros((B, H, P, ns), jnp.float32)
    h_fin, y_c = jax.lax.scan(one_chunk, h0, (xs_c, B_c, C_c, la_c, dt_c))
    y = jnp.moveaxis(y_c, 0, 1).reshape(B, S, H, P)
    y = y + p["d_skip"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(B, S, din).astype(x.dtype)
    y = rms_norm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = y @ p["w_out"].astype(x.dtype)
    if return_state:
        return out, {"h": h_fin, "conv": conv_state}
    return out


def mamba2_init_state(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    H, P, ns = cfg.n_ssm_heads, cfg.d_inner // cfg.n_ssm_heads, cfg.ssm_state
    return {
        "h": jnp.zeros((batch, H, P, ns), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1,
                           cfg.d_inner + 2 * cfg.ssm_state), dtype),
    }


def mamba2_decode(p: Params, x: jax.Array, state: Dict, cfg: ModelConfig):
    """Single-token recurrence.  x: (B, 1, d)."""
    B = x.shape[0]
    din, ns, H = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    P = din // H
    x = rms_norm(p["norm_in"], x, cfg.norm_eps)
    z, xbc, dt = _split_proj(p, x, cfg)
    xbc, conv_state = _causal_conv(p, xbc, cfg, conv_state=state["conv"])
    xs = xbc[:, 0, :din].reshape(B, H, P)
    Bm = xbc[:, 0, din:din + ns].astype(jnp.float32)
    Cm = xbc[:, 0, din + ns:].astype(jnp.float32)
    dtp = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"][None])
    a = jnp.exp(dtp * (-jnp.exp(p["a_log"]))[None])      # (B,H)
    h = state["h"] * a[:, :, None, None] + jnp.einsum(
        "bh,bn,bhp->bhpn", dtp, Bm, xs.astype(jnp.float32))
    y = jnp.einsum("bn,bhpn->bhp", Cm, h)
    y = y + p["d_skip"][None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(B, 1, din).astype(x.dtype)
    y = rms_norm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    return y @ p["w_out"].astype(x.dtype), {"h": h, "conv": conv_state}
