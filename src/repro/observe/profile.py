"""Runtime overlap profiler: device timelines -> per-phase breakdown.

`repro.analysis` proves the paper's overlap claim *structurally* (no
dependency edge from the fused reduction to the in-flight matvec in the
jaxpr/HLO); this module measures it *at runtime*.  A capture context
wraps execution in :func:`jax.profiler.trace`, the emitted perfetto
trace-event timeline is parsed with the stdlib (gzip + json — no
TensorFlow/xprof dependency), device op events are attributed to solver
phases, and the headline number is computed:

    overlap efficiency = |reduce ∩ matvec| / |reduce|

the fraction of reduction/collective device wall time hidden under the
in-flight matvec (interval-union intersection, so concurrent ops are not
double counted), plus the complementary *exposed* communication time per
iteration — exactly how Cools & Vanroose evaluate pipelined solvers.

Phase attribution works in two layers:

1. **HLO metadata map.**  The solver loop bodies wrap their three phases
   in ``jax.named_scope("repro.matvec" | "repro.reduce" | "repro.axpy")``
   (see ``core/pipelined_bicgsafe.py``); those scopes survive into the
   compiled module's per-instruction ``metadata={op_name=...}``.  When a
   capture knows which jitted programs ran (the session front door notes
   them — see :func:`active_capture`), it lowers each with the recorded
   abstract shapes and parses ``compiled.as_text()`` into an
   ``{hlo_module: {instruction: scope path}}`` map.
2. **Name heuristics.**  Ops absent from the map (compiler-inserted
   copies, collectives renamed by SPMD partitioning) fall back to name
   patterns: ``all-reduce``/``psum``/``fused_dots`` -> reduce,
   ``collective-permute``/``ppermute``/``halo``/``spmv`` -> matvec,
   ``fused_axpy`` -> axpy.

Fusions that cross a scope boundary carry one representative op_name, so
per-phase times are attribution-exact only up to XLA's fusion decisions;
the reduce/matvec phases fuse cleanly in practice (dots and stencil
fusions are distinct instructions) and those two are all the headline
number reads.

On a single CPU device XLA executes thunks serially, so measured overlap
is honestly ~0 there — the efficiency math itself is pinned by golden
timeline fixtures in ``tests/test_profile.py``, and the multi-device
bindings report the real number.
"""
from __future__ import annotations

import dataclasses
import glob
import gzip
import json
import os
import re
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

SCHEMA_PROFILE = "repro.observe/profile/v1"

PHASES = ("matvec", "reduce", "axpy", "precond", "other")

# scope tag -> phase (layer 1); checked against the full op_name path
_SCOPE_TAGS = (("repro.reduce", "reduce"), ("repro.matvec", "matvec"),
               ("repro.axpy", "axpy"), ("repro.precond", "precond"))

# op-name pattern -> phase (layer 2 fallback); order matters
_NAME_RULES: Tuple[Tuple[str, str], ...] = (
    ("all-reduce", "reduce"), ("all_reduce", "reduce"),
    ("reduce-scatter", "reduce"), ("psum", "reduce"),
    ("fused_dots", "reduce"), ("bicgsafe_dots", "reduce"),
    ("collective-permute", "matvec"), ("ppermute", "matvec"),
    ("halo", "matvec"), ("spmv", "matvec"), ("stencil", "matvec"),
    ("fused_axpy", "axpy"), ("axpy_phase", "axpy"),
    ("precond", "precond"),
)


# ---------------------------------------------------------------------------
# timeline loading
# ---------------------------------------------------------------------------

def load_timeline(src: Any) -> Dict[str, Any]:
    """Load a Chrome trace-event document from a path (.json / .json.gz)
    or pass a dict through unchanged."""
    if isinstance(src, dict):
        return src
    opener = gzip.open if str(src).endswith(".gz") else open
    with opener(src, "rt") as fh:
        return json.load(fh)


def find_perfetto_trace(profile_dir: str) -> Optional[str]:
    """Newest ``perfetto_trace.json.gz`` under a jax.profiler dump dir."""
    hits = glob.glob(os.path.join(
        profile_dir, "plugins", "profile", "*", "perfetto_trace.json.gz"))
    return max(hits, key=os.path.getmtime) if hits else None


def _thread_names(events: Iterable[dict]) -> Dict[Tuple[Any, Any], str]:
    names = {}
    for e in events:
        if e.get("ph") == "M" and e.get("name") == "thread_name":
            names[(e.get("pid"), e.get("tid"))] = \
                e.get("args", {}).get("name", "")
    return names


def device_events(doc: Dict[str, Any]) -> List[dict]:
    """Complete device op events: ``ph == "X"`` carrying ``args.hlo_op``."""
    return [e for e in doc.get("traceEvents", [])
            if e.get("ph") == "X" and "hlo_op" in (e.get("args") or {})]


def host_spans(doc: Dict[str, Any]) -> Dict[str, Dict[str, float]]:
    """Aggregate host-side ``TraceAnnotation`` spans (the SpanRecorder
    names: ``api.*`` / ``engine.*``) by name -> {count, total_us}."""
    out: Dict[str, Dict[str, float]] = {}
    for e in doc.get("traceEvents", []):
        if e.get("ph") != "X" or "hlo_op" in (e.get("args") or {}):
            continue
        name = e.get("name", "")
        if not re.match(r"^(api|engine|repro)\.", name):
            continue
        rec = out.setdefault(name, {"count": 0, "total_us": 0.0})
        rec["count"] += 1
        rec["total_us"] += float(e.get("dur", 0.0))
    return out


# ---------------------------------------------------------------------------
# HLO metadata map
# ---------------------------------------------------------------------------

_MODULE_RE = re.compile(r"HloModule ([^,\s]+)")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([A-Za-z0-9_.\-]+)\s*\(.*\{\s*$")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([A-Za-z0-9_.\-]+) = ")
_CALLS_RE = re.compile(r"calls=%?([A-Za-z0-9_.\-]+)")
_OPNAME_RE = re.compile(r'op_name="([^"]+)"')

#: when a fusion's body spans scopes, the highest-priority tag wins —
#: reduce first, so boundary-crossing fusions bias the efficiency DOWN
#: (any reduction work they contain is counted as reduction time)
_TAG_PRIORITY = ("repro.reduce", "repro.matvec", "repro.axpy",
                 "repro.precond")


def hlo_op_map(compiled_text: str) -> Tuple[str, Dict[str, str]]:
    """Parse ``compiled.as_text()`` into (module name, {instruction:
    op_name scope path}).

    XLA fuses whole phases into single instructions whose own metadata
    names one representative op; the instructions *inside* the called
    ``%fused_computation`` keep their full scope paths.  A fusion is
    therefore attributed by the tagged scopes of its body (priority:
    reduce > matvec > axpy), falling back to its own metadata.
    """
    m = _MODULE_RE.search(compiled_text)
    module = m.group(1) if m else ""
    ops: Dict[str, str] = {}
    comp_tags: Dict[str, set] = {}
    fusion_calls: Dict[str, str] = {}
    current = ""
    for line in compiled_text.splitlines():
        cm = _COMP_RE.match(line.strip()) if line.rstrip().endswith("{") \
            else None
        if cm:
            current = cm.group(1)
            continue
        im = _INSTR_RE.match(line)
        if not im:
            continue
        name = im.group(1)
        om = _OPNAME_RE.search(line)
        scope = om.group(1) if om else ""
        if scope:
            ops[name] = scope
            for tag in _TAG_PRIORITY:
                if tag in scope:
                    comp_tags.setdefault(current, set()).add(tag)
                    break
        calls = _CALLS_RE.search(line)
        if calls:
            fusion_calls[name] = calls.group(1)
    for name, comp in fusion_calls.items():
        tags = comp_tags.get(comp)
        if not tags:
            continue
        own = ops.get(name, "")
        if any(t in own for t in _TAG_PRIORITY):
            continue                      # own metadata already tagged
        best = next(t for t in _TAG_PRIORITY if t in tags)
        ops[name] = f"{own}#{best}" if own else best
    return module, ops


def classify_op(name: str, scope: str = "") -> str:
    """Phase of one device op: scope tags first, then name patterns."""
    hay = f"{scope}/{name}".lower()
    for tag, phase in _SCOPE_TAGS:
        if tag in hay:
            return phase
    for pat, phase in _NAME_RULES:
        if pat in hay:
            return phase
    return "other"


# ---------------------------------------------------------------------------
# interval math
# ---------------------------------------------------------------------------

def merge_intervals(iv: Sequence[Tuple[float, float]]) \
        -> List[Tuple[float, float]]:
    """Union of half-open intervals, sorted and coalesced."""
    out: List[Tuple[float, float]] = []
    for s, e in sorted(i for i in iv if i[1] > i[0]):
        if out and s <= out[-1][1]:
            out[-1] = (out[-1][0], max(out[-1][1], e))
        else:
            out.append((s, e))
    return out


def intersect_intervals(a: Sequence[Tuple[float, float]],
                        b: Sequence[Tuple[float, float]]) \
        -> List[Tuple[float, float]]:
    """Intersection of two merged interval lists (two-pointer sweep)."""
    out, i, j = [], 0, 0
    while i < len(a) and j < len(b):
        lo = max(a[i][0], b[j][0])
        hi = min(a[i][1], b[j][1])
        if lo < hi:
            out.append((lo, hi))
        if a[i][1] <= b[j][1]:
            i += 1
        else:
            j += 1
    return out


def total(iv: Sequence[Tuple[float, float]]) -> float:
    return sum(e - s for s, e in iv)


# ---------------------------------------------------------------------------
# report
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ProfileReport:
    """Per-phase device-time breakdown + the headline overlap numbers.

    Times are microseconds of *device op wall time* (interval union per
    phase, so concurrent ops on different device lanes are not double
    counted).  ``overlap_efficiency`` is None when no reduce-phase device
    time was observed.
    """
    phase_us: Dict[str, float]
    phase_ops: Dict[str, int]
    device_wall_us: float
    reduce_us: float
    matvec_us: float
    hidden_us: float
    exposed_us: float
    overlap_efficiency: Optional[float]
    iterations: Optional[int]
    exposed_per_iter_us: Optional[float]
    n_device_events: int
    unmapped_ops: int
    host_spans: Dict[str, Dict[str, float]]
    label: str = ""
    timeline_path: Optional[str] = None

    def to_json(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["schema"] = SCHEMA_PROFILE
        return d

    @classmethod
    def from_json(cls, d: Dict[str, Any]) -> "ProfileReport":
        d = {k: v for k, v in d.items() if k != "schema"}
        return cls(**d)

    def save(self, path: str) -> str:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as fh:
            json.dump(self.to_json(), fh, indent=1, sort_keys=True)
        return path

    @classmethod
    def load(cls, path: str) -> "ProfileReport":
        with open(path) as fh:
            return cls.from_json(json.load(fh))

    def render(self, width: int = 46) -> str:
        lines = [f"== phase breakdown{f' ({self.label})' if self.label else ''} =="]
        denom = max(self.device_wall_us, 1e-9)
        for ph in PHASES:
            us = self.phase_us.get(ph, 0.0)
            if not us and ph not in ("matvec", "reduce"):
                continue
            frac = us / denom
            bar = "█" * int(round(width * min(frac, 1.0)))
            lines.append(f"  {ph:<8} {us / 1e3:9.3f} ms "
                         f"|{bar:<{width}}| {100 * frac:5.1f}%  "
                         f"({self.phase_ops.get(ph, 0)} ops)")
        lines.append(f"  device wall {self.device_wall_us / 1e3:.3f} ms, "
                     f"{self.n_device_events} device events"
                     + (f", {self.unmapped_ops} unmapped"
                        if self.unmapped_ops else ""))
        if self.overlap_efficiency is None:
            lines.append("  overlap: no reduce-phase device time observed")
        else:
            lines.append(
                f"  reduce {self.reduce_us / 1e3:.3f} ms: "
                f"{self.hidden_us / 1e3:.3f} ms hidden under matvec, "
                f"{self.exposed_us / 1e3:.3f} ms exposed "
                f"-> overlap efficiency {self.overlap_efficiency:.3f}")
            if self.exposed_per_iter_us is not None:
                lines.append(
                    f"  exposed communication per iteration: "
                    f"{self.exposed_per_iter_us:.2f} us"
                    + (f" ({self.iterations} iterations)"
                       if self.iterations else ""))
        return "\n".join(lines)


def analyze_timeline(src: Any,
                     hlo_maps: Optional[Dict[str, Dict[str, str]]] = None,
                     iterations: Optional[int] = None,
                     label: str = "") -> ProfileReport:
    """Parse one trace-event timeline into a :class:`ProfileReport`.

    ``src`` is a path (.json/.json.gz) or a loaded trace dict;
    ``hlo_maps`` is ``{hlo_module: {instruction: op_name scope}}`` from
    :func:`hlo_op_map`.  ``iterations`` (solver iterations inside the
    capture window) enables the per-iteration exposed time; when omitted
    it is estimated as the execution count of the most-run reduce op.
    """
    doc = load_timeline(src)
    hlo_maps = hlo_maps or {}
    events = device_events(doc)

    phase_iv: Dict[str, List[Tuple[float, float]]] = {p: [] for p in PHASES}
    phase_ops: Dict[str, set] = {p: set() for p in PHASES}
    op_counts: Dict[Tuple[str, str, str], int] = {}
    unmapped = 0
    for e in events:
        args = e["args"]
        op = str(args.get("hlo_op", e.get("name", "")))
        module = str(args.get("hlo_module", ""))
        scope = hlo_maps.get(module, {}).get(op, "")
        if not scope:
            # SPMD partitioning renames modules (e.g. ".spmd"); retry on
            # prefix match before falling back to name heuristics only.
            for mod, ops in hlo_maps.items():
                if module.startswith(mod) or mod.startswith(module):
                    scope = ops.get(op, "")
                    if scope:
                        break
        if not scope:
            unmapped += 1
        phase = classify_op(op, scope)
        ts = float(e.get("ts", 0.0))
        dur = float(e.get("dur", 0.0))
        phase_iv[phase].append((ts, ts + dur))
        phase_ops[phase].add((module, op))
        key = (module, op, phase)
        op_counts[key] = op_counts.get(key, 0) + 1

    merged = {p: merge_intervals(iv) for p, iv in phase_iv.items()}
    phase_us = {p: total(iv) for p, iv in merged.items()}
    all_iv = merge_intervals([i for iv in phase_iv.values() for i in iv])

    R, V = merged["reduce"], merged["matvec"]
    reduce_us = total(R)
    hidden_us = total(intersect_intervals(R, V))
    exposed_us = reduce_us - hidden_us
    eff = (hidden_us / reduce_us) if reduce_us > 0 else None

    if iterations is None:
        reduce_counts = [n for (_, _, p), n in op_counts.items()
                         if p == "reduce"]
        iterations = max(reduce_counts) if reduce_counts else None
    exposed_per_iter = (exposed_us / iterations
                        if eff is not None and iterations else None)

    return ProfileReport(
        phase_us=phase_us,
        phase_ops={p: len(s) for p, s in phase_ops.items()},
        device_wall_us=total(all_iv),
        reduce_us=reduce_us,
        matvec_us=phase_us["matvec"],
        hidden_us=hidden_us,
        exposed_us=exposed_us,
        overlap_efficiency=eff,
        iterations=int(iterations) if iterations is not None else None,
        exposed_per_iter_us=exposed_per_iter,
        n_device_events=len(events),
        unmapped_ops=unmapped,
        host_spans=host_spans(doc),
        label=label,
        timeline_path=src if isinstance(src, str) else None,
    )


# ---------------------------------------------------------------------------
# capture
# ---------------------------------------------------------------------------

class Capture:
    """One profiling window: owns the jax.profiler dump dir, collects the
    jitted programs that executed inside it (noted by the session front
    door via :func:`active_capture`), and produces the HLO metadata maps.
    """

    def __init__(self, out_dir: str):
        self.out_dir = out_dir
        self.perfetto_path: Optional[str] = None
        self._programs: List[Tuple[Any, Any, Dict[str, Any]]] = []
        self._seen: set = set()
        self.hlo_maps: Dict[str, Dict[str, str]] = {}

    def note_program(self, fn: Any, args: Sequence[Any],
                     kwargs: Optional[Dict[str, Any]] = None) -> None:
        """Record a jitted program + abstract arg shapes for post-hoc
        HLO-map extraction (costs one re-lower per distinct program)."""
        if not hasattr(fn, "lower"):
            return
        import jax
        import jax.numpy as jnp

        def struct(x):
            return jax.ShapeDtypeStruct(jnp.shape(x), jnp.result_type(x))

        structs = jax.tree_util.tree_map(struct, tuple(args))
        kwargs = dict(kwargs or {})
        key = (id(fn), str(structs), str(sorted(kwargs.items())))
        if key in self._seen:
            return
        self._seen.add(key)
        self._programs.append((fn, structs, kwargs))

    def finalize(self) -> Dict[str, Dict[str, str]]:
        """Lower + compile every noted program and merge the op maps.
        Failures are non-fatal: the heuristic classifier still applies."""
        for fn, structs, kwargs in self._programs:
            try:
                txt = fn.lower(*structs, **kwargs).compile().as_text()
            except Exception:
                continue
            module, ops = hlo_op_map(txt)
            if module:
                self.hlo_maps.setdefault(module, {}).update(ops)
        self._programs.clear()
        return self.hlo_maps

    def analyze(self, iterations: Optional[int] = None,
                label: str = "") -> ProfileReport:
        self.finalize()
        if self.perfetto_path is None:
            self.perfetto_path = find_perfetto_trace(self.out_dir)
        if self.perfetto_path is None:
            raise FileNotFoundError(
                f"no perfetto_trace.json.gz under {self.out_dir!r} — did "
                "the capture context exit cleanly?")
        return analyze_timeline(self.perfetto_path, self.hlo_maps,
                                iterations=iterations, label=label)

    def save_hlo_map(self, path: Optional[str] = None) -> str:
        path = path or os.path.join(self.out_dir, "hlo_map.json")
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as fh:
            json.dump({"schema": "repro.observe/hlo-map/v1",
                       "modules": self.hlo_maps}, fh)
        return path


_ACTIVE: List[Capture] = []


def active_capture() -> Optional[Capture]:
    """The innermost open capture, if any (the api/service layers call
    this on every program invocation; None check is the fast path)."""
    return _ACTIVE[-1] if _ACTIVE else None


class capture:
    """Context manager: ``with capture(out_dir) as cap: ...`` wraps the
    body in ``jax.profiler.trace`` and locates the emitted perfetto
    timeline on exit.  Programs run through the session front door inside
    the window are noted on ``cap`` for HLO-map extraction.

    Warm (compile + run once) before entering the window, or compilation
    events will dominate the timeline.
    """

    def __init__(self, out_dir: str):
        self.cap = Capture(out_dir)
        self._ctx = None

    def __enter__(self) -> Capture:
        import jax

        os.makedirs(self.cap.out_dir, exist_ok=True)
        self._before = set(glob.glob(os.path.join(
            self.cap.out_dir, "plugins", "profile", "*")))
        self._ctx = jax.profiler.trace(self.cap.out_dir,
                                       create_perfetto_trace=True)
        self._ctx.__enter__()
        _ACTIVE.append(self.cap)
        return self.cap

    def __exit__(self, *exc) -> None:
        _ACTIVE.remove(self.cap)
        self._ctx.__exit__(*exc)
        runs = sorted(set(glob.glob(os.path.join(
            self.cap.out_dir, "plugins", "profile", "*"))) - self._before)
        for run in reversed(runs or []):
            hit = glob.glob(os.path.join(run, "perfetto_trace.json.gz"))
            if hit:
                self.cap.perfetto_path = hit[0]
                break
        if self.cap.perfetto_path is None:
            self.cap.perfetto_path = find_perfetto_trace(self.cap.out_dir)
