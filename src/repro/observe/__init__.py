"""repro.observe — runtime observability for the solver stack.

Three legs, one invariant.  The invariant is the paper's: **zero extra
synchronizations and no new dependency edge to the in-flight matvec**.
Everything this package records is either (a) a value the fused
(9/11, m) reduction phase already computes, written into an on-device
ring buffer (write-only — nothing feeds back into the iteration), or
(b) host-side bookkeeping around program dispatch that never touches
device values on the hot path.  The existing :mod:`repro.analysis`
contract passes run unchanged on observed bindings, and
tests/test_observe.py asserts traced solves are **bitwise identical**
to untraced ones.

The legs:

* **Iteration traces** — ``SolverConfig.trace_cap`` threads a
  ``(cap, C[, m])`` ring buffer through the solver loop state recording
  per-iteration scalars (relres, the rho/alpha/omega coefficient
  denominators, the Cools drift bound, status); surfaced as
  ``session.solve(..., trace=True) -> SolveResult.trace``, a typed
  :class:`ConvergenceTrace`.  The service engine harvests per-column
  traces at chunk boundaries with the ONE host read it already does.
* **Host spans** — :func:`span` context-manager spans (bind, precond
  build, program build, chunk dispatch, splice, retire, re-enqueue)
  recorded by the module :data:`RECORDER`, each also entering a
  ``jax.profiler.TraceAnnotation`` so device timelines align; exported
  as Chrome trace-event JSON (:meth:`SpanRecorder.chrome_trace`)
  loadable in Perfetto.
* **Metrics** — a process-local :class:`MetricsRegistry`
  (:data:`REGISTRY`) of counters/gauges/histograms with Prometheus text
  exposition (:func:`prometheus`) and a JSON snapshot
  (:func:`snapshot`), wired into :mod:`repro.api`,
  :mod:`repro.service.engine` and the guarded solve path.

Two measurement layers sit on top:

* **Device profiles** (:mod:`repro.observe.profile`) — wrap a solve in
  ``jax.profiler.trace``, align the captured device timeline with the
  compiled HLO (the solver loops tag matvec / reduce / axpy via
  ``jax.named_scope`` — metadata only, bitwise-identical math) and
  compute the per-phase device-time breakdown, the **overlap
  efficiency** (fraction of reduction time hidden under in-flight
  matvec — the paper's claim, measured), and the exposed-communication
  time per iteration.  Front doors: ``session.solve(..., profile=DIR)``,
  ``ServiceConfig.profile_dir``, ``python -m repro.observe profile``.
* **Perf trajectory** (:mod:`repro.observe.trajectory`) — consolidate
  the schema-stamped ``experiments/*.json`` benchmark artifacts across
  git history into a time-series and gate on the noise-tolerant
  per-metric thresholds declared in ``benchmarks/run.py``;
  ``python -m repro.observe trajectory`` is the CI gate.

``python -m repro.observe smoke`` writes a full artifact set
(trace-event JSON, Prometheus text, metrics + convergence JSON) under
``experiments/runtime/observe/``; ``python -m repro.observe report``
renders a solve/engine timeline, convergence summary, and any device
profiles from those artifacts.
"""
from __future__ import annotations

from .clock import Clock, SYSTEM_CLOCK, TickingClock
from .metrics import (Counter, Gauge, Histogram, MetricsRegistry, REGISTRY,
                      prometheus, snapshot)
from .profile import ProfileReport, analyze_timeline
from .spans import RECORDER, Span, SpanRecorder, span
from .trace import ConvergenceTrace, wrap_trace
from .trajectory import BenchSpec, Metric, TrajectoryReport

__all__ = [
    "Clock", "SYSTEM_CLOCK", "TickingClock",
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "REGISTRY",
    "prometheus", "snapshot",
    "ProfileReport", "analyze_timeline",
    "RECORDER", "Span", "SpanRecorder", "span",
    "ConvergenceTrace", "wrap_trace",
    "BenchSpec", "Metric", "TrajectoryReport",
]
