"""Perf-trajectory consolidation + regression gate over experiments/*.json.

PR 8 made every benchmark artifact schema-stamped and committed so the
performance trajectory would be diffable commit over commit; this module
is the consumer.  It reads each registered artifact at every commit that
touched it (``git log`` + ``git show`` — no checkout churn), appends the
current working-tree values, and evaluates noise-tolerant per-metric
regression thresholds that are declared NEXT TO the benchmark
registration (``benchmarks/run.py::REGISTRY``) — the person adding a
benchmark decides what "worse" means for it.

Gating model:

* every :class:`Metric` names a value inside the artifact by a
  ``/``-separated path (list indices allowed), a direction, and a
  relative tolerance;
* the baseline is the **median of the last 5 historical points** —
  robust to one noisy CI run poisoning the reference;
* a *gated* metric whose current value is worse than baseline by more
  than ``rel_tol`` fails the gate (exit 1); *watch* metrics
  (``gate=False`` — wall-clock times, throughputs, anything
  machine-sensitive) are reported but never fail;
* booleans gate as 1.0/0.0 with ``rel_tol=0`` — a claim that flips to
  False always trips.

Everything that needs git is separated from the pure evaluation
(:func:`evaluate_metric`, :func:`evaluate`) so the injected-regression
tests run device- and git-free.  CLI:
``python -m repro.observe trajectory [--gate]``.
"""
from __future__ import annotations

import dataclasses
import json
import os
import statistics
import subprocess
from typing import Any, Dict, List, Optional, Sequence, Tuple

SCHEMA_TRAJECTORY = "repro.observe/trajectory/v1"

#: historical points the baseline median reads (newest-first window)
BASELINE_WINDOW = 5


@dataclasses.dataclass(frozen=True)
class Metric:
    """One gated/watched value inside a benchmark artifact.

    ``path`` walks the artifact JSON with ``/`` separators (numeric
    segments index lists).  ``direction`` says which way is better.
    ``rel_tol`` is the fraction of the baseline the current value may be
    worse by before it counts as a regression (0 = any worsening trips —
    use for exact counts and booleans).  ``gate=False`` records the
    series and flags regressions in the report without ever failing the
    gate — for wall-clock metrics that vary machine to machine.
    """

    path: str
    direction: str = "higher"            # "higher" | "lower" is better
    rel_tol: float = 0.1
    gate: bool = True
    note: str = ""

    def __post_init__(self):
        if self.direction not in ("higher", "lower"):
            raise ValueError(f"direction must be 'higher' or 'lower', "
                             f"got {self.direction!r}")


@dataclasses.dataclass(frozen=True)
class BenchSpec:
    """One benchmark registration: runner module, artifact, metrics."""

    name: str
    module: str                          # e.g. "benchmarks.bench_cost"
    artifact: str                        # file name under experiments/
    metrics: Tuple[Metric, ...] = ()


def resolve_path(doc: Any, path: str) -> Optional[float]:
    """Walk ``doc`` by a ``/``-separated path; returns the value as a
    float (bools become 1.0/0.0), or None when absent/non-numeric."""
    cur = doc
    for seg in path.split("/"):
        if isinstance(cur, dict):
            if seg not in cur:
                return None
            cur = cur[seg]
        elif isinstance(cur, list):
            try:
                cur = cur[int(seg)]
            except (ValueError, IndexError):
                return None
        else:
            return None
    if isinstance(cur, bool):
        return 1.0 if cur else 0.0
    if isinstance(cur, (int, float)):
        return float(cur)
    return None


# ---------------------------------------------------------------------------
# artifact history (git) + current run
# ---------------------------------------------------------------------------

def _git(args: Sequence[str], root: str) -> Optional[str]:
    try:
        out = subprocess.run(["git", "-C", root, *args],
                             capture_output=True, text=True, timeout=60)
    except (OSError, subprocess.TimeoutExpired):
        return None
    return out.stdout if out.returncode == 0 else None


def artifact_history(artifact: str, root: str = ".",
                     limit: int = 50) -> List[Dict[str, Any]]:
    """Every committed version of ``experiments/<artifact>``, oldest
    first: ``[{commit, committed_unix, data}, ...]``.  Needs full git
    history (CI: ``fetch-depth: 0``); returns [] outside a repo."""
    rel = f"experiments/{artifact}"
    log = _git(["log", f"--max-count={limit}", "--format=%H %ct",
                "--", rel], root)
    if not log:
        return []
    points = []
    for line in reversed(log.strip().splitlines()):
        sha, _, ct = line.partition(" ")
        blob = _git(["show", f"{sha}:{rel}"], root)
        if blob is None:
            continue                     # commit deleted the artifact
        try:
            data = json.loads(blob)
        except json.JSONDecodeError:
            continue
        points.append({"commit": sha, "committed_unix": int(ct or 0),
                       "data": data})
    return points


def current_point(artifact: str, root: str = ".") -> Optional[Dict[str, Any]]:
    path = os.path.join(root, "experiments", artifact)
    if not os.path.exists(path):
        return None
    try:
        with open(path) as fh:
            return {"commit": None, "data": json.load(fh)}
    except (OSError, json.JSONDecodeError):
        return None


# ---------------------------------------------------------------------------
# evaluation (pure — no git, no filesystem)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class MetricVerdict:
    bench: str
    metric: Metric
    series: List[Optional[float]]        # historical values, oldest first
    current: Optional[float]
    baseline: Optional[float]
    status: str                          # ok|regression|watch-regression|
    detail: str = ""                     # new|no-data

    @property
    def failed(self) -> bool:
        return self.status == "regression"


def evaluate_metric(metric: Metric, history: Sequence[Optional[float]],
                    current: Optional[float], bench: str = "") \
        -> MetricVerdict:
    """Verdict for one metric given its historical series + current
    value.  The baseline is the median of the last
    :data:`BASELINE_WINDOW` non-missing points."""
    series = list(history)
    known = [v for v in series if v is not None]
    if current is None:
        return MetricVerdict(bench, metric, series, None, None, "no-data",
                             "metric absent from the current artifact")
    if not known:
        return MetricVerdict(bench, metric, series, current, None, "new",
                             "no committed history yet")
    baseline = statistics.median(known[-BASELINE_WINDOW:])
    scale = max(abs(baseline), 1e-12)
    delta = (current - baseline) / scale
    worse = -delta if metric.direction == "higher" else delta
    if worse > metric.rel_tol:
        status = "regression" if metric.gate else "watch-regression"
        detail = (f"{current:g} vs baseline {baseline:g} "
                  f"({100 * worse:+.1f}% worse, tol "
                  f"{100 * metric.rel_tol:.0f}%)")
    else:
        status, detail = "ok", f"{current:g} vs baseline {baseline:g}"
    return MetricVerdict(bench, metric, series, current, baseline, status,
                         detail)


@dataclasses.dataclass
class TrajectoryReport:
    verdicts: List[MetricVerdict]
    n_commits: Dict[str, int]            # bench -> history depth

    @property
    def regressions(self) -> List[MetricVerdict]:
        return [v for v in self.verdicts if v.failed]

    @property
    def ok(self) -> bool:
        return not self.regressions


def evaluate(registry: Sequence[BenchSpec],
             histories: Dict[str, List[Dict[str, Any]]],
             currents: Dict[str, Optional[Dict[str, Any]]]) \
        -> TrajectoryReport:
    """Pure evaluation over pre-loaded artifact histories: ``histories``
    and ``currents`` map bench name -> (points list / current point)."""
    verdicts, depths = [], {}
    for spec in registry:
        points = histories.get(spec.name, [])
        cur = currents.get(spec.name)
        depths[spec.name] = len(points)
        for metric in spec.metrics:
            series = [resolve_path(p["data"], metric.path) for p in points]
            current = resolve_path(cur["data"], metric.path) if cur else None
            verdicts.append(
                evaluate_metric(metric, series, current, bench=spec.name))
    return TrajectoryReport(verdicts, depths)


def evaluate_repo(registry: Sequence[BenchSpec], root: str = ".",
                  limit: int = 50) -> TrajectoryReport:
    """Load histories from git + working tree, then :func:`evaluate`."""
    histories = {s.name: artifact_history(s.artifact, root, limit)
                 for s in registry}
    currents = {s.name: current_point(s.artifact, root) for s in registry}
    return evaluate(registry, histories, currents)


# ---------------------------------------------------------------------------
# consolidated artifact + trend report
# ---------------------------------------------------------------------------

def consolidate(registry: Sequence[BenchSpec],
                histories: Dict[str, List[Dict[str, Any]]],
                currents: Dict[str, Optional[Dict[str, Any]]]) \
        -> Dict[str, Any]:
    """One time-series document: per bench, per metric, the value at
    every commit plus the current run."""
    out: Dict[str, Any] = {"schema": SCHEMA_TRAJECTORY, "benches": {}}
    for spec in registry:
        points = histories.get(spec.name, [])
        cur = currents.get(spec.name)
        bench: Dict[str, Any] = {
            "artifact": spec.artifact,
            "commits": [{"commit": p["commit"],
                         "committed_unix": p.get("committed_unix"),
                         "generated_at": p["data"].get("generated_at")}
                        for p in points],
            "metrics": {},
        }
        for metric in spec.metrics:
            bench["metrics"][metric.path] = {
                "direction": metric.direction,
                "rel_tol": metric.rel_tol,
                "gate": metric.gate,
                "series": [resolve_path(p["data"], metric.path)
                           for p in points],
                "current": (resolve_path(cur["data"], metric.path)
                            if cur else None),
            }
        out["benches"][spec.name] = bench
    return out


_SPARK = "▁▂▃▄▅▆▇█"


def sparkline(values: Sequence[Optional[float]]) -> str:
    vals = [v for v in values if v is not None]
    if not vals:
        return ""
    lo, hi = min(vals), max(vals)
    rng = hi - lo
    out = []
    for v in values:
        if v is None:
            out.append("·")
        elif rng == 0:
            out.append(_SPARK[3])
        else:
            out.append(_SPARK[min(7, int(8 * (v - lo) / rng))])
    return "".join(out)


_STATUS_MARK = {"ok": "ok", "new": "new", "no-data": "—",
                "regression": "REGRESSION",
                "watch-regression": "watch(worse)"}


def render_markdown(report: TrajectoryReport) -> str:
    lines = ["# perf trajectory", "",
             "baseline = median of the last "
             f"{BASELINE_WINDOW} committed points; gated metrics fail "
             "CI when the current value is worse than baseline by more "
             "than the tolerance.", "",
             "| bench | metric | dir | tol | trend | baseline | current "
             "| status |",
             "|---|---|---|---|---|---|---|---|"]
    for v in report.verdicts:
        m = v.metric
        fmt = (lambda x: "—" if x is None else f"{x:g}")
        lines.append(
            f"| {v.bench} | `{m.path}` | {m.direction} "
            f"| {'gate ' if m.gate else 'watch '}{m.rel_tol:g} "
            f"| `{sparkline(v.series + [v.current])}` "
            f"| {fmt(v.baseline)} | {fmt(v.current)} "
            f"| {_STATUS_MARK.get(v.status, v.status)} |")
    lines.append("")
    if report.regressions:
        lines.append("## regressions")
        lines.extend(f"- **{v.bench}** `{v.metric.path}`: {v.detail}"
                     for v in report.regressions)
    else:
        lines.append(f"no gated regressions across "
                     f"{len(report.verdicts)} metrics.")
    lines.append("")
    return "\n".join(lines)


def render_ascii(report: TrajectoryReport) -> str:
    lines = ["== perf trajectory =="]
    for v in report.verdicts:
        cur = "—" if v.current is None else f"{v.current:g}"
        base = "—" if v.baseline is None else f"{v.baseline:g}"
        mode = "gate" if v.metric.gate else "watch"
        lines.append(
            f"  {v.bench:<12} {v.metric.path:<42} "
            f"{sparkline(v.series + [v.current]):<12} "
            f"{base:>12} -> {cur:<12} [{mode}] "
            f"{_STATUS_MARK.get(v.status, v.status)}")
        if v.failed or v.status == "watch-regression":
            lines.append(f"      {v.detail}")
    n = sum(report.n_commits.values())
    lines.append(f"  ({len(report.verdicts)} metrics, {n} artifact "
                 f"versions across history; "
                 f"{len(report.regressions)} gated regressions)")
    return "\n".join(lines)


def run_trajectory(out_dir: str = "experiments/runtime/trajectory",
                   root: str = ".", gate: bool = True,
                   registry: Optional[Sequence[BenchSpec]] = None) -> int:
    """CLI body: consolidate + render + (optionally) gate.

    Writes ``trajectory.json`` (the consolidated time-series) and
    ``trend.md`` under ``out_dir``; prints the ASCII report; returns
    exit status 1 when ``gate`` and any gated metric regressed.
    """
    import datetime

    if registry is None:
        import sys
        sys.path.insert(0, root)         # benchmarks/ package lives at repo root
        from benchmarks.run import REGISTRY as registry  # type: ignore

    histories = {s.name: artifact_history(s.artifact, root)
                 for s in registry}
    currents = {s.name: current_point(s.artifact, root) for s in registry}
    report = evaluate(registry, histories, currents)
    doc = consolidate(registry, histories, currents)
    doc["generated_at"] = datetime.datetime.now(
        datetime.timezone.utc).isoformat()

    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "trajectory.json"), "w") as fh:
        json.dump(doc, fh, indent=1)
    with open(os.path.join(out_dir, "trend.md"), "w") as fh:
        fh.write(render_markdown(report))
    print(render_ascii(report))
    print(f"artifacts: {out_dir}/trajectory.json, {out_dir}/trend.md")
    if gate and not report.ok:
        print(f"TRAJECTORY GATE FAILED: {len(report.regressions)} "
              "regression(s)")
        return 1
    return 0
