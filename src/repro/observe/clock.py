"""One clock protocol for the whole stack.

Three consumers used to carry their own notion of time: the service
engine's deadline clock (``SolveEngine(clock=...)``), the
fault-injection harness's :class:`TickingClock`, and — now — span
timestamps.  They all speak the same tiny protocol: a zero-argument
callable returning monotonic seconds.  ``time.monotonic`` satisfies it
(:data:`SYSTEM_CLOCK`); :class:`TickingClock` is the deterministic
virtual implementation tests and benchmarks inject to create deadline
pressure or reproducible span timelines without wall-clock sleeps.

:mod:`repro.resilience.inject` re-exports :class:`TickingClock` as a
shim, so existing imports keep working.
"""
from __future__ import annotations

import time
from typing import Callable

try:                                    # 3.8+: Protocol is available
    from typing import Protocol, runtime_checkable

    @runtime_checkable
    class Clock(Protocol):
        """Monotonic-seconds source: ``clock() -> float``."""

        def __call__(self) -> float: ...
except ImportError:                     # pragma: no cover - very old python
    Clock = Callable[[], float]         # type: ignore[assignment,misc]


#: The real clock (``time.monotonic``) — the default everywhere a
#: :class:`Clock` is consumed.
SYSTEM_CLOCK: Clock = time.monotonic


class TickingClock:
    """Virtual monotonic clock: advances ``dt`` per call.

    Inject as ``SolveEngine(..., clock=TickingClock(dt))`` to create
    deterministic deadline pressure — every engine clock read (submit,
    admission, retirement) advances time, no sleeps involved.  The same
    instance can drive a :class:`~repro.observe.SpanRecorder` for
    reproducible span timelines.
    """

    def __init__(self, dt: float = 0.0, t0: float = 0.0):
        self.t = float(t0)
        self.dt = float(dt)

    def __call__(self) -> float:
        self.t += self.dt
        return self.t

    def advance(self, seconds: float) -> None:
        self.t += float(seconds)
