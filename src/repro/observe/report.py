"""The ``python -m repro.observe`` CLI: smoke artifacts + ASCII reports.

Subcommands (all artifact defaults live under the git-ignored
``experiments/runtime/`` tree — committed ``experiments/*.json`` is
reserved for schema-stamped benchmark results):

* ``smoke [--out DIR] [--full]`` — run one traced solve and one engine
  burst against small stencil problems and write the full artifact set
  under ``DIR`` (default ``experiments/runtime/observe``):
  ``spans.trace.json`` (Chrome trace events — load it in Perfetto),
  ``metrics.prom`` (Prometheus text exposition), ``metrics.json``
  (snapshot), and ``convergence.json`` (the traced solve's ring
  buffer).  This is what the CI observe-smoke job runs.
* ``profile [--out DIR] [--full]`` — capture *device* timelines
  (:mod:`repro.observe.profile`): one session solve per substrate (jnp
  + pallas-interpret) and one engine drain, each under its own
  subdirectory of ``DIR`` (default ``experiments/runtime/profile``)
  with the raw trace, the HLO phase map, and ``profile.json`` carrying
  the per-phase breakdown + overlap efficiency.  The CI profile-smoke
  job runs this.
* ``report [--dir DIR]`` — render whatever artifacts live under
  ``DIR``: host span timeline, metrics digest, convergence summary, and
  any ``profile.json`` phase breakdowns (searched one level deep).
* ``trajectory [--out DIR] [--no-gate]`` — consolidate the committed
  ``experiments/*.json`` benchmark artifacts across git history into a
  time-series + trend report and evaluate the per-metric regression
  thresholds declared in ``benchmarks/run.py`` (see
  :mod:`repro.observe.trajectory`).  Exits 1 on gated regressions
  unless ``--no-gate``.

Everything here is host-side plumbing over :mod:`repro.observe`'s
recorders; the solves themselves go through the ordinary front door
(``repro.make_solver`` / :class:`repro.service.SolveEngine`), so the
artifacts reflect exactly what instrumented production code emits.
"""
from __future__ import annotations

import argparse
import datetime
import json
import os
from typing import Any, Dict, List, Optional

from .metrics import REGISTRY
from .spans import RECORDER
from .trace import ConvergenceTrace

SCHEMA_SPANS = "repro.observe/chrome-trace/v1"
SCHEMA_METRICS = "repro.observe/metrics-snapshot/v1"


def _utcnow() -> str:
    return datetime.datetime.now(datetime.timezone.utc).isoformat()


# ---------------------------------------------------------------------------
# smoke
# ---------------------------------------------------------------------------

def run_smoke(out_dir: str, quick: bool = True) -> Dict[str, str]:
    """Traced quick solve + engine burst; writes the artifact set.

    Returns ``{artifact name: path}``.
    """
    import numpy as np

    from jax.experimental import enable_x64

    import repro
    from repro.core import SolverConfig
    from repro.core import matrices as M
    from repro.service import ServiceConfig, SolveEngine

    os.makedirs(out_dir, exist_ok=True)
    nx = 6 if quick else 10
    n_req = 6 if quick else 24

    # paper protocol is fp64; scoped so an in-process caller (tests, a
    # notebook) gets its global x64 setting back afterwards
    with enable_x64(True):
        # -- leg 1: one traced session solve -----------------------------
        op, b, _ = M.poisson3d(nx)
        solver = repro.make_solver(
            "p-bicgsafe", op, config=SolverConfig(tol=1e-8, maxiter=800))
        res = solver.solve(b, trace=True)
        trace = res.trace

        # -- leg 2: an engine burst (traced resident block) --------------
        eng = SolveEngine(ServiceConfig(max_batch=4, chunk=16, tol=1e-8,
                                        maxiter=800, trace_cap=64))
        eng.register(op, name="poisson")
        rng = np.random.default_rng(0)
        for _ in range(n_req):
            eng.submit("poisson", rng.standard_normal(op.shape[0]))
        results = eng.run()

    conv_path = os.path.join(out_dir, "convergence.json")
    payload = trace.to_json()
    payload["generated_at"] = _utcnow()
    payload["summary"] = trace.summary()
    with open(conv_path, "w") as fh:
        json.dump(payload, fh)

    spans_path = os.path.join(out_dir, "spans.trace.json")
    doc = RECORDER.chrome_trace()
    doc["metadata"] = {"schema": SCHEMA_SPANS, "generated_at": _utcnow()}
    with open(spans_path, "w") as fh:
        json.dump(doc, fh)

    prom_path = os.path.join(out_dir, "metrics.prom")
    with open(prom_path, "w") as fh:
        fh.write(REGISTRY.prometheus())

    mjson_path = os.path.join(out_dir, "metrics.json")
    with open(mjson_path, "w") as fh:
        json.dump({"schema": SCHEMA_METRICS, "generated_at": _utcnow(),
                   "metrics": REGISTRY.snapshot()}, fh)

    n_conv = sum(r.converged for r in results)
    print(f"smoke: traced solve converged={bool(res.converged)} in "
          f"{int(res.iterations)} iterations; engine retired "
          f"{len(results)} requests ({n_conv} converged)")
    print(f"artifacts under {out_dir}/: convergence.json, "
          "spans.trace.json, metrics.prom, metrics.json")
    return {"convergence": conv_path, "spans": spans_path,
            "prometheus": prom_path, "metrics": mjson_path}


# ---------------------------------------------------------------------------
# profile
# ---------------------------------------------------------------------------

def run_profile(out_dir: str, quick: bool = True) -> Dict[str, str]:
    """Device-timeline captures: one session solve per substrate plus
    one engine drain, each written under ``out_dir/<leg>/``.

    Returns ``{leg name: profile.json path}``.
    """
    import numpy as np

    from jax.experimental import enable_x64

    import repro
    from repro.core import SolverConfig
    from repro.core import matrices as M
    from repro.service import ServiceConfig, SolveEngine

    os.makedirs(out_dir, exist_ok=True)
    nx = 6 if quick else 10
    n_req = 6 if quick else 24
    out: Dict[str, str] = {}

    with enable_x64(True):
        op, b, _ = M.poisson3d(nx)
        for sub in ("jnp", "pallas"):
            leg = f"session_{sub}"
            leg_dir = os.path.join(out_dir, leg)
            solver = repro.make_solver(
                "p-bicgsafe", op, substrate=sub,
                config=SolverConfig(tol=1e-8, maxiter=800))
            res = solver.solve(b, profile=leg_dir)
            rep = solver.last_profile
            print(f"\n== profile: {leg} (converged="
                  f"{bool(res.converged)}) ==")
            print(rep.render())
            out[leg] = os.path.join(leg_dir, "profile.json")

        eng_dir = os.path.join(out_dir, "engine")
        eng = SolveEngine(ServiceConfig(max_batch=4, chunk=16, tol=1e-8,
                                        maxiter=800,
                                        profile_dir=eng_dir))
        eng.register(op, name="poisson")
        rng = np.random.default_rng(0)
        for _ in range(n_req):
            eng.submit("poisson", rng.standard_normal(op.shape[0]))
        results = eng.run()
        print(f"\n== profile: engine ({len(results)} requests, "
              f"{sum(r.converged for r in results)} converged) ==")
        print(eng.last_profile.render())
        out["engine"] = os.path.join(eng_dir, "profile.json")

    print(f"\nprofiles under {out_dir}/: " + ", ".join(sorted(out)))
    return out


# ---------------------------------------------------------------------------
# report
# ---------------------------------------------------------------------------

def _render_timeline(doc: Dict[str, Any], width: int = 60) -> List[str]:
    events = sorted(doc.get("traceEvents", []), key=lambda e: e["ts"])
    if not events:
        return ["  (no spans recorded)"]
    t0 = events[0]["ts"]
    t1 = max(e["ts"] + e.get("dur", 0.0) for e in events)
    span_us = max(t1 - t0, 1.0)
    lines = []
    name_w = min(max(len(e["name"]) for e in events), 28)
    for e in events[:200]:
        start = e["ts"] - t0
        dur = e.get("dur", 0.0)
        lo = int(width * start / span_us)
        hi = max(lo + 1, int(width * (start + dur) / span_us))
        bar = " " * lo + "█" * min(hi - lo, width - lo)
        lines.append(f"  {e['name'][:name_w]:<{name_w}} "
                     f"|{bar:<{width}}| {dur / 1e3:8.2f} ms")
    if len(events) > 200:
        lines.append(f"  ... {len(events) - 200} more spans")
    lines.append(f"  total window: {span_us / 1e3:.2f} ms, "
                 f"{len(events)} spans")
    return lines


def _render_metrics(snap: Dict[str, Any]) -> List[str]:
    lines = []
    for name, meta in sorted(snap.items()):
        values = meta.get("values", [])
        if not values:
            continue
        for v in values:
            labels = v.get("labels", {})
            lab = ",".join(f"{k}={val}" for k, val in labels.items())
            lab = f"{{{lab}}}" if lab else ""
            if meta["kind"] == "histogram":
                n, s = v["count"], v["sum"]
                mean = s / n if n else 0.0
                lines.append(f"  {name}{lab}: count={n} sum={s:.4g} "
                             f"mean={mean:.4g}")
            else:
                lines.append(f"  {name}{lab}: {v['value']:g}")
    return lines or ["  (no metrics recorded)"]


def _render_convergence(data: Dict[str, Any]) -> List[str]:
    trace = ConvergenceTrace.from_json(data)
    views = ([trace.column(j) for j in range(trace.m)]
             if trace.batched else [trace])
    lines = []
    for j, view in enumerate(views):
        s = view.summary()
        tag = f"  column {j}: " if trace.batched else "  "
        lines.append(f"{tag}{s['status']} after {s['iterations']} "
                     f"iterations, final relres {s['final_relres']:.3e} "
                     f"({s['recorded']} recorded)")
        rows = view.per_iteration()
        if rows.size:
            ch = {n: i for i, n in enumerate(view.channels)}
            tail = rows[-5:]
            for row in tail:
                lines.append(
                    f"    it {int(row[ch['iteration']]):>5}  "
                    f"relres {row[ch['relres']]:.3e}  "
                    f"rho_den {row[ch['rho_denom']]:+.2e}  "
                    f"omega_den {row[ch['omega_denom']]:+.2e}  "
                    f"drift {row[ch['drift']]:.2e}")
    return lines


def run_report(dir_: str) -> int:
    """Render the artifact set under ``dir_``; returns exit status."""
    found = False
    spans_path = os.path.join(dir_, "spans.trace.json")
    if os.path.exists(spans_path):
        found = True
        with open(spans_path) as fh:
            doc = json.load(fh)
        print("== span timeline ==")
        print("\n".join(_render_timeline(doc)))
    mjson_path = os.path.join(dir_, "metrics.json")
    if os.path.exists(mjson_path):
        found = True
        with open(mjson_path) as fh:
            snap = json.load(fh).get("metrics", {})
        print("\n== metrics ==")
        print("\n".join(_render_metrics(snap)))
    conv_path = os.path.join(dir_, "convergence.json")
    if os.path.exists(conv_path):
        found = True
        with open(conv_path) as fh:
            data = json.load(fh)
        print("\n== convergence ==")
        print("\n".join(_render_convergence(data)))
    # device-profile breakdowns (dir itself + one level of leg subdirs)
    from .profile import ProfileReport
    candidates = [os.path.join(dir_, "profile.json")] + sorted(
        os.path.join(dir_, d, "profile.json")
        for d in (os.listdir(dir_) if os.path.isdir(dir_) else [])
        if os.path.isdir(os.path.join(dir_, d)))
    for p in candidates:
        if not os.path.exists(p):
            continue
        found = True
        rep = ProfileReport.load(p)
        print(f"\n== device profile: {rep.label or p} ==")
        print(rep.render())
    if not found:
        print(f"no observe artifacts under {dir_!r}; run "
              "`python -m repro.observe smoke` first")
        return 1
    return 0


# ---------------------------------------------------------------------------
# entry point
# ---------------------------------------------------------------------------

def main(argv: Optional[List[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.observe",
        description="observability artifacts and reports for the solver "
                    "stack")
    sub = parser.add_subparsers(dest="cmd", required=True)
    p_smoke = sub.add_parser(
        "smoke", help="run a traced quick solve + engine burst and write "
                      "the artifact set")
    p_smoke.add_argument("--out", default="experiments/runtime/observe")
    p_smoke.add_argument("--full", action="store_true",
                         help="larger problem / more requests")
    p_prof = sub.add_parser(
        "profile", help="capture device timelines (session solve per "
                        "substrate + engine drain) and compute the "
                        "per-phase / overlap breakdown")
    p_prof.add_argument("--out", default="experiments/runtime/profile")
    p_prof.add_argument("--full", action="store_true",
                        help="larger problem / more requests")
    p_report = sub.add_parser(
        "report", help="render the artifact set as timeline + metrics + "
                       "convergence summary + device profiles")
    p_report.add_argument("--dir", default="experiments/runtime/observe")
    p_traj = sub.add_parser(
        "trajectory", help="consolidate committed benchmark artifacts "
                           "across git history and gate on the metric "
                           "thresholds from benchmarks/run.py")
    p_traj.add_argument("--out", default="experiments/runtime/trajectory")
    p_traj.add_argument("--root", default=".")
    p_traj.add_argument("--no-gate", action="store_true",
                        help="report only; never exit nonzero")
    args = parser.parse_args(argv)
    if args.cmd == "smoke":
        run_smoke(args.out, quick=not args.full)
        return 0
    if args.cmd == "profile":
        run_profile(args.out, quick=not args.full)
        return 0
    if args.cmd == "trajectory":
        from .trajectory import run_trajectory
        return run_trajectory(args.out, root=args.root,
                              gate=not args.no_gate)
    return run_report(args.dir)
