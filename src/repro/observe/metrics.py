"""Process-local metrics registry with Prometheus + JSON export.

Counters, gauges and histograms keyed by (name, label values), held in
one :class:`MetricsRegistry` (:data:`REGISTRY` is the process default).
No daemon, no HTTP server, no dependency: :func:`prometheus` renders
the standard text exposition format (scrape it, or dump it to a file —
the CI smoke job does), :func:`snapshot` a JSON-able dict.

Hot-path discipline: instruments are plain python dict updates under a
lock — never a device read.  The api layer records only host-known
facts (cache hit/miss, retrace counts); status-labeled outcomes are
recorded where the host already reads device flags (engine retirement,
guarded chunk boundaries), so observability adds zero
synchronizations.  tests/test_observe.py asserts the traced+metered
path is bitwise identical to the bare one.

The pre-declared instruments at the bottom are the stack's vocabulary;
layers import them directly (``from repro.observe.metrics import
ENGINE_CHUNK_SECONDS``).
"""
from __future__ import annotations

import math
import threading
from typing import Any, Dict, Iterable, Optional, Sequence, Tuple


class _Instrument:
    """Base: one named metric family with fixed label names."""

    kind = "untyped"

    def __init__(self, name: str, help: str, labels: Sequence[str] = ()):
        self.name = name
        self.help = help
        self.labels = tuple(labels)
        self._lock = threading.Lock()

    def _key(self, labels: Dict[str, Any]) -> Tuple[str, ...]:
        if set(labels) != set(self.labels):
            raise ValueError(
                f"{self.name}: expected labels {self.labels}, "
                f"got {tuple(labels)}")
        return tuple(str(labels[k]) for k in self.labels)

    def _label_str(self, key: Tuple[str, ...]) -> str:
        if not key:
            return ""
        inner = ",".join(f'{n}="{v}"' for n, v in zip(self.labels, key))
        return "{" + inner + "}"


class Counter(_Instrument):
    """Monotonic counter: ``inc()`` only."""

    kind = "counter"

    def __init__(self, name, help, labels=()):
        super().__init__(name, help, labels)
        self._values: Dict[Tuple[str, ...], float] = {}

    def inc(self, value: float = 1.0, **labels) -> None:
        if value < 0:
            raise ValueError("counters only go up")
        key = self._key(labels)
        with self._lock:
            self._values[key] = self._values.get(key, 0.0) + value

    def value(self, **labels) -> float:
        with self._lock:
            return self._values.get(self._key(labels), 0.0)

    def _reset(self):
        with self._lock:
            self._values.clear()

    def _expose(self) -> Iterable[str]:
        with self._lock:
            for key, v in sorted(self._values.items()):
                yield f"{self.name}{self._label_str(key)} {_fmt(v)}"

    def _snapshot(self):
        with self._lock:
            return [{"labels": dict(zip(self.labels, k)), "value": v}
                    for k, v in sorted(self._values.items())]


class Gauge(_Instrument):
    """Point-in-time value: ``set()`` / ``inc()`` / ``dec()``."""

    kind = "gauge"

    def __init__(self, name, help, labels=()):
        super().__init__(name, help, labels)
        self._values: Dict[Tuple[str, ...], float] = {}

    def set(self, value: float, **labels) -> None:
        key = self._key(labels)
        with self._lock:
            self._values[key] = float(value)

    def inc(self, value: float = 1.0, **labels) -> None:
        key = self._key(labels)
        with self._lock:
            self._values[key] = self._values.get(key, 0.0) + value

    def dec(self, value: float = 1.0, **labels) -> None:
        self.inc(-value, **labels)

    def value(self, **labels) -> float:
        with self._lock:
            return self._values.get(self._key(labels), 0.0)

    _reset = Counter._reset
    _expose = Counter._expose
    _snapshot = Counter._snapshot


#: Default histogram buckets: spans ~100 µs dispatches to ~10 s solves.
DEFAULT_BUCKETS = (1e-4, 5e-4, 1e-3, 5e-3, 0.01, 0.05, 0.1, 0.5, 1.0,
                   5.0, 10.0)

#: Iteration-count buckets (for ``repro_solve_iterations`` & co.).
ITERATION_BUCKETS = (1., 2., 5., 10., 25., 50., 100., 250., 500., 1000.,
                     2500., 5000., 10000.)


class Histogram(_Instrument):
    """Cumulative-bucket histogram (Prometheus semantics)."""

    kind = "histogram"

    def __init__(self, name, help, labels=(),
                 buckets: Sequence[float] = DEFAULT_BUCKETS):
        super().__init__(name, help, labels)
        self.buckets = tuple(sorted(float(b) for b in buckets))
        self._counts: Dict[Tuple[str, ...], list] = {}
        self._sum: Dict[Tuple[str, ...], float] = {}
        self._n: Dict[Tuple[str, ...], int] = {}

    def observe(self, value: float, **labels) -> None:
        value = float(value)
        key = self._key(labels)
        with self._lock:
            counts = self._counts.setdefault(key, [0] * len(self.buckets))
            for i, b in enumerate(self.buckets):
                if value <= b:
                    counts[i] += 1
            self._sum[key] = self._sum.get(key, 0.0) + value
            self._n[key] = self._n.get(key, 0) + 1

    def count(self, **labels) -> int:
        with self._lock:
            return self._n.get(self._key(labels), 0)

    def sum(self, **labels) -> float:
        with self._lock:
            return self._sum.get(self._key(labels), 0.0)

    def _reset(self):
        with self._lock:
            self._counts.clear()
            self._sum.clear()
            self._n.clear()

    def _expose(self) -> Iterable[str]:
        with self._lock:
            for key in sorted(self._n):
                base = list(zip(self.labels, key))
                for b, c in zip(self.buckets, self._counts[key]):
                    lab = ",".join(f'{n}="{v}"' for n, v in
                                   base + [("le", _fmt(b))])
                    yield f"{self.name}_bucket{{{lab}}} {c}"
                lab_inf = ",".join(f'{n}="{v}"' for n, v in
                                   base + [("le", "+Inf")])
                yield f"{self.name}_bucket{{{lab_inf}}} {self._n[key]}"
                ls = self._label_str(key)
                yield f"{self.name}_sum{ls} {_fmt(self._sum[key])}"
                yield f"{self.name}_count{ls} {self._n[key]}"

    def _snapshot(self):
        with self._lock:
            return [{"labels": dict(zip(self.labels, k)),
                     "count": self._n[k], "sum": self._sum[k],
                     "buckets": dict(zip(map(_fmt, self.buckets),
                                         self._counts[k]))}
                    for k in sorted(self._n)]


def _fmt(v: float) -> str:
    if v == math.inf:
        return "+Inf"
    if float(v).is_integer() and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


class MetricsRegistry:
    """Named instrument table; get-or-create semantics.

    ``counter``/``gauge``/``histogram`` return the existing instrument
    when the name is already registered (kind mismatches are loud), so
    modules can declare their instruments idempotently.  ``reset()``
    zeroes every value but keeps the instruments — the test/benchmark
    affordance.
    """

    def __init__(self):
        self._instruments: Dict[str, _Instrument] = {}
        self._lock = threading.Lock()

    def _get(self, cls, name, help, labels, **kw) -> Any:
        with self._lock:
            inst = self._instruments.get(name)
            if inst is not None:
                if not isinstance(inst, cls):
                    raise ValueError(
                        f"metric {name!r} already registered as "
                        f"{inst.kind}, not {cls.kind}")
                return inst
            inst = cls(name, help, labels, **kw)
            self._instruments[name] = inst
            return inst

    def counter(self, name, help="", labels=()) -> Counter:
        return self._get(Counter, name, help, labels)

    def gauge(self, name, help="", labels=()) -> Gauge:
        return self._get(Gauge, name, help, labels)

    def histogram(self, name, help="", labels=(),
                  buckets: Sequence[float] = DEFAULT_BUCKETS) -> Histogram:
        return self._get(Histogram, name, help, labels, buckets=buckets)

    def get(self, name: str) -> Optional[_Instrument]:
        with self._lock:
            return self._instruments.get(name)

    def reset(self) -> None:
        with self._lock:
            insts = list(self._instruments.values())
        for inst in insts:
            inst._reset()

    # -- export -----------------------------------------------------------
    def prometheus(self) -> str:
        """The standard text exposition format."""
        lines = []
        with self._lock:
            insts = sorted(self._instruments.values(),
                           key=lambda i: i.name)
        for inst in insts:
            lines.append(f"# HELP {inst.name} {inst.help}")
            lines.append(f"# TYPE {inst.name} {inst.kind}")
            lines.extend(inst._expose())
        return "\n".join(lines) + "\n"

    def snapshot(self) -> Dict[str, Any]:
        """JSON-able ``{name: {kind, help, values}}`` dict."""
        with self._lock:
            insts = sorted(self._instruments.values(),
                           key=lambda i: i.name)
        return {inst.name: {"kind": inst.kind, "help": inst.help,
                            "values": inst._snapshot()}
                for inst in insts}


#: The process-default registry every instrumented layer records into.
REGISTRY = MetricsRegistry()


def prometheus() -> str:
    return REGISTRY.prometheus()


def snapshot() -> Dict[str, Any]:
    return REGISTRY.snapshot()


# ---------------------------------------------------------------------------
# the stack's instrument vocabulary
# ---------------------------------------------------------------------------

#: Solver-session entry points served (labels never read device values
#: — outcome-by-status lives on the engine/guarded instruments, where
#: the host already holds the flags).
SOLVES = REGISTRY.counter(
    "repro_solves_total", "solver-session entry points served",
    labels=("method", "substrate", "entry"))
SESSION_CACHE = REGISTRY.counter(
    "repro_session_cache_total",
    "content-keyed session cache lookups by outcome (hit|miss)",
    labels=("outcome",))
PROGRAM_TRACES = REGISTRY.counter(
    "repro_program_traces_total",
    "actual jit retraces of session programs (the amortization metric)")
SOLVE_ITERATIONS = REGISTRY.histogram(
    "repro_solve_iterations",
    "iterations to retirement, per request/column (recorded where the "
    "host already reads the flags)", buckets=ITERATION_BUCKETS)

ENGINE_REQUESTS = REGISTRY.counter(
    "repro_engine_requests_total",
    "requests retired by the solve engine, by typed SolveStatus",
    labels=("status",))
ENGINE_RETRIES = REGISTRY.counter(
    "repro_engine_retries_total",
    "failed requests re-enqueued by the recovery policy")
ENGINE_QUEUE_DEPTH = REGISTRY.gauge(
    "repro_engine_queue_depth", "queued requests per operator",
    labels=("operator",))
ENGINE_SLOT_OCCUPANCY = REGISTRY.gauge(
    "repro_engine_slot_occupancy",
    "live request slots in the resident block, per operator",
    labels=("operator",))
ENGINE_CHUNK_SECONDS = REGISTRY.histogram(
    "repro_engine_chunk_seconds",
    "wall time of one engine chunk (dispatch + retirement read)")
REQUEST_QUEUE_WAIT = REGISTRY.histogram(
    "repro_request_queue_wait_seconds",
    "submit -> first resident in the block")
REQUEST_WALL = REGISTRY.histogram(
    "repro_request_wall_seconds", "submit -> retirement")
REQUEST_CHUNKS = REGISTRY.histogram(
    "repro_request_chunks_resident",
    "engine chunks a request stayed resident",
    buckets=(1., 2., 3., 5., 8., 13., 21., 34., 55., 89.))

RECOVERY_ACTIONS = REGISTRY.counter(
    "repro_recovery_actions_total",
    "guarded-solve recovery actions fired, by action",
    labels=("action",))
