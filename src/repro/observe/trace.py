"""Typed view over the on-device iteration-trace ring buffer.

Solvers running with ``SolverConfig.trace_cap > 0`` carry a
``(cap, C[, m])`` ring buffer in their loop state and return it as the
raw payload ``SolveResult.trace = {"buffer": ..., "steps": int32}``
(see :data:`repro.core.types.TRACE_CHANNELS` for the channel layout).
That shape is deliberately dumb — it must live inside
``jax.lax.while_loop`` state.  :class:`ConvergenceTrace` is the host
boundary: it materializes the buffer ONCE (one device-to-host copy, and
only when the caller asked for a trace), unrolls the ring into
chronological order, and answers the questions an operator actually
asks — how did relres fall, which denominator collapsed first, when did
drift start growing.

The ring keeps the LAST ``cap`` iterations: slot ``i % cap`` holds
iteration ``i``, so with ``steps`` total iterations the valid rows are
``steps - min(steps, cap) .. steps - 1`` in slot order
``(steps - k + j) % cap``.  Batched buffers additionally repeat a
frozen column's last row every *global* iteration (the batched body
steps all m columns in lockstep) — :meth:`per_iteration` collapses
those plateaus using the iteration channel.
"""
from __future__ import annotations

import json
from typing import Any, Dict, Optional

import numpy as np

from repro.core.types import SolveStatus, TRACE_CHANNELS

_CH = {name: i for i, name in enumerate(TRACE_CHANNELS)}


class ConvergenceTrace:
    """Chronological per-iteration trace of one solve (or one block).

    Attributes:
      buffer: the raw ``(cap, C)`` or ``(cap, C, m)`` ring buffer
        (host numpy; NaN rows are never-written or splice-reset slots).
      steps: total iterations the traced loop executed (the ring holds
        the last ``min(steps, cap)`` of them).
      channels: channel-name tuple (:data:`~repro.core.types
        .TRACE_CHANNELS`).
    """

    channels = TRACE_CHANNELS

    def __init__(self, buffer, steps: int):
        self.buffer = np.asarray(buffer)
        if self.buffer.ndim not in (2, 3) \
                or self.buffer.shape[1] != len(TRACE_CHANNELS):
            raise ValueError(
                f"trace buffer must be (cap, {len(TRACE_CHANNELS)}[, m]); "
                f"got shape {self.buffer.shape}")
        self.steps = int(steps)

    # -- shape ------------------------------------------------------------
    @property
    def cap(self) -> int:
        return self.buffer.shape[0]

    @property
    def batched(self) -> bool:
        return self.buffer.ndim == 3

    @property
    def m(self) -> Optional[int]:
        return self.buffer.shape[2] if self.batched else None

    def __len__(self) -> int:
        return min(self.steps, self.cap)

    def column(self, j: int) -> "ConvergenceTrace":
        """The single-column view of a batched trace."""
        if not self.batched:
            raise ValueError("column() on a single-RHS trace")
        return ConvergenceTrace(self.buffer[:, :, j], self.steps)

    # -- chronological views ----------------------------------------------
    def rows(self) -> np.ndarray:
        """Valid rows in chronological order: ``(k, C[, m])`` with
        ``k = min(steps, cap)`` (the last k iterations)."""
        k = len(self)
        slots = (np.arange(self.steps - k, self.steps) % self.cap
                 if k else np.zeros((0,), np.int64))
        return self.buffer[slots]

    def channel(self, name: str) -> np.ndarray:
        """One channel's chronological values: ``(k[, m])``."""
        return self.rows()[:, _CH[name]]

    def per_iteration(self) -> np.ndarray:
        """Chronological ``(k', C)`` rows, one per *advanced* iteration.

        Single-RHS view only (take :meth:`column` first for a batched
        trace).  Drops NaN rows (never-written / splice-reset slots) and
        collapses consecutive rows whose iteration channel did not
        advance — the frozen-column plateau a batched lockstep body
        writes after a column converges.
        """
        if self.batched:
            raise ValueError(
                "per_iteration() needs a single column; use .column(j)")
        rows = self.rows()
        if not rows.size:
            return rows
        rows = rows[np.isfinite(rows[:, _CH["iteration"]])]
        if not rows.size:
            return rows
        it = rows[:, _CH["iteration"]]
        keep = np.ones(len(rows), bool)
        keep[1:] = it[1:] != it[:-1]
        return rows[keep]

    # -- summaries --------------------------------------------------------
    def summary(self) -> Dict[str, Any]:
        """Host-friendly digest of a single-column trace."""
        rows = self.per_iteration()
        if not rows.size:
            return {"iterations": 0, "recorded": 0, "final_relres": None,
                    "min_relres": None, "status": None}
        last = rows[-1]
        relres = rows[:, _CH["relres"]]
        code = int(last[_CH["status"]])
        try:
            status = SolveStatus(code).name
        except ValueError:
            status = str(code)
        return {
            "iterations": int(last[_CH["iteration"]]),
            "recorded": int(len(rows)),
            "final_relres": float(last[_CH["relres"]]),
            "min_relres": float(np.nanmin(relres)),
            "status": status,
            "final_drift": float(last[_CH["drift"]]),
        }

    # -- (de)serialization -------------------------------------------------
    def to_json(self) -> Dict[str, Any]:
        """JSON-able payload (NaN encoded as None) for the report CLI."""
        buf = self.buffer.astype(np.float64)
        nested = np.where(np.isfinite(buf), buf, None).tolist()
        return {"schema": "repro.observe/convergence-trace/v1",
                "channels": list(self.channels), "steps": self.steps,
                "buffer": nested}

    @classmethod
    def from_json(cls, data: Dict[str, Any]) -> "ConvergenceTrace":
        buf = np.asarray(
            [[[np.nan if v is None else v for v in
               (col if isinstance(col, list) else [col])]
              for col in row] for row in data["buffer"]], np.float64)
        if not any(isinstance(col, list)
                   for row in data["buffer"] for col in row):
            buf = buf[:, :, 0]
        return cls(buf, int(data["steps"]))

    def save(self, path) -> None:
        with open(path, "w") as fh:
            json.dump(self.to_json(), fh)

    def __repr__(self):
        shape = f"m={self.m}, " if self.batched else ""
        return (f"<ConvergenceTrace {shape}cap={self.cap} "
                f"steps={self.steps} recorded={len(self)}>")


def wrap_trace(payload) -> Optional[ConvergenceTrace]:
    """Wrap a ``SolveResult.trace`` payload at the host boundary.

    ``None`` passes through (tracing off); an already-wrapped trace
    passes through; the in-jit ``{"buffer", "steps"}`` dict becomes a
    :class:`ConvergenceTrace` (this is the one device-to-host copy of
    the buffer).
    """
    if payload is None or isinstance(payload, ConvergenceTrace):
        return payload
    return ConvergenceTrace(payload["buffer"], int(payload["steps"]))
