"""Structured host-side span tracing, aligned with device timelines.

A span is one timed host-side phase of the stack — session bind,
preconditioner build, program build (the retrace cost
``bench_api`` amortizes), engine chunk dispatch, splice, retirement,
re-enqueue.  Spans nest naturally (context managers) and each one also
enters a ``jax.profiler.TraceAnnotation`` of the same name, so when the
user captures a device profile the host spans line up against the
device timeline in the same viewer.

Nothing here touches device values: recording a span is two clock
reads and a list append.  The hot solver loop itself is never spanned —
per-iteration visibility is the on-device ring buffer's job
(:mod:`repro.observe.trace`); spans cover the dispatch granularity the
host actually controls.

Export is Chrome trace-event JSON (:meth:`SpanRecorder.chrome_trace`),
loadable in Perfetto / ``chrome://tracing``.
"""
from __future__ import annotations

import dataclasses
import json
import os
import threading
from collections import deque
from contextlib import contextmanager
from typing import Any, Deque, Dict, Optional

import jax

from .clock import Clock, SYSTEM_CLOCK


@dataclasses.dataclass(frozen=True)
class Span:
    """One completed span: ``[start, end]`` in clock seconds."""

    name: str
    start: float
    end: float
    tid: int
    args: Dict[str, Any]

    @property
    def duration(self) -> float:
        return self.end - self.start


class SpanRecorder:
    """Bounded in-process span buffer.

    ``clock`` is any :class:`~repro.observe.Clock` (inject a
    :class:`~repro.observe.TickingClock` for deterministic timelines in
    tests); ``cap`` bounds memory — a long-running engine keeps the
    LAST ``cap`` spans.  Thread-safe: the engine and user threads may
    record concurrently.
    """

    def __init__(self, clock: Clock = SYSTEM_CLOCK, cap: int = 8192):
        self.clock = clock
        self._spans: Deque[Span] = deque(maxlen=int(cap))
        self._lock = threading.Lock()
        self.enabled = True

    @contextmanager
    def span(self, name: str, **args):
        """Record ``name`` around the with-block (and annotate the
        device timeline with the same name).  Non-string arg values are
        kept as-is; they are stringified only at export."""
        if not self.enabled:
            yield
            return
        t0 = self.clock()
        with jax.profiler.TraceAnnotation(name):
            try:
                yield
            finally:
                self._record(name, t0, self.clock(), args)

    def _record(self, name, t0, t1, args):
        sp = Span(name=name, start=t0, end=t1,
                  tid=threading.get_ident(), args=dict(args))
        with self._lock:
            self._spans.append(sp)

    def spans(self) -> list:
        with self._lock:
            return list(self._spans)

    def clear(self) -> None:
        with self._lock:
            self._spans.clear()

    # -- export -----------------------------------------------------------
    def chrome_trace(self) -> Dict[str, Any]:
        """Chrome trace-event JSON (``ph: "X"`` complete events, µs)."""
        events = []
        for sp in self.spans():
            events.append({
                "name": sp.name, "ph": "X",
                "ts": sp.start * 1e6, "dur": sp.duration * 1e6,
                "pid": os.getpid(), "tid": sp.tid,
                "args": {k: (v if isinstance(v, (int, float, bool))
                             else str(v)) for k, v in sp.args.items()},
            })
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def save_chrome_trace(self, path) -> None:
        with open(path, "w") as fh:
            json.dump(self.chrome_trace(), fh)


#: The process-default recorder every instrumented layer records into.
RECORDER = SpanRecorder()


def span(name: str, **args):
    """``with observe.span("engine.chunk", operator=name): ...`` — the
    module-level shorthand for :data:`RECORDER`'s context manager."""
    return RECORDER.span(name, **args)
