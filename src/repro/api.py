"""repro.api — the front door: bind-once ``LinearSolver`` sessions.

The paper's value proposition is *per-iteration*: one overlapped fused
reduction hidden behind the in-flight matvec (Huynh & Suito 2021).  The
dominant real workload is *per-operator*: many solves against one fixed
A (Krasnopolsky 2019 makes the same observation for multi-RHS
BiCGStab), re-threading ``substrate=`` / ``precond=`` / ``dot_reduce=``
through a free function on every call — rebuilding the preconditioner
and retracing the whole solver each time.  This module binds the
operator ONCE and amortizes everything else:

    import repro

    solver = repro.make_solver("p-bicgsafe", op, precond="block_jacobi",
                               substrate="pallas")
    x1 = solver.solve(b1)            # traces + compiles once
    x2 = solver.solve(b2)            # reuses the compiled program
    R  = solver.solve_many([b3, b4, b5])   # one (9, m) reduction/iter
    st = solver.init(B); st = solver.step_chunk(st, 32)   # open loop
    d  = solver.on_mesh(mesh)        # distributed binding, same session

    x = repro.solve(op, b)           # one-shot; hits the session cache

One source of truth for caching
-------------------------------
The content-fingerprint machinery that :mod:`repro.service`'s registry
introduced (PR 4) is promoted here: :func:`operator_fingerprint` hashes
the operator pytree (and precond spec) by *content*, and
:func:`make_solver` memoizes whole sessions under that key — so repeat
traffic against an equal-content operator reuses the built
preconditioner AND every compiled program, whether it arrives through
``make_solver``, ``repro.solve``, or the solve service (whose registry
is now a thin consumer of this cache).  Compiled programs inside a
session are memoized per (program kind, derived config, argument
structure); ``jax.jit`` handles shape-keyed retraces below that.

Every binding preserves the two structural invariants the test suite
asserts at the jaxpr level (tests/test_substrate_parity.py, through the
session path too): ONE fused reduction per iteration, with no
dependency edge to the in-flight matvec — single, batched, and
distributed.

The historical free functions (``pbicgsafe_solve`` & co.,
``solve_batched``, the distributed drivers) keep working verbatim as
deprecated shims; sessions delegate to the same underlying
implementations, so results are bitwise-identical program-for-program
(tests/test_api.py).
"""
from __future__ import annotations

import dataclasses
import weakref
from collections import OrderedDict
from typing import Any, Callable, Dict, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import SOLVERS
from repro.core._deprecation import internal_use
from repro.core.linear_operator import Stencil7Operator
from repro.core.multirhs import (init_state, result_from_state,
                                 splice_columns, step_chunk)
from repro.core.substrate import SUBSTRATES, SubstrateLike, get_substrate
from repro.core.types import (DotReduce, SolveResult, SolverConfig,
                              identity_reduce, per_column)
from repro.observe import metrics as _metrics
from repro.observe import profile as _profile
from repro.observe.spans import span as _span
from repro.observe.trace import wrap_trace
from repro.precond.base import (PrecondLike, Preconditioner, resolve_precond,
                                validate_precond_spec)

__all__ = [
    "LinearSolver", "DistributedSolver", "make_solver", "solve",
    "operator_fingerprint", "clear_session_cache", "session_cache_info",
]


# ---------------------------------------------------------------------------
# content fingerprinting (promoted from precond/base.py + service/registry.py)
# ---------------------------------------------------------------------------

#: per-object digest memo: id -> (weakref guarding id reuse, digest).
#: Only pytrees whose every leaf is immutable (jax arrays, python
#: scalars, non-writeable ndarrays) are memoized — a live object's
#: content then cannot change, and the weakref callback evicts on death
#: so a recycled id can never alias a dead object's digest.  Operators
#: backed by writeable numpy arrays (mutable in place under the caller's
#: feet) are re-hashed on every call, exactly as before the memo.
_CONTENT_DIGESTS: Dict[int, Tuple[Any, str]] = {}


def _leaf_is_immutable(leaf) -> bool:
    if isinstance(leaf, jax.Array):
        return True
    if isinstance(leaf, np.ndarray):
        return not leaf.flags.writeable
    return isinstance(leaf, (int, float, complex, bool, bytes, str))


def _pytree_is_immutable(obj) -> bool:
    """True when every leaf is immutable — the precondition for BOTH
    content memos (digest and session): a writeable numpy leaf can be
    mutated in place after caching, leaving an entry findable under a
    key its content no longer matches."""
    return all(_leaf_is_immutable(leaf)
               for leaf in jax.tree_util.tree_flatten(obj)[0])


def _content_digest(obj) -> str:
    """sha256 of one pytree's (class, treedef, leaf dtype/shape/bytes).

    Memoized per live immutable-leaved object: repeat fingerprinting of
    the SAME operator (every ``repro.solve`` call in a time-stepping
    loop, every registry re-registration) must not pay a device-to-host
    copy + hash of all leaves just to discover a cache hit.
    """
    import hashlib

    key = id(obj)
    hit = _CONTENT_DIGESTS.get(key)
    if hit is not None and hit[0]() is obj:
        return hit[1]

    h = hashlib.sha256()
    leaves, treedef = jax.tree_util.tree_flatten(obj)
    h.update(type(obj).__name__.encode())
    h.update(repr(treedef).encode())
    for leaf in leaves:
        arr = np.asarray(leaf)
        if arr.dtype == object:
            raise TypeError(
                f"cannot fingerprint non-array content of type "
                f"{type(leaf).__name__} (in {type(obj).__name__}); "
                "content-addressed caching needs operator pytrees "
                "with array leaves")
        h.update(str(arr.dtype).encode())
        h.update(str(arr.shape).encode())
        h.update(arr.tobytes())
    digest = h.hexdigest()
    if not all(_leaf_is_immutable(leaf) for leaf in leaves):
        return digest           # mutable leaves: never memoize
    try:
        ref = weakref.ref(obj, lambda _, k=key: _CONTENT_DIGESTS.pop(k, None))
    except TypeError:
        return digest           # unweakrefable (raw arrays): no memo
    _CONTENT_DIGESTS[key] = (ref, digest)
    return digest


def operator_fingerprint(op, precond: PrecondLike = None) -> str:
    """Content hash identifying an operator (and optionally a precond spec).

    Two operator objects with the same class, static aux data and array
    contents hash identically — this is the cache key under which
    sessions (built preconditioners + compiled solver programs) are
    reused across :func:`make_solver` calls, ``repro.solve`` one-shots,
    and :mod:`repro.service` registrations: repeat traffic against the
    same A must not rebuild block inverses or retrace the step program
    just because the caller re-constructed the operator object.

    ``precond`` folds a name spec or a built
    :class:`~repro.precond.Preconditioner` into the key (a built
    instance hashes by its own pytree contents, so two
    differently-parameterized block-Jacobi instances never collide).

    Raises ``TypeError`` for non-array content (bare matvec callables,
    object-dtype leaves): identity-based hashes would alias after
    garbage collection, so unhashable operators are simply not cached.
    """
    import hashlib

    h = hashlib.sha256()
    h.update(b"op:")
    h.update(_content_digest(op).encode())
    if precond is not None:
        if isinstance(precond, str):
            h.update(f"precond-name:{precond}".encode())
        else:
            h.update(b"precond:")
            h.update(_content_digest(precond).encode())
    return h.hexdigest()


# ---------------------------------------------------------------------------
# the session object
# ---------------------------------------------------------------------------

class LinearSolver:
    """One method bound to one operator: build once, solve many times.

    Construct via :func:`make_solver` (which adds content-keyed session
    caching); the constructor itself resolves the method from
    :data:`repro.core.SOLVERS`, builds the preconditioner ONCE, composes
    the substrate-dispatched (block) matvec, and lazily memoizes one
    jitted program per (program kind, derived config, argument
    structure) in ``self._programs``.

    Attributes:
      method / operator / config: as bound.
      sub: the resolved :class:`~repro.core.Substrate`.
      kernel_backed: True when ``sub`` runs the hand-tiled Pallas kernels.
      precond: the BUILT preconditioner instance (None when unset) —
        validated at bind time, built lazily on first local-solve use;
        ``precond_spec`` keeps the original spec so the distributed
        binding can rebuild shard-locally from a name without paying
        the global build.
      fingerprint: content hash (None when the operator is a bare
        callable — such sessions are never cached).
      stats: ``{"traces", "programs", "solves"}`` — ``traces`` counts
        actual retraces of session programs (the repeat-solve
        amortization metric benchmarks/bench_api.py reports).
    """

    def __init__(self, method: str, operator, *,
                 precond: PrecondLike = None,
                 substrate: SubstrateLike = "jnp",
                 config: SolverConfig = SolverConfig(),
                 dot_reduce: Optional[DotReduce] = None,
                 blocked: bool = False,
                 fingerprint: Optional[str] = None):
        if method not in SOLVERS:
            raise ValueError(f"unknown method {method!r}; expected one of "
                             f"{sorted(SOLVERS)}")
        self.method = method
        self.operator = operator
        self.config = config
        self.sub = get_substrate(substrate)
        self.kernel_backed = bool(getattr(self.sub, "kernel_backed", False))
        if getattr(self.sub, "name", None) == "pallas":
            assert self.kernel_backed, (
                "substrate resolved to 'pallas' but is not kernel-backed")
        self.blocked = bool(blocked)
        self.precond_spec = precond
        self.fingerprint = fingerprint
        self._dot_reduce = identity_reduce if dot_reduce is None else dot_reduce
        self.stats: Dict[str, int] = {"traces": 0, "programs": 0, "solves": 0}
        self._programs: Dict[Any, Callable] = {}
        self._mesh_bindings: Dict[Any, "DistributedSolver"] = {}
        #: ProfileReport of the most recent ``solve(..., profile=dir)``
        self.last_profile = None

        # spec validated EAGERLY (bad binds fail at make_solver time) but
        # built LAZILY on first local-solve use: a session only ever used
        # via .on_mesh rebuilds the preconditioner shard-locally and must
        # not pay the global build (e.g. block-Jacobi's dense inversions)
        validate_precond_spec(precond, operator)
        self._precond_built = False
        self._precond_val: Optional[Preconditioner] = None
        self._bmv: Optional[Callable] = None
        self._papply_val: Optional[Callable] = None

    @property
    def precond(self) -> Optional[Preconditioner]:
        """The BUILT preconditioner (first access builds it, once).

        The build runs under ``ensure_compile_time_eval``: the first
        access often happens while tracing a session program, and the
        built arrays are cached on the session — they must be concrete
        constants, not tracers of whichever trace got there first.
        """
        if not self._precond_built:
            with _span("api.precond_build",
                       spec=str(self.precond_spec)), \
                    jax.ensure_compile_time_eval():
                self._precond_val = resolve_precond(self.precond_spec,
                                                    self.operator)
            self._precond_built = True
        return self._precond_val

    @property
    def _papply(self) -> Optional[Callable]:
        if self._bmv is None:
            self.block_matvec       # composition builds _papply_val
        return self._papply_val

    @property
    def block_matvec(self) -> Callable:
        """Substrate-dispatched block matvec, composed ONCE with M^{-1}.

        Left preconditioning INSIDE the matvec keeps operator dispatch
        to the Pallas kernels and the overlap window — see
        repro/precond/base.py.
        """
        if self._bmv is None:
            raw_bmv = self.operator if self.blocked \
                else self.sub.as_block_matvec(self.operator)
            pc = self.precond
            if pc is None:
                self._bmv = raw_bmv
            else:
                papply = self.sub.as_precond_apply(pc)
                self._papply_val = papply
                self._bmv = lambda X: papply(raw_bmv(X))
        return self._bmv

    def __repr__(self):
        # precond_spec, not the precond property: repr (debugger, log
        # line) must never trigger the lazy global build
        pc = self.precond_spec if not self._precond_built else \
            getattr(self._precond_val, "name", None)
        fp = (self.fingerprint or "uncached")[:12]
        return (f"<LinearSolver {self.method!r} substrate={self.sub.name!r} "
                f"precond={pc!r} fingerprint={fp!r}>")

    def verify_contracts(self, *, bindings: Optional[Sequence[str]] = None,
                         mesh=None, m: int = 3,
                         contracts: Optional[Sequence[str]] = None,
                         raise_on_violation: bool = False):
        """Statically verify the paper's communication contracts on THIS
        session's bindings — tracing only, no solve runs.

        Traces the session's method/operator/substrate/precond/guard
        through :mod:`repro.analysis` and runs the contract passes
        (one fused reduction per iteration, overlap-edge freedom, kernel
        backing, dtype flow; plus the single-psum pass when ``mesh=`` is
        given and the operator is a stencil).

        Args:
          bindings: binding kinds to trace; default: ``["batched"]`` for
            p-BiCGSafe sessions (the multi-RHS front door), else
            ``["single"]``.
          mesh: a ``jax.sharding.Mesh`` — adds the sharded ``"mesh"``
            cell to the sweep.
          contracts: names from :data:`repro.analysis.PASSES` to run
            (default: all applicable).
          raise_on_violation: raise ``ValueError`` listing the violated
            contracts instead of returning reports that carry them.

        Returns:
          list of :class:`repro.analysis.ContractReport`, one per traced
          binding.
        """
        from repro.analysis import run_passes, trace_binding
        if bindings is None:
            bindings = ["batched"] if (self.method == "p-bicgsafe"
                                       or self.blocked) else ["single"]
        bindings = list(bindings)
        if mesh is not None and "mesh" not in bindings:
            bindings.append("mesh")
        reports = []
        for binding in bindings:
            reports.append(run_passes(trace_binding(
                self.method, self.operator, binding=binding,
                substrate=self.sub, precond=self.precond,
                guard=self.config.guard, m=m, config=self.config,
                mesh=mesh if binding == "mesh" else None,
                blocked=self.blocked), names=contracts))
        if raise_on_violation:
            bad = [(r.spec.label, f) for r in reports
                   for f in r.violations]
            if bad:
                raise ValueError(
                    "contract violation(s) on this session's bindings:\n"
                    + "\n".join(f"  {label}: {f.contract} — {f.detail}"
                                for label, f in bad))
        return reports

    def _require_pbicgsafe(self, what: str) -> None:
        """The batched/open-loop iteration (repro.core.multirhs) IS
        p-BiCGSafe; a session bound to another method must not silently
        run the wrong algorithm through these entry points."""
        if self.method != "p-bicgsafe":
            raise ValueError(
                f"{what} runs the batched p-BiCGSafe iteration only "
                f"(this session is bound to {self.method!r}); bind a "
                '"p-bicgsafe" session for multi-RHS / open-loop solves, '
                "or use .solve per right-hand side")

    # -- program memoization ----------------------------------------------

    def _program(self, key, build: Callable[[], Callable]) -> Callable:
        fn = self._programs.get(key)
        if fn is None:
            with _span("api.program_build", method=self.method,
                       kind=str(key[0]) if key else ""):
                fn = self._programs[key] = build()
            self.stats["programs"] += 1
        return fn

    def _run_program(self, key, build, *args, **kwargs):
        """Invoke a memoized program; when a profiling capture is open
        (``repro.observe.profile``), note the program + abstract arg
        shapes so the capture can extract its HLO phase map afterwards.
        The None check is the only overhead on the hot path."""
        fn = self._program(key, build)
        cap = _profile.active_capture()
        if cap is not None:
            cap.note_program(fn, args, kwargs)
        return fn(*args, **kwargs)

    def _profiled_run(self, key, build, args, profile_dir: str,
                      entry: str) -> SolveResult:
        """Warm the program, re-run it inside a profiler capture window,
        and attach the analyzed :class:`~repro.observe.profile
        .ProfileReport` as ``self.last_profile`` (also written to
        ``profile_dir/profile.json`` next to the raw timeline)."""
        import os

        fn = self._program(key, build)
        jax.block_until_ready(fn(*args))        # warm: keep compilation
        with _profile.capture(profile_dir) as cap:  # out of the window
            res = fn(*args)
            jax.block_until_ready(res)
            cap.note_program(fn, args)
        iters = int(np.max(np.asarray(res.iterations)))
        rep = cap.analyze(
            iterations=iters or None,
            label=f"{self.method}/{self.sub.name}/{entry}")
        rep.save(os.path.join(profile_dir, "profile.json"))
        cap.save_hlo_map()
        self.last_profile = rep
        return res

    def _mark_trace(self) -> None:
        """Called from inside each program closure: runs once per actual
        jit (re)trace — the amortization metric."""
        self.stats["traces"] += 1
        _metrics.PROGRAM_TRACES.inc()

    def _derive(self, tol, maxiter, trace=None) -> SolverConfig:
        cfg = self.config
        if tol is not None:
            cfg = dataclasses.replace(cfg, tol=float(tol))
        if maxiter is not None:
            cfg = dataclasses.replace(cfg, maxiter=int(maxiter))
        if trace is not None:
            # trace=True -> ring sized to the iteration budget (a full
            # record); an int -> that capacity; False -> force off
            cap = cfg.maxiter if trace is True else int(trace)
            cfg = dataclasses.replace(cfg, trace_cap=cap)
        return cfg

    def _count_solve(self, entry: str) -> None:
        self.stats["solves"] += 1
        _metrics.SOLVES.inc(method=self.method, substrate=self.sub.name,
                            entry=entry)

    @staticmethod
    def _wrap_trace(res: SolveResult) -> SolveResult:
        """ConvergenceTrace at the host boundary (no-op when tracing is
        off — the result is returned as the program produced it)."""
        if res.trace is None:
            return res
        return res._replace(trace=wrap_trace(res.trace))

    def _prep(self, B):
        return B if self._papply is None else self._papply(B)

    def _as_block(self, B) -> jax.Array:
        """Accept an (n, m) block or a sequence of per-column vectors."""
        if isinstance(B, (list, tuple)):
            B = jnp.stack([jnp.asarray(c) for c in B], axis=1)
        else:
            B = jnp.asarray(B)
        if B.ndim != 2:
            raise ValueError(
                f"B must be (n, m) or a sequence of (n,) columns; got "
                f"shape {B.shape}")
        return B

    def _col(self, value, m, default, dtype, *, name="tol"):
        """Materialize a per-column (m,) vector host-side so every solve
        shares one jitted program signature (scalar and None inputs
        broadcast; (m,) vectors pass through; wrong lengths are loud —
        the same :func:`repro.core.types.per_column` contract the
        solvers enforce)."""
        return per_column(default if value is None else value, m, dtype,
                          name=name)

    # -- single-RHS -------------------------------------------------------

    def solve(self, b, x0=None, *, tol=None, maxiter=None,
              r0_star=None, trace=None, profile=None) -> SolveResult:
        """Solve A x = b; the compiled program is cached on the session.

        ``tol``/``maxiter`` override the bound config (each distinct
        override pair compiles its own program — they are static inside
        the solver loop); ``x0``/``r0_star`` as for the free functions.
        ``trace=True`` records the per-iteration convergence trace
        (``SolveResult.trace`` becomes a :class:`repro.observe
        .ConvergenceTrace`); an int keeps only the last that-many
        iterations; the solution is bitwise identical either way (the
        ring buffer is a write-only consumer of values the fused
        reduction already computes — see :mod:`repro.observe`).
        ``profile=dir`` warms the program, re-runs the solve inside a
        :func:`jax.profiler.trace` window, and attaches the analyzed
        per-phase/overlap :class:`~repro.observe.profile.ProfileReport`
        as ``self.last_profile`` (artifacts land under ``dir``).
        """
        if self.blocked:
            raise ValueError(
                "this session wraps a block matvec (blocked=True); "
                "use solve_many / the open-loop handles")
        cfg = self._derive(tol, maxiter, trace)
        key = ("solve", cfg, x0 is None, r0_star is None)

        def build():
            solver = SOLVERS[self.method]

            def solve_program(b, x0, r0s):
                self._mark_trace()
                with internal_use():
                    return solver(self.operator, b, x0, config=cfg,
                                  r0_star=r0s, dot_reduce=self._dot_reduce,
                                  substrate=self.sub, precond=self.precond)
            return jax.jit(solve_program)

        self._count_solve("solve")
        args = (jnp.asarray(b), x0, r0_star)
        if profile is not None:
            return self._wrap_trace(
                self._profiled_run(key, build, args, profile, "solve"))
        return self._wrap_trace(self._run_program(key, build, *args))

    # -- multi-RHS --------------------------------------------------------

    def solve_many(self, B, X0=None, *, tol=None, maxiter=None,
                   r0_star=None, trace=None, profile=None) -> SolveResult:
        """Solve A X = B for all columns at once (ONE (9, m) reduction
        per iteration).

        ``B`` is an (n, m) block or a sequence of per-column (n,)
        vectors.  ``tol``/``maxiter`` may be scalars or per-column (m,)
        vectors; per-column values are runtime arguments, so
        heterogeneous batches share one compiled program.  A scalar
        ``maxiter`` also re-bounds the compiled loop (one program per
        distinct value); per-column ``maxiter`` vectors are capped by
        ``config.maxiter`` — the loop bound — the same way the
        service's resident blocks are.  ``trace`` as in :meth:`solve`;
        the returned :class:`~repro.observe.ConvergenceTrace` is
        batched (``.column(j)`` for per-column views).  ``profile`` as
        in :meth:`solve` (the report's per-iteration numbers use the
        worst column's iteration count).
        """
        self._require_pbicgsafe("solve_many")
        B = self._as_block(B)
        m = B.shape[1]
        if maxiter is not None and np.ndim(maxiter) == 0:
            cfg = self._derive(None, maxiter, trace)
            maxiter = None
        else:
            cfg = self._derive(None, None, trace)
        tol_col = self._col(tol, m, cfg.tol, B.dtype)
        mit_col = self._col(maxiter, m, cfg.maxiter, jnp.int32,
                            name="maxiter")
        key = ("solve_many", cfg, X0 is None, r0_star is None)

        def build():
            def solve_many_program(B, X0, tolv, mitv, r0s):
                self._mark_trace()
                with internal_use():
                    st = init_state(self.block_matvec, self._prep(B), X0,
                                    config=cfg, r0_star=r0s,
                                    dot_reduce=self._dot_reduce,
                                    substrate=self.sub, tol=tolv,
                                    maxiter=mitv)
                    st = step_chunk(self.block_matvec, st, cfg.maxiter,
                                    config=cfg, dot_reduce=self._dot_reduce,
                                    substrate=self.sub)
                return result_from_state(st)
            return jax.jit(solve_many_program)

        self._count_solve("solve_many")
        args = (B, X0, tol_col, mit_col, r0_star)
        if profile is not None:
            return self._wrap_trace(self._profiled_run(
                key, build, args, profile, "solve_many"))
        return self._wrap_trace(self._run_program(key, build, *args))

    # -- open-loop handles (what repro.service drives) --------------------

    def init(self, B, X0=None, *, tol=None, maxiter=None,
             r0_star=None) -> dict:
        """Build the per-column Krylov state pytree for ``A X = B``
        (left-preconditioning of B happens inside the program)."""
        self._require_pbicgsafe("init")
        B = self._as_block(B)
        m = B.shape[1]
        tol_col = self._col(tol, m, self.config.tol, B.dtype)
        mit_col = self._col(maxiter, m, self.config.maxiter,
                            jnp.int32, name="maxiter")
        key = ("init", X0 is None, r0_star is None)

        def build():
            def init_program(B, X0, tolv, mitv, r0s):
                self._mark_trace()
                with internal_use():
                    return init_state(self.block_matvec, self._prep(B), X0,
                                      config=self.config, r0_star=r0s,
                                      dot_reduce=self._dot_reduce,
                                      substrate=self.sub, tol=tolv,
                                      maxiter=mitv)
            return jax.jit(init_program)

        return self._run_program(key, build, B, X0, tol_col, mit_col,
                                 r0_star)

    def step_chunk(self, state: dict, k: int) -> dict:
        """Advance every live column by up to ``k`` iterations — ONE
        compiled program per k, one (9, m) reduction per iteration."""
        self._require_pbicgsafe("step_chunk")

        def build():
            def step_chunk_program(state, k):
                self._mark_trace()
                with internal_use():
                    return step_chunk(self.block_matvec, state, k,
                                      config=self.config,
                                      dot_reduce=self._dot_reduce,
                                      substrate=self.sub)
            return jax.jit(step_chunk_program, static_argnames=("k",))

        return self._run_program(("step_chunk",), build, state, k=int(k))

    def splice(self, state: dict, refill, B_new, *, tol=None,
               maxiter=None, r0_star=None) -> dict:
        """Refill masked columns with fresh (preconditioned-in-program)
        right-hand sides mid-flight; surviving columns are untouched."""
        self._require_pbicgsafe("splice")
        B_new = self._as_block(B_new)
        m = B_new.shape[1]
        tol_col = self._col(tol, m, self.config.tol, B_new.dtype)
        mit_col = self._col(maxiter, m, self.config.maxiter,
                            jnp.int32, name="maxiter")
        key = ("splice", r0_star is None)

        def build():
            def splice_program(state, refill, Bn, tolv, mitv, r0s):
                self._mark_trace()
                with internal_use():
                    return splice_columns(self.block_matvec, state, refill,
                                          self._prep(Bn), r0_star=r0s,
                                          dot_reduce=self._dot_reduce,
                                          substrate=self.sub, tol=tolv,
                                          maxiter=mitv)
            return jax.jit(splice_program)

        return self._run_program(
            key, build, state, jnp.asarray(refill), B_new, tol_col,
            mit_col, r0_star)

    def splice_step(self, state: dict, refill, B_new, tol, maxiter,
                    k: int) -> dict:
        """Fused splice-then-step: admission costs ONE dispatch + one
        host read, same as a chunk without refills (the service engine's
        'one program regardless of request mix' property)."""
        self._require_pbicgsafe("splice_step")
        B_new = self._as_block(B_new)
        m = B_new.shape[1]
        tol_col = self._col(tol, m, self.config.tol, B_new.dtype)
        mit_col = self._col(maxiter, m, self.config.maxiter,
                            jnp.int32, name="maxiter")

        def build():
            def splice_step_program(state, refill, Bn, tolv, mitv, k):
                self._mark_trace()
                with internal_use():
                    st = splice_columns(self.block_matvec, state, refill,
                                        self._prep(Bn),
                                        dot_reduce=self._dot_reduce,
                                        substrate=self.sub, tol=tolv,
                                        maxiter=mitv)
                    return step_chunk(self.block_matvec, st, k,
                                      config=self.config,
                                      dot_reduce=self._dot_reduce,
                                      substrate=self.sub)
            return jax.jit(splice_step_program, static_argnames=("k",))

        return self._run_program(
            ("splice_step",), build, state, jnp.asarray(refill), B_new,
            tol_col, mit_col, k=int(k))

    def result(self, state: dict) -> SolveResult:
        """Package an open-loop state pytree as a :class:`SolveResult`.

        Open-loop tracing is config-driven: bind the session with
        ``SolverConfig(trace_cap=...)`` (or set ``ServiceConfig
        .trace_cap`` on the engine) and every chunk carries the ring
        buffer; this wraps it into a batched
        :class:`~repro.observe.ConvergenceTrace`.
        """
        return self._wrap_trace(result_from_state(state))

    # -- distributed binding ----------------------------------------------

    def on_mesh(self, mesh, *, shard_axes: Optional[Sequence[str]] = None
                ) -> "DistributedSolver":
        """Bind this session to a JAX mesh: returns a
        :class:`DistributedSolver` whose solves shard the grid by rows
        (halo-exchange matvec, ONE psum of the stacked partials per
        reduction phase) with the shard_map program built and cached
        ONCE — the legacy drivers rebuild it per call.

        The binding itself is memoized per (mesh, shard_axes) on the
        session, so calling ``on_mesh`` inside a loop (the literal
        replacement the legacy drivers' deprecation message suggests)
        still reuses the built programs.

        A custom ``dot_reduce`` cannot be honored here — the sharded
        driver's whole point is supplying its own single-psum reduction
        — so binding one is a loud error rather than a silent drop.
        """
        if self._dot_reduce is not identity_reduce:
            raise ValueError(
                "this session binds a custom dot_reduce, which the "
                "distributed driver replaces with its own single psum; "
                "bind the session without dot_reduce= to use .on_mesh")
        key = (mesh, None if shard_axes is None else tuple(shard_axes))
        try:
            hit = self._mesh_bindings.get(key)
        except TypeError:               # unhashable mesh: uncached binding
            return DistributedSolver(self, mesh, shard_axes)
        if hit is None:
            hit = self._mesh_bindings[key] = DistributedSolver(
                self, mesh, shard_axes)
        return hit


class DistributedSolver:
    """A session bound to a mesh: sharded solves from the same front door.

    Wraps :func:`repro.core.distributed.build_stencil_solver` /
    ``build_stencil_solver_batched``; the operator must be a
    :class:`~repro.core.Stencil7Operator` (the row-sharded halo-exchange
    format).  Name-spec preconditioners are rebuilt SHARD-LOCALLY from
    ``precond_spec`` exactly as the legacy drivers do (zero extra
    collectives — the single psum per iteration survives, asserted
    through this binding in tests/test_substrate_parity.py).
    """

    def __init__(self, session: LinearSolver, mesh,
                 shard_axes: Optional[Sequence[str]] = None):
        if not isinstance(session.operator, Stencil7Operator):
            raise TypeError(
                "on_mesh requires a Stencil7Operator-bound session (the "
                f"row-sharded halo format); got "
                f"{type(session.operator).__name__}")
        self.session = session
        self.mesh = mesh
        self.shard_axes = None if shard_axes is None else tuple(shard_axes)
        self._programs: Dict[Any, Callable] = {}

    def _program(self, key, build):
        fn = self._programs.get(key)
        if fn is None:
            fn = self._programs[key] = build()
            self.session.stats["programs"] += 1
        return fn

    def _run_program(self, key, build, *args):
        fn = self._program(key, build)
        cap = _profile.active_capture()
        if cap is not None:
            cap.note_program(fn, args)
        return fn(*args)

    def solve(self, b_grid, *, tol=None, maxiter=None,
              trace=None, profile=None) -> SolveResult:
        """Sharded single-RHS solve of the bound method on the mesh.

        ``trace`` as in :meth:`LinearSolver.solve` — the ring buffer is
        built from psum-replicated scalars, so tracing adds no
        collective (still ONE psum per iteration, contract-verified).
        ``profile=dir`` as in :meth:`LinearSolver.solve`: the captured
        timeline covers every participating device, so here the overlap
        efficiency reads the psum/all-reduce time actually hidden under
        the halo-exchange matvec (report on ``session.last_profile``).
        """
        s = self.session
        cfg = s._derive(tol, maxiter, trace)

        def build():
            from repro.core.distributed import build_stencil_solver
            return build_stencil_solver(
                SOLVERS[s.method], s.operator, self.mesh,
                shard_axes=self.shard_axes, config=cfg, substrate=s.sub,
                precond=s.precond_spec)

        s._count_solve("mesh_solve")
        key = ("dsolve", cfg)
        if profile is not None:
            import os

            fn = self._program(key, build)
            jax.block_until_ready(fn(b_grid))   # warm outside the window
            with _profile.capture(profile) as cap:
                res = fn(b_grid)
                jax.block_until_ready(res)
                cap.note_program(fn, (b_grid,))
            rep = cap.analyze(
                iterations=int(np.max(np.asarray(res.iterations))) or None,
                label=f"{s.method}/{s.sub.name}/mesh_solve")
            rep.save(os.path.join(profile, "profile.json"))
            cap.save_hlo_map()
            s.last_profile = rep
            return s._wrap_trace(res)
        return s._wrap_trace(self._run_program(key, build, b_grid))

    def solve_many(self, B_grid, *, tol=None, maxiter=None,
                   trace=None) -> SolveResult:
        """Sharded batched solve: (nx, ny, nz, m) right-hand sides, ONE
        (9, m) psum per iteration independent of m."""
        s = self.session
        s._require_pbicgsafe("on_mesh(...).solve_many")
        cfg = s._derive(tol, maxiter, trace)

        def build():
            from repro.core.distributed import build_stencil_solver_batched
            return build_stencil_solver_batched(
                s.operator, self.mesh, shard_axes=self.shard_axes,
                config=cfg, substrate=s.sub, precond=s.precond_spec)

        s._count_solve("mesh_solve_many")
        return s._wrap_trace(
            self._run_program(("dsolve_many", cfg), build, B_grid))


# ---------------------------------------------------------------------------
# the session cache (ONE source of truth; service/registry.py consumes it)
# ---------------------------------------------------------------------------

#: LRU-bounded: a long-running process whose operator content evolves
#: (time-stepping coefficients solved one-shot via ``repro.solve``) must
#: not pin every historical operator's arrays + compiled programs until
#: OOM.  Reuse within the bound is the common repeat-traffic case; a
#: live session handed out by make_solver keeps working after eviction —
#: it is simply no longer findable by content.
_SESSION_CACHE_MAX = 64
_SESSIONS: "OrderedDict[Tuple, LinearSolver]" = OrderedDict()


def _substrate_cache_name(sub) -> Optional[str]:
    """Registry substrates are cacheable by name; ad-hoc instances are
    not (their behavior is not content-addressable)."""
    name = getattr(sub, "name", None)
    return name if SUBSTRATES.get(name) is sub else None


def make_solver(method: str = "p-bicgsafe", operator=None, *,
                scenario=None,
                precond: PrecondLike = None,
                substrate: SubstrateLike = "jnp",
                config: SolverConfig = SolverConfig(),
                dot_reduce: Optional[DotReduce] = None,
                blocked: bool = False,
                recovery=None) -> LinearSolver:
    """Bind ``method`` to ``operator`` once; returns a (usually cached)
    :class:`LinearSolver` session.

    Args:
      method: a name from :data:`repro.core.SOLVERS`
        (default ``"p-bicgsafe"``, the paper's method).
      scenario: a registered scenario name or :class:`repro.scenarios
        .Scenario` — the declarative spelling of this whole call: the
        operator is built through its plugin (cached per spec content)
        and method/precond/substrate/config/recovery come from the
        scenario, so every other argument must be left at its default.
        ``make_solver(scenario="poisson-jacobi")`` is
        ``Scenario.bind()`` through the front door, and hits the same
        session cache.
      operator: operator object (Dense/CSR/ELL/Stencil7), dense matrix,
        or bare matvec callable.  Content-addressable operators make the
        session cacheable; callables do not (name-spec preconditioners
        also need an operator object).
      precond: ``None`` | name | :class:`~repro.precond.Preconditioner`.
        Built ONCE here; the distributed binding rebuilds name specs
        shard-locally.
      substrate: ``"jnp"`` | ``"pallas"`` | Substrate instance.
      config: the bound :class:`~repro.core.SolverConfig`
        (``.solve(tol=..., maxiter=...)`` derives overrides per call).
      dot_reduce: custom reduction combiner — sessions with one are
        never cached (callables are not content-addressable).
      blocked: ``operator`` is already an ``(n, m) -> (n, m)`` block
        matvec (advanced; multi-RHS/open-loop entry points only — this
        is the session analogue of ``solve_batched(blocked=True)``).
      recovery: ``None`` | ``True`` | :class:`repro.resilience
        .RecoveryPolicy` — returns a :class:`repro.resilience
        .GuardedSolver` wrapping a guarded session
        (``config.guard=True``; the fused reduction widens to (11, m)
        carrying in-flight health rows) whose chunked driver applies the
        policy's recovery actions — residual replacement, restart,
        method fallback, substrate degradation — at chunk boundaries.
        ``True`` means the default policy.  p-BiCGSafe only (the guard
        rides the batched pipelined iteration).

    Two calls with equal *content* (operator bytes, precond spec,
    substrate name, config, method) return the SAME session — the built
    preconditioner and every compiled program are reused.  This is the
    cache :mod:`repro.service`'s registry consumes.  Guarded wrappers
    are thin, host-side objects built per call; the guarded *session*
    underneath is cached by the same content key.
    """
    if scenario is not None:
        # lazy: repro.scenarios imports this module's public surface
        from repro.scenarios import resolve_scenario
        if operator is not None or method != "p-bicgsafe" \
                or precond is not None or substrate != "jnp" \
                or config != SolverConfig() or dot_reduce is not None \
                or blocked or recovery is not None:
            raise TypeError(
                "make_solver(scenario=...) is exclusive: the scenario "
                "declares the operator, method, precond, substrate, "
                "config and recovery — pass nothing else")
        return resolve_scenario(scenario).bind()
    if operator is None:
        raise TypeError("make_solver requires an operator")
    if recovery is not None and recovery is not False:
        # lazy import: repro.resilience imports repro.api for fallbacks
        from .resilience.guard import GuardedSolver, guarded_config
        from .resilience.policy import RecoveryPolicy
        policy = RecoveryPolicy() if recovery is True else recovery
        if not isinstance(policy, RecoveryPolicy):
            raise TypeError(
                f"recovery must be None, True or a RecoveryPolicy; got "
                f"{type(recovery).__name__}")
        inner = make_solver(method, operator, precond=precond,
                            substrate=substrate,
                            config=guarded_config(config, policy),
                            dot_reduce=dot_reduce, blocked=blocked)
        return GuardedSolver(inner, policy)
    sub = get_substrate(substrate)
    sub_name = _substrate_cache_name(sub)
    try:
        # always computed when the content allows it — consumers (the
        # service registry) key on it even when the SESSION cache below
        # does not apply (custom substrate instance / dot_reduce)
        fingerprint = operator_fingerprint(operator, precond)
    except TypeError:
        fingerprint = None              # bare callables: uncacheable
    key = None
    if dot_reduce is None and sub_name is not None and not blocked \
            and fingerprint is not None \
            and _pytree_is_immutable(operator) \
            and (precond is None or isinstance(precond, str)
                 or _pytree_is_immutable(precond)):
        key = (method, fingerprint, sub_name, config)
        hit = _SESSIONS.get(key)
        if hit is not None:
            _SESSIONS.move_to_end(key)
            _metrics.SESSION_CACHE.inc(outcome="hit")
            return hit
        _metrics.SESSION_CACHE.inc(outcome="miss")
    with _span("api.bind", method=method, substrate=str(sub_name)):
        session = LinearSolver(method, operator, precond=precond,
                               substrate=sub, config=config,
                               dot_reduce=dot_reduce, blocked=blocked,
                               fingerprint=fingerprint)
    if key is not None:
        _SESSIONS[key] = session
        while len(_SESSIONS) > _SESSION_CACHE_MAX:
            _SESSIONS.popitem(last=False)
    return session


def solve(A, b, method: str = "p-bicgsafe", *, x0=None, tol=None,
          maxiter=None, r0_star=None, precond: PrecondLike = None,
          substrate: SubstrateLike = "jnp",
          config: SolverConfig = SolverConfig(),
          dot_reduce: Optional[DotReduce] = None) -> SolveResult:
    """One-shot convenience: ``repro.solve(A, b)``.

    Routes through :func:`make_solver`, so even one-shot callers hit the
    content-keyed session cache — a second ``repro.solve`` against an
    equal-content operator reuses the compiled program and built
    preconditioner instead of retracing.
    """
    session = make_solver(method, A, precond=precond, substrate=substrate,
                          config=config, dot_reduce=dot_reduce)
    return session.solve(b, x0, tol=tol, maxiter=maxiter, r0_star=r0_star)


def clear_session_cache() -> None:
    """Drop every cached session (tests; memory pressure)."""
    _SESSIONS.clear()


def session_cache_info() -> Dict[str, int]:
    return {"sessions": len(_SESSIONS),
            "programs": sum(len(s._programs) for s in _SESSIONS.values())}
