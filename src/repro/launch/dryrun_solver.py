import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Production-mesh dry-run for the paper's own workload: the distributed
pipelined solvers on a large 3-D stencil system.

Default problem: 2048 x 1024 x 1024 grid (2.1e9 unknowns) — vectors are
~17 GB each in fp64, x-sharded over all mesh axes; p-BiCGSafe keeps 11
state vectors + b + r0* (paper Table 3.1: 15 memories) ~ 1.1 GB/chip on
the 16x16 mesh.

  python -m repro.launch.dryrun_solver --solver p-bicgsafe [--multi-pod]
  python -m repro.launch.dryrun_solver --all
"""

import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402
from pathlib import Path  # noqa: E402

import jax           # noqa: E402

jax.config.update("jax_enable_x64", True)   # paper protocol: fp64 vectors

import jax.numpy as jnp  # noqa: E402

from repro.core import SOLVERS, SolverConfig  # noqa: E402
from repro.core.distributed import build_stencil_solver  # noqa: E402
from repro.core.linear_operator import Stencil7Operator  # noqa: E402
from repro.launch.flops import count_fn  # noqa: E402
from repro.launch.hlo_analysis import collective_stats  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402


def run_cell(solver_name: str, multi_pod: bool, outdir: Path,
             nx: int = 2048, ny: int = 1024, nz: int = 1024,
             dtype=jnp.float64, maxiter: int = 500, force: bool = False,
             tag: str = "") -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    cell = f"solver-{solver_name}{tag}__poisson{nx}x{ny}x{nz}"
    out = outdir / mesh_name / f"{cell}.json"
    if out.exists() and not force:
        return json.loads(out.read_text())
    out.parent.mkdir(parents=True, exist_ok=True)

    rec = {"arch": f"solver-{solver_name}{tag}",
           "shape": f"poisson{nx}x{ny}x{nz}", "mesh": mesh_name}
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        c = jnp.array([6.0, -1.0, -1.0, -1.0, -1.0, -1.0, -1.0],
                      dtype=dtype)
        op = Stencil7Operator(c, nx, ny, nz)
        b_sds = jax.ShapeDtypeStruct((nx, ny, nz), dtype)
        cfg = SolverConfig(tol=1e-8, maxiter=maxiter)
        solver = SOLVERS[solver_name]

        solve = build_stencil_solver(solver, op, mesh, config=cfg,
                                     jit=False)
        fn = jax.jit(solve)
        lowered = fn.lower(b_sds)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        mem_rec = {f: int(getattr(mem, f, 0) or 0) for f in (
            "argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "peak_memory_in_bytes")}
        cost = compiled.cost_analysis() or {}
        if isinstance(cost, (list, tuple)):   # jax >= 0.4.x returns [dict]
            cost = cost[0] if cost else {}
        text = compiled.as_text()
        # the solver iteration loop is a while: per-iteration collectives
        # (reported per iteration, NOT trip-corrected: iteration count is
        # data-dependent; roofline terms below are per-iteration)
        cs = collective_stats(text, n_devices=mesh.size)
        analytic = count_fn(fn, b_sds)   # while body counted once = 1 iter
        rec.update({
            "status": "ok",
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "memory": mem_rec,
            "flops": cost.get("flops"),
            "bytes_accessed": cost.get("bytes accessed"),
            "analytic_global_flops": analytic["flops"],
            "analytic_global_bytes": analytic["bytes"],
            "analytic_global_dot_bytes": analytic["dot_bytes"],
            "per_iteration": True,
            "collectives": {
                "counts": cs.counts,
                "result_bytes": cs.result_bytes,
                "wire_bytes": cs.wire_bytes,
                "total_wire_bytes": cs.total_wire_bytes,
            },
        })
        print(f"[ok] {mesh_name} {cell}: lower {t_lower:.1f}s "
              f"compile {t_compile:.1f}s "
              f"peak={mem_rec['peak_memory_in_bytes']/2**30:.2f}GiB "
              f"wire/iter={cs.total_wire_bytes:.3e}B")
    except Exception as e:  # noqa: BLE001
        rec.update({"status": "error", "error": str(e)[-4000:],
                    "traceback": traceback.format_exc()[-8000:]})
        print(f"[ERR] {mesh_name} {cell}: {e}")
    out.write_text(json.dumps(rec, indent=2))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--solver", default="p-bicgsafe")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--fp32", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    # quick-mode knobs (bench_roofline --quick compiles a small grid)
    ap.add_argument("--nx", type=int, default=2048)
    ap.add_argument("--ny", type=int, default=1024)
    ap.add_argument("--nz", type=int, default=1024)
    ap.add_argument("--maxiter", type=int, default=500)
    args = ap.parse_args()

    solvers = list(SOLVERS) if args.all else [args.solver]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    dtype = jnp.float32 if args.fp32 else jnp.float64
    tag = "-fp32" if args.fp32 else ""
    n_err = 0
    for mp in meshes:
        for s in solvers:
            rec = run_cell(s, mp, Path(args.out), dtype=dtype,
                           nx=args.nx, ny=args.ny, nz=args.nz,
                           maxiter=args.maxiter,
                           force=args.force, tag=tag)
            n_err += rec.get("status") == "error"
    raise SystemExit(1 if n_err else 0)


if __name__ == "__main__":
    main()
