"""Production meshes.

Functions, never module-level constants — importing this module must not
touch jax device state (device count is locked on first jax init).
"""
from __future__ import annotations

import jax

from repro.core.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_solver_mesh(n_devices: int | None = None, *,
                     axes=("data", "model")):
    """Mesh for the distributed solver examples/tests: whatever devices are
    available, folded into the requested axes (row-major)."""
    n = n_devices or jax.device_count()
    if len(axes) == 1:
        shape = (n,)
    else:
        a = 1
        while (a * 2) * (a * 2) <= n * 0:  # pragma: no cover
            a *= 2
        # largest power-of-two split n = d * m with d >= m
        m = 1
        while (m * 2) ** 2 <= n:
            m *= 2
        d = n // m
        shape = (d, m)
    return make_mesh(shape, axes[:len(shape)])


def make_multirhs_mesh(n_devices: int | None = None):
    """Mesh for sharded batched (multi-RHS) solves: one flat ``rows`` axis
    over all devices.  The (n, m) block is row-sharded over it while the m
    columns stay local to every shard, so the batched solver's single
    (9, m) psum reduces over exactly this axis
    (:func:`repro.core.distributed.distributed_stencil_solve_batched`).
    Shard-local preconditioning (``precond=`` on the distributed drivers,
    e.g. block-Jacobi) adds no traffic on any axis of this mesh — the
    psum stays the only per-iteration collective besides the halo
    ppermutes."""
    n = n_devices or jax.device_count()
    return make_mesh((n,), ("rows",))
