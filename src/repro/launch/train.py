"""Training launcher.

Single-host CPU runs train the reduced configs end-to-end; with
``--production-mesh`` the full config is lowered/compiled against the
16x16 (or 2x16x16) mesh instead (dry-run path — this container has one
real device).

  PYTHONPATH=src python -m repro.launch.train --arch xlstm-350m \
      --steps 200 --batch-size 8 --seq-len 128
"""
from __future__ import annotations

import argparse
import json


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-350m")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="checkpoints/train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--smoke", action="store_true", default=True,
                    help="use the reduced config (CPU-sized)")
    ap.add_argument("--full-config", dest="smoke", action="store_false")
    ap.add_argument("--production-mesh", action="store_true",
                    help="lower against the 512-device production mesh "
                         "(dry-run; no real step execution)")
    args = ap.parse_args()

    if args.production_mesh:
        from repro.launch import dryrun
        rec = dryrun.run_cell(args.arch, "train_4k", False,
                              outdir=__import__("pathlib").Path(
                                  "experiments/dryrun"), force=True)
        print(json.dumps({k: rec[k] for k in ("status", "compile_s")
                          if k in rec}, indent=2))
        return

    from repro.configs import get_config, smoke_config
    from repro.data import DataConfig
    from repro.optim import AdamWConfig
    from repro.train import TrainConfig, train

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    dcfg = DataConfig(batch_size=args.batch_size, seq_len=args.seq_len,
                      vocab_size=cfg.vocab_size)
    tcfg = TrainConfig(
        steps=args.steps, ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every,
        opt=AdamWConfig(lr=args.lr, warmup_steps=max(1, args.steps // 20),
                        decay_steps=args.steps))
    out = train(cfg, dcfg, tcfg)
    first = out["history"][0]["loss"] if out["history"] else float("nan")
    print(f"arch={cfg.name} steps={args.steps} "
          f"loss {first:.4f} -> {out['final_loss']:.4f} "
          f"rejected={out['rejected_steps']} "
          f"stragglers={out['straggler_stats']}")


if __name__ == "__main__":
    main()
