import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this produces a JSON artifact with:
  memory_analysis   (bytes per device: args/outputs/temps/peak)
  cost_analysis     (HLO flops / bytes accessed)
  collective_stats  (counts + wire-byte estimates per collective kind)
used by EXPERIMENTS.md §Dry-run and the roofline (§Roofline).

Usage:
  python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out experiments/dryrun]
"""

import argparse       # noqa: E402
import json           # noqa: E402
import time           # noqa: E402
import traceback      # noqa: E402
from pathlib import Path  # noqa: E402

import jax            # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import (SHAPES, get_config, input_specs,  # noqa: E402
                           skip_reason)
from repro.configs.base import ARCH_IDS  # noqa: E402
from repro.launch.hlo_analysis import collective_stats  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import (cache_logical_axes, decode_step, init_cache,  # noqa: E402
                          init_params, loss_fn, prefill_step)
from repro.optim import AdamWConfig, adamw_init, adamw_update  # noqa: E402
from repro.optim.eightbit import Q8  # noqa: E402
from repro.parallel import LogicalMesh, use_mesh  # noqa: E402
from repro.parallel.param_rules import tree_param_specs  # noqa: E402

# 8-bit optimizer states for the very large configs (DESIGN.md §5)
_I8_STATE_ARCHS = {"deepseek-v3-671b", "qwen1.5-110b", "qwen2-vl-72b",
                   "llama4-scout-17b-a16e"}


def _opt_cfg(arch: str) -> AdamWConfig:
    return AdamWConfig(state_dtype="i8" if arch in _I8_STATE_ARCHS else "f32")


def _div_spec(lm: LogicalMesh, shape, *logical):
    """Logical spec with divisibility fallback per dim."""
    parts = []
    for dim, l in zip(shape, logical):
        ax = lm.axes_for(l)
        if ax is None:
            parts.append(None)
            continue
        n = lm.size(l)
        parts.append(ax if dim % max(n, 1) == 0 and dim >= n else None)
    return P(*parts)


def _opt_state_specs(param_specs, lm: LogicalMesh, i8: bool):
    def like(spec):
        if i8:
            # scales shard like the codes' leading dims (blocks on last dim)
            lead = tuple(spec)[:-1] if len(spec) else ()
            return Q8(codes=spec, scales=P(*lead, None))
        return spec

    moments = jax.tree_util.tree_map(
        like, param_specs, is_leaf=lambda x: isinstance(x, P))
    return {"m": moments, "v": moments, "count": P()}


def _sharding_tree(spec_tree, mesh):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def build_cell(arch: str, shape: str, multi_pod: bool,
               overrides: dict | None = None):
    """Returns (jitted_fn, example_args_SDS, static info)."""
    mesh = make_production_mesh(multi_pod=multi_pod)
    lm = LogicalMesh(mesh)
    cfg = get_config(arch)
    if overrides:
        cfg = cfg.replace(**overrides)
    sp = SHAPES[shape]
    specs = input_specs(cfg, shape, arch)

    params_sds = jax.eval_shape(
        lambda: init_params(cfg, jax.random.PRNGKey(0)))
    pspecs = tree_param_specs(params_sds, lm)
    psh = _sharding_tree(pspecs, mesh)

    if sp.kind == "train":
        ocfg = _opt_cfg(arch)
        opt_sds = jax.eval_shape(lambda: adamw_init(params_sds_concrete(
            params_sds), ocfg))
        ospecs = _opt_state_specs(pspecs, lm, ocfg.state_dtype == "i8")
        osh = _sharding_tree(ospecs, mesh)
        batch = specs["batch"]
        bsh = {k: NamedSharding(mesh, _div_spec(lm, v.shape, "batch",
                                                *(None,) * (len(v.shape) - 1)))
               for k, v in batch.items()}

        def train_step(params, opt_state, batch):
            with use_mesh(lm):
                (loss, metrics), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, cfg, batch)
                params, opt_state = adamw_update(params, grads, opt_state,
                                                 ocfg)
            return params, opt_state, metrics

        fn = jax.jit(train_step,
                     in_shardings=(psh, osh, bsh),
                     out_shardings=(psh, osh, None),
                     donate_argnums=(0, 1))   # params/opt update in place
        args = (params_sds, opt_sds, batch)
        return mesh, lm, cfg, fn, args

    if sp.kind == "prefill":
        batch = specs["batch"]
        bsh = {k: NamedSharding(mesh, _div_spec(lm, v.shape, "batch",
                                                *(None,) * (len(v.shape) - 1)))
               for k, v in batch.items()}

        def pre(params, batch):
            with use_mesh(lm):
                return prefill_step(params, cfg, batch)

        fn = jax.jit(pre, in_shardings=(psh, bsh))
        return mesh, lm, cfg, fn, (params_sds, batch)

    # decode
    cache_sds = specs["cache"]
    cax = cache_logical_axes(cfg)
    cspecs = {k: _div_spec(lm, cache_sds[k].shape, *cax[k])
              for k in cache_sds}
    csh = _sharding_tree(cspecs, mesh)
    tsh = NamedSharding(mesh, _div_spec(lm, specs["tokens"].shape, "batch",
                                        None))

    def dec(params, cache, tokens, cache_len):
        with use_mesh(lm):
            return decode_step(params, cfg, cache, tokens, cache_len)

    fn = jax.jit(dec, in_shardings=(psh, csh, tsh, NamedSharding(mesh, P())),
                 out_shardings=(None, csh),
                 donate_argnums=(1,))         # cache updates in place
    args = (params_sds, cache_sds, specs["tokens"], specs["cache_len"])
    return mesh, lm, cfg, fn, args


def params_sds_concrete(sds_tree):
    """eval_shape-compatible stand-in tree (SDS is fine for eval_shape)."""
    return sds_tree


def run_cell(arch: str, shape: str, multi_pod: bool, outdir: Path,
             force: bool = False) -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    out = outdir / mesh_name / f"{arch}__{shape}.json"
    if out.exists() and not force:
        return json.loads(out.read_text())
    out.parent.mkdir(parents=True, exist_ok=True)

    reason = skip_reason(arch, shape)
    if reason:
        rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
               "status": "skip", "reason": reason}
        out.write_text(json.dumps(rec, indent=2))
        return rec

    t0 = time.time()
    rec = {"arch": arch, "shape": shape, "mesh": mesh_name}
    try:
        mesh, lm, cfg, fn, args = build_cell(arch, shape, multi_pod)
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        mem_rec = {}
        for f in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "peak_memory_in_bytes", "alias_size_in_bytes"):
            v = getattr(mem, f, None)
            if v is not None:
                mem_rec[f] = int(v)
        cost = compiled.cost_analysis()
        cost_rec = {k: float(v) for k, v in cost.items()
                    if isinstance(v, (int, float))} if cost else {}
        text = compiled.as_text()
        # layer-scan trip-count correction (HLO lists while bodies once)
        cs = collective_stats(text, n_devices=mesh.size,
                              while_body_multiplier=max(
                                  cfg.n_layers, cfg.n_encoder_layers, 1))
        cs_raw = collective_stats(text, n_devices=mesh.size)
        # analytic global flop/byte count from the jaxpr (scan-aware;
        # compiled cost_analysis undercounts while bodies + oneDNN calls)
        from repro.launch.flops import count_fn
        analytic = count_fn(fn, *args)
        rec.update({
            "status": "ok",
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "memory": mem_rec,
            "flops": cost_rec.get("flops"),
            "bytes_accessed": cost_rec.get("bytes accessed"),
            "analytic_global_flops": analytic["flops"],
            "analytic_global_bytes": analytic["bytes"],
            "analytic_global_dot_bytes": analytic["dot_bytes"],
            "cost": cost_rec,
            "collectives": {
                "counts": cs.counts,
                "result_bytes": cs.result_bytes,
                "wire_bytes": cs.wire_bytes,
                "wire_by_dtype": cs.wire_by_dtype,
                "total_wire_bytes": cs.total_wire_bytes,
                # XLA:CPU legalizes bf16->f32; TPU estimate halves f32 wire
                "tpu_wire_bytes": cs.tpu_wire_bytes(bf16_program=True),
                "total_wire_bytes_uncorrected": cs_raw.total_wire_bytes,
            },
        })
        print(f"[ok] {mesh_name} {arch} {shape}: "
              f"lower {t_lower:.1f}s compile {t_compile:.1f}s "
              f"flops={cost_rec.get('flops', 0):.3e} "
              f"wire={cs.total_wire_bytes:.3e}B")
    except Exception as e:  # noqa: BLE001 - record and continue
        rec.update({"status": "error", "error": str(e)[-4000:],
                    "traceback": traceback.format_exc()[-8000:]})
        print(f"[ERR] {mesh_name} {arch} {shape}: {e}")
    out.write_text(json.dumps(rec, indent=2))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    outdir = Path(args.out)
    archs = ARCH_IDS if args.all or not args.arch else [args.arch]
    shapes = list(SHAPES) if args.all or not args.shape else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    n_err = 0
    for mp in meshes:
        for arch in archs:
            for shape in shapes:
                rec = run_cell(arch, shape, mp, outdir, force=args.force)
                n_err += rec.get("status") == "error"
    print(f"done, {n_err} errors")
    raise SystemExit(1 if n_err else 0)


if __name__ == "__main__":
    main()
