"""Analytic FLOP/byte counting by walking the jaxpr (scan-aware).

XLA's HloCostAnalysis (compiled.cost_analysis()) counts while-loop bodies
ONCE, so scan-over-layers programs under-report flops/bytes by ~n_layers
(and the CPU backend attributes zero flops to oneDNN custom-call matmuls).
This walker counts dot_general/conv flops exactly and multiplies scan
bodies by their trip count; remat recompute inside backward scans is
counted naturally (it appears in the jaxpr).  Used by the roofline
(§Roofline) as the primary compute/memory term; compiled cost_analysis is
reported alongside as the per-iteration lower bound.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Tuple

import jax
import numpy as np
from jax.extend import core


def _aval_bytes(aval) -> int:
    try:
        return int(np.prod(aval.shape)) * aval.dtype.itemsize
    except Exception:  # noqa: BLE001
        return 0


def _dot_flops(eqn) -> float:
    d = eqn.params["dimension_numbers"]
    (lc, rc), (lb, rb) = d
    lhs, rhs = eqn.invars[0].aval, eqn.invars[1].aval
    batch = np.prod([lhs.shape[i] for i in lb], initial=1.0)
    k = np.prod([lhs.shape[i] for i in lc], initial=1.0)
    m = np.prod([lhs.shape[i] for i in range(len(lhs.shape))
                 if i not in lc and i not in lb], initial=1.0)
    n = np.prod([rhs.shape[i] for i in range(len(rhs.shape))
                 if i not in rc and i not in rb], initial=1.0)
    return 2.0 * batch * m * n * k


def _conv_flops(eqn) -> float:
    out = eqn.outvars[0].aval
    rhs = eqn.invars[1].aval
    # flops = 2 * out_elems * (kernel spatial * in_features)
    kernel = np.prod(rhs.shape, initial=1.0) / max(rhs.shape[-1], 1)
    return 2.0 * np.prod(out.shape, initial=1.0) * kernel


def _zero():
    return {"flops": 0.0, "bytes": 0.0, "dot_bytes": 0.0}


def _acc(tot, sub):
    for k in tot:
        tot[k] += sub[k]


def count_jaxpr(jaxpr, mult: float = 1.0) -> Dict[str, float]:
    """Returns {"flops", "bytes", "dot_bytes"} for one (closed) jaxpr.

    ``bytes``     unfused upper bound (every op's operands + results);
    ``dot_bytes`` matmul/conv-adjacent traffic only — the fusion-optimistic
                  lower bound the roofline memory term uses.
    """
    tot = _zero()
    inner = getattr(jaxpr, "jaxpr", jaxpr)
    for eqn in inner.eqns:
        prim = eqn.primitive.name
        if prim in ("dot_general", "conv_general_dilated"):
            fl = _dot_flops(eqn) if prim == "dot_general" else _conv_flops(eqn)
            io = (sum(_aval_bytes(v.aval) for v in eqn.invars)
                  + sum(_aval_bytes(v.aval) for v in eqn.outvars))
            tot["flops"] += fl * mult
            tot["bytes"] += io * mult
            tot["dot_bytes"] += io * mult
        elif prim == "scan":
            _acc(tot, count_jaxpr(eqn.params["jaxpr"],
                                  mult * eqn.params["length"]))
        elif prim == "while":
            # no unbounded whiles in the step functions; count body once
            _acc(tot, count_jaxpr(eqn.params["body_jaxpr"], mult))
        elif prim == "cond":
            subs = [count_jaxpr(b, mult) for b in eqn.params["branches"]]
            best = max(subs, key=lambda s: s["flops"])
            _acc(tot, best)
        else:
            # generic: descend into any jaxpr-valued params (jit, remat2,
            # custom_vjp_call, shard_map, ...)
            descended = False
            for val in eqn.params.values():
                if hasattr(val, "jaxpr") or hasattr(val, "eqns"):
                    _acc(tot, count_jaxpr(val, mult))
                    descended = True
            if not descended:
                # elementwise & reductions: write traffic of big outputs
                out_b = sum(_aval_bytes(v.aval) for v in eqn.outvars)
                if out_b >= 2 ** 16:
                    in_b = sum(_aval_bytes(v.aval) for v in eqn.invars
                               if not isinstance(v, core.Literal))
                    tot["flops"] += (out_b / 2) * mult
                    tot["bytes"] += (out_b + in_b) * mult
    return tot


def count_fn(fn, *args, **kwargs) -> Dict[str, float]:
    """Trace ``fn`` on ShapeDtypeStructs/arrays and count analytically."""
    jaxpr = jax.make_jaxpr(partial(fn, **kwargs))(*args)
    return count_jaxpr(jaxpr)
