"""Generates the EXPERIMENTS.md §Dry-run and §Roofline tables from the
dry-run artifacts.

  PYTHONPATH=src python -m repro.launch.report > experiments/report.md
"""
from __future__ import annotations

import json
from pathlib import Path

PEAK = 197e12
HBM = 819e9
LINK = 50e9

PARAMS_B = {
    "phi3-mini-3.8b": (3.7, 3.7), "qwen2.5-32b": (32.8, 32.8),
    "qwen3-8b": (8.0, 8.0), "qwen1.5-110b": (111.2, 111.2),
    "deepseek-v3-671b": (672.0, 37.0),
    "llama4-scout-17b-a16e": (108.6, 16.8),
    "zamba2-1.2b": (1.2, 1.2), "xlstm-350m": (0.35, 0.35),
    "whisper-tiny": (0.039, 0.039), "qwen2-vl-72b": (72.7, 72.7),
}
TOKENS = {"train_4k": 4096 * 256, "prefill_32k": 32768 * 32,
          "decode_32k": 128, "long_500k": 1}


def cell_terms(rec: dict, chips: int) -> dict:
    flops = (rec.get("analytic_global_flops") or 0.0) / chips
    coll = rec.get("collectives") or {}
    if rec["arch"].startswith("solver-"):
        # shard_map jaxprs are per-shard already: no /chips
        byts = rec.get("analytic_global_bytes") or 0.0
        flops = rec.get("analytic_global_flops") or 0.0
        wire = coll.get("total_wire_bytes", 0.0)
    else:
        byts = (rec.get("analytic_global_dot_bytes") or 0.0) / chips
        wire = coll.get("tpu_wire_bytes", coll.get("total_wire_bytes", 0.0))
    t = {"t_c": flops / PEAK, "t_m": byts / HBM, "t_x": wire / LINK}
    t["dominant"] = max(("compute", t["t_c"]), ("memory", t["t_m"]),
                        ("collective", t["t_x"]), key=lambda kv: kv[1])[0]
    arch, shape = rec["arch"], rec["shape"]
    if arch in PARAMS_B and shape in TOKENS:
        act = PARAMS_B[arch][1]
        mult = 3.0 if shape == "train_4k" else 1.0
        mf = 2 * act * 1e9 * TOKENS[shape] * mult / chips
        t["useful"] = mf / flops if flops else 0.0
        bound = max(t["t_c"], t["t_m"], t["t_x"])
        t["frac"] = (mf / PEAK) / bound if bound else 0.0
    return t


def dryrun_table(mesh: str) -> str:
    d = Path("experiments/dryrun") / mesh
    chips = 256 if mesh == "pod16x16" else 512
    lines = [
        f"### {mesh} ({chips} chips)",
        "",
        "| arch | shape | status | compile s | peak GiB/chip | "
        "flops/chip | HBM bytes/chip | wire B/chip | collectives |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for f in sorted(d.glob("*.json")):
        r = json.loads(f.read_text())
        if r["status"] == "skip":
            lines.append(f"| {r['arch']} | {r['shape']} | SKIP "
                         f"({r['reason'][:40]}...) | | | | | | |")
            continue
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | ERROR | | | | | | |")
            continue
        peak = r["memory"].get("peak_memory_in_bytes", 0) / 2 ** 30
        cc = r["collectives"]["counts"]
        cstr = " ".join(f"{k.split('-')[-1][:4]}:{v}"
                        for k, v in sorted(cc.items()))
        lines.append(
            f"| {r['arch']} | {r['shape']} | ok | {r['compile_s']:.0f} "
            f"| {peak:.2f} | {(r.get('analytic_global_flops') or 0)/chips:.2e} "
            f"| {(r.get('analytic_global_dot_bytes') or 0)/chips:.2e} "
            f"| {r['collectives']['total_wire_bytes']:.2e} | {cstr} |")
    return "\n".join(lines)


def roofline_table(mesh: str = "pod16x16") -> str:
    d = Path("experiments/dryrun") / mesh
    chips = 256 if mesh == "pod16x16" else 512
    lines = [
        "| arch | shape | t_compute ms | t_memory ms | t_collective ms | "
        "dominant | MODEL/HLO | roofline frac |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for f in sorted(d.glob("*.json")):
        r = json.loads(f.read_text())
        if r["status"] != "ok":
            continue
        t = cell_terms(r, chips)
        lines.append(
            f"| {r['arch']} | {r['shape']} | {t['t_c']*1e3:.2f} "
            f"| {t['t_m']*1e3:.2f} | {t['t_x']*1e3:.2f} | {t['dominant']} "
            f"| {t.get('useful', 0):.2f} | {t.get('frac', 0):.3f} |")
    return "\n".join(lines)


def main():
    for mesh in ("pod16x16", "pod2x16x16"):
        print(dryrun_table(mesh))
        print()
    print("### Roofline (single-pod, per-chip)")
    print()
    print(roofline_table("pod16x16"))


if __name__ == "__main__":
    main()
