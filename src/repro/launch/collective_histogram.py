"""Per-shape collective histogram for one dry-run cell — the §Perf
profiling tool (we reason from the lowered IR, not wall-clock traces).

  PYTHONPATH=src python -m repro.launch.collective_histogram \
      --arch qwen1.5-110b --shape train_4k
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse          # noqa: E402
import re                # noqa: E402
from collections import Counter  # noqa: E402

import numpy as np       # noqa: E402

from repro.launch.dryrun import build_cell  # noqa: E402
from repro.launch.hlo_analysis import (_DTYPE_BYTES, _SHAPE_RE,  # noqa: E402
                                       split_computations)


def histogram(text: str, multiplier_bodies=None, mult: float = 1.0):
    comps = split_computations(text)
    bodies = set()
    for line in text.splitlines():
        m = re.search(r"\bwhile\(.*?body=%?([\w.\-]+)", line)
        if m:
            bodies.add(m.group(1))
    hist = Counter()
    for cname, body in comps.items():
        k = mult if cname in bodies else 1.0
        for line in body.splitlines():
            m = re.search(r"=\s*((?:\([^)]*\))|(?:[^\s]+))\s+([\w\-]+)\(",
                          line)
            if not m:
                continue
            typestr, op = m.groups()
            base = op.split(".")[0]
            if base.rstrip("-start") not in (
                    "all-reduce", "all-gather", "reduce-scatter",
                    "all-to-all", "collective-permute") and base not in (
                    "all-reduce", "all-gather", "reduce-scatter",
                    "all-to-all", "collective-permute"):
                continue
            nbytes = 0
            for dt, dims in _SHAPE_RE.findall(typestr):
                n = 1
                for d in dims.split(","):
                    if d:
                        n *= int(d)
                nbytes += n * _DTYPE_BYTES.get(dt, 4)
            hist[(base, typestr[:60])] += k * nbytes
    return hist


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--top", type=int, default=20)
    ap.add_argument("--set", action="append", default=[],
                    help="config override key=value (e.g. remat=dots)")
    args = ap.parse_args()

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        overrides[k] = {"True": True, "False": False}.get(
            v, int(v) if v.isdigit() else v)
    mesh, lm, cfg, fn, fargs = build_cell(args.arch, args.shape,
                                          args.multi_pod, overrides)
    text = fn.lower(*fargs).compile().as_text()
    hist = histogram(text, mult=max(cfg.n_layers, 1))
    total = sum(hist.values())
    print(f"{args.arch} {args.shape}: total collective result bytes "
          f"(trip-corrected) {total:.3e}")
    for (op, shape), b in hist.most_common(args.top):
        print(f"  {b:12.3e}  {b/total*100:5.1f}%  {op:20s} {shape}")


if __name__ == "__main__":
    main()
