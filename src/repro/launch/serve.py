"""Serving launcher: batched greedy decoding on a reduced config.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --requests 6
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--max-batch", type=int, default=4)
    args = ap.parse_args()

    from repro.configs import smoke_config
    from repro.serve import Request, ServeConfig, ServingEngine

    cfg = smoke_config(args.arch)
    eng = ServingEngine(cfg, ServeConfig(max_batch=args.max_batch,
                                         max_len=args.prompt_len + args.max_new + 8))
    rng = np.random.default_rng(0)
    t0 = time.time()
    for i in range(args.requests):
        eng.submit(Request(
            prompt=list(rng.integers(1, cfg.vocab_size,
                                     args.prompt_len).astype(int)),
            max_new_tokens=args.max_new))
    done = eng.run()
    dt = time.time() - t0
    total_tokens = sum(len(r.output) for r in done)
    for r in done[:4]:
        print(f"req {r.rid}: {len(r.output)} tokens -> {r.output[:8]}...")
    print(f"{len(done)} requests, {total_tokens} tokens "
          f"in {dt:.2f}s ({total_tokens / dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
