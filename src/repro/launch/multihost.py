"""Multi-host bring-up for real pods.

On a real TPU pod each host runs this same program; `jax.distributed`
wires the hosts together and `jax.devices()` becomes the global device
set, after which `make_production_mesh()` and every step function in this
repo work unchanged (GSPMD is multi-host-transparent; per-host data
sharding comes from DataConfig.shard_index/shard_count).

    python -m repro.launch.multihost --coordinator $HOST0:1234 \
        --num-processes $N --process-id $I -- \
        python -m repro.launch.train --arch qwen3-8b --full-config

Fault-tolerance contract at this layer (see train/fault_tolerance.py for
the in-process half):
  * a host failure kills the step collective -> every surviving host gets
    a distributed runtime error -> the supervisor (run_with_restarts or
    the cluster scheduler) relaunches all hosts;
  * relaunch may use a DIFFERENT topology (lost pod): checkpoints are
    topology-free (tests/test_elastic.py) and the data pipeline is
    stateless in the step index, so the resumed run is deterministic;
  * stragglers: StepTimer feeds per-host step times; eviction is the
    scheduler's job — synchronous SPMD cannot rebalance mid-step.
"""
from __future__ import annotations

import argparse
import os
import subprocess
import sys


def initialize_from_args(coordinator: str, num_processes: int,
                         process_id: int):
    import jax
    jax.distributed.initialize(coordinator_address=coordinator,
                               num_processes=num_processes,
                               process_id=process_id)
    return jax.device_count(), jax.local_device_count()


def initialize_from_env():
    """TPU-pod style: JAX infers everything from the environment."""
    import jax
    jax.distributed.initialize()
    return jax.device_count(), jax.local_device_count()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--coordinator", required=True)
    ap.add_argument("--num-processes", type=int, required=True)
    ap.add_argument("--process-id", type=int, required=True)
    ap.add_argument("cmd", nargs=argparse.REMAINDER,
                    help="-- command to run after distributed init")
    args = ap.parse_args()

    env = dict(os.environ)
    env["JAX_COORDINATOR_ADDRESS"] = args.coordinator
    env["JAX_NUM_PROCESSES"] = str(args.num_processes)
    env["JAX_PROCESS_ID"] = str(args.process_id)
    cmd = [c for c in args.cmd if c != "--"]
    if not cmd:
        n, nl = initialize_from_args(args.coordinator, args.num_processes,
                                     args.process_id)
        print(f"distributed ok: {n} global / {nl} local devices")
        return
    raise SystemExit(subprocess.call(cmd, env=env))


if __name__ == "__main__":
    main()
