"""Compatibility shim: this module moved to :mod:`repro.analysis.hlo`.

The HLO text machinery (collective-bytes extraction, the def-use
``HloGraph``, the overlap report) is now the HLO backend of the static
contract analyzer.  Existing importers keep working through this
re-export.
"""
from repro.analysis.hlo import (_DTYPE_BYTES, _SHAPE_RE, COLLECTIVES,  # noqa: F401
                                CollectiveStats, HloGraph,
                                collective_stats, overlap_report,
                                split_computations)
