"""Parameter partitioning rules (path + shape -> PartitionSpec).

Logical placement follows MaxText-style 2-D sharding: every weight is
sharded on the TP axis ("model") along its parallel dim (heads / ff /
experts / vocab) and on the FSDP axis ("data") along the other dim; the
"pod" axis (multi-pod mesh) carries pure data parallelism, so parameters
are *replicated* across pods and gradients reduce over ("pod","data").

Axes that do not divide the dimension are dropped (e.g. whisper's vocab
51865 on a 16-way axis) — correctness first, the dry-run memory report
shows the cost.
"""
from __future__ import annotations

from typing import Optional, Tuple

from jax.sharding import PartitionSpec as P

# trailing-dims logical layout per parameter name (last path segment)
_TRAILING: dict = {
    # name: tuple of logical names for the trailing dims
    "embed": ("vocab", "fsdp"),
    "lm_head": ("fsdp", "vocab"),
    "pos_embed": (None, "fsdp"),
    # up-style projections (d -> parallel)
    "wq": ("fsdp", "tensor"), "wk": ("fsdp", "tensor"),
    "wv": ("fsdp", "tensor"), "wi": ("fsdp", "tensor"),
    "wg": ("fsdp", "tensor"), "w_up": ("fsdp", "tensor"),
    "w_x": ("fsdp", None), "w_in": ("fsdp", None),
    "wdq": ("fsdp", "tensor"), "wuq": ("fsdp", "tensor"),
    "wdkv": ("fsdp", None), "wuk": ("fsdp", "tensor"),
    "wuv": ("fsdp", "tensor"),
    "shared_wi": ("fsdp", "tensor"), "shared_wg": ("fsdp", "tensor"),
    "w_if": ("fsdp", None),
    "mtp_proj": ("fsdp", "tensor"),
    # down-style projections (parallel -> d)
    "wo": ("tensor", "fsdp"), "w_down": ("tensor", "fsdp"),
    "w_out": ("tensor", "fsdp"), "shared_wo": ("tensor", "fsdp"),
    # router
    "router": ("fsdp", None), "router_bias": (None,),
    # everything else (norms, biases, convs, gates): replicated
}

# MoE expert tensors (path contains "/moe"): trailing 3 dims
_MOE_TRAILING = {
    "wi": ("experts", "fsdp", None),
    "wg": ("experts", "fsdp", None),
    "wo": ("experts", None, "fsdp"),
}


def spec_for_param(path: str, shape: Tuple[int, ...], lm) -> P:
    name = path.split("/")[-1]
    if "moe" in path and name in _MOE_TRAILING:
        logical = _MOE_TRAILING[name]
    else:
        logical = _TRAILING.get(name, ())

    ndim = len(shape)
    spec: list = [None] * ndim
    # align logical names to the trailing dims (leading dims: layer stack)
    off = ndim - len(logical)
    for i, lname in enumerate(logical):
        if lname is None or off + i < 0:
            continue
        dim = shape[off + i]
        axes = lm.axes_for(lname)
        if axes is None:
            continue
        n = lm.size(lname)
        if dim % max(n, 1) == 0 and dim >= n:
            spec[off + i] = axes
    return P(*spec)


def tree_param_specs(params, lm, prefix: str = ""):
    """Map a param pytree (nested dicts) to a matching tree of specs."""
    if isinstance(params, dict):
        return {k: tree_param_specs(v, lm, f"{prefix}/{k}")
                for k, v in params.items()}
    return spec_for_param(prefix, params.shape, lm)
