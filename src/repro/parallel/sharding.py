"""Logical-axis sharding rules.

Model code annotates tensors with *logical* axis names ("batch", "seq",
"heads", "ff", "experts", "vocab", "fsdp", ...).  A :class:`LogicalMesh`
maps logical names to physical mesh axes for the active mesh:

    single-pod (16, 16)    ("data", "model")
    multi-pod  (2, 16, 16) ("pod", "data", "model")

Rules (MaxText-style):
    batch   -> ("pod", "data")   # DP (+pod DP)
    fsdp    -> "data"            # weight shard dim for FSDP/ZeRO
    tensor  -> "model"           # TP dim: heads / ff / experts / vocab
    seq     -> "model"           # sequence parallelism for long KV

Outside any mesh context every annotation is a no-op, so the same model
code runs in single-device smoke tests unchanged.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_STATE = threading.local()

DEFAULT_RULES: Dict[str, Tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "fsdp": ("data",),
    "tensor": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "ff": ("model",),
    "experts": ("model",),
    "vocab": ("model",),
    "seq": ("model",),
    "embed": (),        # d_model of activations: replicated
    "layers": (),
}


class LogicalMesh:
    """A physical mesh + logical->physical axis rules."""

    def __init__(self, mesh: Mesh,
                 rules: Optional[Dict[str, Tuple[str, ...]]] = None):
        self.mesh = mesh
        self.rules = dict(DEFAULT_RULES)
        if rules:
            self.rules.update(rules)

    def axes_for(self, logical: Optional[str]):
        """Physical axes for one logical name, filtered to existing axes."""
        if logical is None:
            return None
        phys = tuple(a for a in self.rules.get(logical, ())
                     if a in self.mesh.axis_names)
        if not phys:
            return None
        return phys if len(phys) > 1 else phys[0]

    def spec(self, *logical: Optional[str]) -> P:
        return P(*(self.axes_for(l) for l in logical))

    def sharding(self, *logical: Optional[str]) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(*logical))

    def size(self, logical: str) -> int:
        phys = self.rules.get(logical, ())
        n = 1
        for a in phys:
            if a in self.mesh.axis_names:
                n *= self.mesh.shape[a]
        return n


def set_mesh(lm: Optional[LogicalMesh]):
    _STATE.mesh = lm


def current_mesh() -> Optional[LogicalMesh]:
    return getattr(_STATE, "mesh", None)


@contextlib.contextmanager
def use_mesh(lm: Optional[LogicalMesh]):
    prev = current_mesh()
    set_mesh(lm)
    try:
        yield lm
    finally:
        set_mesh(prev)


def logical_constraint(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """with_sharding_constraint by logical names; no-op without a mesh."""
    lm = current_mesh()
    if lm is None:
        return x
    # drop logical names that would over-partition tiny dims
    spec = []
    for dim, l in zip(x.shape, logical):
        ax = lm.axes_for(l)
        if ax is None:
            spec.append(None)
            continue
        n = lm.size(l) if isinstance(ax, tuple) else lm.mesh.shape[ax]
        spec.append(ax if dim % max(n, 1) == 0 and dim >= n else None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(lm.mesh, P(*spec)))


def param_spec(path: str, shape: Tuple[int, ...],
               lm: LogicalMesh) -> P:
    """PartitionSpec for a parameter leaf by naming convention.

    Heuristics keyed on the param path (".../wq", ".../wi", "embed", ...)
    — see repro/launch/dryrun.py for the full table applied to each arch.
    """
    from .param_rules import spec_for_param  # local import to avoid cycle
    return spec_for_param(path, shape, lm)
