from .sharding import (LogicalMesh, current_mesh, logical_constraint,
                       param_spec, set_mesh, use_mesh)

__all__ = ["LogicalMesh", "current_mesh", "logical_constraint",
           "param_spec", "set_mesh", "use_mesh"]
