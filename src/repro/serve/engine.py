"""Batched serving engine: prefill + decode with continuous batching.

Slot-based design (vLLM-style at the batch level): a fixed-size decode
batch of ``max_batch`` slots; finished/empty slots are refilled from the
request queue each cycle by running a fresh prefill and splicing the new
KV into the batch cache.  Decode steps run one token for all active slots.

Padding unification: all slots share one (B, max_len) cache; per-slot
lengths are tracked host-side and finished slots are masked.  This keeps
exactly ONE compiled decode program regardless of request mix (no
shape churn), which is the production property that matters.

The solver service (:mod:`repro.service.engine`) is this engine's
sibling and shares the same padding-unification/slot-refill idiom: a
fixed slot block stepped by one compiled program, finished slots masked
(there, per-column convergence masks inside the Krylov iteration;
here, per-slot length masks), and freed slots refilled mid-flight by
splicing fresh state into the resident batch (there, per-column Krylov
state via ``multirhs.splice_columns``; here, prefill KV into the batch
cache).  Improvements to either engine's scheduling usually translate
to the other.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import (ModelConfig, decode_step, init_cache, init_params,
                          prefill_step)


@dataclasses.dataclass
class Request:
    prompt: List[int]
    max_new_tokens: int = 16
    temperature: float = 0.0
    rid: int = 0
    # filled by the engine:
    output: Optional[List[int]] = None


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_batch: int = 4
    max_len: int = 256
    eos_id: int = -1          # -1: never stop early
    seed: int = 0


class ServingEngine:
    """Single-host engine; the same step functions lower on the production
    mesh via launch/dryrun.py (decode_32k / prefill_32k cells)."""

    def __init__(self, cfg: ModelConfig, scfg: ServeConfig,
                 params=None, key=None):
        self.cfg = cfg
        self.scfg = scfg
        key = key if key is not None else jax.random.PRNGKey(scfg.seed)
        self.params = params if params is not None else init_params(cfg, key)
        self._decode = jax.jit(
            lambda p, c, t, l: decode_step(p, cfg, c, t, l))
        self._prefill = jax.jit(
            lambda p, b: prefill_step(p, cfg, b))
        self.queue: deque = deque()
        self.done: List[Request] = []
        self._next_rid = 0

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> int:
        req.rid = self._next_rid
        self._next_rid += 1
        req.output = []
        self.queue.append(req)
        return req.rid

    def run(self) -> List[Request]:
        """Process the queue to completion; returns finished requests.

        Requests are grouped into equal-prompt-length batches (length
        buckets) so positions/caches are exact without ragged masking."""
        B = self.scfg.max_batch
        while self.queue:
            first = self.queue.popleft()
            batch = [first]
            rest = deque()
            while self.queue and len(batch) < B:
                r = self.queue.popleft()
                if len(r.prompt) == len(first.prompt):
                    batch.append(r)
                else:
                    rest.append(r)
            self.queue.extendleft(reversed(rest))
            self._run_batch(batch)
            self.done.extend(batch)
        return self.done

    # ------------------------------------------------------------------
    def _run_batch(self, reqs: List[Request]):
        cfg, scfg = self.cfg, self.scfg
        B = len(reqs)
        plen = max(len(r.prompt) for r in reqs)
        toks = np.zeros((B, plen), np.int32)
        for i, r in enumerate(reqs):
            toks[i, -len(r.prompt):] = r.prompt      # left-pad
        batch = {"tokens": jnp.asarray(toks)}
        if cfg.family == "audio":
            batch["frames"] = jnp.zeros((B, plen, cfg.d_model), cfg.dtype)
        if cfg.family == "vlm":
            batch["positions"] = jnp.broadcast_to(
                jnp.arange(plen)[None, :, None], (B, plen, 3)
            ).astype(jnp.int32)

        logits, pcache = self._prefill(self.params, batch)
        cache = self._splice(pcache, B, plen)
        last = np.asarray(jnp.argmax(logits[:, -1], axis=-1), np.int32)
        for i, r in enumerate(reqs):
            r.output.append(int(last[i]))

        max_new = max(r.max_new_tokens for r in reqs)
        cache_len = jnp.asarray(plen, jnp.int32)
        cur = jnp.asarray(last)[:, None]
        active = np.ones(B, bool)
        for step in range(max_new - 1):
            if not active.any():
                break
            logits, cache = self._decode(self.params, cache, cur, cache_len)
            cache_len = cache_len + 1
            nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1), np.int32)
            for i, r in enumerate(reqs):
                if not active[i]:
                    continue
                if len(r.output) >= r.max_new_tokens or \
                        (self.scfg.eos_id >= 0 and nxt[i] == self.scfg.eos_id):
                    active[i] = False
                    continue
                r.output.append(int(nxt[i]))
            cur = jnp.asarray(nxt)[:, None]

    def _splice(self, pcache: Dict, B: int, plen: int) -> Dict:
        """Right-pad the length-plen prefill cache to max_len."""
        target = init_cache(self.cfg, B, self.scfg.max_len,
                            enc_len=plen if self.cfg.family == "audio" else 0)

        def fit(dst, src):
            if dst.shape == src.shape:
                return src.astype(dst.dtype)
            pads = []
            for a, (d, s) in enumerate(zip(dst.shape, src.shape)):
                pads.append((0, d - s))
            return jnp.pad(src, pads).astype(dst.dtype)

        out = {}
        for k in target:
            if k in pcache:
                out[k] = fit(target[k], pcache[k])
            else:
                out[k] = target[k]
        return out
