"""Pallas TPU kernel: the paper's single fused inner-product phase.

Computes the 9 inner products of p-BiCGSafe/ssBiCGSafe2 over the vectors
(s, y, r, t_{i-1}, r0*) in ONE pass: each vector tile is read from HBM into
VMEM exactly once and contributes to all of its dot products, vs. 9
separate dot kernels reading 18 operands.  The local partials this kernel
emits are exactly what the solver's single ``psum`` reduces (Fig. 1.1 of
the paper: local partial sums -> one global reduction).

Layout: vectors are reshaped to (rows, 128) lanes; the grid walks row
blocks sequentially and accumulates into the (1, 16)-padded output
(first 9 entries meaningful).

``fused_dots_batched_pallas`` is the multi-RHS generalization: inputs are
(n, m) column blocks (m right-hand sides) and the output is a (9, m)
partial block — the m-column analogue of the same phase.  One HBM pass
computes 9*m inner products, and the solver still reduces the whole block
with ONE ``psum``: batching amortizes both the memory traffic and the
reduction latency across right-hand sides (Krasnopolsky's multi-RHS
argument applied to the pipelined communication model).

``fused_dots_health_pallas`` / ``fused_dots_health_batched_pallas`` are
the guarded variants (repro.resilience): two extra health rows — the
solution-norm dot ``x.x`` and a NaN/Inf finiteness probe — ride along in
the SAME pass and the SAME single reduction, so breakdown/drift
detection costs zero additional communication phases.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
OUT_PAD = 16   # pad 9 -> 16 for clean layout
HEALTH_ROWS = 11  # 9 solver dots + x.x + finiteness probe (still <= OUT_PAD)


def _kernel(s_ref, y_ref, r_ref, t_ref, rs_ref, out_ref):
    i = pl.program_id(0)
    acc = out_ref.dtype
    s = s_ref[...].astype(acc)
    y = y_ref[...].astype(acc)
    r = r_ref[...].astype(acc)
    t = t_ref[...].astype(acc)
    rs = rs_ref[...].astype(acc)
    partial = jnp.stack([
        jnp.sum(s * s), jnp.sum(y * y), jnp.sum(s * y), jnp.sum(s * r),
        jnp.sum(y * r), jnp.sum(rs * r), jnp.sum(rs * s), jnp.sum(rs * t),
        jnp.sum(r * r)])
    partial = jnp.pad(partial, (0, OUT_PAD - 9)).reshape(1, OUT_PAD)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += partial


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def fused_dots_pallas(s, y, r, t, rs, *, block_rows: int = 256,
                      interpret: bool = False) -> jax.Array:
    """Returns the 9 fused dots (fp32).  Inputs: equal-length 1-D vectors."""
    n = s.shape[0]
    lane_rows = -(-n // LANES)              # ceil
    rows = -(-lane_rows // block_rows) * block_rows
    padded = rows * LANES

    def prep(v):
        return jnp.pad(v, (0, padded - n)).reshape(rows, LANES)

    args = [prep(v) for v in (s, y, r, t, rs)]
    grid = (rows // block_rows,)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))] * 5,
        out_specs=pl.BlockSpec((1, OUT_PAD), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct(
            (1, OUT_PAD), jnp.promote_types(s.dtype, jnp.float32)),
        interpret=interpret,
    )(*args)
    return out[0, :9]


def _batched_kernel(s_ref, y_ref, r_ref, t_ref, rs_ref, out_ref):
    i = pl.program_id(1)                  # row block within this column
    acc = out_ref.dtype
    s = s_ref[...].astype(acc)            # (1, block_rows, LANES)
    y = y_ref[...].astype(acc)
    r = r_ref[...].astype(acc)
    t = t_ref[...].astype(acc)
    rs = rs_ref[...].astype(acc)
    partial = jnp.stack([                 # the 9 dots of column j
        jnp.sum(s * s), jnp.sum(y * y), jnp.sum(s * y), jnp.sum(s * r),
        jnp.sum(y * r), jnp.sum(rs * r), jnp.sum(rs * s), jnp.sum(rs * t),
        jnp.sum(r * r)])
    partial = jnp.pad(partial, (0, OUT_PAD - 9)).reshape(OUT_PAD, 1)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += partial


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def fused_dots_batched_pallas(s, y, r, t, rs, *, block_rows: int = 256,
                              interpret: bool = False) -> jax.Array:
    """Multi-RHS fused dots: (n, m) inputs -> (9, m) partials (fp32+).

    Rows stay on the lane axis exactly as in the 1-D kernel (each column
    is laid out as (rows, 128) tiles) and the grid walks (column,
    row-block), so the per-column memory traffic matches the single-RHS
    kernel — no padding of the RHS axis up to a lane multiple, which for
    small m would multiply HBM reads by 128/m.
    """
    n, m = s.shape
    lane_rows = -(-n // LANES)
    rows = -(-lane_rows // block_rows) * block_rows
    padded = rows * LANES

    def prep(v):
        # (n, m) -> (m, rows, LANES): column-major tiles, rows on lanes
        return jnp.pad(v.T, ((0, 0), (0, padded - n))).reshape(
            m, rows, LANES)

    args = [prep(v) for v in (s, y, r, t, rs)]
    vec_spec = pl.BlockSpec((1, block_rows, LANES), lambda j, i: (j, i, 0))
    out = pl.pallas_call(
        _batched_kernel,
        grid=(m, rows // block_rows),
        in_specs=[vec_spec] * 5,
        out_specs=pl.BlockSpec((OUT_PAD, 1), lambda j, i: (0, j)),
        out_shape=jax.ShapeDtypeStruct(
            (OUT_PAD, m), jnp.promote_types(s.dtype, jnp.float32)),
        interpret=interpret,
    )(*args)
    return out[:9, :]


def _health_kernel(s_ref, y_ref, r_ref, t_ref, rs_ref, x_ref, out_ref):
    i = pl.program_id(0)
    acc = out_ref.dtype
    s = s_ref[...].astype(acc)
    y = y_ref[...].astype(acc)
    r = r_ref[...].astype(acc)
    t = t_ref[...].astype(acc)
    rs = rs_ref[...].astype(acc)
    x = x_ref[...].astype(acc)
    partial = jnp.stack([
        jnp.sum(s * s), jnp.sum(y * y), jnp.sum(s * y), jnp.sum(s * r),
        jnp.sum(y * r), jnp.sum(rs * r), jnp.sum(rs * s), jnp.sum(rs * t),
        jnp.sum(r * r), jnp.sum(x * x), jnp.sum(s + y + t + rs + x)])
    partial = jnp.pad(partial, (0, OUT_PAD - HEALTH_ROWS)).reshape(1, OUT_PAD)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += partial


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def fused_dots_health_pallas(s, y, r, t, rs, x, *, block_rows: int = 256,
                             interpret: bool = False) -> jax.Array:
    """Guarded fused dots: 9 solver dots + 2 health rows (x.x, NaN/Inf
    probe) in one HBM pass — see ``kernels.ref.fused_dots_health`` for
    the row layout.  Same tiling as ``fused_dots_pallas``; the padded
    output still fits the (1, 16) tile, so the guarded phase costs one
    extra VMEM operand and zero extra output traffic."""
    n = s.shape[0]
    lane_rows = -(-n // LANES)              # ceil
    rows = -(-lane_rows // block_rows) * block_rows
    padded = rows * LANES

    def prep(v):
        return jnp.pad(v, (0, padded - n)).reshape(rows, LANES)

    args = [prep(v) for v in (s, y, r, t, rs, x)]
    grid = (rows // block_rows,)
    out = pl.pallas_call(
        _health_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))] * 6,
        out_specs=pl.BlockSpec((1, OUT_PAD), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct(
            (1, OUT_PAD), jnp.promote_types(s.dtype, jnp.float32)),
        interpret=interpret,
    )(*args)
    return out[0, :HEALTH_ROWS]


def _health_batched_kernel(s_ref, y_ref, r_ref, t_ref, rs_ref, x_ref,
                           out_ref):
    i = pl.program_id(1)                  # row block within this column
    acc = out_ref.dtype
    s = s_ref[...].astype(acc)            # (1, block_rows, LANES)
    y = y_ref[...].astype(acc)
    r = r_ref[...].astype(acc)
    t = t_ref[...].astype(acc)
    rs = rs_ref[...].astype(acc)
    x = x_ref[...].astype(acc)
    partial = jnp.stack([                 # 9 dots + 2 health rows, column j
        jnp.sum(s * s), jnp.sum(y * y), jnp.sum(s * y), jnp.sum(s * r),
        jnp.sum(y * r), jnp.sum(rs * r), jnp.sum(rs * s), jnp.sum(rs * t),
        jnp.sum(r * r), jnp.sum(x * x), jnp.sum(s + y + t + rs + x)])
    partial = jnp.pad(partial, (0, OUT_PAD - HEALTH_ROWS)).reshape(OUT_PAD, 1)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += partial


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def fused_dots_health_batched_pallas(s, y, r, t, rs, x, *,
                                     block_rows: int = 256,
                                     interpret: bool = False) -> jax.Array:
    """Multi-RHS guarded dots: (n, m) inputs -> (11, m) partials.

    The m-column analogue of ``fused_dots_health_pallas``: identical
    (column, row-block) grid and lane layout as the unguarded batched
    kernel, one extra operand (the previous iterate block ``x``), and the
    (16, m) padded output carries 11 meaningful rows instead of 9 — the
    guarded solve still issues exactly ONE reduction per iteration.
    """
    n, m = s.shape
    lane_rows = -(-n // LANES)
    rows = -(-lane_rows // block_rows) * block_rows
    padded = rows * LANES

    def prep(v):
        # (n, m) -> (m, rows, LANES): column-major tiles, rows on lanes
        return jnp.pad(v.T, ((0, 0), (0, padded - n))).reshape(
            m, rows, LANES)

    args = [prep(v) for v in (s, y, r, t, rs, x)]
    vec_spec = pl.BlockSpec((1, block_rows, LANES), lambda j, i: (j, i, 0))
    out = pl.pallas_call(
        _health_batched_kernel,
        grid=(m, rows // block_rows),
        in_specs=[vec_spec] * 6,
        out_specs=pl.BlockSpec((OUT_PAD, 1), lambda j, i: (0, j)),
        out_shape=jax.ShapeDtypeStruct(
            (OUT_PAD, m), jnp.promote_types(s.dtype, jnp.float32)),
        interpret=interpret,
    )(*args)
    return out[:HEALTH_ROWS, :]
