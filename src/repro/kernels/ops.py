"""Jitted public wrappers for the Pallas kernels.

Each op dispatches to the hand-tiled Pallas kernel on TPU and to
``interpret=True`` (Python emulation of the same kernel body) elsewhere, so
the call sites are backend-agnostic.  ``repro.kernels.ref`` holds the
pure-jnp oracles the kernels are validated against.

These ops are the backing store of the ``"pallas"`` compute substrate
(:mod:`repro.core.substrate`): the solver hot loop calls ``fused_dots`` /
``fused_axpy`` / ``spmv_ell`` through the substrate object rather than
inlining jnp, so the same iteration body runs against either the reference
jnp path or these kernels.  ``fused_dots``, ``fused_axpy`` and
``spmv_ell`` all accept both single-RHS ``(n,)`` vectors and multi-RHS
``(n, m)`` blocks: the block variants stream ``(n, m)`` tiles with
per-column coefficients (``fused_axpy`` additionally applies the
per-column convergence mask in-kernel) and amortize the matrix/index
loads of the SpMV over all m columns.  In every case the dot partials are
reduced by the solver's single ``psum``, which is what keeps the
synchronization count at one regardless of m.

``block_jacobi_apply`` backs the block-Jacobi preconditioner of
:mod:`repro.precond` the same way: (n,) and (n, m) applies through the
batched block kernel, with the shared-block (nb == 1) case
short-circuited to one dense matmul.
"""
from __future__ import annotations

import functools
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from . import ref
from .flash_attention import flash_attention_pallas
from .fused_axpy import fused_axpy_batched_pallas, fused_axpy_pallas
from .fused_dots import (fused_dots_batched_pallas,
                         fused_dots_health_batched_pallas,
                         fused_dots_health_pallas, fused_dots_pallas)
from .precond_apply import (block_jacobi_apply_batched_pallas,
                            block_jacobi_apply_pallas)
from .spmv_ell import spmv_ell_batched_pallas, spmv_ell_pallas


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def fused_dots(s, y, r, t, rs) -> jax.Array:
    """9 fused inner products (local partials; reduce with one psum).

    1-D ``(n,)`` inputs -> ``(9,)``; 2-D ``(n, m)`` multi-RHS blocks ->
    ``(9, m)`` (one per-column dot block, still one reduction).
    """
    if s.ndim == 2:
        return fused_dots_batched_pallas(s, y, r, t, rs,
                                         interpret=_interpret())
    return fused_dots_pallas(s, y, r, t, rs, interpret=_interpret())


def fused_dots_health(s, y, r, t, rs, x) -> jax.Array:
    """Guarded fused dots: the 9 solver dots plus 2 in-reduction health
    rows (``x.x`` and a NaN/Inf probe) — see ``ref.fused_dots_health``.

    1-D ``(n,)`` inputs -> ``(11,)``; 2-D ``(n, m)`` blocks -> ``(11, m)``.
    Same single-pass/single-reduction contract as :func:`fused_dots`.
    """
    if s.ndim == 2:
        return fused_dots_health_batched_pallas(s, y, r, t, rs, x,
                                                interpret=_interpret())
    return fused_dots_health_pallas(s, y, r, t, rs, x,
                                    interpret=_interpret())


def spmv_ell(op, x) -> jax.Array:
    """Banded ELL SpMV via the Pallas kernel; falls back to the jnp path
    when the band assumption does not hold.  ``x`` may be an ``(n, m)``
    multi-RHS block — the block kernel reads the matrix tiles once for all
    m columns."""
    from repro.core.linear_operator import ELLOperator
    assert isinstance(op, ELLOperator)
    if not ell_is_banded(op):
        return ref.spmv_ell(op.values, op.cols, x)
    if x.ndim == 2:
        return spmv_ell_batched_pallas(op.values, op.cols, x,
                                       interpret=_interpret())
    return spmv_ell_pallas(op.values, op.cols, x, interpret=_interpret())


@functools.lru_cache(maxsize=64)
def _banded_cache(key):  # pragma: no cover - trivial
    return None


def ell_is_banded(op, block_rows: int = 512) -> bool:
    rows = np.arange(op.n)[:, None]
    cols = np.asarray(op.cols)
    vals = np.asarray(op.values)
    band = np.abs(np.where(vals != 0, cols - rows, 0)).max()
    return bool(band < block_rows)


def block_jacobi_apply(inv_blocks, x) -> jax.Array:
    """Block-Jacobi M^{-1} apply via the Pallas batched block kernel.

    ``inv_blocks``: (nb, bs, bs) pre-inverted diagonal blocks; ``x`` an
    ``(n,)`` vector or ``(n, m)`` multi-RHS block.  The shared-block case
    (nb == 1, every row block identical — constant-coefficient stencils)
    is a single dense matmul that XLA already maps onto the MXU, so it
    short-circuits to the reference path rather than the kernel.
    """
    nb, bs, _ = inv_blocks.shape
    assert x.shape[0] % bs == 0, (x.shape, bs)
    if nb == 1:
        return ref.block_jacobi_apply(inv_blocks, x)
    assert x.shape[0] == nb * bs, (x.shape, inv_blocks.shape)
    if x.ndim == 2:
        return block_jacobi_apply_batched_pallas(inv_blocks, x,
                                                 interpret=_interpret())
    return block_jacobi_apply_pallas(inv_blocks, x, interpret=_interpret())


def fused_axpy(vecs: Dict[str, jax.Array], scalars,
               mask=None) -> Dict[str, jax.Array]:
    """p-BiCGSafe fused vector-update phase (Alg. 3.1 lines 23-32).

    ``(n,)`` vectors dispatch to the single-RHS kernel; ``(n, m)`` blocks
    to the batched kernel with per-column ``(m,)`` coefficients and the
    optional ``(m,)`` convergence ``mask`` applied in-kernel."""
    if vecs["r"].ndim == 2:
        return fused_axpy_batched_pallas(vecs, scalars, mask,
                                         interpret=_interpret())
    assert mask is None, "mask is a multi-RHS (column) concept"
    return fused_axpy_pallas(vecs, scalars, interpret=_interpret())


def flash_attention(qg, k, v, *, scale: float, causal: bool = True
                    ) -> jax.Array:
    """Causal flash attention.  qg: (B,S,K,G,hd), k/v: (B,S,K,hd) (the
    model stack's layout) -> (B,S,K*G*hd)."""
    B, S, K, G, hd = qg.shape
    q = jnp.moveaxis(qg.reshape(B, S, K * G, hd), 1, 2)   # (B,H,S,hd)
    kk = jnp.moveaxis(k, 1, 2)                            # (B,K,S,hd)
    vv = jnp.moveaxis(v, 1, 2)
    o = flash_attention_pallas(q, kk, vv, scale=scale, causal=causal,
                               interpret=_interpret())
    return jnp.moveaxis(o, 2, 1).reshape(B, S, K * G * hd)
