"""Pallas TPU kernels for the perf-critical hot spots.

<name>.py      pl.pallas_call + BlockSpec VMEM tiling
ops.py         jit'd backend-dispatching wrappers (public API)
ref.py         pure-jnp oracles (tests assert allclose against these)

Kernels (each solver kernel has a multi-RHS block variant that streams
(n, m) column tiles — see the *_batched entry points in each module):
  fused_dots       the paper's single fused inner-product phase (9 dots;
                   batched: one (9, m) partial block per pass)
  spmv_ell         banded ELLPACK SpMV (TPU-native layout of the paper's
                   CSR SpMV; batched: matrix/index tiles read once for
                   all m columns)
  fused_axpy       p-BiCGSafe's 10 vector updates in one HBM pass
                   (batched: per-column coefficients + the convergence
                   mask applied in-kernel)
  precond_apply    block-Jacobi M^{-1}: batched pre-inverted (bs, bs)
                   block matmuls (backs repro.precond's block_jacobi;
                   batched: block tiles read once for all m columns)
  flash_attention  causal GQA flash attention (model-stack hot spot)
"""
from . import ops, ref

__all__ = ["ops", "ref"]
