"""Pallas TPU kernels for the perf-critical hot spots.

<name>.py      pl.pallas_call + BlockSpec VMEM tiling
ops.py         jit'd backend-dispatching wrappers (public API)
ref.py         pure-jnp oracles (tests assert allclose against these)

Kernels:
  fused_dots       the paper's single fused inner-product phase (9 dots)
  spmv_ell         banded ELLPACK SpMV (TPU-native layout of the paper's
                   CSR SpMV)
  fused_axpy       p-BiCGSafe's 10 vector updates in one HBM pass
  flash_attention  causal GQA flash attention (model-stack hot spot)
"""
from . import ops, ref

__all__ = ["ops", "ref"]
