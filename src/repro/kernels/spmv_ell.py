"""Pallas TPU kernel: banded ELLPACK SpMV.

TPU adaptation of the paper's PETSc CSR SpMV (DESIGN.md §3): ELL stores a
fixed ``k`` nonzeros per row as dense (n, k) tiles — a regular layout that
maps onto VMEM blocks, unlike CSR's ragged rows.  The kernel assumes the
matrix is *banded* (|col - row| < block_rows, true for the stencil/banded
generators after ordering): for row block i only the x-blocks i-1, i, i+1
are needed, so x is streamed through VMEM three blocks at a time (this is
also exactly the halo pattern of the distributed SpMV — one kernel serves
both).

Per row r: y[r] = sum_j values[r, j] * x[cols[r, j]].

``spmv_ell_batched_pallas`` is the block (multi-RHS) variant: ``x`` is an
``(n, m)`` column block and each grid step streams the three neighbouring
``(block_rows, m)`` x-tiles instead of ``(block_rows,)`` slices.  The row
tile layout, band assumption, and halo pattern are identical to the 1-D
kernel — the point of the block kernel is that the ``values``/``cols``
tiles (and the gather addressing they imply) are loaded ONCE per row block
and reused for all m right-hand sides, where m vmapped 1-D SpMVs would
re-read the matrix m times (Krasnopolsky's amortization argument applied
to the index stream).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(values_ref, local_ref, xprev_ref, xself_ref, xnext_ref, y_ref):
    # accumulate in f64 for f64 inputs (solver fidelity), else f32
    acc = jnp.promote_types(y_ref.dtype, jnp.float32)
    vals = values_ref[...].astype(acc)                    # (bn, k)
    local = local_ref[...]                                # (bn, k) in [0,3bn)
    x_cat = jnp.concatenate([xprev_ref[...], xself_ref[...],
                             xnext_ref[...]]).astype(acc)  # (3bn,)
    gathered = jnp.take(x_cat, local, axis=0)             # (bn, k)
    y_ref[...] = jnp.sum(vals * gathered, axis=1).astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def spmv_ell_pallas(values, cols, x, *, block_rows: int = 512,
                    interpret: bool = False) -> jax.Array:
    """Banded ELL SpMV.  values/cols: (n, k); x: (n,).

    Requires max|cols[r,:] - r| < block_rows (checked by ops.spmv_ell).
    """
    n, k = values.shape
    bn = block_rows
    pad = (-n) % bn
    if pad:
        values = jnp.pad(values, ((0, pad), (0, 0)))
        # padded rows: point at column 0 with value 0
        cols = jnp.pad(cols, ((0, pad), (0, 0)))
        x = jnp.pad(x, (0, pad))
    np_ = n + pad
    nblk = np_ // bn

    # local index of each referenced column within [x_prev | x_self | x_next]
    # (block 0's duplicated x_prev and the last block's duplicated x_next
    # are never addressed: the band bound keeps local in range)
    row_block = jnp.arange(np_, dtype=jnp.int32)[:, None] // bn
    base = (row_block - 1) * bn
    local = jnp.clip((cols - base).astype(jnp.int32), 0, 3 * bn - 1)

    y = pl.pallas_call(
        _kernel,
        grid=(nblk,),
        in_specs=[
            pl.BlockSpec((bn, k), lambda i: (i, 0)),       # values
            pl.BlockSpec((bn, k), lambda i: (i, 0)),       # local idx
            pl.BlockSpec((bn,), lambda i: (jnp.maximum(i - 1, 0),)),
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn,), lambda i: (jnp.minimum(i + 1, nblk - 1),)),
        ],
        out_specs=pl.BlockSpec((bn,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((np_,), x.dtype),
        interpret=interpret,
    )(values, local, x, x, x)
    return y[:n]


def _batched_kernel(values_ref, local_ref, xprev_ref, xself_ref, xnext_ref,
                    y_ref):
    acc = jnp.promote_types(y_ref.dtype, jnp.float32)
    vals = values_ref[...].astype(acc)                    # (bn, k)
    local = local_ref[...]                                # (bn, k) in [0,3bn)
    x_cat = jnp.concatenate([xprev_ref[...], xself_ref[...],
                             xnext_ref[...]]).astype(acc)  # (3bn, m)
    gathered = jnp.take(x_cat, local, axis=0)             # (bn, k, m)
    y_ref[...] = jnp.sum(vals[:, :, None] * gathered,
                         axis=1).astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def spmv_ell_batched_pallas(values, cols, x, *, block_rows: int = 512,
                            interpret: bool = False) -> jax.Array:
    """Block banded ELL SpMV.  values/cols: (n, k); x: (n, m) -> (n, m).

    Same band requirement as :func:`spmv_ell_pallas`; the values/cols/index
    tiles are read once per row block and serve all m columns.
    """
    n, k = values.shape
    m = x.shape[1]
    bn = block_rows
    pad = (-n) % bn
    if pad:
        values = jnp.pad(values, ((0, pad), (0, 0)))
        cols = jnp.pad(cols, ((0, pad), (0, 0)))
        x = jnp.pad(x, ((0, pad), (0, 0)))
    np_ = n + pad
    nblk = np_ // bn

    row_block = jnp.arange(np_, dtype=jnp.int32)[:, None] // bn
    base = (row_block - 1) * bn
    local = jnp.clip((cols - base).astype(jnp.int32), 0, 3 * bn - 1)

    x_spec_prev = pl.BlockSpec((bn, m), lambda i: (jnp.maximum(i - 1, 0), 0))
    x_spec_self = pl.BlockSpec((bn, m), lambda i: (i, 0))
    x_spec_next = pl.BlockSpec((bn, m),
                               lambda i: (jnp.minimum(i + 1, nblk - 1), 0))
    y = pl.pallas_call(
        _batched_kernel,
        grid=(nblk,),
        in_specs=[
            pl.BlockSpec((bn, k), lambda i: (i, 0)),       # values
            pl.BlockSpec((bn, k), lambda i: (i, 0)),       # local idx
            x_spec_prev, x_spec_self, x_spec_next,
        ],
        out_specs=pl.BlockSpec((bn, m), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((np_, m), x.dtype),
        interpret=interpret,
    )(values, local, x, x, x)
    return y[:n]
