"""Pallas TPU kernel: banded ELLPACK SpMV.

TPU adaptation of the paper's PETSc CSR SpMV (DESIGN.md §3): ELL stores a
fixed ``k`` nonzeros per row as dense (n, k) tiles — a regular layout that
maps onto VMEM blocks, unlike CSR's ragged rows.  The kernel assumes the
matrix is *banded* (|col - row| < block_rows, true for the stencil/banded
generators after ordering): for row block i only the x-blocks i-1, i, i+1
are needed, so x is streamed through VMEM three blocks at a time (this is
also exactly the halo pattern of the distributed SpMV — one kernel serves
both).

Per row r: y[r] = sum_j values[r, j] * x[cols[r, j]].
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(values_ref, local_ref, xprev_ref, xself_ref, xnext_ref, y_ref):
    # accumulate in f64 for f64 inputs (solver fidelity), else f32
    acc = jnp.promote_types(y_ref.dtype, jnp.float32)
    vals = values_ref[...].astype(acc)                    # (bn, k)
    local = local_ref[...]                                # (bn, k) in [0,3bn)
    x_cat = jnp.concatenate([xprev_ref[...], xself_ref[...],
                             xnext_ref[...]]).astype(acc)  # (3bn,)
    gathered = jnp.take(x_cat, local, axis=0)             # (bn, k)
    y_ref[...] = jnp.sum(vals * gathered, axis=1).astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def spmv_ell_pallas(values, cols, x, *, block_rows: int = 512,
                    interpret: bool = False) -> jax.Array:
    """Banded ELL SpMV.  values/cols: (n, k); x: (n,).

    Requires max|cols[r,:] - r| < block_rows (checked by ops.spmv_ell).
    """
    n, k = values.shape
    bn = block_rows
    pad = (-n) % bn
    if pad:
        values = jnp.pad(values, ((0, pad), (0, 0)))
        # padded rows: point at column 0 with value 0
        cols = jnp.pad(cols, ((0, pad), (0, 0)))
        x = jnp.pad(x, (0, pad))
    np_ = n + pad
    nblk = np_ // bn

    # local index of each referenced column within [x_prev | x_self | x_next]
    # (block 0's duplicated x_prev and the last block's duplicated x_next
    # are never addressed: the band bound keeps local in range)
    row_block = jnp.arange(np_, dtype=jnp.int32)[:, None] // bn
    base = (row_block - 1) * bn
    local = jnp.clip((cols - base).astype(jnp.int32), 0, 3 * bn - 1)

    y = pl.pallas_call(
        _kernel,
        grid=(nblk,),
        in_specs=[
            pl.BlockSpec((bn, k), lambda i: (i, 0)),       # values
            pl.BlockSpec((bn, k), lambda i: (i, 0)),       # local idx
            pl.BlockSpec((bn,), lambda i: (jnp.maximum(i - 1, 0),)),
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn,), lambda i: (jnp.minimum(i + 1, nblk - 1),)),
        ],
        out_specs=pl.BlockSpec((bn,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((np_,), x.dtype),
        interpret=interpret,
    )(values, local, x, x, x)
    return y[:n]
