"""Pallas TPU kernel: p-BiCGSafe's fused vector-update phase.

Alg. 3.1 lines 23-32 are 10 vector updates (26 alpha*x + 22 x+y flops per
element, paper Table 3.1).  Issued as separate AXPYs they read/write each
vector several times; this kernel performs the whole phase in a single HBM
pass: 12 tile reads + 10 tile writes per block, all arithmetic in VMEM.
That matters because the phase is pure memory-bound (arith intensity
~0.6 flop/byte) — fusing it is worth ~2.5x on the solver's vector-update
time at the 819 GB/s HBM roofline.

``fused_axpy_batched_pallas`` is the multi-RHS generalization (the
Krasnopolsky regime): the 12 inputs are ``(n, m)`` column blocks, the
coefficients are per-column ``(m,)`` vectors, and the whole phase is still
ONE streaming pass — each ``(block_rows, 128)`` tile of every column is
read once and all 10 updates of that tile are computed in VMEM, so the
memory traffic of the phase is amortized over m right-hand sides.  The
per-column convergence mask is applied *in-kernel*: frozen (converged /
broken-down) columns write back their input tiles unchanged, which is
what lets ``solve_batched`` freeze finished columns without a second
masking pass over the ``(n, m)`` state.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
IN_ORDER = ("r", "p", "u", "t", "y", "z", "s", "l", "g", "w", "x", "As")
OUT_ORDER = ("p", "o", "u", "q", "w", "t", "z", "y", "x", "r")


def _kernel(scal_ref, r_ref, p_ref, u_ref, t_ref, y_ref, z_ref, s_ref,
            l_ref, g_ref, w_ref, x_ref, As_ref,
            p_o, o_o, u_o, q_o, w_o, t_o, z_o, y_o, x_o, r_o):
    f32 = jnp.promote_types(r_ref.dtype, jnp.float32)
    al = scal_ref[0, 0].astype(f32)
    be = scal_ref[0, 1].astype(f32)
    ze = scal_ref[0, 2].astype(f32)
    et = scal_ref[0, 3].astype(f32)
    r = r_ref[...].astype(f32)
    p = p_ref[...].astype(f32)
    u = u_ref[...].astype(f32)
    t = t_ref[...].astype(f32)
    y = y_ref[...].astype(f32)
    z = z_ref[...].astype(f32)
    s = s_ref[...].astype(f32)
    l = l_ref[...].astype(f32)
    g = g_ref[...].astype(f32)
    w = w_ref[...].astype(f32)
    x = x_ref[...].astype(f32)
    As = As_ref[...].astype(f32)

    p2 = r + be * (p - u)
    o = s + be * t
    u2 = ze * o + et * (y + be * u)
    q = As + be * l
    w2 = ze * q + et * (g + be * w)
    t2 = o - w2
    z2 = ze * r + et * z - al * u2
    y2 = ze * s + et * y - al * w2
    x2 = x + al * p2 + z2
    r2 = r - al * o - y2

    for ref, val in zip((p_o, o_o, u_o, q_o, w_o, t_o, z_o, y_o, x_o, r_o),
                        (p2, o, u2, q, w2, t2, z2, y2, x2, r2)):
        ref[...] = val.astype(ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def fused_axpy_pallas(vecs: dict, scalars, *, block_rows: int = 256,
                      interpret: bool = False) -> dict:
    """vecs: dict of 12 equal-length vectors (IN_ORDER); scalars: (4,).
    Returns dict of the 10 updated vectors (OUT_ORDER)."""
    n = vecs["r"].shape[0]
    dtype = vecs["r"].dtype
    lane_rows = -(-n // LANES)
    rows = -(-lane_rows // block_rows) * block_rows
    padded = rows * LANES

    def prep(v):
        return jnp.pad(v, (0, padded - n)).reshape(rows, LANES)

    args = [prep(vecs[k]) for k in IN_ORDER]
    sdt = jnp.promote_types(dtype, jnp.float32)
    scal = jnp.zeros((1, LANES), sdt).at[0, :4].set(
        jnp.asarray(scalars, sdt))

    vec_spec = pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))
    outs = pl.pallas_call(
        _kernel,
        grid=(rows // block_rows,),
        in_specs=[pl.BlockSpec((1, LANES), lambda i: (0, 0))]
        + [vec_spec] * 12,
        out_specs=[vec_spec] * 10,
        out_shape=[jax.ShapeDtypeStruct((rows, LANES), dtype)] * 10,
        interpret=interpret,
    )(scal, *args)
    return {k: o.reshape(-1)[:n] for k, o in zip(OUT_ORDER, outs)}


# outputs with an input of the same name: their old tile is what a frozen
# column must keep (o and q have no state counterpart — their values for
# frozen columns are discarded by the solver's recurrence-tail masking)
MASKED_OUT = ("p", "u", "w", "t", "z", "y", "x", "r")


def _batched_kernel(scal_ref, r_ref, p_ref, u_ref, t_ref, y_ref, z_ref,
                    s_ref, l_ref, g_ref, w_ref, x_ref, As_ref,
                    p_o, o_o, u_o, q_o, w_o, t_o, z_o, y_o, x_o, r_o):
    f32 = jnp.promote_types(r_ref.dtype, jnp.float32)
    al = scal_ref[0, 0].astype(f32)        # this column's coefficients
    be = scal_ref[0, 1].astype(f32)
    ze = scal_ref[0, 2].astype(f32)
    et = scal_ref[0, 3].astype(f32)
    mk = scal_ref[0, 4] != 0.0             # convergence mask (1 = advance)
    r = r_ref[...].astype(f32)             # (1, block_rows, LANES) tiles
    p = p_ref[...].astype(f32)
    u = u_ref[...].astype(f32)
    t = t_ref[...].astype(f32)
    y = y_ref[...].astype(f32)
    z = z_ref[...].astype(f32)
    s = s_ref[...].astype(f32)
    l = l_ref[...].astype(f32)
    g = g_ref[...].astype(f32)
    w = w_ref[...].astype(f32)
    x = x_ref[...].astype(f32)
    As = As_ref[...].astype(f32)

    p2 = r + be * (p - u)
    o = s + be * t
    u2 = ze * o + et * (y + be * u)
    q = As + be * l
    w2 = ze * q + et * (g + be * w)
    t2 = o - w2
    z2 = ze * r + et * z - al * u2
    y2 = ze * s + et * y - al * w2
    x2 = x + al * p2 + z2
    r2 = r - al * o - y2

    old = {"p": p, "u": u, "w": w, "t": t, "z": z, "y": y, "x": x, "r": r}
    new = {"p": p2, "o": o, "u": u2, "q": q, "w": w2, "t": t2,
           "z": z2, "y": y2, "x": x2, "r": r2}
    refs = dict(zip(("p", "o", "u", "q", "w", "t", "z", "y", "x", "r"),
                    (p_o, o_o, u_o, q_o, w_o, t_o, z_o, y_o, x_o, r_o)))
    for k, ref in refs.items():
        val = jnp.where(mk, new[k], old[k]) if k in old else new[k]
        ref[...] = val.astype(ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def fused_axpy_batched_pallas(vecs: dict, scalars, mask=None, *,
                              block_rows: int = 256,
                              interpret: bool = False) -> dict:
    """Multi-RHS fused update phase: (n, m) blocks, (m,) coefficients.

    ``mask``: optional (m,) bool; columns with ``mask == False`` are frozen
    — every state output (:data:`MASKED_OUT`) writes its input back
    unchanged.  ``o`` and ``q`` are always the fresh values (they have no
    old state; the solver masks their consumers).  Returns the 10 updated
    (n, m) blocks (OUT_ORDER).

    Layout mirrors ``fused_dots_batched_pallas``: each column is tiled as
    (rows, 128) with rows on the lane axis, the grid walks (column,
    row-block), so per-column traffic matches the single-RHS kernel and
    small m does not force lane padding (an (n, m) minor-dim layout would
    multiply HBM reads by 128/m).  The (n, m) <-> (m, rows, 128)
    relayout at the call boundary is not free — XLA fuses it with the pad
    where it can, but a layout-conscious caller that keeps solver state
    column-major would avoid it entirely (noted as a perf follow-up; the
    kernel body itself is one pass either way).
    """
    n, m = vecs["r"].shape
    dtype = vecs["r"].dtype
    lane_rows = -(-n // LANES)
    rows = -(-lane_rows // block_rows) * block_rows
    padded = rows * LANES

    def prep(v):
        # (n, m) -> (m, rows, LANES): column-major tiles, rows on lanes
        return jnp.pad(v.T, ((0, 0), (0, padded - n))).reshape(
            m, rows, LANES)

    args = [prep(vecs[k]) for k in IN_ORDER]
    sdt = jnp.promote_types(dtype, jnp.float32)
    scal = jnp.zeros((m, LANES), sdt)
    for j, coef in enumerate(scalars):
        scal = scal.at[:, j].set(jnp.asarray(coef, sdt))
    mk = (jnp.ones((m,), sdt) if mask is None
          else jnp.asarray(mask).astype(sdt))
    scal = scal.at[:, 4].set(mk)

    vec_spec = pl.BlockSpec((1, block_rows, LANES), lambda j, i: (j, i, 0))
    outs = pl.pallas_call(
        _batched_kernel,
        grid=(m, rows // block_rows),
        in_specs=[pl.BlockSpec((1, LANES), lambda j, i: (j, 0))]
        + [vec_spec] * 12,
        out_specs=[vec_spec] * 10,
        out_shape=[jax.ShapeDtypeStruct((m, rows, LANES), dtype)] * 10,
        interpret=interpret,
    )(scal, *args)
    return {k: o.reshape(m, -1)[:, :n].T
            for k, o in zip(OUT_ORDER, outs)}
