"""Pallas TPU kernel: causal flash attention (forward), GQA-aware.

Online-softmax over KV blocks with the classic (m, l, acc) running state
in VMEM scratch; the grid's innermost dim walks KV blocks sequentially so
the (S x S) score matrix never exists.  Blocks are (bq x hd) / (bk x hd)
MXU-aligned tiles.  Causal skipping: KV blocks strictly above the diagonal
are not computed.

Used by the model stack when ``cfg.use_flash_kernel`` (TPU target);
validated against ref.flash_attention in interpret mode on CPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, bq: int, bk: int, nk: int, causal: bool):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = iq * bq
    k_start = ik * bk
    # causal: process only blocks intersecting the lower triangle
    needed = (not causal) or (k_start <= q_start + bq - 1)

    @pl.when(needed)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)               # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)               # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)               # (bk, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            rows = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            cols = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(rows >= cols, s, NEG_INF)
        m_prev = m_scr[...]                               # (bq, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "causal", "block_q",
                                             "block_k", "interpret"))
def flash_attention_pallas(q, k, v, *, scale: float, causal: bool = True,
                           block_q: int = 256, block_k: int = 256,
                           interpret: bool = False) -> jax.Array:
    """q: (B,H,S,hd)  k/v: (B,K,S,hd) -> (B,H,S,hd).  GQA via H = K*G."""
    B, H, S, hd = q.shape
    K = k.shape[1]
    G = H // K
    bq = min(block_q, S)
    bk = min(block_k, S)
    assert S % bq == 0 and S % bk == 0
    nq, nk = S // bq, S // bk

    kernel = functools.partial(_kernel, scale=scale, bq=bq, bk=bk, nk=nk,
                               causal=causal)
    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, iq, ik: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, iq, ik: (b, h // G, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
