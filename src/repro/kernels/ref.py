"""Pure-jnp oracles for every Pallas kernel (the correctness references)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def fused_dots(s, y, r, t, rs) -> jax.Array:
    """The 9 inner products of ssBiCGSafe2/p-BiCGSafe's single reduction
    phase: [a,b,c,d,e,f,g,h,rr] (paper Alg. 3.1 lines 7-8)."""
    f32 = jnp.promote_types(s.dtype, jnp.float32)
    return jnp.stack([
        jnp.sum(s * s, dtype=f32), jnp.sum(y * y, dtype=f32),
        jnp.sum(s * y, dtype=f32), jnp.sum(s * r, dtype=f32),
        jnp.sum(y * r, dtype=f32), jnp.sum(rs * r, dtype=f32),
        jnp.sum(rs * s, dtype=f32), jnp.sum(rs * t, dtype=f32),
        jnp.sum(r * r, dtype=f32)])


def fused_dots_batched(s, y, r, t, rs) -> jax.Array:
    """Multi-RHS fused dots: (n, m) inputs -> (9, m) per-column dots."""
    f32 = jnp.promote_types(s.dtype, jnp.float32)
    return jnp.stack([
        jnp.sum(s * s, axis=0, dtype=f32), jnp.sum(y * y, axis=0, dtype=f32),
        jnp.sum(s * y, axis=0, dtype=f32), jnp.sum(s * r, axis=0, dtype=f32),
        jnp.sum(y * r, axis=0, dtype=f32), jnp.sum(rs * r, axis=0, dtype=f32),
        jnp.sum(rs * s, axis=0, dtype=f32), jnp.sum(rs * t, axis=0, dtype=f32),
        jnp.sum(r * r, axis=0, dtype=f32)])


def fused_dots_health(s, y, r, t, rs, x) -> jax.Array:
    """Guarded fused dots: the 9 rows of :func:`fused_dots` plus two
    health rows, all in the SAME single reduction phase (11 rows total):

      row  9: ``x . x``        — solution-norm estimate feeding the
              recurrence-vs-true residual drift bound (Cools criterion);
      row 10: ``sum(s+y+t+rs+x)`` — finiteness probe: NaN/Inf anywhere in
              the operands poisons the sum (``r``'s finiteness is already
              visible through row 8, ``r . r``).

    ``x`` is the PREVIOUS iterate — a loop-carried value, so reading it
    here adds no dependency edge to the in-flight matvec ``A s``.
    """
    f32 = jnp.promote_types(s.dtype, jnp.float32)
    return jnp.concatenate([
        fused_dots(s, y, r, t, rs),
        jnp.stack([jnp.sum(x * x, dtype=f32),
                   jnp.sum(s + y + t + rs + x, dtype=f32)])])


def fused_dots_health_batched(s, y, r, t, rs, x) -> jax.Array:
    """Multi-RHS guarded dots: (n, m) inputs -> (11, m) per-column rows
    (see :func:`fused_dots_health` for the row layout)."""
    f32 = jnp.promote_types(s.dtype, jnp.float32)
    return jnp.concatenate([
        fused_dots_batched(s, y, r, t, rs),
        jnp.stack([jnp.sum(x * x, axis=0, dtype=f32),
                   jnp.sum(s + y + t + rs + x, axis=0, dtype=f32)])])


def spmv_ell(values, cols, x) -> jax.Array:
    """ELLPACK SpMV: y[i] = sum_j values[i,j] * x[cols[i,j]].

    ``x`` may be an (n, m) multi-RHS block: each column is multiplied
    independently (the oracle of the block-ELL kernel).
    """
    if x.ndim == 2:
        return jnp.einsum("rk,rkm->rm", values, x[cols])
    return jnp.sum(values * x[cols], axis=1)


def fused_axpy(vecs, scalars, mask=None):
    """The fused vector-update phase of p-BiCGSafe (Alg. 3.1 lines 23-32).

    vecs: dict with r,p,u,t,y,z,s,l,g,w,x,As   scalars: (alpha,beta,zeta,eta)
    Returns dict with p,o,u,q,w,t,z,y,x,r (primed values).

    Column-batched: (n, m) blocks with (m,) per-column scalars broadcast.
    ``mask`` (optional (m,) bool, multi-RHS only): frozen columns
    (mask == False) keep their INPUT values for every output that has a
    same-named input (p,u,w,t,z,y,x,r); ``o``/``q`` are always fresh (no
    old state exists — the solver masks their consumers instead).
    """
    al, be, ze, et = scalars
    r, p, u, t, y, z = (vecs[k] for k in "rputyz")
    s, l, g, w, x, As = (vecs[k] for k in ("s", "l", "g", "w", "x", "As"))
    p2 = r + be * (p - u)
    o = s + be * t
    u2 = ze * o + et * (y + be * u)
    q = As + be * l
    w2 = ze * q + et * (g + be * w)
    t2 = o - w2
    z2 = ze * r + et * z - al * u2
    y2 = ze * s + et * y - al * w2
    x2 = x + al * p2 + z2
    r2 = r - al * o - y2
    out = {"p": p2, "o": o, "u": u2, "q": q, "w": w2, "t": t2,
           "z": z2, "y": y2, "x": x2, "r": r2}
    if mask is not None:
        from .fused_axpy import MASKED_OUT
        mk = mask[None, :] if out["r"].ndim == 2 else mask
        for k in MASKED_OUT:
            out[k] = jnp.where(mk, out[k], vecs[k])
    return out


def block_jacobi_apply(inv_blocks, x) -> jax.Array:
    """Block-Jacobi apply: y_g = inv_blocks[g] @ x_g per row block.

    ``inv_blocks`` is (nb, bs, bs), or (1, bs, bs) for one block shared
    by every row block (constant-coefficient stencils).  ``x`` may be an
    (n,) vector or an (n, m) multi-RHS block; n == (n // bs) * bs.
    """
    nb, bs, _ = inv_blocks.shape
    n = x.shape[0]
    g = n // bs
    if x.ndim == 2:
        xb = x.reshape(g, bs, x.shape[1])
        if nb == 1:
            y = jnp.einsum("ij,gjm->gim", inv_blocks[0], xb)
        else:
            y = jnp.einsum("gij,gjm->gim", inv_blocks, xb)
        return y.reshape(x.shape)
    xb = x.reshape(g, bs)
    if nb == 1:
        y = xb @ inv_blocks[0].T
    else:
        y = jnp.einsum("gij,gj->gi", inv_blocks, xb)
    return y.reshape(n)


def flash_attention(q, k, v, scale: float, causal: bool = True) -> jax.Array:
    """q: (B,H,S,hd)  k/v: (B,K,S,hd), GQA with G=H//K."""
    B, H, S, hd = q.shape
    K = k.shape[1]
    G = H // K
    qg = q.reshape(B, K, G, S, hd)
    logits = jnp.einsum("bkgsh,bkth->bkgst", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if causal:
        idx = jnp.arange(S)
        mask = idx[:, None] >= idx[None, :]
        logits = jnp.where(mask[None, None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bkgst,bkth->bkgsh", p, v.astype(jnp.float32))
    return o.reshape(B, H, S, hd).astype(q.dtype)
