"""Pallas TPU kernel: batched block-Jacobi apply.

y_g = B_g @ x_g for every row block g, with B the pre-inverted (bs, bs)
diagonal blocks of the block-Jacobi preconditioner
(:mod:`repro.precond.block_jacobi`).  The apply is a streaming batched
small-matmul: each grid step loads a ``(group, bs, bs)`` tile of inverted
blocks plus the matching ``(group, bs)`` x-tile into VMEM and emits the
``(group, bs)`` product — one HBM pass over the blocks and the vector,
no gather/scatter (contiguous row blocks), no communication.

``block_jacobi_apply_batched_pallas`` is the multi-RHS variant: the
x-tile is ``(group, bs, m)`` and the per-block matmul serves all m
right-hand-side columns from ONE load of the block tile — the same
amortize-the-matrix-stream argument as the block-ELL SpMV kernel.

Layout note: ``bs`` sits on the lane axis, so block sizes below 128 pad
lanes (correct everywhere; bandwidth-optimal for bs >= 128 — use z-line
blocks of a production-sized nz, or fold the group axis, if that matters).
The shared-block case (``inv_blocks`` of shape (1, bs, bs), constant-
coefficient stencils) is NOT routed here: one dense matmul already maps
onto the MXU optimally (see ops.block_jacobi_apply).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _group(nb: int, bs: int) -> int:
    """Blocks per grid step: aim for ~64k elements of block tile."""
    g = max(1, 65536 // max(bs * bs, 1))
    return min(g, nb)


def _kernel(blocks_ref, x_ref, y_ref):
    acc = jnp.promote_types(y_ref.dtype, jnp.float32)
    blk = blocks_ref[...].astype(acc)          # (g, bs, bs)
    x = x_ref[...].astype(acc)                 # (g, bs)
    y = jnp.einsum("gij,gj->gi", blk, x)
    y_ref[...] = y.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def block_jacobi_apply_pallas(inv_blocks, x, *, interpret: bool = False
                              ) -> jax.Array:
    """inv_blocks: (nb, bs, bs); x: (n,) with n == nb * bs -> (n,)."""
    nb, bs, _ = inv_blocks.shape
    n = x.shape[0]
    g = _group(nb, bs)
    pad = (-nb) % g
    if pad:   # zero blocks x zero rows -> zero rows, sliced off below
        inv_blocks = jnp.pad(inv_blocks, ((0, pad), (0, 0), (0, 0)))
    xb = jnp.pad(x.reshape(nb, bs), ((0, pad), (0, 0)))
    y = pl.pallas_call(
        _kernel,
        grid=((nb + pad) // g,),
        in_specs=[
            pl.BlockSpec((g, bs, bs), lambda i: (i, 0, 0)),
            pl.BlockSpec((g, bs), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((g, bs), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb + pad, bs), x.dtype),
        interpret=interpret,
    )(inv_blocks, xb)
    return y[:nb].reshape(n)


def _batched_kernel(blocks_ref, x_ref, y_ref):
    acc = jnp.promote_types(y_ref.dtype, jnp.float32)
    blk = blocks_ref[...].astype(acc)          # (g, bs, bs)
    x = x_ref[...].astype(acc)                 # (g, bs, m)
    y = jnp.einsum("gij,gjm->gim", blk, x)     # block tile read ONCE for m
    y_ref[...] = y.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def block_jacobi_apply_batched_pallas(inv_blocks, x, *,
                                      interpret: bool = False) -> jax.Array:
    """inv_blocks: (nb, bs, bs); x: (n, m) -> (n, m)."""
    nb, bs, _ = inv_blocks.shape
    n, m = x.shape
    g = _group(nb, bs)
    pad = (-nb) % g
    if pad:
        inv_blocks = jnp.pad(inv_blocks, ((0, pad), (0, 0), (0, 0)))
    xb = jnp.pad(x.reshape(nb, bs, m), ((0, pad), (0, 0), (0, 0)))
    y = pl.pallas_call(
        _batched_kernel,
        grid=((nb + pad) // g,),
        in_specs=[
            pl.BlockSpec((g, bs, bs), lambda i: (i, 0, 0)),
            pl.BlockSpec((g, bs, m), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((g, bs, m), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((nb + pad, bs, m), x.dtype),
        interpret=interpret,
    )(inv_blocks, xb)
    return y[:nb].reshape(n, m)
