"""Fault tolerance for the training loop.

Mechanisms (each unit-tested in tests/test_fault_tolerance.py):

* **Checkpoint/restart** — atomic checkpoints every N steps (see
  checkpoint.py); on (re)start the loop restores the newest complete step
  and the stateless data pipeline replays from exactly that step.
* **Bad-step rejection** — non-finite loss or grad-norm spike (> ``nan_zap``
  x running median) skips the optimizer update for that step; ``max_bad``
  consecutive bad steps aborts to restart-from-checkpoint (round-off /
  hardware-corruption containment).
* **Failure injection** — ``FailureInjector`` raises at configured steps so
  tests can assert end-to-end recovery reproduces the uninterrupted run.
* **Straggler mitigation** — ``StepTimer`` tracks a running median step
  time; steps slower than ``straggler_factor`` x median are logged and
  counted.  On real multi-host pods this signal feeds the
  coordinator's slow-host eviction (jax.experimental
  multihost_utils); in-process we surface the hook + stats.  Synchronous
  SPMD means in-step work cannot be rebalanced, so detection + eviction +
  elastic restart IS the mitigation at this layer.

The same philosophy applied to the solver substrate itself — in-band
detection (the guarded (11, m) fused reduction), typed failure codes,
and policy-driven recovery (restart / residual replacement / substrate
degradation / method fallback) — lives in :mod:`repro.resilience`; the
solve service wires it to serving traffic (``ServiceConfig.recovery``,
:mod:`repro.service.engine`).
"""
from __future__ import annotations

import time
from collections import deque
from typing import Callable, Dict, List, Optional

import numpy as np


class FailureInjector:
    """Raises RuntimeError at the given step indices (once each)."""

    def __init__(self, fail_at: Optional[List[int]] = None):
        self.fail_at = set(fail_at or [])

    def check(self, step: int):
        if step in self.fail_at:
            self.fail_at.discard(step)
            raise RuntimeError(f"injected failure at step {step}")


class BadStepFilter:
    """Rejects non-finite/spiking steps; aborts after max_bad in a row."""

    def __init__(self, nan_zap: float = 50.0, max_bad: int = 5,
                 window: int = 32):
        self.nan_zap = nan_zap
        self.max_bad = max_bad
        self.norms: deque = deque(maxlen=window)
        self.consecutive_bad = 0
        self.rejected = 0

    def accept(self, loss: float, grad_norm: float) -> bool:
        finite = np.isfinite(loss) and np.isfinite(grad_norm)
        spike = (len(self.norms) >= 8
                 and grad_norm > self.nan_zap * np.median(self.norms))
        ok = finite and not spike
        if ok:
            self.norms.append(grad_norm)
            self.consecutive_bad = 0
        else:
            self.consecutive_bad += 1
            self.rejected += 1
            if self.consecutive_bad > self.max_bad:
                raise RuntimeError(
                    f"{self.consecutive_bad} consecutive bad steps — "
                    "aborting for restart-from-checkpoint")
        return ok


class StepTimer:
    """Running median step time + straggler detection."""

    def __init__(self, straggler_factor: float = 3.0, window: int = 64,
                 on_straggler: Optional[Callable[[int, float], None]] = None):
        self.factor = straggler_factor
        self.times: deque = deque(maxlen=window)
        self.stragglers = 0
        self.on_straggler = on_straggler
        self._t0: Optional[float] = None

    def start(self):
        self._t0 = time.monotonic()

    def stop(self, step: int) -> float:
        dt = time.monotonic() - self._t0
        if len(self.times) >= 8 and dt > self.factor * np.median(self.times):
            self.stragglers += 1
            if self.on_straggler:
                self.on_straggler(step, dt)
        self.times.append(dt)
        return dt

    def stats(self) -> Dict[str, float]:
        if not self.times:
            return {"median_s": 0.0, "stragglers": 0}
        return {"median_s": float(np.median(self.times)),
                "stragglers": self.stragglers}


def run_with_restarts(run_fn: Callable[[], Dict], max_restarts: int = 3
                      ) -> Dict:
    """Supervisor: rerun ``run_fn`` (which restores from its newest
    checkpoint) after failures, up to ``max_restarts`` times."""
    restarts = 0
    while True:
        try:
            out = run_fn()
            out["restarts"] = restarts
            return out
        except RuntimeError:
            restarts += 1
            if restarts > max_restarts:
                raise
