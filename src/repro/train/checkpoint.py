"""Checkpointing: sharded-agnostic pytree save/restore with async writes.

Format: one ``.npz`` per checkpoint step holding every leaf (flattened
path -> array, gathered to host) + a JSON manifest (step, pytree structure
fingerprint, dtypes).  Writes go to a temp name and are atomically renamed,
so a failure mid-write never corrupts the latest checkpoint (restart reads
the newest *complete* step — the fault-tolerance contract).

Because leaves are stored unsharded, restore works on ANY mesh/device
count: the restoring job re-shards under its own in_shardings — this is
what makes elastic scaling (resume on a different topology) work.

Async: ``CheckpointManager.save`` snapshots to host then writes on a
background thread, so the training loop only blocks for the device->host
copy, not the disk write.
"""
from __future__ import annotations

import json

import jax.numpy as jnp
import os
import threading
import time
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = jax.tree_util.keystr(path)
        arr = np.asarray(leaf)
        if str(arr.dtype) not in ("float64", "float32", "float16", "int64",
                                  "int32", "int16", "int8", "uint8", "bool"):
            arr = arr.astype(np.float32)   # bf16/fp8 -> f32 for npz
        flat[key] = arr
    return flat


def save_pytree(tree, directory: str | Path, step: int) -> Path:
    d = Path(directory)
    d.mkdir(parents=True, exist_ok=True)
    flat = _flatten(tree)
    tmp = d / f".tmp-{step}-{os.getpid()}.npz"
    final = d / f"step_{step:08d}.npz"
    with open(tmp, "wb") as f:
        np.savez(f, **flat)
    os.replace(tmp, final)                      # atomic publish
    manifest = d / f"step_{step:08d}.json"
    manifest.write_text(json.dumps({
        "step": step, "leaves": len(flat), "time": time.time()}))
    return final


def latest_step(directory: str | Path) -> Optional[int]:
    d = Path(directory)
    if not d.exists():
        return None
    steps = sorted(int(p.stem.split("_")[1]) for p in d.glob("step_*.npz"))
    return steps[-1] if steps else None


def restore_pytree(template, directory: str | Path,
                   step: Optional[int] = None):
    """Restore into the structure/dtypes/shardings of ``template``.

    ``template`` may hold concrete arrays or ShapeDtypeStructs; sharded
    placement is applied by the caller's jit in_shardings on first use.
    """
    d = Path(directory)
    if step is None:
        step = latest_step(d)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {d}")
    data = np.load(d / f"step_{step:08d}.npz")
    paths = jax.tree_util.tree_flatten_with_path(template)[0]
    treedef = jax.tree_util.tree_structure(template)
    leaves = []
    for path, leaf in paths:
        key = jax.tree_util.keystr(path)
        arr = data[key]
        if hasattr(leaf, "dtype") and arr.dtype != leaf.dtype:
            arr = jnp.asarray(arr).astype(leaf.dtype)   # handles bf16
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves), step


class CheckpointManager:
    """Async checkpointer with retention.

    save(): device->host snapshot synchronously, disk write on a daemon
    thread; keeps the last ``keep`` checkpoints.  ``wait()`` joins pending
    writes (called before exit and in tests).
    """

    def __init__(self, directory: str | Path, keep: int = 3):
        self.dir = Path(directory)
        self.keep = keep
        self._pending: Optional[threading.Thread] = None

    def save(self, tree, step: int, blocking: bool = False):
        host_tree = jax.tree_util.tree_map(np.asarray, tree)   # snapshot
        self.wait()

        def write():
            save_pytree(host_tree, self.dir, step)
            self._gc()

        if blocking:
            write()
        else:
            self._pending = threading.Thread(target=write, daemon=True)
            self._pending.start()

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def restore(self, template, step: Optional[int] = None):
        return restore_pytree(template, self.dir, step)

    def latest_step(self) -> Optional[int]:
        return latest_step(self.dir)

    def _gc(self):
        steps = sorted(int(p.stem.split("_")[1])
                       for p in self.dir.glob("step_*.npz"))
        for s in steps[:-self.keep]:
            for suffix in (".npz", ".json"):
                p = self.dir / f"step_{s:08d}{suffix}"
                if p.exists():
                    p.unlink()
