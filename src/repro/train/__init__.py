from .checkpoint import (CheckpointManager, latest_step, restore_pytree,
                         save_pytree)
from .train_loop import TrainConfig, train

__all__ = ["CheckpointManager", "latest_step", "restore_pytree",
           "save_pytree", "TrainConfig", "train"]
