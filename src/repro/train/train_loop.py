"""Training loop: mesh-aware, fault-tolerant, restartable.

One jitted step fuses: loss+grad -> pipelined grad-norm clip (stale norm,
off the critical path — DESIGN.md §4) -> in-graph bad-step gate (non-finite
or spiking grads leave params/opt untouched) -> AdamW update.  The loop
around it owns checkpoints (atomic, async), restart-on-failure, straggler
timing, and the stateless data pipeline (step index = iterator state).
"""
from __future__ import annotations

import dataclasses
import time
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import DataConfig, make_dataset
from repro.models import ModelConfig, init_params, loss_fn
from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         pipelined_clip, pipelined_clip_init)
from repro.optim.clipping import global_norm
from repro.parallel import LogicalMesh, use_mesh
from repro.parallel.param_rules import tree_param_specs

from .checkpoint import CheckpointManager
from .fault_tolerance import BadStepFilter, FailureInjector, StepTimer


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    steps: int = 100
    log_every: int = 10
    ckpt_every: int = 50
    ckpt_dir: str = "checkpoints"
    keep_ckpts: int = 3
    max_grad_norm: float = 1.0
    spike_factor: float = 50.0
    seed: int = 0
    resume: bool = True
    opt: AdamWConfig = AdamWConfig()


def make_train_step(model_cfg: ModelConfig, tcfg: TrainConfig,
                    lm: Optional[LogicalMesh] = None):
    """Returns the jitted fused step:
    (params, opt, clip, batch, spike_thresh) -> (params, opt, clip, metrics)
    """

    def step_fn(params, opt_state, clip_state, batch, spike_thresh):
        with use_mesh(lm):
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, model_cfg, batch)
            scale, clip_state2 = pipelined_clip(grads, clip_state,
                                                tcfg.max_grad_norm)
            gnorm = clip_state2.prev_norm
            new_params, new_opt = adamw_update(params, grads, opt_state,
                                               tcfg.opt, grad_scale=scale)
            # in-graph bad-step gate: non-finite loss/grads or a spike
            # leaves params, opt and clip state untouched
            ok = jnp.isfinite(loss) & jnp.isfinite(gnorm) \
                & (gnorm < spike_thresh)
            sel = lambda a, b: jax.tree_util.tree_map(
                lambda x, y: jnp.where(ok, x, y), a, b)
            params = sel(new_params, params)
            opt_state = sel(new_opt, opt_state)
            clip_state = sel(clip_state2, clip_state)
        metrics = dict(metrics)
        metrics.update(grad_norm=gnorm, accepted=ok.astype(jnp.float32))
        return params, opt_state, clip_state, metrics

    donate = (0, 1, 2)
    if lm is None:
        return jax.jit(step_fn, donate_argnums=donate)
    params_sds = jax.eval_shape(
        lambda: init_params(model_cfg, jax.random.PRNGKey(tcfg.seed)))
    pspecs = tree_param_specs(params_sds, lm)
    psh = jax.tree_util.tree_map(
        lambda s: jax.sharding.NamedSharding(lm.mesh, s), pspecs,
        is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
    return jax.jit(step_fn, donate_argnums=donate,
                   in_shardings=(psh, None, None, None, None),
                   out_shardings=(psh, None, None, None))


def train(model_cfg: ModelConfig, data_cfg: DataConfig, tcfg: TrainConfig,
          lm: Optional[LogicalMesh] = None,
          injector: Optional[FailureInjector] = None,
          callback: Optional[Callable[[int, Dict], None]] = None
          ) -> Dict[str, Any]:
    """Run (or resume) training.  Returns summary + metric history."""
    ckpt = CheckpointManager(tcfg.ckpt_dir, keep=tcfg.keep_ckpts)
    step_fn = make_train_step(model_cfg, tcfg, lm)
    batch_fn = make_dataset(data_cfg, model_cfg)

    params = init_params(model_cfg, jax.random.PRNGKey(tcfg.seed))
    opt_state = adamw_init(params, tcfg.opt)
    clip_state = pipelined_clip_init()
    start_step = 0
    if tcfg.resume and ckpt.latest_step() is not None:
        state_tpl = {"params": params, "opt": opt_state, "clip": clip_state}
        state, start_step = ckpt.restore(state_tpl)
        params, opt_state, clip_state = (state["params"], state["opt"],
                                         state["clip"])

    bad_filter = BadStepFilter(nan_zap=tcfg.spike_factor)
    timer = StepTimer()
    history: List[Dict[str, float]] = []

    step = start_step
    while step < tcfg.steps:
        if injector is not None:
            injector.check(step)
        timer.start()
        batch = {k: jnp.asarray(v) for k, v in batch_fn(step).items()}
        norms = list(bad_filter.norms) or [1e9]
        spike = jnp.asarray(tcfg.spike_factor * float(np.median(norms)),
                            jnp.float32)
        params, opt_state, clip_state, metrics = step_fn(
            params, opt_state, clip_state, batch, spike)
        loss = float(metrics["loss"])
        gnorm = float(metrics["grad_norm"])
        accepted = bool(metrics["accepted"] > 0)
        if accepted:
            bad_filter.accept(loss, gnorm)   # updates running stats
        else:
            bad_filter.rejected += 1
        dt = timer.stop(step)
        rec = {"step": step, "loss": loss, "grad_norm": gnorm,
               "accepted": accepted, "time_s": dt}
        history.append(rec)
        if callback:
            callback(step, rec)
        step += 1
        if step % tcfg.ckpt_every == 0 or step == tcfg.steps:
            ckpt.save({"params": params, "opt": opt_state,
                       "clip": clip_state}, step)
    ckpt.wait()
    return {
        "params": params,
        "final_loss": history[-1]["loss"] if history else float("nan"),
        "history": history,
        "start_step": start_step,
        "rejected_steps": bad_filter.rejected,
        "straggler_stats": timer.stats(),
    }
