"""Continuous-batching solve engine.

Slot-based design, the solver-side sibling of the LM serving engine in
:mod:`repro.serve.engine` (vLLM-style at the batch level): a fixed
``(n, max_batch)`` block of right-hand-side *slots* is stepped in chunks
of k iterations by ONE compiled program per registered operator,
regardless of which request mix occupies the slots.  Padding
unification: empty slots ride along as frozen columns (per-column budget
0), so the step program's shapes never change and nothing recompiles
under load.

Between chunks the engine retires finished columns — converged, broken
down, past their per-request ``maxiter`` budget (enforced on-device by
the per-column mask), or past their wall-clock ``deadline`` — and
refills the freed slots mid-flight by splicing fresh right-hand sides
and reset per-column Krylov state into the live state pytree (the
``splice_step`` handle of the operator's bound
:class:`repro.api.LinearSolver` session — admission fused into the
chunk as ONE compiled program).  Columns are independent
in "individual" blocked mode, so multiplexing is *exact*: a request's
trajectory is the one it would have had in a standalone
``solve_batched`` call (property-tested in tests/test_service.py).

What makes the batched p-BiCGSafe iteration the right substrate for a
solver service is the paper's own production property: every iteration
of the resident block issues ONE ``dot_reduce`` of a ``(9, m)`` partial
block — the single synchronization phase, amortized over every resident
request (Krasnopolsky, arXiv:1907.12874) — and that reduction keeps no
dependency edge to the in-flight block matvec, so the comm-hiding
overlap (Cools & Vanroose, arXiv:1612.01395) is intact under load
(asserted on the engine's step program in tests/test_service.py).

Throughput/latency against sequential and static-batch serving:
``benchmarks/bench_service.py``.

Resilience (``ServiceConfig.recovery``; see :mod:`repro.resilience`):
with a :class:`~repro.resilience.RecoveryPolicy` bound, the resident
blocks step guarded — the fused reduction carries the (11, m) health
rows, so breakdown/NaN detection costs zero extra synchronization —
and every retirement carries a typed :class:`~repro.core.SolveStatus`.
Columns that went non-finite are scrubbed (freeze-spliced) before their
slot is reused, and failed requests are re-enqueued with capped
exponential backoff up to ``recovery.max_retries`` times (stable rid
across retries).  Fault-injection chaos tests:
tests/test_resilience.py via :mod:`repro.resilience.inject`.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import SolveStatus
from repro.observe import metrics as _metrics
from repro.observe.spans import span as _span
from repro.observe.trace import ConvergenceTrace

from .registry import OperatorRegistry, RegisteredOperator
from .types import (RequestResult, RequestTelemetry, ServiceConfig,
                    SolveRequest)


@dataclasses.dataclass
class _Block:
    """One operator's resident (n, max_batch) block + host slot table."""

    state: dict
    slots: List[Optional[SolveRequest]]
    #: slots whose device column is still iterating but whose request was
    #: retired host-side (deadline) — they must be freeze-spliced
    orphans: set = dataclasses.field(default_factory=set)

    def live(self) -> bool:
        return any(s is not None for s in self.slots)


class SolveEngine:
    """Multiplex heterogeneous solve requests onto resident blocks.

    One resident block per registered operator; :meth:`poll` services one
    operator for one chunk (round-robin over operators with work) and
    returns the requests that completed; :meth:`run` drains everything.

    ``clock`` is injectable (tests and benchmarks drive deadlines with a
    virtual clock); it must be monotonic seconds.
    """

    def __init__(self, scfg: ServiceConfig = ServiceConfig(),
                 clock=time.monotonic):
        self.scfg = scfg
        self.registry = OperatorRegistry(scfg)
        self._clock = clock
        self._queues: Dict[str, Deque[SolveRequest]] = {}
        self._blocks: Dict[str, Optional[_Block]] = {}
        self._next_rid = 0
        self._rr = 0                     # round-robin cursor
        self._expired: List[RequestResult] = []
        #: ProfileReport of the most recent profiled run()
        #: (``ServiceConfig.profile_dir``); None otherwise
        self.last_profile = None

    # -- registration / submission ---------------------------------------
    def register(self, op, precond=None, name: Optional[str] = None) -> str:
        """Register an operator (idempotent by content; see registry)."""
        name = self.registry.register(op, precond, name)
        canon = self.registry[name].name
        self._queues.setdefault(canon, deque())
        self._blocks.setdefault(canon, None)
        return name

    def register_scenario(self, scenario,
                          name: Optional[str] = None) -> str:
        """Register a scenario (name or :class:`repro.scenarios
        .Scenario`): its plugin-built operator + precond become a
        resident block under the scenario's name."""
        name = self.registry.register_scenario(scenario, name)
        canon = self.registry[name].name
        self._queues.setdefault(canon, deque())
        self._blocks.setdefault(canon, None)
        return name

    def submit(self, operator: str, b, *, tol: Optional[float] = None,
               maxiter: Optional[int] = None,
               deadline: Optional[float] = None) -> int:
        """Enqueue one right-hand side; returns the request id."""
        entry = self.registry[operator]
        # host-side staging: the rhs is only ever consumed when the host
        # assembles an admission block, so keeping it as np avoids a
        # device put here AND a device pull per request at refill time
        b = np.asarray(b, dtype=np.dtype(entry.dtype))
        if b.shape != (entry.n,):
            raise ValueError(
                f"operator {operator!r} expects rhs of shape "
                f"({entry.n},); got {b.shape}")
        req = SolveRequest(operator=entry.name, b=b, tol=tol,
                           maxiter=maxiter, deadline=deadline,
                           rid=self._next_rid, t_submit=self._clock())
        self._next_rid += 1
        self._queues[entry.name].append(req)
        _metrics.ENGINE_QUEUE_DEPTH.set(len(self._queues[entry.name]),
                                        operator=entry.name)
        return req.rid

    # -- serving ---------------------------------------------------------
    def has_work(self) -> bool:
        return any(q for q in self._queues.values()) or \
            any(b is not None and b.live() for b in self._blocks.values())

    def run(self) -> List[RequestResult]:
        """Drain all queues and blocks; completed requests in retirement
        order.

        With ``ServiceConfig.profile_dir`` set, the whole drain runs
        inside a :mod:`repro.observe.profile` capture window: the step/
        splice programs the chunks execute are noted for HLO phase
        mapping, and the analyzed report lands on ``self.last_profile``
        + ``profile_dir/profile.json``.  Results are identical.
        """
        if self.scfg.profile_dir:
            return self._run_profiled()
        return self._drain()

    def _drain(self) -> List[RequestResult]:
        out: List[RequestResult] = []
        while self.has_work():
            out.extend(self.poll())
        out.extend(self._take_expired())
        return out

    def _run_profiled(self) -> List[RequestResult]:
        import os

        import jax

        from repro.observe import profile as _profile

        with _profile.capture(self.scfg.profile_dir) as cap:
            out = self._drain()
            # the ONE host read per chunk already synchronized; this
            # only fences stragglers before the window closes
            for blk in self._blocks.values():
                if blk is not None:
                    jax.block_until_ready(blk.state)
        rep = cap.analyze(label=f"engine/{self.scfg.substrate}")
        rep.save(os.path.join(self.scfg.profile_dir, "profile.json"))
        cap.save_hlo_map()
        self.last_profile = rep
        return out

    def poll(self) -> List[RequestResult]:
        """Service ONE operator for one chunk; returns newly completed
        requests (possibly none).  No-op when nothing has work."""
        entries = self.registry.entries()
        for off in range(len(entries)):
            entry = entries[(self._rr + off) % len(entries)]
            if self._entry_has_work(entry):
                self._rr = (self._rr + off + 1) % len(entries)
                done = self._service_chunk(entry)
                return self._take_expired() + done
        return self._take_expired()

    # -- internals -------------------------------------------------------
    def _entry_has_work(self, entry: RegisteredOperator) -> bool:
        blk = self._blocks[entry.name]
        return bool(self._queues[entry.name]) or \
            (blk is not None and blk.live())

    def _take_expired(self) -> List[RequestResult]:
        out, self._expired = self._expired, []
        return out

    def _next_request(self, q: Deque[SolveRequest]
                      ) -> Optional[SolveRequest]:
        """Pop the next serviceable request; requests whose deadline
        elapsed while queued are retired immediately (never occupy a
        slot), and retried requests still inside their backoff window
        (``not_before``) rotate to the back of the queue."""
        for _ in range(len(q)):
            req = q.popleft()
            if req.deadline is not None and \
                    self._clock() - req.t_submit > req.deadline:
                now = self._clock()
                self._expired.append(RequestResult(
                    rid=req.rid, operator=req.operator,
                    x=np.zeros((req.b.shape[0],), req.b.dtype),
                    iterations=0, relres=float("inf"),
                    converged=False, breakdown=False,
                    telemetry=RequestTelemetry(
                        queue_wait_s=now - req.t_submit, service_s=0.0,
                        wall_s=now - req.t_submit, chunks_resident=0,
                        deadline_exceeded=True),
                    status=SolveStatus.DEADLINE, retries=req.retries))
                self._observe_result(self._expired[-1])
                continue
            if req.not_before and self._clock() < req.not_before:
                q.append(req)            # backing off: not eligible yet
                continue
            return req
        return None

    def _fill_vectors(self, entry, slot_iter, B, tolv, mitv, mask=None):
        """Assign queued requests (then freeze-dummies) to the given free
        slots, writing the rhs block and per-column tol/maxiter in place.
        ``mask=None`` marks the initial fill (every slot is written);
        otherwise only masked columns are spliced."""
        q = self._queues[entry.name]
        blk = self._blocks[entry.name]
        for j in slot_iter:
            req = self._next_request(q)
            if req is not None:
                req.t_start = self._clock()
                B[:, j] = req.b
                tolv[j] = self.scfg.tol if req.tol is None else req.tol
                mitv[j] = self.scfg.maxiter if req.maxiter is None \
                    else req.maxiter
                blk.slots[j] = req
                blk.orphans.discard(j)
                if mask is not None:
                    mask[j] = True
            elif mask is not None and j in blk.orphans:
                # no request for this slot: freeze-splice the orphan
                # column (deadline-retired but still burning iterations)
                B[:, j] = 1.0            # safe nonzero rhs, budget 0
                mitv[j] = 0
                mask[j] = True
                blk.orphans.discard(j)
            elif mask is None:
                B[:, j] = 1.0            # initial fill: inert pad column
                mitv[j] = 0

    @staticmethod
    def _observe_result(res: RequestResult) -> None:
        """One retirement into the metrics registry — the single source
        of truth ``bench_service`` and external scrapes read; every
        value here is host-known (the engine already pulled the flags),
        so recording adds no device read."""
        _metrics.ENGINE_REQUESTS.inc(status=res.status.name)
        t = res.telemetry
        _metrics.REQUEST_QUEUE_WAIT.observe(t.queue_wait_s)
        _metrics.REQUEST_WALL.observe(t.wall_s)
        _metrics.REQUEST_CHUNKS.observe(t.chunks_resident)
        _metrics.SOLVE_ITERATIONS.observe(res.iterations)

    def _service_chunk(self, entry: RegisteredOperator
                       ) -> List[RequestResult]:
        with _span("engine.chunk", operator=entry.name):
            t0 = self._clock()
            out = self._service_chunk_inner(entry)
            _metrics.ENGINE_CHUNK_SECONDS.observe(self._clock() - t0)
        blk = self._blocks[entry.name]
        _metrics.ENGINE_QUEUE_DEPTH.set(
            len(self._queues[entry.name]), operator=entry.name)
        _metrics.ENGINE_SLOT_OCCUPANCY.set(
            0 if blk is None else sum(s is not None for s in blk.slots),
            operator=entry.name)
        return out

    def _service_chunk_inner(self, entry: RegisteredOperator
                             ) -> List[RequestResult]:
        name = entry.name
        q = self._queues[name]
        blk = self._blocks[name]
        m = self.scfg.max_batch
        np_dtype = np.dtype(entry.dtype)

        # 1) admit + step, as ONE compiled program per chunk: either the
        # plain chunk step, or the fused splice-then-step when freed
        # slots are being refilled mid-flight (admission costs no extra
        # dispatch or host round-trip)
        if blk is None:
            if not q:
                return []
            B = np.zeros((entry.n, m), np_dtype)
            tolv = np.full((m,), self.scfg.tol, np.float64)
            mitv = np.zeros((m,), np.int32)
            blk = _Block(state=None, slots=[None] * m)
            self._blocks[name] = blk
            self._fill_vectors(entry, range(m), B, tolv, mitv)
            with _span("engine.init_fill", operator=name):
                blk.state = entry.step_fn(
                    entry.init_fn(jnp.asarray(B), jnp.asarray(tolv),
                                  jnp.asarray(mitv)))
        else:
            free = [j for j in range(m) if blk.slots[j] is None]
            mask = np.zeros((m,), bool)
            if free and (q or blk.orphans):
                B = np.zeros((entry.n, m), np_dtype)
                tolv = np.zeros((m,), np.float64)
                mitv = np.zeros((m,), np.int32)
                self._fill_vectors(entry, free, B, tolv, mitv, mask=mask)
            if mask.any():
                with _span("engine.splice_step", operator=name,
                           refills=int(mask.sum())):
                    blk.state = entry.splice_step_fn(
                        blk.state, jnp.asarray(mask), jnp.asarray(B),
                        jnp.asarray(tolv), jnp.asarray(mitv))
            else:
                with _span("engine.step", operator=name):
                    blk.state = entry.step_fn(blk.state)
        for req in blk.slots:
            if req is not None:
                req.chunks_resident += 1

        # 3) retire finished / deadline-blown columns (ONE host transfer
        # for the (m,) flag vectors — plus the typed status vector when
        # the block is guarded and the trace ring when tracing is on:
        # the harvest rides the host read the engine already does)
        st = blk.state
        guarded = "status" in st
        traced = "trace" in st
        flags = [st["converged"], st["breakdown"], st["iterations"],
                 st["relres"], st["col_maxiter"]]
        if guarded:
            flags.append(st["status"])
        if traced:
            flags += [st["trace"], st["i"]]
        with _span("engine.retire", operator=name):
            got = jax.device_get(tuple(flags))
        conv, brk, iters, relres, budget = got[:5]
        k = 5
        status_arr = None
        if guarded:
            status_arr = got[k]
            k += 1
        trace_buf, trace_steps = None, 0
        if traced:
            trace_buf, trace_steps = got[k], int(got[k + 1])
        recovery = self.scfg.recovery
        results: List[RequestResult] = []
        x_host = None
        now = self._clock()
        for j, req in enumerate(blk.slots):
            if req is None:
                continue
            finished = bool(conv[j] or brk[j] or iters[j] >= budget[j])
            late = (req.deadline is not None
                    and now - req.t_submit > req.deadline)
            if not (finished or late):
                continue
            # typed retirement status: the guarded block carries the
            # in-reduction per-column code; unguarded blocks get the
            # coarse classification — DEADLINE trumps either
            if guarded and finished \
                    and int(status_arr[j]) != SolveStatus.RUNNING.value:
                sts = SolveStatus(int(status_arr[j]))
            elif conv[j]:
                sts = SolveStatus.CONVERGED
            elif brk[j]:
                sts = SolveStatus.BREAKDOWN
            else:
                sts = SolveStatus.MAXITER
            if late and not finished:
                sts = SolveStatus.DEADLINE
            poisoned = sts == SolveStatus.NONFINITE \
                or not np.isfinite(relres[j])
            blk.slots[j] = None
            if late and not finished:
                blk.orphans.add(j)       # still iterating: freeze later
            if poisoned:
                blk.orphans.add(j)       # scrub before the slot is reused
            # failed requests re-enqueue with capped exponential backoff
            # (stable rid); no result is emitted for this attempt
            if recovery is not None and sts.is_failure \
                    and sts != SolveStatus.DEADLINE \
                    and req.retries < recovery.max_retries and not late:
                req.retries += 1
                back = 0.0
                if recovery.retry_backoff_s:
                    back = min(
                        recovery.retry_backoff_s * 2 ** (req.retries - 1),
                        recovery.retry_backoff_cap_s)
                req.not_before = now + back
                q.append(req)
                _metrics.ENGINE_RETRIES.inc()
                continue
            if x_host is None:
                x_host = np.asarray(st["x"])
            xj = x_host[:, j].copy()
            if not np.isfinite(xj).all():
                # finite-output guarantee: a poisoned column never hands
                # NaN back to the caller (the typed status says why)
                xj = np.where(np.isfinite(xj), xj, 0.0)
            rr_j = float(relres[j])
            trace = None
            if traced:
                # per-column slice of the block's shared ring; spliced
                # columns had their pre-admission rows NaN'd, which
                # ConvergenceTrace.per_iteration() drops
                trace = ConvergenceTrace(
                    np.ascontiguousarray(trace_buf[:, :, j]), trace_steps)
            res = RequestResult(
                rid=req.rid, operator=name, x=xj,
                iterations=int(iters[j]),
                relres=rr_j if np.isfinite(rr_j) else float("inf"),
                converged=bool(conv[j]), breakdown=bool(brk[j]),
                telemetry=RequestTelemetry(
                    queue_wait_s=req.t_start - req.t_submit,
                    service_s=now - req.t_start,
                    wall_s=now - req.t_submit,
                    chunks_resident=req.chunks_resident,
                    deadline_exceeded=bool(late and not finished)),
                status=sts, retries=req.retries, trace=trace)
            self._observe_result(res)
            results.append(res)

        # 4) drop a drained block (frozen orphans die with it)
        if not blk.live() and not q:
            self._blocks[name] = None

        return results
