"""Operator registry: named views onto :mod:`repro.api` solver sessions.

Serving traffic is repetitive: many requests arrive against the same
operator A (same mesh, same physics), often re-constructed per request
by the caller.  Deduplication by *content* — and everything expensive
that rides on it: building the preconditioner ONCE, tracing the
open-loop ``init`` / ``step_chunk`` / ``splice_step`` programs ONCE —
lives in :mod:`repro.api` since PR 5: :func:`repro.api.make_solver`
memoizes whole :class:`~repro.api.LinearSolver` sessions under the
operator-content fingerprint, so the registry here is a thin consumer:
it binds engine-facing *names* (and the engine's chunk size) to
sessions, and two registrations with equal content — in this engine, in
another engine, or via a direct ``repro.make_solver`` call — share one
session and therefore one set of compiled programs.

Each :class:`RegisteredOperator` exposes the session's composed
``M^{-1} ∘ A`` block matvec (operator dispatch intact — a banded ELL
operator on the pallas substrate runs the block-ELL kernel) and the
three jitted open-loop programs sized to the engine's
``(n, max_batch)`` resident block, exactly as before the promotion.
"""
from __future__ import annotations

from typing import Dict, Optional

from repro.api import LinearSolver, make_solver, operator_fingerprint
from repro.core.types import SolverConfig
from repro.precond.base import PrecondLike

from .types import ServiceConfig


class RegisteredOperator:
    """One operator (+ optional preconditioner) bound to the engine block.

    A named, chunk-sized view onto a cached :class:`repro.api
    .LinearSolver` session: the built preconditioner, the composed
    block matvec, and the compiled open-loop programs all belong to the
    session — reusing the session (the api cache's job) is what reuses
    them.
    """

    def __init__(self, name: str, op, precond: PrecondLike,
                 scfg: ServiceConfig, session: LinearSolver):
        self.name = name
        self.op = op
        self.scfg = scfg
        self.session = session
        self.fingerprint = session.fingerprint
        self.sub = session.sub
        #: kernel-backed path assertion: a pallas-substrate service must
        #: actually be running the hand-tiled kernels, not a lookalike
        #: (the session asserts it at construction; surfaced here).
        self.kernel_backed = session.kernel_backed
        self.precond = session.precond          # built ONCE, by the session
        self.bmv = session.block_matvec
        self.n = op.shape[0]
        self.dtype = op.dtype

        # The engine hands these RAW right-hand-side blocks; the left
        # preconditioning of the system (solve M^{-1} A x = M^{-1} b)
        # happens inside the session's jitted programs.  Admission stays
        # fused: splice-then-step is ONE compiled program, so a chunk
        # boundary with refills costs one dispatch + one host read, same
        # as a chunk without.
        chunk = int(scfg.chunk)
        self.init_fn = lambda B, tolv, mitv: session.init(
            B, tol=tolv, maxiter=mitv)
        self.step_fn = lambda st: session.step_chunk(st, chunk)
        self.splice_step_fn = lambda st, mask, Bn, tolv, mitv: \
            session.splice_step(st, mask, Bn, tolv, mitv, chunk)

    def __repr__(self):
        pc = getattr(self.precond, "name", None)
        return (f"<RegisteredOperator {self.name!r} n={self.n} "
                f"precond={pc!r} substrate={self.sub.name!r}>")


class OperatorRegistry:
    """Content-addressed operator table (names -> sessions).

    ``register`` is idempotent under re-registration of equal content:
    the same (operator bytes, precond spec) fingerprint returns the
    EXISTING entry — preconditioner and compiled programs included —
    under whichever names it was registered.  The fingerprinting and the
    session reuse are :func:`repro.api.make_solver`'s; this class only
    maps names.
    """

    def __init__(self, scfg: ServiceConfig):
        self._scfg = scfg
        self._by_name: Dict[str, RegisteredOperator] = {}
        self._by_fp: Dict[str, RegisteredOperator] = {}

    def _make_session(self, op, precond: PrecondLike) -> LinearSolver:
        scfg = self._scfg
        cfg = SolverConfig(tol=scfg.tol, maxiter=scfg.maxiter,
                           trace_cap=scfg.trace_cap)
        if scfg.recovery is not None:
            # guarded serving: the open-loop programs step with the
            # (11, m) health reduction and carry typed per-column
            # statuses the engine reads at chunk boundaries
            from repro.resilience.guard import guarded_config
            cfg = guarded_config(cfg, scfg.recovery)
        return make_solver(
            "p-bicgsafe", op, precond=precond, substrate=scfg.substrate,
            config=cfg)

    def register(self, op, precond: PrecondLike = None,
                 name: Optional[str] = None) -> str:
        # fingerprint FIRST, session only on a miss: re-registering known
        # content must stay cheap even when the api layer's LRU has
        # evicted the session (no throwaway preconditioner builds)
        try:
            fp = operator_fingerprint(op, precond)
        except TypeError:
            # the engine needs op.shape/op.dtype for request validation
            # and the service's whole reuse story is content addressing —
            # bare matvec callables support neither
            raise TypeError(
                "the solve service requires a content-addressable operator "
                f"object (got {type(op).__name__}); wrap the matvec in an "
                "operator class (Dense/CSR/ELL/Stencil7) to register it"
            ) from None
        entry = self._by_fp.get(fp)
        if entry is None:
            if name is None:                 # first free auto name
                i = len(self._by_fp)
                while f"op{i}" in self._by_name:
                    i += 1
                name = f"op{i}"
            elif name in self._by_name \
                    and self._by_name[name].fingerprint != fp:
                raise ValueError(
                    f"operator name {name!r} already registered with "
                    "different content")
            # session built only after the name conflict check: a
            # rejected registration must not occupy an api cache slot
            session = self._make_session(op, precond)
            entry = RegisteredOperator(name, op, precond, self._scfg, session)
            self._by_fp[fp] = entry
            self._by_name[name] = entry
        elif name is not None:
            existing = self._by_name.get(name)
            if existing is not None and existing.fingerprint != fp:
                raise ValueError(
                    f"operator name {name!r} already registered with "
                    "different content")
            self._by_name[name] = entry     # alias to the cached entry
        return entry.name if name is None else name

    def register_scenario(self, scenario,
                          name: Optional[str] = None) -> str:
        """Register a scenario's operator + preconditioner by name.

        ``scenario`` is a registered scenario name or a
        :class:`repro.scenarios.Scenario`; the operator is built through
        its plugin (cached per spec content, so two engines registering
        the same scenario share one session).  The engine serves its own
        open-loop p-BiCGSafe blocks under :class:`ServiceConfig` — a
        scenario contributes its operator, precond and name; its
        method/substrate/tol describe the offline sweep cell, not the
        serving configuration.
        """
        from repro.scenarios import resolve_scenario
        sc = resolve_scenario(scenario)
        op = sc.problem()[0]
        return self.register(op, sc.precond, name or sc.name)

    def __getitem__(self, name: str) -> RegisteredOperator:
        try:
            return self._by_name[name]
        except KeyError:
            raise KeyError(
                f"unknown operator {name!r}; registered: "
                f"{sorted(self._by_name)}") from None

    def __contains__(self, name: str) -> bool:
        return name in self._by_name

    def entries(self):
        """Unique entries (aliases deduplicated), registration order."""
        return list(self._by_fp.values())

    def names(self):
        return sorted(self._by_name)
