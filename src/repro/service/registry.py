"""Operator registry: fingerprint-keyed reuse of preconditioners and
compiled step programs.

Serving traffic is repetitive: many requests arrive against the same
operator A (same mesh, same physics), often re-constructed per request by
the caller.  The registry deduplicates by *content*
(:func:`repro.precond.operator_fingerprint` hashes the operator pytree
and the precond spec), so for repeat traffic:

* the preconditioner is built ONCE — block-Jacobi's dense block
  inversions and SSOR's setup are the expensive parts, and they are
  exactly what the fingerprint cache reuses;
* the compiled programs are reused — ``init_fn`` / ``step_fn`` /
  ``splice_step_fn`` close over the operator arrays, so a fresh entry
  would retrace and recompile; the cache hands back the entry that
  already traced them.

Each :class:`RegisteredOperator` owns the substrate-bound block matvec
(operator dispatch intact — a banded ELL operator on the pallas substrate
runs the block-ELL kernel) composed with the M^{-1}-apply, exactly as
:func:`repro.precond.base.wrap_block_preconditioned` builds it for
``solve_batched``, plus the jitted open-loop programs of
:mod:`repro.core.multirhs` sized to the engine's ``(n, max_batch)``
resident block.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax

from repro.core.multirhs import init_state, splice_columns, step_chunk
from repro.core.substrate import get_substrate
from repro.core.types import SolverConfig
from repro.precond.base import (PrecondLike, operator_fingerprint,
                                resolve_precond)

from .types import ServiceConfig


class RegisteredOperator:
    """One operator (+ optional preconditioner) bound to the engine block.

    Holds the built preconditioner, the composed ``M^{-1} ∘ A`` block
    matvec, and the three jitted programs the engine drives.  All three
    close over the operator arrays — reusing the entry (the registry's
    job) is what reuses their compilations.
    """

    def __init__(self, name: str, op, precond: PrecondLike,
                 scfg: ServiceConfig, fingerprint: str):
        self.name = name
        self.op = op
        self.fingerprint = fingerprint
        self.scfg = scfg
        sub = get_substrate(scfg.substrate)
        self.sub = sub
        #: kernel-backed path assertion: a pallas-substrate service must
        #: actually be running the hand-tiled kernels, not a lookalike.
        self.kernel_backed = bool(getattr(sub, "kernel_backed", False))
        if getattr(sub, "name", None) == "pallas":
            assert self.kernel_backed, (
                "substrate resolved to 'pallas' but is not kernel-backed")

        self.precond = resolve_precond(precond, op)   # built ONCE
        raw_bmv = sub.as_block_matvec(op)
        if self.precond is None:
            self.papply = None
            self.bmv = raw_bmv
        else:
            papply = sub.as_precond_apply(self.precond)
            self.papply = papply
            self.bmv = lambda X: papply(raw_bmv(X))

        n = op.shape[0]
        self.n = n
        self.dtype = op.dtype
        # solver config for the resident block: per-column tol/maxiter
        # vectors override these defaults per request
        cfg = SolverConfig(tol=scfg.tol, maxiter=scfg.maxiter)
        self._cfg = cfg

        # The engine hands these RAW right-hand-side blocks; the left
        # preconditioning of the system (solve M^{-1} A x = M^{-1} b)
        # happens inside the jitted program, exactly as
        # wrap_block_preconditioned does for solve_batched.
        def prep(B):
            return self.papply(B) if self.papply is not None else B

        self.init_fn = jax.jit(
            lambda B, tolv, mitv: init_state(
                self.bmv, prep(B), config=cfg, substrate=sub,
                tol=tolv, maxiter=mitv))
        chunk = int(scfg.chunk)
        self.step_fn = jax.jit(
            lambda st: step_chunk(self.bmv, st, chunk, config=cfg,
                                  substrate=sub))
        # admission fused into the chunk: splice-then-step is ONE
        # compiled program, so a chunk boundary with refills costs one
        # dispatch + one host read, same as a chunk without (this is the
        # "one program regardless of request mix" property, taken
        # literally — per-chunk host round-trips are what a CPU-bound
        # service actually pays for)
        self.splice_step_fn = jax.jit(
            lambda st, mask, Bn, tolv, mitv: step_chunk(
                self.bmv,
                splice_columns(self.bmv, st, mask, prep(Bn),
                               substrate=sub, tol=tolv, maxiter=mitv),
                chunk, config=cfg, substrate=sub))

    def __repr__(self):
        pc = getattr(self.precond, "name", None)
        return (f"<RegisteredOperator {self.name!r} n={self.n} "
                f"precond={pc!r} substrate={self.sub.name!r}>")


class OperatorRegistry:
    """Content-addressed operator table.

    ``register`` is idempotent under re-registration of equal content:
    the same (operator bytes, precond spec) fingerprint returns the
    EXISTING entry — preconditioner and compiled programs included —
    under whichever names it was registered.
    """

    def __init__(self, scfg: ServiceConfig):
        self._scfg = scfg
        self._by_name: Dict[str, RegisteredOperator] = {}
        self._by_fp: Dict[str, RegisteredOperator] = {}

    def register(self, op, precond: PrecondLike = None,
                 name: Optional[str] = None) -> str:
        fp = operator_fingerprint(op, precond)
        entry = self._by_fp.get(fp)
        if entry is None:
            if name is None:                 # first free auto name
                i = len(self._by_fp)
                while f"op{i}" in self._by_name:
                    i += 1
                name = f"op{i}"
            elif name in self._by_name \
                    and self._by_name[name].fingerprint != fp:
                raise ValueError(
                    f"operator name {name!r} already registered with "
                    "different content")
            entry = RegisteredOperator(name, op, precond, self._scfg, fp)
            self._by_fp[fp] = entry
            self._by_name[name] = entry
        elif name is not None:
            existing = self._by_name.get(name)
            if existing is not None and existing.fingerprint != fp:
                raise ValueError(
                    f"operator name {name!r} already registered with "
                    "different content")
            self._by_name[name] = entry     # alias to the cached entry
        return entry.name if name is None else name

    def __getitem__(self, name: str) -> RegisteredOperator:
        try:
            return self._by_name[name]
        except KeyError:
            raise KeyError(
                f"unknown operator {name!r}; registered: "
                f"{sorted(self._by_name)}") from None

    def __contains__(self, name: str) -> bool:
        return name in self._by_name

    def entries(self):
        """Unique entries (aliases deduplicated), registration order."""
        return list(self._by_fp.values())

    def names(self):
        return sorted(self._by_name)
