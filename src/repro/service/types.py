"""Request/response types and configuration for the solve service.

A :class:`SolveRequest` is one right-hand side against one registered
operator, with its own ``tol`` / ``maxiter`` / ``deadline``; the engine
multiplexes heterogeneous requests onto one resident ``(n, max_batch)``
block (see :mod:`repro.service.engine`) and returns a
:class:`RequestResult` per request, carrying the same solver fields as a
standalone :class:`repro.core.SolveResult` column plus serving telemetry
(queue wait, chunks resident, wall time).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import numpy as np

from repro.core.types import SolveStatus
from repro.resilience.policy import RecoveryPolicy


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    """Static engine configuration.

    Attributes:
      max_batch: slots in the resident block — the m of the one compiled
        ``(n, m)`` step program.  Request mix never changes it (padding
        unification: empty slots ride along frozen), so there is no shape
        churn and no recompilation under load.
      chunk: iterations per engine step.  Retirement/refill happens only
        at chunk boundaries: larger chunks amortize host round-trips,
        smaller chunks tighten refill latency.  The early-exit inside
        :func:`repro.core.multirhs.step_chunk` means an almost-drained
        block does not burn the full chunk.
      substrate: compute substrate for the hot loop ("jnp" | "pallas" or
        a :class:`repro.core.Substrate` instance) — see
        :mod:`repro.core.substrate`.
      tol / maxiter: per-request defaults when the request leaves them
        unset (``maxiter`` is also the hard per-column budget the step
        program enforces on device).
      recovery: ``None`` runs the engine exactly as before.  A
        :class:`repro.resilience.RecoveryPolicy` turns on guarded
        serving: the resident blocks step with ``SolverConfig.guard``
        (the (11, m) in-reduction health rows — same single reduction
        per iteration), broken columns retire with their typed
        :class:`~repro.core.SolveStatus`, non-finite columns are
        scrubbed before their slot is reused, and failed requests are
        re-enqueued up to ``recovery.max_retries`` times with capped
        exponential backoff.
      trace_cap: per-column iteration-trace ring capacity
        (``SolverConfig.trace_cap``) for the resident blocks.  0 (the
        default) serves untraced; when set, every retirement carries a
        :class:`repro.observe.ConvergenceTrace` on
        ``RequestResult.trace``, harvested at chunk boundaries with the
        ONE host read the engine already does — zero extra
        synchronizations on the device path.
      profile_dir: when set, :meth:`repro.service.SolveEngine.run`
        wraps its drain loop in a :mod:`repro.observe.profile` capture
        window: the device timeline + HLO phase map land under this
        directory, and the per-phase/overlap :class:`~repro.observe
        .profile.ProfileReport` is attached as ``engine.last_profile``
        (and written to ``profile_dir/profile.json``).  Serving
        behavior and results are unchanged; use for one diagnostic run,
        not steady-state serving (the capture holds the whole timeline
        in memory).
    """

    max_batch: int = 8
    chunk: int = 32
    substrate: Any = "jnp"
    tol: float = 1e-8
    maxiter: int = 10_000
    recovery: Optional[RecoveryPolicy] = None
    trace_cap: int = 0
    profile_dir: Optional[str] = None


@dataclasses.dataclass
class SolveRequest:
    """One right-hand side against a registered operator.

    ``tol``/``maxiter`` default from :class:`ServiceConfig`; ``deadline``
    is a wall-clock budget in seconds from submission — a request still
    in flight past its deadline is retired unconverged at the next chunk
    boundary (its partial iterate is returned).

    ``b`` is staged host-side (np) by the engine: it is only consumed
    when the host assembles an admission block, so device puts happen
    once per block, not per request.
    """

    operator: str
    b: np.ndarray
    tol: Optional[float] = None
    maxiter: Optional[int] = None
    deadline: Optional[float] = None
    rid: int = -1
    # host-side bookkeeping (filled by the engine)
    t_submit: float = 0.0
    t_start: Optional[float] = None
    chunks_resident: int = 0
    #: retry attempts consumed so far (guarded serving; see
    #: ``ServiceConfig.recovery``) — the rid is stable across retries
    retries: int = 0
    #: earliest clock time this request may next occupy a slot (retry
    #: backoff; 0.0 = immediately eligible)
    not_before: float = 0.0


@dataclasses.dataclass(frozen=True)
class RequestTelemetry:
    """Serving telemetry for one completed request."""

    queue_wait_s: float       # submit -> first resident in the block
    service_s: float          # first resident -> retirement
    wall_s: float             # submit -> retirement
    chunks_resident: int      # engine chunks the request stayed resident
    deadline_exceeded: bool


@dataclasses.dataclass(frozen=True)
class RequestResult:
    """Per-request outcome: the solver fields a standalone
    ``solve_batched`` column would report, plus telemetry.

    ``status`` is the typed :class:`~repro.core.SolveStatus` of the
    retirement: always filled (guarded serving reports the in-reduction
    per-column code — which BiCGSafe denominator broke, NONFINITE, … —
    unguarded serving the coarse classification; deadline expiry is
    ``DEADLINE`` either way).  ``retries`` counts how many times the
    engine re-ran the request before this outcome (0 without a recovery
    policy).  ``trace`` is the request's per-iteration
    :class:`repro.observe.ConvergenceTrace` when the engine serves with
    ``ServiceConfig.trace_cap`` set (``None`` otherwise).
    """

    rid: int
    operator: str
    x: np.ndarray
    iterations: int
    relres: float
    converged: bool
    breakdown: bool
    telemetry: RequestTelemetry
    status: SolveStatus = SolveStatus.CONVERGED
    retries: int = 0
    trace: Optional[Any] = None
