"""repro.service — continuous-batching solve service.

The serving layer for the paper's batched pipelined solver: independent
user requests (one right-hand side each, with their own ``tol`` /
``maxiter`` / ``deadline``) are multiplexed onto a fixed
``(n, max_batch)`` resident block stepped by ONE compiled program per
registered operator — one ``(9, m)`` reduction per iteration for the
whole block, comm-hiding overlap intact under load.  Converged columns
retire between chunks and freed slots are refilled mid-flight by
splicing fresh Krylov state into the live block
(:mod:`repro.core.multirhs`'s ``init_state / step_chunk /
splice_columns`` open-loop API).

The engine drives :class:`repro.api.LinearSolver` sessions (PR 5): the
registry binds engine-facing names to sessions from the content-keyed
cache in :mod:`repro.api`, so preconditioner builds and compiled step
programs are shared with direct ``repro.make_solver`` users — and
across engines — not just within one registry.

Quickstart::

    from repro.service import ServiceConfig, SolveEngine

    eng = SolveEngine(ServiceConfig(max_batch=8, chunk=16))
    name = eng.register(op, precond="block_jacobi")
    rids = [eng.submit(name, b_i, tol=1e-8) for b_i in rhs_stream]
    for res in eng.run():
        print(res.rid, res.converged, res.iterations,
              res.telemetry.queue_wait_s)

See ``examples/serve_solver.py`` for a runnable tour and
``benchmarks/bench_service.py`` for throughput/latency against
sequential and static-batch serving.
"""
from .engine import SolveEngine
from .registry import OperatorRegistry, RegisteredOperator
from .types import (RequestResult, RequestTelemetry, ServiceConfig,
                    SolveRequest)

__all__ = [
    "SolveEngine",
    "OperatorRegistry", "RegisteredOperator",
    "ServiceConfig", "SolveRequest", "RequestResult", "RequestTelemetry",
]
