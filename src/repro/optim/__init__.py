from .adamw import AdamWConfig, adamw_init, adamw_update
from .clipping import PipelinedClipState, pipelined_clip_init, pipelined_clip

__all__ = ["AdamWConfig", "adamw_init", "adamw_update",
           "PipelinedClipState", "pipelined_clip_init", "pipelined_clip"]
