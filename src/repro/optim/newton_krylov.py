"""Newton-Krylov optimizer: the paper's solver as a first-class training
feature (DESIGN.md §4).

Each step solves the damped Gauss-Newton system

    (J'J + lambda I) delta = -g          (GGN = J'J for CE loss via JVP/VJP)

with **p-BiCGSafe** (paper Alg. 3.1) as the inner linear solver.  The
operator is matrix-free over the *flattened parameter vector*; on a mesh
the HVP inherits the model's sharding and the solver's 9 fused dots reduce
in the one psum whose latency hides behind the HVP matvec — the paper's
communication-hiding mechanism applied verbatim to training.

The GGN matvec uses the standard JVP-then-VJP composition through the
model's logits with the CE Hessian (diag(p) - pp') in between.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from repro.core import SolverConfig
# the unwrapped implementation (not the deprecated free-function shim):
# the inner Krylov solve is library-internal delegation, not user API
from repro.core.pipelined_bicgsafe import pbicgsafe_solve
from repro.core.types import identity_reduce


@dataclasses.dataclass(frozen=True)
class NewtonKrylovConfig:
    lr: float = 1.0
    damping: float = 1e-2
    trust_radius: float = 1.0      # cap on ||delta|| (LM-style safeguard
    #                                against near-null-space amplification)
    inner_tol: float = 1e-3
    inner_maxiter: int = 20
    solver: Callable = pbicgsafe_solve


def _ravel(tree):
    return ravel_pytree(tree)


def make_ggn_matvec(loss_logits_fn: Callable, params, batch,
                    damping: float):
    """loss_logits_fn(params, batch) -> (B..., V) logits for CE loss.

    Returns matvec over the raveled parameter vector computing
    (J' H_CE J + damping I) v  with H_CE = diag(p) - p p'.
    """
    flat0, unravel = _ravel(params)

    def logits_of(flat):
        return loss_logits_fn(unravel(flat), batch)

    acc_dtype = jnp.promote_types(flat0.dtype, jnp.float32)

    def matvec(v):
        _, jv = jax.jvp(logits_of, (flat0,), (v,))          # (B..., V)
        logits = logits_of(flat0)
        p = jax.nn.softmax(logits.astype(acc_dtype), axis=-1)
        # Accumulate the CE-Hessian product in acc_dtype (f64 when the
        # params are f64).  Downcasting jv to f32 here makes the operator
        # nonlinear at the f32 rounding level, which silently breaks
        # p-BiCGSafe's recurrences (q_i = A s_i + beta l_{i-1} etc. assume
        # an exactly linear A): the recurred residual converges while the
        # true residual stalls O(1), and every Newton direction is garbage.
        hjv = p * jv.astype(acc_dtype)
        hjv = hjv - p * jnp.sum(hjv, axis=-1, keepdims=True)
        n_rows = hjv.size // hjv.shape[-1]
        hjv = (hjv / n_rows).astype(jv.dtype)
        _, vjp = jax.vjp(logits_of, flat0)
        (jt_hjv,) = vjp(hjv)
        return jt_hjv + damping * v

    return matvec, flat0, unravel


def newton_krylov_step(loss_fn_: Callable, logits_fn: Callable, params,
                       batch, cfg: NewtonKrylovConfig,
                       dot_reduce=identity_reduce
                       ) -> Tuple[Any, Dict[str, jax.Array]]:
    """One truncated Gauss-Newton step.  Returns (new_params, metrics)."""
    loss, grads = jax.value_and_grad(loss_fn_)(params, batch)
    g_flat, unravel = _ravel(grads)
    matvec, flat0, _ = make_ggn_matvec(logits_fn, params, batch, cfg.damping)

    res = cfg.solver(
        matvec, -g_flat,
        config=SolverConfig(tol=cfg.inner_tol, maxiter=cfg.inner_maxiter),
        dot_reduce=dot_reduce)
    dnorm = jnp.linalg.norm(res.x)
    step_flat = res.x * jnp.minimum(1.0, cfg.trust_radius
                                    / jnp.maximum(dnorm, 1e-12))

    # backtracking line search (incl. 0 fallback => monotone descent)
    def params_at(t):
        delta = unravel(step_flat * t)
        return jax.tree_util.tree_map(
            lambda p, d: (p.astype(jnp.float32)
                          + cfg.lr * d.astype(jnp.float32)).astype(p.dtype),
            params, delta)

    ts = jnp.asarray([1.0, 0.3, 0.1, 0.0])
    losses = jnp.stack([loss_fn_(params_at(t), batch) for t in ts])
    best = jnp.argmin(losses)
    new_params = jax.tree_util.tree_map(
        lambda *xs: jnp.stack(xs)[best],
        *[params_at(t) for t in ts])
    metrics = {"loss": loss, "inner_iters": res.iterations,
               "inner_relres": res.relres,
               "inner_converged": res.converged,
               "step_scale": ts[best], "new_loss": losses[best]}
    return new_params, metrics
