"""Pipelined gradient-norm clipping — the paper's dependency-breaking idea
applied to training (beyond-paper feature, DESIGN.md §4).

Standard global-norm clipping puts the norm's all-reduce on the critical
path between backward and the optimizer.  Like p-BiCGSafe's reduction
(which consumes only last-iteration quantities), we clip step k with the
*previous* step's global norm: the norm all-reduce of step k then has no
consumer inside step k and overlaps with the optimizer/backward compute.
One-step-stale clipping is a standard large-batch practice; the clip
threshold changes slowly relative to one step.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


class PipelinedClipState(NamedTuple):
    prev_norm: jax.Array   # global grad norm from the previous step
    initialized: jax.Array


def pipelined_clip_init() -> PipelinedClipState:
    return PipelinedClipState(jnp.ones((), jnp.float32),
                              jnp.zeros((), bool))


def global_norm(grads) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree_util.tree_leaves(grads)))


def pipelined_clip(grads, state: PipelinedClipState, max_norm: float
                   ) -> Tuple[jax.Array, PipelinedClipState]:
    """Returns (grad_scale, new_state).

    ``grad_scale`` is computed from state.prev_norm (stale by one step) so
    this step's norm reduction is off the critical path.  The fresh norm is
    returned in the new state for the next step.
    """
    fresh = global_norm(grads)              # all-reduce, no consumer here
    eff = jnp.where(state.initialized, state.prev_norm, fresh)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(eff, 1e-9))
    return scale, PipelinedClipState(fresh, jnp.ones((), bool))
