"""Blockwise 8-bit state quantization (Dettmers-style) for optimizer
moments — a distributed-optimization memory trick: Adam m/v in int8 with
fp32 per-block scales cuts optimizer state from 8 to ~2.06 bytes/param,
which is what lets the 671B config fit 256 × 16 GB chips (EXPERIMENTS.md
§Dry-run)."""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

BLOCK = 128


class Q8(NamedTuple):
    codes: jax.Array    # int8, original shape
    scales: jax.Array   # fp32, (*shape[:-1], last_dim // bs)


def _blocksize(x_shape) -> int:
    if not x_shape:
        return 1
    last = x_shape[-1]
    return BLOCK if last % BLOCK == 0 else last

# Blocks run along the LAST dim so the scales tensor keeps the codes'
# leading-dim sharding (the scales are 1/32 the codes' bytes and shard with
# them — never replicated; that matters at 671B scale).


def quantize(x: jax.Array) -> Q8:
    if x.ndim == 0:
        return Q8(jnp.clip(jnp.round(x), -127, 127).astype(jnp.int8),
                  jnp.ones((), jnp.float32))
    bs = _blocksize(x.shape)
    xb = x.astype(jnp.float32).reshape(*x.shape[:-1], x.shape[-1] // bs, bs)
    scale = jnp.max(jnp.abs(xb), axis=-1) / 127.0
    scale = jnp.where(scale == 0, 1.0, scale)
    codes = jnp.clip(jnp.round(xb / scale[..., None]), -127, 127
                     ).astype(jnp.int8)
    return Q8(codes.reshape(x.shape), scale)


def dequantize(q: Q8) -> jax.Array:
    if q.codes.ndim == 0:
        return q.codes.astype(jnp.float32) * q.scales
    bs = _blocksize(q.codes.shape)
    xb = q.codes.astype(jnp.float32).reshape(
        *q.codes.shape[:-1], q.codes.shape[-1] // bs, bs)
    return (xb * q.scales[..., None]).reshape(q.codes.shape)


def zeros_like_q8(x: jax.Array) -> Q8:
    if x.ndim == 0:
        return Q8(jnp.zeros((), jnp.int8), jnp.ones((), jnp.float32))
    bs = _blocksize(x.shape)
    return Q8(jnp.zeros(x.shape, jnp.int8),
              jnp.ones((*x.shape[:-1], x.shape[-1] // bs), jnp.float32))
