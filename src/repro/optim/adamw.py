"""Functional AdamW with optional 8-bit moment states.

States are plain pytrees mirroring the parameter tree, so they shard with
the same PartitionSpecs (ZeRO: optimizer state lives wherever the parameter
shard lives).  ``state_dtype='i8'`` swaps both moments to blockwise int8
(see eightbit.py) — used by the biggest assigned configs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .eightbit import Q8, dequantize, quantize, zeros_like_q8


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    state_dtype: str = "f32"       # f32 | bf16 | i8
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((s - cfg.warmup_steps)
                    / jnp.maximum(cfg.decay_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    mult = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos
    return cfg.lr * jnp.where(s < cfg.warmup_steps, warm, mult)


def _zeros_state(p, cfg: AdamWConfig):
    if cfg.state_dtype == "i8":
        return zeros_like_q8(p)
    dt = jnp.bfloat16 if cfg.state_dtype == "bf16" else jnp.float32
    return jnp.zeros(p.shape, dt)


def adamw_init(params, cfg: AdamWConfig):
    return {
        "m": jax.tree_util.tree_map(lambda p: _zeros_state(p, cfg), params),
        "v": jax.tree_util.tree_map(lambda p: _zeros_state(p, cfg), params),
        "count": jnp.zeros((), jnp.int32),
    }


def _load(s):
    return dequantize(s) if isinstance(s, Q8) else s.astype(jnp.float32)


def _store(x, like):
    if isinstance(like, Q8):
        return quantize(x)
    return x.astype(like.dtype)


def adamw_update(params, grads, state, cfg: AdamWConfig,
                 grad_scale: Optional[jax.Array] = None):
    """One AdamW step.  ``grad_scale`` multiplies gradients (used by the
    pipelined clipper).  Returns (new_params, new_state)."""
    count = state["count"] + 1
    lr = schedule(cfg, count)
    c1 = 1 - cfg.b1 ** count.astype(jnp.float32)
    c2 = 1 - cfg.b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        if grad_scale is not None:
            g = g * grad_scale
        mf = _load(m) * cfg.b1 + (1 - cfg.b1) * g
        vf = _load(v) * cfg.b2 + (1 - cfg.b2) * g * g
        mhat = mf / c1
        vhat = vf / c2
        step = mhat / (jnp.sqrt(vhat) + cfg.eps)
        pf = p.astype(jnp.float32)
        pf = pf - lr * (step + cfg.weight_decay * pf)
        return pf.astype(p.dtype), _store(mf, m), _store(vf, v)

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v)
           for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "count": count}
