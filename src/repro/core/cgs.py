"""CGS — Conjugate Gradient Squared (Sonneveld 1989).

The pre-BiCGStab product-type baseline: applies the BiCG polynomial twice
(r_i = R_i(A)^2 r_0).  Converges erratically (squared residual polynomial
amplifies round-off) — included as the historical baseline the
stabilized family (BiCGStab -> GPBi-CG -> BiCGSafe) improves upon, and as
an extra convergence-comparison row in bench_convergence.
Two reduction phases per iteration.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from ..precond.base import PrecondLike, preconditioned_system
from ._common import init_guess, safe_div, tree_select
from .substrate import SubstrateLike, get_substrate
from .types import (DotReduce, SolveResult, SolverConfig, classify_status,
                    history_init, history_update, identity_reduce)


def cgs_solve(matvec: Callable,
              b: jax.Array,
              x0: Optional[jax.Array] = None,
              *,
              config: SolverConfig = SolverConfig(),
              r0_star: Optional[jax.Array] = None,
              dot_reduce: DotReduce = identity_reduce,
              substrate: SubstrateLike = "jnp",
              precond: PrecondLike = None) -> SolveResult:
    """Solve A x = b with CGS (left-preconditioned when ``precond`` set)."""
    sub = get_substrate(substrate)
    matvec, b = preconditioned_system(sub, matvec, b, precond)
    eps = config.breakdown_threshold(b.dtype)
    x = init_guess(b, x0)
    r0 = b - matvec(x) if x0 is not None else b
    rs = r0 if r0_star is None else r0_star.astype(b.dtype)

    init = dot_reduce(sub.dots([(r0, r0), (rs, r0)]))
    norm_r0 = jnp.sqrt(init[0])
    # ||r_0|| == 0: converge at t=0 instead of dividing by zero.
    conv0 = norm_r0 == 0
    norm_r0 = jnp.where(conv0, jnp.ones_like(norm_r0), norm_r0)
    z0 = jnp.zeros_like(b)
    hist = history_init(config, norm_r0.dtype)

    state = dict(
        x=x, r=r0, p=r0, u=r0, q=z0,
        rho=init[1], rr=init[0],
        i=jnp.zeros((), jnp.int32),
        relres=jnp.where(conv0, 0.0, 1.0).astype(norm_r0.dtype),
        converged=conv0, breakdown=jnp.zeros((), bool),
        hist=hist)

    def cond(st):
        return (~st["converged"]) & (~st["breakdown"]) & (st["i"] < config.maxiter)

    def body(st):
        relres = jnp.sqrt(jnp.abs(st["rr"])) / norm_r0
        done = relres <= config.tol
        hist_i = history_update(st["hist"], st["i"], relres, config)

        p, u, r = st["p"], st["u"], st["r"]
        vp = matvec(p)
        # --- phase 1 ---
        d1 = dot_reduce(sub.dots([(rs, vp)]))
        alpha, bad1 = safe_div(st["rho"], d1[0], eps)
        q = u - alpha * vp
        uq = u + q
        x_next = st["x"] + alpha * uq
        r_next = r - alpha * matvec(uq)
        # --- phase 2 ---
        d2 = dot_reduce(sub.dots([(rs, r_next), (r_next, r_next)]))
        rho_next = d2[0]
        beta, bad2 = safe_div(rho_next, st["rho"], eps)
        u_next = r_next + beta * q
        p_next = u_next + beta * (q + beta * p)

        bad = bad1 | bad2
        new = dict(
            x=x_next, r=r_next, p=p_next, u=u_next, q=q,
            rho=rho_next, rr=d2[1],
            i=st["i"] + 1, relres=relres,
            converged=jnp.zeros((), bool), breakdown=bad,
            hist=hist_i)
        stopped = dict(st)
        stopped.update(relres=relres, converged=done, hist=hist_i)
        return tree_select(done, stopped, new)

    st = jax.lax.while_loop(cond, body, state)
    final_relres = jnp.where(st["converged"], st["relres"],
                             jnp.sqrt(jnp.abs(st["rr"])) / norm_r0)
    converged = st["converged"] | (final_relres <= config.tol)
    return SolveResult(st["x"], st["i"], final_relres, converged,
                       st["breakdown"], st["hist"],
                       classify_status(converged, st["breakdown"],
                                       final_relres))
