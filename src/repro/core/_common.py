"""Shared machinery for the Krylov solvers.

The central abstraction is the *fused dot phase*: every solver computes all
inner products of one synchronization phase as a single stacked vector of
local partial sums and calls ``dot_reduce`` exactly once on it.  Standalone,
``dot_reduce`` is the identity (the dots are already global); inside the
``shard_map``-distributed driver it is a single ``lax.psum`` — one global
reduction per phase, which is the paper's communication model.  The number
of ``dot_reduce`` calls per iteration therefore *is* the solver's
synchronization count (1 for ssBiCGSafe2/p-BiCGSafe, 2 for BiCGStab and
p-BiCGStab, 3 for GPBi-CG), and tests assert it.

*Who computes the partials* is pluggable: the solvers obtain their local
partial sums (and the Alg. 3.1 vector-update phase) from a compute
substrate (:mod:`repro.core.substrate`) — ``"jnp"`` produces them with the
plain jnp ops below, ``"pallas"`` with the fused one-HBM-pass kernels in
:mod:`repro.kernels`.  Either way the stacked-partials contract is
identical, so ``dot_reduce`` semantics and the synchronization counts are
substrate-independent.

Multi-RHS: every helper here is column-batched.  ``local_dots`` accepts
``(n, m)`` operand blocks and yields ``(k, m)`` stacked partials (one
column of dots per right-hand side, still one reduction), and
``bicgsafe_coefficients`` broadcasts elementwise over trailing RHS axes —
this is what :func:`repro.core.multirhs.solve_batched` runs on.

Supported path matrix (every cell runs the SAME iteration body; the
substrate picks who computes the vector phases, the driver picks where):

====================  =======================  ==========================
scenario              ``substrate="jnp"``      ``substrate="pallas"``
====================  =======================  ==========================
single RHS            inline jnp ops           fused_dots / fused_axpy /
                                               banded spmv_ell kernels
batched (n, m)        jnp broadcasting         (n, m) block kernels:
                                               fused_dots_batched,
                                               fused_axpy_batched (with
                                               the per-column convergence
                                               mask in-kernel), block-ELL
                                               spmv_ell_batched
distributed           per-shard jnp + 1 psum   per-shard kernels + 1 psum
batched+distributed   row-sharded (n, m),      row-sharded block kernels,
                      1 psum of (9, m)/iter    1 psum of (9, m)/iter
====================  =======================  ==========================

(``distributed_stencil_solve`` / ``distributed_stencil_solve_batched`` in
:mod:`repro.core.distributed`; the single psum per iteration and its
independence from the in-flight matvec hold in every cell — asserted in
tests/test_substrate_parity.py, tests/_distributed_check.py and
benchmarks/bench_overlap.py.)

The batched row is also exposed open-loop — ``multirhs.init_state`` /
``step_chunk`` / ``splice_columns`` — which is what the
continuous-batching solve service (:mod:`repro.service`) drives: one
resident (n, max_batch) block per operator, heterogeneous requests
multiplexed onto its columns, same single (9, m) reduction and overlap
structure per iteration (asserted on the engine's step program in
tests/test_service.py).

Preconditioning (the ``precond=`` column of every cell above; see
:mod:`repro.precond`) — how each M^{-1}-apply executes per substrate,
and its distributed locality:

==============  ==========================  =======================  ============
preconditioner  ``substrate="jnp"``         ``substrate="pallas"``   distributed
==============  ==========================  =======================  ============
jacobi          elementwise jnp (fused      same (no kernel needed)  exact,
                by XLA)                                              shard-local
block_jacobi    batched jnp einsum          Pallas batched           exact,
                                            block-apply kernel       shard-local
                                            (shared-block case:
                                            one MXU matmul)
neumann         jnp matvec series           series on the Pallas     shard-local
                                            SpMV / block-ELL         (additive-
                                            kernels (banded ELL)     Schwarz)
ssor            stencil shifts (jnp,        same jnp body (no        shard-local
                XLA-fused)                  dedicated kernel)        (additive-
                                                                     Schwarz)
==============  ==========================  =======================  ============

Every apply is shape-polymorphic over ``(n,)`` / ``(n, m)`` operands,
contains no inner products (the dot_reduce/psum counts above are
precond-independent), and — composed as ``M^{-1} ∘ A`` — sits inside the
pipelined solvers' overlap window, so the single reduction keeps no
dependency edge to the in-flight precond+matvec (asserted in
tests/test_substrate_parity.py and benchmarks/_overlap_child.py).
"""
from __future__ import annotations

from typing import Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from .types import SolverConfig


def local_dots(pairs: Sequence[Tuple[jax.Array, jax.Array]],
               dtype=None) -> jax.Array:
    """Stack partial inner products <a,b> for each pair into one vector.

    On a sharded vector this yields the *local* partial sums; a single
    reduction of the stacked vector produces every global inner product of
    the phase at once (8 scalars -> one 8-word message, as in the paper).

    ``(n, m)`` multi-RHS operands produce a ``(len(pairs), m)`` block of
    per-column dots — the same single reduction then serves all m systems.
    """
    outs = []
    for a, b in pairs:
        if a.ndim == 2:
            acc = jnp.sum(a * b, axis=0, dtype=dtype)
        elif dtype is not None:
            acc = jnp.sum(a * b, dtype=dtype)
        else:
            acc = jnp.vdot(a, b)
        outs.append(acc)
    return jnp.stack(outs)


def safe_div(num: jax.Array, den: jax.Array, eps: float):
    """num/den with breakdown detection: returns (value, is_breakdown)."""
    bad = jnp.abs(den) <= eps
    val = num / jnp.where(bad, jnp.ones_like(den), den)
    return jnp.where(bad, jnp.zeros_like(val), val), bad


def init_guess(b: jax.Array, x0: Optional[jax.Array]) -> jax.Array:
    return jnp.zeros_like(b) if x0 is None else x0.astype(b.dtype)


def tree_select(pred, on_true, on_false):
    """Elementwise select over matching pytrees (pred is a scalar bool)."""
    return jax.tree_util.tree_map(
        lambda a, b: jnp.where(pred, a, b), on_true, on_false)


def bicgsafe_coefficients(dots: jax.Array, i: jax.Array,
                          alpha_prev, zeta_prev, f_prev, eps: float):
    """Coefficients shared by ssBiCGSafe2 (Alg 2.3) and p-BiCGSafe (Alg 3.1).

    ``dots = [a, b, c, d, e, f, g, h, rr]`` with
      a=(s,s) b=(y,y) c=(s,y) d=(s,r) e=(y,r)
      f=(r0*,r) g=(r0*,s) h=(r0*,t_{i-1}) rr=(r,r).

    i = 0:  beta=0, alpha=f/g, zeta=d/a, eta=0          (paper lines 10-14)
    i > 0:  beta=(alpha_{i-1} f)/(zeta_{i-1} f_{i-1}),
            alpha=f/(g + beta h),
            zeta=(b d - c e)/(a b - c^2),
            eta =(a e - c d)/(a b - c^2)                (paper lines 16-20)

    Returns (beta, alpha, zeta, eta, f, rr, breakdown).
    """
    a, b, c, d, e, f, g, h, rr = (dots[k] for k in range(9))
    first = i == 0

    beta_g, bad_beta = safe_div(alpha_prev * f, zeta_prev * f_prev, eps)
    beta = jnp.where(first, jnp.zeros_like(f), beta_g)

    alpha, bad_alpha = safe_div(f, g + beta * h, eps)

    zeta0, bad_z0 = safe_div(d, a, eps)
    denom = a * b - c * c
    zeta_g, bad_zg = safe_div(b * d - c * e, denom, eps)
    eta_g, _ = safe_div(a * e - c * d, denom, eps)
    zeta = jnp.where(first, zeta0, zeta_g)
    eta = jnp.where(first, jnp.zeros_like(f), eta_g)

    breakdown = jnp.where(
        first, bad_z0 | bad_alpha,
        bad_beta | bad_alpha | bad_zg)
    return beta, alpha, zeta, eta, f, rr, breakdown


def bicgsafe_breakdown_code(dots: jax.Array, i: jax.Array,
                            alpha_prev, zeta_prev, f_prev,
                            eps: float) -> jax.Array:
    """Typed cause of a BiCGSafe coefficient breakdown, as an int32
    :class:`repro.core.types.SolveStatus` code (0 == no breakdown).

    Recomputes the same three denominators :func:`bicgsafe_coefficients`
    guards with ``safe_div`` (XLA CSEs the shared subexpressions, so this
    adds a handful of scalar compares, no vector work) and names the
    first offender in precedence order rho -> alpha -> omega, matching
    the ``breakdown`` flag's ``first``/``i>0`` gating exactly:

    * BREAKDOWN_RHO:   beta denominator ``zeta_{i-1} * f_{i-1}`` (i > 0)
    * BREAKDOWN_ALPHA: alpha denominator ``g + beta * h`` (incl. the
      i == 0 pivot ``(s,s)`` of ``zeta_0 = d/a``)
    * BREAKDOWN_OMEGA: zeta/eta denominator ``a*b - c^2`` (i > 0)
    """
    from .types import SolveStatus
    a, b, c, d, e, f, g, h, rr = (dots[k] for k in range(9))
    del d, e, rr
    first = i == 0

    bad_rho = (~first) & (jnp.abs(zeta_prev * f_prev) <= eps)
    beta_g, _ = safe_div(alpha_prev * f, zeta_prev * f_prev, eps)
    beta = jnp.where(first, jnp.zeros_like(f), beta_g)
    bad_alpha = jnp.abs(g + beta * h) <= eps
    bad_pivot = jnp.where(first, jnp.abs(a) <= eps,
                          jnp.abs(a * b - c * c) <= eps)

    code = jnp.where(bad_pivot, SolveStatus.BREAKDOWN_OMEGA.value, 0)
    code = jnp.where(first & bad_pivot, SolveStatus.BREAKDOWN_ALPHA.value,
                     code)
    code = jnp.where(bad_alpha, SolveStatus.BREAKDOWN_ALPHA.value, code)
    code = jnp.where(bad_rho, SolveStatus.BREAKDOWN_RHO.value, code)
    return code.astype(jnp.int32)


def pipelined_recurrence_tail(q, s, As, g, Aw, alpha, zeta, eta):
    """p-BiCGSafe's recurred A-images after MV #2 (Aw = A w_i).

    Returns (l, g_next, s_next) per Eqns. 3.7 / 3.10 / 3.2:
    l_i == A t_i, g_{i+1} == A y_{i+1}, s_{i+1} == A r_{i+1}.
    Shared by the single-RHS solver and the batched multi-RHS solver
    (scalars may be () or (m,); (m,) broadcasts over (n, m) blocks).
    """
    l = q - Aw
    g_next = zeta * As + eta * g - alpha * Aw
    s_next = s - alpha * q - g_next
    return l, g_next, s_next


class SyncCounter:
    """Trace-time counter of dot_reduce invocations (sync phases/iter)."""

    def __init__(self, reduce_fn):
        self._fn = reduce_fn
        self.calls = 0

    def __call__(self, partials):
        self.calls += 1
        return self._fn(partials)
