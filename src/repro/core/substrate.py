"""Compute substrate: pluggable backend for the solver hot loop.

The per-iteration critical path of every solver in :mod:`repro.core` is
made of three primitive phases:

* ``dots(pairs)``       — stacked local partial inner products (the fused
                          synchronization phase; one ``dot_reduce`` per call),
* ``axpy_phase(...)``   — the blocked vector-update phase,
* ``as_matvec(op)``     — operator -> matvec dispatch (SpMV).

A :class:`Substrate` bundles one implementation of each, so the iteration
bodies are written once against the abstraction and run unchanged on

* ``"jnp"``     — the reference implementation (plain jnp ops; what the
                  solvers inlined historically).  XLA fuses what it can, but
                  the 9-dot phase lowers to 9 separate reductions reading 18
                  operand streams and the Alg. 3.1 update phase to ~10
                  unfused AXPYs.
* ``"pallas"``  — the hand-tiled kernels in :mod:`repro.kernels`: one HBM
                  pass for the 9-dot phase (``fused_dots``), one for the
                  whole vector-update phase (``fused_axpy``), and the banded
                  ELL SpMV (``spmv_ell``).  On TPU these are the compiled
                  Mosaic kernels; elsewhere the same kernel bodies run in
                  interpret mode, so CI exercises them without hardware.

Both substrates keep the solver's communication structure byte-identical:
the fused dot phase still reads only ``{s, y, r, t_prev, rs}`` (no edge to
the in-flight matvec — the paper's overlap property, asserted structurally
in tests/test_substrate_parity.py) and is reduced by the solver's single
``dot_reduce``/``psum``.  Multi-RHS blocks ``(n, m)`` flow through the same
methods and produce ``(k, m)`` partial blocks — still ONE reduction.

Every phase is column-batched on BOTH substrates: ``bicgsafe_dots``
accepts ``(n, m)`` blocks (-> ``(9, m)`` partials), ``axpy_phase`` streams
``(n, m)`` tiles with per-column ``(m,)`` coefficients and an optional
per-column convergence ``mask`` (applied in-kernel on the pallas
substrate), and :meth:`Substrate.as_block_matvec` lifts an operator to
``(n, m) -> (n, m)`` column blocks — for banded ELL operators on the
pallas substrate this is the block-ELL kernel, which reads the matrix
tiles once for all m columns instead of m times.  ``solve_batched`` runs
its entire hot loop through these, so single, batched, distributed, and
batched+distributed solves all execute the same kernel bodies.

Use ``substrate="pallas"`` (or a :class:`Substrate` instance) on any solver
entry point; resolve names with :func:`get_substrate`.
"""
from __future__ import annotations

import functools
from typing import Sequence, Tuple, Union

import jax
import jax.numpy as jnp

from . import linear_operator
from ._common import local_dots

BICGSAFE_DOT_PAIRS = (
    ("s", "s"), ("y", "y"), ("s", "y"), ("s", "r"), ("y", "r"),
    ("rs", "r"), ("rs", "s"), ("rs", "t"), ("r", "r"))


class Substrate:
    """Strategy object for the solver hot-loop phases.

    Subclasses provide the three primitives; solvers never touch jnp or the
    Pallas kernels directly for these phases.
    """

    name = "abstract"
    #: True when the substrate executes the hand-tiled Pallas kernels —
    #: preconditioners consult this in ``bind`` to pick their kernel path.
    kernel_backed = False

    def dots(self, pairs: Sequence[Tuple[jax.Array, jax.Array]]) -> jax.Array:
        """Stacked local partials <a,b> per pair: (k,) or (k, m) batched."""
        raise NotImplementedError

    def bicgsafe_dots(self, s, y, r, t_prev, rs) -> jax.Array:
        """The 9-dot fused phase of ssBiCGSafe2/p-BiCGSafe.

        Reads ONLY {s, y, r, t_prev, rs} so it carries no dependency edge
        to the iteration's in-flight matvec (the overlap invariant).
        Returns (9,) local partials, or (9, m) for (n, m) multi-RHS blocks.
        """
        raise NotImplementedError

    def bicgsafe_dots_health(self, s, y, r, t_prev, rs, x) -> jax.Array:
        """Guarded fused phase: the 9 dots plus 2 in-reduction health rows.

        Row 9 is ``x.x`` (solution-norm estimate for the drift bound),
        row 10 a NaN/Inf finiteness probe ``sum(s+y+t_prev+rs+x)``.  ``x``
        is the previous iterate (loop-carried), so the phase STILL has no
        dependency edge to the in-flight matvec, and the whole (11,) /
        (11, m) block is reduced by the solver's same single
        ``dot_reduce`` — health monitoring costs zero extra reductions.
        """
        raise NotImplementedError

    def axpy_phase(self, vecs: dict, scalars, mask=None) -> dict:
        """p-BiCGSafe's blocked vector-update phase (Alg. 3.1 lines 23-32).

        vecs: dict with r,p,u,t,y,z,s,l,g,w,x,As; scalars: (alpha, beta,
        zeta, eta).  Returns dict with the primed p,o,u,q,w,t,z,y,x,r.

        Multi-RHS: ``(n, m)`` blocks with ``(m,)`` per-column scalars, and
        an optional ``(m,)`` bool ``mask`` — frozen (mask=False) columns
        keep their input values for every output with same-named state.
        """
        raise NotImplementedError

    def as_matvec(self, op):
        """Operator / matrix / callable -> matvec callable."""
        return linear_operator.as_matvec(op)

    def as_block_matvec(self, op):
        """Operator -> column-blocked matvec ``(n, m) -> (n, m)``.

        Default: vmap the single-vector matvec over columns
        (:func:`repro.core.multirhs.batched_matvec` — the canonical
        lift).  Substrates with a dedicated block SpMV kernel override
        this so the matrix is streamed once for all m right-hand sides.
        """
        from .multirhs import batched_matvec   # lazy: multirhs imports us
        return batched_matvec(self.as_matvec(op))

    def as_precond_apply(self, pc):
        """Preconditioner -> substrate-routed M^{-1}-apply callable.

        Delegates to ``pc.bind(self)`` so kernel dispatch lives with each
        preconditioner class (:mod:`repro.precond`): block-Jacobi binds
        the Pallas batched block-apply kernel on kernel-backed substrates,
        Neumann builds its series on this substrate's (block) matvec, and
        elementwise/shift applies stay jnp (XLA fuses them).  The bound
        apply is shape-polymorphic over ``(n,)`` / ``(n, m)`` operands
        and contains NO inner products — preconditioning never changes
        the solver's ``dot_reduce`` count.
        """
        return pc.bind(self)

    def __repr__(self):
        return f"<{type(self).__name__} {self.name!r}>"


class JnpSubstrate(Substrate):
    """Reference substrate: the historical inline-jnp hot loop."""

    name = "jnp"

    def dots(self, pairs):
        return local_dots(pairs)

    def bicgsafe_dots(self, s, y, r, t_prev, rs):
        v = dict(s=s, y=y, r=r, t=t_prev, rs=rs)
        return local_dots([(v[a], v[b]) for a, b in BICGSAFE_DOT_PAIRS])

    def bicgsafe_dots_health(self, s, y, r, t_prev, rs, x):
        v = dict(s=s, y=y, r=r, t=t_prev, rs=rs)
        base = local_dots(
            [(v[a], v[b]) for a, b in BICGSAFE_DOT_PAIRS] + [(x, x)])
        comb = s + y + t_prev + rs + x
        probe = jnp.sum(comb, axis=0) if comb.ndim == 2 else jnp.sum(comb)
        return jnp.concatenate([base, probe[None]])

    def axpy_phase(self, vecs, scalars, mask=None):
        from repro.kernels import ref
        return ref.fused_axpy(vecs, scalars, mask=mask)


class PallasSubstrate(Substrate):
    """Pallas-kernel substrate (compiled on TPU, interpret mode elsewhere).

    The 9-dot phase and the vector-update phase each become one fused
    kernel pass; ELL operators with a banded structure dispatch to the
    Pallas SpMV.  Phases with no dedicated kernel (the 1-5 dot phases of
    the BiCGStab/GPBi-CG family) fall back to the jnp reference — they are
    not the paper's hot path.
    """

    name = "pallas"
    kernel_backed = True

    def dots(self, pairs):
        return local_dots(pairs)

    def bicgsafe_dots(self, s, y, r, t_prev, rs):
        from repro.kernels import ops
        return ops.fused_dots(s, y, r, t_prev, rs)

    def bicgsafe_dots_health(self, s, y, r, t_prev, rs, x):
        from repro.kernels import ops
        return ops.fused_dots_health(s, y, r, t_prev, rs, x)

    def axpy_phase(self, vecs, scalars, mask=None):
        from repro.kernels import ops
        return ops.fused_axpy(vecs, scalars, mask=mask)

    def as_matvec(self, op):
        from repro.kernels import ops
        if isinstance(op, linear_operator.ELLOperator) \
                and ops.ell_is_banded(op):
            return functools.partial(ops.spmv_ell, op)
        return linear_operator.as_matvec(op)

    def as_block_matvec(self, op):
        from repro.kernels import ops
        if isinstance(op, linear_operator.ELLOperator) \
                and ops.ell_is_banded(op):
            # ops.spmv_ell handles (n, m) via the block kernel directly —
            # NOT a vmap of the 1-D kernel, which would re-read values/cols
            # once per column
            return functools.partial(ops.spmv_ell, op)
        return super().as_block_matvec(op)


SUBSTRATES = {
    "jnp": JnpSubstrate(),
    "pallas": PallasSubstrate(),
}

SubstrateLike = Union[str, Substrate, None]


def get_substrate(spec: SubstrateLike) -> Substrate:
    """Resolve a substrate name / instance / None (-> ``"jnp"``)."""
    if spec is None:
        return SUBSTRATES["jnp"]
    if isinstance(spec, Substrate):
        return spec
    try:
        return SUBSTRATES[spec]
    except KeyError:
        raise ValueError(
            f"unknown substrate {spec!r}; expected one of "
            f"{sorted(SUBSTRATES)} or a Substrate instance") from None
