"""ssBiCGSafe2 — single-synchronization BiCGSafe (paper Alg. 2.3, Fujino).

The non-pipelined baseline: one global-reduction phase per iteration, but
the inner products *depend* on the fresh matvec ``s_i = A r_i``, so the
reduction cannot overlap with it.  Two matvecs per iteration
(``A r_i``, ``A u_i``), 9 fused inner products, 10 vectors.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from ..precond.base import PrecondLike, preconditioned_system
from ._common import bicgsafe_coefficients, init_guess, tree_select
from .substrate import SubstrateLike, get_substrate
from .types import (DotReduce, SolveResult, SolverConfig, classify_status,
                    history_init, history_update, identity_reduce,
                    trace_init)


def ssbicgsafe2_solve(matvec: Callable,
                      b: jax.Array,
                      x0: Optional[jax.Array] = None,
                      *,
                      config: SolverConfig = SolverConfig(),
                      r0_star: Optional[jax.Array] = None,
                      dot_reduce: DotReduce = identity_reduce,
                      substrate: SubstrateLike = "jnp",
                      precond: PrecondLike = None) -> SolveResult:
    """Solve A x = b with ssBiCGSafe2 (Alg. 2.3; left-preconditioned when
    ``precond`` is set)."""
    sub = get_substrate(substrate)
    matvec, b = preconditioned_system(sub, matvec, b, precond)
    eps = config.breakdown_threshold(b.dtype)
    x = init_guess(b, x0)
    r0 = b - matvec(x) if x0 is not None else b
    rs = r0 if r0_star is None else r0_star.astype(b.dtype)

    norm_r0_sq = dot_reduce(sub.dots([(r0, r0)]))[0]
    norm_r0 = jnp.sqrt(norm_r0_sq)
    # ||r_0|| == 0: converge at t=0 instead of dividing by zero.
    conv0 = norm_r0 == 0
    norm_r0 = jnp.where(conv0, jnp.ones_like(norm_r0), norm_r0)
    z0 = jnp.zeros_like(b)
    hist = history_init(config, norm_r0.dtype)
    hist = history_update(hist, 0, jnp.ones_like(norm_r0), config)

    one = jnp.ones((), b.dtype)
    zero = jnp.zeros((), b.dtype)
    state = dict(
        x=x, r=r0, p=z0, u=z0, t=z0, y=z0, z=z0,
        alpha=zero, zeta=one, f=one,
        i=jnp.zeros((), jnp.int32),
        relres=jnp.where(conv0, 0.0, 1.0).astype(norm_r0.dtype),
        converged=conv0, breakdown=jnp.zeros((), bool),
        hist=hist)
    if config.trace_cap:
        state["trace"] = trace_init(config, norm_r0.dtype)
        state["trace_steps"] = jnp.zeros((), jnp.int32)

    def cond(st):
        return (~st["converged"]) & (~st["breakdown"]) & (st["i"] < config.maxiter)

    def body(st):
        r, y, t_prev = st["r"], st["y"], st["t"]
        # named scopes tag the HLO op metadata for the runtime profiler
        # (repro.observe.profile); no ops are emitted, math is unchanged.
        with jax.named_scope("repro.matvec"):
            s = matvec(r)                               # MV #1: s_i = A r_i
        # --- single fused reduction phase (depends on s -> no overlap) ---
        with jax.named_scope("repro.reduce"):
            dots = dot_reduce(sub.bicgsafe_dots(s, y, r, t_prev, rs))
        beta, alpha, zeta, eta, f, rr, bad = bicgsafe_coefficients(
            dots, st["i"], st["alpha"], st["zeta"], st["f"], eps)
        relres = jnp.sqrt(jnp.abs(rr)) / norm_r0
        done = relres <= config.tol

        # --- vector updates (paper lines 23-30) ---
        with jax.named_scope("repro.axpy"):
            p = r + beta * (st["p"] - st["u"])
            o = s + beta * t_prev
            u = zeta * o + eta * (y + beta * st["u"])
        with jax.named_scope("repro.matvec"):
            w = matvec(u)                               # MV #2: w_i = A u_i
        with jax.named_scope("repro.axpy"):
            t = o - w
            z = zeta * r + eta * st["z"] - alpha * u
            y_next = zeta * s + eta * y - alpha * w
            x_next = st["x"] + alpha * p + z
            r_next = r - alpha * o - y_next

        hist_i = history_update(st["hist"], st["i"], relres, config)
        new = dict(
            x=x_next, r=r_next, p=p, u=u, t=t, y=y_next, z=z,
            alpha=alpha, zeta=zeta, f=f,
            i=st["i"] + 1, relres=relres,
            converged=jnp.zeros((), bool), breakdown=jnp.zeros((), bool),
            hist=hist_i)
        stopped = dict(st)
        stopped.update(relres=relres, converged=done, breakdown=bad & ~done,
                       hist=hist_i)
        if config.trace_cap:
            from .pipelined_bicgsafe import _trace_row
            trace_i = _trace_row(st, dots, beta, relres, done, bad, config)
            new["trace"] = stopped["trace"] = trace_i
            new["trace_steps"] = stopped["trace_steps"] = \
                st["trace_steps"] + 1
        return tree_select(done | bad, stopped, new)

    st = jax.lax.while_loop(cond, body, state)
    trace = {"buffer": st["trace"], "steps": st["trace_steps"]} \
        if config.trace_cap else None
    return SolveResult(st["x"], st["i"], st["relres"], st["converged"],
                       st["breakdown"], st["hist"],
                       classify_status(st["converged"], st["breakdown"],
                                       st["relres"]), trace)
