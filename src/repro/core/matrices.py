"""Synthetic test-problem generators.

The paper evaluates on SuiteSparse matrices (unreachable offline); these
generators produce matrices of the same *kinds* (paper Table 5.1 "kind"
column) and difficulty spread:

* :func:`poisson3d`            — SPD 7-point Laplacian            (≈ poisson3Db)
* :func:`convection_diffusion` — non-symmetric fluid dynamics     (≈ atmosmodd)
* :func:`anisotropic3d`        — badly scaled SPD                 (≈ s3dkq4m2)
* :func:`random_nonsym`        — generic non-symmetric sparse     (≈ xenon2 etc.)
* :func:`hard_nonsym`          — ill-conditioned non-symmetric; drives the
  recurred residual of p-BiCGSafe into stagnation so that p-BiCGSafe-rr is
  needed (≈ sherman3 / utm5940, paper §5.2).

Every generator returns ``(operator, b, x_true)`` with the right-hand side
built so the exact solution is the all-ones vector (paper §5 protocol).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .linear_operator import (CSROperator, DenseOperator, ELLOperator,
                              Stencil7Operator)


def _with_unit_solution(op) -> Tuple[object, jax.Array, jax.Array]:
    x_true = jnp.ones((op.shape[0],), dtype=op.dtype)
    b = op.matvec(x_true)
    return op, b, x_true


def poisson3d(nx: int = 16, ny: Optional[int] = None, nz: Optional[int] = None,
              dtype=jnp.float64):
    """SPD 7-point Laplacian on an nx×ny×nz grid (Dirichlet)."""
    ny = ny or nx
    nz = nz or nx
    c = jnp.array([6.0, -1.0, -1.0, -1.0, -1.0, -1.0, -1.0], dtype=dtype)
    return _with_unit_solution(Stencil7Operator(c, nx, ny, nz))


def convection_diffusion(nx: int = 16, ny: Optional[int] = None,
                         nz: Optional[int] = None, peclet: float = 0.5,
                         dtype=jnp.float64):
    """Non-symmetric convection-diffusion (upwinded convection in x and y).

    ``peclet`` controls the skew: 0 → symmetric Laplacian, larger → more
    non-normal.  The paper's dominant matrix kind (fluid dynamics).
    """
    ny = ny or nx
    nz = nz or nx
    px, py = peclet, 0.5 * peclet
    c = jnp.array([
        6.0 + px + py,
        -1.0 - px, -1.0,           # x- (upwind heavier), x+
        -1.0 - py, -1.0,           # y-, y+
        -1.0, -1.0,                # z-, z+
    ], dtype=dtype)
    return _with_unit_solution(Stencil7Operator(c, nx, ny, nz))


def anisotropic3d(nx: int = 16, ny: Optional[int] = None,
                  nz: Optional[int] = None, eps: float = 1e-3,
                  dtype=jnp.float64):
    """SPD but badly scaled: strong coupling in x, weak (eps) in y/z."""
    ny = ny or nx
    nz = nz or nx
    c = jnp.array([2.0 + 4.0 * eps, -1.0, -1.0, -eps, -eps, -eps, -eps],
                  dtype=dtype)
    return _with_unit_solution(Stencil7Operator(c, nx, ny, nz))


def random_nonsym(n: int = 2000, nnz_per_row: int = 8, seed: int = 0,
                  diag_dominance: float = 1.2, dtype=np.float64,
                  fmt: str = "csr"):
    """Random sparse non-symmetric matrix, row-wise diagonally dominant.

    ``diag_dominance > 1`` guarantees solvability; values near 1 make the
    problem harder (more iterations), matching the paper's mid-range
    matrices.
    """
    rng = np.random.default_rng(seed)
    k = nnz_per_row - 1  # off-diagonals per row
    cols = rng.integers(0, n, size=(n, k), dtype=np.int64)
    vals = rng.standard_normal((n, k)).astype(dtype)
    # remove accidental diagonal hits
    row = np.arange(n)[:, None]
    vals = np.where(cols == row, 0.0, vals)
    diag = diag_dominance * np.abs(vals).sum(axis=1) + 1e-3

    data = np.concatenate([diag[:, None], vals], axis=1).reshape(-1)
    indices = np.concatenate([row, cols], axis=1).reshape(-1).astype(np.int32)
    row_ids = np.repeat(np.arange(n, dtype=np.int32), nnz_per_row)
    op = CSROperator(jnp.asarray(data), jnp.asarray(indices),
                     jnp.asarray(row_ids), n)
    if fmt == "ell":
        op = ELLOperator.from_csr(op)
    return _with_unit_solution(op)


def hard_nonsym(n: int = 1500, seed: int = 3, scale_range: float = 8.0,
                dtype=np.float64):
    """Ill-conditioned non-symmetric matrix (paper §5.2 regime).

    Tridiagonal-plus-random structure with log-uniform row scaling over
    ``10**±(scale_range/2)`` — condition number ~10**scale_range.  In fp64
    the pipelined recurrences of p-BiCGSafe drift and stagnate above the
    1e-8 tolerance on this family, while ssBiCGSafe2 converges; residual
    replacement recovers convergence (paper Fig. 5.2).
    """
    rng = np.random.default_rng(seed)
    scales = 10.0 ** rng.uniform(-scale_range / 2, scale_range / 2, size=n)
    a = np.zeros((n, n), dtype=dtype)
    idx = np.arange(n)
    a[idx, idx] = 2.5
    a[idx[:-1], idx[:-1] + 1] = -1.0 + 0.3 * rng.standard_normal(n - 1)
    a[idx[1:], idx[1:] - 1] = -1.2 + 0.3 * rng.standard_normal(n - 1)
    # sparse long-range couplings
    nnz_extra = 4 * n
    ri = rng.integers(0, n, nnz_extra)
    ci = rng.integers(0, n, nnz_extra)
    a[ri, ci] += 0.2 * rng.standard_normal(nnz_extra)
    a = a * scales[:, None]
    return _with_unit_solution(DenseOperator(jnp.asarray(a)))


def spd_dense(n: int = 200, seed: int = 0, cond: float = 1e4,
              dtype=np.float64):
    """Small dense SPD matrix with prescribed condition number (tests)."""
    rng = np.random.default_rng(seed)
    q, _ = np.linalg.qr(rng.standard_normal((n, n)))
    eigs = np.logspace(0, np.log10(cond), n)
    a = (q * eigs) @ q.T
    return _with_unit_solution(DenseOperator(jnp.asarray(a.astype(dtype))))


def nonsym_dense(n: int = 200, seed: int = 1, skew: float = 0.4,
                 dtype=np.float64):
    """Small dense non-symmetric, well-conditioned (tests)."""
    rng = np.random.default_rng(seed)
    s = rng.standard_normal((n, n)) / np.sqrt(n)
    a = np.eye(n) * 2.0 + 0.5 * (s + s.T) + skew * (s - s.T)
    return _with_unit_solution(DenseOperator(jnp.asarray(a.astype(dtype))))
