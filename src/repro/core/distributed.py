"""Distributed solver runtime: shard_map + halo exchange + single psum.

Row-block domain decomposition over an arbitrary JAX mesh.  The grid's
x-dimension is sharded over *all* mesh axes (flattened, row-major); halo
exchange is a nearest-neighbour ``ppermute`` on the flattened logical ring,
implemented recursively so it works on 1-, 2- ((data, model)) and
3-axis ((pod, data, model)) production meshes — the wrap slab cascades to
the next outer axis exactly like a carry.

The inner-product phases of the solvers call ``dot_reduce`` once per phase;
here that is **one ``lax.psum`` of the stacked partials over the whole
mesh** — the paper's single global reduction.  Because p-BiCGSafe's dots do
not consume the in-flight matvec, the lowered HLO contains no dependency
path from that all-reduce to the halo ppermutes / stencil compute, which is
what lets the XLA latency-hiding scheduler overlap them (verified
structurally in benchmarks/bench_overlap.py).
"""
from __future__ import annotations

import functools
from typing import Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from . import compat
from .linear_operator import Stencil7Operator
from .types import SolveResult, SolverConfig


# ---------------------------------------------------------------------------
# flattened-ring halo exchange
# ---------------------------------------------------------------------------

def _axis_sizes(mesh: Mesh, axes: Sequence[str]) -> Tuple[int, ...]:
    return tuple(mesh.shape[a] for a in axes)


def ring_shift(x: jax.Array, axes: Sequence[str], sizes: Sequence[int],
               forward: bool) -> jax.Array:
    """Shift ``x`` by one position along the flattened (row-major) mesh ring.

    ``forward`` sends to linear index +1 (receiver gets its left/lower
    neighbour's slab); missing senders at the global boundary yield zeros
    (Dirichlet).  Recursion: a within-axis shift on the innermost axis plus
    a wrap term that moves the last-position slab to position 0 and then
    ring-shifts it along the remaining outer axes.
    """
    axis, size = axes[-1], sizes[-1]
    if forward:
        within_perm = [(i, i + 1) for i in range(size - 1)]
        wrap_perm = [(size - 1, 0)]
    else:
        within_perm = [(i + 1, i) for i in range(size - 1)]
        wrap_perm = [(0, size - 1)]

    within = lax.ppermute(x, axis, within_perm) if within_perm else \
        jnp.zeros_like(x)
    if len(axes) == 1:
        return within
    wrap = lax.ppermute(x, axis, wrap_perm)
    wrap = ring_shift(wrap, axes[:-1], sizes[:-1], forward)
    return within + wrap


def halo_stencil_matvec(c: jax.Array, u_flat: jax.Array,
                        local_shape: Tuple[int, int, int],
                        axes: Sequence[str], sizes: Sequence[int]) -> jax.Array:
    """7-point stencil matvec on the local x-slab with ring halo exchange.

    Communication: two 1-slab ppermute cascades (up & down neighbours) of
    ny*nz elements each — the O(surface) cost that the paper's SpMV hides
    the O(1) reduction message behind.
    """
    nxl, ny, nz = local_shape
    u = u_flat.reshape(nxl, ny, nz)

    # x-direction halos from the flattened ring
    top = u[-1:]      # sent forward: becomes receiver's u[x-1] slab
    bot = u[:1]       # sent backward: becomes receiver's u[x+1] slab
    halo_lo = ring_shift(top, axes, sizes, forward=True)    # u[i-1] at i=0
    halo_hi = ring_shift(bot, axes, sizes, forward=False)   # u[i+1] at i=nxl-1

    um = jnp.concatenate([halo_lo, u[:-1]], axis=0)
    up = jnp.concatenate([u[1:], halo_hi], axis=0)
    zy = jnp.zeros_like(u[:, :1])
    vm = jnp.concatenate([zy, u[:, :-1]], axis=1)
    vp = jnp.concatenate([u[:, 1:], zy], axis=1)
    zz = jnp.zeros_like(u[:, :, :1])
    wm = jnp.concatenate([zz, u[:, :, :-1]], axis=2)
    wp = jnp.concatenate([u[:, :, 1:], zz], axis=2)

    out = (c[0] * u + c[1] * um + c[2] * up + c[3] * vm + c[4] * vp
           + c[5] * wm + c[6] * wp)
    return out.reshape(-1)


# ---------------------------------------------------------------------------
# distributed solve driver
# ---------------------------------------------------------------------------

def distributed_stencil_solve(solver: Callable,
                              op: Stencil7Operator,
                              b_grid: jax.Array,
                              mesh: Mesh,
                              *,
                              shard_axes: Optional[Sequence[str]] = None,
                              config: SolverConfig = SolverConfig(),
                              substrate: str = "jnp",
                              jit: bool = True):
    """Solve the stencil system on ``mesh`` with any solver from repro.core.

    ``b_grid`` has shape (nx, ny, nz); its x-dimension is sharded over
    ``shard_axes`` (default: every mesh axis, row-major).  Returns a
    :class:`SolveResult` whose ``x`` is the sharded solution grid.

    ``substrate`` selects the per-shard compute substrate
    (:mod:`repro.core.substrate`): the fused dot partials and vector
    updates inside each shard come from that substrate, while the global
    reduction stays this driver's single ``psum`` either way.
    """
    axes = tuple(shard_axes if shard_axes is not None else mesh.axis_names)
    sizes = _axis_sizes(mesh, axes)
    n_shards = int(np.prod(sizes))
    nx, ny, nz = op.nx, op.ny, op.nz
    if nx % n_shards:
        raise ValueError(f"nx={nx} not divisible by {n_shards} shards")
    local_shape = (nx // n_shards, ny, nz)
    c = op.c

    def dot_reduce(partials):
        return lax.psum(partials, axes)   # ONE reduction for all dots

    def shard_fn(b_local):
        mv = functools.partial(halo_stencil_matvec, c,
                               local_shape=local_shape, axes=axes, sizes=sizes)
        res = solver(mv, b_local.reshape(-1), config=config,
                     dot_reduce=dot_reduce, substrate=substrate)
        return res._replace(x=res.x.reshape(local_shape))

    in_specs = P(axes)
    out_specs = SolveResult(
        x=P(axes), iterations=P(), relres=P(), converged=P(),
        breakdown=P(), residual_history=P())

    fn = compat.shard_map(shard_fn, mesh=mesh, in_specs=(in_specs,),
                          out_specs=out_specs, check_vma=False)
    if jit:
        fn = jax.jit(fn)
    return fn(b_grid)


def replicated_dot_reduce(axes):
    """dot_reduce for custom shard_map code: one psum over ``axes``."""
    return lambda partials: lax.psum(partials, axes)
