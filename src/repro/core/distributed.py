"""Distributed solver runtime: shard_map + halo exchange + single psum.

Row-block domain decomposition over an arbitrary JAX mesh.  The grid's
x-dimension is sharded over *all* mesh axes (flattened, row-major); halo
exchange is a nearest-neighbour ``ppermute`` on the flattened logical ring,
implemented recursively so it works on 1-, 2- ((data, model)) and
3-axis ((pod, data, model)) production meshes — the wrap slab cascades to
the next outer axis exactly like a carry.

The inner-product phases of the solvers call ``dot_reduce`` once per phase;
here that is **one ``lax.psum`` of the stacked partials over the whole
mesh** — the paper's single global reduction.  Because p-BiCGSafe's dots do
not consume the in-flight matvec, the lowered HLO contains no dependency
path from that all-reduce to the halo ppermutes / stencil compute, which is
what lets the XLA latency-hiding scheduler overlap them (verified
structurally in benchmarks/bench_overlap.py).

:func:`distributed_stencil_solve_batched` extends the same decomposition
to multi-RHS blocks: the (n, m) block is row-sharded, the halo exchange
carries all m columns in one ppermute cascade, and the single psum now
reduces the (9, m) partial block — communication per iteration is
independent of m, and the overlap property survives (same structural
proof, batched entry).
"""
from __future__ import annotations

import functools
from typing import Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..precond.base import PrecondLike, resolve_precond
from . import _deprecation, compat
from .linear_operator import Stencil7Operator
from .types import SolveResult, SolverConfig


def _shard_local_precond(precond: PrecondLike, c: jax.Array,
                         local_shape: Tuple[int, int, int]):
    """Resolve ``precond`` against the LOCAL slab operator.

    Name specs build from the shard's own ``(nxl, ny, nz)`` stencil
    operator, so every preconditioner is communication-free by
    construction: its arrays describe one slab and its apply touches no
    mesh axis (the per-iteration psum count is therefore unchanged —
    asserted in tests/_distributed_check.py).  For ``"jacobi"`` and
    ``"block_jacobi"`` this is *exact* (the diagonal is constant and
    z-line blocks never straddle x-slab boundaries); for ``"neumann"``
    and ``"ssor"`` it is the shard-local (zero-Dirichlet at slab
    boundaries) additive-Schwarz flavor of the global preconditioner —
    still a fixed linear M^{-1}, just a slightly weaker one.

    A :class:`~repro.precond.Preconditioner` instance is passed through
    untouched; its arrays must already be local-slab sized (or
    shard-shape-free, like a shared (1, bs, bs) block).
    """
    if not isinstance(precond, str):
        return precond
    local_op = Stencil7Operator(c, *local_shape)
    return resolve_precond(precond, local_op)


# ---------------------------------------------------------------------------
# flattened-ring halo exchange
# ---------------------------------------------------------------------------

def _axis_sizes(mesh: Mesh, axes: Sequence[str]) -> Tuple[int, ...]:
    return tuple(mesh.shape[a] for a in axes)


def ring_shift(x: jax.Array, axes: Sequence[str], sizes: Sequence[int],
               forward: bool) -> jax.Array:
    """Shift ``x`` by one position along the flattened (row-major) mesh ring.

    ``forward`` sends to linear index +1 (receiver gets its left/lower
    neighbour's slab); missing senders at the global boundary yield zeros
    (Dirichlet).  Recursion: a within-axis shift on the innermost axis plus
    a wrap term that moves the last-position slab to position 0 and then
    ring-shifts it along the remaining outer axes.
    """
    axis, size = axes[-1], sizes[-1]
    if forward:
        within_perm = [(i, i + 1) for i in range(size - 1)]
        wrap_perm = [(size - 1, 0)]
    else:
        within_perm = [(i + 1, i) for i in range(size - 1)]
        wrap_perm = [(0, size - 1)]

    within = lax.ppermute(x, axis, within_perm) if within_perm else \
        jnp.zeros_like(x)
    if len(axes) == 1:
        return within
    wrap = lax.ppermute(x, axis, wrap_perm)
    wrap = ring_shift(wrap, axes[:-1], sizes[:-1], forward)
    return within + wrap


def halo_stencil_matvec(c: jax.Array, u_flat: jax.Array,
                        local_shape: Tuple[int, int, int],
                        axes: Sequence[str], sizes: Sequence[int]) -> jax.Array:
    """7-point stencil matvec on the local x-slab with ring halo exchange.

    Communication: two 1-slab ppermute cascades (up & down neighbours) of
    ny*nz elements each — the O(surface) cost that the paper's SpMV hides
    the O(1) reduction message behind.

    ``u_flat`` may be a multi-RHS block ``(nxl*ny*nz, m)``: the stencil and
    the halo ppermutes carry the trailing column axis along, so one halo
    cascade serves all m right-hand sides (the per-column communication
    cost is amortized m-fold, mirroring the batched reduction).
    """
    nxl, ny, nz = local_shape
    u = u_flat.reshape(nxl, ny, nz, *u_flat.shape[1:])

    # x-direction halos from the flattened ring
    top = u[-1:]      # sent forward: becomes receiver's u[x-1] slab
    bot = u[:1]       # sent backward: becomes receiver's u[x+1] slab
    halo_lo = ring_shift(top, axes, sizes, forward=True)    # u[i-1] at i=0
    halo_hi = ring_shift(bot, axes, sizes, forward=False)   # u[i+1] at i=nxl-1

    um = jnp.concatenate([halo_lo, u[:-1]], axis=0)
    up = jnp.concatenate([u[1:], halo_hi], axis=0)
    zy = jnp.zeros_like(u[:, :1])
    vm = jnp.concatenate([zy, u[:, :-1]], axis=1)
    vp = jnp.concatenate([u[:, 1:], zy], axis=1)
    zz = jnp.zeros_like(u[:, :, :1])
    wm = jnp.concatenate([zz, u[:, :, :-1]], axis=2)
    wp = jnp.concatenate([u[:, :, 1:], zz], axis=2)

    out = (c[0] * u + c[1] * um + c[2] * up + c[3] * vm + c[4] * vp
           + c[5] * wm + c[6] * wp)
    return out.reshape(u_flat.shape)


# ---------------------------------------------------------------------------
# distributed solve driver
# ---------------------------------------------------------------------------

def build_stencil_solver(solver: Callable,
                         op: Stencil7Operator,
                         mesh: Mesh,
                         *,
                         shard_axes: Optional[Sequence[str]] = None,
                         config: SolverConfig = SolverConfig(),
                         substrate: str = "jnp",
                         precond: PrecondLike = None,
                         jit: bool = True) -> Callable:
    """Build the sharded solve program ``fn(b_grid) -> SolveResult``.

    This is the reusable half of :func:`distributed_stencil_solve`: the
    shard-local preconditioner is resolved and the shard_map program is
    constructed ONCE; the returned (jitted) callable is what a bound
    session (:meth:`repro.api.LinearSolver.on_mesh`) caches so repeat
    sharded solves stop paying per-call retracing.
    """
    axes = tuple(shard_axes if shard_axes is not None else mesh.axis_names)
    sizes = _axis_sizes(mesh, axes)
    n_shards = int(np.prod(sizes))
    nx, ny, nz = op.nx, op.ny, op.nz
    if nx % n_shards:
        raise ValueError(f"nx={nx} not divisible by {n_shards} shards")
    local_shape = (nx // n_shards, ny, nz)
    c = op.c
    pc = _shard_local_precond(precond, c, local_shape)

    def dot_reduce(partials):
        return lax.psum(partials, axes)   # ONE reduction for all dots

    def shard_fn(b_local):
        mv = functools.partial(halo_stencil_matvec, c,
                               local_shape=local_shape, axes=axes, sizes=sizes)
        with _deprecation.internal_use():
            res = solver(mv, b_local.reshape(-1), config=config,
                         dot_reduce=dot_reduce, substrate=substrate,
                         precond=pc)
        return res._replace(x=res.x.reshape(local_shape))

    in_specs = P(axes)
    # the trace ring buffer is built from psum-replicated dot-derived
    # scalars, so every shard holds the same buffer: replicated specs
    out_specs = SolveResult(
        x=P(axes), iterations=P(), relres=P(), converged=P(),
        breakdown=P(), residual_history=P(), status=P(),
        trace={"buffer": P(), "steps": P()} if config.trace_cap else None)

    fn = compat.shard_map(shard_fn, mesh=mesh, in_specs=(in_specs,),
                          out_specs=out_specs, check_vma=False)
    if jit:
        fn = jax.jit(fn)
    return fn


def distributed_stencil_solve(solver: Callable,
                              op: Stencil7Operator,
                              b_grid: jax.Array,
                              mesh: Mesh,
                              *,
                              shard_axes: Optional[Sequence[str]] = None,
                              config: SolverConfig = SolverConfig(),
                              substrate: str = "jnp",
                              precond: PrecondLike = None,
                              jit: bool = True):
    """Solve the stencil system on ``mesh`` with any solver from repro.core.

    ``b_grid`` has shape (nx, ny, nz); its x-dimension is sharded over
    ``shard_axes`` (default: every mesh axis, row-major).  Returns a
    :class:`SolveResult` whose ``x`` is the sharded solution grid.

    ``substrate`` selects the per-shard compute substrate
    (:mod:`repro.core.substrate`): the fused dot partials and vector
    updates inside each shard come from that substrate, while the global
    reduction stays this driver's single ``psum`` either way.

    ``precond`` is resolved against the LOCAL slab operator
    (:func:`_shard_local_precond`), so every preconditioner apply is
    shard-local — zero extra communication and an unchanged single psum
    per reduction phase.

    Deprecated as a direct entry point: this shim rebuilds (and
    retraces) the shard_map program on every call.  A mesh-bound session
    — ``repro.make_solver(method, op).on_mesh(mesh)`` — builds it once
    and reuses the compiled program.
    """
    _deprecation.warn_legacy(
        "distributed_stencil_solve",
        "repro.make_solver(method, op).on_mesh(mesh)")
    return build_stencil_solver(
        solver, op, mesh, shard_axes=shard_axes, config=config,
        substrate=substrate, precond=precond, jit=jit)(b_grid)


def build_stencil_solver_batched(op: Stencil7Operator,
                                 mesh: Mesh,
                                 *,
                                 shard_axes: Optional[Sequence[str]] = None,
                                 config: SolverConfig = SolverConfig(),
                                 substrate: str = "jnp",
                                 precond: PrecondLike = None,
                                 jit: bool = True) -> Callable:
    """Build the sharded batched solve program ``fn(B_grid) -> SolveResult``.

    The reusable half of :func:`distributed_stencil_solve_batched` (see
    :func:`build_stencil_solver`); the returned callable accepts any
    column count m — ``jax.jit`` keys the compiled program by shape.
    """
    from .multirhs import solve_batched

    axes = tuple(shard_axes if shard_axes is not None else mesh.axis_names)
    sizes = _axis_sizes(mesh, axes)
    n_shards = int(np.prod(sizes))
    nx, ny, nz = op.nx, op.ny, op.nz
    if nx % n_shards:
        raise ValueError(f"nx={nx} not divisible by {n_shards} shards")
    local_shape = (nx // n_shards, ny, nz)
    n_local = local_shape[0] * ny * nz
    c = op.c
    # shard-local preconditioner (shape-polymorphic apply: the same bound
    # M^{-1} serves the (n_local, m) block — one build for all m columns)
    pc = _shard_local_precond(precond, c, local_shape)

    def dot_reduce(partials):
        return lax.psum(partials, axes)   # ONE reduction: the (9, m) block

    def shard_fn(b_local):
        m = b_local.shape[-1]
        mv = functools.partial(halo_stencil_matvec, c,
                               local_shape=local_shape, axes=axes, sizes=sizes)
        # NOTE: no r0_star passthrough — a global shadow vector would have
        # to be row-sharded alongside B for the per-shard partial dots to
        # be correct; the default (RS = R0, already local) is what the
        # single-RHS driver uses too.
        with _deprecation.internal_use():
            res = solve_batched(mv, b_local.reshape(n_local, m),
                                config=config, dot_reduce=dot_reduce,
                                substrate=substrate, blocked=True, precond=pc)
        return res._replace(x=res.x.reshape(*local_shape, m))

    in_specs = P(axes)
    # the trace ring buffer is built from psum-replicated dot-derived
    # scalars, so every shard holds the same buffer: replicated specs
    out_specs = SolveResult(
        x=P(axes), iterations=P(), relres=P(), converged=P(),
        breakdown=P(), residual_history=P(), status=P(),
        trace={"buffer": P(), "steps": P()} if config.trace_cap else None)

    sharded = compat.shard_map(shard_fn, mesh=mesh, in_specs=(in_specs,),
                               out_specs=out_specs, check_vma=False)

    def fn(B_grid):
        if B_grid.ndim != 4:
            raise ValueError(
                f"B_grid must be (nx, ny, nz, m); got {B_grid.shape}")
        return sharded(B_grid)

    if jit:
        fn = jax.jit(fn)
    return fn


def distributed_stencil_solve_batched(op: Stencil7Operator,
                                      B_grid: jax.Array,
                                      mesh: Mesh,
                                      *,
                                      shard_axes: Optional[Sequence[str]] = None,
                                      config: SolverConfig = SolverConfig(),
                                      substrate: str = "jnp",
                                      precond: PrecondLike = None,
                                      jit: bool = True):
    """Batched multi-RHS stencil solve sharded over ``mesh``.

    ``B_grid`` has shape (nx, ny, nz, m): the x-dimension is sharded over
    ``shard_axes`` (default: every mesh axis, row-major) exactly as in
    :func:`distributed_stencil_solve`, and the m right-hand-side columns
    stay local to every shard — the sharded state block is the
    (n_local, m) tile the batched kernels stream.

    Communication per iteration is identical to the single-RHS distributed
    solve: one halo ppermute cascade per block matvec (carrying all m
    columns at once) and ONE ``psum`` — now of the ``(9, m)`` partial
    block, so the per-iteration synchronization cost is amortized over all
    m systems while the no-dependency-edge overlap with the in-flight
    block matvec is preserved (asserted structurally in
    tests/test_substrate_parity.py and benchmarks/bench_overlap.py).

    Returns a :class:`SolveResult` whose ``x`` is the sharded
    (nx, ny, nz, m) solution grid; per-column ``iterations``/``relres``/
    ``converged``/``breakdown`` are replicated.

    Deprecated as a direct entry point (rebuilds the shard_map program
    per call): use ``repro.make_solver("p-bicgsafe", op).on_mesh(mesh)
    .solve_many(B_grid)``, which caches the built program.
    """
    _deprecation.warn_legacy(
        "distributed_stencil_solve_batched",
        'repro.make_solver("p-bicgsafe", op).on_mesh(mesh).solve_many(B)')
    if B_grid.ndim != 4:
        raise ValueError(f"B_grid must be (nx, ny, nz, m); got {B_grid.shape}")
    return build_stencil_solver_batched(
        op, mesh, shard_axes=shard_axes, config=config, substrate=substrate,
        precond=precond, jit=jit)(B_grid)


def replicated_dot_reduce(axes):
    """dot_reduce for custom shard_map code: one psum over ``axes``."""
    return lambda partials: lax.psum(partials, axes)
