"""Linear operators for the Krylov solvers.

All operators expose ``matvec`` (jit/vmap-safe pure function of a vector),
``shape`` and ``dtype``.  Sparse formats:

* :class:`CSROperator` — classic compressed sparse row; gather + segment
  sum.  Reference format (CPU-friendly; what PETSc used in the paper).
* :class:`ELLOperator` — ELLPACK: fixed ``k`` nonzeros per row stored as two
  dense ``(n, k)`` arrays.  Dense regular layout → maps directly onto TPU
  VMEM tiles; this is the format the Pallas SpMV kernel consumes.
* :class:`Stencil7Operator` — matrix-free 7-point (3-D) finite-difference
  operator with optional convection (non-symmetric) terms; the structured
  analogue of the paper's fluid-dynamics matrices, and the operator used by
  the distributed halo-exchange path.

Design note: operators are pytrees (registered dataclasses) so they can be
closed over or passed as arguments to jitted solvers and sharded with
shard_map.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .types import MatVec


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class DenseOperator:
    """Dense matrix operator (small systems / tests)."""

    a: jax.Array

    @property
    def shape(self):
        return self.a.shape

    @property
    def dtype(self):
        return self.a.dtype

    def matvec(self, x: jax.Array) -> jax.Array:
        return self.a @ x

    def rmatvec(self, x: jax.Array) -> jax.Array:
        return self.a.T @ x

    def diagonal(self) -> jax.Array:
        return jnp.diagonal(self.a)

    def tree_flatten(self):
        return (self.a,), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class CSROperator:
    """CSR sparse operator.

    ``data``/``indices`` are nnz-length; ``row_ids`` is the expanded row
    index per nonzero (precomputed from indptr so matvec is a pure gather +
    segment_sum with static shapes — no dynamic loops).
    """

    data: jax.Array      # (nnz,)
    indices: jax.Array   # (nnz,) int32 column ids
    row_ids: jax.Array   # (nnz,) int32 row ids
    n: int               # static number of rows/cols

    @property
    def shape(self):
        return (self.n, self.n)

    @property
    def dtype(self):
        return self.data.dtype

    def matvec(self, x: jax.Array) -> jax.Array:
        prods = self.data * x[self.indices]
        return jax.ops.segment_sum(prods, self.row_ids, num_segments=self.n)

    def rmatvec(self, x: jax.Array) -> jax.Array:
        prods = self.data * x[self.row_ids]
        return jax.ops.segment_sum(prods, self.indices, num_segments=self.n)

    def diagonal(self) -> jax.Array:
        on_diag = jnp.where(self.indices == self.row_ids, self.data, 0.0)
        return jax.ops.segment_sum(on_diag, self.row_ids, num_segments=self.n)

    @staticmethod
    def from_scipy(m) -> "CSROperator":
        m = m.tocsr()
        n = m.shape[0]
        indptr = np.asarray(m.indptr)
        row_ids = np.repeat(np.arange(n, dtype=np.int32),
                            np.diff(indptr).astype(np.int32))
        return CSROperator(jnp.asarray(m.data), jnp.asarray(m.indices, jnp.int32),
                           jnp.asarray(row_ids), n)

    def tree_flatten(self):
        return (self.data, self.indices, self.row_ids), self.n

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, n=aux)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class ELLOperator:
    """ELLPACK operator: fixed k nonzeros/row, padded with zeros.

    TPU-friendly: ``values``/``cols`` are dense (n, k) arrays so the SpMV is
    a gather + row reduction over a regular layout (Pallas kernel target).
    Padding entries have ``cols == pad_col`` (their value is 0 so any column
    works; we use 0).
    """

    values: jax.Array  # (n, k)
    cols: jax.Array    # (n, k) int32
    n: int

    @property
    def shape(self):
        return (self.n, self.n)

    @property
    def dtype(self):
        return self.values.dtype

    @property
    def k(self) -> int:
        return self.values.shape[1]

    def matvec(self, x: jax.Array) -> jax.Array:
        return jnp.sum(self.values * x[self.cols], axis=1)

    def diagonal(self) -> jax.Array:
        row = jnp.arange(self.n)[:, None]
        return jnp.sum(jnp.where(self.cols == row, self.values, 0.0), axis=1)

    @staticmethod
    def from_csr(op: CSROperator, k: Optional[int] = None) -> "ELLOperator":
        """Convert (host-side) a CSR operator to padded ELL."""
        data = np.asarray(op.data)
        indices = np.asarray(op.indices)
        row_ids = np.asarray(op.row_ids)
        n = op.n
        counts = np.bincount(row_ids, minlength=n)
        kk = int(counts.max()) if k is None else k
        values = np.zeros((n, kk), dtype=data.dtype)
        cols = np.zeros((n, kk), dtype=np.int32)
        # position of each nnz within its row
        pos = np.arange(len(data)) - np.concatenate(
            ([0], np.cumsum(counts)[:-1]))[row_ids]
        values[row_ids, pos] = data
        cols[row_ids, pos] = indices
        return ELLOperator(jnp.asarray(values), jnp.asarray(cols), n)

    def tree_flatten(self):
        return (self.values, self.cols), self.n

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, n=aux)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class Stencil7Operator:
    """Matrix-free 7-point stencil on an (nx, ny, nz) grid.

    A = -div(grad u) * diag_scale + convection  (Dirichlet boundaries).

    ``c`` holds the 7 coefficients (center, ±x, ±y, ±z); allowing
    asymmetric off-diagonal pairs gives a non-symmetric matrix
    (convection–diffusion), the paper's dominant matrix kind.

    Vectors are flattened (nx*ny*nz,); matvec reshapes internally.  This
    operator is also the one the distributed driver shards by x-slabs with
    ppermute halo exchange.
    """

    c: jax.Array  # (7,) [center, xm, xp, ym, yp, zm, zp]
    nx: int
    ny: int
    nz: int

    @property
    def n(self):
        return self.nx * self.ny * self.nz

    @property
    def shape(self):
        return (self.n, self.n)

    @property
    def dtype(self):
        return self.c.dtype

    def matvec(self, x: jax.Array) -> jax.Array:
        u = x.reshape(self.nx, self.ny, self.nz)
        c = self.c
        out = c[0] * u
        # zero-Dirichlet shifts (no wraparound): pad+slice
        zx = jnp.zeros_like(u[:1])
        um = jnp.concatenate([zx, u[:-1]], axis=0)   # u[i-1]
        up = jnp.concatenate([u[1:], zx], axis=0)    # u[i+1]
        zy = jnp.zeros_like(u[:, :1])
        vm = jnp.concatenate([zy, u[:, :-1]], axis=1)
        vp = jnp.concatenate([u[:, 1:], zy], axis=1)
        zz = jnp.zeros_like(u[:, :, :1])
        wm = jnp.concatenate([zz, u[:, :, :-1]], axis=2)
        wp = jnp.concatenate([u[:, :, 1:], zz], axis=2)
        out = out + c[1] * um + c[2] * up + c[3] * vm + c[4] * vp \
            + c[5] * wm + c[6] * wp
        return out.reshape(-1)

    def diagonal(self) -> jax.Array:
        return jnp.full((self.n,), self.c[0], dtype=self.dtype)

    def tree_flatten(self):
        return (self.c,), (self.nx, self.ny, self.nz)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], *aux)


def as_matvec(op) -> MatVec:
    """Accept an operator object, a dense matrix, or a callable."""
    if callable(op) and not hasattr(op, "matvec"):
        return op
    if hasattr(op, "matvec"):
        return op.matvec
    a = jnp.asarray(op)
    return lambda x: a @ x


# -- deprecation re-exports ---------------------------------------------------
# The preconditioning machinery moved to the repro.precond subsystem
# (PR 3): JacobiPreconditioner gained a dtype-preserving zero-diagonal
# guard + (n, m) multi-RHS applies there, and preconditioned_matvec is
# superseded by precond= on a bound session (repro.make_solver), which
# keeps operator dispatch to the Pallas kernels and routes the
# M^{-1}-apply through the compute substrate.  PEP 562 module
# __getattr__ keeps the historical import path working but announces the
# move with one DeprecationWarning per process instead of aliasing
# silently (identity is preserved: the returned objects ARE the
# repro.precond ones).

def __getattr__(name: str):
    from ._deprecation import warn_legacy
    if name == "preconditioned_matvec":
        warn_legacy("repro.core.linear_operator.preconditioned_matvec",
                    'precond= on repro.make_solver(...) '
                    "(or repro.precond.preconditioned_matvec)")
        from repro.precond.base import preconditioned_matvec
        return preconditioned_matvec
    if name == "JacobiPreconditioner":
        warn_legacy("repro.core.linear_operator.JacobiPreconditioner",
                    "repro.precond.JacobiPreconditioner")
        from repro.precond.jacobi import JacobiPreconditioner
        return JacobiPreconditioner
    raise AttributeError(
        f"module {__name__!r} has no attribute {name!r}")
