"""jax version-compatibility shims.

The repo targets a range of jax versions: newer releases expose
``jax.shard_map`` (with ``check_vma``) and ``jax.sharding.AxisType``,
while 0.4.x has ``jax.experimental.shard_map.shard_map`` (``check_rep``)
and no axis types.  Call sites import these wrappers instead of
branching locally.

The jaxpr vocabulary types (``Jaxpr``, ``ClosedJaxpr``, ``Literal``,
``Var``) moved from ``jax.core`` to ``jax.extend.core``; referencing
them through ``jax.core`` emits DeprecationWarnings on newer jax and
will eventually break.  The static contract analyzer
(:mod:`repro.analysis`) and every jaxpr probe import them from here.
"""
from __future__ import annotations

import jax

try:                                     # jax >= 0.4.33
    from jax.extend.core import ClosedJaxpr, Jaxpr, Literal, Var
except ImportError:                      # older jax: the pre-move home
    from jax.core import ClosedJaxpr, Jaxpr, Literal, Var  # noqa: F401


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` on new jax, ``jax.experimental.shard_map`` on old."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_rep=check_vma)


def make_mesh(shape, axis_names):
    """``jax.make_mesh`` with explicit Auto axis types where supported."""
    try:
        from jax.sharding import AxisType
    except ImportError:
        return jax.make_mesh(shape, axis_names)
    return jax.make_mesh(shape, axis_names,
                         axis_types=(AxisType.Auto,) * len(axis_names))
