"""GPBi-CG (Zhang 1997; paper Alg. 2.2).

Generalized product-type method: three-term stabilizing polynomial with
coefficients (zeta, eta) minimizing ||t - eta*y - zeta*A t||.  Three
synchronization phases per iteration (paper Fig. 3.1) — the convergence
baseline that BiCGSafe/ssBiCGSafe improve upon.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from ..precond.base import PrecondLike, preconditioned_system
from ._common import init_guess, safe_div, tree_select
from .substrate import SubstrateLike, get_substrate
from .types import (DotReduce, SolveResult, SolverConfig, classify_status,
                    history_init, history_update, identity_reduce)


def gpbicg_solve(matvec: Callable,
                 b: jax.Array,
                 x0: Optional[jax.Array] = None,
                 *,
                 config: SolverConfig = SolverConfig(),
                 r0_star: Optional[jax.Array] = None,
                 dot_reduce: DotReduce = identity_reduce,
                 substrate: SubstrateLike = "jnp",
                 precond: PrecondLike = None) -> SolveResult:
    """Solve A x = b with GPBi-CG (Alg. 2.2; left-preconditioned when
    ``precond`` is set)."""
    sub = get_substrate(substrate)
    matvec, b = preconditioned_system(sub, matvec, b, precond)
    eps = config.breakdown_threshold(b.dtype)
    x = init_guess(b, x0)
    r0 = b - matvec(x) if x0 is not None else b
    rs = r0 if r0_star is None else r0_star.astype(b.dtype)

    init = dot_reduce(sub.dots([(r0, r0), (rs, r0)]))
    norm_r0 = jnp.sqrt(init[0])
    # ||r_0|| == 0: converge at t=0 instead of dividing by zero.
    conv0 = norm_r0 == 0
    norm_r0 = jnp.where(conv0, jnp.ones_like(norm_r0), norm_r0)
    z0 = jnp.zeros_like(b)
    hist = history_init(config, norm_r0.dtype)

    zero = jnp.zeros((), b.dtype)
    state = dict(
        x=x, r=r0, p=z0, u=z0, t=z0, w=z0, z=z0,
        rho=init[1],                       # (r0*, r_i)
        beta=zero, zeta=jnp.ones((), b.dtype),
        rr=init[0],
        i=jnp.zeros((), jnp.int32),
        relres=jnp.where(conv0, 0.0, 1.0).astype(norm_r0.dtype),
        converged=conv0, breakdown=jnp.zeros((), bool),
        hist=hist)

    def cond(st):
        return (~st["converged"]) & (~st["breakdown"]) & (st["i"] < config.maxiter)

    def body(st):
        relres = jnp.sqrt(jnp.abs(st["rr"])) / norm_r0
        done = relres <= config.tol
        hist_i = history_update(st["hist"], st["i"], relres, config)

        r, beta = st["r"], st["beta"]
        t_prev, w_prev, u_prev, z_prev = st["t"], st["w"], st["u"], st["z"]
        first = st["i"] == 0

        p = r + beta * (st["p"] - u_prev)                 # line 7
        ap = matvec(p)                                    # line 8
        # --- phase 1: alpha ---
        d1 = dot_reduce(sub.dots([(rs, ap)]))
        alpha, bad1 = safe_div(st["rho"], d1[0], eps)

        y = t_prev - r - alpha * w_prev + alpha * ap      # line 10
        t = r - alpha * ap                                # line 11
        at = matvec(t)                                    # line 12
        # --- phase 2: a..e for (zeta, eta) ---
        d2 = dot_reduce(sub.dots([
            (y, y), (at, t), (y, t), (at, y), (at, at)]))
        a_, b_, c_, d_, e_ = (d2[k] for k in range(5))
        zeta0, badz0 = safe_div(b_, e_, eps)              # line 15
        den = e_ * a_ - d_ * d_
        zeta_g, badzg = safe_div(a_ * b_ - c_ * d_, den, eps)   # line 18
        eta_g, _ = safe_div(e_ * c_ - d_ * b_, den, eps)        # line 19
        zeta = jnp.where(first, zeta0, zeta_g)
        eta = jnp.where(first, jnp.zeros_like(zeta), eta_g)
        bad2 = jnp.where(first, badz0, badzg)

        u = zeta * ap + eta * (t_prev - r + beta * u_prev)      # line 21
        z = zeta * r + eta * z_prev - alpha * u                 # line 22
        x_next = st["x"] + alpha * p + z                        # line 23
        r_next = t - eta * y - zeta * at                        # line 24
        # --- phase 3: beta + residual norm ---
        d3 = dot_reduce(sub.dots([(rs, r_next), (r_next, r_next)]))
        rho_next = d3[0]
        beta_next_num = alpha * rho_next
        beta_next, bad3 = safe_div(beta_next_num, zeta * st["rho"], eps)
        w = at + beta_next * ap                                 # line 26

        bad = bad1 | bad2 | bad3
        new = dict(
            x=x_next, r=r_next, p=p, u=u, t=t, w=w, z=z,
            rho=rho_next, beta=beta_next, zeta=zeta, rr=d3[1],
            i=st["i"] + 1, relres=relres,
            converged=jnp.zeros((), bool), breakdown=bad,
            hist=hist_i)
        stopped = dict(st)
        stopped.update(relres=relres, converged=done, hist=hist_i)
        return tree_select(done, stopped, new)

    st = jax.lax.while_loop(cond, body, state)
    final_relres = jnp.where(st["converged"], st["relres"],
                             jnp.sqrt(jnp.abs(st["rr"])) / norm_r0)
    converged = st["converged"] | (final_relres <= config.tol)
    return SolveResult(st["x"], st["i"], final_relres, converged,
                       st["breakdown"], st["hist"],
                       classify_status(converged, st["breakdown"],
                                       final_relres))
