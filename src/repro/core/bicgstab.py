"""BiCGStab (van der Vorst 1992; paper Alg. 2.1), parallel 2-phase form.

Per paper Fig. 3.1, BiCGStab runs two synchronization phases per iteration.
The textbook listing (Alg. 2.1) would need a third reduction for
``(r0*, r_{i+1})`` and ``||r_{i+1}||``; the standard parallel arrangement
(used here, and what Fig. 3.1 depicts) folds them into phase 2 via

    (r0*, r_{i+1}) = (r0*, t) - omega (r0*, At)
    ||r_{i+1}||^2  = (t,t) - 2 omega (At,t) + omega^2 (At,At)

at the cost of one extra inner product (6/iter vs Table 3.1's 5).
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from ..precond.base import PrecondLike, preconditioned_system
from ._common import init_guess, safe_div, tree_select
from .substrate import SubstrateLike, get_substrate
from .types import (DotReduce, SolveResult, SolverConfig, classify_status,
                    history_init, history_update, identity_reduce)


def bicgstab_solve(matvec: Callable,
                   b: jax.Array,
                   x0: Optional[jax.Array] = None,
                   *,
                   config: SolverConfig = SolverConfig(),
                   r0_star: Optional[jax.Array] = None,
                   dot_reduce: DotReduce = identity_reduce,
                   substrate: SubstrateLike = "jnp",
                   precond: PrecondLike = None) -> SolveResult:
    """Solve A x = b with BiCGStab.

    ``precond`` (name or :class:`repro.precond.Preconditioner`) runs the
    left-preconditioned system M^{-1} A x = M^{-1} b; relres/tol are then
    in the preconditioned norm.
    """
    sub = get_substrate(substrate)
    matvec, b = preconditioned_system(sub, matvec, b, precond)
    eps = config.breakdown_threshold(b.dtype)
    x = init_guess(b, x0)
    r0 = b - matvec(x) if x0 is not None else b
    rs = r0 if r0_star is None else r0_star.astype(b.dtype)

    init = dot_reduce(sub.dots([(r0, r0), (rs, r0)]))
    norm_r0 = jnp.sqrt(init[0])
    # ||r_0|| == 0 (zero rhs, or exact initial guess): converge at t=0
    # instead of dividing by zero in the relres checks.
    conv0 = norm_r0 == 0
    norm_r0 = jnp.where(conv0, jnp.ones_like(norm_r0), norm_r0)
    rho0 = init[1]                      # (r0*, r_0)
    z0 = jnp.zeros_like(b)
    hist = history_init(config, norm_r0.dtype)

    one = jnp.ones((), b.dtype)
    zero = jnp.zeros((), b.dtype)
    state = dict(
        x=x, r=r0, p=r0, ap=z0,
        rho=rho0, alpha=one, omega=one,
        rr=init[0],                      # ||r_i||^2 (recurred)
        i=jnp.zeros((), jnp.int32),
        relres=jnp.where(conv0, 0.0, 1.0).astype(norm_r0.dtype),
        converged=conv0, breakdown=jnp.zeros((), bool),
        hist=hist)

    def cond(st):
        return (~st["converged"]) & (~st["breakdown"]) & (st["i"] < config.maxiter)

    def body(st):
        relres = jnp.sqrt(jnp.abs(st["rr"])) / norm_r0
        done = relres <= config.tol
        hist_i = history_update(st["hist"], st["i"], relres, config)

        r, p = st["r"], st["p"]
        ap = matvec(p)
        # --- phase 1: single dot (r0*, Ap) ---
        d1 = dot_reduce(sub.dots([(rs, ap)]))
        alpha, bad1 = safe_div(st["rho"], d1[0], eps)
        t = r - alpha * ap
        at = matvec(t)
        # --- phase 2: 5 fused dots ---
        d2 = dot_reduce(sub.dots([
            (at, t), (at, at), (rs, t), (rs, at), (t, t)]))
        omega, bad2 = safe_div(d2[0], d2[1], eps)
        rho_next = d2[2] - omega * d2[3]
        rr_next = d2[4] - 2.0 * omega * d2[0] + omega * omega * d2[1]
        beta_num = rho_next * alpha
        beta, bad3 = safe_div(beta_num, st["rho"] * omega, eps)

        x_next = st["x"] + alpha * p + omega * t
        r_next = t - omega * at
        p_next = r_next + beta * (p - omega * ap)

        bad = bad1 | bad2 | bad3
        new = dict(
            x=x_next, r=r_next, p=p_next, ap=ap,
            rho=rho_next, alpha=alpha, omega=omega, rr=rr_next,
            i=st["i"] + 1, relres=relres,
            converged=jnp.zeros((), bool), breakdown=bad,
            hist=hist_i)
        stopped = dict(st)
        stopped.update(relres=relres, converged=done, hist=hist_i)
        return tree_select(done, stopped, new)

    st = jax.lax.while_loop(cond, body, state)
    # Final convergence state: re-derive from the last recurred ||r||^2 if
    # the loop exited on maxiter after an un-checked update.
    final_relres = jnp.where(st["converged"], st["relres"],
                             jnp.sqrt(jnp.abs(st["rr"])) / norm_r0)
    converged = st["converged"] | (final_relres <= config.tol)
    return SolveResult(st["x"], st["i"], final_relres, converged,
                       st["breakdown"], st["hist"],
                       classify_status(converged, st["breakdown"],
                                       final_relres))
