"""p-BiCGSafe — communication-hiding pipelined BiCGSafe (paper Alg. 3.1)
and p-BiCGSafe-rr — with residual replacement (paper Alg. 4.1).

The paper's core contribution.  Algebraically identical to ssBiCGSafe2 but
with the matvec results replaced by recurrences on auxiliary vectors

    q_i = A s_i + beta_i l_{i-1}              (== A o_i,   Eqn. 3.5)
    w_i = zeta_i q_i + eta_i(g_i + beta_i w_{i-1})   (== A u_i, Eqn. 3.9)
    l_i = q_i - A w_i                         (== A t_i,   Eqn. 3.7)
    g_{i+1} = zeta_i A s_i + eta_i g_i - alpha_i A w_i  (== A y_{i+1}, 3.10)
    s_{i+1} = s_i - alpha_i q_i - g_{i+1}     (== A r_{i+1}, Eqn. 3.2)

so that the single fused inner-product reduction of the iteration consumes
only ``s_i, y_i, r_i, t_{i-1}`` — none of which depend on this iteration's
matvec ``A s_i``.  The reduction and the matvec therefore have **no
dependency edge** and overlap: MPI_Iallreduce+compute in the paper, the XLA
latency-hiding scheduler / dependency-free psum here (DESIGN.md §3;
structural proof in benchmarks/bench_overlap.py).

p-BiCGSafe-rr resets ``r, q, w, l, g, s`` to their true values every
``rr_epoch`` iterations while ``i < rr_maxiter`` (paper §4) to arrest the
round-off drift of the recurred quantities.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from ._common import (bicgsafe_coefficients, init_guess, local_dots,
                      tree_select)
from .types import (DotReduce, SolveResult, SolverConfig, history_init,
                    history_update, identity_reduce)


def _pipelined_solve(matvec, b, x0, config, r0_star, dot_reduce,
                     residual_replacement: bool):
    eps = config.breakdown_threshold(b.dtype)
    x = init_guess(b, x0)
    r0 = b - matvec(x) if x0 is not None else b          # MV (init)
    rs = r0 if r0_star is None else r0_star.astype(b.dtype)
    s0 = matvec(r0)                                      # MV (init): s_0 = A r_0

    norm_r0 = jnp.sqrt(dot_reduce(local_dots([(r0, r0)]))[0])
    z0 = jnp.zeros_like(b)
    hist = history_init(config, norm_r0.dtype)

    one = jnp.ones((), b.dtype)
    zero = jnp.zeros((), b.dtype)
    state = dict(
        x=x, r=r0, s=s0, p=z0, u=z0, t=z0, y=z0, z=z0, w=z0, l=z0, g=z0,
        alpha=zero, zeta=one, f=one,
        i=jnp.zeros((), jnp.int32),
        relres=jnp.ones((), norm_r0.dtype),
        converged=jnp.zeros((), bool), breakdown=jnp.zeros((), bool),
        hist=hist)

    def cond(st):
        return (~st["converged"]) & (~st["breakdown"]) & (st["i"] < config.maxiter)

    def body(st):
        r, s, y, t_prev = st["r"], st["s"], st["y"], st["t"]

        # MV #1 (A s_i) and the fused reduction are mutually independent:
        # the dots read only {s, y, r, t_prev, rs}.  This is the paper's
        # communication hiding — in the lowered HLO there is no path from
        # the all-reduce to the matvec.
        As = matvec(s)
        dots = dot_reduce(local_dots([
            (s, s), (y, y), (s, y), (s, r), (y, r),
            (rs, r), (rs, s), (rs, t_prev), (r, r)]))

        beta, alpha, zeta, eta, f, rr, bad = bicgsafe_coefficients(
            dots, st["i"], st["alpha"], st["zeta"], st["f"], eps)
        relres = jnp.sqrt(jnp.abs(rr)) / norm_r0
        done = relres <= config.tol

        # --- vector updates (identical algebra to Alg. 2.3 lines 23-30) ---
        p = r + beta * (st["p"] - st["u"])
        o = s + beta * t_prev
        u = zeta * o + eta * (y + beta * st["u"])

        if residual_replacement:
            # Alg. 4.1 lines 26-33: on replacement steps q, w come from
            # true matvecs instead of the recurrences.
            do_rr = ((st["i"] % config.rr_epoch) == 0) & (st["i"] > 0) \
                & (st["i"] < config.rr_maxiter)
            q, w = jax.lax.cond(
                do_rr,
                lambda: (matvec(o), matvec(u)),
                lambda: (As + beta * st["l"],
                         zeta * (As + beta * st["l"])
                         + eta * (st["g"] + beta * st["w"])))
        else:
            q = As + beta * st["l"]                       # == A o_i (3.5)
            w = zeta * q + eta * (st["g"] + beta * st["w"])  # == A u_i (3.9)

        t = o - w
        z = zeta * r + eta * st["z"] - alpha * u
        y_next = zeta * s + eta * y - alpha * w
        x_next = st["x"] + alpha * p + z

        if residual_replacement:
            do_rr = ((st["i"] % config.rr_epoch) == 0) & (st["i"] > 0) \
                & (st["i"] < config.rr_maxiter)

            def rr_branch():
                # Alg. 4.1 lines 38-45: reset recurred vectors to truth.
                r_n = b - matvec(x_next)
                l_n = matvec(t)
                g_n = matvec(y_next)
                s_n = matvec(r_n)
                return r_n, l_n, g_n, s_n

            def pipe_branch():
                r_n = r - alpha * o - y_next
                Aw = matvec(w)                            # MV #2 (A w_i)
                l_n = q - Aw                              # == A t_i (3.7)
                g_n = zeta * As + eta * st["g"] - alpha * Aw   # (3.10)
                s_n = s - alpha * q - g_n                 # == A r_{i+1} (3.2)
                return r_n, l_n, g_n, s_n

            r_next, l, g_next, s_next = jax.lax.cond(do_rr, rr_branch,
                                                     pipe_branch)
        else:
            r_next = r - alpha * o - y_next
            Aw = matvec(w)                                # MV #2 (A w_i)
            l = q - Aw                                    # == A t_i (3.7)
            g_next = zeta * As + eta * st["g"] - alpha * Aw    # (3.10)
            s_next = s - alpha * q - g_next               # == A r_{i+1} (3.2)

        hist_i = history_update(st["hist"], st["i"], relres, config)
        new = dict(
            x=x_next, r=r_next, s=s_next, p=p, u=u, t=t, y=y_next, z=z,
            w=w, l=l, g=g_next,
            alpha=alpha, zeta=zeta, f=f,
            i=st["i"] + 1, relres=relres,
            converged=jnp.zeros((), bool), breakdown=jnp.zeros((), bool),
            hist=hist_i)
        stopped = dict(st)
        stopped.update(relres=relres, converged=done, breakdown=bad & ~done,
                       hist=hist_i)
        return tree_select(done | bad, stopped, new)

    st = jax.lax.while_loop(cond, body, state)
    return SolveResult(st["x"], st["i"], st["relres"], st["converged"],
                       st["breakdown"], st["hist"])


def pbicgsafe_solve(matvec: Callable,
                    b: jax.Array,
                    x0: Optional[jax.Array] = None,
                    *,
                    config: SolverConfig = SolverConfig(),
                    r0_star: Optional[jax.Array] = None,
                    dot_reduce: DotReduce = identity_reduce) -> SolveResult:
    """Solve A x = b with p-BiCGSafe (paper Alg. 3.1)."""
    return _pipelined_solve(matvec, b, x0, config, r0_star, dot_reduce,
                            residual_replacement=False)


def pbicgsafe_rr_solve(matvec: Callable,
                       b: jax.Array,
                       x0: Optional[jax.Array] = None,
                       *,
                       config: SolverConfig = SolverConfig(),
                       r0_star: Optional[jax.Array] = None,
                       dot_reduce: DotReduce = identity_reduce) -> SolveResult:
    """Solve A x = b with p-BiCGSafe-rr (paper Alg. 4.1).

    ``config.rr_epoch`` is the paper's ``m`` (default 100, the paper's
    default), ``config.rr_maxiter`` the cutoff ``M``.
    """
    return _pipelined_solve(matvec, b, x0, config, r0_star, dot_reduce,
                            residual_replacement=True)
