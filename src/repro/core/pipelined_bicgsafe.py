"""p-BiCGSafe — communication-hiding pipelined BiCGSafe (paper Alg. 3.1)
and p-BiCGSafe-rr — with residual replacement (paper Alg. 4.1).

The paper's core contribution.  Algebraically identical to ssBiCGSafe2 but
with the matvec results replaced by recurrences on auxiliary vectors

    q_i = A s_i + beta_i l_{i-1}              (== A o_i,   Eqn. 3.5)
    w_i = zeta_i q_i + eta_i(g_i + beta_i w_{i-1})   (== A u_i, Eqn. 3.9)
    l_i = q_i - A w_i                         (== A t_i,   Eqn. 3.7)
    g_{i+1} = zeta_i A s_i + eta_i g_i - alpha_i A w_i  (== A y_{i+1}, 3.10)
    s_{i+1} = s_i - alpha_i q_i - g_{i+1}     (== A r_{i+1}, Eqn. 3.2)

so that the single fused inner-product reduction of the iteration consumes
only ``s_i, y_i, r_i, t_{i-1}`` — none of which depend on this iteration's
matvec ``A s_i``.  The reduction and the matvec therefore have **no
dependency edge** and overlap: MPI_Iallreduce+compute in the paper, the XLA
latency-hiding scheduler / dependency-free psum here (DESIGN.md §3;
structural proof in benchmarks/bench_overlap.py).

p-BiCGSafe-rr resets ``r, q, w, l, g, s`` to their true values every
``rr_epoch`` iterations while ``i < rr_maxiter`` (paper §4) to arrest the
round-off drift of the recurred quantities.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from ..precond.base import PrecondLike, preconditioned_system
from ._common import (bicgsafe_coefficients, init_guess,
                      pipelined_recurrence_tail, tree_select)
from .substrate import SubstrateLike, get_substrate
from .types import (DotReduce, SolveResult, SolveStatus, SolverConfig,
                    classify_status, history_init, history_update,
                    identity_reduce, trace_init, trace_record)


def _pipelined_solve(matvec, b, x0, config, r0_star, dot_reduce,
                     residual_replacement: bool, substrate: SubstrateLike,
                     precond: PrecondLike = None):
    # Left preconditioning composes M^{-1} INTO the matvec, so every
    # recurred A-image below is an (M^{-1}A)-image and the algebra is
    # unchanged; the M^{-1}-apply becomes part of the in-flight compute
    # the single reduction overlaps (the dots still read none of it).
    sub = get_substrate(substrate)
    matvec, b = preconditioned_system(sub, matvec, b, precond)
    eps = config.breakdown_threshold(b.dtype)
    x = init_guess(b, x0)
    r0 = b - matvec(x) if x0 is not None else b          # MV (init)
    rs = r0 if r0_star is None else r0_star.astype(b.dtype)
    s0 = matvec(r0)                                      # MV (init): s_0 = A r_0

    norm_r0 = jnp.sqrt(dot_reduce(sub.dots([(r0, r0)]))[0])
    # ||r_0|| == 0 (zero rhs, or exact initial guess): x already solves
    # the system — converge at t=0 instead of dividing by zero below.
    conv0 = norm_r0 == 0
    norm_r0 = jnp.where(conv0, jnp.ones_like(norm_r0), norm_r0)
    z0 = jnp.zeros_like(b)
    hist = history_init(config, norm_r0.dtype)

    one = jnp.ones((), b.dtype)
    zero = jnp.zeros((), b.dtype)
    state = dict(
        x=x, r=r0, s=s0, p=z0, u=z0, t=z0, y=z0, z=z0, w=z0, l=z0, g=z0,
        alpha=zero, zeta=one, f=one,
        i=jnp.zeros((), jnp.int32),
        relres=jnp.where(conv0, 0.0, 1.0).astype(norm_r0.dtype),
        converged=conv0, breakdown=jnp.zeros((), bool),
        hist=hist)
    if config.trace_cap:
        state["trace"] = trace_init(config, norm_r0.dtype)
        # rows written (the terminal detection writes one WITHOUT
        # advancing i, so i alone undercounts by one on converge)
        state["trace_steps"] = jnp.zeros((), jnp.int32)

    def cond(st):
        return (~st["converged"]) & (~st["breakdown"]) & (st["i"] < config.maxiter)

    def body(st):
        r, s, y, t_prev = st["r"], st["s"], st["y"], st["t"]

        # MV #1 (A s_i) and the fused reduction are mutually independent:
        # the dots read only {s, y, r, t_prev, rs}.  This is the paper's
        # communication hiding — in the lowered HLO there is no path from
        # the all-reduce to the matvec.  The named scopes land in HLO op
        # metadata so repro.observe.profile can attribute device time to
        # phases; they emit no ops and leave the math bitwise-unchanged.
        with jax.named_scope("repro.matvec"):
            As = matvec(s)
        with jax.named_scope("repro.reduce"):
            dots = dot_reduce(sub.bicgsafe_dots(s, y, r, t_prev, rs))

        beta, alpha, zeta, eta, f, rr, bad = bicgsafe_coefficients(
            dots, st["i"], st["alpha"], st["zeta"], st["f"], eps)
        relres = jnp.sqrt(jnp.abs(rr)) / norm_r0
        done = relres <= config.tol

        # --- blocked vector-update phase (Alg. 3.1 lines 23-32): one
        # substrate call covers all 10 recurrence updates (one fused HBM
        # pass on the pallas substrate).
        with jax.named_scope("repro.axpy"):
            upd = sub.axpy_phase(
                dict(r=r, p=st["p"], u=st["u"], t=t_prev, y=y, z=st["z"],
                     s=s, l=st["l"], g=st["g"], w=st["w"], x=st["x"], As=As),
                (alpha, beta, zeta, eta))
        p, o, u, q, w = (upd[k] for k in ("p", "o", "u", "q", "w"))
        t, z, y_next, x_next, r_next = (
            upd[k] for k in ("t", "z", "y", "x", "r"))

        def pipe_tail():
            """Recurrence closure: MV #2 and the three recurred A-images."""
            with jax.named_scope("repro.matvec"):
                Aw = matvec(w)                        # MV #2 (A w_i)
            with jax.named_scope("repro.axpy"):
                l_n, g_n, s_n = pipelined_recurrence_tail(
                    q, s, As, st["g"], Aw, alpha, zeta, eta)
            return w, t, y_next, x_next, r_next, l_n, g_n, s_n

        if not residual_replacement:
            w, t, y_next, x_next, r_next, l, g_next, s_next = pipe_tail()
        else:
            # Alg. 4.1: every rr_epoch-th step replaces the recurred
            # quantities with true matvec values (p, o, u, z keep their
            # recurrence values — they are exact either way).
            do_rr = ((st["i"] % config.rr_epoch) == 0) & (st["i"] > 0) \
                & (st["i"] < config.rr_maxiter)

            def rr_branch():
                # Alg. 4.1 lines 26-33 + 38-45: w from a true matvec, then
                # reset r, l, g, s to their true values.
                with jax.named_scope("repro.matvec"):
                    w_t = matvec(u)                   # true A u_i
                t_t = o - w_t
                y_t = zeta * s + eta * y - alpha * w_t
                x_t = st["x"] + alpha * p + z
                with jax.named_scope("repro.matvec"):
                    r_t = b - matvec(x_t)
                    l_t = matvec(t_t)
                    g_t = matvec(y_t)
                    s_t = matvec(r_t)
                return w_t, t_t, y_t, x_t, r_t, l_t, g_t, s_t

            w, t, y_next, x_next, r_next, l, g_next, s_next = jax.lax.cond(
                do_rr, rr_branch, pipe_tail)

        hist_i = history_update(st["hist"], st["i"], relres, config)
        new = dict(
            x=x_next, r=r_next, s=s_next, p=p, u=u, t=t, y=y_next, z=z,
            w=w, l=l, g=g_next,
            alpha=alpha, zeta=zeta, f=f,
            i=st["i"] + 1, relres=relres,
            converged=jnp.zeros((), bool), breakdown=jnp.zeros((), bool),
            hist=hist_i)
        stopped = dict(st)
        stopped.update(relres=relres, converged=done, breakdown=bad & ~done,
                       hist=hist_i)
        if config.trace_cap:
            trace_i = _trace_row(st, dots, beta, relres, done, bad, config)
            new["trace"] = stopped["trace"] = trace_i
            new["trace_steps"] = stopped["trace_steps"] = \
                st["trace_steps"] + 1
        return tree_select(done | bad, stopped, new)

    st = jax.lax.while_loop(cond, body, state)
    trace = {"buffer": st["trace"], "steps": st["trace_steps"]} \
        if config.trace_cap else None
    return SolveResult(st["x"], st["i"], st["relres"], st["converged"],
                       st["breakdown"], st["hist"],
                       classify_status(st["converged"], st["breakdown"],
                                       st["relres"]), trace)


def _trace_row(st, dots, beta, relres, done, bad, config):
    """Record one single-RHS iteration into the trace ring buffer — all
    channels re-express values the fused phase already computed (XLA
    CSEs the denominators with ``bicgsafe_coefficients``); write-only,
    so the emitted loop math is untouched.  Shared with ssBiCGSafe2.

    The iteration channel is the number of COMPLETED updates when
    relres was measured (the same indexing ``residual_history`` uses):
    the first row is ``(0, 1.0, ...)`` and the terminal row is
    ``(iterations, final relres, ..., CONVERGED/BREAKDOWN)``.
    """
    a_d, b_d, c_d, g_d, h_d = (dots[k] for k in (0, 1, 2, 6, 7))
    first = st["i"] == 0
    status_ch = jnp.where(done, SolveStatus.CONVERGED.value,
                          jnp.where(bad, SolveStatus.BREAKDOWN.value,
                                    SolveStatus.RUNNING.value))
    return trace_record(st["trace"], st["i"], (
        st["i"], relres,
        st["zeta"] * st["f"],
        g_d + beta * h_d,
        jnp.where(first, a_d, a_d * b_d - c_d * c_d),
        jnp.zeros_like(relres), status_ch))


def pbicgsafe_solve(matvec: Callable,
                    b: jax.Array,
                    x0: Optional[jax.Array] = None,
                    *,
                    config: SolverConfig = SolverConfig(),
                    r0_star: Optional[jax.Array] = None,
                    dot_reduce: DotReduce = identity_reduce,
                    substrate: SubstrateLike = "jnp",
                    precond: PrecondLike = None) -> SolveResult:
    """Solve A x = b with p-BiCGSafe (paper Alg. 3.1).

    ``precond`` runs the left-preconditioned system M^{-1} A x = M^{-1} b
    with the M^{-1}-apply scheduled inside the overlap window of the one
    reduction per iteration (relres/tol are in the preconditioned norm).
    """
    return _pipelined_solve(matvec, b, x0, config, r0_star, dot_reduce,
                            residual_replacement=False, substrate=substrate,
                            precond=precond)


def pbicgsafe_rr_solve(matvec: Callable,
                       b: jax.Array,
                       x0: Optional[jax.Array] = None,
                       *,
                       config: SolverConfig = SolverConfig(),
                       r0_star: Optional[jax.Array] = None,
                       dot_reduce: DotReduce = identity_reduce,
                       substrate: SubstrateLike = "jnp",
                       precond: PrecondLike = None) -> SolveResult:
    """Solve A x = b with p-BiCGSafe-rr (paper Alg. 4.1).

    ``config.rr_epoch`` is the paper's ``m`` (default 100, the paper's
    default), ``config.rr_maxiter`` the cutoff ``M``.  ``precond`` as in
    :func:`pbicgsafe_solve`; the replacement branch recomputes the true
    residual of the *preconditioned* system, so the recurred and replaced
    quantities stay consistent.
    """
    return _pipelined_solve(matvec, b, x0, config, r0_star, dot_reduce,
                            residual_replacement=True, substrate=substrate,
                            precond=precond)
