"""repro.core — the paper's contribution: pipelined BiCGSafe solvers.

FRONT DOOR: :mod:`repro.api` — bind an operator once with
``repro.make_solver(method, op, precond=..., substrate=...)`` and solve
many times from the session (``.solve`` / ``.solve_many`` /
``.init``/``.step_chunk``/``.splice`` / ``.on_mesh``); compiled
programs and built preconditioners are cached by operator content.  The
free functions below keep working verbatim but are deprecated as direct
entry points (one DeprecationWarning per process each).

Public API:

* Solvers (all ``(matvec, b, x0=None, *, config, r0_star, dot_reduce)``):
  - :func:`bicgstab_solve`        BiCGStab            (Alg. 2.1, 2 syncs)
  - :func:`pbicgstab_solve`       pipelined BiCGStab  (Cools-Vanroose, 2 overlapped)
  - :func:`gpbicg_solve`          GPBi-CG             (Alg. 2.2, 3 syncs)
  - :func:`ssbicgsafe2_solve`     ssBiCGSafe2         (Alg. 2.3, 1 sync)
  - :func:`pbicgsafe_solve`       p-BiCGSafe          (Alg. 3.1, 1 overlapped sync)
  - :func:`pbicgsafe_rr_solve`    p-BiCGSafe-rr       (Alg. 4.1)
* Operators: Dense/CSR/ELL/Stencil7.
* Problem generators: :mod:`repro.core.matrices`.
* Distributed driver: :mod:`repro.core.distributed`.
* Compute substrates: every solver takes ``substrate="jnp"|"pallas"``
  (:mod:`repro.core.substrate`) selecting who computes the fused dot /
  vector-update / SpMV phases of the hot loop.
* Multi-RHS: :func:`solve_batched` solves ``A X = B`` for ``(n, m)``
  right-hand sides with per-RHS convergence (per-column ``tol=``
  vectors supported), one reduction per iteration; the iteration is
  also exposed open-loop as :func:`init_state` / :func:`step_chunk` /
  :func:`splice_columns`, which the continuous-batching solve service
  (:mod:`repro.service`) drives.
* Preconditioning: every solver entry point (including the batched and
  distributed drivers) takes ``precond=`` — a name or a
  :class:`repro.precond.Preconditioner` (Jacobi / block-Jacobi / Neumann
  polynomial / SSOR) — running the left-preconditioned system with the
  M^{-1}-apply routed through the substrate and, for the pipelined
  solvers, scheduled inside the overlap window of the single reduction
  (:mod:`repro.precond`).
"""
from repro.precond import (BlockJacobiPreconditioner, JacobiPreconditioner,
                           NeumannPreconditioner, Preconditioner,
                           SSORPreconditioner, block_jacobi, jacobi, neumann,
                           ssor)
import functools as _functools

from ._deprecation import warn_legacy as _warn_legacy
from .types import SolveResult, SolverConfig, identity_reduce
from .linear_operator import (CSROperator, DenseOperator, ELLOperator,
                              Stencil7Operator, as_matvec)
from .substrate import (SUBSTRATES, JnpSubstrate, PallasSubstrate, Substrate,
                        get_substrate)
from .bicgstab import bicgstab_solve
from .cgs import cgs_solve
from .pipelined_bicgstab import pbicgstab_solve
from .gpbicg import gpbicg_solve
from .ssbicgsafe import ssbicgsafe2_solve
from .pipelined_bicgsafe import pbicgsafe_solve, pbicgsafe_rr_solve
from .multirhs import (init_state, solve_batched, splice_columns,
                       step_chunk)


def _legacy_shim(fn, name: str, replacement: str):
    """Wrap a free-function entry point as a deprecated shim.

    The wrapped function keeps working verbatim (the session layer in
    :mod:`repro.api` delegates to the SAME underlying implementation),
    but a direct call announces the front door with one
    DeprecationWarning per process.  Internal/delegating callers are
    silent: the session layer runs under ``internal_use()`` and
    intra-package callers import from the defining modules, which stay
    unwrapped.
    """
    @_functools.wraps(fn)
    def shim(*args, **kwargs):
        _warn_legacy(name, replacement)
        return fn(*args, **kwargs)
    return shim


bicgstab_solve = _legacy_shim(
    bicgstab_solve, "bicgstab_solve", 'repro.make_solver("bicgstab", A)')
cgs_solve = _legacy_shim(
    cgs_solve, "cgs_solve", 'repro.make_solver("cgs", A)')
pbicgstab_solve = _legacy_shim(
    pbicgstab_solve, "pbicgstab_solve", 'repro.make_solver("p-bicgstab", A)')
gpbicg_solve = _legacy_shim(
    gpbicg_solve, "gpbicg_solve", 'repro.make_solver("gpbicg", A)')
ssbicgsafe2_solve = _legacy_shim(
    ssbicgsafe2_solve, "ssbicgsafe2_solve",
    'repro.make_solver("ssbicgsafe2", A)')
pbicgsafe_solve = _legacy_shim(
    pbicgsafe_solve, "pbicgsafe_solve", 'repro.make_solver("p-bicgsafe", A)')
pbicgsafe_rr_solve = _legacy_shim(
    pbicgsafe_rr_solve, "pbicgsafe_rr_solve",
    'repro.make_solver("p-bicgsafe-rr", A)')
solve_batched = _legacy_shim(
    solve_batched, "solve_batched",
    'repro.make_solver("p-bicgsafe", A).solve_many(B)')


def __getattr__(name: str):
    # deprecated alias, same PEP 562 treatment as its twin in
    # core/linear_operator.py: superseded by precond= on a bound session
    if name == "preconditioned_matvec":
        _warn_legacy("repro.core.preconditioned_matvec",
                     "precond= on repro.make_solver(...) "
                     "(or repro.precond.preconditioned_matvec)")
        from repro.precond.base import preconditioned_matvec
        return preconditioned_matvec
    raise AttributeError(
        f"module {__name__!r} has no attribute {name!r}")

SOLVERS = {
    "bicgstab": bicgstab_solve,
    "p-bicgstab": pbicgstab_solve,
    "gpbicg": gpbicg_solve,
    "cgs": cgs_solve,
    "ssbicgsafe2": ssbicgsafe2_solve,
    "p-bicgsafe": pbicgsafe_solve,
    "p-bicgsafe-rr": pbicgsafe_rr_solve,
}

__all__ = [
    "SolveResult", "SolverConfig", "identity_reduce",
    "CSROperator", "DenseOperator", "ELLOperator",
    "Stencil7Operator", "as_matvec", "preconditioned_matvec",
    "Preconditioner", "JacobiPreconditioner", "BlockJacobiPreconditioner",
    "NeumannPreconditioner", "SSORPreconditioner",
    "jacobi", "block_jacobi", "neumann", "ssor",
    "Substrate", "JnpSubstrate", "PallasSubstrate", "SUBSTRATES",
    "get_substrate",
    "bicgstab_solve", "pbicgstab_solve", "gpbicg_solve",
    "ssbicgsafe2_solve", "pbicgsafe_solve", "pbicgsafe_rr_solve",
    "solve_batched", "init_state", "step_chunk", "splice_columns",
    "SOLVERS",
]
