"""p-BiCGStab — communication-hiding pipelined BiCGStab.

Cools & Vanroose, "The communication-hiding pipelined BiCGstab method for
the parallel solution of large unsymmetric linear systems", Parallel
Computing 65:1-20, 2017 (paper reference [10]).  Two reduction phases per
iteration, each overlapped with one of the two matvecs (the Table 3.1
"diamond"):

    phase 1 {(q,y),(y,y), [(q,q) for ||r||]}   overlaps  v_i = A y_i
    phase 2 {(r0*,r),(r0*,w),(r0*,s),(r0*,z)}  overlaps  t_{i+1} = A w_{i+1}
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from ..precond.base import PrecondLike, preconditioned_system
from ._common import init_guess, safe_div, tree_select
from .substrate import SubstrateLike, get_substrate
from .types import (DotReduce, SolveResult, SolverConfig, classify_status,
                    history_init, history_update, identity_reduce)


def pbicgstab_solve(matvec: Callable,
                    b: jax.Array,
                    x0: Optional[jax.Array] = None,
                    *,
                    config: SolverConfig = SolverConfig(),
                    r0_star: Optional[jax.Array] = None,
                    dot_reduce: DotReduce = identity_reduce,
                    substrate: SubstrateLike = "jnp",
                    precond: PrecondLike = None) -> SolveResult:
    """Solve A x = b with pipelined BiCGStab (Cools-Vanroose Alg. 5).

    This is the method the reference presents *preconditioned*: with
    ``precond`` set, the M^{-1}-applies ride inside each matvec and both
    reduction phases keep their overlap with the in-flight
    preconditioned matvec (the dots never read its output).
    """
    sub = get_substrate(substrate)
    matvec, b = preconditioned_system(sub, matvec, b, precond)
    eps = config.breakdown_threshold(b.dtype)
    x = init_guess(b, x0)
    r0 = b - matvec(x) if x0 is not None else b
    rs = r0 if r0_star is None else r0_star.astype(b.dtype)

    w0 = matvec(r0)
    t0 = matvec(w0)
    init = dot_reduce(sub.dots([(r0, r0), (rs, r0), (rs, w0)]))
    norm_r0 = jnp.sqrt(init[0])
    # ||r_0|| == 0: converge at t=0 — and don't report the init-time
    # alpha_0 = 0/0 as a breakdown for an already-solved system.
    conv0 = norm_r0 == 0
    norm_r0 = jnp.where(conv0, jnp.ones_like(norm_r0), norm_r0)
    rho0 = init[1]
    alpha0, bad0 = safe_div(rho0, init[2], eps)

    z0 = jnp.zeros_like(b)
    hist = history_init(config, norm_r0.dtype)
    zero = jnp.zeros((), b.dtype)
    state = dict(
        x=x, r=r0, w=w0, t=t0, p=z0, s=z0, z=z0, v=z0,
        alpha=alpha0, beta=zero, omega=jnp.ones((), b.dtype), rho=rho0,
        rr=init[0],
        i=jnp.zeros((), jnp.int32),
        relres=jnp.where(conv0, 0.0, 1.0).astype(norm_r0.dtype),
        converged=conv0,
        breakdown=bad0 & ~conv0,
        hist=hist)

    def cond(st):
        return (~st["converged"]) & (~st["breakdown"]) & (st["i"] < config.maxiter)

    def body(st):
        relres = jnp.sqrt(jnp.abs(st["rr"])) / norm_r0
        done = relres <= config.tol
        hist_i = history_update(st["hist"], st["i"], relres, config)

        beta, omega_p = st["beta"], st["omega"]
        alpha = st["alpha"]
        r, w, t = st["r"], st["w"], st["t"]

        p = r + beta * (st["p"] - omega_p * st["s"])
        s = w + beta * (st["s"] - omega_p * st["z"])      # == A p
        z = t + beta * (st["z"] - omega_p * st["v"])      # == A s
        q = r - alpha * s
        y = w - alpha * z                                 # == A q

        # --- phase 1 (overlaps v = A z): residual norm folded in ---
        # v_i := A z_i (= A^3 p_i); A y_i is then t_i - alpha v_i, so the
        # dots here depend on none of this iteration's matvec output.
        v = matvec(z)                                     # MV #1
        d1 = dot_reduce(sub.dots([(q, y), (y, y), (q, q)]))
        omega, bad1 = safe_div(d1[0], d1[1], eps)

        x_next = st["x"] + alpha * p + omega * q
        r_next = q - omega * y
        rr_next = d1[2] - 2.0 * omega * d1[0] + omega * omega * d1[1]
        w_next = y - omega * (t - alpha * v)

        # --- phase 2 (overlaps t = A w_next) ---
        t_next = matvec(w_next)                           # MV #2
        d2 = dot_reduce(sub.dots([
            (rs, r_next), (rs, w_next), (rs, s), (rs, z)]))
        rho_next = d2[0]
        beta_next_num = alpha * rho_next
        beta_next, bad2 = safe_div(beta_next_num, omega * st["rho"], eps)
        alpha_den = d2[1] + beta_next * d2[2] - beta_next * omega * d2[3]
        alpha_next, bad3 = safe_div(rho_next, alpha_den, eps)

        bad = bad1 | bad2 | bad3
        new = dict(
            x=x_next, r=r_next, w=w_next, t=t_next, p=p, s=s, z=z, v=v,
            alpha=alpha_next, beta=beta_next, omega=omega, rho=rho_next,
            rr=rr_next,
            i=st["i"] + 1, relres=relres,
            converged=jnp.zeros((), bool), breakdown=bad,
            hist=hist_i)
        stopped = dict(st)
        stopped.update(relres=relres, converged=done, hist=hist_i)
        return tree_select(done, stopped, new)

    st = jax.lax.while_loop(cond, body, state)
    final_relres = jnp.where(st["converged"], st["relres"],
                             jnp.sqrt(jnp.abs(st["rr"])) / norm_r0)
    converged = st["converged"] | (final_relres <= config.tol)
    return SolveResult(st["x"], st["i"], final_relres, converged,
                       st["breakdown"], st["hist"],
                       classify_status(converged, st["breakdown"],
                                       final_relres))
