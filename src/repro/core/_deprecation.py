"""Once-per-process deprecation plumbing for the legacy entry points.

PR 5 made :mod:`repro.api` the front door: bind an operator once with
:func:`repro.make_solver` and solve many times.  The historical free
functions (``*_solve``, ``solve_batched``, the distributed drivers) keep
working verbatim as thin shims, but each one announces its replacement
with a single :class:`DeprecationWarning` per process — not per call, so
a hot loop over a legacy entry point does not drown the user in
warnings, and not silently, so the migration path is discoverable.

The session layer itself delegates to the same underlying functions;
those internal calls are wrapped in :func:`internal_use` so that code
that has already migrated never sees a warning.
"""
from __future__ import annotations

import contextlib
import warnings

_warned: set = set()
_suppress_depth = 0


def warn_legacy(name: str, replacement: str) -> None:
    """Emit one DeprecationWarning per process for the named entry point.

    Silent when called (transitively) from the session layer — a user on
    the new API must never be warned about machinery they did not call.
    """
    if _suppress_depth or name in _warned:
        return
    _warned.add(name)
    warnings.warn(
        f"{name} is deprecated as a direct entry point; bind the operator "
        f"once with {replacement} and reuse the session (compiled programs "
        "and built preconditioners are cached per operator content). "
        "The legacy call keeps working verbatim.",
        DeprecationWarning, stacklevel=3)


@contextlib.contextmanager
def internal_use():
    """Suppress legacy-entry warnings for delegating (already-migrated)
    callers — the session layer and the drivers it builds on."""
    global _suppress_depth
    _suppress_depth += 1
    try:
        yield
    finally:
        _suppress_depth -= 1


def reset_for_testing() -> None:
    """Forget which warnings fired (tests assert once-per-process)."""
    _warned.clear()
