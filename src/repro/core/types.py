"""Common types for the Krylov solver core.

Every solver in ``repro.core`` returns a :class:`SolveResult` and accepts a
:class:`SolverConfig`.  All solvers are pure functions built on
``jax.lax.while_loop`` so they jit, vmap and shard_map cleanly.

:class:`SolveStatus` is the typed outcome vocabulary of the resilience
layer (:mod:`repro.resilience`): every solver now reports WHY it stopped
— converged, out of budget, which denominator broke down, non-finite
state, deadline — as a small int code that lives happily inside device
arrays (per-column ``(m,)`` status vectors in the batched/guarded paths)
and converts to the enum at the host boundary.
"""
from __future__ import annotations

import dataclasses
import enum
from functools import partial
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class SolveStatus(enum.IntEnum):
    """Typed outcome of a solve (or of one column of a batched solve).

    Values are stable small ints so statuses can be carried per column in
    device arrays; ``SolveStatus(int(code))`` recovers the enum host-side.
    ``RUNNING`` only appears on open-loop state packaged mid-flight.

    Failure taxonomy (``is_failure``): the three ``BREAKDOWN_*`` codes
    name the specific denominator of the BiCGSafe coefficient formulas
    that underflowed (guarded paths); ``BREAKDOWN`` is the generic
    pivot-underflow code of the unguarded single-RHS solvers;
    ``NONFINITE`` means NaN/Inf was detected in the iteration state;
    ``STAGNATION`` means the guarded driver gave up on a column whose
    residual stopped improving; ``DEADLINE`` is service-side wall-clock
    expiry.
    """

    RUNNING = 0
    CONVERGED = 1
    MAXITER = 2
    BREAKDOWN = 3        # generic pivot/denominator underflow
    BREAKDOWN_RHO = 4    # beta denominator zeta_{i-1} * f_{i-1} (rho ratio)
    BREAKDOWN_ALPHA = 5  # alpha denominator g + beta * h
    BREAKDOWN_OMEGA = 6  # zeta/eta denominator a*b - c^2 (omega analogue)
    NONFINITE = 7        # NaN/Inf detected in the iteration state
    STAGNATION = 8       # residual stopped improving; recovery exhausted
    DEADLINE = 9         # service wall-clock budget expired

    @property
    def is_failure(self) -> bool:
        return self >= SolveStatus.BREAKDOWN

    @property
    def is_terminal(self) -> bool:
        return self != SolveStatus.RUNNING


def classify_status(converged, breakdown, relres) -> jax.Array:
    """Coarse device-side status from a solver's final flags.

    Used by the unguarded solvers to fill ``SolveResult.status`` at zero
    marginal cost (a few scalar selects AFTER the loop): CONVERGED /
    BREAKDOWN / NONFINITE / MAXITER.  The guarded batched path carries a
    richer per-column code through the iteration instead
    (:mod:`repro.core.multirhs` with ``SolverConfig.guard``).
    """
    converged = jnp.asarray(converged)
    s = jnp.where(converged, SolveStatus.CONVERGED.value,
                  SolveStatus.MAXITER.value)
    s = jnp.where(jnp.asarray(breakdown) & ~converged,
                  SolveStatus.BREAKDOWN.value, s)
    s = jnp.where(~jnp.isfinite(jnp.asarray(relres)) & ~converged,
                  SolveStatus.NONFINITE.value, s)
    return s.astype(jnp.int32)


#: Channel layout of the on-device iteration-trace ring buffer
#: (``SolverConfig.trace_cap``; see :mod:`repro.observe`).  Every channel
#: is a value the fused (9/11, m) reduction phase ALREADY computes — the
#: trace is a write-only consumer, so recording adds zero
#: synchronizations and no dependency edge to the in-flight matvec.
#: NOTE: the channel count must never equal
#: :data:`repro.analysis.trace.REDUCE_MARK_DIM` (13) or the fused
#: leading dims 9/11 — those shapes identify reduction phases in the
#: contract passes.
TRACE_CHANNELS = ("iteration", "relres", "rho_denom", "alpha_denom",
                  "omega_denom", "drift", "status")


class SolveResult(NamedTuple):
    """Result of an iterative solve.

    Attributes:
      x: approximate solution vector.
      iterations: number of iterations executed (int32 scalar).
      relres: final relative residual norm ||r_i|| / ||r_0|| (recurred).
      converged: bool scalar — relres <= tol within maxiter.
      breakdown: bool scalar — a pivot/denominator underflowed (solver
        stopped making progress for numerical reasons, not convergence).
      residual_history: optional (maxiter+1,) array of relative residual
        norms (filled with NaN past ``iterations``) when
        ``SolverConfig.record_history`` is set; otherwise a (0,) array.
      status: typed outcome — an int32 :class:`SolveStatus` code (scalar,
        or (m,) per column for batched solves).  Every solver fills it;
        the default ``None`` only exists so externally constructed
        results (and the pre-status pickles/tests) stay valid.
      trace: iteration-trace payload when ``SolverConfig.trace_cap`` was
        set — inside jit a ``{"buffer": (cap, C[, m]), "steps": int32}``
        dict (the raw ring buffer; channels per
        :data:`TRACE_CHANNELS`); the session layer wraps it into a
        :class:`repro.observe.ConvergenceTrace` at the host boundary.
        ``None`` when tracing is off (the default) or the solver does
        not support it.
    """

    x: jax.Array
    iterations: jax.Array
    relres: jax.Array
    converged: jax.Array
    breakdown: jax.Array
    residual_history: jax.Array
    status: Any = None
    trace: Any = None


@dataclasses.dataclass(frozen=True)
class SolverConfig:
    """Static configuration for a solve (hashable; closed over at trace time).

    Attributes:
      tol: relative residual tolerance (paper uses 1e-8).
      maxiter: iteration cap (paper uses 1e4).
      record_history: record per-iteration relative residuals (costs a
        (maxiter+1,) buffer; used by the convergence benchmarks).
      rr_epoch: residual-replacement epoch ``m`` (p-BiCGSafe-rr only).
      rr_maxiter: residual-replacement cutoff ``M`` (p-BiCGSafe-rr only).
      breakdown_eps: |denominator| threshold treated as breakdown.
      guard: carry per-column health scalars through the fused dot phase
        (batched p-BiCGSafe only).  The (9, m) reduction becomes a
        (11, m) reduction — same single communication phase, still no
        dependency edge to the in-flight matvec — and the state gains
        typed per-column status codes plus drift/stagnation monitors
        that :class:`repro.resilience.GuardedSolver` reads at chunk
        boundaries.  Off by default; the unguarded program is bit-for-bit
        unchanged.
      stagnation_window: with ``guard``, flag a column as stagnant after
        this many consecutive iterations without improving its best
        relative residual (0 disables stagnation detection).
      drift_scale: with ``guard``, trip the drift monitor when the
        accumulated Cools/van-der-Vorst–Ye rounding-error bound on the
        recurred-vs-true residual gap exceeds
        ``drift_scale * tol * ||r_0||`` — i.e. when the drift could
        corrupt the *convergence decision* itself, which is when
        residual replacement pays.  0 → 1.0 (replace once the bound
        reaches the absolute tolerance).
      trace_cap: capacity of the on-device iteration-trace ring buffer
        (0 — the default — disables tracing; the emitted program is
        bit-for-bit the untraced one).  When set, the loop state carries
        a ``(trace_cap, len(TRACE_CHANNELS)[, m])`` buffer recording
        per-iteration scalars the fused reduction already computes
        (relres, the rho/alpha/omega denominators, the Cools drift
        bound, status) — write-only, zero extra synchronizations, no
        new dependency edge (contract-verified; see
        :mod:`repro.observe`).  Iterations past the cap wrap around:
        the buffer keeps the LAST ``trace_cap`` iterations.
    """

    tol: float = 1e-8
    maxiter: int = 10_000
    record_history: bool = False
    rr_epoch: int = 100
    rr_maxiter: int = 10_000
    breakdown_eps: float = 0.0  # 0 → use dtype-scaled default
    guard: bool = False
    stagnation_window: int = 0
    drift_scale: float = 0.0  # 0 → 1.0 (bound reaches the abs tolerance)
    trace_cap: int = 0  # 0 → no iteration tracing

    def breakdown_threshold(self, dtype) -> float:
        if self.breakdown_eps:
            return self.breakdown_eps
        return float(jnp.finfo(dtype).tiny) * 1e4

    def drift_threshold(self, dtype) -> float:
        del dtype
        return self.drift_scale if self.drift_scale else 1.0


# A matvec is any callable Array -> Array preserving shape/dtype.
MatVec = Callable[[jax.Array], jax.Array]

# A dot-combiner: given a list of local partial sums, produce global sums.
# In the single-process solvers this is the identity; the distributed
# driver replaces it with a single fused psum (one global reduction --
# the paper's "single synchronization phase").
DotReduce = Callable[[jax.Array], jax.Array]


def identity_reduce(partials: jax.Array) -> jax.Array:
    return partials


def per_column(value, m: int, dtype, *, name: str = "tol") -> jax.Array:
    """Broadcast a per-solve setting to a per-column ``(m,)`` vector.

    Heterogeneous multi-RHS solves (``repro.core.multirhs``, and the
    continuous-batching engine in :mod:`repro.service` built on it) carry
    ``tol`` / ``maxiter`` per column: a scalar (e.g. the
    :class:`SolverConfig` default) broadcasts to all m columns, an ``(m,)``
    vector is taken as-is, and anything else is a loud shape error — a
    silently broadcast ``(k,)`` vector of the wrong length would assign
    tolerances to the wrong requests.
    """
    arr = jnp.asarray(value, dtype=dtype)
    if arr.ndim == 0:
        return jnp.full((m,), arr, dtype=dtype)
    if arr.shape != (m,):
        raise ValueError(
            f"per-column {name} must be a scalar or shape ({m},); "
            f"got shape {arr.shape}")
    return arr


def history_init(cfg: SolverConfig, n_dtype) -> jax.Array:
    if cfg.record_history:
        return jnp.full((cfg.maxiter + 1,), jnp.nan, dtype=n_dtype)
    return jnp.zeros((0,), dtype=n_dtype)


def history_update(hist: jax.Array, i: jax.Array, relres: jax.Array,
                   cfg: SolverConfig) -> jax.Array:
    if cfg.record_history:
        return hist.at[i].set(relres.astype(hist.dtype))
    return hist


def trace_init(cfg: SolverConfig, rdtype, m: Optional[int] = None
               ) -> jax.Array:
    """Fresh NaN-filled iteration-trace ring buffer: ``(cap, C)`` for a
    single-RHS solve, ``(cap, C, m)`` batched (C = len(TRACE_CHANNELS)).
    Call only when ``cfg.trace_cap > 0``."""
    shape = (cfg.trace_cap, len(TRACE_CHANNELS))
    if m is not None:
        shape += (m,)
    return jnp.full(shape, jnp.nan, rdtype)


def trace_record(buf: jax.Array, i: jax.Array, channels) -> jax.Array:
    """Write one stacked channel row at ring slot ``i % cap``.

    ``channels`` is a sequence matching :data:`TRACE_CHANNELS`; each
    entry is a scalar (single-RHS) or (m,) vector.  Pure data movement
    of values the iteration already computed — no reductions, so the
    contract passes see nothing new.
    """
    row = jnp.stack([jnp.asarray(c).astype(buf.dtype) for c in channels])
    return buf.at[jnp.mod(i, buf.shape[0])].set(row)
