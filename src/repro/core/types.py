"""Common types for the Krylov solver core.

Every solver in ``repro.core`` returns a :class:`SolveResult` and accepts a
:class:`SolverConfig`.  All solvers are pure functions built on
``jax.lax.while_loop`` so they jit, vmap and shard_map cleanly.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class SolveResult(NamedTuple):
    """Result of an iterative solve.

    Attributes:
      x: approximate solution vector.
      iterations: number of iterations executed (int32 scalar).
      relres: final relative residual norm ||r_i|| / ||r_0|| (recurred).
      converged: bool scalar — relres <= tol within maxiter.
      breakdown: bool scalar — a pivot/denominator underflowed (solver
        stopped making progress for numerical reasons, not convergence).
      residual_history: optional (maxiter+1,) array of relative residual
        norms (filled with NaN past ``iterations``) when
        ``SolverConfig.record_history`` is set; otherwise a (0,) array.
    """

    x: jax.Array
    iterations: jax.Array
    relres: jax.Array
    converged: jax.Array
    breakdown: jax.Array
    residual_history: jax.Array


@dataclasses.dataclass(frozen=True)
class SolverConfig:
    """Static configuration for a solve (hashable; closed over at trace time).

    Attributes:
      tol: relative residual tolerance (paper uses 1e-8).
      maxiter: iteration cap (paper uses 1e4).
      record_history: record per-iteration relative residuals (costs a
        (maxiter+1,) buffer; used by the convergence benchmarks).
      rr_epoch: residual-replacement epoch ``m`` (p-BiCGSafe-rr only).
      rr_maxiter: residual-replacement cutoff ``M`` (p-BiCGSafe-rr only).
      breakdown_eps: |denominator| threshold treated as breakdown.
    """

    tol: float = 1e-8
    maxiter: int = 10_000
    record_history: bool = False
    rr_epoch: int = 100
    rr_maxiter: int = 10_000
    breakdown_eps: float = 0.0  # 0 → use dtype-scaled default

    def breakdown_threshold(self, dtype) -> float:
        if self.breakdown_eps:
            return self.breakdown_eps
        return float(jnp.finfo(dtype).tiny) * 1e4


# A matvec is any callable Array -> Array preserving shape/dtype.
MatVec = Callable[[jax.Array], jax.Array]

# A dot-combiner: given a list of local partial sums, produce global sums.
# In the single-process solvers this is the identity; the distributed
# driver replaces it with a single fused psum (one global reduction --
# the paper's "single synchronization phase").
DotReduce = Callable[[jax.Array], jax.Array]


def identity_reduce(partials: jax.Array) -> jax.Array:
    return partials


def per_column(value, m: int, dtype, *, name: str = "tol") -> jax.Array:
    """Broadcast a per-solve setting to a per-column ``(m,)`` vector.

    Heterogeneous multi-RHS solves (``repro.core.multirhs``, and the
    continuous-batching engine in :mod:`repro.service` built on it) carry
    ``tol`` / ``maxiter`` per column: a scalar (e.g. the
    :class:`SolverConfig` default) broadcasts to all m columns, an ``(m,)``
    vector is taken as-is, and anything else is a loud shape error — a
    silently broadcast ``(k,)`` vector of the wrong length would assign
    tolerances to the wrong requests.
    """
    arr = jnp.asarray(value, dtype=dtype)
    if arr.ndim == 0:
        return jnp.full((m,), arr, dtype=dtype)
    if arr.shape != (m,):
        raise ValueError(
            f"per-column {name} must be a scalar or shape ({m},); "
            f"got shape {arr.shape}")
    return arr


def history_init(cfg: SolverConfig, n_dtype) -> jax.Array:
    if cfg.record_history:
        return jnp.full((cfg.maxiter + 1,), jnp.nan, dtype=n_dtype)
    return jnp.zeros((0,), dtype=n_dtype)


def history_update(hist: jax.Array, i: jax.Array, relres: jax.Array,
                   cfg: SolverConfig) -> jax.Array:
    if cfg.record_history:
        return hist.at[i].set(relres.astype(hist.dtype))
    return hist
