"""Batched multi-RHS solves: A X = B for (n, m) right-hand sides.

Krasnopolsky ("Revisiting Performance of BiCGStab Methods for Solving
Systems with Multiple Right-Hand Sides") observes that blocked BiCGStab
variants win not by sharing the Krylov space but by *amortizing memory
traffic and reduction latency* across right-hand sides: every vector phase
streams (n, m) blocks instead of m separate (n,) vectors, and the m
synchronization phases collapse into one.  Applied to the paper's
pipelined single-synchronization methods this is maximal leverage: the
batched p-BiCGSafe iteration below performs ONE ``dot_reduce`` of a
``(9, m)`` partial block per iteration — the same single message as the
m=1 solver, now carrying the inner products of all m systems — and the
fused-dots phase still reads only ``{s, y, r, t_prev, rs}``, preserving
the no-dependency-edge overlap with the in-flight block matvec.

Each column keeps its own coefficients (alpha_j, beta_j, zeta_j, eta_j) —
this is the "individual" blocked mode: convergence per column is
identical to m independent solves in exact arithmetic, and columns that
converge (or break down) early are frozen by masking while the rest
continue.  ``benchmarks/bench_multirhs.py`` measures batched vs. looped.

The whole hot loop routes through the compute substrate
(:mod:`repro.core.substrate`): on ``substrate="pallas"`` the fused
(9, m) dots, the (n, m) update phase (with the convergence mask applied
in-kernel) and the block-ELL SpMV are the hand-tiled kernels, and on the
distributed driver (:func:`repro.core.distributed
.distributed_stencil_solve_batched`) the same iteration runs per shard
with the (9, m) partial block reduced by ONE psum.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from ..precond.base import PrecondLike, wrap_block_preconditioned
from ._common import bicgsafe_coefficients, pipelined_recurrence_tail
from .substrate import SubstrateLike, get_substrate
from .types import (DotReduce, SolveResult, SolverConfig, identity_reduce)


def _masked(mask_cols, new, old):
    """Per-column select: mask is (m,); operands are (m,) or (n, m).

    ``new`` may arrive with the trailing RHS axis squeezed away — e.g. a
    user ``dot_reduce`` that collapses the degenerate ``(9, 1)`` partial
    block to ``(9,)`` for m=1 turns every coefficient into a scalar.  Such
    lower-rank ``new`` values are broadcast back up to ``old``'s shape
    instead of raising: the state block's shape is authoritative.
    """
    if new.ndim < old.ndim and old.shape[-1] == 1:  # squeezed m=1 only
        new = jnp.broadcast_to(
            new.reshape(new.shape + (1,) * (old.ndim - new.ndim)),
            old.shape)
    elif new.ndim != old.ndim:
        # m>1 stays a loud failure: a dot_reduce that collapses the RHS
        # axis of a real block would otherwise broadcast one column's
        # coefficients to all m
        raise ValueError(
            f"rank mismatch: new {new.shape} vs old {old.shape}")
    m = mask_cols if new.ndim == 1 else mask_cols[None, :]
    return jnp.where(m, new, old)


def batched_matvec(matvec: Callable) -> Callable:
    """Lift a single-vector matvec (n,)->(n,) to (n, m) column blocks."""
    return jax.vmap(matvec, in_axes=1, out_axes=1)


def solve_batched(matvec: Callable,
                  B: jax.Array,
                  X0: Optional[jax.Array] = None,
                  *,
                  config: SolverConfig = SolverConfig(),
                  r0_star: Optional[jax.Array] = None,
                  dot_reduce: DotReduce = identity_reduce,
                  substrate: SubstrateLike = "jnp",
                  blocked: bool = False,
                  precond: PrecondLike = None) -> SolveResult:
    """Solve A X = B with p-BiCGSafe for all m columns of B at once.

    Args:
      matvec: single-vector matvec (n,) -> (n,); lifted to column blocks
        by the substrate (vmap, or the block-ELL kernel for banded ELL
        operators on the pallas substrate).  May also be an operator
        accepted by the substrate.
      B: (n, m) right-hand sides.
      X0: optional (n, m) initial guesses.
      config/r0_star/dot_reduce/substrate: as for the single-RHS solvers;
        ``r0_star`` is a single (n,) shadow vector shared by all columns
        or an (n, m) block of per-column shadows.
      blocked: the given ``matvec`` already maps (n, m) column blocks to
        (n, m) — used by the distributed driver, whose halo-exchange
        matvec streams whole blocks (one ppermute cascade for all m).
      precond: optional left preconditioner (name or
        :class:`repro.precond.Preconditioner`): the solve runs on
        M^{-1} A with M^{-1} B, every column through the SAME M^{-1}
        (its apply is column-batched, in-kernel for block-Jacobi on the
        pallas substrate), still ONE (9, m) reduction per iteration.
        With ``blocked=True`` pass an instance — name specs need the
        operator object to build from.

    Returns a :class:`SolveResult` with column-batched fields: ``x`` is
    (n, m); ``iterations``, ``relres``, ``converged``, ``breakdown`` are
    (m,); ``residual_history`` is (maxiter+1, m) when recorded.

    One ``dot_reduce`` call per iteration regardless of m (the (9, m)
    partial block is one message), plus one for ||r_0||.  The whole
    per-iteration vector phase — fused dots, update phase, block SpMV —
    runs through the substrate, so ``substrate="pallas"`` executes it on
    the hand-tiled (n, m) kernels with the per-column convergence mask
    applied in-kernel.
    """
    if B.ndim != 2:
        raise ValueError(f"B must be (n, m); got shape {B.shape}")
    sub = get_substrate(substrate)
    bmv = matvec if blocked else sub.as_block_matvec(matvec)
    bmv, B = wrap_block_preconditioned(sub, bmv, B, precond, matvec)
    n, m = B.shape
    eps = config.breakdown_threshold(B.dtype)

    X = jnp.zeros_like(B) if X0 is None else X0.astype(B.dtype)
    R0 = B - bmv(X) if X0 is not None else B
    if r0_star is None:
        RS = R0
    else:
        RS = r0_star.astype(B.dtype)
        if RS.ndim == 1:
            RS = jnp.broadcast_to(RS[:, None], B.shape)
    S0 = bmv(R0)                                  # block MV (init): A R_0

    norm_r0 = jnp.sqrt(dot_reduce(sub.dots([(R0, R0)]))[0])   # (m,)
    Z0 = jnp.zeros_like(B)
    ones_m = jnp.ones((m,), B.dtype)
    if config.record_history:
        hist = jnp.full((config.maxiter + 1, m), jnp.nan, norm_r0.dtype)
    else:
        hist = jnp.zeros((0, m), norm_r0.dtype)

    state = dict(
        x=X, r=R0, s=S0, p=Z0, u=Z0, t=Z0, y=Z0, z=Z0, w=Z0, l=Z0, g=Z0,
        alpha=jnp.zeros((m,), B.dtype), zeta=ones_m, f=ones_m,
        i=jnp.zeros((), jnp.int32),
        iterations=jnp.zeros((m,), jnp.int32),
        relres=jnp.ones((m,), norm_r0.dtype),
        converged=jnp.zeros((m,), bool), breakdown=jnp.zeros((m,), bool),
        hist=hist)

    def cond(st):
        active = (~st["converged"]) & (~st["breakdown"])
        return jnp.any(active) & (st["i"] < config.maxiter)

    def body(st):
        r, s, y, t_prev = st["r"], st["s"], st["y"], st["t"]
        active = (~st["converged"]) & (~st["breakdown"])          # (m,)

        # Block MV and the single fused (9, m) reduction — mutually
        # independent, exactly as in the m=1 pipelined iteration.
        As = bmv(s)
        dots = dot_reduce(sub.bicgsafe_dots(s, y, r, t_prev, RS))

        beta, alpha, zeta, eta, f, rr, bad = bicgsafe_coefficients(
            dots, st["i"], st["alpha"], st["zeta"], st["f"], eps)   # (m,)
        relres = jnp.sqrt(jnp.abs(rr)) / norm_r0
        done = relres <= config.tol

        # Per-RHS freeze mask: only active-and-unfinished columns advance;
        # converged / broken-down columns stay at their final state.
        advance = active & ~done & ~bad               # (m,)

        # Blocked vector-update phase through the substrate (the (m,)
        # coefficients broadcast over the (n, m) column blocks).  The
        # convergence mask rides into the phase — on the pallas substrate
        # frozen columns write their input tiles back inside the kernel,
        # so no second (n, m) masking pass is needed for these outputs.
        upd = sub.axpy_phase(
            dict(r=r, p=st["p"], u=st["u"], t=t_prev, y=y, z=st["z"],
                 s=s, l=st["l"], g=st["g"], w=st["w"], x=st["x"], As=As),
            (alpha, beta, zeta, eta), mask=advance)
        p, u, q, w, t = (upd[k] for k in ("p", "u", "q", "w", "t"))
        z, y_next, x_next, r_next = (
            upd[k] for k in ("z", "y", "x", "r"))

        Aw = bmv(w)                                   # block MV #2
        l, g_next, s_next = pipelined_recurrence_tail(
            q, s, As, st["g"], Aw, alpha, zeta, eta)

        # The recurrence tail (l, g, s) and the scalar carries have no
        # in-kernel mask — freeze them here.
        upd = lambda new, old: _masked(advance, new, old)  # noqa: E731
        relres_out = _masked(active, relres, st["relres"])
        if config.record_history:
            hist_i = st["hist"].at[st["i"]].set(
                jnp.where(active, relres_out.astype(st["hist"].dtype),
                          st["hist"][st["i"]]))
        else:
            hist_i = st["hist"]

        return dict(
            x=x_next, r=r_next, s=upd(s_next, s),
            p=p, u=u, t=t, y=y_next, z=z, w=w,
            l=upd(l, st["l"]), g=upd(g_next, st["g"]),
            alpha=upd(alpha, st["alpha"]), zeta=upd(zeta, st["zeta"]),
            f=upd(f, st["f"]),
            i=st["i"] + 1,
            iterations=jnp.where(advance, st["i"] + 1, st["iterations"]),
            relres=relres_out,
            converged=st["converged"] | (active & done),
            breakdown=st["breakdown"] | (active & bad & ~done),
            hist=hist_i)

    st = jax.lax.while_loop(cond, body, state)
    return SolveResult(st["x"], st["iterations"], st["relres"],
                       st["converged"], st["breakdown"], st["hist"])
